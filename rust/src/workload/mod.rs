//! Workload substrate: job taxonomy, the synthetic Gavel-style
//! throughput oracle, arrival traces, and the Ψ feature encoding.
//!
//! The paper evaluates on the Gavel dataset \[9\]: measured throughputs of
//! deep-learning jobs (Table 2) on six accelerator types, solo and
//! pairwise co-located. That dataset is not redistributable here, so
//! [`gavel`] provides a calibrated synthetic oracle with the same
//! *structure* (see DESIGN.md §Substitution): per-family × per-GPU
//! affinity (the inter-GPU correlation P2 exploits), batch-size
//! throughput curves (the similarity P1's nearest-neighbour step
//! exploits), and contention-shaped co-location interference.

pub mod encoding;
pub mod families;
pub mod gavel;
pub mod gavel_csv;
pub mod serving;
pub mod trace;

pub use encoding::{accel_onehot, psi, ACCEL_DIM, PSI_DIM};
pub use families::{AccelType, ModelFamily, ACCEL_TYPES, FAMILIES};
pub use gavel::ThroughputOracle;
pub use gavel_csv::ThroughputTable;
pub use trace::{Trace, TraceConfig, TraceEvent};

/// Unique job identifier (monotonic per trace).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct JobId(pub u32);

impl std::fmt::Display for JobId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "j{}", self.0)
    }
}

/// Which half of the paper's workload space a job belongs to: batch
/// training (throughput-SLO, finite work) or online inference serving
/// (request-rate + latency-SLO, replica-scaled). The paper's system
/// "allocates resources to incoming training or inference requests";
/// this enum is how the rest of the stack branches on that.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum JobKind {
    /// Batch training: a throughput floor T̄_j and finite remaining work.
    #[default]
    Training,
    /// Latency-SLO serving: a diurnal request rate served by 1..R
    /// replicas; see [`InferenceSpec`] and [`serving`].
    Inference,
}

/// Serving profile of an inference job ([`JobKind::Inference`]): the
/// request-arrival process and the latency SLO. Request rates follow a
/// diurnal sine, `λ(t) = base_rate · (1 + A · sin(2π (t + φ) / 86400))`,
/// the shape production inference traffic overwhelmingly has.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct InferenceSpec {
    /// Mean request arrival rate λ̄ in requests/second.
    pub base_rate: f64,
    /// Diurnal modulation amplitude A ∈ [0, 1).
    pub diurnal_amplitude: f64,
    /// Diurnal phase offset φ in seconds.
    pub diurnal_phase_s: f64,
    /// Latency SLO: target mean sojourn (queueing + service) seconds.
    pub latency_slo_s: f64,
}

impl InferenceSpec {
    /// Peak request rate over the diurnal cycle, `λ̄ · (1 + A)`.
    pub fn peak_rate(&self) -> f64 {
        self.base_rate * (1.0 + self.diurnal_amplitude)
    }
}

/// Priority tier of a job. Tiers order the scheduler's sympathies under
/// contention: the ILP weights a tier's SLO slack by
/// [`Priority::weight`], and with preemption enabled a higher-tier
/// arrival may suspend lower-tier victims to get capacity
/// ([`crate::cluster::PlacementOp::Suspend`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord, Default)]
pub enum Priority {
    /// Best-effort: cheapest to violate, first to be preempted.
    Best,
    /// The default tier (and what every pre-priority trace ran as).
    #[default]
    Standard,
    /// Latency- or deadline-critical: its slack is priced 4× Standard
    /// and it may preempt lower tiers when capacity is tight.
    Critical,
}

impl Priority {
    /// Every tier, in ascending order (index order of the per-tier
    /// report accumulators).
    pub const ALL: [Priority; 3] = [Priority::Best, Priority::Standard, Priority::Critical];

    /// Stable wire/snapshot/config key.
    pub fn key(self) -> &'static str {
        match self {
            Priority::Best => "best",
            Priority::Standard => "standard",
            Priority::Critical => "critical",
        }
    }

    pub fn from_key(s: &str) -> crate::Result<Self> {
        match s {
            "best" => Ok(Priority::Best),
            "standard" => Ok(Priority::Standard),
            "critical" => Ok(Priority::Critical),
            other => anyhow::bail!("unknown priority {other:?} (want best|standard|critical)"),
        }
    }

    /// Index into `[best, standard, critical]` accumulators.
    pub fn index(self) -> usize {
        match self {
            Priority::Best => 0,
            Priority::Standard => 1,
            Priority::Critical => 2,
        }
    }

    /// Multiplier on this tier's SLO-slack penalty in the Problem-1
    /// objective. `Standard` is exactly 1.0 so priority-free workloads
    /// price bit-identically to the pre-priority objective.
    pub fn weight(self) -> f64 {
        match self {
            Priority::Best => 0.25,
            Priority::Standard => 1.0,
            Priority::Critical => 4.0,
        }
    }
}

/// A deep-learning job as the scheduler sees it (paper §2.2: the
/// attribute vector Ψ_j is derived from these fields).
#[derive(Debug, Clone, PartialEq)]
pub struct JobSpec {
    pub id: JobId,
    pub family: ModelFamily,
    pub batch_size: u32,
    /// Replication factor (fixed at 1 in the paper's study).
    pub replication: u32,
    /// Minimum required throughput T̄_j, normalized to [0, 1]. Zero for
    /// inference jobs — their requirement is the latency SLO instead.
    pub min_throughput: f64,
    /// Distributability D_j: max number of accelerators (constraint 2c).
    /// For inference jobs this is the replica cap R_j; for elastic
    /// training jobs it is the top of the elastic accel range.
    pub distributability: u32,
    /// Remaining work in normalized-throughput · seconds. For inference
    /// jobs: remaining serving lifetime in *placed* seconds.
    pub work: f64,
    /// Priority tier (see [`Priority`]; `Standard` reproduces the
    /// pre-priority behaviour everywhere).
    pub priority: Priority,
    /// Elastic training: the coordinator's monitor-tick path may grow or
    /// shrink this job's accelerator count within `1..=distributability`
    /// (mirroring the inference replica autoscaler), and a pure
    /// grow/shrink is not billed as a migration. Ignored for inference
    /// jobs (their replicas are always elastic).
    pub elastic: bool,
    /// Serving profile when this is an inference job; `None` = training.
    pub inference: Option<InferenceSpec>,
}

impl JobSpec {
    /// Ψ_j attribute vector for the estimator networks.
    pub fn psi(&self) -> [f32; PSI_DIM] {
        encoding::psi(self.family, self.batch_size, self.replication)
    }

    /// Training or inference (see [`JobKind`]).
    pub fn kind(&self) -> JobKind {
        if self.inference.is_some() {
            JobKind::Inference
        } else {
            JobKind::Training
        }
    }

    /// Whether this job is a latency-SLO serving job.
    pub fn is_inference(&self) -> bool {
        self.inference.is_some()
    }

    /// Instantaneous request-arrival rate λ(t) in requests/second
    /// (0 for training jobs).
    pub fn request_rate_at(&self, t_s: f64) -> f64 {
        match self.inference {
            None => 0.0,
            Some(inf) => {
                let phase = std::f64::consts::TAU * (t_s + inf.diurnal_phase_s) / 86_400.0;
                (inf.base_rate * (1.0 + inf.diurnal_amplitude * phase.sin())).max(0.0)
            }
        }
    }
}

/// A combination of co-located jobs: the paper restricts |c| ≤ 2.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Combo {
    Solo(JobId),
    Pair(JobId, JobId),
}

impl Combo {
    /// Normalized pair constructor (order-independent).
    pub fn pair(a: JobId, b: JobId) -> Self {
        if a <= b {
            Combo::Pair(a, b)
        } else {
            Combo::Pair(b, a)
        }
    }

    /// |c| — number of jobs in the combination.
    pub fn len(&self) -> usize {
        match self {
            Combo::Solo(_) => 1,
            Combo::Pair(_, _) => 2,
        }
    }

    pub fn is_empty(&self) -> bool {
        false
    }

    pub fn jobs(&self) -> Vec<JobId> {
        match *self {
            Combo::Solo(j) => vec![j],
            Combo::Pair(a, b) => vec![a, b],
        }
    }

    pub fn contains(&self, j: JobId) -> bool {
        match *self {
            Combo::Solo(a) => a == j,
            Combo::Pair(a, b) => a == j || b == j,
        }
    }

    /// The co-runner of `j` in this combination, if any.
    pub fn other(&self, j: JobId) -> Option<JobId> {
        match *self {
            Combo::Solo(_) => None,
            Combo::Pair(a, b) if a == j => Some(b),
            Combo::Pair(a, b) if b == j => Some(a),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn combo_pair_is_order_independent() {
        assert_eq!(Combo::pair(JobId(2), JobId(1)), Combo::pair(JobId(1), JobId(2)));
    }

    #[test]
    fn combo_other() {
        let c = Combo::pair(JobId(1), JobId(2));
        assert_eq!(c.other(JobId(1)), Some(JobId(2)));
        assert_eq!(c.other(JobId(2)), Some(JobId(1)));
        assert_eq!(c.other(JobId(3)), None);
        assert_eq!(Combo::Solo(JobId(1)).other(JobId(1)), None);
    }

    #[test]
    fn job_kind_and_diurnal_rate() {
        let mut j = JobSpec {
            id: JobId(1),
            family: ModelFamily::ResNet18,
            batch_size: 32,
            replication: 1,
            min_throughput: 0.2,
            distributability: 1,
            work: 10.0,
            priority: Priority::Standard,
            elastic: false,
            inference: None,
        };
        assert_eq!(j.kind(), JobKind::Training);
        assert_eq!(j.request_rate_at(0.0), 0.0);
        j.inference = Some(InferenceSpec {
            base_rate: 10.0,
            diurnal_amplitude: 0.5,
            diurnal_phase_s: 0.0,
            latency_slo_s: 0.2,
        });
        assert_eq!(j.kind(), JobKind::Inference);
        assert!(j.is_inference());
        // sine peaks a quarter-day in: λ(21600) = 10 · 1.5
        let peak = j.request_rate_at(21_600.0);
        assert!((peak - 15.0).abs() < 1e-9, "{peak}");
        assert!((j.inference.unwrap().peak_rate() - 15.0).abs() < 1e-12);
        // trough: 10 · 0.5
        assert!((j.request_rate_at(3.0 * 21_600.0) - 5.0).abs() < 1e-9);
        assert_eq!(JobKind::default(), JobKind::Training);
    }

    #[test]
    fn priority_keys_order_and_weights() {
        for p in Priority::ALL {
            assert_eq!(Priority::from_key(p.key()).unwrap(), p);
            assert_eq!(Priority::ALL[p.index()], p);
        }
        // tiers are ordered (preemption compares them) and Standard's
        // weight is exactly 1.0 (priority-free objectives must not move)
        assert!(Priority::Best < Priority::Standard && Priority::Standard < Priority::Critical);
        assert_eq!(Priority::Standard.weight(), 1.0);
        assert!(Priority::Best.weight() < 1.0 && Priority::Critical.weight() > 1.0);
        assert_eq!(Priority::default(), Priority::Standard);
        let err = Priority::from_key("vip").unwrap_err().to_string();
        assert!(err.contains("best|standard|critical"), "{err}");
    }

    #[test]
    fn combo_len_and_contains() {
        assert_eq!(Combo::Solo(JobId(0)).len(), 1);
        let c = Combo::pair(JobId(3), JobId(4));
        assert_eq!(c.len(), 2);
        assert!(c.contains(JobId(3)) && c.contains(JobId(4)) && !c.contains(JobId(5)));
    }
}
