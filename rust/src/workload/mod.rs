//! Workload substrate: job taxonomy, the synthetic Gavel-style
//! throughput oracle, arrival traces, and the Ψ feature encoding.
//!
//! The paper evaluates on the Gavel dataset \[9\]: measured throughputs of
//! deep-learning jobs (Table 2) on six accelerator types, solo and
//! pairwise co-located. That dataset is not redistributable here, so
//! [`gavel`] provides a calibrated synthetic oracle with the same
//! *structure* (see DESIGN.md §Substitution): per-family × per-GPU
//! affinity (the inter-GPU correlation P2 exploits), batch-size
//! throughput curves (the similarity P1's nearest-neighbour step
//! exploits), and contention-shaped co-location interference.

pub mod encoding;
pub mod families;
pub mod gavel;
pub mod gavel_csv;
pub mod trace;

pub use encoding::{accel_onehot, psi, ACCEL_DIM, PSI_DIM};
pub use families::{AccelType, ModelFamily, ACCEL_TYPES, FAMILIES};
pub use gavel::ThroughputOracle;
pub use gavel_csv::ThroughputTable;
pub use trace::{Trace, TraceConfig, TraceEvent};

/// Unique job identifier (monotonic per trace).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct JobId(pub u32);

impl std::fmt::Display for JobId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "j{}", self.0)
    }
}

/// A deep-learning job as the scheduler sees it (paper §2.2: the
/// attribute vector Ψ_j is derived from these fields).
#[derive(Debug, Clone, PartialEq)]
pub struct JobSpec {
    pub id: JobId,
    pub family: ModelFamily,
    pub batch_size: u32,
    /// Replication factor (fixed at 1 in the paper's study).
    pub replication: u32,
    /// Minimum required throughput T̄_j, normalized to [0, 1].
    pub min_throughput: f64,
    /// Distributability D_j: max number of accelerators (constraint 2c).
    pub distributability: u32,
    /// Remaining work in normalized-throughput · seconds.
    pub work: f64,
}

impl JobSpec {
    /// Ψ_j attribute vector for the estimator networks.
    pub fn psi(&self) -> [f32; PSI_DIM] {
        encoding::psi(self.family, self.batch_size, self.replication)
    }
}

/// A combination of co-located jobs: the paper restricts |c| ≤ 2.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Combo {
    Solo(JobId),
    Pair(JobId, JobId),
}

impl Combo {
    /// Normalized pair constructor (order-independent).
    pub fn pair(a: JobId, b: JobId) -> Self {
        if a <= b {
            Combo::Pair(a, b)
        } else {
            Combo::Pair(b, a)
        }
    }

    /// |c| — number of jobs in the combination.
    pub fn len(&self) -> usize {
        match self {
            Combo::Solo(_) => 1,
            Combo::Pair(_, _) => 2,
        }
    }

    pub fn is_empty(&self) -> bool {
        false
    }

    pub fn jobs(&self) -> Vec<JobId> {
        match *self {
            Combo::Solo(j) => vec![j],
            Combo::Pair(a, b) => vec![a, b],
        }
    }

    pub fn contains(&self, j: JobId) -> bool {
        match *self {
            Combo::Solo(a) => a == j,
            Combo::Pair(a, b) => a == j || b == j,
        }
    }

    /// The co-runner of `j` in this combination, if any.
    pub fn other(&self, j: JobId) -> Option<JobId> {
        match *self {
            Combo::Solo(_) => None,
            Combo::Pair(a, b) if a == j => Some(b),
            Combo::Pair(a, b) if b == j => Some(a),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn combo_pair_is_order_independent() {
        assert_eq!(Combo::pair(JobId(2), JobId(1)), Combo::pair(JobId(1), JobId(2)));
    }

    #[test]
    fn combo_other() {
        let c = Combo::pair(JobId(1), JobId(2));
        assert_eq!(c.other(JobId(1)), Some(JobId(2)));
        assert_eq!(c.other(JobId(2)), Some(JobId(1)));
        assert_eq!(c.other(JobId(3)), None);
        assert_eq!(Combo::Solo(JobId(1)).other(JobId(1)), None);
    }

    #[test]
    fn combo_len_and_contains() {
        assert_eq!(Combo::Solo(JobId(0)).len(), 1);
        let c = Combo::pair(JobId(3), JobId(4));
        assert_eq!(c.len(), 2);
        assert!(c.contains(JobId(3)) && c.contains(JobId(4)) && !c.contains(JobId(5)));
    }
}
