//! Online job arrival traces: Poisson arrivals over the Table 2
//! workload grid, with per-job SLOs and durations.

use crate::util::Rng;

use super::families::{ModelFamily, FAMILIES};
use super::gavel::ThroughputOracle;
use super::{JobId, JobSpec};
use crate::workload::families::AccelType;

/// Trace generation parameters.
#[derive(Debug, Clone)]
pub struct TraceConfig {
    /// Number of jobs to generate.
    pub n_jobs: usize,
    /// Mean inter-arrival time in seconds (Poisson process).
    pub mean_interarrival_s: f64,
    /// Mean job work in seconds-at-unit-throughput (exponential).
    pub mean_work_s: f64,
    /// Fraction of a job's *median-GPU solo throughput* demanded as the
    /// minimum throughput SLO T̄_j (paper constraint 2e). Values well
    /// under 1.0 leave the optimizer room to co-locate and down-bin.
    pub slo_fraction: f64,
    /// Max accelerators per job D_j (constraint 2c).
    pub max_distributability: u32,
    pub seed: u64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        Self {
            n_jobs: 40,
            mean_interarrival_s: 60.0,
            mean_work_s: 1800.0,
            slo_fraction: 0.5,
            max_distributability: 2,
            seed: 17,
        }
    }
}

/// A single trace event.
#[derive(Debug, Clone, PartialEq)]
pub enum TraceEvent {
    /// Job arrives at `at` seconds.
    Arrival { at: f64, job: JobSpec },
}

/// A generated arrival trace (sorted by time).
#[derive(Debug, Clone)]
pub struct Trace {
    pub events: Vec<TraceEvent>,
    pub config: TraceConfig,
}

impl Trace {
    /// Generate a trace. The oracle is used to scale each job's SLO to
    /// something feasible on the mid-generation GPU (so SLOs are tight
    /// but satisfiable, as in the paper's setup).
    pub fn generate(cfg: &TraceConfig, oracle: &ThroughputOracle) -> Self {
        let mut rng = Rng::seed_from_u64(cfg.seed ^ 0x7ace);
        let mut events = Vec::with_capacity(cfg.n_jobs);
        let mut t = 0.0f64;
        for i in 0..cfg.n_jobs {
            // exponential inter-arrival
            t += rng.exponential(cfg.mean_interarrival_s);
            let family = FAMILIES[rng.range_usize(0, FAMILIES.len())];
            let batches = family.batch_sizes();
            let batch = batches[rng.range_usize(0, batches.len())];
            let mut job = JobSpec {
                id: JobId(i as u32),
                family,
                batch_size: batch,
                replication: 1,
                min_throughput: 0.0,
                distributability: rng.range_u32_inclusive(1, cfg.max_distributability),
                work: rng.exponential(cfg.mean_work_s),
            };
            // SLO: a fraction of the P100 solo throughput for this job.
            let p100 = oracle.solo(&job, AccelType::P100);
            job.min_throughput = cfg.slo_fraction * p100 * rng.range_f64(0.6, 1.0);
            events.push(TraceEvent::Arrival { at: t, job });
        }
        Self {
            events,
            config: cfg.clone(),
        }
    }

    pub fn jobs(&self) -> impl Iterator<Item = &JobSpec> {
        self.events.iter().map(|TraceEvent::Arrival { job, .. }| job)
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }
}

/// Enumerate the full Table 2 job universe (every family × batch size),
/// used by the dataset builders for the figure benches.
pub fn table2_universe() -> Vec<(ModelFamily, u32)> {
    let mut v = vec![];
    for f in FAMILIES {
        for &b in f.batch_sizes() {
            v.push((f, b));
        }
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_is_deterministic_and_sorted() {
        let oracle = ThroughputOracle::new(1);
        let cfg = TraceConfig::default();
        let a = Trace::generate(&cfg, &oracle);
        let b = Trace::generate(&cfg, &oracle);
        assert_eq!(a.events.len(), cfg.n_jobs);
        let times: Vec<f64> = a
            .events
            .iter()
            .map(|TraceEvent::Arrival { at, .. }| *at)
            .collect();
        assert!(times.windows(2).all(|w| w[0] <= w[1]));
        for (ea, eb) in a.events.iter().zip(&b.events) {
            assert_eq!(ea, eb);
        }
    }

    #[test]
    fn slos_are_feasible_on_some_gpu() {
        // every job's SLO must be below its best solo throughput,
        // otherwise constraint 2e is unsatisfiable even solo on v100.
        let oracle = ThroughputOracle::new(1);
        let trace = Trace::generate(&TraceConfig::default(), &oracle);
        for job in trace.jobs() {
            let best = crate::workload::ACCEL_TYPES
                .iter()
                .map(|&a| oracle.solo(job, a))
                .fold(0.0f64, f64::max);
            assert!(job.min_throughput < best, "{job:?} infeasible");
        }
    }

    #[test]
    fn batch_sizes_come_from_table2() {
        let oracle = ThroughputOracle::new(5);
        let trace = Trace::generate(
            &TraceConfig {
                n_jobs: 200,
                ..Default::default()
            },
            &oracle,
        );
        for job in trace.jobs() {
            assert!(job.family.batch_sizes().contains(&job.batch_size));
        }
    }

    #[test]
    fn universe_size_matches_table2() {
        // 5+5+4+4+4 = 22 (resnet18, resnet50: 5 each; others: 4 each)
        assert_eq!(table2_universe().len(), 22);
    }
}
