//! Online job traces: Poisson arrivals over the Table 2 workload grid
//! with per-job SLOs and durations, plus optional cancellation and
//! accelerator-churn (maintenance/failure) events for the richer
//! scenarios the event-driven driver replays.

use crate::util::Rng;

use super::families::{ModelFamily, FAMILIES};
use super::gavel::ThroughputOracle;
use super::{serving, InferenceSpec, JobId, JobSpec, Priority};
use crate::workload::families::AccelType;

/// Trace generation parameters.
#[derive(Debug, Clone)]
pub struct TraceConfig {
    /// Number of jobs to generate.
    pub n_jobs: usize,
    /// Mean inter-arrival time in seconds (Poisson process).
    pub mean_interarrival_s: f64,
    /// Mean job work in seconds-at-unit-throughput (exponential).
    pub mean_work_s: f64,
    /// Fraction of a job's *median-GPU solo throughput* demanded as the
    /// minimum throughput SLO T̄_j (paper constraint 2e). Values well
    /// under 1.0 leave the optimizer room to co-locate and down-bin.
    pub slo_fraction: f64,
    /// Max accelerators per job D_j (constraint 2c).
    pub max_distributability: u32,
    /// Probability that a job is cancelled by its owner some time after
    /// arriving (0 disables; the cancellation may still race the job's
    /// natural completion, in which case it is a no-op).
    pub cancel_rate: f64,
    /// Expected number of accelerator down/up maintenance cycles over
    /// the arrival horizon (0 disables).
    pub accel_churn: f64,
    /// Probability that an arriving job is an inference-serving job
    /// (latency SLO + diurnal request rate) instead of a training job.
    /// Inference fields draw from their own RNG stream, so 0 keeps the
    /// arrival trace byte-identical to the pre-inference generator.
    pub inference_fraction: f64,
    /// Fraction of arrivals in the `Critical` priority tier. Tier and
    /// elastic draws use their own RNG stream (like inference above),
    /// so all-zero fractions keep traces byte-identical to the
    /// pre-priority generator.
    pub critical_fraction: f64,
    /// Fraction of arrivals in the best-effort tier.
    pub best_fraction: f64,
    /// Probability that a *training* arrival is elastic (grow/shrink
    /// within `1..=distributability` at monitor ticks).
    pub elastic_fraction: f64,
    pub seed: u64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        Self {
            n_jobs: 40,
            mean_interarrival_s: 60.0,
            mean_work_s: 1800.0,
            slo_fraction: 0.5,
            max_distributability: 2,
            cancel_rate: 0.0,
            accel_churn: 0.0,
            inference_fraction: 0.0,
            critical_fraction: 0.0,
            best_fraction: 0.0,
            elastic_fraction: 0.0,
            seed: 17,
        }
    }
}

impl TraceConfig {
    /// The `large` scale preset: ≥ 50k trace events for the
    /// ≥ 1024-accelerator scenario (`ExperimentConfig::large_scale`).
    /// 48k arrivals at a 2 s mean inter-arrival plus ~6% owner
    /// cancellations and a dozen maintenance cycles; mean work of 900
    /// normalized-seconds keeps the steady-state active-job count a few
    /// hundred — heavily loaded but placeable on 1032 instances.
    pub fn large() -> Self {
        Self {
            n_jobs: 48_000,
            mean_interarrival_s: 2.0,
            mean_work_s: 900.0,
            slo_fraction: 0.35,
            max_distributability: 2,
            cancel_rate: 0.06,
            accel_churn: 12.0,
            inference_fraction: 0.0,
            critical_fraction: 0.0,
            best_fraction: 0.0,
            elastic_fraction: 0.0,
            seed: 42,
        }
    }

    /// The `huge` scale preset: ≥ 500k trace events for the
    /// ~10k-accelerator scenario (`ExperimentConfig::preset("huge")`).
    /// 500k arrivals at a 0.5 s mean inter-arrival; mean work of 700
    /// normalized-seconds keeps the steady-state active-job count in
    /// the low thousands — the regime where only the hierarchical
    /// topology keeps per-decision work bounded. CI truncates the job
    /// count via `GOGH_SCALE_JOBS`; the full trace is the bench/soak
    /// shape.
    pub fn huge() -> Self {
        Self {
            n_jobs: 500_000,
            mean_interarrival_s: 0.5,
            mean_work_s: 700.0,
            cancel_rate: 0.05,
            accel_churn: 24.0,
            seed: 43,
            ..Self::large()
        }
    }

    /// The `mixed` preset: roughly one third of arrivals are
    /// latency-SLO inference jobs, the rest training — the smallest
    /// trace that exercises the full train+infer decision path (the CI
    /// mixed-workload smoke runs it at 200 jobs).
    pub fn mixed() -> Self {
        Self {
            n_jobs: 300,
            mean_interarrival_s: 30.0,
            mean_work_s: 900.0,
            slo_fraction: 0.4,
            max_distributability: 2,
            cancel_rate: 0.02,
            accel_churn: 0.0,
            inference_fraction: 0.35,
            critical_fraction: 0.0,
            best_fraction: 0.0,
            elastic_fraction: 0.0,
            seed: 77,
        }
    }

    /// The `serving` preset: a serving-dominated cluster (80% inference
    /// arrivals) — stresses replica autoscaling and the latency ILP
    /// constraint rather than batch packing.
    pub fn serving_heavy() -> Self {
        Self {
            inference_fraction: 0.8,
            n_jobs: 200,
            seed: 78,
            ..Self::mixed()
        }
    }
}

/// A single trace event.
#[derive(Debug, Clone, PartialEq)]
pub enum TraceEvent {
    /// Job arrives at `at` seconds.
    Arrival { at: f64, job: JobSpec },
    /// Job `job` is cancelled by its owner at `at` seconds.
    Cancel { at: f64, job: JobId },
    /// Accelerator instance `accel_index` (modulo the cluster size at
    /// replay time — traces are cluster-agnostic) goes down (`up ==
    /// false`) or returns to service (`up == true`).
    AccelChurn { at: f64, accel_index: usize, up: bool },
}

impl TraceEvent {
    pub fn at(&self) -> f64 {
        match self {
            TraceEvent::Arrival { at, .. }
            | TraceEvent::Cancel { at, .. }
            | TraceEvent::AccelChurn { at, .. } => *at,
        }
    }
}

/// A generated arrival trace (sorted by time).
#[derive(Debug, Clone)]
pub struct Trace {
    pub events: Vec<TraceEvent>,
    pub config: TraceConfig,
}

impl Trace {
    /// Generate a trace. The oracle is used to scale each job's SLO to
    /// something feasible on the mid-generation GPU (so SLOs are tight
    /// but satisfiable, as in the paper's setup).
    pub fn generate(cfg: &TraceConfig, oracle: &ThroughputOracle) -> Self {
        let mut rng = Rng::seed_from_u64(cfg.seed ^ 0x7ace);
        // Inference fields draw from their own stream (like cancels and
        // churn below): training-only traces stay byte-identical for a
        // given seed, and mixing in inference never perturbs the shared
        // arrival-stream draws (times, families, batches, work).
        let mut irng =
            (cfg.inference_fraction > 0.0).then(|| Rng::seed_from_u64(cfg.seed ^ 0x1f5e));
        // Tier/elastic draws get their own stream too: priority-free
        // traces (all fractions zero) never consume from it and stay
        // byte-identical to the pre-priority generator.
        let mut prng = (cfg.critical_fraction > 0.0
            || cfg.best_fraction > 0.0
            || cfg.elastic_fraction > 0.0)
            .then(|| Rng::seed_from_u64(cfg.seed ^ 0x9121));
        let mut events = Vec::with_capacity(cfg.n_jobs);
        let mut t = 0.0f64;
        for i in 0..cfg.n_jobs {
            // exponential inter-arrival
            t += rng.exponential(cfg.mean_interarrival_s);
            let family = FAMILIES[rng.range_usize(0, FAMILIES.len())];
            let batches = family.batch_sizes();
            let batch = batches[rng.range_usize(0, batches.len())];
            let mut job = JobSpec {
                id: JobId(i as u32),
                family,
                batch_size: batch,
                replication: 1,
                min_throughput: 0.0,
                distributability: rng.range_u32_inclusive(1, cfg.max_distributability),
                work: rng.exponential(cfg.mean_work_s),
                priority: Default::default(),
                elastic: false,
                inference: None,
            };
            // SLO: a fraction of the P100 solo throughput for this job.
            let p100 = oracle.solo(&job, AccelType::P100);
            job.min_throughput = cfg.slo_fraction * p100 * rng.range_f64(0.6, 1.0);
            if let Some(irng) = irng.as_mut() {
                if irng.bool(cfg.inference_fraction.clamp(0.0, 1.0)) {
                    // Serving job: rate sized against the job's own P100
                    // service capability (feasible with ≤ 2 mid-range
                    // replicas), SLO a few mean service times, and a
                    // replica cap of 2..4. `work` (drawn above from the
                    // shared stream) becomes the serving lifetime; the
                    // throughput floor moves to the latency SLO.
                    let mu_p100 = serving::service_rate(p100);
                    job.min_throughput = 0.0;
                    job.distributability = irng.range_u32_inclusive(2, 4);
                    job.inference = Some(InferenceSpec {
                        base_rate: mu_p100 * irng.range_f64(0.35, 0.8),
                        diurnal_amplitude: irng.range_f64(0.15, 0.45),
                        diurnal_phase_s: irng.range_f64(0.0, 86_400.0),
                        latency_slo_s: irng.range_f64(4.0, 12.0) / mu_p100.max(1e-9),
                    });
                }
            }
            if let Some(prng) = prng.as_mut() {
                let r = prng.range_f64(0.0, 1.0);
                job.priority = if r < cfg.critical_fraction {
                    Priority::Critical
                } else if r < cfg.critical_fraction + cfg.best_fraction {
                    Priority::Best
                } else {
                    Priority::Standard
                };
                if !job.is_inference() && prng.bool(cfg.elastic_fraction.clamp(0.0, 1.0)) {
                    // elastic training: widen the accel range so the
                    // grow path has somewhere to go
                    job.elastic = true;
                    job.distributability =
                        job.distributability.max(prng.range_u32_inclusive(2, 4));
                }
            }
            events.push(TraceEvent::Arrival { at: t, job });
        }
        // Cancellations / accel churn draw from their own streams so the
        // arrival trace stays byte-identical for a given seed whether or
        // not these scenario knobs are on.
        let horizon = t.max(1.0);
        if cfg.cancel_rate > 0.0 {
            let mut crng = Rng::seed_from_u64(cfg.seed ^ 0xca9c_e1);
            let arrivals: Vec<(f64, JobId)> = events
                .iter()
                .filter_map(|e| match e {
                    TraceEvent::Arrival { at, job } => Some((*at, job.id)),
                    _ => None,
                })
                .collect();
            for (at, job) in arrivals {
                if crng.bool(cfg.cancel_rate.clamp(0.0, 1.0)) {
                    let delay = crng.exponential(0.5 * cfg.mean_work_s).max(1.0);
                    events.push(TraceEvent::Cancel { at: at + delay, job });
                }
            }
        }
        if cfg.accel_churn > 0.0 {
            let mut arng = Rng::seed_from_u64(cfg.seed ^ 0xac41);
            let cycles = cfg.accel_churn.round().max(1.0) as usize;
            // per-index end of the previous outage: cycles on the same
            // instance must not overlap (the driver ignores a Down on an
            // already-down accel, which would silently shrink the outage)
            let mut busy_until: std::collections::HashMap<usize, f64> = Default::default();
            for _ in 0..cycles {
                let accel_index = arng.range_usize(0, 4096);
                let mut down_at = arng.range_f64(0.0, horizon);
                if let Some(&free_at) = busy_until.get(&accel_index) {
                    down_at = down_at.max(free_at + 1.0);
                }
                let outage = arng.exponential(4.0 * cfg.mean_interarrival_s).max(1.0);
                busy_until.insert(accel_index, down_at + outage);
                events.push(TraceEvent::AccelChurn {
                    at: down_at,
                    accel_index,
                    up: false,
                });
                events.push(TraceEvent::AccelChurn {
                    at: down_at + outage,
                    accel_index,
                    up: true,
                });
            }
        }
        // stable sort: same-time events keep generation order (a job's
        // arrival always precedes its own cancellation).
        events.sort_by(|a, b| a.at().total_cmp(&b.at()));
        Self {
            events,
            config: cfg.clone(),
        }
    }

    /// Arriving job specs, in arrival order.
    pub fn jobs(&self) -> impl Iterator<Item = &JobSpec> {
        self.events.iter().filter_map(|e| match e {
            TraceEvent::Arrival { job, .. } => Some(job),
            _ => None,
        })
    }

    /// Number of job arrivals in the trace (the driver's `jobs_total`).
    pub fn n_jobs(&self) -> usize {
        self.jobs().count()
    }

    /// Total number of trace events (arrivals + cancels + churn).
    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }
}

/// Enumerate the full Table 2 job universe (every family × batch size),
/// used by the dataset builders for the figure benches.
pub fn table2_universe() -> Vec<(ModelFamily, u32)> {
    let mut v = vec![];
    for f in FAMILIES {
        for &b in f.batch_sizes() {
            v.push((f, b));
        }
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_is_deterministic_and_sorted() {
        let oracle = ThroughputOracle::new(1);
        let cfg = TraceConfig::default();
        let a = Trace::generate(&cfg, &oracle);
        let b = Trace::generate(&cfg, &oracle);
        assert_eq!(a.events.len(), cfg.n_jobs);
        assert_eq!(a.n_jobs(), cfg.n_jobs);
        let times: Vec<f64> = a.events.iter().map(|e| e.at()).collect();
        assert!(times.windows(2).all(|w| w[0] <= w[1]));
        for (ea, eb) in a.events.iter().zip(&b.events) {
            assert_eq!(ea, eb);
        }
    }

    #[test]
    fn scenario_knobs_do_not_perturb_arrivals() {
        let oracle = ThroughputOracle::new(1);
        let plain = Trace::generate(&TraceConfig::default(), &oracle);
        let rich = Trace::generate(
            &TraceConfig {
                cancel_rate: 0.5,
                accel_churn: 3.0,
                ..Default::default()
            },
            &oracle,
        );
        // identical arrival stream; extra events appended + time-sorted
        let plain_jobs: Vec<_> = plain.jobs().collect();
        let rich_jobs: Vec<_> = rich.jobs().collect();
        assert_eq!(plain_jobs, rich_jobs);
        assert!(rich.len() > plain.len());
        let times: Vec<f64> = rich.events.iter().map(|e| e.at()).collect();
        assert!(times.windows(2).all(|w| w[0] <= w[1]));
        assert_eq!(rich.n_jobs(), plain.n_jobs());
    }

    #[test]
    fn cancellations_follow_their_arrival_and_churn_pairs_up() {
        let oracle = ThroughputOracle::new(2);
        let trace = Trace::generate(
            &TraceConfig {
                n_jobs: 60,
                cancel_rate: 0.7,
                accel_churn: 4.0,
                ..Default::default()
            },
            &oracle,
        );
        let mut cancels = 0;
        for e in &trace.events {
            if let TraceEvent::Cancel { at, job } = e {
                cancels += 1;
                let arrival = trace
                    .events
                    .iter()
                    .find_map(|e| match e {
                        TraceEvent::Arrival { at, job: j } if j.id == *job => Some(*at),
                        _ => None,
                    })
                    .expect("cancel references an arriving job");
                assert!(*at > arrival, "cancel before arrival");
            }
        }
        assert!(cancels > 0, "cancel_rate=0.7 over 60 jobs produced none");
        let downs = trace
            .events
            .iter()
            .filter(|e| matches!(e, TraceEvent::AccelChurn { up: false, .. }))
            .count();
        let ups = trace
            .events
            .iter()
            .filter(|e| matches!(e, TraceEvent::AccelChurn { up: true, .. }))
            .count();
        assert_eq!(downs, ups);
        assert!(downs >= 1);
    }

    #[test]
    fn slos_are_feasible_on_some_gpu() {
        // every job's SLO must be below its best solo throughput,
        // otherwise constraint 2e is unsatisfiable even solo on v100.
        let oracle = ThroughputOracle::new(1);
        let trace = Trace::generate(&TraceConfig::default(), &oracle);
        for job in trace.jobs() {
            let best = crate::workload::ACCEL_TYPES
                .iter()
                .map(|&a| oracle.solo(job, a))
                .fold(0.0f64, f64::max);
            assert!(job.min_throughput < best, "{job:?} infeasible");
        }
    }

    #[test]
    fn batch_sizes_come_from_table2() {
        let oracle = ThroughputOracle::new(5);
        let trace = Trace::generate(
            &TraceConfig {
                n_jobs: 200,
                ..Default::default()
            },
            &oracle,
        );
        for job in trace.jobs() {
            assert!(job.family.batch_sizes().contains(&job.batch_size));
        }
    }

    #[test]
    fn large_preset_reaches_event_floor() {
        let cfg = TraceConfig::large();
        let oracle = ThroughputOracle::new(cfg.seed);
        let trace = Trace::generate(&cfg, &oracle);
        assert!(trace.len() >= 50_000, "only {} events", trace.len());
        assert_eq!(trace.n_jobs(), cfg.n_jobs);
        // cancellations and churn both present, times sorted
        assert!(trace.events.iter().any(|e| matches!(e, TraceEvent::Cancel { .. })));
        assert!(trace.events.iter().any(|e| matches!(e, TraceEvent::AccelChurn { .. })));
        let times: Vec<f64> = trace.events.iter().map(|e| e.at()).collect();
        assert!(times.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn inference_fraction_only_retypes_jobs() {
        // Mixing in inference never perturbs the shared arrival-stream
        // draws: times, families, batches and work are identical to the
        // training-only trace; only kind-specific fields differ.
        let oracle = ThroughputOracle::new(1);
        let plain = Trace::generate(&TraceConfig::default(), &oracle);
        let mixed = Trace::generate(
            &TraceConfig {
                inference_fraction: 0.5,
                ..Default::default()
            },
            &oracle,
        );
        let plain_jobs: Vec<_> = plain.jobs().collect();
        let mixed_jobs: Vec<_> = mixed.jobs().collect();
        assert_eq!(plain_jobs.len(), mixed_jobs.len());
        let mut inference = 0;
        for (p, m) in plain_jobs.iter().zip(&mixed_jobs) {
            assert_eq!(p.id, m.id);
            assert_eq!(p.family, m.family);
            assert_eq!(p.batch_size, m.batch_size);
            assert_eq!(p.work, m.work);
            if m.is_inference() {
                inference += 1;
            } else {
                assert_eq!(p.min_throughput, m.min_throughput);
                assert_eq!(p.distributability, m.distributability);
            }
        }
        assert!(inference > 5, "only {inference} inference jobs at fraction 0.5");
        assert!(inference < 40, "every job became inference");
    }

    #[test]
    fn inference_jobs_are_feasibly_specified() {
        // Every generated serving job must be satisfiable within its
        // replica cap on the best GPU: peak-load pooled capacity from
        // `distributability` v100-class replicas clears the 2e′ floor.
        let oracle = ThroughputOracle::new(3);
        let trace = Trace::generate(&TraceConfig::mixed(), &oracle);
        let mut seen = 0;
        for job in trace.jobs().filter(|j| j.is_inference()) {
            seen += 1;
            let inf = job.inference.unwrap();
            assert!(inf.base_rate > 0.0 && inf.latency_slo_s > 0.0);
            assert!((0.0..1.0).contains(&inf.diurnal_amplitude));
            assert!(job.min_throughput == 0.0, "serving job kept a throughput floor");
            assert!((2..=4).contains(&job.distributability));
            let v100 = oracle.solo(job, AccelType::V100);
            let replicas = job.distributability as usize;
            let mus = vec![crate::workload::serving::service_rate(v100); replicas];
            let peak = inf.peak_rate();
            let w = crate::workload::serving::mmc_sojourn(peak, &mus);
            assert!(
                w <= inf.latency_slo_s,
                "{}: {replicas} v100 replicas give {w:.3} s > SLO {:.3} s",
                job.id,
                inf.latency_slo_s
            );
        }
        assert!(seen > 20, "mixed preset produced only {seen} inference jobs");
    }

    #[test]
    fn priority_fractions_only_retier_jobs() {
        // The tier/elastic stream is separate: a tiered trace keeps the
        // exact arrival times, families, batches, work and SLOs of the
        // priority-free trace; only priority/elastic fields differ.
        let oracle = ThroughputOracle::new(1);
        let plain = Trace::generate(&TraceConfig::default(), &oracle);
        let tiered = Trace::generate(
            &TraceConfig {
                critical_fraction: 0.25,
                best_fraction: 0.35,
                elastic_fraction: 0.4,
                ..Default::default()
            },
            &oracle,
        );
        let mut crit = 0;
        let mut best = 0;
        let mut elastic = 0;
        for (p, m) in plain.jobs().zip(tiered.jobs()) {
            assert_eq!(p.id, m.id);
            assert_eq!(p.family, m.family);
            assert_eq!(p.batch_size, m.batch_size);
            assert_eq!(p.work, m.work);
            assert_eq!(p.min_throughput, m.min_throughput);
            assert_eq!(p.priority, Priority::Standard);
            assert!(!p.elastic);
            match m.priority {
                Priority::Critical => crit += 1,
                Priority::Best => best += 1,
                Priority::Standard => {}
            }
            if m.elastic {
                elastic += 1;
                assert!(!m.is_inference(), "inference jobs are never flagged elastic");
                assert!(m.distributability >= 2, "elastic job with nowhere to grow");
            }
        }
        assert!(crit > 0 && best > 0 && elastic > 0, "{crit}/{best}/{elastic}");
        // all-zero fractions leave the field at the Standard default
        for j in plain.jobs() {
            assert_eq!(j.priority, Priority::Standard);
        }
    }

    #[test]
    fn mixed_and_serving_presets() {
        let m = TraceConfig::mixed();
        assert!(m.inference_fraction > 0.0 && m.inference_fraction < 0.5);
        let s = TraceConfig::serving_heavy();
        assert!(s.inference_fraction > m.inference_fraction);
        let oracle = ThroughputOracle::new(s.seed);
        let t = Trace::generate(&s, &oracle);
        let inf = t.jobs().filter(|j| j.is_inference()).count();
        assert!(inf * 2 > t.n_jobs(), "serving preset is not serving-heavy: {inf}");
    }

    #[test]
    fn universe_size_matches_table2() {
        // 5+5+4+4+4 = 22 (resnet18, resnet50: 5 each; others: 4 each)
        assert_eq!(table2_universe().len(), 22);
    }
}
