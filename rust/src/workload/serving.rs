//! Queueing model for inference serving ([`super::JobKind::Inference`]):
//! replicas of a serving job form an M/M/c-style system — requests
//! arrive at the diurnal rate λ(t) and each replica serves at a rate
//! proportional to its measured/estimated normalized throughput.
//!
//! Two views of the same model live here:
//!
//! * the **closed form** ([`mmc_sojourn`], Erlang-C) — the ground truth
//!   the simulator integrates and the autoscaler reacts to;
//! * the **linearization** ([`effective_min_throughput`]) — the pooled
//!   single-server lower bound `W ≥ 1/(Σμ − λ)` plus a utilization cap,
//!   which turns the latency SLO into an aggregate-capacity floor the
//!   allocation ILP can carry on its existing throughput constraint
//!   (2e′ in `ilp/problem1.rs`). The bound under-states M/M/c waiting,
//!   which is exactly why the monitor-tick autoscaler exists: it closes
//!   the gap with measured latencies.

use super::JobSpec;

/// Requests/second served by one replica at normalized throughput 1.0
/// (the unit bridge between the catalog's throughput currency and
/// request rates).
pub const REQS_PER_UNIT_THROUGHPUT: f64 = 50.0;

/// Utilization cap ρ_max of the ILP linearization: aggregate service
/// capacity must keep λ/Σμ below this even when the 1/SLO term is slack.
pub const RHO_MAX: f64 = 0.85;

/// Multiplicative headroom applied to λ(t) when sizing capacity (absorbs
/// rate drift between allocation events).
pub const LOAD_HEADROOM: f64 = 1.15;

/// Fraction of its placed lifetime an inference job must spend inside
/// its latency SLO to count as "met" in the run report.
pub const SLO_MET_FRACTION: f64 = 0.9;

/// Requests/second one replica serves at the given normalized
/// throughput.
pub fn service_rate(throughput: f64) -> f64 {
    (throughput * REQS_PER_UNIT_THROUGHPUT).max(0.0)
}

/// Erlang-C: probability an arriving request queues in an M/M/c system
/// with `c` equal servers and offered load `a = λ/μ` Erlangs. Returns
/// 1.0 when the system is saturated (`a ≥ c`). Computed through the
/// numerically stable Erlang-B recurrence.
pub fn erlang_c(c: usize, a: f64) -> f64 {
    if c == 0 || a >= c as f64 {
        return 1.0;
    }
    if a <= 0.0 {
        return 0.0;
    }
    let mut b = 1.0; // Erlang-B with zero servers
    for k in 1..=c {
        b = a * b / (k as f64 + a * b);
    }
    let rho = a / c as f64;
    b / (1.0 - rho * (1.0 - b))
}

/// Expected sojourn time (queueing + service, seconds) of an M/M/c
/// system with arrival rate `lambda` (requests/s) and per-replica
/// service rates `mus` (requests/s). Heterogeneous replicas are
/// approximated by `c` equal servers at the mean rate — the standard
/// closed-form surrogate. Returns `INFINITY` when unplaced (`mus`
/// empty) or saturated (`λ ≥ Σμ`).
pub fn mmc_sojourn(lambda: f64, mus: &[f64]) -> f64 {
    let total: f64 = mus.iter().sum();
    if mus.is_empty() || total <= 0.0 {
        return f64::INFINITY;
    }
    let c = mus.len();
    let mu = total / c as f64;
    if lambda <= 0.0 {
        return 1.0 / mu;
    }
    if lambda >= total {
        return f64::INFINITY;
    }
    let a = lambda / mu;
    erlang_c(c, a) / (total - lambda) + 1.0 / mu
}

/// The latency-feasibility constraint 2e′ as a throughput floor: the
/// normalized aggregate capability an inference job needs at time
/// `now_s` so that (i) the pooled-server bound `1/(Σμ − λ)` meets the
/// SLO and (ii) utilization stays below [`RHO_MAX`]. Training jobs pass
/// through unchanged (their T̄_j). Linear in the ILP's `n_{a,c}`
/// variables, so Problem 1 stays an ILP.
pub fn effective_min_throughput(spec: &JobSpec, now_s: f64) -> f64 {
    let Some(inf) = spec.inference else {
        return spec.min_throughput;
    };
    let lam = spec.request_rate_at(now_s) * LOAD_HEADROOM;
    let req = (lam / RHO_MAX).max(lam + 1.0 / inf.latency_slo_s.max(1e-6));
    req / REQS_PER_UNIT_THROUGHPUT
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{InferenceSpec, JobId, ModelFamily};

    fn inf_job(base_rate: f64, slo: f64) -> JobSpec {
        JobSpec {
            id: JobId(1),
            family: ModelFamily::ResNet50,
            batch_size: 64,
            replication: 1,
            min_throughput: 0.0,
            distributability: 4,
            work: 100.0,
            priority: Default::default(),
            elastic: false,
            inference: Some(InferenceSpec {
                base_rate,
                diurnal_amplitude: 0.0,
                diurnal_phase_s: 0.0,
                latency_slo_s: slo,
            }),
        }
    }

    #[test]
    fn mm1_matches_textbook_closed_form() {
        // M/M/1 sojourn is exactly 1/(μ − λ)
        for (lam, mu) in [(5.0, 10.0), (0.5, 2.0), (9.0, 10.0)] {
            let w = mmc_sojourn(lam, &[mu]);
            assert!((w - 1.0 / (mu - lam)).abs() < 1e-12, "λ={lam} μ={mu}: {w}");
        }
    }

    #[test]
    fn erlang_c_known_values() {
        // c=1: queueing probability equals ρ
        assert!((erlang_c(1, 0.5) - 0.5).abs() < 1e-12);
        // empty and saturated edges
        assert_eq!(erlang_c(0, 0.5), 1.0);
        assert_eq!(erlang_c(2, 2.0), 1.0);
        assert_eq!(erlang_c(3, 0.0), 0.0);
        // more servers at the same offered load queue less
        assert!(erlang_c(4, 1.5) < erlang_c(2, 1.5));
    }

    #[test]
    fn more_replicas_never_raise_latency() {
        let lam = 12.0;
        let mut prev = f64::INFINITY;
        for c in 1..=6 {
            let w = mmc_sojourn(lam, &vec![5.0; c]);
            assert!(w <= prev + 1e-12, "c={c}: {w} > {prev}");
            prev = w;
        }
        // c = 1..2 saturated (λ ≥ Σμ), c = 3 finite
        assert_eq!(mmc_sojourn(lam, &[5.0, 5.0]), f64::INFINITY);
        assert!(mmc_sojourn(lam, &[5.0, 5.0, 5.0]).is_finite());
    }

    #[test]
    fn unplaced_and_idle_edges() {
        assert_eq!(mmc_sojourn(1.0, &[]), f64::INFINITY);
        // no load: sojourn is just the mean service time
        assert!((mmc_sojourn(0.0, &[4.0]) - 0.25).abs() < 1e-12);
    }

    #[test]
    fn effective_floor_meets_the_pooled_bound() {
        let j = inf_job(20.0, 0.25);
        let floor = effective_min_throughput(&j, 0.0);
        // capacity at the floor satisfies the pooled bound with headroom
        let mu_total = service_rate(floor);
        let lam = 20.0 * LOAD_HEADROOM;
        assert!(mu_total >= lam + 1.0 / 0.25 - 1e-9);
        assert!(lam / mu_total <= RHO_MAX + 1e-9);
        // training jobs pass through their T̄_j untouched
        let mut t = inf_job(20.0, 0.25);
        t.inference = None;
        t.min_throughput = 0.37;
        assert_eq!(effective_min_throughput(&t, 0.0), 0.37);
    }

    #[test]
    fn effective_floor_tracks_the_diurnal_wave() {
        let mut j = inf_job(20.0, 0.25);
        j.inference.as_mut().unwrap().diurnal_amplitude = 0.4;
        let peak = effective_min_throughput(&j, 21_600.0); // sine max
        let trough = effective_min_throughput(&j, 3.0 * 21_600.0);
        assert!(peak > trough, "peak {peak} vs trough {trough}");
    }
}
