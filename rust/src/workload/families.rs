//! The workload taxonomy of Table 2 and the six Gavel accelerator types.

/// Model families of the paper's Table 2.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ModelFamily {
    ResNet18,
    ResNet50,
    Transformer,
    /// Language Model (LM) row of Table 2.
    LanguageModel,
    Recommendation,
}

/// All families, index order == one-hot position in Ψ.
pub const FAMILIES: [ModelFamily; 5] = [
    ModelFamily::ResNet18,
    ModelFamily::ResNet50,
    ModelFamily::Transformer,
    ModelFamily::LanguageModel,
    ModelFamily::Recommendation,
];

impl ModelFamily {
    pub fn index(self) -> usize {
        FAMILIES.iter().position(|&f| f == self).unwrap()
    }

    pub fn name(self) -> &'static str {
        match self {
            ModelFamily::ResNet18 => "resnet18",
            ModelFamily::ResNet50 => "resnet50",
            ModelFamily::Transformer => "transformer",
            ModelFamily::LanguageModel => "lm",
            ModelFamily::Recommendation => "recommendation",
        }
    }

    /// Batch-size grid of Table 2.
    pub fn batch_sizes(self) -> &'static [u32] {
        match self {
            ModelFamily::ResNet18 | ModelFamily::ResNet50 => &[16, 32, 64, 128, 256],
            ModelFamily::Transformer => &[16, 32, 128, 256],
            ModelFamily::LanguageModel => &[5, 10, 20, 80],
            ModelFamily::Recommendation => &[512, 1024, 2048, 8192],
        }
    }

    /// Resource demand vector `(compute, memory-bandwidth)` in [0, 1] —
    /// drives the co-location interference model (DESIGN.md): image
    /// models are compute-heavy, recommendation is memory-heavy, NLP
    /// sits in between. These shapes mirror Gavel's qualitative
    /// co-location results.
    pub fn resource_vector(self) -> (f64, f64) {
        match self {
            ModelFamily::ResNet18 => (0.75, 0.35),
            ModelFamily::ResNet50 => (0.95, 0.45),
            ModelFamily::Transformer => (0.80, 0.60),
            ModelFamily::LanguageModel => (0.60, 0.70),
            ModelFamily::Recommendation => (0.30, 0.95),
        }
    }
}

/// The six accelerator types of the Gavel cluster (§3.1): three GPU
/// generations plus their `_unconsolidated` variants (fragmented /
/// partially-utilized placements).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum AccelType {
    K80,
    P100,
    V100,
    K80Unconsolidated,
    P100Unconsolidated,
    V100Unconsolidated,
}

/// All accelerator types, index order == one-hot position in net inputs.
pub const ACCEL_TYPES: [AccelType; 6] = [
    AccelType::K80,
    AccelType::P100,
    AccelType::V100,
    AccelType::K80Unconsolidated,
    AccelType::P100Unconsolidated,
    AccelType::V100Unconsolidated,
];

impl AccelType {
    pub fn index(self) -> usize {
        ACCEL_TYPES.iter().position(|&a| a == self).unwrap()
    }

    pub fn name(self) -> &'static str {
        match self {
            AccelType::K80 => "k80",
            AccelType::P100 => "p100",
            AccelType::V100 => "v100",
            AccelType::K80Unconsolidated => "k80_unconsolidated",
            AccelType::P100Unconsolidated => "p100_unconsolidated",
            AccelType::V100Unconsolidated => "v100_unconsolidated",
        }
    }

    /// The consolidated base generation.
    pub fn consolidated(self) -> AccelType {
        match self {
            AccelType::K80 | AccelType::K80Unconsolidated => AccelType::K80,
            AccelType::P100 | AccelType::P100Unconsolidated => AccelType::P100,
            AccelType::V100 | AccelType::V100Unconsolidated => AccelType::V100,
        }
    }

    pub fn is_unconsolidated(self) -> bool {
        self != self.consolidated()
    }

    /// Relative generation speed (k80 ≈ 1×, p100 ≈ 2.5×, v100 ≈ 5×;
    /// unconsolidated placements lose ~15% — DESIGN.md §Substitution).
    pub fn base_speed(self) -> f64 {
        let gen = match self.consolidated() {
            AccelType::K80 => 1.0,
            AccelType::P100 => 2.5,
            AccelType::V100 => 5.0,
            _ => unreachable!(),
        };
        if self.is_unconsolidated() {
            gen * 0.85
        } else {
            gen
        }
    }

    /// Job capacity θ_a: every Gavel type supports at most two
    /// co-located jobs (paper §2.2).
    pub fn capacity(self) -> u32 {
        2
    }

    /// Power curve parameters `(idle_watts, peak_extra_watts)`; power at
    /// relative load u ∈ \[0,1\] is `idle + peak_extra · u^0.8` (sublinear,
    /// as measured GPU power curves are). Newer GPUs burn more peak
    /// power but far less energy *per unit work*.
    pub fn power_params(self) -> (f64, f64) {
        match self.consolidated() {
            AccelType::K80 => (25.0, 130.0),
            AccelType::P100 => (30.0, 170.0),
            AccelType::V100 => (35.0, 215.0),
            _ => unreachable!(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indices_are_consistent() {
        for (i, f) in FAMILIES.iter().enumerate() {
            assert_eq!(f.index(), i);
        }
        for (i, a) in ACCEL_TYPES.iter().enumerate() {
            assert_eq!(a.index(), i);
        }
    }

    #[test]
    fn table2_batch_grids() {
        assert_eq!(ModelFamily::ResNet18.batch_sizes(), &[16, 32, 64, 128, 256]);
        assert_eq!(ModelFamily::Transformer.batch_sizes(), &[16, 32, 128, 256]);
        assert_eq!(ModelFamily::LanguageModel.batch_sizes(), &[5, 10, 20, 80]);
        assert_eq!(ModelFamily::Recommendation.batch_sizes(), &[512, 1024, 2048, 8192]);
    }

    #[test]
    fn speed_ordering_matches_generations() {
        assert!(AccelType::V100.base_speed() > AccelType::P100.base_speed());
        assert!(AccelType::P100.base_speed() > AccelType::K80.base_speed());
        assert!(AccelType::V100Unconsolidated.base_speed() < AccelType::V100.base_speed());
    }

    #[test]
    fn capacity_is_two_everywhere() {
        for a in ACCEL_TYPES {
            assert_eq!(a.capacity(), 2);
        }
    }

    #[test]
    fn power_increases_with_generation() {
        let p = |a: AccelType| a.power_params().0 + a.power_params().1;
        assert!(p(AccelType::V100) > p(AccelType::P100));
        assert!(p(AccelType::P100) > p(AccelType::K80));
    }
}
