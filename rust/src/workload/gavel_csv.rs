//! Tabulated throughput overrides — plug the *real* Gavel dataset in.
//!
//! The synthetic oracle (gavel.rs) reproduces the structure of the
//! Gavel measurements, but anyone holding the actual dataset \[9\] can
//! export it to this CSV form and run every experiment on real numbers:
//!
//! ```csv
//! # kind, model, batch, accel, throughput[, model2, batch2, throughput2]
//! solo, resnet18, 64, v100, 123.4
//! pair, resnet18, 64, v100, 80.2, transformer, 32, 41.0
//! ```
//!
//! `kind=solo` rows give a job's solo iterations/s on an accelerator;
//! `kind=pair` rows give both jobs' co-located iterations/s. Unknown
//! (job, accel) combinations fall back to the synthetic model, so a
//! partial table is fine. Load with
//! [`crate::workload::ThroughputOracle::with_table`].

use std::collections::HashMap;

use crate::workload::families::{AccelType, ModelFamily, ACCEL_TYPES, FAMILIES};
use crate::Result;

/// One workload configuration key.
pub type CfgKey = (ModelFamily, u32);

/// Parsed table of measured throughputs (raw iterations/s).
#[derive(Debug, Clone, Default)]
pub struct ThroughputTable {
    /// (cfg, accel) -> solo iterations/s
    pub solo: HashMap<(CfgKey, AccelType), f64>,
    /// ordered ((cfg1, cfg2), accel) -> (t1, t2); stored with cfg1 ≤ cfg2
    /// by (family index, batch).
    pub pairs: HashMap<(CfgKey, CfgKey, AccelType), (f64, f64)>,
}

fn parse_family(s: &str) -> Result<ModelFamily> {
    FAMILIES
        .iter()
        .copied()
        .find(|f| f.name() == s.trim())
        .ok_or_else(|| anyhow::anyhow!("unknown model family {s:?}"))
}

fn parse_accel(s: &str) -> Result<AccelType> {
    ACCEL_TYPES
        .iter()
        .copied()
        .find(|a| a.name() == s.trim())
        .ok_or_else(|| anyhow::anyhow!("unknown accelerator {s:?}"))
}

fn order(a: CfgKey, b: CfgKey) -> (CfgKey, CfgKey, bool) {
    if (a.0.index(), a.1) <= (b.0.index(), b.1) {
        (a, b, false)
    } else {
        (b, a, true)
    }
}

impl ThroughputTable {
    /// Parse the CSV format in the module docs. `#`-lines and blank
    /// lines are ignored.
    pub fn from_csv(text: &str) -> Result<Self> {
        let mut table = ThroughputTable::default();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let fields: Vec<&str> = line.split(',').map(|f| f.trim()).collect();
            let ctx = |e: anyhow::Error| anyhow::anyhow!("line {}: {e}", lineno + 1);
            match fields.as_slice() {
                ["solo", model, batch, accel, t] => {
                    let cfg = (parse_family(model).map_err(ctx)?, batch.parse::<u32>()?);
                    let a = parse_accel(accel).map_err(ctx)?;
                    table.solo.insert((cfg, a), t.parse::<f64>()?);
                }
                ["pair", m1, b1, accel, t1, m2, b2, t2] => {
                    let c1 = (parse_family(m1).map_err(ctx)?, b1.parse::<u32>()?);
                    let c2 = (parse_family(m2).map_err(ctx)?, b2.parse::<u32>()?);
                    let a = parse_accel(accel).map_err(ctx)?;
                    let (t1, t2) = (t1.parse::<f64>()?, t2.parse::<f64>()?);
                    let (lo, hi, swapped) = order(c1, c2);
                    let v = if swapped { (t2, t1) } else { (t1, t2) };
                    table.pairs.insert((lo, hi, a), v);
                }
                _ => anyhow::bail!(
                    "line {}: expected solo(5) or pair(8) fields, got {}",
                    lineno + 1,
                    fields.len()
                ),
            }
        }
        Ok(table)
    }

    pub fn load(path: &std::path::Path) -> Result<Self> {
        Self::from_csv(&std::fs::read_to_string(path)?)
    }

    pub fn solo_of(&self, cfg: CfgKey, a: AccelType) -> Option<f64> {
        self.solo.get(&(cfg, a)).copied()
    }

    /// Pair throughputs, returned in (query, other) order.
    pub fn pair_of(&self, cfg: CfgKey, other: CfgKey, a: AccelType) -> Option<(f64, f64)> {
        let (lo, hi, swapped) = order(cfg, other);
        self.pairs.get(&(lo, hi, a)).map(|&(t1, t2)| {
            if swapped {
                (t2, t1)
            } else {
                (t1, t2)
            }
        })
    }

    pub fn is_empty(&self) -> bool {
        self.solo.is_empty() && self.pairs.is_empty()
    }

    pub fn len(&self) -> usize {
        self.solo.len() + self.pairs.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const CSV: &str = "\
# comment line

solo, resnet18, 64, v100, 123.4
solo, resnet18, 64, k80, 25.0
pair, resnet18, 64, v100, 80.2, transformer, 32, 41.0
";

    #[test]
    fn parses_solo_and_pair_rows() {
        let t = ThroughputTable::from_csv(CSV).unwrap();
        assert_eq!(t.len(), 3);
        assert_eq!(
            t.solo_of((ModelFamily::ResNet18, 64), AccelType::V100),
            Some(123.4)
        );
        assert_eq!(t.solo_of((ModelFamily::ResNet18, 32), AccelType::V100), None);
        let p = t
            .pair_of(
                (ModelFamily::ResNet18, 64),
                (ModelFamily::Transformer, 32),
                AccelType::V100,
            )
            .unwrap();
        assert_eq!(p, (80.2, 41.0));
        // symmetric lookup flips the tuple
        let q = t
            .pair_of(
                (ModelFamily::Transformer, 32),
                (ModelFamily::ResNet18, 64),
                AccelType::V100,
            )
            .unwrap();
        assert_eq!(q, (41.0, 80.2));
    }

    #[test]
    fn rejects_malformed_rows() {
        assert!(ThroughputTable::from_csv("solo, resnet18, 64, v100").is_err());
        assert!(ThroughputTable::from_csv("solo, vgg, 64, v100, 1.0").is_err());
        assert!(ThroughputTable::from_csv("solo, resnet18, 64, h100, 1.0").is_err());
        assert!(ThroughputTable::from_csv("solo, resnet18, x, v100, 1.0").is_err());
    }
}
