//! Synthetic Gavel-style throughput oracle (DESIGN.md §Substitution).
//!
//! Ground-truth throughputs for every (job, accelerator, combination).
//! The generator is deterministic given a seed and reproduces the three
//! structural properties the paper's learning loop exploits:
//!
//! 1. **Inter-GPU correlation** — a job's throughputs across GPU types
//!    are a smooth function of generation speed × a per-(family, gen)
//!    affinity factor, so observing one GPU type is informative about
//!    the others (what P2 learns, Eq. 3).
//! 2. **Inter-job similarity** — jobs of the same family with nearby
//!    batch sizes have nearby throughput profiles (what the Catalog's
//!    nearest-neighbour step + P1 exploit, Eq. 1).
//! 3. **Contention-shaped co-location** — pairwise slowdowns follow a
//!    resource-vector contention model: compute-heavy × compute-heavy
//!    collide hard, compute × memory mixes co-exist well (the Gavel
//!    dataset's qualitative shape).
//!
//! All throughputs are reported *normalized* to (0, 1]: the scale is the
//! fastest solo throughput in the universe, mirroring the normalization
//! the estimator networks train with.

use crate::util::Rng;

use super::families::{AccelType, ModelFamily, ACCEL_TYPES, FAMILIES};
use super::{Combo, JobSpec};
use std::collections::HashMap;

/// Deterministic ground-truth throughput model.
#[derive(Debug, Clone)]
pub struct ThroughputOracle {
    /// affinity[(family, consolidated gen index)] ∈ [0.7, 1.3]: how much
    /// better/worse than the raw generation speed this family does.
    affinity: HashMap<(usize, usize), f64>,
    /// per-(family, accel) jitter on the batch curve knee.
    knee_jitter: HashMap<(usize, usize), f64>,
    /// contention strength β for the interference model.
    beta: f64,
    /// normalization scale (fastest solo throughput, iterations/s).
    scale: f64,
    /// measured overrides (the *real* Gavel dataset, when available —
    /// see gavel_csv.rs); lookups fall back to the synthetic model.
    table: Option<std::sync::Arc<super::gavel_csv::ThroughputTable>>,
    seed: u64,
}

fn gen_index(a: AccelType) -> usize {
    match a.consolidated() {
        AccelType::K80 => 0,
        AccelType::P100 => 1,
        AccelType::V100 => 2,
        _ => unreachable!(),
    }
}

impl ThroughputOracle {
    pub fn new(seed: u64) -> Self {
        let mut rng = Rng::seed_from_u64(seed ^ 0x60_67_68_00);
        let mut affinity = HashMap::new();
        let mut knee_jitter = HashMap::new();
        for (fi, _f) in FAMILIES.iter().enumerate() {
            for gi in 0..3 {
                affinity.insert((fi, gi), rng.range_f64(0.7, 1.3));
            }
            for (ai, _a) in ACCEL_TYPES.iter().enumerate() {
                knee_jitter.insert((fi, ai), rng.range_f64(0.85, 1.15));
            }
        }
        let mut o = Self {
            affinity,
            knee_jitter,
            beta: 0.9,
            scale: 1.0,
            table: None,
            seed,
        };
        o.renormalize();
        o
    }

    /// Overlay measured throughputs (e.g. the real Gavel dataset parsed
    /// by [`super::gavel_csv::ThroughputTable`]); unknown entries keep
    /// the synthetic model. The normalization scale is recomputed so
    /// all reported values stay in (0, 1].
    pub fn with_table(mut self, table: super::gavel_csv::ThroughputTable) -> Self {
        self.table = Some(std::sync::Arc::new(table));
        self.renormalize();
        self
    }

    /// normalize: fastest solo throughput over the whole universe → 1.0
    fn renormalize(&mut self) {
        let mut max_t: f64 = 0.0;
        for f in FAMILIES {
            for &b in f.batch_sizes() {
                for a in ACCEL_TYPES {
                    max_t = max_t.max(self.solo_raw(f, b, a));
                }
            }
        }
        self.scale = max_t;
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Raw (unnormalized) solo throughput in iterations/s.
    ///
    /// Model: `speed(a) · affinity(f, gen) · knee / (knee + batch/ref)`,
    /// a saturating curve — iterations/s falls as batch grows (larger
    /// batches do more work per iteration), matching the paper's
    /// "increasing the batch size … leads to lower predicted throughput".
    fn solo_raw(&self, family: ModelFamily, batch: u32, a: AccelType) -> f64 {
        if let Some(t) = self.table.as_ref().and_then(|t| t.solo_of((family, batch), a)) {
            return t;
        }
        let fi = family.index();
        let speed = a.base_speed();
        let aff = self.affinity[&(fi, gen_index(a))];
        let jit = self.knee_jitter[&(fi, a.index())];
        let batches = family.batch_sizes();
        let ref_batch = batches[batches.len() / 2] as f64;
        let knee = 2.0 * jit;
        // family base rate: normalized so each family's mid-batch k80 solo ≈ O(1)
        let base = 10.0;
        base * speed * aff * knee / (knee + (batch as f64) / ref_batch)
    }

    /// Normalized solo throughput T^{{j}}_{a,j} ∈ (0, 1].
    pub fn solo(&self, job: &JobSpec, a: AccelType) -> f64 {
        self.solo_raw(job.family, job.batch_size, a) / self.scale
    }

    /// Pairwise slowdown factor for `job` when co-located with `other`
    /// on `a`: `1 / (1 + β · r_job · r_other · pressure(a))`.
    ///
    /// Unconsolidated placements suffer slightly more contention (the
    /// fragmented-resource scenario the `_unconsolidated` variants
    /// capture).
    fn slowdown(&self, job: &JobSpec, other: &JobSpec, a: AccelType) -> f64 {
        let (c1, m1) = job.family.resource_vector();
        let (c2, m2) = other.family.resource_vector();
        // batch size raises memory pressure within a family
        let bscale = |j: &JobSpec| {
            let bs = j.family.batch_sizes();
            let pos = bs.iter().position(|&b| b == j.batch_size).unwrap_or(bs.len() / 2);
            0.9 + 0.2 * (pos as f64) / (bs.len().max(2) - 1) as f64
        };
        let contention = c1 * c2 + m1 * m2 * bscale(job) * bscale(other);
        let pressure = if a.is_unconsolidated() { 1.15 } else { 1.0 };
        1.0 / (1.0 + self.beta * contention * pressure)
    }

    /// Normalized co-located throughput of `job` within combination `c`
    /// (|c| ≤ 2) on accelerator type `a`. `lookup` resolves JobIds to
    /// specs for the co-runner.
    pub fn throughput(
        &self,
        job: &JobSpec,
        combo: &Combo,
        a: AccelType,
        lookup: &dyn Fn(super::JobId) -> Option<JobSpec>,
    ) -> f64 {
        debug_assert!(combo.contains(job.id));
        match combo.other(job.id) {
            None => self.solo(job, a),
            Some(other_id) => {
                let other = lookup(other_id).expect("co-runner spec must exist");
                self.pair(job, &other, a).0
            }
        }
    }

    /// Convenience: both throughputs of a pair `(j1, j2)` on `a`.
    pub fn pair(&self, j1: &JobSpec, j2: &JobSpec, a: AccelType) -> (f64, f64) {
        if let Some((t1, t2)) = self.table.as_ref().and_then(|t| {
            t.pair_of((j1.family, j1.batch_size), (j2.family, j2.batch_size), a)
        }) {
            return (t1 / self.scale, t2 / self.scale);
        }
        (
            self.solo(j1, a) * self.slowdown(j1, j2, a),
            self.solo(j2, a) * self.slowdown(j2, j1, a),
        )
    }

    /// Normalization scale (iterations/s that maps to 1.0).
    pub fn scale(&self) -> f64 {
        self.scale
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::JobId;

    fn job(id: u32, f: ModelFamily, batch: u32) -> JobSpec {
        JobSpec {
            id: JobId(id),
            family: f,
            batch_size: batch,
            replication: 1,
            min_throughput: 0.0,
            distributability: 1,
            work: 1.0,
            priority: Default::default(),
            elastic: false,
            inference: None,
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = ThroughputOracle::new(42);
        let b = ThroughputOracle::new(42);
        let j = job(0, ModelFamily::ResNet50, 64);
        assert_eq!(a.solo(&j, AccelType::V100), b.solo(&j, AccelType::V100));
        let c = ThroughputOracle::new(43);
        assert_ne!(a.solo(&j, AccelType::V100), c.solo(&j, AccelType::V100));
    }

    #[test]
    fn normalized_to_unit_interval() {
        let o = ThroughputOracle::new(7);
        let mut max_t: f64 = 0.0;
        for f in FAMILIES {
            for &b in f.batch_sizes() {
                let j = job(0, f, b);
                for a in ACCEL_TYPES {
                    let t = o.solo(&j, a);
                    assert!(t > 0.0 && t <= 1.0 + 1e-12, "{f:?} {b} {a:?} -> {t}");
                    max_t = max_t.max(t);
                }
            }
        }
        assert!((max_t - 1.0).abs() < 1e-9);
    }

    #[test]
    fn newer_generations_are_mostly_faster() {
        // affinity jitter is ±30% but generation gaps are ≥2×, so
        // v100 > k80 must hold for every family.
        let o = ThroughputOracle::new(3);
        for f in FAMILIES {
            let j = job(0, f, f.batch_sizes()[0]);
            assert!(o.solo(&j, AccelType::V100) > o.solo(&j, AccelType::K80));
        }
    }

    #[test]
    fn unconsolidated_is_slower() {
        let o = ThroughputOracle::new(3);
        let j = job(0, ModelFamily::Transformer, 32);
        assert!(o.solo(&j, AccelType::V100Unconsolidated) < o.solo(&j, AccelType::V100));
    }

    #[test]
    fn iterations_per_second_fall_with_batch_size() {
        let o = ThroughputOracle::new(3);
        for f in FAMILIES {
            let bs = f.batch_sizes();
            let lo = o.solo(&job(0, f, bs[0]), AccelType::P100);
            let hi = o.solo(&job(0, f, bs[bs.len() - 1]), AccelType::P100);
            assert!(lo > hi, "{f:?}: {lo} vs {hi}");
        }
    }

    #[test]
    fn colocation_degrades_but_never_kills() {
        let o = ThroughputOracle::new(3);
        let j1 = job(1, ModelFamily::ResNet50, 64);
        let j2 = job(2, ModelFamily::Recommendation, 1024);
        let (t1, t2) = o.pair(&j1, &j2, AccelType::V100);
        assert!(t1 < o.solo(&j1, AccelType::V100));
        assert!(t2 < o.solo(&j2, AccelType::V100));
        assert!(t1 > 0.2 * o.solo(&j1, AccelType::V100));
        assert!(t2 > 0.2 * o.solo(&j2, AccelType::V100));
    }

    #[test]
    fn conflicting_pairs_degrade_more_than_complementary() {
        // compute×compute (two resnet50s) must collide harder than
        // compute×memory (resnet50 + recommendation).
        let o = ThroughputOracle::new(3);
        let cc = job(1, ModelFamily::ResNet50, 64);
        let cc2 = job(2, ModelFamily::ResNet50, 64);
        let mem = job(3, ModelFamily::Recommendation, 512);
        let (t_cc, _) = o.pair(&cc, &cc2, AccelType::V100);
        let (t_cm, _) = o.pair(&cc, &mem, AccelType::V100);
        assert!(t_cc < t_cm, "compute-compute {t_cc} should be < compute-mem {t_cm}");
    }

    #[test]
    fn table_overrides_synthetic_values() {
        use crate::workload::gavel_csv::ThroughputTable;
        let base = ThroughputOracle::new(42);
        let j = job(0, ModelFamily::ResNet18, 64);
        let synthetic = base.solo_raw(ModelFamily::ResNet18, 64, AccelType::V100);
        // override with twice the synthetic rate → it becomes the new max
        let csv = format!("solo, resnet18, 64, v100, {}", synthetic * 2.0);
        let o = ThroughputOracle::new(42).with_table(ThroughputTable::from_csv(&csv).unwrap());
        // raw (denormalized) value equals the table entry exactly
        assert!(
            (o.solo(&j, AccelType::V100) * o.scale() - synthetic * 2.0).abs() < 1e-9,
            "override not applied"
        );
        // non-overridden entries still come from the synthetic model
        let other = job(1, ModelFamily::LanguageModel, 10);
        assert!(o.solo(&other, AccelType::K80) > 0.0);
        // pair override is used through throughput()
        let j2 = job(2, ModelFamily::Transformer, 32);
        let csv2 = format!(
            "pair, resnet18, 64, v100, {}, transformer, 32, {}",
            synthetic * 0.5,
            synthetic * 0.25
        );
        let o2 = ThroughputOracle::new(42).with_table(ThroughputTable::from_csv(&csv2).unwrap());
        let (t1, t2) = o2.pair(&j, &j2, AccelType::V100);
        assert!((t1 / t2 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn cross_gpu_correlation_exists() {
        // Rank correlation of job throughputs between two GPU types
        // should be strongly positive — the signal P2 learns.
        let o = ThroughputOracle::new(3);
        let mut jobs = vec![];
        let mut id = 0;
        for f in FAMILIES {
            for &b in f.batch_sizes() {
                jobs.push(job(id, f, b));
                id += 1;
            }
        }
        let xs: Vec<f64> = jobs.iter().map(|j| o.solo(j, AccelType::K80)).collect();
        let ys: Vec<f64> = jobs.iter().map(|j| o.solo(j, AccelType::V100)).collect();
        let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
        let (mx, my) = (mean(&xs), mean(&ys));
        let cov: f64 = xs.iter().zip(&ys).map(|(x, y)| (x - mx) * (y - my)).sum();
        let vx: f64 = xs.iter().map(|x| (x - mx).powi(2)).sum();
        let vy: f64 = ys.iter().map(|y| (y - my).powi(2)).sum();
        let corr = cov / (vx.sqrt() * vy.sqrt());
        assert!(corr > 0.8, "cross-GPU correlation too weak: {corr}");
    }
}
