//! Ψ attribute-vector encoding (paper §2.2) and the estimator input
//! tuple builders for P1 (Eq. 1) and P2 (Eq. 3).
//!
//! This module is the single source of truth for the feature layout the
//! AOT-compiled networks were trained with; it must stay byte-compatible
//! with `python/compile/model.py` (the layout is asserted in
//! `rust/tests/runtime_e2e.rs` against `artifacts/manifest.json`).

use super::families::{AccelType, ModelFamily, FAMILIES};

/// Ψ vector width: 5 (family one-hot) + log-batch + replication + bias.
pub const PSI_DIM: usize = 8;
/// Accelerator one-hot width.
pub const ACCEL_DIM: usize = 6;
/// P1 input width: Ψ_j2 ‖ Ψ_j3 ‖ a ‖ T_{a,j2} ‖ T_{a,j3} ‖ Ψ_j1.
pub const P1_DIM: usize = 2 * PSI_DIM + ACCEL_DIM + 2 + PSI_DIM; // 32
/// P2 raw input width (padded to [`P2_PADDED`] for the networks).
pub const P2_DIM: usize = 2 * PSI_DIM + 2 * ACCEL_DIM + 6; // 34
/// P2 padded width (5 tokens × 8).
pub const P2_PADDED: usize = 40;

/// Ψ_j for a job; the synthetic empty job j0 (paper §2.3) is all-zeros.
pub fn psi(family: ModelFamily, batch_size: u32, replication: u32) -> [f32; PSI_DIM] {
    let mut v = [0.0f32; PSI_DIM];
    v[family.index()] = 1.0;
    v[FAMILIES.len()] = (batch_size as f32).log2() / 13.0; // 2^13 = max batch in Table 2
    v[FAMILIES.len() + 1] = replication as f32;
    v[FAMILIES.len() + 2] = 1.0; // bias
    v
}

/// Ψ_{j0} — the synthetic empty-slot job (all zeros, throughput 0).
pub const PSI_EMPTY: [f32; PSI_DIM] = [0.0; PSI_DIM];

/// One-hot accelerator encoding.
pub fn accel_onehot(a: AccelType) -> [f32; ACCEL_DIM] {
    let mut v = [0.0f32; ACCEL_DIM];
    v[a.index()] = 1.0;
    v
}

/// Build one P1 input row (Eq. 1):
/// `(Ψ_j2, Ψ_j3, a, T_{a,j2}^{(j2,j3)}, T_{a,j3}^{(j2,j3)}, Ψ_j1)`.
/// Throughputs must already be normalized to [0, 1].
pub fn p1_row(
    psi_j2: &[f32; PSI_DIM],
    psi_j3: &[f32; PSI_DIM],
    a: AccelType,
    t_j2: f32,
    t_j3: f32,
    psi_j1: &[f32; PSI_DIM],
) -> [f32; P1_DIM] {
    let mut row = [0.0f32; P1_DIM];
    let mut o = 0;
    row[o..o + PSI_DIM].copy_from_slice(psi_j2);
    o += PSI_DIM;
    row[o..o + PSI_DIM].copy_from_slice(psi_j3);
    o += PSI_DIM;
    row[o..o + ACCEL_DIM].copy_from_slice(&accel_onehot(a));
    o += ACCEL_DIM;
    row[o] = t_j2;
    row[o + 1] = t_j3;
    o += 2;
    row[o..o + PSI_DIM].copy_from_slice(psi_j1);
    row
}

/// Build one P2 input row (Eq. 3), zero-padded to [`P2_PADDED`]:
/// `(Ψ_j1, Ψ_j2, a1, a2, T̃_{a1,j1}, T̃_{a1,j2}, T_{a1,j1}, T_{a1,j2},
///   T̃_{a2,j1}, T̃_{a2,j2})`.
#[allow(clippy::too_many_arguments)]
pub fn p2_row(
    psi_j1: &[f32; PSI_DIM],
    psi_j2: &[f32; PSI_DIM],
    a1: AccelType,
    a2: AccelType,
    est_a1_j1: f32,
    est_a1_j2: f32,
    meas_a1_j1: f32,
    meas_a1_j2: f32,
    est_a2_j1: f32,
    est_a2_j2: f32,
) -> [f32; P2_PADDED] {
    let mut row = [0.0f32; P2_PADDED];
    let mut o = 0;
    row[o..o + PSI_DIM].copy_from_slice(psi_j1);
    o += PSI_DIM;
    row[o..o + PSI_DIM].copy_from_slice(psi_j2);
    o += PSI_DIM;
    row[o..o + ACCEL_DIM].copy_from_slice(&accel_onehot(a1));
    o += ACCEL_DIM;
    row[o..o + ACCEL_DIM].copy_from_slice(&accel_onehot(a2));
    o += ACCEL_DIM;
    for (i, t) in [est_a1_j1, est_a1_j2, meas_a1_j1, meas_a1_j2, est_a2_j1, est_a2_j2]
        .into_iter()
        .enumerate()
    {
        row[o + i] = t;
    }
    row
}

/// Squared L2 distance between Ψ vectors — the Catalog's similarity
/// metric (paper §2.3 "based on feature similarity").
pub fn psi_distance(a: &[f32; PSI_DIM], b: &[f32; PSI_DIM]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn psi_layout() {
        let v = psi(ModelFamily::Transformer, 128, 1);
        assert_eq!(v[2], 1.0); // transformer one-hot
        assert_eq!(v[0], 0.0);
        assert!((v[5] - 7.0 / 13.0).abs() < 1e-6); // log2(128)/13
        assert_eq!(v[6], 1.0); // replication
        assert_eq!(v[7], 1.0); // bias
    }

    #[test]
    fn dims_match_manifest_expectations() {
        assert_eq!(P1_DIM, 32);
        assert_eq!(P2_DIM, 34);
        assert_eq!(P2_PADDED, 40);
    }

    #[test]
    fn p1_row_layout() {
        let pa = psi(ModelFamily::ResNet18, 16, 1);
        let pb = psi(ModelFamily::ResNet50, 32, 1);
        let pc = psi(ModelFamily::LanguageModel, 5, 1);
        let row = p1_row(&pa, &pb, AccelType::V100, 0.5, 0.25, &pc);
        assert_eq!(&row[0..8], &pa);
        assert_eq!(&row[8..16], &pb);
        assert_eq!(row[16 + AccelType::V100.index()], 1.0);
        assert_eq!(row[22], 0.5);
        assert_eq!(row[23], 0.25);
        assert_eq!(&row[24..32], &pc);
    }

    #[test]
    fn p2_row_padding_is_zero() {
        let pa = psi(ModelFamily::ResNet18, 16, 1);
        let row = p2_row(
            &pa,
            &PSI_EMPTY,
            AccelType::K80,
            AccelType::V100,
            0.1,
            0.0,
            0.2,
            0.0,
            0.3,
            0.0,
        );
        assert_eq!(&row[34..40], &[0.0; 6]);
        assert_eq!(row[28], 0.1);
        assert_eq!(row[30], 0.2);
        assert_eq!(row[32], 0.3);
    }

    #[test]
    fn psi_distance_zero_iff_same_features() {
        let a = psi(ModelFamily::ResNet18, 64, 1);
        let b = psi(ModelFamily::ResNet18, 64, 1);
        let c = psi(ModelFamily::ResNet18, 128, 1);
        assert_eq!(psi_distance(&a, &b), 0.0);
        assert!(psi_distance(&a, &c) > 0.0);
    }
}
