//! The monitoring module (paper §2.1): measures the *actual* throughput
//! of each running job on each accelerator after placement.
//!
//! In this substrate, measurements come from the ground-truth oracle
//! plus multiplicative lognormal noise — the observability GOGH would
//! have via job-iteration counters in a real deployment. GOGH never
//! touches the oracle directly; everything it learns flows through
//! [`Monitor::sample`].

use crate::util::Rng;

use super::{AccelId, Cluster};
use crate::workload::{Combo, JobId, ThroughputOracle};

/// One throughput measurement: job `job` in combination `combo` on
/// accelerator `accel` achieved `throughput` (normalized).
#[derive(Debug, Clone, PartialEq)]
pub struct Measurement {
    pub job: JobId,
    pub combo: Combo,
    pub accel: AccelId,
    pub throughput: f64,
    pub at: f64,
}

/// Samples noisy measurements of the current placement.
#[derive(Debug, Clone)]
pub struct Monitor {
    oracle: ThroughputOracle,
    /// multiplicative noise sigma (lognormal); 0 disables noise.
    pub noise_sigma: f64,
    rng: Rng,
}

impl Monitor {
    pub fn new(oracle: ThroughputOracle, noise_sigma: f64, seed: u64) -> Self {
        Self {
            oracle,
            noise_sigma,
            rng: Rng::seed_from_u64(seed ^ 0x304),
        }
    }

    /// Ground-truth oracle — exposed ONLY for metrics (estimation-error
    /// reporting) and the oracle baseline; the GOGH decision path must
    /// not call this.
    pub fn oracle(&self) -> &ThroughputOracle {
        &self.oracle
    }

    /// Measure every (job, accelerator) of the current placement.
    pub fn sample(&mut self, cluster: &Cluster) -> Vec<Measurement> {
        let mut out = vec![];
        let mut placements: Vec<(AccelId, Combo)> =
            cluster.placement.iter().map(|(a, c)| (*a, *c)).collect();
        placements.sort_by_key(|(a, _)| *a); // deterministic order
        for (aid, combo) in placements {
            for j in combo.jobs() {
                let job = cluster.job(j).expect("placed job must be registered");
                let lookup = |id: JobId| cluster.job(id).cloned();
                let truth = self.oracle.throughput(job, &combo, aid.accel, &lookup);
                let noise = self.rng.lognormal(self.noise_sigma);
                out.push(Measurement {
                    job: j,
                    combo,
                    accel: aid,
                    throughput: (truth * noise).max(0.0),
                    at: cluster.now(),
                });
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::workload::{JobSpec, ModelFamily};

    fn setup() -> (Cluster, Monitor) {
        let mut c = Cluster::new(ClusterSpec::balanced(1));
        c.add_job(JobSpec {
            id: JobId(1),
            family: ModelFamily::ResNet50,
            batch_size: 64,
            replication: 1,
            min_throughput: 0.1,
            distributability: 1,
            work: 10.0,
            priority: Default::default(),
            elastic: false,
            inference: None,
        });
        c.add_job(JobSpec {
            id: JobId(2),
            family: ModelFamily::Recommendation,
            batch_size: 1024,
            replication: 1,
            min_throughput: 0.1,
            distributability: 1,
            work: 10.0,
            priority: Default::default(),
            elastic: false,
            inference: None,
        });
        let aid = c.spec.accels[2]; // a v100
        c.placement.assign(aid, Combo::pair(JobId(1), JobId(2)));
        let monitor = Monitor::new(ThroughputOracle::new(9), 0.0, 1);
        (c, monitor)
    }

    #[test]
    fn noiseless_sample_equals_oracle() {
        let (c, mut m) = setup();
        let samples = m.sample(&c);
        assert_eq!(samples.len(), 2);
        for s in &samples {
            let job = c.job(s.job).unwrap();
            let lookup = |id: JobId| c.job(id).cloned();
            let truth = m.oracle().throughput(job, &s.combo, s.accel.accel, &lookup);
            assert!((s.throughput - truth).abs() < 1e-12);
        }
    }

    #[test]
    fn noisy_sample_is_near_oracle() {
        let (c, _) = setup();
        let mut m = Monitor::new(ThroughputOracle::new(9), 0.05, 1);
        let mut rel_errs = vec![];
        for _ in 0..50 {
            for s in m.sample(&c) {
                let job = c.job(s.job).unwrap();
                let lookup = |id: JobId| c.job(id).cloned();
                let truth = m.oracle().throughput(job, &s.combo, s.accel.accel, &lookup);
                rel_errs.push((s.throughput / truth - 1.0).abs());
            }
        }
        let mean: f64 = rel_errs.iter().sum::<f64>() / rel_errs.len() as f64;
        assert!(mean < 0.15, "noise too large: {mean}");
        assert!(mean > 0.005, "noise suspiciously absent: {mean}");
    }

    #[test]
    fn sample_order_is_deterministic() {
        let (c, mut m1) = setup();
        let (_, mut m2) = setup();
        assert_eq!(m1.sample(&c), m2.sample(&c));
    }
}
