//! Heterogeneous cluster substrate: servers × accelerator instances,
//! placement state, energy accounting and the monitoring module.
//!
//! The paper assumes a real cluster; here the substrate is a
//! discrete-time simulator backed by the [`crate::workload::ThroughputOracle`]
//! ground truth. GOGH itself only ever sees the oracle through
//! [`monitor::Monitor`] measurements (with noise) — exactly the
//! observability a real deployment would have.

pub mod energy;
pub mod monitor;
pub mod topology;

pub use energy::{power_watts, EnergyMeter};
pub use monitor::{Measurement, Monitor};
pub use topology::{Topology, TopologyGroup};

// Ordered containers only on this decision path: placement and job maps
// are iterated when diffing deltas and accruing energy, and BTreeMap's
// sorted order keeps those walks — and the f64 accumulation order they
// feed — identical run to run (the determinism-hash-container lint).
use std::collections::{BTreeMap, BTreeSet};

use crate::power::{state_power_watts, PowerState};
use crate::workload::{AccelType, Combo, JobId, JobSpec};
use crate::Result;

/// Identifies one accelerator instance: (server, accel type).
/// The ILP's x^c_{a,s} variables range over these (constraint 2f: each
/// instance hosts at most one combination).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct AccelId {
    pub server: u32,
    pub accel: AccelType,
}

impl std::fmt::Display for AccelId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "s{}/{}", self.server, self.accel.name())
    }
}

/// Static cluster topology.
#[derive(Debug, Clone)]
pub struct ClusterSpec {
    /// Accelerator instances; a server may appear with several types.
    pub accels: Vec<AccelId>,
}

impl ClusterSpec {
    /// A balanced heterogeneous cluster: `servers_per_type` servers for
    /// each of the six Gavel accelerator types.
    pub fn balanced(servers_per_type: u32) -> Self {
        let mut accels = vec![];
        let mut server = 0;
        for a in crate::workload::ACCEL_TYPES {
            for _ in 0..servers_per_type {
                accels.push(AccelId { server, accel: a });
                server += 1;
            }
        }
        Self { accels }
    }

    /// A custom mix: `(accel type, count)` pairs.
    pub fn mix(counts: &[(AccelType, u32)]) -> Self {
        let mut accels = vec![];
        let mut server = 0;
        for &(a, n) in counts {
            for _ in 0..n {
                accels.push(AccelId { server, accel: a });
                server += 1;
            }
        }
        Self { accels }
    }

    pub fn len(&self) -> usize {
        self.accels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.accels.is_empty()
    }

    /// Partition the cluster into `p` server-pool shards for the
    /// shard-parallel decision path. Instances are dealt round-robin
    /// over spec order; since [`ClusterSpec::mix`] lists each type as a
    /// contiguous run, every shard receives a near-equal slice of every
    /// accelerator type. Deterministic, covers each instance exactly
    /// once, and `p` is clamped to [1, len].
    ///
    /// Deprecated: the flat partition is the depth-1 special case of
    /// the two-level [`ClusterSpec::topology`]; `topology(1, p)`
    /// reproduces it bit-for-bit (parity-tested in
    /// `cluster/topology.rs`). Kept as the PR 3 ground truth that
    /// parity test compares against.
    #[deprecated(note = "use ClusterSpec::topology(1, p); this is its depth-1 special case")]
    pub fn shards(&self, p: usize) -> Vec<ShardSpec> {
        let p = p.clamp(1, self.accels.len().max(1));
        (0..p)
            .map(|index| ShardSpec {
                index,
                accels: self
                    .accels
                    .iter()
                    .enumerate()
                    .filter(|(i, _)| i % p == index)
                    .map(|(_, a)| *a)
                    .collect(),
            })
            .collect()
    }
}

/// One server-pool shard: a deterministic slice of the cluster spec that
/// the parallel arrival path treats as an independent placement domain
/// (cross-shard moves happen only on the periodic full re-solve).
#[derive(Debug, Clone)]
pub struct ShardSpec {
    pub index: usize,
    /// Member instances, in spec order.
    pub accels: Vec<AccelId>,
}

impl ShardSpec {
    pub fn contains(&self, a: AccelId) -> bool {
        self.accels.contains(&a)
    }
}

/// Live placement state of the cluster. Both maps are ordered, so
/// [`Placement::iter`] and [`Placement::jobs`] walk in sorted key
/// order — deterministic for every consumer (delta diffs, energy
/// accrual, snapshots).
#[derive(Debug, Clone, Default)]
pub struct Placement {
    /// accelerator instance -> hosted combination.
    by_accel: BTreeMap<AccelId, Combo>,
    /// job -> accelerator instances running it (|set| ≤ D_j).
    by_job: BTreeMap<JobId, Vec<AccelId>>,
}

impl Placement {
    pub fn new() -> Self {
        Self::default()
    }

    /// Assign `combo` to `accel`, replacing whatever ran there.
    pub fn assign(&mut self, accel: AccelId, combo: Combo) {
        self.clear_accel(accel);
        for j in combo.jobs() {
            self.by_job.entry(j).or_default().push(accel);
        }
        self.by_accel.insert(accel, combo);
    }

    /// Remove whatever combination runs on `accel`.
    pub fn clear_accel(&mut self, accel: AccelId) {
        if let Some(old) = self.by_accel.remove(&accel) {
            for j in old.jobs() {
                if let Some(v) = self.by_job.get_mut(&j) {
                    v.retain(|&a| a != accel);
                    if v.is_empty() {
                        self.by_job.remove(&j);
                    }
                }
            }
        }
    }

    /// Remove a finished/departed job everywhere. Co-runners are
    /// re-hosted as solos on the same instance.
    pub fn remove_job(&mut self, j: JobId) {
        let accels: Vec<AccelId> = self.accels_of(j).to_vec();
        for a in accels {
            let combo = self.by_accel[&a];
            self.clear_accel(a);
            if let Some(other) = combo.other(j) {
                self.assign(a, Combo::Solo(other));
            }
        }
    }

    pub fn combo_on(&self, accel: AccelId) -> Option<&Combo> {
        self.by_accel.get(&accel)
    }

    pub fn accels_of(&self, j: JobId) -> &[AccelId] {
        self.by_job.get(&j).map(|v| v.as_slice()).unwrap_or(&[])
    }

    pub fn is_placed(&self, j: JobId) -> bool {
        self.by_job.contains_key(&j)
    }

    pub fn iter(&self) -> impl Iterator<Item = (&AccelId, &Combo)> {
        self.by_accel.iter()
    }

    pub fn busy_accels(&self) -> usize {
        self.by_accel.len()
    }

    pub fn jobs(&self) -> impl Iterator<Item = &JobId> {
        self.by_job.keys()
    }

    /// Number of placement moves needed to turn `self` into `target`
    /// (migration cost metric reported by the coordinator).
    pub fn diff_count(&self, target: &Placement) -> usize {
        let mut moves = 0;
        for (a, c) in target.iter() {
            if self.by_accel.get(a) != Some(c) {
                moves += 1;
            }
        }
        for a in self.by_accel.keys() {
            if !target.by_accel.contains_key(a) {
                moves += 1;
            }
        }
        moves
    }
}

/// One typed placement mutation. Policies return these inside a
/// [`PlacementDelta`]; [`Cluster::apply_delta`] validates and applies
/// them transactionally.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlacementOp {
    /// Host `combo` on `accel`. The instance must currently be empty
    /// (evict first — implicit replacement hides policy bugs).
    Assign { accel: AccelId, combo: Combo },
    /// Remove whatever runs on `accel` (must be occupied).
    Evict { accel: AccelId },
    /// Move `job` off `from` (a co-runner, if any, stays behind solo)
    /// and re-host it solo on the empty instance `to`.
    Migrate { job: JobId, from: AccelId, to: AccelId },
    /// Re-state `accel` to the DVFS point `state` without touching its
    /// hosted combo. Cheap (no migration, no placement move); legal on a
    /// *down* instance — the state is remembered for when it returns,
    /// and a down instance bills zero joules regardless.
    SetPowerState { accel: AccelId, state: PowerState },
    /// Park `job` (the preemption primitive): clear every instance it
    /// holds (a co-runner stays behind solo) and mark it suspended. The
    /// job keeps its remaining work — parking loses no progress — but
    /// pays the migration stall when it restarts. The job must be
    /// registered, placed, and not already suspended.
    Suspend { job: JobId },
    /// Un-park `job` solo onto the empty in-service instance `accel`.
    /// The job must currently be suspended. A plain [`PlacementOp::Assign`]
    /// naming a suspended job auto-resumes it too, so full re-solve
    /// replace deltas restore parked jobs without special-casing.
    Resume { job: JobId, accel: AccelId },
}

/// An incremental placement change: the unit every [`crate::coordinator::Scheduler`]
/// decision carries. Applying the delta produced by [`PlacementDelta::diff`]
/// is exactly equivalent to replacing the placement wholesale (property
/// tested in `tests/proptests.rs`), but lets the cluster count and
/// charge migrations per touched job.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct PlacementDelta {
    pub ops: Vec<PlacementOp>,
}

impl PlacementDelta {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, op: PlacementOp) {
        self.ops.push(op);
    }

    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }

    pub fn len(&self) -> usize {
        self.ops.len()
    }

    /// The delta that turns `current` into `target`: evictions first
    /// (freeing every instance whose combo changes), then assignments.
    /// Unchanged instances produce no ops — stable placements are free.
    pub fn diff(current: &Placement, target: &Placement) -> Self {
        let mut evicts: Vec<AccelId> = vec![];
        let mut assigns: Vec<(AccelId, Combo)> = vec![];
        for (a, c) in current.iter() {
            if target.by_accel.get(a) != Some(c) {
                evicts.push(*a);
            }
        }
        for (a, c) in target.iter() {
            if current.by_accel.get(a) != Some(c) {
                assigns.push((*a, *c));
            }
        }
        evicts.sort();
        assigns.sort();
        let mut ops: Vec<PlacementOp> =
            evicts.into_iter().map(|accel| PlacementOp::Evict { accel }).collect();
        ops.extend(assigns.into_iter().map(|(accel, combo)| PlacementOp::Assign { accel, combo }));
        Self { ops }
    }
}

/// What applying a delta actually changed.
#[derive(Debug, Clone, Default)]
pub struct DeltaOutcome {
    /// instance-level placement moves (same metric as [`Placement::diff_count`])
    pub moves: usize,
    /// jobs that were running before AND after but on a different accel
    /// set — these pay the migration/restart penalty. Exceptions: an
    /// *inference* job that purely gained or purely lost replicas (one
    /// accel set contains the other) is NOT a migration — its surviving
    /// replicas never stop serving, so the autoscaler's grow/shrink
    /// actions must not stall the whole job — and an *elastic* training
    /// job gets the same grace for pure grows/shrinks.
    pub migrated_jobs: Vec<JobId>,
    /// jobs newly parked by this delta ([`PlacementOp::Suspend`]);
    /// the engine counts these as preemptions.
    pub suspended_jobs: Vec<JobId>,
    /// jobs un-parked by this delta ([`PlacementOp::Resume`], or an
    /// `Assign` naming a suspended job); the engine charges the
    /// migration stall to these on restart.
    pub resumed_jobs: Vec<JobId>,
}

/// The simulated cluster: spec + placement + job registry + clock +
/// accelerator availability (maintenance/failure churn).
#[derive(Debug, Clone)]
pub struct Cluster {
    pub spec: ClusterSpec,
    pub placement: Placement,
    jobs: BTreeMap<JobId, JobSpec>,
    now: f64,
    /// instances currently out of service (AccelDown events).
    down: BTreeSet<AccelId>,
    /// restart penalty: jobs make no progress until this simulated time.
    stalled_until: BTreeMap<JobId, f64>,
    /// jobs parked by [`PlacementOp::Suspend`]: registered, hold no
    /// instances, keep their remaining work until resumed.
    suspended: BTreeSet<JobId>,
    /// DVFS states; absent = [`PowerState::Nominal`] (the map stays
    /// sparse so a never-restated cluster costs nothing).
    power_states: BTreeMap<AccelId, PowerState>,
    /// cluster power cap (worst-case watts); deltas breaching it are
    /// rejected transactionally.
    power_cap_w: Option<f64>,
}

impl Cluster {
    pub fn new(spec: ClusterSpec) -> Self {
        Self {
            spec,
            placement: Placement::new(),
            jobs: BTreeMap::new(),
            now: 0.0,
            down: BTreeSet::new(),
            stalled_until: BTreeMap::new(),
            suspended: BTreeSet::new(),
            power_states: BTreeMap::new(),
            power_cap_w: None,
        }
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    pub fn advance_to(&mut self, t: f64) {
        debug_assert!(t >= self.now);
        self.now = t;
    }

    pub fn add_job(&mut self, job: JobSpec) {
        self.jobs.insert(job.id, job);
    }

    pub fn remove_job(&mut self, j: JobId) -> Option<JobSpec> {
        self.placement.remove_job(j);
        self.stalled_until.remove(&j);
        self.suspended.remove(&j);
        self.jobs.remove(&j)
    }

    /// Is `j` currently parked by a [`PlacementOp::Suspend`]?
    pub fn is_suspended(&self, j: JobId) -> bool {
        self.suspended.contains(&j)
    }

    /// Suspended job ids in ascending order (reports and snapshots).
    pub fn suspended_job_ids(&self) -> Vec<JobId> {
        self.suspended.iter().copied().collect()
    }

    /// Restore/rebuild hook: mark a job suspended directly, bypassing
    /// delta validation (snapshot restore; policies go through
    /// [`PlacementOp::Suspend`]).
    pub fn set_suspended(&mut self, j: JobId) {
        self.suspended.insert(j);
    }

    /// Instances currently in service, in spec order.
    pub fn available_accels(&self) -> Vec<AccelId> {
        self.spec
            .accels
            .iter()
            .filter(|a| !self.down.contains(a))
            .copied()
            .collect()
    }

    pub fn is_accel_down(&self, a: AccelId) -> bool {
        self.down.contains(&a)
    }

    /// Every out-of-service instance, in sorted order (snapshot capture).
    pub fn down_accels(&self) -> Vec<AccelId> {
        self.down.iter().copied().collect()
    }

    /// In-service instances of one shard, in spec order — the
    /// availability filtering every shard worker's instance pool starts
    /// from (a down accelerator must never enter a local ILP).
    pub fn shard_available_accels(&self, shard: &ShardSpec) -> Vec<AccelId> {
        shard
            .accels
            .iter()
            .filter(|a| !self.down.contains(a))
            .copied()
            .collect()
    }

    /// Take an instance out of service, evicting whatever ran there.
    /// Returns the jobs that lost that instance (sorted).
    pub fn set_accel_down(&mut self, a: AccelId) -> Vec<JobId> {
        let mut evicted: Vec<JobId> =
            self.placement.combo_on(a).map(|c| c.jobs()).unwrap_or_default();
        evicted.sort();
        self.placement.clear_accel(a);
        self.down.insert(a);
        evicted
    }

    /// Return an instance to service.
    pub fn set_accel_up(&mut self, a: AccelId) {
        self.down.remove(&a);
    }

    // -- power management (docs/POWER.md) --------------------------------

    /// Current DVFS state of `a` ([`PowerState::Nominal`] by default).
    pub fn power_state(&self, a: AccelId) -> PowerState {
        self.power_states.get(&a).copied().unwrap_or_default()
    }

    /// Restore/rebuild hook: set a state directly, bypassing delta
    /// validation (snapshot restore; policies go through
    /// [`PlacementOp::SetPowerState`]).
    pub fn set_power_state(&mut self, a: AccelId, s: PowerState) {
        Self::write_state(&mut self.power_states, a, s);
    }

    fn write_state(states: &mut BTreeMap<AccelId, PowerState>, a: AccelId, s: PowerState) {
        if s == PowerState::Nominal {
            states.remove(&a);
        } else {
            states.insert(a, s);
        }
    }

    /// Every instance in a non-default state, sorted (snapshot capture
    /// and the daemon's `status` body; BTreeMap order is already the
    /// sort order).
    pub fn power_state_entries(&self) -> Vec<(AccelId, PowerState)> {
        self.power_states.iter().map(|(a, s)| (*a, *s)).collect()
    }

    /// Set (or clear) the cluster power cap in worst-case watts.
    pub fn set_power_cap(&mut self, cap_w: Option<f64>) {
        self.power_cap_w = cap_w.filter(|c| c.is_finite() && *c > 0.0);
    }

    pub fn power_cap_w(&self) -> Option<f64> {
        self.power_cap_w
    }

    /// Worst-case cluster draw under the current placement and states:
    /// every in-service instance at `u = 1` if occupied, idle if empty;
    /// down instances contribute zero. The quantity the power cap bounds
    /// — actual loads are ≤ 1, so measured power can never exceed a cap
    /// this accepted.
    pub fn worst_case_watts(&self) -> f64 {
        self.worst_case_watts_of(&self.placement, &self.power_states)
    }

    fn worst_case_watts_of(
        &self,
        placement: &Placement,
        states: &BTreeMap<AccelId, PowerState>,
    ) -> f64 {
        self.spec
            .accels
            .iter()
            .filter(|a| !self.down.contains(a))
            .map(|a| {
                let s = states.get(a).copied().unwrap_or_default();
                let u = if placement.combo_on(*a).is_some() { 1.0 } else { 0.0 };
                state_power_watts(a.accel, s, u)
            })
            .sum()
    }

    /// Shrink a policy delta to fit the power cap (no-op when uncapped):
    /// ops are replayed in order against scratch state; an op that would
    /// push the worst case over the cap is retried with its target
    /// instance forced to [`PowerState::Low`] (assignments/migrations)
    /// or dropped (turbo upgrades). Ops that fail validation outright
    /// are kept verbatim so [`Cluster::apply_delta`] still surfaces the
    /// policy bug transactionally.
    pub fn trim_to_power_cap(&self, delta: &PlacementDelta) -> PlacementDelta {
        let Some(cap) = self.power_cap_w else {
            return delta.clone();
        };
        let mut next = self.placement.clone();
        let mut states = self.power_states.clone();
        let mut parked = self.suspended.clone();
        let mut kept: Vec<PlacementOp> = vec![];
        for op in &delta.ops {
            let next_bak = next.clone();
            let states_bak = states.clone();
            let parked_bak = parked.clone();
            if self.apply_op(&mut next, &mut states, &mut parked, op).is_err() {
                next = next_bak;
                states = states_bak;
                parked = parked_bak;
                kept.push(*op);
                continue;
            }
            if self.worst_case_watts_of(&next, &states) <= cap + 1e-9 {
                kept.push(*op);
                continue;
            }
            // breach: for load-adding ops, try the target down-clocked
            let target = match *op {
                PlacementOp::Assign { accel, .. } => Some(accel),
                PlacementOp::Migrate { to, .. } => Some(to),
                PlacementOp::Resume { accel, .. } => Some(accel),
                _ => None,
            };
            let retry =
                target.filter(|a| states.get(a).copied().unwrap_or_default() != PowerState::Low);
            if let Some(accel) = retry {
                Self::write_state(&mut states, accel, PowerState::Low);
                if self.worst_case_watts_of(&next, &states) <= cap + 1e-9 {
                    kept.push(PlacementOp::SetPowerState {
                        accel,
                        state: PowerState::Low,
                    });
                    kept.push(*op);
                    continue;
                }
            }
            next = next_bak;
            states = states_bak;
            parked = parked_bak;
        }
        PlacementDelta { ops: kept }
    }

    /// Charge a restart penalty: `j` makes no progress before `until`.
    /// Returns the stall seconds actually added — overlapping penalties
    /// extend the stall window instead of double-charging it.
    pub fn stall_job(&mut self, j: JobId, until: f64) -> f64 {
        let cur = self.stalled_until.get(&j).copied().unwrap_or(0.0).max(self.now);
        let e = self.stalled_until.entry(j).or_insert(0.0);
        *e = e.max(until);
        (until - cur).max(0.0)
    }

    /// Simulated time before which `j` is restarting (0 when not stalled).
    pub fn stalled_until(&self, j: JobId) -> f64 {
        self.stalled_until.get(&j).copied().unwrap_or(0.0)
    }

    /// Validate and apply an incremental placement change atomically:
    /// either every op applies, or the placement is left untouched.
    ///
    /// Invariants enforced per op (the "delta never double-books"
    /// property of `tests/proptests.rs`): assignments and migration
    /// targets must be empty in-service instances, combos may only name
    /// registered distinct jobs, evictions/migration sources must hit
    /// live state, and no job may end up on more instances than its
    /// distributability D_j allows.
    pub fn apply_delta(&mut self, delta: &PlacementDelta) -> Result<DeltaOutcome> {
        let mut next = self.placement.clone();
        let mut next_states = self.power_states.clone();
        let mut next_suspended = self.suspended.clone();
        for op in &delta.ops {
            self.apply_op(&mut next, &mut next_states, &mut next_suspended, op)?;
        }
        if let Some(cap) = self.power_cap_w {
            let worst = self.worst_case_watts_of(&next, &next_states);
            anyhow::ensure!(
                worst <= cap + 1e-9,
                "delta breaches the power cap (worst case {worst:.0} W > cap {cap:.0} W)"
            );
        }
        for (j, accels) in next.by_job.iter() {
            let d = self
                .jobs
                .get(j)
                .map(|s| s.distributability as usize)
                .unwrap_or(usize::MAX);
            anyhow::ensure!(
                accels.len() <= d,
                "delta places {j} on {} instances (distributability {d})",
                accels.len()
            );
        }
        // outcome: moves + which running jobs changed instances.
        // Inference jobs scale replicas up/down in place: a pure grow or
        // pure shrink (one accel set containing the other) leaves every
        // surviving replica untouched and is not a restart.
        let moves = self.placement.diff_count(&next);
        let mut migrated: Vec<JobId> = self
            .jobs
            .iter()
            .filter(|(j, spec)| {
                let before = self.placement.by_job.get(j);
                let after = next.by_job.get(j);
                match (before, after) {
                    (Some(b), Some(a)) => {
                        let b: BTreeSet<AccelId> = b.iter().copied().collect();
                        let a: BTreeSet<AccelId> = a.iter().copied().collect();
                        if b == a {
                            false
                        } else if spec.is_inference() || spec.elastic {
                            !(b.is_subset(&a) || a.is_subset(&b))
                        } else {
                            true
                        }
                    }
                    _ => false,
                }
            })
            .map(|(j, _)| *j)
            .collect();
        migrated.sort();
        // BTreeSet::difference walks in ascending order — both lists
        // come out sorted.
        let suspended_jobs: Vec<JobId> =
            next_suspended.difference(&self.suspended).copied().collect();
        let resumed_jobs: Vec<JobId> =
            self.suspended.difference(&next_suspended).copied().collect();
        self.placement = next;
        self.power_states = next_states;
        self.suspended = next_suspended;
        Ok(DeltaOutcome {
            moves,
            migrated_jobs: migrated,
            suspended_jobs,
            resumed_jobs,
        })
    }

    fn apply_op(
        &self,
        next: &mut Placement,
        states: &mut BTreeMap<AccelId, PowerState>,
        suspended: &mut BTreeSet<JobId>,
        op: &PlacementOp,
    ) -> Result<()> {
        let check_target = |accel: AccelId, next: &Placement| -> Result<()> {
            anyhow::ensure!(
                self.spec.accels.contains(&accel),
                "unknown accelerator {accel}"
            );
            anyhow::ensure!(!self.down.contains(&accel), "accelerator {accel} is down");
            anyhow::ensure!(
                next.combo_on(accel).is_none(),
                "accelerator {accel} already hosts a combo (evict first)"
            );
            Ok(())
        };
        match *op {
            PlacementOp::Assign { accel, combo } => {
                check_target(accel, next)?;
                let js = combo.jobs();
                anyhow::ensure!(
                    js.len() < 2 || js[0] != js[1],
                    "combo pairs {0} with itself",
                    js[0]
                );
                for j in &js {
                    anyhow::ensure!(self.jobs.contains_key(j), "unregistered job {j}");
                    anyhow::ensure!(
                        !next.accels_of(*j).contains(&accel),
                        "job {j} already on {accel}"
                    );
                    // assigning a suspended job auto-resumes it, so a
                    // full re-solve replace delta restores parked jobs
                    suspended.remove(j);
                }
                next.assign(accel, combo);
            }
            PlacementOp::Evict { accel } => {
                anyhow::ensure!(
                    next.combo_on(accel).is_some(),
                    "evicting empty accelerator {accel}"
                );
                next.clear_accel(accel);
            }
            PlacementOp::Migrate { job, from, to } => {
                let combo = *next
                    .combo_on(from)
                    .ok_or_else(|| anyhow::anyhow!("migrate source {from} is empty"))?;
                anyhow::ensure!(combo.contains(job), "job {job} is not on {from}");
                check_target(to, next)?;
                next.clear_accel(from);
                if let Some(peer) = combo.other(job) {
                    next.assign(from, Combo::Solo(peer));
                }
                next.assign(to, Combo::Solo(job));
            }
            PlacementOp::SetPowerState { accel, state } => {
                // deliberately NOT check_target: re-stating a down or
                // occupied instance is legal (no combo is touched)
                anyhow::ensure!(
                    self.spec.accels.contains(&accel),
                    "unknown accelerator {accel}"
                );
                Self::write_state(states, accel, state);
            }
            PlacementOp::Suspend { job } => {
                anyhow::ensure!(self.jobs.contains_key(&job), "unregistered job {job}");
                anyhow::ensure!(!suspended.contains(&job), "job {job} is already suspended");
                anyhow::ensure!(next.is_placed(job), "suspending unplaced job {job}");
                next.remove_job(job);
                suspended.insert(job);
            }
            PlacementOp::Resume { job, accel } => {
                anyhow::ensure!(
                    suspended.contains(&job),
                    "resuming job {job} that is not suspended"
                );
                check_target(accel, next)?;
                suspended.remove(&job);
                next.assign(accel, Combo::Solo(job));
            }
        }
        Ok(())
    }

    pub fn job(&self, j: JobId) -> Option<&JobSpec> {
        self.jobs.get(&j)
    }

    pub fn job_mut(&mut self, j: JobId) -> Option<&mut JobSpec> {
        self.jobs.get_mut(&j)
    }

    pub fn jobs(&self) -> impl Iterator<Item = &JobSpec> {
        self.jobs.values()
    }

    /// Active job ids in ascending (arrival) order — BTreeMap key order.
    pub fn active_job_ids(&self) -> Vec<JobId> {
        self.jobs.keys().copied().collect()
    }

    pub fn n_jobs(&self) -> usize {
        self.jobs.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::ModelFamily;

    fn job(id: u32) -> JobSpec {
        JobSpec {
            id: JobId(id),
            family: ModelFamily::ResNet18,
            batch_size: 32,
            replication: 1,
            min_throughput: 0.1,
            distributability: 2,
            work: 100.0,
            priority: Default::default(),
            elastic: false,
            inference: None,
        }
    }

    fn aid(s: u32) -> AccelId {
        AccelId {
            server: s,
            accel: AccelType::V100,
        }
    }

    #[test]
    fn balanced_spec_has_six_types() {
        let spec = ClusterSpec::balanced(2);
        assert_eq!(spec.len(), 12);
        let types: std::collections::HashSet<_> = spec.accels.iter().map(|a| a.accel).collect();
        assert_eq!(types.len(), 6);
    }

    #[test]
    #[allow(deprecated)] // exercises the legacy flat partition directly
    fn shards_partition_exactly_once_and_balance_types() {
        let spec = ClusterSpec::balanced(4); // 24 instances, 6 types
        for p in [1, 2, 3, 4, 8] {
            let shards = spec.shards(p);
            assert_eq!(shards.len(), p);
            let mut seen: Vec<AccelId> = shards.iter().flat_map(|s| s.accels.clone()).collect();
            seen.sort();
            let mut all = spec.accels.clone();
            all.sort();
            assert_eq!(seen, all, "p={p}: shards must cover each instance exactly once");
        }
        // round-robin over the contiguous type runs spreads each type
        let shards = spec.shards(4);
        for s in &shards {
            let types: std::collections::HashSet<_> = s.accels.iter().map(|a| a.accel).collect();
            assert_eq!(types.len(), 6, "shard {} missing types", s.index);
        }
        // p is clamped to the instance count (and to ≥ 1)
        assert_eq!(spec.shards(100).len(), 24);
        assert_eq!(spec.shards(0).len(), 1);
    }

    #[test]
    #[allow(deprecated)] // exercises the legacy flat partition directly
    fn shard_available_accels_filters_down_instances() {
        let mut c = delta_cluster();
        let shards = c.spec.shards(2);
        let victim = shards[0].accels[0];
        c.set_accel_down(victim);
        let avail = c.shard_available_accels(&shards[0]);
        assert_eq!(avail.len(), shards[0].accels.len() - 1);
        assert!(!avail.contains(&victim));
        // the other shard is untouched
        assert_eq!(c.shard_available_accels(&shards[1]), shards[1].accels);
        assert!(shards[0].contains(victim) && !shards[1].contains(victim));
    }

    #[test]
    fn assign_replaces_previous_combo() {
        let mut p = Placement::new();
        p.assign(aid(0), Combo::Solo(JobId(1)));
        p.assign(aid(0), Combo::pair(JobId(2), JobId(3)));
        assert!(!p.is_placed(JobId(1)));
        assert_eq!(p.combo_on(aid(0)), Some(&Combo::pair(JobId(2), JobId(3))));
        assert_eq!(p.accels_of(JobId(2)), &[aid(0)]);
    }

    #[test]
    fn remove_job_rehosts_co_runner_solo() {
        let mut p = Placement::new();
        p.assign(aid(0), Combo::pair(JobId(1), JobId(2)));
        p.remove_job(JobId(1));
        assert_eq!(p.combo_on(aid(0)), Some(&Combo::Solo(JobId(2))));
        assert!(p.is_placed(JobId(2)));
        assert!(!p.is_placed(JobId(1)));
    }

    #[test]
    fn distributed_job_tracked_on_all_accels() {
        let mut p = Placement::new();
        p.assign(aid(0), Combo::Solo(JobId(1)));
        p.assign(aid(1), Combo::Solo(JobId(1)));
        assert_eq!(p.accels_of(JobId(1)).len(), 2);
        p.remove_job(JobId(1));
        assert_eq!(p.busy_accels(), 0);
    }

    #[test]
    fn diff_count_counts_moves() {
        let mut a = Placement::new();
        a.assign(aid(0), Combo::Solo(JobId(1)));
        let mut b = Placement::new();
        b.assign(aid(0), Combo::Solo(JobId(1)));
        assert_eq!(a.diff_count(&b), 0);
        b.assign(aid(1), Combo::Solo(JobId(2)));
        assert_eq!(a.diff_count(&b), 1);
        b.assign(aid(0), Combo::Solo(JobId(3)));
        assert_eq!(a.diff_count(&b), 2);
    }

    #[test]
    fn cluster_job_lifecycle() {
        let mut c = Cluster::new(ClusterSpec::balanced(1));
        c.add_job(job(1));
        assert!(c.job(JobId(1)).is_some());
        c.placement.assign(c.spec.accels[0], Combo::Solo(JobId(1)));
        let removed = c.remove_job(JobId(1));
        assert!(removed.is_some());
        assert_eq!(c.placement.busy_accels(), 0);
    }

    fn delta_cluster() -> Cluster {
        let mut c = Cluster::new(ClusterSpec::balanced(1));
        for i in 0..3 {
            c.add_job(job(i));
        }
        c
    }

    #[test]
    fn apply_delta_assign_evict_migrate() {
        let mut c = delta_cluster();
        let a0 = c.spec.accels[0];
        let a1 = c.spec.accels[1];
        let mut d = PlacementDelta::new();
        d.push(PlacementOp::Assign {
            accel: a0,
            combo: Combo::pair(JobId(0), JobId(1)),
        });
        let out = c.apply_delta(&d).unwrap();
        assert_eq!(out.moves, 1);
        assert!(out.migrated_jobs.is_empty(), "first placement is not a migration");

        // migrate job 0 off the pair: peer stays behind solo
        let d = PlacementDelta {
            ops: vec![PlacementOp::Migrate {
                job: JobId(0),
                from: a0,
                to: a1,
            }],
        };
        let out = c.apply_delta(&d).unwrap();
        assert_eq!(c.placement.combo_on(a0), Some(&Combo::Solo(JobId(1))));
        assert_eq!(c.placement.combo_on(a1), Some(&Combo::Solo(JobId(0))));
        // job 1 kept its instance (pair → solo on a0): only job 0 migrated
        assert_eq!(out.migrated_jobs, vec![JobId(0)]);

        // evict
        let d = PlacementDelta {
            ops: vec![PlacementOp::Evict { accel: a1 }],
        };
        c.apply_delta(&d).unwrap();
        assert!(!c.placement.is_placed(JobId(0)));
    }

    #[test]
    fn apply_delta_is_transactional_and_validates() {
        let mut c = delta_cluster();
        let a0 = c.spec.accels[0];
        c.placement.assign(a0, Combo::Solo(JobId(0)));
        let before = c.placement.clone();
        // second op targets an occupied instance → whole delta rejected
        let d = PlacementDelta {
            ops: vec![
                PlacementOp::Assign {
                    accel: c.spec.accels[1],
                    combo: Combo::Solo(JobId(1)),
                },
                PlacementOp::Assign {
                    accel: a0,
                    combo: Combo::Solo(JobId(2)),
                },
            ],
        };
        assert!(c.apply_delta(&d).is_err());
        assert_eq!(c.placement.diff_count(&before), 0, "partial apply leaked");
        // unregistered job
        let d = PlacementDelta {
            ops: vec![PlacementOp::Assign {
                accel: c.spec.accels[1],
                combo: Combo::Solo(JobId(99)),
            }],
        };
        assert!(c.apply_delta(&d).is_err());
        // distributability: job(…) has D_j = 2, a third instance is too many
        let mut d = PlacementDelta::new();
        for accel in c.spec.accels.iter().skip(1).take(3) {
            d.push(PlacementOp::Assign {
                accel: *accel,
                combo: Combo::Solo(JobId(1)),
            });
        }
        assert!(c.apply_delta(&d).is_err());
    }

    #[test]
    fn replica_grow_and_shrink_are_not_migrations() {
        let mut c = Cluster::new(ClusterSpec::balanced(1));
        let mut serving = job(0);
        serving.distributability = 3;
        serving.inference = Some(crate::workload::InferenceSpec {
            base_rate: 5.0,
            diurnal_amplitude: 0.0,
            diurnal_phase_s: 0.0,
            latency_slo_s: 0.5,
        });
        c.add_job(serving);
        let a = [c.spec.accels[0], c.spec.accels[1], c.spec.accels[2]];
        c.placement.assign(a[0], Combo::Solo(JobId(0)));
        // scale-up (pure grow): surviving replica keeps serving → free
        let grow = PlacementDelta {
            ops: vec![PlacementOp::Assign {
                accel: a[1],
                combo: Combo::Solo(JobId(0)),
            }],
        };
        let out = c.apply_delta(&grow).unwrap();
        assert!(out.migrated_jobs.is_empty(), "scale-up billed as migration");
        // scale-down (pure shrink) → free
        let shrink = PlacementDelta {
            ops: vec![PlacementOp::Evict { accel: a[0] }],
        };
        let out = c.apply_delta(&shrink).unwrap();
        assert!(out.migrated_jobs.is_empty(), "scale-down billed as migration");
        // an actual replica MOVE still restarts the job
        let mv = PlacementDelta {
            ops: vec![PlacementOp::Migrate {
                job: JobId(0),
                from: a[1],
                to: a[2],
            }],
        };
        let out = c.apply_delta(&mv).unwrap();
        assert_eq!(out.migrated_jobs, vec![JobId(0)]);
        // training jobs keep the strict PR-2 semantics: any set change
        // (including a pure grow) is a restart
        let mut t = job(1);
        t.distributability = 2;
        c.add_job(t);
        c.placement.assign(a[0], Combo::Solo(JobId(1)));
        let grow = PlacementDelta {
            ops: vec![PlacementOp::Assign {
                accel: a[1],
                combo: Combo::Solo(JobId(1)),
            }],
        };
        let out = c.apply_delta(&grow).unwrap();
        assert_eq!(out.migrated_jobs, vec![JobId(1)]);
    }

    #[test]
    fn accel_down_evicts_and_blocks_assignment() {
        let mut c = delta_cluster();
        let a0 = c.spec.accels[0];
        c.placement.assign(a0, Combo::pair(JobId(0), JobId(1)));
        let evicted = c.set_accel_down(a0);
        assert_eq!(evicted, vec![JobId(0), JobId(1)]);
        assert!(c.placement.combo_on(a0).is_none());
        assert_eq!(c.available_accels().len(), c.spec.len() - 1);
        let d = PlacementDelta {
            ops: vec![PlacementOp::Assign {
                accel: a0,
                combo: Combo::Solo(JobId(0)),
            }],
        };
        assert!(c.apply_delta(&d).is_err(), "down accel must reject work");
        c.set_accel_up(a0);
        assert!(c.apply_delta(&d).is_ok());
    }

    #[test]
    fn diff_delta_equals_replacement() {
        let mut c = delta_cluster();
        c.placement.assign(c.spec.accels[0], Combo::Solo(JobId(0)));
        c.placement.assign(c.spec.accels[1], Combo::Solo(JobId(1)));
        let mut target = Placement::new();
        target.assign(c.spec.accels[1], Combo::pair(JobId(1), JobId(2)));
        target.assign(c.spec.accels[2], Combo::Solo(JobId(0)));
        let d = PlacementDelta::diff(&c.placement, &target);
        let out = c.apply_delta(&d).unwrap();
        assert_eq!(c.placement.diff_count(&target), 0);
        assert_eq!(out.migrated_jobs, vec![JobId(0)]);
    }

    #[test]
    fn set_power_state_is_cheap_validated_and_down_legal() {
        let mut c = delta_cluster();
        let v100 = *c.spec.accels.iter().find(|a| a.accel == AccelType::V100).unwrap();
        assert_eq!(c.power_state(v100), crate::power::PowerState::Nominal);
        let d = PlacementDelta {
            ops: vec![PlacementOp::SetPowerState {
                accel: v100,
                state: crate::power::PowerState::Low,
            }],
        };
        let out = c.apply_delta(&d).unwrap();
        assert_eq!(out.moves, 0, "re-stating is not a placement move");
        assert!(out.migrated_jobs.is_empty());
        assert_eq!(c.power_state(v100), crate::power::PowerState::Low);
        // back to nominal keeps the map sparse
        let d = PlacementDelta {
            ops: vec![PlacementOp::SetPowerState {
                accel: v100,
                state: crate::power::PowerState::Nominal,
            }],
        };
        c.apply_delta(&d).unwrap();
        assert!(c.power_state_entries().is_empty());
        // legal on a down instance (unlike Assign)
        c.set_accel_down(v100);
        let d = PlacementDelta {
            ops: vec![PlacementOp::SetPowerState {
                accel: v100,
                state: crate::power::PowerState::Turbo,
            }],
        };
        c.apply_delta(&d).unwrap();
        assert_eq!(c.power_state(v100), crate::power::PowerState::Turbo);
        // unknown instance still rejected
        let bogus = AccelId {
            server: 999,
            accel: AccelType::V100,
        };
        let d = PlacementDelta {
            ops: vec![PlacementOp::SetPowerState {
                accel: bogus,
                state: crate::power::PowerState::Low,
            }],
        };
        assert!(c.apply_delta(&d).is_err());
    }

    #[test]
    fn worst_case_watts_tracks_occupancy_states_and_outages() {
        use crate::power::{state_power_watts, PowerState};
        let mut c = delta_cluster(); // balanced(1): one instance per type
        let all_idle: f64 =
            c.spec.accels.iter().map(|a| crate::cluster::power_watts(a.accel, 0.0)).sum();
        assert!((c.worst_case_watts() - all_idle).abs() < 1e-9);
        let v100 = *c.spec.accels.iter().find(|a| a.accel == AccelType::V100).unwrap();
        c.placement.assign(v100, Combo::Solo(JobId(0)));
        let busy_nominal = all_idle - crate::cluster::power_watts(AccelType::V100, 0.0)
            + crate::cluster::power_watts(AccelType::V100, 1.0);
        assert!((c.worst_case_watts() - busy_nominal).abs() < 1e-9);
        c.set_power_state(v100, PowerState::Low);
        let busy_low = all_idle - crate::cluster::power_watts(AccelType::V100, 0.0)
            + state_power_watts(AccelType::V100, PowerState::Low, 1.0);
        assert!((c.worst_case_watts() - busy_low).abs() < 1e-9);
        // a down instance contributes nothing, whatever its state
        c.set_accel_down(v100);
        let without = all_idle - crate::cluster::power_watts(AccelType::V100, 0.0);
        assert!((c.worst_case_watts() - without).abs() < 1e-9);
    }

    #[test]
    fn power_cap_rejects_breaching_deltas_transactionally() {
        use crate::power::PowerState;
        let mut c = delta_cluster();
        let v100 = *c.spec.accels.iter().find(|a| a.accel == AccelType::V100).unwrap();
        // balanced(1) all-idle nominal = 180 W; busy V100 nominal = 395 W,
        // busy V100 low = 293 W (see docs/POWER.md worked example)
        c.set_power_cap(Some(300.0));
        let before = c.placement.clone();
        let assign = PlacementOp::Assign {
            accel: v100,
            combo: Combo::Solo(JobId(0)),
        };
        let d = PlacementDelta {
            ops: vec![assign],
        };
        let err = c.apply_delta(&d).unwrap_err().to_string();
        assert!(err.contains("power cap"), "{err}");
        assert_eq!(c.placement.diff_count(&before), 0, "partial apply leaked");
        assert!(c.power_state_entries().is_empty(), "state change leaked");
        // the same assignment fits once the target is down-clocked
        let d = PlacementDelta {
            ops: vec![
                PlacementOp::SetPowerState {
                    accel: v100,
                    state: PowerState::Low,
                },
                assign,
            ],
        };
        c.apply_delta(&d).unwrap();
        assert!(c.worst_case_watts() <= 300.0 + 1e-9);
    }

    #[test]
    fn trim_to_power_cap_downclocks_then_drops() {
        use crate::power::PowerState;
        let mut c = delta_cluster();
        let v100 = *c.spec.accels.iter().find(|a| a.accel == AccelType::V100).unwrap();
        let assign = PlacementOp::Assign {
            accel: v100,
            combo: Combo::Solo(JobId(0)),
        };
        let d = PlacementDelta {
            ops: vec![assign],
        };
        // uncapped: the delta passes through untouched
        assert_eq!(c.trim_to_power_cap(&d), d);
        // 300 W: fits only at low → trim inserts the down-clock
        c.set_power_cap(Some(300.0));
        let trimmed = c.trim_to_power_cap(&d);
        assert_eq!(
            trimmed.ops,
            vec![
                PlacementOp::SetPowerState {
                    accel: v100,
                    state: PowerState::Low,
                },
                assign,
            ]
        );
        c.apply_delta(&trimmed).unwrap();
        assert!(c.worst_case_watts() <= 300.0 + 1e-9);
        c.placement.clear_accel(v100);
        c.set_power_state(v100, PowerState::Nominal);
        // 200 W: not even low fits → the assignment is dropped
        c.set_power_cap(Some(200.0));
        let trimmed = c.trim_to_power_cap(&d);
        assert!(trimmed.is_empty(), "{:?}", trimmed.ops);
        // an invalid op is kept so apply_delta still surfaces the bug
        let bad = PlacementDelta {
            ops: vec![PlacementOp::Evict { accel: v100 }],
        };
        assert_eq!(c.trim_to_power_cap(&bad), bad);
        assert!(c.apply_delta(&bad).is_err());
    }

    #[test]
    fn suspend_parks_and_resume_restores() {
        let mut c = delta_cluster();
        let a0 = c.spec.accels[0];
        let a1 = c.spec.accels[1];
        c.placement.assign(a0, Combo::pair(JobId(0), JobId(1)));
        let d = PlacementDelta {
            ops: vec![PlacementOp::Suspend { job: JobId(0) }],
        };
        let out = c.apply_delta(&d).unwrap();
        assert!(c.is_suspended(JobId(0)));
        assert!(!c.placement.is_placed(JobId(0)));
        // the co-runner is re-hosted solo on the same instance
        assert_eq!(c.placement.combo_on(a0), Some(&Combo::Solo(JobId(1))));
        assert_eq!(out.suspended_jobs, vec![JobId(0)]);
        assert!(out.resumed_jobs.is_empty());
        assert!(out.migrated_jobs.is_empty(), "parking is not a migration");
        assert_eq!(c.suspended_job_ids(), vec![JobId(0)]);
        // resume onto an empty instance restores it solo
        let d = PlacementDelta {
            ops: vec![PlacementOp::Resume {
                job: JobId(0),
                accel: a1,
            }],
        };
        let out = c.apply_delta(&d).unwrap();
        assert!(!c.is_suspended(JobId(0)));
        assert_eq!(c.placement.combo_on(a1), Some(&Combo::Solo(JobId(0))));
        assert_eq!(out.resumed_jobs, vec![JobId(0)]);
        assert!(out.suspended_jobs.is_empty());
    }

    #[test]
    fn suspend_resume_validation() {
        let mut c = delta_cluster();
        let a0 = c.spec.accels[0];
        let a1 = c.spec.accels[1];
        let park0 = PlacementOp::Suspend { job: JobId(0) };
        // suspending an unplaced job is a policy bug
        assert!(c.apply_delta(&PlacementDelta { ops: vec![park0] }).is_err());
        c.placement.assign(a0, Combo::Solo(JobId(0)));
        c.apply_delta(&PlacementDelta { ops: vec![park0] }).unwrap();
        // double-suspend rejected
        assert!(c.apply_delta(&PlacementDelta { ops: vec![park0] }).is_err());
        // resuming a job that is not suspended is rejected
        let d = PlacementDelta {
            ops: vec![PlacementOp::Resume {
                job: JobId(1),
                accel: a1,
            }],
        };
        assert!(c.apply_delta(&d).is_err());
        // resume onto an occupied instance is rejected
        c.placement.assign(a1, Combo::Solo(JobId(1)));
        let d = PlacementDelta {
            ops: vec![PlacementOp::Resume {
                job: JobId(0),
                accel: a1,
            }],
        };
        assert!(c.apply_delta(&d).is_err());
        // resume onto a down instance is rejected
        let a2 = c.spec.accels[2];
        c.set_accel_down(a2);
        let d = PlacementDelta {
            ops: vec![PlacementOp::Resume {
                job: JobId(0),
                accel: a2,
            }],
        };
        assert!(c.apply_delta(&d).is_err());
        assert!(c.is_suspended(JobId(0)), "failed resume must leave the job parked");
        // a plain Assign naming the suspended job auto-resumes it
        let d = PlacementDelta {
            ops: vec![PlacementOp::Assign {
                accel: c.spec.accels[3],
                combo: Combo::Solo(JobId(0)),
            }],
        };
        let out = c.apply_delta(&d).unwrap();
        assert!(!c.is_suspended(JobId(0)));
        assert_eq!(out.resumed_jobs, vec![JobId(0)]);
        // departure clears any parked state
        c.apply_delta(&PlacementDelta { ops: vec![park0] }).unwrap();
        c.remove_job(JobId(0));
        assert!(!c.is_suspended(JobId(0)));
    }

    #[test]
    fn elastic_training_grow_and_shrink_are_not_migrations() {
        let mut c = Cluster::new(ClusterSpec::balanced(1));
        let mut t = job(0);
        t.elastic = true;
        t.distributability = 3;
        c.add_job(t);
        let a = [c.spec.accels[0], c.spec.accels[1], c.spec.accels[2]];
        c.placement.assign(a[0], Combo::Solo(JobId(0)));
        let grow = PlacementDelta {
            ops: vec![PlacementOp::Assign {
                accel: a[1],
                combo: Combo::Solo(JobId(0)),
            }],
        };
        let out = c.apply_delta(&grow).unwrap();
        assert!(out.migrated_jobs.is_empty(), "elastic grow billed as migration");
        let shrink = PlacementDelta {
            ops: vec![PlacementOp::Evict { accel: a[0] }],
        };
        let out = c.apply_delta(&shrink).unwrap();
        assert!(out.migrated_jobs.is_empty(), "elastic shrink billed as migration");
        // an actual replica MOVE still restarts the job
        let mv = PlacementDelta {
            ops: vec![PlacementOp::Migrate {
                job: JobId(0),
                from: a[1],
                to: a[2],
            }],
        };
        let out = c.apply_delta(&mv).unwrap();
        assert_eq!(out.migrated_jobs, vec![JobId(0)]);
    }

    #[test]
    fn stall_tracking() {
        let mut c = delta_cluster();
        assert_eq!(c.stalled_until(JobId(0)), 0.0);
        assert_eq!(c.stall_job(JobId(0), 42.0), 42.0);
        // overlapping penalty: only the extension beyond 42 is charged
        assert_eq!(c.stall_job(JobId(0), 30.0), 0.0); // never shortens
        assert_eq!(c.stall_job(JobId(0), 50.0), 8.0);
        assert_eq!(c.stalled_until(JobId(0)), 50.0);
        c.remove_job(JobId(0));
        assert_eq!(c.stalled_until(JobId(0)), 0.0);
    }
}
