//! Heterogeneous cluster substrate: servers × accelerator instances,
//! placement state, energy accounting and the monitoring module.
//!
//! The paper assumes a real cluster; here the substrate is a
//! discrete-time simulator backed by the [`crate::workload::ThroughputOracle`]
//! ground truth. GOGH itself only ever sees the oracle through
//! [`monitor::Monitor`] measurements (with noise) — exactly the
//! observability a real deployment would have.

pub mod energy;
pub mod monitor;

pub use energy::{power_watts, EnergyMeter};
pub use monitor::{Measurement, Monitor};

use std::collections::HashMap;

use crate::workload::{AccelType, Combo, JobId, JobSpec};

/// Identifies one accelerator instance: (server, accel type).
/// The ILP's x^c_{a,s} variables range over these (constraint 2f: each
/// instance hosts at most one combination).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct AccelId {
    pub server: u32,
    pub accel: AccelType,
}

impl std::fmt::Display for AccelId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "s{}/{}", self.server, self.accel.name())
    }
}

/// Static cluster topology.
#[derive(Debug, Clone)]
pub struct ClusterSpec {
    /// Accelerator instances; a server may appear with several types.
    pub accels: Vec<AccelId>,
}

impl ClusterSpec {
    /// A balanced heterogeneous cluster: `servers_per_type` servers for
    /// each of the six Gavel accelerator types.
    pub fn balanced(servers_per_type: u32) -> Self {
        let mut accels = vec![];
        let mut server = 0;
        for a in crate::workload::ACCEL_TYPES {
            for _ in 0..servers_per_type {
                accels.push(AccelId { server, accel: a });
                server += 1;
            }
        }
        Self { accels }
    }

    /// A custom mix: `(accel type, count)` pairs.
    pub fn mix(counts: &[(AccelType, u32)]) -> Self {
        let mut accels = vec![];
        let mut server = 0;
        for &(a, n) in counts {
            for _ in 0..n {
                accels.push(AccelId { server, accel: a });
                server += 1;
            }
        }
        Self { accels }
    }

    pub fn len(&self) -> usize {
        self.accels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.accels.is_empty()
    }
}

/// Live placement state of the cluster.
#[derive(Debug, Clone, Default)]
pub struct Placement {
    /// accelerator instance -> hosted combination.
    by_accel: HashMap<AccelId, Combo>,
    /// job -> accelerator instances running it (|set| ≤ D_j).
    by_job: HashMap<JobId, Vec<AccelId>>,
}

impl Placement {
    pub fn new() -> Self {
        Self::default()
    }

    /// Assign `combo` to `accel`, replacing whatever ran there.
    pub fn assign(&mut self, accel: AccelId, combo: Combo) {
        self.clear_accel(accel);
        for j in combo.jobs() {
            self.by_job.entry(j).or_default().push(accel);
        }
        self.by_accel.insert(accel, combo);
    }

    /// Remove whatever combination runs on `accel`.
    pub fn clear_accel(&mut self, accel: AccelId) {
        if let Some(old) = self.by_accel.remove(&accel) {
            for j in old.jobs() {
                if let Some(v) = self.by_job.get_mut(&j) {
                    v.retain(|&a| a != accel);
                    if v.is_empty() {
                        self.by_job.remove(&j);
                    }
                }
            }
        }
    }

    /// Remove a finished/departed job everywhere. Co-runners are
    /// re-hosted as solos on the same instance.
    pub fn remove_job(&mut self, j: JobId) {
        let accels: Vec<AccelId> = self.accels_of(j).to_vec();
        for a in accels {
            let combo = self.by_accel[&a];
            self.clear_accel(a);
            if let Some(other) = combo.other(j) {
                self.assign(a, Combo::Solo(other));
            }
        }
    }

    pub fn combo_on(&self, accel: AccelId) -> Option<&Combo> {
        self.by_accel.get(&accel)
    }

    pub fn accels_of(&self, j: JobId) -> &[AccelId] {
        self.by_job.get(&j).map(|v| v.as_slice()).unwrap_or(&[])
    }

    pub fn is_placed(&self, j: JobId) -> bool {
        self.by_job.contains_key(&j)
    }

    pub fn iter(&self) -> impl Iterator<Item = (&AccelId, &Combo)> {
        self.by_accel.iter()
    }

    pub fn busy_accels(&self) -> usize {
        self.by_accel.len()
    }

    pub fn jobs(&self) -> impl Iterator<Item = &JobId> {
        self.by_job.keys()
    }

    /// Number of placement moves needed to turn `self` into `target`
    /// (migration cost metric reported by the coordinator).
    pub fn diff_count(&self, target: &Placement) -> usize {
        let mut moves = 0;
        for (a, c) in target.iter() {
            if self.by_accel.get(a) != Some(c) {
                moves += 1;
            }
        }
        for a in self.by_accel.keys() {
            if !target.by_accel.contains_key(a) {
                moves += 1;
            }
        }
        moves
    }
}

/// The simulated cluster: spec + placement + job registry + clock.
#[derive(Debug, Clone)]
pub struct Cluster {
    pub spec: ClusterSpec,
    pub placement: Placement,
    jobs: HashMap<JobId, JobSpec>,
    now: f64,
}

impl Cluster {
    pub fn new(spec: ClusterSpec) -> Self {
        Self {
            spec,
            placement: Placement::new(),
            jobs: HashMap::new(),
            now: 0.0,
        }
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    pub fn advance_to(&mut self, t: f64) {
        debug_assert!(t >= self.now);
        self.now = t;
    }

    pub fn add_job(&mut self, job: JobSpec) {
        self.jobs.insert(job.id, job);
    }

    pub fn remove_job(&mut self, j: JobId) -> Option<JobSpec> {
        self.placement.remove_job(j);
        self.jobs.remove(&j)
    }

    pub fn job(&self, j: JobId) -> Option<&JobSpec> {
        self.jobs.get(&j)
    }

    pub fn job_mut(&mut self, j: JobId) -> Option<&mut JobSpec> {
        self.jobs.get_mut(&j)
    }

    pub fn jobs(&self) -> impl Iterator<Item = &JobSpec> {
        self.jobs.values()
    }

    pub fn active_job_ids(&self) -> Vec<JobId> {
        let mut v: Vec<JobId> = self.jobs.keys().copied().collect();
        v.sort();
        v
    }

    pub fn n_jobs(&self) -> usize {
        self.jobs.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::ModelFamily;

    fn job(id: u32) -> JobSpec {
        JobSpec {
            id: JobId(id),
            family: ModelFamily::ResNet18,
            batch_size: 32,
            replication: 1,
            min_throughput: 0.1,
            distributability: 2,
            work: 100.0,
        }
    }

    fn aid(s: u32) -> AccelId {
        AccelId {
            server: s,
            accel: AccelType::V100,
        }
    }

    #[test]
    fn balanced_spec_has_six_types() {
        let spec = ClusterSpec::balanced(2);
        assert_eq!(spec.len(), 12);
        let types: std::collections::HashSet<_> = spec.accels.iter().map(|a| a.accel).collect();
        assert_eq!(types.len(), 6);
    }

    #[test]
    fn assign_replaces_previous_combo() {
        let mut p = Placement::new();
        p.assign(aid(0), Combo::Solo(JobId(1)));
        p.assign(aid(0), Combo::pair(JobId(2), JobId(3)));
        assert!(!p.is_placed(JobId(1)));
        assert_eq!(p.combo_on(aid(0)), Some(&Combo::pair(JobId(2), JobId(3))));
        assert_eq!(p.accels_of(JobId(2)), &[aid(0)]);
    }

    #[test]
    fn remove_job_rehosts_co_runner_solo() {
        let mut p = Placement::new();
        p.assign(aid(0), Combo::pair(JobId(1), JobId(2)));
        p.remove_job(JobId(1));
        assert_eq!(p.combo_on(aid(0)), Some(&Combo::Solo(JobId(2))));
        assert!(p.is_placed(JobId(2)));
        assert!(!p.is_placed(JobId(1)));
    }

    #[test]
    fn distributed_job_tracked_on_all_accels() {
        let mut p = Placement::new();
        p.assign(aid(0), Combo::Solo(JobId(1)));
        p.assign(aid(1), Combo::Solo(JobId(1)));
        assert_eq!(p.accels_of(JobId(1)).len(), 2);
        p.remove_job(JobId(1));
        assert_eq!(p.busy_accels(), 0);
    }

    #[test]
    fn diff_count_counts_moves() {
        let mut a = Placement::new();
        a.assign(aid(0), Combo::Solo(JobId(1)));
        let mut b = Placement::new();
        b.assign(aid(0), Combo::Solo(JobId(1)));
        assert_eq!(a.diff_count(&b), 0);
        b.assign(aid(1), Combo::Solo(JobId(2)));
        assert_eq!(a.diff_count(&b), 1);
        b.assign(aid(0), Combo::Solo(JobId(3)));
        assert_eq!(a.diff_count(&b), 2);
    }

    #[test]
    fn cluster_job_lifecycle() {
        let mut c = Cluster::new(ClusterSpec::balanced(1));
        c.add_job(job(1));
        assert!(c.job(JobId(1)).is_some());
        c.placement.assign(c.spec.accels[0], Combo::Solo(JobId(1)));
        let removed = c.remove_job(JobId(1));
        assert!(removed.is_some());
        assert_eq!(c.placement.busy_accels(), 0);
    }
}
