//! Energy model γ_a(·) (paper §2.4, objective 2a).
//!
//! Power of an accelerator of type `a` at relative load `u ∈ [0, 1]` is
//! `idle + extra · u^0.8` — idle draw plus a sublinear utilization term,
//! the shape reported by GPU profiling studies (the paper cites \[10\] for
//! profiling γ_a). An idle-but-present accelerator still burns its idle
//! power, which is what makes consolidation onto fewer, faster GPUs
//! energy-favourable — the effect GOGH's objective exploits.

use std::collections::BTreeMap;

use super::{AccelId, Placement};
use crate::power::{state_power_watts, PowerState};
use crate::workload::{AccelType, JobId};

/// Instantaneous power (watts) of accelerator type `a` at load `u`.
///
/// `u` is the hosted combination's aggregate normalized throughput
/// relative to the accelerator's own solo capability — the `Σ T x`
/// argument of γ_a in objective (2a).
pub fn power_watts(a: AccelType, u: f64) -> f64 {
    let (idle, extra) = a.power_params();
    let u = u.clamp(0.0, 1.0);
    idle + extra * u.powf(0.8)
}

/// Piecewise-linear (chord) approximation of `power_watts` for the ILP:
/// each segment interpolates between the curve's knot values. Since
/// `u ↦ u^0.8` is concave, the chord is a *lower* bound on the true
/// power within each segment (exact at the knots) — not an upper
/// envelope; tangent lines, not secants, would over-approximate a
/// concave curve. The paper notes γ_a can be linearized; since each
/// instance hosts at most one combination (constraint 2f), the
/// objective is evaluated per-combo and needs no explicit linearization
/// — this helper exists for the ablation bench that solves the
/// "linearized-γ" variant instead.
pub fn power_linearized(a: AccelType, u: f64, segments: usize) -> f64 {
    let (idle, extra) = a.power_params();
    let u = u.clamp(0.0, 1.0);
    // sample the curve at segment knots, take the chord value
    let seg = (u * segments as f64).floor().min(segments as f64 - 1.0);
    let u0 = seg / segments as f64;
    let u1 = (seg + 1.0) / segments as f64;
    let p0 = idle + extra * u0.powf(0.8);
    let p1 = idle + extra * u1.powf(0.8);
    p0 + (p1 - p0) * (u - u0) / (u1 - u0)
}

/// Integrates cluster energy over simulated time.
#[derive(Debug, Clone, Default)]
pub struct EnergyMeter {
    total_joules: f64,
    /// per-accelerator-type cumulative joules (for the breakdown table)
    by_type: BTreeMap<AccelType, f64>,
    /// per-DVFS-state cumulative joules, indexed by [`PowerState::index`]
    by_state: [f64; 3],
    /// cumulative grams of CO₂ (0 unless a carbon signal is configured)
    grams_co2: f64,
    last_t: f64,
}

impl EnergyMeter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Accrue energy for the interval `[last_t, t]` given the placement
    /// and each hosted job's current *measured* normalized throughput.
    /// `loads` maps accelerator instance → relative load u.
    ///
    /// `accels_in_service` must be the cluster's *available* set (e.g.
    /// [`crate::cluster::Cluster::available_accels`]), never the raw
    /// spec: an accelerator that is down draws nothing, and billing its
    /// idle watts through an `AccelDown` window would inflate total
    /// joules for every policy (asserted by the churn regression test in
    /// `coordinator/scheduler.rs`). This holds *regardless of DVFS
    /// state*: a down instance may still carry a remembered non-nominal
    /// state, but because billing walks the in-service list — never the
    /// state map — it accrues zero until it returns (the down+re-state
    /// regression test next to the churn test pins this).
    pub fn accrue(
        &mut self,
        t: f64,
        accels_in_service: &[AccelId],
        loads: &BTreeMap<AccelId, f64>,
    ) {
        self.accrue_states(t, accels_in_service, &|_| PowerState::Nominal, loads, 0.0);
    }

    /// State- and carbon-aware accrual: like [`EnergyMeter::accrue`] but
    /// each instance bills its DVFS state's power curve, joules are also
    /// bucketed per state, and `gco2_per_kwh` (the carbon signal's
    /// intensity over this interval; 0 = no signal) accrues emissions.
    pub fn accrue_states(
        &mut self,
        t: f64,
        accels_in_service: &[AccelId],
        state_of: &dyn Fn(AccelId) -> PowerState,
        loads: &BTreeMap<AccelId, f64>,
        gco2_per_kwh: f64,
    ) {
        let dt = (t - self.last_t).max(0.0);
        self.last_t = t;
        if dt == 0.0 {
            return;
        }
        for aid in accels_in_service {
            let u = loads.get(aid).copied().unwrap_or(0.0);
            let s = state_of(*aid);
            let joules = state_power_watts(aid.accel, s, u) * dt;
            self.total_joules += joules;
            *self.by_type.entry(aid.accel).or_default() += joules;
            self.by_state[s.index()] += joules;
            self.grams_co2 += gco2_per_kwh * joules / 3.6e6;
        }
    }

    pub fn total_joules(&self) -> f64 {
        self.total_joules
    }

    pub fn joules_by_type(&self) -> &BTreeMap<AccelType, f64> {
        &self.by_type
    }

    /// Cumulative joules per DVFS state, `[low, nominal, turbo]`.
    pub fn joules_by_state(&self) -> [f64; 3] {
        self.by_state
    }

    /// Cumulative emissions (grams of CO₂); 0 without a carbon signal.
    pub fn grams_co2(&self) -> f64 {
        self.grams_co2
    }

    pub fn reset_clock(&mut self, t: f64) {
        self.last_t = t;
    }
}

/// Compute per-instance relative loads for a placement: the load of an
/// instance is the sum of its hosted jobs' throughputs divided by the
/// instance's best solo capability (so a well-packed pair ≈ 1.0).
pub fn placement_loads(
    placement: &Placement,
    throughput_of: &dyn Fn(JobId, AccelId) -> f64,
    solo_capability: &dyn Fn(AccelId) -> f64,
) -> BTreeMap<AccelId, f64> {
    let mut loads = BTreeMap::new();
    for (aid, combo) in placement.iter() {
        let total: f64 = combo.jobs().iter().map(|&j| throughput_of(j, *aid)).sum();
        let cap = solo_capability(*aid).max(1e-9);
        loads.insert(*aid, (total / cap).clamp(0.0, 1.0));
    }
    loads
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::Combo;

    #[test]
    fn power_is_monotone_in_load() {
        for a in crate::workload::ACCEL_TYPES {
            let mut last = 0.0;
            for i in 0..=10 {
                let p = power_watts(a, i as f64 / 10.0);
                assert!(p >= last);
                last = p;
            }
        }
    }

    #[test]
    fn idle_power_is_nonzero() {
        assert!(power_watts(AccelType::K80, 0.0) > 0.0);
    }

    #[test]
    fn linearization_error_is_small_with_many_segments() {
        for a in [AccelType::K80, AccelType::V100] {
            for i in 0..=20 {
                let u = i as f64 / 20.0;
                let exact = power_watts(a, u);
                let lin = power_linearized(a, u, 16);
                assert!((exact - lin).abs() / exact < 0.02, "{a:?} u={u}: {exact} vs {lin}");
            }
        }
    }

    #[test]
    fn meter_integrates_idle_cluster() {
        let mut m = EnergyMeter::new();
        let accels = vec![AccelId {
            server: 0,
            accel: AccelType::K80,
        }];
        m.accrue(10.0, &accels, &BTreeMap::new());
        // 10 s at k80 idle (25 W) = 250 J
        assert!((m.total_joules() - 250.0).abs() < 1e-9);
    }

    #[test]
    fn state_aware_accrual_buckets_joules_and_carbon() {
        let mut m = EnergyMeter::new();
        let k80 = AccelId {
            server: 0,
            accel: AccelType::K80,
        };
        let v100 = AccelId {
            server: 1,
            accel: AccelType::V100,
        };
        let accels = vec![k80, v100];
        let state_of = |a: AccelId| if a == k80 { PowerState::Low } else { PowerState::Nominal };
        m.accrue_states(10.0, &accels, &state_of, &BTreeMap::new(), 360.0);
        // 10 s idle: k80 low 21.25 W → 212.5 J, v100 nominal 35 W → 350 J
        assert!((m.total_joules() - 562.5).abs() < 1e-9);
        let by = m.joules_by_state();
        assert!((by[PowerState::Low.index()] - 212.5).abs() < 1e-9);
        assert!((by[PowerState::Nominal.index()] - 350.0).abs() < 1e-9);
        assert_eq!(by[PowerState::Turbo.index()], 0.0);
        // 360 gCO₂/kWh = 1e-4 g/J → 562.5 J = 0.05625 g
        assert!((m.grams_co2() - 0.05625).abs() < 1e-9);
    }

    #[test]
    fn legacy_accrue_is_nominal_and_carbon_free() {
        let accels = vec![AccelId {
            server: 0,
            accel: AccelType::P100,
        }];
        let mut loads = BTreeMap::new();
        loads.insert(accels[0], 0.7);
        let mut legacy = EnergyMeter::new();
        legacy.accrue(25.0, &accels, &loads);
        let mut stated = EnergyMeter::new();
        stated.accrue_states(25.0, &accels, &|_| PowerState::Nominal, &loads, 0.0);
        assert_eq!(legacy.total_joules(), stated.total_joules());
        assert_eq!(legacy.grams_co2(), 0.0);
        assert_eq!(legacy.joules_by_state()[PowerState::Nominal.index()], legacy.total_joules());
    }

    #[test]
    fn loaded_cluster_burns_more() {
        let accels = vec![AccelId {
            server: 0,
            accel: AccelType::V100,
        }];
        let mut idle = EnergyMeter::new();
        idle.accrue(10.0, &accels, &BTreeMap::new());
        let mut busy = EnergyMeter::new();
        let mut loads = BTreeMap::new();
        loads.insert(accels[0], 1.0);
        busy.accrue(10.0, &accels, &loads);
        assert!(busy.total_joules() > idle.total_joules());
    }

    #[test]
    fn placement_loads_clamped_unit() {
        let mut p = Placement::new();
        let aid = AccelId {
            server: 0,
            accel: AccelType::K80,
        };
        p.assign(aid, Combo::pair(JobId(1), JobId(2)));
        let loads = placement_loads(&p, &|_, _| 0.9, &|_| 1.0);
        assert_eq!(loads[&aid], 1.0); // 1.8 clamped
    }
}
