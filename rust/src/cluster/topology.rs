//! Two-level cluster topology: shard-groups of server-pool shards.
//!
//! PR 3's flat round-robin [`ClusterSpec::shards`] partition scales to
//! ~1k accelerators: every arrival fans one local solve per shard, so
//! the per-decision fan-out grows linearly with the fleet. The
//! hierarchical topology bounds that. A cheap top-level router scores
//! *groups* (catalog-only marginal energy, no LP) and descends into the
//! winning group's local shards, so a 10k-accelerator cluster still
//! solves the same bounded number of local ILPs per arrival.
//!
//! Depth 1 (`groups == 1`) reproduces the PR 3 flat partition
//! bit-for-bit (parity-tested below), so existing single-level
//! configurations see identical placements.

use std::collections::BTreeSet;

use super::{AccelId, ClusterSpec, ShardSpec};

/// One shard-group: a deterministic slice of the cluster spec that the
/// top-level router treats as a routing domain. Its shards are the
/// actual placement domains the local ILP workers solve.
#[derive(Debug, Clone)]
pub struct TopologyGroup {
    pub index: usize,
    /// Member instances, in spec order.
    pub accels: Vec<AccelId>,
    /// Local shards. [`ShardSpec::index`] is globally unique across the
    /// whole topology (sequential over groups), so per-shard stats and
    /// logs keep a single flat index space whatever the depth.
    pub shards: Vec<ShardSpec>,
    /// Membership sets, parallel to `shards` (ordered sets so walks on
    /// the decision path stay deterministic).
    pub sets: Vec<BTreeSet<AccelId>>,
}

impl TopologyGroup {
    pub fn contains(&self, a: AccelId) -> bool {
        self.accels.contains(&a)
    }
}

/// The full two-level partition: every instance appears in exactly one
/// shard of exactly one group (property-tested in `tests/proptests.rs`).
#[derive(Debug, Clone)]
pub struct Topology {
    pub groups: Vec<TopologyGroup>,
}

impl Topology {
    /// Total number of local shards across all groups.
    pub fn total_shards(&self) -> usize {
        self.groups.iter().map(|g| g.shards.len()).sum()
    }

    /// Flattened walk over every (group, shard, membership set), in
    /// global shard-index order.
    pub fn shards(&self) -> impl Iterator<Item = (&TopologyGroup, &ShardSpec, &BTreeSet<AccelId>)> {
        self.groups
            .iter()
            .flat_map(|g| g.shards.iter().zip(&g.sets).map(move |(s, set)| (g, s, set)))
    }

    /// Flatten into the plain shard list (the PR 3 shape); global shard
    /// indices are already sequential, so the order is `0..total`.
    pub fn into_shards(self) -> Vec<ShardSpec> {
        self.groups.into_iter().flat_map(|g| g.shards).collect()
    }
}

impl ClusterSpec {
    /// Build the two-level topology: `groups` shard-groups, each split
    /// into `shards_per_group` local shards. Instances are dealt
    /// round-robin over spec order at both levels — since
    /// [`ClusterSpec::mix`] lists each type as a contiguous run, every
    /// group (and every shard within it) receives a near-equal slice of
    /// every accelerator type. Both counts are clamped so no group or
    /// shard is ever empty on a non-empty cluster. `topology(1, p)`
    /// reproduces the flat [`ClusterSpec::shards`] partition
    /// bit-for-bit.
    pub fn topology(&self, groups: usize, shards_per_group: usize) -> Topology {
        let g = groups.clamp(1, self.accels.len().max(1));
        let mut members: Vec<Vec<AccelId>> = vec![vec![]; g];
        for (i, a) in self.accels.iter().enumerate() {
            members[i % g].push(*a);
        }
        let mut out: Vec<TopologyGroup> = Vec::with_capacity(g);
        let mut next_shard = 0usize;
        for (index, accels) in members.into_iter().enumerate() {
            let p = shards_per_group.clamp(1, accels.len().max(1));
            let shards: Vec<ShardSpec> = (0..p)
                .map(|s| ShardSpec {
                    index: next_shard + s,
                    accels: accels
                        .iter()
                        .enumerate()
                        .filter(|(i, _)| i % p == s)
                        .map(|(_, a)| *a)
                        .collect(),
                })
                .collect();
            next_shard += p;
            let sets = shards.iter().map(|s| s.accels.iter().copied().collect()).collect();
            out.push(TopologyGroup {
                index,
                accels,
                shards,
                sets,
            });
        }
        Topology { groups: out }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn depth1_topology_matches_flat_shards_bit_for_bit() {
        // The deprecated flat partition is the PR 3 ground truth; a
        // depth-1 topology must reproduce it exactly so single-level
        // configurations keep byte-identical placements.
        for spt in [1u32, 4] {
            let spec = ClusterSpec::balanced(spt);
            for p in [0usize, 1, 2, 3, 5, 8, 100] {
                #[allow(deprecated)]
                let flat = spec.shards(p);
                let topo = spec.topology(1, p);
                assert_eq!(topo.groups.len(), 1);
                let nested = topo.into_shards();
                assert_eq!(flat.len(), nested.len(), "p={p}");
                for (f, n) in flat.iter().zip(&nested) {
                    assert_eq!(f.index, n.index, "p={p}");
                    assert_eq!(f.accels, n.accels, "p={p} shard {}", f.index);
                }
            }
        }
    }

    #[test]
    fn two_level_topology_partitions_exactly_once() {
        let spec = ClusterSpec::balanced(4); // 24 instances, 6 types
        for g in [1usize, 2, 3, 4] {
            for p in [1usize, 2, 3] {
                let topo = spec.topology(g, p);
                assert_eq!(topo.groups.len(), g);
                assert_eq!(topo.total_shards(), g * p);
                // global shard indices are sequential over groups
                let indices: Vec<usize> = topo.shards().map(|(_, s, _)| s.index).collect();
                assert_eq!(indices, (0..g * p).collect::<Vec<_>>());
                // every instance lands in exactly one shard of one group
                let mut seen: Vec<AccelId> =
                    topo.shards().flat_map(|(_, s, _)| s.accels.clone()).collect();
                seen.sort();
                let mut all = spec.accels.clone();
                all.sort();
                assert_eq!(seen, all, "g={g} p={p}");
                for (grp, shard, set) in topo.shards() {
                    assert_eq!(
                        set.iter().copied().collect::<Vec<_>>(),
                        {
                            let mut v = shard.accels.clone();
                            v.sort();
                            v
                        },
                        "set/shard mismatch in group {}",
                        grp.index
                    );
                    for a in &shard.accels {
                        assert!(grp.contains(*a));
                    }
                }
            }
        }
    }

    #[test]
    fn topology_clamps_both_levels() {
        let spec = ClusterSpec::balanced(1); // 6 instances
        let topo = spec.topology(100, 100);
        assert_eq!(topo.groups.len(), 6, "groups clamp to the instance count");
        assert_eq!(topo.total_shards(), 6, "singleton groups hold one shard");
        for (g, s, _) in topo.shards() {
            assert_eq!(g.accels.len(), 1);
            assert_eq!(s.accels.len(), 1);
        }
        assert_eq!(spec.topology(0, 0).total_shards(), 1, "zeros clamp to one");
        let empty = ClusterSpec { accels: vec![] };
        let topo = empty.topology(4, 4);
        assert_eq!(topo.groups.len(), 1);
        assert_eq!(topo.total_shards(), 1);
        assert!(topo.groups[0].shards[0].accels.is_empty());
    }
}
