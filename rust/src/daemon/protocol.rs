//! `goghd` wire protocol: newline-delimited JSON over a TCP or Unix
//! socket (see `docs/PROTOCOL.md` for the full message reference and a
//! transcript).
//!
//! One request per line, one response line per request. Requests carry
//! an optional protocol version `v` (absent ⇒ 1); responses always
//! carry `"ok"` plus the version, and failures use the same error
//! envelope the CLI config loader uses: an error `code` from a small
//! closed set and a human `message` with position/field context.

use crate::util::Json;
use crate::workload::{InferenceSpec, JobId, JobSpec, ModelFamily, Priority, FAMILIES};

/// Version of the request/response schema. The daemon answers requests
/// with `v` ≤ this; larger values are rejected with
/// `unsupported_version` (clients must not assume newer fields degrade
/// gracefully).
pub const PROTOCOL_VERSION: u32 = 1;

/// The closed set of wire error codes (docs/PROTOCOL.md §Errors).
/// Clients match on these, so adding one is a protocol change: extend
/// this list and the doc together — `gogh-lint` (docs/LINTS.md,
/// `protocol-error-code`) rejects any `ProtoError::new` literal under
/// `daemon/` that is not in this set.
pub const ERROR_CODES: &[&str] = &[
    "bad_request",
    "unknown_cmd",
    "unknown_job",
    "draining",
    "unsupported_version",
    "internal",
];

/// A protocol-level failure: one of the closed set of error codes plus
/// a human-readable message (the `error` object of the envelope).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ProtoError {
    /// One of [`ERROR_CODES`]: `bad_request` | `unknown_cmd` |
    /// `unknown_job` | `draining` | `unsupported_version` | `internal`
    pub code: &'static str,
    pub message: String,
}

impl ProtoError {
    pub fn new(code: &'static str, message: impl Into<String>) -> Self {
        Self {
            code,
            message: message.into(),
        }
    }

    /// Malformed or type-mismatched request content.
    pub fn bad_request(message: impl Into<String>) -> Self {
        Self::new("bad_request", message)
    }
}

/// A job as submitted over the wire (the daemon assigns the [`JobId`]).
#[derive(Debug, Clone, PartialEq)]
pub struct JobRequest {
    pub family: ModelFamily,
    pub batch_size: u32,
    pub min_throughput: f64,
    pub distributability: u32,
    /// Remaining work (training) or serving lifetime (inference), in
    /// seconds of normalized-throughput / placed time.
    pub work: f64,
    /// Priority tier; absent on the wire ⇒ `Standard` (the additive-v1
    /// rule: pre-priority clients keep their exact behaviour).
    pub priority: Priority,
    pub inference: Option<InferenceSpec>,
}

impl JobRequest {
    /// Materialize the cluster-side job spec under a daemon-assigned id.
    pub fn into_spec(self, id: JobId) -> JobSpec {
        JobSpec {
            id,
            family: self.family,
            batch_size: self.batch_size,
            replication: 1,
            min_throughput: self.min_throughput,
            distributability: self.distributability,
            work: self.work,
            priority: self.priority,
            elastic: false,
            inference: self.inference,
        }
    }

    pub fn to_json(&self) -> Json {
        let mut kv = vec![
            ("family", Json::from(self.family.name())),
            ("batch_size", self.batch_size.into()),
            ("min_throughput", self.min_throughput.into()),
            ("distributability", self.distributability.into()),
            ("work", self.work.into()),
        ];
        if self.priority != Priority::Standard {
            kv.push(("priority", self.priority.key().into()));
        }
        if let Some(inf) = self.inference {
            let inf_json = Json::obj(vec![
                ("base_rate", inf.base_rate.into()),
                ("diurnal_amplitude", inf.diurnal_amplitude.into()),
                ("diurnal_phase_s", inf.diurnal_phase_s.into()),
                ("latency_slo_s", inf.latency_slo_s.into()),
            ]);
            kv.push(("inference", inf_json));
        }
        Json::obj(kv)
    }

    /// Parse a job object; unknown fields are ignored (forward
    /// compatibility), wrong types and unknown family names are
    /// `bad_request` with the field named.
    pub fn from_json(j: &Json) -> Result<Self, ProtoError> {
        let family_name = req_str(j, "job.family")?;
        let family = FAMILIES
            .iter()
            .copied()
            .find(|f| f.name() == family_name)
            .ok_or_else(|| {
                ProtoError::bad_request(format!("job.family: unknown family {family_name:?}"))
            })?;
        let work = req_f64(j, "job.work")?;
        if !(work > 0.0 && work.is_finite()) {
            return Err(ProtoError::bad_request(format!(
                "job.work: must be a positive finite number of seconds, got {work}"
            )));
        }
        let inference = match j.get("inference") {
            None | Some(Json::Null) => None,
            Some(inf) => Some(InferenceSpec {
                base_rate: req_f64(inf, "job.inference.base_rate")?,
                diurnal_amplitude: opt_f64(inf, "diurnal_amplitude", 0.0, "job.inference")?,
                diurnal_phase_s: opt_f64(inf, "diurnal_phase_s", 0.0, "job.inference")?,
                latency_slo_s: req_f64(inf, "job.inference.latency_slo_s")?,
            }),
        };
        let priority = match j.get("priority") {
            None | Some(Json::Null) => Priority::Standard,
            Some(v) => {
                let key = v.as_str().ok_or_else(|| {
                    ProtoError::bad_request(format!("job.priority: expected a string, got {v}"))
                })?;
                Priority::from_key(key).map_err(|e| {
                    ProtoError::bad_request(format!("job.priority: {e}"))
                })?
            }
        };
        Ok(Self {
            family,
            batch_size: opt_f64(j, "batch_size", 32.0, "job")? as u32,
            min_throughput: opt_f64(j, "min_throughput", 0.0, "job")?,
            distributability: (opt_f64(j, "distributability", 1.0, "job")? as u32).max(1),
            work,
            priority,
            inference,
        })
    }
}

/// One client request (the `cmd` discriminant on the wire).
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// Submit a new job; the response carries the assigned job id.
    Submit { job: JobRequest },
    /// List active jobs (queued + running) with their placement state.
    Queue,
    /// Cancel an active job by daemon-assigned id.
    Cancel { job: u32 },
    /// Cluster + run-report summary (placements, counters, catalog).
    Status,
    /// Stop accepting submissions; the daemon snapshots and exits once
    /// the last active job finishes.
    Drain,
}

impl Request {
    /// Serialize to one wire line (no trailing newline).
    pub fn to_json(&self) -> Json {
        let mut kv = vec![("v", Json::from(PROTOCOL_VERSION))];
        match self {
            Request::Submit { job } => {
                kv.push(("cmd", "submit".into()));
                kv.push(("job", job.to_json()));
            }
            Request::Queue => kv.push(("cmd", "queue".into())),
            Request::Cancel { job } => {
                kv.push(("cmd", "cancel".into()));
                kv.push(("job", (*job).into()));
            }
            Request::Status => kv.push(("cmd", "status".into())),
            Request::Drain => kv.push(("cmd", "drain".into())),
        }
        Json::obj(kv)
    }

    /// Parse one request line. Absent `v` means version 1; versions
    /// above [`PROTOCOL_VERSION`] are rejected. Unknown fields anywhere
    /// are tolerated; unknown `cmd` values are not.
    pub fn parse(line: &str) -> Result<Self, ProtoError> {
        let j = Json::parse(line)
            .map_err(|e| ProtoError::bad_request(format!("invalid request JSON: {e}")))?;
        let v = match j.get("v") {
            None => 1,
            Some(v) => match v.as_f64() {
                Some(n) => n as u32,
                None => {
                    let msg = format!("v: expected an integer, got {v}");
                    return Err(ProtoError::bad_request(msg));
                }
            },
        };
        if v > PROTOCOL_VERSION {
            return Err(ProtoError::new(
                "unsupported_version",
                format!("protocol version {v} not supported (max {PROTOCOL_VERSION})"),
            ));
        }
        let cmd = req_str(&j, "cmd")?;
        match cmd {
            "submit" => match j.get("job") {
                None => Err(ProtoError::bad_request("missing field \"job\" for cmd submit")),
                Some(job) => {
                    let job = JobRequest::from_json(job)?;
                    Ok(Request::Submit { job })
                }
            },
            "queue" => Ok(Request::Queue),
            "cancel" => {
                let job = req_f64(&j, "job")? as u32;
                Ok(Request::Cancel { job })
            }
            "status" => Ok(Request::Status),
            "drain" => Ok(Request::Drain),
            other => Err(ProtoError::new(
                "unknown_cmd",
                format!("unknown cmd {other:?} (want submit|queue|cancel|status|drain)"),
            )),
        }
    }
}

/// Success envelope: `{"ok":true,"v":1,`…body…`}`.
pub fn ok_envelope(body: Vec<(&str, Json)>) -> Json {
    let mut kv = vec![("ok", Json::from(true)), ("v", Json::from(PROTOCOL_VERSION))];
    kv.extend(body);
    Json::obj(kv)
}

/// Error envelope: `{"ok":false,"v":1,"error":{"code":…,"message":…}}`.
pub fn error_envelope(e: &ProtoError) -> Json {
    let err = Json::obj(vec![("code", e.code.into()), ("message", e.message.as_str().into())]);
    Json::obj(vec![("ok", false.into()), ("v", PROTOCOL_VERSION.into()), ("error", err)])
}

fn req_str<'j>(j: &'j Json, path: &str) -> Result<&'j str, ProtoError> {
    match j.get(field_name(path)) {
        None => Err(ProtoError::bad_request(format!("missing field {path:?}"))),
        Some(v) => v.as_str().ok_or_else(|| {
            ProtoError::bad_request(format!("{path}: expected a string, got {v}"))
        }),
    }
}

fn req_f64(j: &Json, path: &str) -> Result<f64, ProtoError> {
    match j.get(field_name(path)) {
        None => Err(ProtoError::bad_request(format!("missing field {path:?}"))),
        Some(v) => v.as_f64().ok_or_else(|| {
            ProtoError::bad_request(format!("{path}: expected a number, got {v}"))
        }),
    }
}

fn opt_f64(j: &Json, key: &str, default: f64, parent: &str) -> Result<f64, ProtoError> {
    match j.get(key) {
        None | Some(Json::Null) => Ok(default),
        Some(v) => v.as_f64().ok_or_else(|| {
            ProtoError::bad_request(format!("{parent}.{key}: expected a number, got {v}"))
        }),
    }
}

/// Last segment of a dotted error path (the actual JSON key).
fn field_name(path: &str) -> &str {
    path.rsplit('.').next().unwrap_or(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn train_job() -> JobRequest {
        JobRequest {
            family: ModelFamily::ResNet50,
            batch_size: 64,
            min_throughput: 0.25,
            distributability: 2,
            work: 1800.0,
            priority: Default::default(),
            inference: None,
        }
    }

    fn serve_job() -> JobRequest {
        JobRequest {
            priority: Default::default(),
            inference: Some(InferenceSpec {
                base_rate: 12.0,
                diurnal_amplitude: 0.4,
                diurnal_phase_s: 3600.0,
                latency_slo_s: 0.25,
            }),
            ..train_job()
        }
    }

    #[test]
    fn every_request_round_trips() {
        let requests = vec![
            Request::Submit { job: train_job() },
            Request::Submit { job: serve_job() },
            Request::Queue,
            Request::Cancel { job: 7 },
            Request::Status,
            Request::Drain,
        ];
        for r in requests {
            let line = r.to_json().to_string();
            let back = Request::parse(&line).unwrap_or_else(|e| panic!("{line}: {e:?}"));
            assert_eq!(back, r, "{line}");
        }
    }

    #[test]
    fn unknown_fields_are_tolerated() {
        let line = r#"{"v":1,"cmd":"cancel","job":3,"reason":"tired","extra":{"a":1}}"#;
        assert_eq!(Request::parse(line).unwrap(), Request::Cancel { job: 3 });
        let line = r#"{"cmd":"submit","job":{"family":"lm","work":60,"future_knob":true}}"#;
        match Request::parse(line).unwrap() {
            Request::Submit { job } => {
                assert_eq!(job.family.name(), "lm");
                assert_eq!(job.batch_size, 32); // default
                assert_eq!(job.distributability, 1); // default
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn priority_is_additive_v1() {
        // absent ⇒ Standard, and Standard is omitted on the wire, so
        // pre-priority clients and transcripts are untouched
        let line = r#"{"cmd":"submit","job":{"family":"lm","work":60}}"#;
        match Request::parse(line).unwrap() {
            Request::Submit { job } => assert_eq!(job.priority, Priority::Standard),
            other => panic!("{other:?}"),
        }
        assert!(!train_job().to_json().to_string().contains("priority"));
        // explicit tiers round-trip
        let mut j = train_job();
        j.priority = Priority::Critical;
        let line = Request::Submit { job: j.clone() }.to_json().to_string();
        assert!(line.contains(r#""priority":"critical""#), "{line}");
        assert_eq!(Request::parse(&line).unwrap(), Request::Submit { job: j });
        // junk tiers are bad_request naming the field
        let line = r#"{"cmd":"submit","job":{"family":"lm","work":60,"priority":"vip"}}"#;
        let e = Request::parse(line).unwrap_err();
        assert_eq!(e.code, "bad_request");
        assert!(e.message.contains("job.priority"), "{}", e.message);
        // wire priority reaches the cluster spec; daemon jobs are rigid
        let mut j = train_job();
        j.priority = Priority::Best;
        let spec = j.into_spec(JobId(3));
        assert_eq!(spec.priority, Priority::Best);
        assert!(!spec.elastic);
    }

    #[test]
    fn version_rules() {
        // absent v ⇒ version 1
        assert_eq!(Request::parse(r#"{"cmd":"queue"}"#).unwrap(), Request::Queue);
        // same version accepted
        assert_eq!(Request::parse(r#"{"v":1,"cmd":"queue"}"#).unwrap(), Request::Queue);
        // newer versions rejected with the dedicated code
        let e = Request::parse(r#"{"v":2,"cmd":"queue"}"#).unwrap_err();
        assert_eq!(e.code, "unsupported_version");
    }

    #[test]
    fn bad_requests_name_the_problem() {
        let e = Request::parse("{nope").unwrap_err();
        assert_eq!(e.code, "bad_request");
        assert!(e.message.contains("line 1"), "{}", e.message);

        let e = Request::parse(r#"{"cmd":"fly"}"#).unwrap_err();
        assert_eq!(e.code, "unknown_cmd");

        let e =
            Request::parse(r#"{"cmd":"submit","job":{"family":"gpt9","work":60}}"#).unwrap_err();
        assert_eq!(e.code, "bad_request");
        assert!(e.message.contains("job.family"), "{}", e.message);

        let e = Request::parse(r#"{"cmd":"submit","job":{"family":"lm"}}"#).unwrap_err();
        assert!(e.message.contains("job.work"), "{}", e.message);

        let e = Request::parse(r#"{"cmd":"submit","job":{"family":"lm","work":-5}}"#).unwrap_err();
        assert!(e.message.contains("positive"), "{}", e.message);

        let e = Request::parse(r#"{"cmd":"cancel"}"#).unwrap_err();
        assert!(e.message.contains("job"), "{}", e.message);
    }

    #[test]
    fn envelopes_have_the_documented_shape() {
        let ok = ok_envelope(vec![("id", 4u32.into())]);
        assert_eq!(ok.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(ok.get("v").and_then(Json::as_u64), Some(1));
        assert_eq!(ok.get("id").and_then(Json::as_u64), Some(4));

        let err = error_envelope(&ProtoError::new("unknown_job", "no job j9"));
        assert_eq!(err.get("ok"), Some(&Json::Bool(false)));
        let e = err.get("error").unwrap();
        assert_eq!(e.req_str("code").unwrap(), "unknown_job");
        assert_eq!(e.req_str("message").unwrap(), "no job j9");
        // and it parses back as one wire line
        let line = err.to_string();
        assert!(!line.contains('\n'));
        assert_eq!(Json::parse(&line).unwrap(), err);
    }

    #[test]
    fn job_request_spec_materialization() {
        let spec = serve_job().into_spec(JobId(41));
        assert_eq!(spec.id, JobId(41));
        assert_eq!(spec.replication, 1);
        assert!(spec.is_inference());
        assert_eq!(spec.work, 1800.0);
    }
}
