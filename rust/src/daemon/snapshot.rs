#![doc = include_str!("../../../docs/SNAPSHOT.md")]

use std::path::Path;

use crate::catalog::Catalog;
use crate::cluster::AccelId;
use crate::coordinator::GoghScheduler;
use crate::engine::{CoreEvent, GoghCore};
use crate::power::PowerState;
use crate::util::Json;
use crate::workload::{
    AccelType, Combo, InferenceSpec, JobId, JobSpec, ModelFamily, ACCEL_TYPES, FAMILIES,
};
use crate::Result;
use anyhow::Context as _;

/// Version stamp written into every state file. Loads accept
/// `1..=SNAPSHOT_VERSION`: version 1 predates power management, so its
/// files simply restore with every accelerator at the nominal state;
/// versions 1–2 predate priorities, so their jobs restore as
/// `Standard`/rigid with nothing suspended.
pub const SNAPSHOT_VERSION: u32 = 3;

/// In-memory form of one state file (format: module docs above).
#[derive(Debug, Clone, PartialEq)]
pub struct Snapshot {
    /// Simulated clock at capture.
    pub now_s: f64,
    /// Daemon job-id allocator cursor.
    pub next_job_id: u32,
    /// Whether a drain was in progress at capture.
    pub draining: bool,
    pub jobs_total: usize,
    pub jobs_completed: usize,
    pub jobs_cancelled: usize,
    /// Active jobs as `(arrived_at, spec)`, sorted by job id.
    pub jobs: Vec<(f64, JobSpec)>,
    /// Busy accelerators and their co-location combos, sorted.
    pub placements: Vec<(AccelId, Combo)>,
    /// Out-of-service accelerators, sorted.
    pub down: Vec<AccelId>,
    /// Non-nominal DVFS states, sorted (an absent accelerator is
    /// nominal). New in version 2; empty for version-1 files.
    pub power_states: Vec<(AccelId, PowerState)>,
    /// Jobs parked by `PlacementOp::Suspend` at capture, ascending.
    /// New in version 3; empty for older files.
    pub suspended: Vec<JobId>,
    /// Undelivered queue events in dispatch order (no monitor tick).
    pub queue: Vec<(f64, CoreEvent)>,
    /// Learned state, embedded in the catalog store's own format.
    pub catalog: Json,
}

impl Snapshot {
    /// Capture the daemon's full resumable state.
    pub fn capture(
        core: &GoghCore,
        scheduler: &GoghScheduler,
        next_job_id: u32,
        draining: bool,
    ) -> Snapshot {
        let report = core.report(scheduler);
        let cluster = core.cluster();
        let now = cluster.now();
        let mut jobs: Vec<(f64, JobSpec)> = cluster
            .jobs()
            .map(|j| (core.arrival_time(j.id).unwrap_or(now), j.clone()))
            .collect();
        jobs.sort_by_key(|(_, j)| j.id);
        let mut placements: Vec<(AccelId, Combo)> =
            cluster.placement.iter().map(|(a, c)| (*a, *c)).collect();
        placements.sort();
        Snapshot {
            now_s: now,
            next_job_id,
            draining,
            jobs_total: report.jobs_total,
            jobs_completed: report.jobs_completed,
            jobs_cancelled: report.jobs_cancelled,
            jobs,
            placements,
            down: cluster.down_accels(),
            power_states: cluster.power_state_entries(),
            suspended: cluster.suspended_job_ids(),
            queue: core.pending_events(),
            catalog: scheduler.catalog.to_json(),
        }
    }

    /// Rebuild daemon state from this snapshot: accelerator health and
    /// DVFS states first, then jobs (with their original arrival
    /// times), then the placement map, then the clock, counters,
    /// pending events, and finally the learned catalog. The caller
    /// starts the monitor tick afterwards.
    pub fn restore_into(&self, core: &mut GoghCore, scheduler: &mut GoghScheduler) -> Result<()> {
        for a in &self.down {
            core.cluster_mut().set_accel_down(*a);
        }
        for (a, s) in &self.power_states {
            core.cluster_mut().set_power_state(*a, *s);
        }
        for (arrived_at, spec) in &self.jobs {
            core.restore_job(spec.clone(), *arrived_at);
        }
        for (accel, combo) in &self.placements {
            for j in combo.jobs() {
                anyhow::ensure!(
                    core.cluster().job(j).is_some(),
                    "snapshot places unknown job {j} on {accel}"
                );
            }
            core.cluster_mut().placement.assign(*accel, *combo);
        }
        for j in &self.suspended {
            anyhow::ensure!(
                core.cluster().job(*j).is_some(),
                "snapshot suspends unknown job {j}"
            );
            anyhow::ensure!(
                !core.cluster().placement.is_placed(*j),
                "snapshot suspends job {j} that is also placed"
            );
            core.cluster_mut().set_suspended(*j);
        }
        core.cluster_mut().advance_to(self.now_s);
        core.restore_counters(self.jobs_total, self.jobs_completed, self.jobs_cancelled);
        for (at, ev) in &self.queue {
            core.restore_event(*at, ev.clone());
        }
        let catalog = Catalog::from_json(&self.catalog).context("snapshot catalog section")?;
        scheduler.restore_catalog(catalog);
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let counters = Json::obj(vec![
            ("jobs_total", self.jobs_total.into()),
            ("jobs_completed", self.jobs_completed.into()),
            ("jobs_cancelled", self.jobs_cancelled.into()),
        ]);
        let jobs: Vec<Json> = self.jobs.iter().map(|(t, s)| job_entry_json(*t, s)).collect();
        let placements: Vec<Json> =
            self.placements.iter().map(|(a, c)| placement_entry_json(*a, c)).collect();
        let down: Vec<Json> = self.down.iter().map(|a| accel_to_json(*a)).collect();
        let power: Vec<Json> =
            self.power_states.iter().map(|(a, s)| power_entry_json(*a, *s)).collect();
        let suspended: Vec<Json> = self.suspended.iter().map(|j| Json::from(j.0)).collect();
        let queue: Vec<Json> = self.queue.iter().map(|(t, e)| event_to_json(*t, e)).collect();
        Json::obj(vec![
            ("version", SNAPSHOT_VERSION.into()),
            ("now_s", self.now_s.into()),
            ("next_job_id", self.next_job_id.into()),
            ("draining", self.draining.into()),
            ("counters", counters),
            ("jobs", Json::Array(jobs)),
            ("placements", Json::Array(placements)),
            ("down", Json::Array(down)),
            ("power_states", Json::Array(power)),
            ("suspended", Json::Array(suspended)),
            ("queue", Json::Array(queue)),
            ("catalog", self.catalog.clone()),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Snapshot> {
        let version = v.req_f64("version").context("snapshot")? as u32;
        anyhow::ensure!(
            (1..=SNAPSHOT_VERSION).contains(&version),
            "snapshot version {version} unsupported (this build reads 1..={SNAPSHOT_VERSION})"
        );
        let counters = v.get("counters").context("snapshot: missing counters")?;
        let mut jobs = Vec::new();
        for (i, e) in req_array(v, "jobs")?.iter().enumerate() {
            let spec = e.get("spec").with_context(|| format!("jobs[{i}]: missing spec"))?;
            jobs.push((
                e.req_f64("arrived_at").with_context(|| format!("jobs[{i}]"))?,
                job_spec_from_json(spec).with_context(|| format!("jobs[{i}].spec"))?,
            ));
        }
        let mut placements = Vec::new();
        for (i, e) in req_array(v, "placements")?.iter().enumerate() {
            let ctx = || format!("placements[{i}]");
            let accel = accel_from_json(e.get("accel").with_context(ctx)?).with_context(ctx)?;
            let mut ids = Vec::new();
            for j in req_array(e, "jobs").with_context(ctx)? {
                let n = j.as_u64().with_context(|| format!("{}: bad job id {j}", ctx()))?;
                ids.push(JobId(n as u32));
            }
            let combo = match ids[..] {
                [a] => Combo::Solo(a),
                [a, b] => Combo::pair(a, b),
                _ => anyhow::bail!("{}: combo must hold 1 or 2 jobs, got {}", ctx(), ids.len()),
            };
            placements.push((accel, combo));
        }
        let mut down = Vec::new();
        for (i, e) in req_array(v, "down")?.iter().enumerate() {
            down.push(accel_from_json(e).with_context(|| format!("down[{i}]"))?);
        }
        // required from version 2 on; version-1 files predate it
        let mut power_states = Vec::new();
        if version >= 2 {
            for (i, e) in req_array(v, "power_states")?.iter().enumerate() {
                let ctx = || format!("power_states[{i}]");
                let accel = accel_from_json(e.get("accel").with_context(ctx)?).with_context(ctx)?;
                let state = PowerState::from_key(e.req_str("state").with_context(ctx)?)
                    .with_context(ctx)?;
                power_states.push((accel, state));
            }
        }
        // required from version 3 on; older files predate suspension
        let mut suspended = Vec::new();
        if version >= 3 {
            for (i, e) in req_array(v, "suspended")?.iter().enumerate() {
                let n = e
                    .as_u64()
                    .ok_or_else(|| anyhow::anyhow!("suspended[{i}]: bad job id {e}"))?;
                suspended.push(JobId(n as u32));
            }
        }
        let mut queue = Vec::new();
        for (i, e) in req_array(v, "queue")?.iter().enumerate() {
            queue.push(event_from_json(e).with_context(|| format!("queue[{i}]"))?);
        }
        Ok(Snapshot {
            now_s: v.req_f64("now_s").context("snapshot")?,
            next_job_id: v.req_f64("next_job_id").context("snapshot")? as u32,
            draining: v.get("draining").and_then(Json::as_bool).unwrap_or(false),
            jobs_total: counters.req_usize("jobs_total").context("counters")?,
            jobs_completed: counters.req_usize("jobs_completed").context("counters")?,
            jobs_cancelled: counters.req_usize("jobs_cancelled").context("counters")?,
            jobs,
            placements,
            down,
            power_states,
            suspended,
            queue,
            catalog: v.get("catalog").context("snapshot: missing catalog")?.clone(),
        })
    }

    /// Atomic write: serialize to `<path>.tmp`, then rename over `path`.
    pub fn save(&self, path: &Path) -> Result<()> {
        let tmp = path.with_extension("tmp");
        std::fs::write(&tmp, self.to_json().to_string())
            .with_context(|| format!("writing snapshot to {}", tmp.display()))?;
        std::fs::rename(&tmp, path)
            .with_context(|| format!("renaming snapshot into {}", path.display()))?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Snapshot> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading snapshot {}", path.display()))?;
        let v = Json::parse(&text).with_context(|| format!("snapshot {}", path.display()))?;
        Self::from_json(&v)
    }
}

fn req_array<'j>(j: &'j Json, key: &str) -> Result<&'j [Json]> {
    j.get(key)
        .and_then(Json::as_array)
        .ok_or_else(|| anyhow::anyhow!("snapshot: missing array {key:?}"))
}

fn job_entry_json(arrived_at: f64, spec: &JobSpec) -> Json {
    Json::obj(vec![("arrived_at", arrived_at.into()), ("spec", job_spec_to_json(spec))])
}

fn placement_entry_json(a: AccelId, c: &Combo) -> Json {
    let ids: Vec<Json> = c.jobs().iter().map(|j| Json::from(j.0)).collect();
    Json::obj(vec![("accel", accel_to_json(a)), ("jobs", Json::Array(ids))])
}

fn power_entry_json(a: AccelId, s: PowerState) -> Json {
    Json::obj(vec![("accel", accel_to_json(a)), ("state", s.key().into())])
}

fn accel_to_json(a: AccelId) -> Json {
    Json::obj(vec![("server", a.server.into()), ("type", a.accel.name().into())])
}

fn accel_from_json(v: &Json) -> Result<AccelId> {
    let name = v.req_str("type")?;
    let accel = ACCEL_TYPES
        .iter()
        .copied()
        .find(|a: &AccelType| a.name() == name)
        .ok_or_else(|| anyhow::anyhow!("unknown accelerator type {name:?}"))?;
    Ok(AccelId {
        server: v.req_f64("server")? as u32,
        accel,
    })
}

fn job_spec_to_json(j: &JobSpec) -> Json {
    let inference = match j.inference {
        None => Json::Null,
        Some(inf) => Json::obj(vec![
            ("base_rate", inf.base_rate.into()),
            ("diurnal_amplitude", inf.diurnal_amplitude.into()),
            ("diurnal_phase_s", inf.diurnal_phase_s.into()),
            ("latency_slo_s", inf.latency_slo_s.into()),
        ]),
    };
    let mut kv = vec![
        ("id", j.id.0.into()),
        ("family", j.family.name().into()),
        ("batch_size", j.batch_size.into()),
        ("replication", j.replication.into()),
        ("min_throughput", j.min_throughput.into()),
        ("distributability", j.distributability.into()),
        ("work", j.work.into()),
    ];
    // additive fields (version 3): defaults are omitted, so a
    // priority-free job serializes exactly as version 2 wrote it
    if j.priority != crate::workload::Priority::Standard {
        kv.push(("priority", j.priority.key().into()));
    }
    if j.elastic {
        kv.push(("elastic", true.into()));
    }
    kv.push(("inference", inference));
    Json::obj(kv)
}

fn job_spec_from_json(v: &Json) -> Result<JobSpec> {
    let family_name = v.req_str("family")?;
    let family = FAMILIES
        .iter()
        .copied()
        .find(|f: &ModelFamily| f.name() == family_name)
        .ok_or_else(|| anyhow::anyhow!("unknown model family {family_name:?}"))?;
    let inference = match v.get("inference") {
        None | Some(Json::Null) => None,
        Some(inf) => Some(InferenceSpec {
            base_rate: inf.req_f64("base_rate")?,
            diurnal_amplitude: inf.req_f64("diurnal_amplitude")?,
            diurnal_phase_s: inf.req_f64("diurnal_phase_s")?,
            latency_slo_s: inf.req_f64("latency_slo_s")?,
        }),
    };
    let priority = match v.get("priority") {
        None | Some(Json::Null) => crate::workload::Priority::Standard,
        Some(p) => {
            let key = p
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("priority: expected a string, got {p}"))?;
            crate::workload::Priority::from_key(key)?
        }
    };
    Ok(JobSpec {
        id: JobId(v.req_f64("id")? as u32),
        family,
        batch_size: v.req_f64("batch_size")? as u32,
        replication: v.req_f64("replication")? as u32,
        min_throughput: v.req_f64("min_throughput")?,
        distributability: v.req_f64("distributability")? as u32,
        work: v.req_f64("work")?,
        priority,
        elastic: v.get("elastic").and_then(Json::as_bool).unwrap_or(false),
        inference,
    })
}

fn event_to_json(at: f64, ev: &CoreEvent) -> Json {
    let mut kv = vec![("at", Json::from(at))];
    match ev {
        CoreEvent::Arrival(spec) => {
            kv.push(("kind", "arrival".into()));
            kv.push(("spec", job_spec_to_json(spec)));
        }
        CoreEvent::Cancel(j) => {
            kv.push(("kind", "cancel".into()));
            kv.push(("job", j.0.into()));
        }
        CoreEvent::AccelDown(a) => {
            kv.push(("kind", "accel_down".into()));
            kv.push(("accel", accel_to_json(*a)));
        }
        CoreEvent::AccelUp(a) => {
            kv.push(("kind", "accel_up".into()));
            kv.push(("accel", accel_to_json(*a)));
        }
        // excluded by `pending_events`; unreachable on the capture path
        CoreEvent::MonitorTick => kv.push(("kind", "monitor_tick".into())),
    }
    Json::obj(kv)
}

fn event_from_json(v: &Json) -> Result<(f64, CoreEvent)> {
    let at = v.req_f64("at")?;
    let spec = || v.get("spec").context("missing spec");
    let accel = || v.get("accel").context("missing accel");
    let ev = match v.req_str("kind")? {
        "arrival" => CoreEvent::Arrival(job_spec_from_json(spec()?)?),
        "cancel" => CoreEvent::Cancel(JobId(v.req_f64("job")? as u32)),
        "accel_down" => CoreEvent::AccelDown(accel_from_json(accel()?)?),
        "accel_up" => CoreEvent::AccelUp(accel_from_json(accel()?)?),
        other => anyhow::bail!("unknown event kind {other:?}"),
    };
    Ok((at, ev))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::config::ExperimentConfig;
    use crate::coordinator::build_scheduler;
    use crate::workload::ThroughputOracle;

    fn training_job(id: u32, work: f64) -> JobSpec {
        JobSpec {
            id: JobId(id),
            family: ModelFamily::ResNet50,
            batch_size: 32,
            replication: 1,
            min_throughput: 0.1,
            distributability: 1,
            work,
            priority: Default::default(),
            elastic: false,
            inference: None,
        }
    }

    fn serving_job(id: u32) -> JobSpec {
        JobSpec {
            family: ModelFamily::LanguageModel,
            priority: Default::default(),
            elastic: false,
            inference: Some(InferenceSpec {
                base_rate: 9.0,
                diurnal_amplitude: 0.3,
                diurnal_phase_s: 600.0,
                latency_slo_s: 0.4,
            }),
            ..training_job(id, 3600.0)
        }
    }

    /// Drive a tiny daemon-shaped run, capture, serialize, reload, and
    /// require the reloaded snapshot to serialize bit-identically —
    /// catalog included.
    #[test]
    fn save_load_round_trip_is_bit_identical() {
        let mut cfg = ExperimentConfig::default();
        cfg.gogh.backend = crate::config::BackendKind::Native;
        let oracle = ThroughputOracle::new(7);
        let (mut sched, _backend) = build_scheduler(&cfg, &oracle).unwrap();
        let mut core = GoghCore::new(
            ClusterSpec::balanced(1),
            oracle.clone(),
            0.01,
            cfg.monitor_interval_s,
            7,
        )
        .unwrap();
        core.submit(0.0, training_job(0, 500.0));
        core.submit(1.0, serving_job(1));
        core.start_monitor();
        core.advance_to(30.0, &mut sched).unwrap();
        // leave one event pending so the queue section is exercised
        core.cancel(99.0, JobId(0));

        let snap = Snapshot::capture(&core, &sched, 2, false);
        assert_eq!(snap.jobs.len(), 2, "both jobs should still be active");
        assert!(!snap.placements.is_empty(), "jobs should be placed by t=30");
        assert_eq!(snap.queue.len(), 1);

        let text = snap.to_json().to_string();
        let back = Snapshot::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, snap);
        assert_eq!(back.to_json().to_string(), text, "serialization is stable");
        assert_eq!(back.catalog, snap.catalog, "catalog survives bit-identically");
    }

    #[test]
    fn restore_rebuilds_cluster_and_catalog() {
        let mut cfg = ExperimentConfig::default();
        cfg.gogh.backend = crate::config::BackendKind::Native;
        let oracle = ThroughputOracle::new(7);
        let (mut sched, _) = build_scheduler(&cfg, &oracle).unwrap();
        let mut core = GoghCore::new(
            ClusterSpec::balanced(1),
            oracle.clone(),
            0.01,
            cfg.monitor_interval_s,
            7,
        )
        .unwrap();
        core.submit(0.0, training_job(0, 500.0));
        core.submit(1.0, training_job(1, 800.0));
        core.start_monitor();
        core.advance_to(45.0, &mut sched).unwrap();
        let snap = Snapshot::capture(&core, &sched, 2, false);

        // a "restarted process": fresh core + scheduler, then restore
        let (mut sched2, _) = build_scheduler(&cfg, &oracle).unwrap();
        let mut core2 = GoghCore::new(
            ClusterSpec::balanced(1),
            oracle.clone(),
            0.01,
            cfg.monitor_interval_s,
            7,
        )
        .unwrap();
        snap.restore_into(&mut core2, &mut sched2).unwrap();

        assert_eq!(core2.cluster().now(), snap.now_s);
        assert_eq!(core2.cluster().n_jobs(), snap.jobs.len());
        let restored: Vec<(AccelId, Combo)> = {
            let mut v: Vec<_> = core2.cluster().placement.iter().map(|(a, c)| (*a, *c)).collect();
            v.sort();
            v
        };
        assert_eq!(restored, snap.placements);
        assert_eq!(sched2.catalog.to_json(), snap.catalog, "learned state restored");
        // counters carried over
        let report = core2.report(&sched2);
        assert_eq!(report.jobs_total, snap.jobs_total);

        // the restored pair keeps scheduling: run to completion
        core2.start_monitor();
        core2.run(&mut sched2, 24.0 * 3600.0).unwrap();
        let done = core2.report(&sched2);
        assert_eq!(done.jobs_completed, 2);
    }

    #[test]
    fn version_mismatch_is_rejected() {
        let err = Snapshot::from_json(&Json::parse(r#"{"version": 9}"#).unwrap()).unwrap_err();
        assert!(err.to_string().contains("version 9"), "{err}");
    }

    /// Power states (new in snapshot version 2) survive the full
    /// capture → serialize → parse → restore cycle.
    #[test]
    fn power_states_round_trip_through_snapshot() {
        let mut cfg = ExperimentConfig::default();
        cfg.gogh.backend = crate::config::BackendKind::Native;
        let oracle = ThroughputOracle::new(7);
        let (mut sched, _) = build_scheduler(&cfg, &oracle).unwrap();
        let mut core = GoghCore::new(
            ClusterSpec::balanced(1),
            oracle.clone(),
            0.01,
            cfg.monitor_interval_s,
            7,
        )
        .unwrap();
        core.submit(0.0, training_job(0, 500.0));
        core.start_monitor();
        core.advance_to(10.0, &mut sched).unwrap();
        let accels = core.cluster().available_accels();
        core.cluster_mut().set_power_state(accels[0], PowerState::Low);
        core.cluster_mut().set_power_state(accels[1], PowerState::Turbo);

        let snap = Snapshot::capture(&core, &sched, 1, false);
        assert_eq!(snap.power_states.len(), 2, "nominal accels stay out of the sparse map");
        let text = snap.to_json().to_string();
        let back = Snapshot::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, snap);

        let (mut sched2, _) = build_scheduler(&cfg, &oracle).unwrap();
        let mut core2 = GoghCore::new(
            ClusterSpec::balanced(1),
            oracle.clone(),
            0.01,
            cfg.monitor_interval_s,
            7,
        )
        .unwrap();
        back.restore_into(&mut core2, &mut sched2).unwrap();
        assert_eq!(core2.cluster().power_state(accels[0]), PowerState::Low);
        assert_eq!(core2.cluster().power_state(accels[1]), PowerState::Turbo);
        assert_eq!(core2.cluster().power_state_entries(), snap.power_states);
    }

    /// Version skew: a version-1 state file (written before power
    /// management existed) restores cleanly with every accelerator at
    /// the nominal state.
    #[test]
    fn v1_snapshot_without_power_states_restores_nominal() {
        let mut cfg = ExperimentConfig::default();
        cfg.gogh.backend = crate::config::BackendKind::Native;
        let oracle = ThroughputOracle::new(7);
        let (mut sched, _) = build_scheduler(&cfg, &oracle).unwrap();
        let mut core = GoghCore::new(
            ClusterSpec::balanced(1),
            oracle.clone(),
            0.01,
            cfg.monitor_interval_s,
            7,
        )
        .unwrap();
        core.submit(0.0, training_job(0, 500.0));
        core.start_monitor();
        core.advance_to(10.0, &mut sched).unwrap();
        let text = Snapshot::capture(&core, &sched, 1, false).to_json().to_string();
        // rewrite to the exact byte shape a version-1 build produced:
        // old version stamp, no power_states or suspended sections
        let v1 = text
            .replace("\"version\":3", "\"version\":1")
            .replace(",\"power_states\":[]", "")
            .replace(",\"suspended\":[]", "");
        assert!(v1.contains("\"version\":1") && !v1.contains("power_states"), "{v1}");
        assert!(!v1.contains("suspended"), "{v1}");
        let snap = Snapshot::from_json(&Json::parse(&v1).unwrap()).unwrap();
        assert!(snap.power_states.is_empty());
        assert!(snap.suspended.is_empty());

        let (mut sched2, _) = build_scheduler(&cfg, &oracle).unwrap();
        let mut core2 = GoghCore::new(
            ClusterSpec::balanced(1),
            oracle.clone(),
            0.01,
            cfg.monitor_interval_s,
            7,
        )
        .unwrap();
        snap.restore_into(&mut core2, &mut sched2).unwrap();
        assert!(core2.cluster().power_state_entries().is_empty());
        for a in core2.cluster().available_accels() {
            assert_eq!(core2.cluster().power_state(a), PowerState::Nominal);
        }
    }

    /// Priority tiers, elastic flags and the suspended set (new in
    /// snapshot version 3) survive capture → serialize → restore, and
    /// a restored parked job is suspended, not merely unplaced.
    #[test]
    fn priority_and_suspension_round_trip_through_snapshot() {
        use crate::cluster::{PlacementDelta, PlacementOp};
        use crate::workload::Priority;
        let mut cfg = ExperimentConfig::default();
        cfg.gogh.backend = crate::config::BackendKind::Native;
        let oracle = ThroughputOracle::new(7);
        let (mut sched, _) = build_scheduler(&cfg, &oracle).unwrap();
        let mut core = GoghCore::new(
            ClusterSpec::balanced(1),
            oracle.clone(),
            0.01,
            cfg.monitor_interval_s,
            7,
        )
        .unwrap();
        let mut critical = training_job(0, 500.0);
        critical.priority = Priority::Critical;
        let mut victim = training_job(1, 800.0);
        victim.priority = Priority::Best;
        victim.elastic = true;
        victim.distributability = 3;
        core.submit(0.0, critical);
        core.submit(1.0, victim);
        core.start_monitor();
        core.advance_to(30.0, &mut sched).unwrap();
        // park the best-effort job the way the preemption path would
        let d = PlacementDelta {
            ops: vec![PlacementOp::Suspend { job: JobId(1) }],
        };
        core.cluster_mut().apply_delta(&d).unwrap();

        let snap = Snapshot::capture(&core, &sched, 2, false);
        assert_eq!(snap.suspended, vec![JobId(1)]);
        let text = snap.to_json().to_string();
        assert!(text.contains(r#""priority":"critical""#), "{text}");
        assert!(text.contains(r#""elastic":true"#), "{text}");
        let back = Snapshot::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, snap);

        let (mut sched2, _) = build_scheduler(&cfg, &oracle).unwrap();
        let mut core2 = GoghCore::new(
            ClusterSpec::balanced(1),
            oracle.clone(),
            0.01,
            cfg.monitor_interval_s,
            7,
        )
        .unwrap();
        back.restore_into(&mut core2, &mut sched2).unwrap();
        let c = core2.cluster();
        assert_eq!(c.job(JobId(0)).unwrap().priority, Priority::Critical);
        let v = c.job(JobId(1)).unwrap();
        assert_eq!(v.priority, Priority::Best);
        assert!(v.elastic);
        assert!(c.is_suspended(JobId(1)), "restored job must still be parked");
        assert!(!c.placement.is_placed(JobId(1)));
        // a corrupted file that suspends a placed job is refused
        let bad = text.replace("\"suspended\":[1]", "\"suspended\":[0]");
        let snap = Snapshot::from_json(&Json::parse(&bad).unwrap()).unwrap();
        let (mut sched3, _) = build_scheduler(&cfg, &oracle).unwrap();
        let mut core3 = GoghCore::new(
            ClusterSpec::balanced(1),
            oracle.clone(),
            0.01,
            cfg.monitor_interval_s,
            7,
        )
        .unwrap();
        let err = snap.restore_into(&mut core3, &mut sched3).unwrap_err();
        assert!(err.to_string().contains("also placed"), "{err}");
    }

    #[test]
    fn save_is_atomic_and_loadable() {
        let oracle = ThroughputOracle::new(7);
        let cfg = {
            let mut c = ExperimentConfig::default();
            c.gogh.backend = crate::config::BackendKind::Native;
            c
        };
        let (sched, _) = build_scheduler(&cfg, &oracle).unwrap();
        let core = GoghCore::new(
            ClusterSpec::balanced(1),
            oracle.clone(),
            0.01,
            cfg.monitor_interval_s,
            7,
        )
        .unwrap();
        let snap = Snapshot::capture(&core, &sched, 0, true);
        let dir = std::env::temp_dir().join(format!("gogh_snap_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("state.json");
        snap.save(&path).unwrap();
        assert!(!path.with_extension("tmp").exists(), "tmp renamed away");
        let back = Snapshot::load(&path).unwrap();
        assert_eq!(back, snap);
        assert!(back.draining);
        std::fs::remove_dir_all(&dir).ok();
    }
}
