//! `goghd` — the long-lived service frontend over the shared
//! [`engine::GoghCore`](crate::engine::GoghCore).
//!
//! Three layers, one per module:
//!
//! - [`protocol`] — the newline-delimited JSON wire format clients
//!   speak (`gogh submit|queue|cancel|status|drain`, or raw `nc`).
//! - [`server`] — the single-threaded accept/advance loop mapping wall
//!   clock onto the core's simulated clock.
//! - [`snapshot`] — versioned crash-safe persistence of jobs,
//!   placements, and the learned catalog across daemon restarts.
//!
//! The simulator and the daemon are peers: both drive the same core
//! and policy code, differing only in where events come from (trace
//! file vs socket) and what the clock is (virtual vs wall).

pub mod protocol;
pub mod server;
pub mod snapshot;

pub use protocol::{JobRequest, ProtoError, Request, PROTOCOL_VERSION};
pub use server::{serve, DaemonOptions, Endpoint};
pub use snapshot::{Snapshot, SNAPSHOT_VERSION};
