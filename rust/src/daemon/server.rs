//! The `goghd` daemon: a long-lived, wall-clock-driven frontend over
//! [`GoghCore`].
//!
//! Where the simulator replays a trace against a virtual clock, the
//! daemon maps real elapsed time (`std::time::Instant`, optionally
//! sped up by `--time-scale`) onto the core's simulated clock and
//! feeds it submissions arriving over a TCP or Unix socket, one JSON
//! request per line (see `docs/PROTOCOL.md`). State is periodically
//! checkpointed to a versioned snapshot file (see `docs/SNAPSHOT.md`)
//! and restored on restart, so a bounced daemon keeps its learned
//! catalog and placements.
//!
//! The server is deliberately single-threaded: one nonblocking accept
//! loop owns the core, the scheduler, and every connection, so request
//! handling needs no locking and stays deterministic under test.

use std::io::{Read as _, Write as _};
use std::net::{TcpListener, TcpStream};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::PathBuf;
use std::time::{Duration, Instant};

use crate::cluster::{Cluster, ClusterSpec};
use crate::config::ExperimentConfig;
use crate::coordinator::{build_scheduler, GoghScheduler};
use crate::daemon::protocol::{error_envelope, ok_envelope, ProtoError, Request};
use crate::daemon::snapshot::Snapshot;
use crate::engine::{EngineOptions, GoghCore};
use crate::util::Json;
use crate::workload::{JobId, JobSpec};
use crate::Result;
use anyhow::Context as _;

/// Where the daemon listens.
#[derive(Debug, Clone)]
pub enum Endpoint {
    /// `host:port`; port 0 binds an ephemeral port (pair with
    /// `port_file` so clients can find it).
    Tcp(String),
    /// Filesystem path; any stale socket file is removed before bind.
    Unix(PathBuf),
}

/// Everything `goghd` needs to run (built from CLI flags in
/// `bin/goghd.rs`).
#[derive(Debug, Clone)]
pub struct DaemonOptions {
    pub cfg: ExperimentConfig,
    pub endpoint: Endpoint,
    /// Snapshot file; `None` disables persistence entirely.
    pub state: Option<PathBuf>,
    /// Seconds of *wall* time between periodic snapshots (0 = every
    /// loop iteration; only sensible in tests).
    pub snapshot_every_s: f64,
    /// Simulated seconds per wall second (1 = real time).
    pub time_scale: f64,
    /// When set, the bound TCP port is written here after listen.
    pub port_file: Option<PathBuf>,
    /// Ignore an existing snapshot and start from empty state.
    pub fresh: bool,
}

enum Listener {
    Tcp(TcpListener),
    Unix(UnixListener),
}

enum Stream {
    Tcp(TcpStream),
    Unix(UnixStream),
}

impl Stream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.read(buf),
            Stream::Unix(s) => s.read(buf),
        }
    }

    /// Blocking-ish write of one small response line: retries
    /// `WouldBlock` briefly rather than buffering, since responses are
    /// a few hundred bytes against an OS-level send buffer.
    fn write_line(&mut self, line: &str) -> std::io::Result<()> {
        let mut data = line.as_bytes().to_vec();
        data.push(b'\n');
        let mut off = 0;
        while off < data.len() {
            let r = match self {
                Stream::Tcp(s) => s.write(&data[off..]),
                Stream::Unix(s) => s.write(&data[off..]),
            };
            match r {
                Ok(n) => off += n,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(1));
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => return Err(e),
            }
        }
        Ok(())
    }
}

/// One client connection and its partial-line read buffer.
struct Conn {
    stream: Stream,
    buf: Vec<u8>,
}

/// Hard cap on a single request line; longer input drops the
/// connection instead of growing the buffer without bound.
const MAX_LINE_BYTES: usize = 1 << 20;

/// The daemon's mutable world: the shared policy/event core plus the
/// pieces the simulator doesn't have (id allocator, drain flag).
struct DaemonState {
    core: GoghCore,
    scheduler: GoghScheduler,
    backend: &'static str,
    next_job_id: u32,
    draining: bool,
}

impl DaemonState {
    fn handle(&mut self, req: Request, sim_now: f64) -> std::result::Result<Json, ProtoError> {
        match req {
            Request::Submit { job } => {
                if self.draining {
                    return Err(ProtoError::new(
                        "draining",
                        "daemon is draining; new submissions are refused",
                    ));
                }
                let id = self.next_job_id;
                self.next_job_id += 1;
                self.core.submit(sim_now, job.into_spec(JobId(id)));
                Ok(ok_envelope(vec![("id", id.into()), ("at", sim_now.into())]))
            }
            Request::Queue => {
                let cluster = self.core.cluster();
                let mut jobs: Vec<&JobSpec> = cluster.jobs().collect();
                jobs.sort_by_key(|j| j.id);
                let rows: Vec<Json> = jobs.iter().map(|j| queue_row(cluster, j)).collect();
                Ok(ok_envelope(vec![
                    ("jobs", Json::Array(rows)),
                    ("pending", self.core.pending_arrivals().into()),
                    ("draining", self.draining.into()),
                ]))
            }
            Request::Cancel { job } => {
                let id = JobId(job);
                if self.core.cluster().job(id).is_none() {
                    return Err(ProtoError::new(
                        "unknown_job",
                        format!("job {id} is not active on this daemon"),
                    ));
                }
                self.core.cancel(sim_now, id);
                Ok(ok_envelope(vec![("id", job.into()), ("cancelled", true.into())]))
            }
            Request::Status => Ok(self.status(sim_now)),
            Request::Drain => {
                self.draining = true;
                Ok(ok_envelope(vec![
                    ("draining", true.into()),
                    ("active", self.core.cluster().n_jobs().into()),
                ]))
            }
        }
    }

    fn status(&self, sim_now: f64) -> Json {
        let report = self.core.report(&self.scheduler);
        let cluster = self.core.cluster();
        let mut placements: Vec<Json> = Vec::new();
        let mut placed: Vec<_> = cluster.placement.iter().collect();
        placed.sort_by_key(|(a, _)| **a);
        for (a, combo) in placed {
            let ids = Json::Array(combo.jobs().iter().map(|j| Json::from(j.0)).collect());
            placements.push(Json::obj(vec![("accel", a.to_string().into()), ("jobs", ids)]));
        }
        let jobs = Json::obj(vec![
            ("total", report.jobs_total.into()),
            ("completed", report.jobs_completed.into()),
            ("cancelled", report.jobs_cancelled.into()),
            ("active", cluster.n_jobs().into()),
        ]);
        let catalog = Json::obj(vec![
            ("records", self.scheduler.catalog.len().into()),
            ("measured", self.scheduler.catalog.n_measured().into()),
        ]);
        // additive power block (protocol stays v1 — clients ignore
        // unknown fields): peak/cap draw, cumulative emissions, and the
        // sparse per-accel DVFS states (absent accel = nominal)
        let states: Vec<Json> = cluster
            .power_state_entries()
            .into_iter()
            .map(|(a, s)| {
                Json::obj(vec![("accel", a.to_string().into()), ("state", s.key().into())])
            })
            .collect();
        let power = Json::obj(vec![
            ("peak_w", report.power_peak_w.into()),
            ("cap_w", report.power_cap_w.map(Json::from).unwrap_or(Json::Null)),
            ("cap_attainment", report.power_cap_attainment.into()),
            ("grams_co2", report.grams_co2.into()),
            ("states", Json::Array(states)),
        ]);
        // additive priority block (still protocol v1): preemption
        // counters plus per-tier SLO attainment, best→critical
        let suspended = cluster.suspended_job_ids().len();
        let tiers = Json::Array(
            crate::workload::Priority::ALL
                .iter()
                .map(|p| {
                    Json::obj(vec![
                        ("tier", p.key().into()),
                        ("attainment", report.tier_attainment[p.index()].into()),
                    ])
                })
                .collect(),
        );
        let priority = Json::obj(vec![
            ("preemptions", report.preemptions.into()),
            ("suspended_now", suspended.into()),
            ("suspended_seconds", report.suspended_seconds.into()),
            ("ftf_p99", report.ftf_p99.into()),
            ("tiers", tiers),
        ]);
        ok_envelope(vec![
            ("backend", self.backend.into()),
            ("draining", self.draining.into()),
            ("sim_seconds", sim_now.into()),
            ("jobs", jobs),
            ("placements", Json::Array(placements)),
            ("catalog", catalog),
            ("energy_joules", report.energy_joules.into()),
            ("power", power),
            ("priority", priority),
        ])
    }
}

/// One `queue` response row for an active job.
fn queue_row(cluster: &Cluster, j: &JobSpec) -> Json {
    let accels: Vec<Json> =
        cluster.placement.accels_of(j.id).iter().map(|a| Json::from(a.to_string())).collect();
    let kind = if j.is_inference() { "inference" } else { "training" };
    Json::obj(vec![
        ("id", j.id.0.into()),
        ("family", j.family.name().into()),
        ("kind", kind.into()),
        ("priority", j.priority.key().into()),
        ("placed", (!accels.is_empty()).into()),
        ("suspended", cluster.is_suspended(j.id).into()),
        ("accels", Json::Array(accels)),
        ("work_remaining", j.work.into()),
    ])
}

/// Run the daemon until it drains (after a `drain` request) or the
/// process is killed. Blocks the calling thread.
pub fn serve(opts: DaemonOptions) -> Result<()> {
    anyhow::ensure!(
        opts.time_scale > 0.0 && opts.time_scale.is_finite(),
        "time-scale must be a positive number (got {})",
        opts.time_scale
    );
    let oracle = opts.cfg.build_oracle()?;
    let (mut scheduler, backend) = build_scheduler(&opts.cfg, &oracle)?;
    let mut core = GoghCore::new(
        ClusterSpec::mix(&opts.cfg.cluster.accel_mix),
        oracle,
        opts.cfg.noise_sigma,
        opts.cfg.monitor_interval_s,
        opts.cfg.seed,
    )?
    .with_options(
        EngineOptions::new()
            .with_migration_cost(opts.cfg.migration_cost_s)
            .with_power_cap(opts.cfg.power.cap_w)
            .with_carbon(opts.cfg.power.carbon.signal()),
    );

    let mut next_job_id = 0;
    let mut draining = false;
    let mut base_sim_t = 0.0;
    if let Some(path) = opts.state.as_ref().filter(|p| p.exists() && !opts.fresh) {
        // a corrupt state file must refuse startup with a named error,
        // never panic or silently start empty (pinned by the
        // garbage-snapshot test in rust/tests/daemon.rs); --fresh is
        // the explicit way to discard it
        let snap = Snapshot::load(path).with_context(|| {
            format!("state snapshot {} is unreadable (--fresh discards it)", path.display())
        })?;
        snap.restore_into(&mut core, &mut scheduler).with_context(|| {
            format!("state snapshot {} failed to restore (--fresh discards it)", path.display())
        })?;
        next_job_id = snap.next_job_id;
        draining = snap.draining;
        base_sim_t = snap.now_s;
        println!(
            "goghd: restored snapshot ({} jobs, {} placements, {} catalog records) from {}",
            snap.jobs.len(),
            snap.placements.len(),
            scheduler.catalog.len(),
            path.display()
        );
    }
    core.start_monitor();

    let listener = match &opts.endpoint {
        Endpoint::Tcp(addr) => {
            let l = TcpListener::bind(addr).with_context(|| format!("binding tcp {addr}"))?;
            l.set_nonblocking(true)?;
            let local = l.local_addr()?;
            if let Some(pf) = &opts.port_file {
                std::fs::write(pf, local.port().to_string())
                    .with_context(|| format!("writing port file {}", pf.display()))?;
            }
            println!(
                "goghd: listening on {local} (backend {backend}, time-scale {})",
                opts.time_scale
            );
            Listener::Tcp(l)
        }
        Endpoint::Unix(path) => {
            if path.exists() {
                std::fs::remove_file(path).ok();
            }
            let l = UnixListener::bind(path)
                .with_context(|| format!("binding unix socket {}", path.display()))?;
            l.set_nonblocking(true)?;
            println!(
                "goghd: listening on {} (backend {backend}, time-scale {})",
                path.display(),
                opts.time_scale
            );
            Listener::Unix(l)
        }
    };

    let mut state = DaemonState {
        core,
        scheduler,
        backend,
        next_job_id,
        draining,
    };
    let started = Instant::now();
    let mut last_snapshot = Instant::now();
    let mut conns: Vec<Conn> = Vec::new();
    loop {
        // accept any newly connected clients
        loop {
            let accepted = match &listener {
                Listener::Tcp(l) => l.accept().map(|(s, _)| {
                    s.set_nonblocking(true).ok();
                    Stream::Tcp(s)
                }),
                Listener::Unix(l) => l.accept().map(|(s, _)| {
                    s.set_nonblocking(true).ok();
                    Stream::Unix(s)
                }),
            };
            match accepted {
                Ok(stream) => conns.push(Conn {
                    stream,
                    buf: Vec::new(),
                }),
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) => return Err(e).context("accepting connection"),
            }
        }

        let sim_now = base_sim_t + started.elapsed().as_secs_f64() * opts.time_scale;

        // service every connection: read what's available, answer
        // complete lines, drop closed or misbehaving clients
        let mut i = 0;
        while i < conns.len() {
            match service_conn(&mut conns[i], &mut state, sim_now) {
                Ok(true) => i += 1,
                Ok(false) | Err(_) => {
                    conns.swap_remove(i);
                }
            }
        }

        // advance the shared core to wall-derived simulated time
        state.core.advance_to(sim_now, &mut state.scheduler).context("advancing the core")?;

        // periodic checkpoint
        if let Some(path) = &opts.state {
            if last_snapshot.elapsed().as_secs_f64() >= state_snapshot_period(&opts) {
                Snapshot::capture(&state.core, &state.scheduler, state.next_job_id, state.draining)
                    .save(path)?;
                last_snapshot = Instant::now();
            }
        }

        // drain exit: everything submitted has finished
        if state.draining && state.core.drained() {
            if let Some(path) = &opts.state {
                Snapshot::capture(&state.core, &state.scheduler, state.next_job_id, true)
                    .save(path)?;
                println!("goghd: final snapshot saved to {}", path.display());
            }
            println!("goghd: drained; exiting");
            return Ok(());
        }

        std::thread::sleep(Duration::from_millis(10));
    }
}

fn state_snapshot_period(opts: &DaemonOptions) -> f64 {
    opts.snapshot_every_s.max(0.0)
}

/// Read and answer whatever complete request lines `conn` has buffered.
/// Returns `Ok(false)` when the peer closed the connection.
fn service_conn(conn: &mut Conn, state: &mut DaemonState, sim_now: f64) -> Result<bool> {
    let mut chunk = [0u8; 4096];
    loop {
        match conn.stream.read(&mut chunk) {
            Ok(0) => return Ok(false),
            Ok(n) => {
                conn.buf.extend_from_slice(&chunk[..n]);
                if conn.buf.len() > MAX_LINE_BYTES {
                    anyhow::bail!("request line exceeds {MAX_LINE_BYTES} bytes");
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e).context("reading request"),
        }
    }
    while let Some(nl) = conn.buf.iter().position(|&b| b == b'\n') {
        let line: Vec<u8> = conn.buf.drain(..=nl).collect();
        let line = String::from_utf8_lossy(&line[..nl]).into_owned();
        if line.trim().is_empty() {
            continue;
        }
        let response = match Request::parse(&line) {
            Ok(req) => match state.handle(req, sim_now) {
                Ok(ok) => ok,
                Err(proto) => error_envelope(&proto),
            },
            Err(proto) => error_envelope(&proto),
        };
        conn.stream.write_line(&response.to_string()).context("writing response")?;
    }
    Ok(true)
}
