//! Configuration system: JSON-serializable experiment configuration
//! covering the cluster mix, trace, estimator choice and optimizer
//! limits. `ExperimentConfig::default()` is the quickstart setup; the
//! CLI (`gogh simulate --config exp.json`) and every bench build from
//! this type.
//!
//! (Offline-build note: config files are JSON via the in-tree parser —
//! see Cargo.toml.)

use crate::util::Json;
use crate::workload::{AccelType, TraceConfig, ACCEL_TYPES};
use crate::Result;

/// Which neural architecture drives an estimator (paper §3.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Arch {
    Ff,
    Rnn,
    Transformer,
}

impl Arch {
    pub fn key(self) -> &'static str {
        match self {
            Arch::Ff => "ff",
            Arch::Rnn => "rnn",
            Arch::Transformer => "transformer",
        }
    }

    pub fn from_key(k: &str) -> Result<Self> {
        Ok(match k {
            "ff" => Arch::Ff,
            "rnn" => Arch::Rnn,
            "transformer" => Arch::Transformer,
            other => anyhow::bail!("unknown arch {other:?}"),
        })
    }

    pub const ALL: [Arch; 3] = [Arch::Ff, Arch::Rnn, Arch::Transformer];
}

impl std::fmt::Display for Arch {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.key())
    }
}

/// Which estimator backend drives the P1/P2 networks (`gogh.backend`
/// in config JSON, `--backend` on the CLI).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BackendKind {
    /// Resolve at startup: pjrt if artifacts load, else native (a
    /// warning names the backend actually used).
    #[default]
    Auto,
    /// AOT-compiled PJRT artifacts; a missing artifact dir is a hard
    /// error, never a silent fallback.
    Pjrt,
    /// The pure-Rust in-crate MLP engine (`runtime::native`) — zero
    /// external artifacts, bit-reproducible from the seed.
    Native,
    /// Estimator-free: catalog priors + measurements only.
    None,
}

impl BackendKind {
    pub fn key(self) -> &'static str {
        match self {
            BackendKind::Auto => "auto",
            BackendKind::Pjrt => "pjrt",
            BackendKind::Native => "native",
            BackendKind::None => "none",
        }
    }

    pub fn from_key(k: &str) -> Result<Self> {
        Ok(match k {
            "auto" => BackendKind::Auto,
            "pjrt" => BackendKind::Pjrt,
            "native" => BackendKind::Native,
            "none" => BackendKind::None,
            other => anyhow::bail!("unknown backend {other:?} (want auto|pjrt|native|none)"),
        })
    }
}

impl std::fmt::Display for BackendKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.key())
    }
}

/// Cluster composition.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Instances per accelerator type, `(type, count)`.
    pub accel_mix: Vec<(AccelType, u32)>,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        Self {
            accel_mix: ACCEL_TYPES.iter().map(|&a| (a, 2)).collect(),
        }
    }
}

/// Estimator / learning-loop configuration.
#[derive(Debug, Clone)]
pub struct EstimatorConfig {
    /// P1 architecture (paper's best: RNN).
    pub p1_arch: Arch,
    /// P2 architecture (paper's best: FF).
    pub p2_arch: Arch,
    /// Directory with AOT artifacts + manifest.json.
    pub artifacts_dir: String,
    /// Online training steps per monitoring round (0 disables online
    /// learning — the "frozen estimator" ablation).
    pub online_steps_per_round: usize,
    /// Pre-training steps on bootstrap (historical) data at startup.
    pub bootstrap_steps: usize,
    /// Replay-buffer capacity for online training samples.
    pub replay_capacity: usize,
}

impl Default for EstimatorConfig {
    fn default() -> Self {
        Self {
            p1_arch: Arch::Rnn,
            p2_arch: Arch::Ff,
            artifacts_dir: "artifacts".to_string(),
            online_steps_per_round: 4,
            bootstrap_steps: 300,
            replay_capacity: 8192,
        }
    }
}

/// Optimizer (Problem 1) limits.
#[derive(Debug, Clone)]
pub struct OptimizerConfig {
    pub max_pairs_per_job: usize,
    /// Branch-and-bound node budget (anytime cutoff; the search degrades
    /// gracefully to the warm-start incumbent when it trips).
    pub max_nodes: usize,
    /// Branch-and-bound wall-clock budget in seconds.
    pub time_limit_s: f64,
    /// SLO slack penalty (soft constraints; see problem1.rs).
    pub slack_penalty: f64,
    /// Lagrangian throughput bonus λ (see problem1.rs; 0 = the paper's
    /// literal instantaneous-power objective).
    pub throughput_bonus: f64,
    /// Seed branch-and-bound with the greedy incumbent from
    /// `baselines::greedy` (strictly fewer explored nodes; disable only
    /// for solver benchmarking).
    pub warm_start: bool,
    /// Node-selection strategy for the branch-and-bound frontier.
    pub node_selection: crate::ilp::NodeSelection,
}

impl Default for OptimizerConfig {
    fn default() -> Self {
        Self {
            max_pairs_per_job: 3,
            // Anytime limits: the greedy warm start + per-node rounding
            // heuristic give a feasible incumbent immediately; these caps
            // bound the decision-path latency (§Perf). The LP relaxation
            // of Problem 1 is fixed-charge-weak, so proving optimality at
            // |J| ≥ 12 is not worth the wall-clock on the request path.
            max_nodes: 2000,
            time_limit_s: 2.0,
            slack_penalty: 2000.0,
            throughput_bonus: 300.0,
            warm_start: true,
            node_selection: crate::ilp::NodeSelection::BestBound,
        }
    }
}

/// GOGH policy knobs (the coordinator's own behaviour, as opposed to
/// the estimator or optimizer subsystems).
#[derive(Debug, Clone)]
pub struct GoghPolicyConfig {
    /// Estimator backend (`auto` resolves pjrt → native at startup).
    pub backend: BackendKind,
    /// Historical jobs seeded into the catalog at startup.
    pub history_jobs: usize,
    /// Apply P2 cross-GPU refinement (Eq. 3/4); disabling it is the
    /// "P1-only" ablation.
    pub enable_refinement: bool,
    /// Active-exploration probability per full allocation round.
    pub exploration_epsilon: f64,
    /// Escape hatch for the incremental arrival path: force a full
    /// Problem-1 re-solve every K events (1 = always full re-solve).
    pub full_resolve_every: usize,
    /// Neighborhood size of the incremental arrival path: the bounded
    /// local ILP re-solves the new job plus up to this many co-location
    /// candidates (0 disables the incremental path entirely).
    pub neighborhood: usize,
    /// Server-pool shards of the parallel decision path: each arrival is
    /// solved per shard on scoped worker threads and routed to the shard
    /// with the lowest marginal energy; the periodic full re-solve stays
    /// global as the cross-shard rebalance. 1 (the default) keeps the
    /// single-threaded pre-shard path.
    pub shards: usize,
    /// Top-level shard-groups of the hierarchical two-level decision
    /// path (`shards` then counts shards *per group*): a catalog-only
    /// router picks the cheapest group per arrival and only that
    /// group's shards solve, bounding per-decision work at 10k-accel
    /// scale. 1 (the default) keeps flat single-level sharding.
    pub topology_groups: usize,
    /// Memoize estimate-matrix lookups between catalog mutations
    /// (value-transparent; disable only for cache benchmarking).
    pub estimate_cache: bool,
    /// Cap on P1 co-runner candidates per arrival (0 = every active
    /// job); large clusters need the cap to keep the round-0 estimate
    /// fan-out O(active) instead of O(active²).
    pub p1_candidates: usize,
    /// Priority preemption: let a higher-tier arrival suspend the
    /// cheapest strictly-lower-tier job when no instance is free, and
    /// let the periodic full re-solve park jobs the ILP drops instead
    /// of leaving them pending. Off (the default) reproduces the
    /// pre-priority decision stream bit-for-bit.
    pub preemption: bool,
}

impl Default for GoghPolicyConfig {
    fn default() -> Self {
        Self {
            backend: BackendKind::Auto,
            history_jobs: 24,
            enable_refinement: true,
            exploration_epsilon: 0.0,
            full_resolve_every: 8,
            neighborhood: 4,
            shards: 1,
            topology_groups: 1,
            estimate_cache: true,
            p1_candidates: 0,
            preemption: false,
        }
    }
}

/// Power management (docs/POWER.md): per-accelerator DVFS states, the
/// cluster power cap and the diurnal carbon signal. All off by default —
/// the pre-power behaviour, bit-for-bit.
#[derive(Debug, Clone, Default)]
pub struct PowerConfig {
    /// Cluster-wide power cap in watts (`None` = uncapped). Enforced
    /// transactionally against the worst-case draw of every placement
    /// delta; the run report carries cap attainment.
    pub cap_w: Option<f64>,
    /// Let the optimizer and the monitor-tick governor pick per-accel
    /// DVFS states. Off pins every instance to nominal frequency.
    pub dvfs: bool,
    /// Diurnal grid carbon signal (disabled while `base_gco2_per_kwh`
    /// is 0).
    pub carbon: CarbonConfig,
}

/// Diurnal carbon/price signal parameters (see
/// [`crate::power::CarbonSignal`]) — also the schema of `--carbon-trace`
/// JSON files.
#[derive(Debug, Clone, Default)]
pub struct CarbonConfig {
    /// Mean grid intensity in gCO₂ per kWh; ≤ 0 disables the signal.
    pub base_gco2_per_kwh: f64,
    /// Diurnal swing as a fraction of the mean, clamped to 0..1.
    pub amplitude: f64,
    /// Phase offset in seconds (0 puts the peak 6 h into the day).
    pub phase_s: f64,
}

impl CarbonConfig {
    /// The runtime signal, or `None` while disabled.
    pub fn signal(&self) -> Option<crate::power::CarbonSignal> {
        (self.base_gco2_per_kwh > 0.0).then(|| crate::power::CarbonSignal {
            base_gco2_per_kwh: self.base_gco2_per_kwh,
            amplitude: self.amplitude,
            phase_s: self.phase_s,
        })
    }

    /// Parse a `--carbon-trace` JSON file: the same keys as the
    /// `power.carbon` config section, with `base_gco2_per_kwh` required
    /// (a trace file that disables the signal is almost certainly a
    /// typo).
    pub fn from_json(text: &str) -> Result<Self> {
        use anyhow::Context as _;
        let j = Json::parse(text).context("invalid carbon trace JSON")?;
        let base = j
            .get("base_gco2_per_kwh")
            .ok_or_else(|| anyhow::anyhow!("carbon trace: missing base_gco2_per_kwh"))?;
        let mut cfg = Self {
            base_gco2_per_kwh: expect_f64(base, "base_gco2_per_kwh")?,
            ..Self::default()
        };
        if let Some(v) = j.get("amplitude") {
            cfg.amplitude = expect_f64(v, "amplitude")?;
        }
        if let Some(v) = j.get("phase_s") {
            cfg.phase_s = expect_f64(v, "phase_s")?;
        }
        Ok(cfg)
    }
}

/// Full experiment configuration.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub cluster: ClusterConfig,
    pub trace: TraceConfig,
    pub estimator: EstimatorConfig,
    pub optimizer: OptimizerConfig,
    pub gogh: GoghPolicyConfig,
    pub power: PowerConfig,
    /// Monitoring interval (seconds of simulated time). Must be > 0;
    /// validated by `SimDriver::new`.
    pub monitor_interval_s: f64,
    /// Measurement noise sigma.
    pub noise_sigma: f64,
    /// Restart penalty charged to every migrated job (seconds of stall).
    pub migration_cost_s: f64,
    /// Ground-truth / trace seed.
    pub seed: u64,
    /// Optional CSV of measured throughputs (the real Gavel dataset —
    /// see `workload/gavel_csv.rs`) overlaid on the synthetic oracle.
    pub gavel_csv: Option<String>,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        Self {
            cluster: Default::default(),
            trace: Default::default(),
            estimator: Default::default(),
            optimizer: Default::default(),
            gogh: Default::default(),
            power: Default::default(),
            monitor_interval_s: 30.0,
            noise_sigma: 0.03,
            migration_cost_s: 0.0,
            seed: 17,
            gavel_csv: None,
        }
    }
}

fn accel_from_name(n: &str) -> Result<AccelType> {
    ACCEL_TYPES
        .iter()
        .copied()
        .find(|a| a.name() == n)
        .ok_or_else(|| anyhow::anyhow!("unknown accel type {n:?}"))
}

// Typed field readers with dotted-path context. A key that is *absent*
// keeps its default (partial configs are fine); a key that is present
// with the wrong JSON type is a hard error naming the offending field —
// previously such typos silently fell back to the default value.
fn expect_f64(v: &Json, path: &str) -> Result<f64> {
    v.as_f64()
        .ok_or_else(|| anyhow::anyhow!("config field {path}: expected a number, got {v}"))
}

fn expect_u64(v: &Json, path: &str) -> Result<u64> {
    v.as_u64()
        .ok_or_else(|| anyhow::anyhow!("config field {path}: expected an integer, got {v}"))
}

fn expect_usize(v: &Json, path: &str) -> Result<usize> {
    v.as_usize()
        .ok_or_else(|| anyhow::anyhow!("config field {path}: expected an integer, got {v}"))
}

fn expect_bool(v: &Json, path: &str) -> Result<bool> {
    v.as_bool()
        .ok_or_else(|| anyhow::anyhow!("config field {path}: expected a boolean, got {v}"))
}

fn expect_str<'j>(v: &'j Json, path: &str) -> Result<&'j str> {
    v.as_str()
        .ok_or_else(|| anyhow::anyhow!("config field {path}: expected a string, got {v}"))
}

impl ExperimentConfig {
    /// Named experiment presets (`gogh simulate --preset <name>`).
    pub fn preset(name: &str) -> Result<Self> {
        match name {
            "default" => Ok(Self::default()),
            "large" => Ok(Self::large_scale()),
            "huge" => Ok(Self::huge_scale()),
            "mixed" => Ok(Self::mixed_workload()),
            "serving" => Ok(Self::serving_heavy()),
            "powercap" => Ok(Self::powercap()),
            "carbon" => Ok(Self::carbon()),
            "priority" => Ok(Self::priority()),
            "burst" => Ok(Self::burst()),
            "contended" => Ok(Self::contended()),
            other => anyhow::bail!(
                "unknown preset {other:?} (want default|large|huge|mixed|serving|powercap|\
                 carbon|priority|burst|contended)"
            ),
        }
    }

    /// The `large` scale scenario: ≥ 1024 accelerator instances and a
    /// ≥ 50k-event trace ([`TraceConfig::large`]), with solver budgets
    /// tuned so the periodic full re-solve stays an off-path rebalance
    /// and the sharded incremental path carries the arrival load.
    pub fn large_scale() -> Self {
        let mut cfg = Self::default();
        // 6 types × 172 = 1032 instances
        cfg.cluster.accel_mix = ACCEL_TYPES.iter().map(|&a| (a, 172)).collect();
        cfg.trace = TraceConfig::large();
        cfg.seed = 42;
        // fewer, coarser monitoring rounds: ~320 ticks over the horizon
        cfg.monitor_interval_s = 300.0;
        // a ~450-job full ILP is seconds even warm-started: keep it rare
        // and tightly budgeted; the local solves carry the decision path
        cfg.optimizer.max_pairs_per_job = 1;
        cfg.optimizer.max_nodes = 200;
        cfg.optimizer.time_limit_s = 1.0;
        cfg.gogh.full_resolve_every = 5000;
        cfg.gogh.shards = 4;
        cfg.gogh.p1_candidates = 8;
        cfg
    }

    /// The `huge` scale scenario: ~10k accelerator instances under a
    /// ≥ 500k-event trace ([`TraceConfig::huge`]) — the regime the
    /// hierarchical topology exists for. The top-level router fans
    /// each arrival into a single group's shards, so per-decision work
    /// matches the `large` scenario at ten times the fleet.
    pub fn huge_scale() -> Self {
        let mut cfg = Self::large_scale();
        // 6 types × 1667 = 10,002 instances
        cfg.cluster.accel_mix = ACCEL_TYPES.iter().map(|&a| (a, 1667)).collect();
        cfg.trace = TraceConfig::huge();
        cfg.seed = 43;
        // coarser monitoring: ~420 ticks over the ~250k-second horizon
        cfg.monitor_interval_s = 600.0;
        // 8 groups × 4 shards/group: each arrival routes to one group
        // and solves 4 local ILPs over ~310-instance pools
        cfg.gogh.topology_groups = 8;
        // a 10k-accel full ILP is out of budget at any frequency: the
        // hierarchical path carries the whole run and the global
        // re-solve remains only as the no-feasible-shard fallback
        cfg.gogh.full_resolve_every = 1_000_000;
        cfg
    }

    /// The `mixed` train+infer scenario ([`TraceConfig::mixed`]): a
    /// 48-instance heterogeneous cluster where roughly a third of the
    /// arrivals are latency-SLO serving jobs — the CI mixed-workload
    /// smoke runs this end to end with the native backend.
    pub fn mixed_workload() -> Self {
        let mut cfg = Self::default();
        // 6 types × 8 = 48 instances: enough headroom for replicas
        cfg.cluster.accel_mix = ACCEL_TYPES.iter().map(|&a| (a, 8)).collect();
        cfg.trace = TraceConfig::mixed();
        cfg.seed = 77;
        cfg.monitor_interval_s = 60.0;
        cfg.optimizer.max_pairs_per_job = 2;
        cfg.optimizer.max_nodes = 600;
        cfg.gogh.full_resolve_every = 12;
        cfg.gogh.p1_candidates = 8;
        cfg
    }

    /// The `serving` scenario ([`TraceConfig::serving_heavy`]): the same
    /// cluster under a serving-dominated (80% inference) arrival mix.
    pub fn serving_heavy() -> Self {
        let mut cfg = Self::mixed_workload();
        cfg.trace = TraceConfig::serving_heavy();
        cfg.seed = 78;
        cfg
    }

    /// The `powercap` scenario: the default 12-instance cluster run
    /// under a binding 1.2 kW cluster cap with the DVFS layer on — low
    /// enough that some decisions get trimmed to `Low`, high enough that
    /// every job still completes. The CI power smoke asserts the report
    /// never shows peak draw above the cap.
    pub fn powercap() -> Self {
        let mut cfg = Self::default();
        cfg.power.cap_w = Some(1200.0);
        cfg.power.dvfs = true;
        cfg.seed = 91;
        cfg
    }

    /// The `carbon` scenario: the default cluster priced under a diurnal
    /// grid signal (420 gCO₂/kWh mean, ±35% swing) with DVFS on, so the
    /// objective's energy term follows the grid and the report carries
    /// emissions.
    pub fn carbon() -> Self {
        let mut cfg = Self::default();
        cfg.power.dvfs = true;
        cfg.power.carbon = CarbonConfig {
            base_gco2_per_kwh: 420.0,
            amplitude: 0.35,
            phase_s: 0.0,
        };
        cfg.seed = 92;
        cfg
    }

    /// The `priority` scenario: a tiered arrival mix (20% Critical, 35%
    /// best-effort, some elastic training) on the default 12-instance
    /// cluster with arrivals fast enough that tiers regularly contend
    /// for instances. Preemption is on — the CI priority smoke asserts
    /// Critical-tier attainment ≥ Standard and preemptions > 0 here.
    pub fn priority() -> Self {
        let mut cfg = Self::default();
        cfg.trace.critical_fraction = 0.2;
        cfg.trace.best_fraction = 0.35;
        cfg.trace.elastic_fraction = 0.25;
        cfg.trace.slo_fraction = 0.8;
        cfg.trace.mean_interarrival_s = 12.0;
        cfg.trace.mean_work_s = 240.0;
        cfg.migration_cost_s = 5.0;
        cfg.gogh.preemption = true;
        cfg.seed = 93;
        cfg
    }

    /// The `burst` scenario: the priority mix under bursty arrivals
    /// (interarrivals a third of `priority`'s), so queues form even
    /// though the long-run load is serviceable — the case preemption
    /// and round-based fairness answer differently.
    pub fn burst() -> Self {
        let mut cfg = Self::priority();
        cfg.trace.mean_interarrival_s = 4.0;
        cfg.trace.mean_work_s = 180.0;
        cfg.seed = 94;
        cfg
    }

    /// The `contended` scenario: standing overload (offered load well
    /// above capacity), where tier weights decide who runs at all and
    /// elastic jobs surrender instances first.
    pub fn contended() -> Self {
        let mut cfg = Self::priority();
        cfg.trace.critical_fraction = 0.3;
        cfg.trace.best_fraction = 0.3;
        cfg.trace.mean_interarrival_s = 6.0;
        cfg.trace.mean_work_s = 480.0;
        cfg.seed = 95;
        cfg
    }

    /// Parse a config, overlaying the given fields on the defaults.
    /// Errors carry a pointer to the offending input: parse failures
    /// name the line/column, type mismatches and unknown enum values
    /// name the dotted field path (e.g. `trace.n_jobs`).
    pub fn from_json(text: &str) -> Result<Self> {
        use anyhow::Context as _;
        let j = Json::parse(text).context("invalid config JSON")?;
        let mut cfg = ExperimentConfig::default();
        if let Some(c) = j.get("cluster") {
            if let Some(mix) = c.get("accel_mix").and_then(|m| m.as_object()) {
                cfg.cluster.accel_mix = mix
                    .iter()
                    .map(|(k, v)| {
                        let n = expect_f64(v, &format!("cluster.accel_mix.{k}"))?;
                        Ok((accel_from_name(k)?, n as u32))
                    })
                    .collect::<Result<Vec<_>>>()?;
            }
        }
        if let Some(t) = j.get("trace") {
            if let Some(v) = t.get("n_jobs") {
                cfg.trace.n_jobs = expect_usize(v, "trace.n_jobs")?;
            }
            if let Some(v) = t.get("mean_interarrival_s") {
                cfg.trace.mean_interarrival_s = expect_f64(v, "trace.mean_interarrival_s")?;
            }
            if let Some(v) = t.get("mean_work_s") {
                cfg.trace.mean_work_s = expect_f64(v, "trace.mean_work_s")?;
            }
            if let Some(v) = t.get("slo_fraction") {
                cfg.trace.slo_fraction = expect_f64(v, "trace.slo_fraction")?;
            }
            if let Some(v) = t.get("max_distributability") {
                cfg.trace.max_distributability =
                    expect_f64(v, "trace.max_distributability")? as u32;
            }
            if let Some(v) = t.get("cancel_rate") {
                cfg.trace.cancel_rate = expect_f64(v, "trace.cancel_rate")?;
            }
            if let Some(v) = t.get("accel_churn") {
                cfg.trace.accel_churn = expect_f64(v, "trace.accel_churn")?;
            }
            if let Some(v) = t.get("inference_fraction") {
                cfg.trace.inference_fraction =
                    expect_f64(v, "trace.inference_fraction")?.clamp(0.0, 1.0);
            }
            if let Some(v) = t.get("critical_fraction") {
                cfg.trace.critical_fraction =
                    expect_f64(v, "trace.critical_fraction")?.clamp(0.0, 1.0);
            }
            if let Some(v) = t.get("best_fraction") {
                cfg.trace.best_fraction = expect_f64(v, "trace.best_fraction")?.clamp(0.0, 1.0);
            }
            if let Some(v) = t.get("elastic_fraction") {
                cfg.trace.elastic_fraction =
                    expect_f64(v, "trace.elastic_fraction")?.clamp(0.0, 1.0);
            }
            if let Some(v) = t.get("seed") {
                cfg.trace.seed = expect_u64(v, "trace.seed")?;
            }
        }
        if let Some(e) = j.get("estimator") {
            if let Some(v) = e.get("p1_arch") {
                cfg.estimator.p1_arch = Arch::from_key(expect_str(v, "estimator.p1_arch")?)
                    .context("config field estimator.p1_arch")?;
            }
            if let Some(v) = e.get("p2_arch") {
                cfg.estimator.p2_arch = Arch::from_key(expect_str(v, "estimator.p2_arch")?)
                    .context("config field estimator.p2_arch")?;
            }
            if let Some(v) = e.get("artifacts_dir") {
                cfg.estimator.artifacts_dir =
                    expect_str(v, "estimator.artifacts_dir")?.to_string();
            }
            if let Some(v) = e.get("online_steps_per_round") {
                cfg.estimator.online_steps_per_round =
                    expect_usize(v, "estimator.online_steps_per_round")?;
            }
            if let Some(v) = e.get("bootstrap_steps") {
                cfg.estimator.bootstrap_steps = expect_usize(v, "estimator.bootstrap_steps")?;
            }
            if let Some(v) = e.get("replay_capacity") {
                cfg.estimator.replay_capacity = expect_usize(v, "estimator.replay_capacity")?;
            }
        }
        if let Some(o) = j.get("optimizer") {
            if let Some(v) = o.get("max_pairs_per_job") {
                cfg.optimizer.max_pairs_per_job = expect_usize(v, "optimizer.max_pairs_per_job")?;
            }
            if let Some(v) = o.get("max_nodes") {
                cfg.optimizer.max_nodes = expect_usize(v, "optimizer.max_nodes")?;
            }
            if let Some(v) = o.get("time_limit_s") {
                cfg.optimizer.time_limit_s = expect_f64(v, "optimizer.time_limit_s")?;
            }
            if let Some(v) = o.get("slack_penalty") {
                cfg.optimizer.slack_penalty = expect_f64(v, "optimizer.slack_penalty")?;
            }
            if let Some(v) = o.get("throughput_bonus") {
                cfg.optimizer.throughput_bonus = expect_f64(v, "optimizer.throughput_bonus")?;
            }
            if let Some(v) = o.get("warm_start") {
                cfg.optimizer.warm_start = expect_bool(v, "optimizer.warm_start")?;
            }
            if let Some(v) = o.get("node_selection") {
                let key = expect_str(v, "optimizer.node_selection")?;
                cfg.optimizer.node_selection =
                    crate::ilp::NodeSelection::from_key(key).ok_or_else(|| {
                        anyhow::anyhow!(
                            "config field optimizer.node_selection: unknown strategy {key:?}"
                        )
                    })?;
            }
        }
        if let Some(g) = j.get("gogh") {
            if let Some(v) = g.get("backend") {
                cfg.gogh.backend = BackendKind::from_key(expect_str(v, "gogh.backend")?)
                    .context("config field gogh.backend")?;
            }
            if let Some(v) = g.get("history_jobs") {
                cfg.gogh.history_jobs = expect_usize(v, "gogh.history_jobs")?;
            }
            if let Some(v) = g.get("enable_refinement") {
                cfg.gogh.enable_refinement = expect_bool(v, "gogh.enable_refinement")?;
            }
            if let Some(v) = g.get("exploration_epsilon") {
                cfg.gogh.exploration_epsilon = expect_f64(v, "gogh.exploration_epsilon")?;
            }
            if let Some(v) = g.get("full_resolve_every") {
                cfg.gogh.full_resolve_every =
                    expect_usize(v, "gogh.full_resolve_every")?.max(1);
            }
            if let Some(v) = g.get("neighborhood") {
                cfg.gogh.neighborhood = expect_usize(v, "gogh.neighborhood")?;
            }
            if let Some(v) = g.get("shards") {
                cfg.gogh.shards = expect_usize(v, "gogh.shards")?.max(1);
            }
            if let Some(v) = g.get("topology_groups") {
                cfg.gogh.topology_groups = expect_usize(v, "gogh.topology_groups")?.max(1);
            }
            if let Some(v) = g.get("estimate_cache") {
                cfg.gogh.estimate_cache = expect_bool(v, "gogh.estimate_cache")?;
            }
            if let Some(v) = g.get("p1_candidates") {
                cfg.gogh.p1_candidates = expect_usize(v, "gogh.p1_candidates")?;
            }
            if let Some(v) = g.get("preemption") {
                cfg.gogh.preemption = expect_bool(v, "gogh.preemption")?;
            }
        }
        if let Some(p) = j.get("power") {
            if let Some(v) = p.get("cap_w") {
                cfg.power.cap_w = match v {
                    Json::Null => None,
                    other => Some(expect_f64(other, "power.cap_w")?),
                };
            }
            if let Some(v) = p.get("dvfs") {
                cfg.power.dvfs = expect_bool(v, "power.dvfs")?;
            }
            if let Some(c) = p.get("carbon") {
                if let Some(v) = c.get("base_gco2_per_kwh") {
                    cfg.power.carbon.base_gco2_per_kwh =
                        expect_f64(v, "power.carbon.base_gco2_per_kwh")?;
                }
                if let Some(v) = c.get("amplitude") {
                    cfg.power.carbon.amplitude = expect_f64(v, "power.carbon.amplitude")?;
                }
                if let Some(v) = c.get("phase_s") {
                    cfg.power.carbon.phase_s = expect_f64(v, "power.carbon.phase_s")?;
                }
            }
        }
        if let Some(v) = j.get("monitor_interval_s") {
            cfg.monitor_interval_s = expect_f64(v, "monitor_interval_s")?;
        }
        if let Some(v) = j.get("noise_sigma") {
            cfg.noise_sigma = expect_f64(v, "noise_sigma")?;
        }
        if let Some(v) = j.get("migration_cost_s") {
            cfg.migration_cost_s = expect_f64(v, "migration_cost_s")?;
        }
        if let Some(v) = j.get("seed") {
            cfg.seed = expect_u64(v, "seed")?;
        }
        if let Some(v) = j.get("gavel_csv") {
            cfg.gavel_csv = match v {
                Json::Null => None,
                other => Some(expect_str(other, "gavel_csv")?.to_string()),
            };
        }
        Ok(cfg)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "cluster",
                Json::obj(vec![(
                    "accel_mix",
                    Json::Object(
                        self.cluster
                            .accel_mix
                            .iter()
                            .map(|(a, n)| (a.name().to_string(), Json::from(*n)))
                            .collect(),
                    ),
                )]),
            ),
            (
                "trace",
                Json::obj(vec![
                    ("n_jobs", self.trace.n_jobs.into()),
                    ("mean_interarrival_s", self.trace.mean_interarrival_s.into()),
                    ("mean_work_s", self.trace.mean_work_s.into()),
                    ("slo_fraction", self.trace.slo_fraction.into()),
                    ("max_distributability", self.trace.max_distributability.into()),
                    ("cancel_rate", self.trace.cancel_rate.into()),
                    ("accel_churn", self.trace.accel_churn.into()),
                    ("inference_fraction", self.trace.inference_fraction.into()),
                    ("critical_fraction", self.trace.critical_fraction.into()),
                    ("best_fraction", self.trace.best_fraction.into()),
                    ("elastic_fraction", self.trace.elastic_fraction.into()),
                    ("seed", self.trace.seed.into()),
                ]),
            ),
            (
                "estimator",
                Json::obj(vec![
                    ("p1_arch", self.estimator.p1_arch.key().into()),
                    ("p2_arch", self.estimator.p2_arch.key().into()),
                    ("artifacts_dir", self.estimator.artifacts_dir.as_str().into()),
                    (
                        "online_steps_per_round",
                        self.estimator.online_steps_per_round.into(),
                    ),
                    ("bootstrap_steps", self.estimator.bootstrap_steps.into()),
                    ("replay_capacity", self.estimator.replay_capacity.into()),
                ]),
            ),
            (
                "optimizer",
                Json::obj(vec![
                    ("max_pairs_per_job", self.optimizer.max_pairs_per_job.into()),
                    ("max_nodes", self.optimizer.max_nodes.into()),
                    ("time_limit_s", self.optimizer.time_limit_s.into()),
                    ("slack_penalty", self.optimizer.slack_penalty.into()),
                    ("throughput_bonus", self.optimizer.throughput_bonus.into()),
                    ("warm_start", self.optimizer.warm_start.into()),
                    ("node_selection", self.optimizer.node_selection.key().into()),
                ]),
            ),
            (
                "gogh",
                Json::obj(vec![
                    ("backend", self.gogh.backend.key().into()),
                    ("history_jobs", self.gogh.history_jobs.into()),
                    ("enable_refinement", self.gogh.enable_refinement.into()),
                    ("exploration_epsilon", self.gogh.exploration_epsilon.into()),
                    ("full_resolve_every", self.gogh.full_resolve_every.into()),
                    ("neighborhood", self.gogh.neighborhood.into()),
                    ("shards", self.gogh.shards.into()),
                    ("topology_groups", self.gogh.topology_groups.into()),
                    ("estimate_cache", self.gogh.estimate_cache.into()),
                    ("p1_candidates", self.gogh.p1_candidates.into()),
                    ("preemption", self.gogh.preemption.into()),
                ]),
            ),
            (
                "power",
                Json::obj(vec![
                    ("cap_w", self.power.cap_w.map(Json::from).unwrap_or(Json::Null)),
                    ("dvfs", self.power.dvfs.into()),
                    (
                        "carbon",
                        Json::obj(vec![
                            (
                                "base_gco2_per_kwh",
                                self.power.carbon.base_gco2_per_kwh.into(),
                            ),
                            ("amplitude", self.power.carbon.amplitude.into()),
                            ("phase_s", self.power.carbon.phase_s.into()),
                        ]),
                    ),
                ]),
            ),
            ("monitor_interval_s", self.monitor_interval_s.into()),
            ("noise_sigma", self.noise_sigma.into()),
            ("migration_cost_s", self.migration_cost_s.into()),
            ("seed", self.seed.into()),
            (
                "gavel_csv",
                self.gavel_csv.as_deref().map(Json::from).unwrap_or(Json::Null),
            ),
        ])
    }

    pub fn load(path: &std::path::Path) -> Result<Self> {
        Self::from_json(&std::fs::read_to_string(path)?)
    }

    /// Build the ground-truth oracle this config describes (synthetic,
    /// with real measured overlays when `gavel_csv` is set).
    pub fn build_oracle(&self) -> Result<crate::workload::ThroughputOracle> {
        let oracle = crate::workload::ThroughputOracle::new(self.seed);
        match &self.gavel_csv {
            None => Ok(oracle),
            Some(path) => {
                let table =
                    crate::workload::ThroughputTable::load(std::path::Path::new(path))?;
                Ok(oracle.with_table(table))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_roundtrips_through_json() {
        let cfg = ExperimentConfig::default();
        let text = cfg.to_json().to_string();
        let back = ExperimentConfig::from_json(&text).unwrap();
        assert_eq!(back.estimator.p1_arch, Arch::Rnn);
        assert_eq!(back.cluster.accel_mix.len(), 6);
        assert_eq!(back.monitor_interval_s, cfg.monitor_interval_s);
        assert_eq!(back.trace.n_jobs, cfg.trace.n_jobs);
        assert_eq!(back.optimizer.max_nodes, cfg.optimizer.max_nodes);
    }

    #[test]
    fn partial_config_uses_defaults() {
        let cfg = ExperimentConfig::from_json(r#"{"seed": 42, "trace": {"n_jobs": 7}}"#).unwrap();
        assert_eq!(cfg.seed, 42);
        assert_eq!(cfg.trace.n_jobs, 7);
        assert_eq!(cfg.estimator.p2_arch, Arch::Ff);
    }

    #[test]
    fn arch_keys_match_manifest_names() {
        assert_eq!(Arch::Ff.key(), "ff");
        assert_eq!(Arch::from_key("transformer").unwrap(), Arch::Transformer);
        assert!(Arch::from_key("mlp").is_err());
    }

    #[test]
    fn optimizer_solver_knobs_roundtrip() {
        let mut cfg = ExperimentConfig::default();
        cfg.optimizer.warm_start = false;
        cfg.optimizer.node_selection = crate::ilp::NodeSelection::DepthFirst;
        let back = ExperimentConfig::from_json(&cfg.to_json().to_string()).unwrap();
        assert!(!back.optimizer.warm_start);
        assert_eq!(back.optimizer.node_selection, crate::ilp::NodeSelection::DepthFirst);
        // defaults survive omission; junk strategy names are rejected
        let d = ExperimentConfig::from_json("{}").unwrap();
        assert!(d.optimizer.warm_start);
        assert_eq!(d.optimizer.node_selection, crate::ilp::NodeSelection::BestBound);
        assert!(
            ExperimentConfig::from_json(r#"{"optimizer": {"node_selection": "bogus"}}"#).is_err()
        );
    }

    #[test]
    fn type_mismatch_names_the_field_path() {
        let err = ExperimentConfig::from_json(r#"{"trace": {"n_jobs": "many"}}"#).unwrap_err();
        assert!(err.to_string().contains("trace.n_jobs"), "{err}");
        let err = ExperimentConfig::from_json(r#"{"gogh": {"shards": true}}"#).unwrap_err();
        assert!(err.to_string().contains("gogh.shards"), "{err}");
        let err = ExperimentConfig::from_json(r#"{"optimizer": {"warm_start": 3}}"#).unwrap_err();
        assert!(err.to_string().contains("optimizer.warm_start"), "{err}");
        let err = ExperimentConfig::from_json(r#"{"gogh": {"backend": "tpu"}}"#).unwrap_err();
        assert!(err.to_string().contains("gogh.backend"), "{err}");
        let err =
            ExperimentConfig::from_json(r#"{"cluster": {"accel_mix": {"v100": "two"}}}"#)
                .unwrap_err();
        assert!(err.to_string().contains("cluster.accel_mix.v100"), "{err}");
    }

    #[test]
    fn parse_failure_names_line_and_column() {
        let err = ExperimentConfig::from_json("{\n  \"seed\": }\n").unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("invalid config JSON"), "{msg}");
        assert!(msg.contains("line 2"), "{msg}");
    }

    #[test]
    fn bad_accel_name_is_error() {
        assert!(
            ExperimentConfig::from_json(r#"{"cluster": {"accel_mix": {"h100": 2}}}"#).is_err()
        );
    }

    #[test]
    fn backend_kind_roundtrips_and_rejects_junk() {
        let mut cfg = ExperimentConfig::default();
        assert_eq!(cfg.gogh.backend, BackendKind::Auto);
        cfg.gogh.backend = BackendKind::Native;
        let back = ExperimentConfig::from_json(&cfg.to_json().to_string()).unwrap();
        assert_eq!(back.gogh.backend, BackendKind::Native);
        for (key, kind) in [
            ("auto", BackendKind::Auto),
            ("pjrt", BackendKind::Pjrt),
            ("native", BackendKind::Native),
            ("none", BackendKind::None),
        ] {
            assert_eq!(BackendKind::from_key(key).unwrap(), kind);
            assert_eq!(kind.key(), key);
            let j = format!(r#"{{"gogh": {{"backend": "{key}"}}}}"#);
            assert_eq!(ExperimentConfig::from_json(&j).unwrap().gogh.backend, kind);
        }
        assert!(BackendKind::from_key("tpu").is_err());
        assert!(ExperimentConfig::from_json(r#"{"gogh": {"backend": "tpu"}}"#).is_err());
        // omission keeps the auto ladder
        let d = ExperimentConfig::from_json("{}").unwrap();
        assert_eq!(d.gogh.backend, BackendKind::Auto);
    }

    #[test]
    fn gogh_policy_knobs_roundtrip() {
        let mut cfg = ExperimentConfig::default();
        cfg.gogh.history_jobs = 7;
        cfg.gogh.enable_refinement = false;
        cfg.gogh.exploration_epsilon = 0.25;
        cfg.gogh.full_resolve_every = 3;
        cfg.gogh.neighborhood = 2;
        cfg.gogh.shards = 6;
        cfg.gogh.estimate_cache = false;
        cfg.gogh.p1_candidates = 12;
        cfg.migration_cost_s = 45.0;
        cfg.trace.cancel_rate = 0.2;
        cfg.trace.accel_churn = 1.5;
        let back = ExperimentConfig::from_json(&cfg.to_json().to_string()).unwrap();
        assert_eq!(back.gogh.history_jobs, 7);
        assert!(!back.gogh.enable_refinement);
        assert_eq!(back.gogh.exploration_epsilon, 0.25);
        assert_eq!(back.gogh.full_resolve_every, 3);
        assert_eq!(back.gogh.neighborhood, 2);
        assert_eq!(back.gogh.shards, 6);
        assert!(!back.gogh.estimate_cache);
        assert_eq!(back.gogh.p1_candidates, 12);
        assert_eq!(back.migration_cost_s, 45.0);
        assert_eq!(back.trace.cancel_rate, 0.2);
        assert_eq!(back.trace.accel_churn, 1.5);
        // defaults survive omission
        let d = ExperimentConfig::from_json("{}").unwrap();
        assert_eq!(d.gogh.history_jobs, 24);
        assert!(d.gogh.enable_refinement);
        assert_eq!(d.gogh.exploration_epsilon, 0.0);
        assert_eq!(d.gogh.full_resolve_every, 8);
        assert_eq!(d.migration_cost_s, 0.0);
        assert_eq!(d.trace.cancel_rate, 0.0);
        // full_resolve_every is clamped to ≥ 1 (0 would never re-solve)
        let z = ExperimentConfig::from_json(r#"{"gogh": {"full_resolve_every": 0}}"#).unwrap();
        assert_eq!(z.gogh.full_resolve_every, 1);
        // shards clamp to ≥ 1, defaults keep the unsharded path + cache
        let z = ExperimentConfig::from_json(r#"{"gogh": {"shards": 0}}"#).unwrap();
        assert_eq!(z.gogh.shards, 1);
        assert_eq!(d.gogh.shards, 1);
        assert!(d.gogh.estimate_cache);
        assert_eq!(d.gogh.p1_candidates, 0);
    }

    #[test]
    fn power_knobs_roundtrip_and_presets_resolve() {
        let mut cfg = ExperimentConfig::default();
        assert_eq!(cfg.power.cap_w, None);
        assert!(!cfg.power.dvfs);
        assert!(cfg.power.carbon.signal().is_none());
        cfg.power.cap_w = Some(900.0);
        cfg.power.dvfs = true;
        cfg.power.carbon.base_gco2_per_kwh = 300.0;
        cfg.power.carbon.amplitude = 0.2;
        cfg.power.carbon.phase_s = 3600.0;
        let back = ExperimentConfig::from_json(&cfg.to_json().to_string()).unwrap();
        assert_eq!(back.power.cap_w, Some(900.0));
        assert!(back.power.dvfs);
        let sig = back.power.carbon.signal().unwrap();
        assert_eq!(sig.base_gco2_per_kwh, 300.0);
        assert_eq!(sig.amplitude, 0.2);
        assert_eq!(sig.phase_s, 3600.0);
        // omission keeps power management entirely off
        let d = ExperimentConfig::from_json("{}").unwrap();
        assert_eq!(d.power.cap_w, None);
        assert!(!d.power.dvfs);
        assert!(d.power.carbon.signal().is_none());
        // explicit null lifts a cap set earlier in the overlay chain
        let n = ExperimentConfig::from_json(r#"{"power": {"cap_w": null}}"#).unwrap();
        assert_eq!(n.power.cap_w, None);
        // type mismatches name the dotted path
        let err = ExperimentConfig::from_json(r#"{"power": {"cap_w": "big"}}"#).unwrap_err();
        assert!(err.to_string().contains("power.cap_w"), "{err}");
        // presets
        let p = ExperimentConfig::preset("powercap").unwrap();
        assert_eq!(p.power.cap_w, Some(1200.0));
        assert!(p.power.dvfs);
        let c = ExperimentConfig::preset("carbon").unwrap();
        assert!(c.power.dvfs);
        assert!(c.power.carbon.signal().is_some());
        let back = ExperimentConfig::from_json(&p.to_json().to_string()).unwrap();
        assert_eq!(back.power.cap_w, Some(1200.0));
        assert!(back.power.dvfs);
    }

    #[test]
    fn carbon_trace_file_parses_and_validates() {
        let c = CarbonConfig::from_json(r#"{"base_gco2_per_kwh": 420.0, "amplitude": 0.35}"#)
            .unwrap();
        assert_eq!(c.base_gco2_per_kwh, 420.0);
        assert_eq!(c.amplitude, 0.35);
        assert_eq!(c.phase_s, 0.0);
        assert!(c.signal().is_some());
        // base is required in the file form; junk is a parse error
        assert!(CarbonConfig::from_json(r#"{"amplitude": 0.35}"#).is_err());
        assert!(CarbonConfig::from_json("not json").is_err());
    }

    #[test]
    fn inference_fraction_roundtrips_and_clamps() {
        let mut cfg = ExperimentConfig::default();
        assert_eq!(cfg.trace.inference_fraction, 0.0);
        cfg.trace.inference_fraction = 0.35;
        let back = ExperimentConfig::from_json(&cfg.to_json().to_string()).unwrap();
        assert_eq!(back.trace.inference_fraction, 0.35);
        let j = r#"{"trace": {"inference_fraction": 7.0}}"#;
        assert_eq!(ExperimentConfig::from_json(j).unwrap().trace.inference_fraction, 1.0);
        // omission keeps training-only
        let d = ExperimentConfig::from_json("{}").unwrap();
        assert_eq!(d.trace.inference_fraction, 0.0);
    }

    #[test]
    fn mixed_and_serving_presets_resolve() {
        let m = ExperimentConfig::preset("mixed").unwrap();
        assert!(m.trace.inference_fraction > 0.0);
        let total: u32 = m.cluster.accel_mix.iter().map(|(_, n)| n).sum();
        assert_eq!(total, 48);
        let back = ExperimentConfig::from_json(&m.to_json().to_string()).unwrap();
        assert_eq!(back.trace.inference_fraction, m.trace.inference_fraction);
        let s = ExperimentConfig::preset("serving").unwrap();
        assert!(s.trace.inference_fraction > m.trace.inference_fraction);
        // training presets stay training-only
        assert_eq!(ExperimentConfig::preset("large").unwrap().trace.inference_fraction, 0.0);
    }

    #[test]
    fn priority_knobs_roundtrip_and_presets_resolve() {
        let mut cfg = ExperimentConfig::default();
        assert!(!cfg.gogh.preemption);
        assert_eq!(cfg.trace.critical_fraction, 0.0);
        assert_eq!(cfg.trace.best_fraction, 0.0);
        assert_eq!(cfg.trace.elastic_fraction, 0.0);
        cfg.gogh.preemption = true;
        cfg.trace.critical_fraction = 0.2;
        cfg.trace.best_fraction = 0.3;
        cfg.trace.elastic_fraction = 0.4;
        let back = ExperimentConfig::from_json(&cfg.to_json().to_string()).unwrap();
        assert!(back.gogh.preemption);
        assert_eq!(back.trace.critical_fraction, 0.2);
        assert_eq!(back.trace.best_fraction, 0.3);
        assert_eq!(back.trace.elastic_fraction, 0.4);
        // omission keeps the pre-priority behaviour entirely off
        let d = ExperimentConfig::from_json("{}").unwrap();
        assert!(!d.gogh.preemption);
        assert_eq!(d.trace.critical_fraction, 0.0);
        // fractions clamp; type mismatches name the dotted path
        let j = r#"{"trace": {"critical_fraction": 9.0}}"#;
        assert_eq!(ExperimentConfig::from_json(j).unwrap().trace.critical_fraction, 1.0);
        let err = ExperimentConfig::from_json(r#"{"gogh": {"preemption": 3}}"#).unwrap_err();
        assert!(err.to_string().contains("gogh.preemption"), "{err}");
        // presets
        for (name, seed) in [("priority", 93), ("burst", 94), ("contended", 95)] {
            let p = ExperimentConfig::preset(name).unwrap();
            assert_eq!(p.seed, seed, "{name}");
            assert!(p.gogh.preemption, "{name}");
            assert!(p.trace.critical_fraction > 0.0 && p.trace.best_fraction > 0.0, "{name}");
            let back = ExperimentConfig::from_json(&p.to_json().to_string()).unwrap();
            assert_eq!(back.trace.critical_fraction, p.trace.critical_fraction);
            assert!(back.gogh.preemption);
        }
        assert!(ExperimentConfig::preset("burst").unwrap().trace.mean_interarrival_s < 6.0);
    }

    #[test]
    fn large_preset_is_cluster_scale_and_roundtrips() {
        let cfg = ExperimentConfig::preset("large").unwrap();
        let total: u32 = cfg.cluster.accel_mix.iter().map(|(_, n)| n).sum();
        assert!(total >= 1024, "large preset has only {total} accels");
        assert!(cfg.trace.n_jobs >= 40_000);
        assert_eq!(cfg.gogh.shards, 4);
        assert!(cfg.gogh.p1_candidates > 0);
        let back = ExperimentConfig::from_json(&cfg.to_json().to_string()).unwrap();
        assert_eq!(back.gogh.shards, cfg.gogh.shards);
        assert_eq!(back.trace.n_jobs, cfg.trace.n_jobs);
        assert_eq!(ExperimentConfig::preset("default").unwrap().gogh.shards, 1);
    }

    #[test]
    fn huge_preset_is_fleet_scale_and_topology_groups_roundtrip() {
        let cfg = ExperimentConfig::preset("huge").unwrap();
        let total: u32 = cfg.cluster.accel_mix.iter().map(|(_, n)| n).sum();
        assert!(total >= 10_000, "huge preset has only {total} accels");
        assert!(cfg.trace.n_jobs >= 500_000);
        assert!(cfg.gogh.topology_groups > 1, "huge must route hierarchically");
        assert!(cfg.gogh.shards > 1);
        let back = ExperimentConfig::from_json(&cfg.to_json().to_string()).unwrap();
        assert_eq!(back.gogh.topology_groups, cfg.gogh.topology_groups);
        assert_eq!(back.trace.n_jobs, cfg.trace.n_jobs);
        // depth-1 default + clamp semantics match `shards`
        assert_eq!(ExperimentConfig::default().gogh.topology_groups, 1);
        let z = ExperimentConfig::from_json(r#"{"gogh": {"topology_groups": 0}}"#).unwrap();
        assert_eq!(z.gogh.topology_groups, 1);
        let err =
            ExperimentConfig::from_json(r#"{"gogh": {"topology_groups": true}}"#).unwrap_err();
        assert!(err.to_string().contains("gogh.topology_groups"), "{err}");
    }
}
