//! `goghd` — the long-lived GOGH scheduling daemon.
//!
//! Listens on a TCP or Unix socket for newline-delimited JSON requests
//! (`docs/PROTOCOL.md`), schedules submitted jobs with the same policy
//! core the simulator uses, and checkpoints its state — including the
//! learned throughput catalog — to a snapshot file (`docs/SNAPSHOT.md`)
//! so a restart resumes where it left off.

use gogh::config::{BackendKind, CarbonConfig, ExperimentConfig};
use gogh::daemon::{serve, DaemonOptions, Endpoint};
use gogh::util::Args;
use gogh::Result;

const USAGE: &str = "goghd — long-lived GOGH scheduling daemon

USAGE:
  goghd [--config cfg.json | --preset default|large|mixed|serving|powercap|carbon]
        [--backend auto|pjrt|native|none] [--seed S] [--gavel-csv data.csv]
        [--addr HOST:PORT | --socket PATH] [--port-file PATH]
        [--state snapshot.json] [--snapshot-every SECONDS] [--fresh]
        [--time-scale X] [--power-cap W] [--power-dvfs true|false]
        [--carbon-trace signal.json]

Defaults: --addr 127.0.0.1:7411, --snapshot-every 30, --time-scale 1.
Use `--addr 127.0.0.1:0 --port-file p.txt` for an ephemeral port.
Submit work with the `gogh submit|queue|cancel|status|drain` client
subcommands, or speak the one-line-JSON protocol directly over nc.
";

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.iter().any(|a| a == "--help" || a == "-h" || a == "help") {
        print!("{USAGE}");
        return Ok(());
    }
    let args = Args::parse(&argv);

    let mut cfg = match (args.get("config"), args.get("preset")) {
        (Some(_), Some(_)) => anyhow::bail!("--config and --preset are mutually exclusive"),
        (Some(p), None) => ExperimentConfig::load(std::path::Path::new(p))?,
        (None, Some(name)) => ExperimentConfig::preset(name)?,
        (None, None) => ExperimentConfig::default(),
    };
    if let Some(b) = args.get("backend") {
        cfg.gogh.backend = BackendKind::from_key(b)?;
    }
    if let Some(s) = args.get_parse::<u64>("seed") {
        cfg.seed = s;
    }
    if let Some(p) = args.get("gavel-csv") {
        cfg.gavel_csv = Some(p.to_string());
    }
    if let Some(w) = args.get_parse::<f64>("power-cap") {
        cfg.power.cap_w = Some(w);
    }
    if let Some(d) = args.get_parse::<bool>("power-dvfs") {
        cfg.power.dvfs = d;
    }
    if let Some(p) = args.get("carbon-trace") {
        let text = std::fs::read_to_string(p).map_err(|e| anyhow::anyhow!("{p}: {e}"))?;
        cfg.power.carbon =
            CarbonConfig::from_json(&text).map_err(|e| anyhow::anyhow!("{p}: {e}"))?;
    }

    let endpoint = match (args.get("socket"), args.get("addr")) {
        (Some(_), Some(_)) => anyhow::bail!("--socket and --addr are mutually exclusive"),
        (Some(path), None) => Endpoint::Unix(path.into()),
        (None, addr) => Endpoint::Tcp(addr.unwrap_or("127.0.0.1:7411").to_string()),
    };

    serve(DaemonOptions {
        cfg,
        endpoint,
        state: args.get("state").map(Into::into),
        snapshot_every_s: args.get_parse("snapshot-every").unwrap_or(30.0),
        time_scale: args.get_parse("time-scale").unwrap_or(1.0),
        port_file: args.get("port-file").map(Into::into),
        fresh: args.has("fresh"),
    })
}
