//! `gogh-lint` — the project-invariant static-analysis pass
//! (docs/LINTS.md): determinism, panic-safety, protocol-evolution and
//! RNG-discipline rules that clippy cannot express.
//!
//! Usage: `cargo run --bin gogh_lint -- [PATH …]` (default `rust/src`).
//! Prints `file:line: rule: message` per finding and exits nonzero if
//! any. `--list-rules` prints the rule table (consumed by the
//! docs-freshness CI check).

#![deny(unsafe_code)]

use std::path::Path;
use std::process::ExitCode;

use gogh::lint::{check_tree, RULES};

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.iter().any(|a| a == "--list-rules") {
        for r in RULES {
            println!("{}: {}", r.name, r.summary);
        }
        return ExitCode::SUCCESS;
    }
    let roots: Vec<&str> = if args.is_empty() {
        vec!["rust/src"]
    } else {
        args.iter().map(String::as_str).collect()
    };
    let mut total = 0usize;
    for root in roots {
        match check_tree(Path::new(root)) {
            Ok(violations) => {
                for v in &violations {
                    println!("{v}");
                }
                total += violations.len();
            }
            Err(e) => {
                eprintln!("gogh-lint: {e}");
                return ExitCode::FAILURE;
            }
        }
    }
    if total > 0 {
        eprintln!("gogh-lint: {total} violation(s)");
        ExitCode::FAILURE
    } else {
        println!("gogh-lint: clean");
        ExitCode::SUCCESS
    }
}
