//! Nearest-neighbour job similarity over Ψ vectors (paper §2.3: "GOGH
//! retrieves the most similar previously seen job from the Catalog —
//! based on feature similarity").
//!
//! The index is a flat scan over the registered jobs' Ψ vectors with
//! squared-L2 distance — exact, deterministic, and fast at the catalog
//! sizes a cluster accumulates (thousands); the hotpath bench measures
//! it, and at larger scales the scan is trivially replaceable by a KD
//! tree behind the same API.

use crate::workload::encoding::{psi_distance, PSI_DIM};
use crate::workload::JobId;

use super::store::Catalog;

/// Similarity queries over the Catalog's job registry.
pub struct SimilarityIndex<'a> {
    catalog: &'a Catalog,
}

impl<'a> SimilarityIndex<'a> {
    pub fn new(catalog: &'a Catalog) -> Self {
        Self { catalog }
    }

    /// Most similar known job to `psi`, excluding the ids in `exclude`
    /// (typically the query job itself). Requires the candidate to have
    /// at least one *measured* record if `require_measured` — P1's Eq. 1
    /// needs real throughput history for j2.
    pub fn most_similar(
        &self,
        psi: &[f32; PSI_DIM],
        exclude: &[JobId],
        require_measured: bool,
    ) -> Option<JobId> {
        let mut best: Option<(f32, JobId)> = None;
        let mut ids: Vec<JobId> = self.catalog.known_jobs().copied().collect();
        ids.sort(); // deterministic tie-breaking
        for id in ids {
            if exclude.contains(&id) {
                continue;
            }
            if require_measured && !self.catalog.has_measurements(id) {
                continue;
            }
            let d = psi_distance(psi, self.catalog.psi(id).unwrap());
            if best.map_or(true, |(bd, _)| d < bd) {
                best = Some((d, id));
            }
        }
        best.map(|(_, id)| id)
    }

    /// Top-k most similar jobs (for the ensemble ablation).
    pub fn top_k(&self, psi: &[f32; PSI_DIM], exclude: &[JobId], k: usize) -> Vec<JobId> {
        let mut scored: Vec<(f32, JobId)> = self
            .catalog
            .known_jobs()
            .filter(|id| !exclude.contains(id))
            .map(|id| (psi_distance(psi, self.catalog.psi(*id).unwrap()), *id))
            .collect();
        scored.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
        scored.into_iter().take(k).map(|(_, id)| id).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::catalog::store::EstimateKey;
    use crate::workload::{encoding::psi, AccelType, Combo, ModelFamily};

    fn setup() -> Catalog {
        let mut c = Catalog::new();
        c.register_job(JobId(1), psi(ModelFamily::ResNet18, 32, 1));
        c.register_job(JobId(2), psi(ModelFamily::ResNet18, 64, 1));
        c.register_job(JobId(3), psi(ModelFamily::Recommendation, 2048, 1));
        for j in [1, 2, 3] {
            c.record_measurement(
                EstimateKey {
                    accel: AccelType::K80,
                    job: JobId(j),
                    combo: Combo::Solo(JobId(j)),
                },
                0.5,
            );
        }
        c
    }

    #[test]
    fn finds_same_family_neighbour() {
        let c = setup();
        let idx = SimilarityIndex::new(&c);
        let q = psi(ModelFamily::ResNet18, 32, 1);
        // exclude exact-match job 1 → job 2 (same family) must win over 3
        assert_eq!(idx.most_similar(&q, &[JobId(1)], true), Some(JobId(2)));
    }

    #[test]
    fn exact_match_wins() {
        let c = setup();
        let idx = SimilarityIndex::new(&c);
        let q = psi(ModelFamily::ResNet18, 32, 1);
        assert_eq!(idx.most_similar(&q, &[], true), Some(JobId(1)));
    }

    #[test]
    fn require_measured_filters() {
        let mut c = setup();
        c.register_job(JobId(4), psi(ModelFamily::Recommendation, 2048, 1));
        let idx = SimilarityIndex::new(&c);
        let q = psi(ModelFamily::Recommendation, 2048, 1);
        // job 4 is an exact match but has no measurements → skipped when
        // measurements are required, chosen otherwise.
        assert_ne!(idx.most_similar(&q, &[JobId(3)], true), Some(JobId(4)));
        assert_eq!(idx.most_similar(&q, &[JobId(3)], false), Some(JobId(4)));
    }

    #[test]
    fn top_k_ordering() {
        let c = setup();
        let idx = SimilarityIndex::new(&c);
        let q = psi(ModelFamily::ResNet18, 32, 1);
        let top = idx.top_k(&q, &[], 2);
        assert_eq!(top, vec![JobId(1), JobId(2)]);
    }

    #[test]
    fn empty_catalog_returns_none() {
        let c = Catalog::new();
        let idx = SimilarityIndex::new(&c);
        let q = psi(ModelFamily::ResNet18, 32, 1);
        assert_eq!(idx.most_similar(&q, &[], false), None);
    }
}
