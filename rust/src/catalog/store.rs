//! Throughput estimate store.
//!
//! For every (accelerator type, job, combination) the Catalog keeps:
//!  * the latest *measurement* (if the combo ever ran on that type), and
//!  * the refinement set 𝒯^c_{a,j} (Eq. 4): every estimate produced by
//!    P1 (round 0) or P2 (rounds i ≥ 1), whose running average is the
//!    current estimate T̃^c_{a,j}.
//!
//! Measurements always dominate estimates for the same key (the paper's
//! "measured or estimated" precedence in §2.4).

use std::collections::HashMap;

use crate::util::Json;
use crate::workload::{AccelType, Combo, JobId};

/// Key of one throughput record.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct EstimateKey {
    pub accel: AccelType,
    pub job: JobId,
    pub combo: Combo,
}

/// One record: refinement set + running mean + optional measurement.
#[derive(Debug, Clone, Default)]
pub struct Record {
    /// Σ of refinement-set values (Eq. 4 numerator).
    sum: f64,
    /// |𝒯| (Eq. 4 denominator).
    count: u32,
    /// latest measured throughput, if any.
    measured: Option<f64>,
    /// round index of the last update (0 = P1 initial).
    pub last_round: u32,
}

impl Record {
    /// Current estimate: measurement wins; otherwise the 𝒯-average.
    pub fn value(&self) -> Option<f64> {
        if let Some(m) = self.measured {
            return Some(m);
        }
        (self.count > 0).then(|| self.sum / self.count as f64)
    }

    pub fn estimate_only(&self) -> Option<f64> {
        (self.count > 0).then(|| self.sum / self.count as f64)
    }

    pub fn is_measured(&self) -> bool {
        self.measured.is_some()
    }

    pub fn refinements(&self) -> u32 {
        self.count
    }
}

/// The Catalog.
#[derive(Debug, Clone, Default)]
pub struct Catalog {
    records: HashMap<EstimateKey, Record>,
    /// Ψ vectors of every job ever seen (for similarity lookups, the
    /// paper's "historical data from previously executed jobs").
    psis: HashMap<JobId, [f32; crate::workload::PSI_DIM]>,
}

impl Catalog {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a job's attribute vector.
    pub fn register_job(&mut self, j: JobId, psi: [f32; crate::workload::PSI_DIM]) {
        self.psis.insert(j, psi);
    }

    pub fn psi(&self, j: JobId) -> Option<&[f32; crate::workload::PSI_DIM]> {
        self.psis.get(&j)
    }

    pub fn known_jobs(&self) -> impl Iterator<Item = &JobId> {
        self.psis.keys()
    }

    /// Record an initial P1 estimate (round 0): starts a fresh
    /// refinement set for the key.
    pub fn write_initial(&mut self, key: EstimateKey, value: f64) {
        let r = self.records.entry(key).or_default();
        r.sum = value;
        r.count = 1;
        r.last_round = 0;
    }

    /// Push a P2 refinement into 𝒯 (Eq. 4): the estimate becomes the
    /// running average of all refinements.
    pub fn push_refinement(&mut self, key: EstimateKey, value: f64, round: u32) {
        let r = self.records.entry(key).or_default();
        r.sum += value;
        r.count += 1;
        r.last_round = r.last_round.max(round);
    }

    /// Record a measurement (dominates estimates for this key).
    pub fn record_measurement(&mut self, key: EstimateKey, value: f64) {
        let r = self.records.entry(key).or_default();
        r.measured = Some(value);
    }

    /// Current value (measured > averaged estimate > None).
    pub fn value(&self, key: &EstimateKey) -> Option<f64> {
        self.records.get(key).and_then(|r| r.value())
    }

    pub fn record(&self, key: &EstimateKey) -> Option<&Record> {
        self.records.get(key)
    }

    /// All measured (accel, combo) pairs involving `j` — the historical
    /// co-location evidence P1's Eq. 1 inputs are drawn from.
    pub fn measured_records_of(&self, j: JobId) -> Vec<(EstimateKey, f64)> {
        let mut v: Vec<(EstimateKey, f64)> = self
            .records
            .iter()
            .filter(|(k, r)| k.job == j && r.is_measured())
            .map(|(k, r)| (*k, r.value().unwrap()))
            .collect();
        v.sort_by_key(|(k, _)| (k.accel.index(), k.combo));
        v
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Number of measured records (diagnostics).
    pub fn n_measured(&self) -> usize {
        self.records.values().filter(|r| r.is_measured()).count()
    }

    // -- persistence ----------------------------------------------------
    //
    // A deployed catalog is the cluster's accumulated knowledge; GOGH
    // checkpoints it across restarts (`gogh simulate --catalog c.json`).

    fn combo_json(c: &Combo) -> Json {
        match c {
            Combo::Solo(j) => Json::Array(vec![Json::from(j.0)]),
            Combo::Pair(a, b) => Json::Array(vec![Json::from(a.0), Json::from(b.0)]),
        }
    }

    fn combo_from_json(v: &Json) -> crate::Result<Combo> {
        let arr = v
            .as_array()
            .ok_or_else(|| anyhow::anyhow!("combo must be an array"))?;
        match arr {
            [a] => Ok(Combo::Solo(JobId(a.as_u64().unwrap_or(0) as u32))),
            [a, b] => Ok(Combo::pair(
                JobId(a.as_u64().unwrap_or(0) as u32),
                JobId(b.as_u64().unwrap_or(0) as u32),
            )),
            _ => anyhow::bail!("combo arity {} unsupported", arr.len()),
        }
    }

    /// Serialize the full catalog (records + Ψ registry) to JSON.
    pub fn to_json(&self) -> Json {
        let mut jobs: Vec<(String, Json)> = self
            .psis
            .iter()
            .map(|(j, psi)| {
                (
                    j.0.to_string(),
                    Json::Array(psi.iter().map(|&x| Json::Num(x as f64)).collect()),
                )
            })
            .collect();
        jobs.sort_by(|a, b| a.0.parse::<u32>().unwrap().cmp(&b.0.parse::<u32>().unwrap()));
        let mut recs: Vec<Json> = vec![];
        let mut keys: Vec<&EstimateKey> = self.records.keys().collect();
        keys.sort_by_key(|k| (k.accel.index(), k.job, k.combo));
        for k in keys {
            let r = &self.records[k];
            let mut fields = vec![
                ("accel", Json::from(k.accel.name())),
                ("job", Json::from(k.job.0)),
                ("combo", Self::combo_json(&k.combo)),
                ("sum", Json::Num(r.sum)),
                ("count", Json::from(r.count)),
                ("last_round", Json::from(r.last_round)),
            ];
            if let Some(m) = r.measured {
                fields.push(("measured", Json::Num(m)));
            }
            recs.push(Json::obj(fields));
        }
        Json::obj(vec![
            ("version", Json::from(1u32)),
            ("jobs", Json::Object(jobs)),
            ("records", Json::Array(recs)),
        ])
    }

    /// Restore a catalog serialized by [`Catalog::to_json`].
    pub fn from_json(v: &Json) -> crate::Result<Self> {
        anyhow::ensure!(v.req_f64("version")? as u32 == 1, "catalog version");
        let mut c = Catalog::new();
        for (id, psi_v) in v
            .req("jobs")?
            .as_object()
            .ok_or_else(|| anyhow::anyhow!("jobs must be an object"))?
        {
            let arr = psi_v
                .as_array()
                .ok_or_else(|| anyhow::anyhow!("psi must be an array"))?;
            anyhow::ensure!(arr.len() == crate::workload::PSI_DIM, "psi width");
            let mut psi = [0.0f32; crate::workload::PSI_DIM];
            for (i, x) in arr.iter().enumerate() {
                psi[i] = x.as_f64().unwrap_or(0.0) as f32;
            }
            c.register_job(JobId(id.parse()?), psi);
        }
        for rec in v
            .req("records")?
            .as_array()
            .ok_or_else(|| anyhow::anyhow!("records must be an array"))?
        {
            let accel_name = rec.req_str("accel")?;
            let accel = crate::workload::ACCEL_TYPES
                .iter()
                .copied()
                .find(|a| a.name() == accel_name)
                .ok_or_else(|| anyhow::anyhow!("unknown accel {accel_name}"))?;
            let key = EstimateKey {
                accel,
                job: JobId(rec.req_f64("job")? as u32),
                combo: Self::combo_from_json(rec.req("combo")?)?,
            };
            let r = c.records.entry(key).or_default();
            r.sum = rec.req_f64("sum")?;
            r.count = rec.req_f64("count")? as u32;
            r.last_round = rec.req_f64("last_round")? as u32;
            r.measured = rec.get("measured").and_then(|m| m.as_f64());
        }
        Ok(c)
    }

    pub fn save(&self, path: &std::path::Path) -> crate::Result<()> {
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }

    pub fn load(path: &std::path::Path) -> crate::Result<Self> {
        Self::from_json(&Json::parse(&std::fs::read_to_string(path)?)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(a: AccelType, j: u32) -> EstimateKey {
        EstimateKey {
            accel: a,
            job: JobId(j),
            combo: Combo::Solo(JobId(j)),
        }
    }

    #[test]
    fn eq4_running_average() {
        let mut c = Catalog::new();
        let k = key(AccelType::K80, 1);
        c.write_initial(k, 0.4);
        assert_eq!(c.value(&k), Some(0.4));
        c.push_refinement(k, 0.6, 1);
        assert!((c.value(&k).unwrap() - 0.5).abs() < 1e-12);
        c.push_refinement(k, 0.8, 2);
        assert!((c.value(&k).unwrap() - 0.6).abs() < 1e-12);
        assert_eq!(c.record(&k).unwrap().refinements(), 3);
    }

    #[test]
    fn measurement_dominates_estimates() {
        let mut c = Catalog::new();
        let k = key(AccelType::V100, 2);
        c.write_initial(k, 0.3);
        c.record_measurement(k, 0.9);
        assert_eq!(c.value(&k), Some(0.9));
        // refinements keep accumulating but don't override the measurement
        c.push_refinement(k, 0.1, 1);
        assert_eq!(c.value(&k), Some(0.9));
        assert_eq!(c.record(&k).unwrap().estimate_only(), Some(0.2));
    }

    #[test]
    fn write_initial_resets_refinement_set() {
        let mut c = Catalog::new();
        let k = key(AccelType::P100, 3);
        c.push_refinement(k, 1.0, 1);
        c.push_refinement(k, 0.0, 2);
        c.write_initial(k, 0.5);
        assert_eq!(c.value(&k), Some(0.5));
        assert_eq!(c.record(&k).unwrap().refinements(), 1);
    }

    #[test]
    fn measured_records_filtering() {
        let mut c = Catalog::new();
        let k1 = key(AccelType::K80, 1);
        let k2 = EstimateKey {
            accel: AccelType::V100,
            job: JobId(1),
            combo: Combo::pair(JobId(1), JobId(2)),
        };
        c.write_initial(k1, 0.4); // estimate only
        c.record_measurement(k2, 0.7);
        let recs = c.measured_records_of(JobId(1));
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].0, k2);
    }

    #[test]
    fn unknown_key_is_none() {
        let c = Catalog::new();
        assert_eq!(c.value(&key(AccelType::K80, 9)), None);
    }

    #[test]
    fn json_persistence_roundtrip() {
        let mut c = Catalog::new();
        c.register_job(JobId(1), [0.5; crate::workload::PSI_DIM]);
        c.register_job(JobId(2), [0.25; crate::workload::PSI_DIM]);
        let k1 = key(AccelType::K80, 1);
        let k2 = EstimateKey {
            accel: AccelType::V100,
            job: JobId(1),
            combo: Combo::pair(JobId(1), JobId(2)),
        };
        c.write_initial(k1, 0.4);
        c.push_refinement(k1, 0.6, 3);
        c.record_measurement(k2, 0.77);
        let back = Catalog::from_json(&c.to_json()).unwrap();
        assert_eq!(back.len(), c.len());
        assert_eq!(back.value(&k1), c.value(&k1));
        assert_eq!(back.value(&k2), Some(0.77));
        assert_eq!(back.record(&k1).unwrap().refinements(), 2);
        assert_eq!(back.record(&k1).unwrap().last_round, 3);
        assert_eq!(back.psi(JobId(2)), c.psi(JobId(2)));
        // serialization is deterministic
        assert_eq!(c.to_json().to_string(), back.to_json().to_string());
    }
}
