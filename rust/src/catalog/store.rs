//! Throughput estimate store.
//!
//! For every (accelerator type, job, combination) the Catalog keeps:
//!  * the latest *measurement* (if the combo ever ran on that type), and
//!  * the refinement set 𝒯^c_{a,j} (Eq. 4): every estimate produced by
//!    P1 (round 0) or P2 (rounds i ≥ 1), whose running average is the
//!    current estimate T̃^c_{a,j}.
//!
//! Measurements always dominate estimates for the same key (the paper's
//! "measured or estimated" precedence in §2.4).

use std::collections::HashMap;

use crate::util::Json;
use crate::workload::{AccelType, Combo, JobId};

/// Key of one throughput record.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct EstimateKey {
    pub accel: AccelType,
    pub job: JobId,
    pub combo: Combo,
}

/// One record: refinement set + running mean + optional measurement.
#[derive(Debug, Clone, Default)]
pub struct Record {
    /// Σ of refinement-set values (Eq. 4 numerator).
    sum: f64,
    /// |𝒯| (Eq. 4 denominator).
    count: u32,
    /// latest measured throughput, if any.
    measured: Option<f64>,
    /// round index of the last update (0 = P1 initial).
    pub last_round: u32,
}

impl Record {
    /// Current estimate: measurement wins; otherwise the 𝒯-average.
    pub fn value(&self) -> Option<f64> {
        if let Some(m) = self.measured {
            return Some(m);
        }
        (self.count > 0).then(|| self.sum / self.count as f64)
    }

    pub fn estimate_only(&self) -> Option<f64> {
        (self.count > 0).then(|| self.sum / self.count as f64)
    }

    pub fn is_measured(&self) -> bool {
        self.measured.is_some()
    }

    pub fn refinements(&self) -> u32 {
        self.count
    }
}

/// The Catalog.
#[derive(Debug, Clone, Default)]
pub struct Catalog {
    records: HashMap<EstimateKey, Record>,
    /// Ψ vectors of every job ever seen (for similarity lookups, the
    /// paper's "historical data from previously executed jobs").
    psis: HashMap<JobId, [f32; crate::workload::PSI_DIM]>,
    /// Measured keys per job: keeps `measured_records_of` (the hottest
    /// catalog query — similarity filtering + Eq. 1 inputs run it per
    /// arrival) O(own records) instead of O(all records), which is the
    /// difference between linear and quadratic decision cost at
    /// 1000-accelerator scale.
    measured_keys: HashMap<JobId, Vec<EstimateKey>>,
    /// Unmeasured-estimate keys touching each job, for O(own keys)
    /// cleanup when the job departs ([`Catalog::evict_job_estimates`]).
    /// A key appears under every job of its combo; entries whose record
    /// was since measured or already removed are skipped at evict time.
    estimate_keys: HashMap<JobId, Vec<EstimateKey>>,
}

impl Catalog {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a job's attribute vector.
    pub fn register_job(&mut self, j: JobId, psi: [f32; crate::workload::PSI_DIM]) {
        self.psis.insert(j, psi);
    }

    pub fn psi(&self, j: JobId) -> Option<&[f32; crate::workload::PSI_DIM]> {
        self.psis.get(&j)
    }

    pub fn known_jobs(&self) -> impl Iterator<Item = &JobId> {
        self.psis.keys()
    }

    fn index_new_estimate(&mut self, key: EstimateKey) {
        for j in key.combo.jobs() {
            self.estimate_keys.entry(j).or_default().push(key);
        }
    }

    /// Record an initial P1 estimate (round 0): starts a fresh
    /// refinement set for the key.
    pub fn write_initial(&mut self, key: EstimateKey, value: f64) {
        if !self.records.contains_key(&key) {
            self.index_new_estimate(key);
        }
        let r = self.records.entry(key).or_default();
        r.sum = value;
        r.count = 1;
        r.last_round = 0;
    }

    /// Push a P2 refinement into 𝒯 (Eq. 4): the estimate becomes the
    /// running average of all refinements.
    pub fn push_refinement(&mut self, key: EstimateKey, value: f64, round: u32) {
        if !self.records.contains_key(&key) {
            self.index_new_estimate(key);
        }
        let r = self.records.entry(key).or_default();
        r.sum += value;
        r.count += 1;
        r.last_round = r.last_round.max(round);
    }

    /// Record a measurement (dominates estimates for this key).
    pub fn record_measurement(&mut self, key: EstimateKey, value: f64) {
        let r = self.records.entry(key).or_default();
        if r.measured.is_none() {
            self.measured_keys.entry(key.job).or_default().push(key);
        }
        r.measured = Some(value);
    }

    /// Drop every *unmeasured pair* record involving `j` (as the keyed
    /// job or as a combo partner). Called when a job departs: a pairing
    /// with a finished job can never recur, so those estimates are dead
    /// weight — without this the matrix grows O(jobs × active × types)
    /// over a trace. *Solo* estimates survive (O(types) per job): a
    /// departed job stays a similarity source, and Eq. 1's transfer
    /// inputs keep reading its solo values. Measured records always
    /// stay as the cluster's history.
    pub fn evict_job_estimates(&mut self, j: JobId) {
        let Some(keys) = self.estimate_keys.remove(&j) else {
            return;
        };
        for key in keys {
            if key.combo.len() == 1 {
                continue; // solo estimates remain queryable transfer history
            }
            let measured = self.records.get(&key).map_or(true, |r| r.is_measured());
            if !measured {
                self.records.remove(&key);
            }
        }
    }

    /// Whether `j` has at least one measured record (O(1); the
    /// similarity index's `require_measured` filter).
    pub fn has_measurements(&self, j: JobId) -> bool {
        self.measured_keys.get(&j).map_or(false, |v| !v.is_empty())
    }

    /// Current value (measured > averaged estimate > None).
    pub fn value(&self, key: &EstimateKey) -> Option<f64> {
        self.records.get(key).and_then(|r| r.value())
    }

    pub fn record(&self, key: &EstimateKey) -> Option<&Record> {
        self.records.get(key)
    }

    /// All measured (accel, combo) pairs involving `j` — the historical
    /// co-location evidence P1's Eq. 1 inputs are drawn from.
    pub fn measured_records_of(&self, j: JobId) -> Vec<(EstimateKey, f64)> {
        let mut v: Vec<(EstimateKey, f64)> = self
            .measured_keys
            .get(&j)
            .map(|keys| {
                keys.iter()
                    .map(|k| (*k, self.records[k].value().unwrap()))
                    .collect()
            })
            .unwrap_or_default();
        v.sort_by_key(|(k, _)| (k.accel.index(), k.combo));
        v
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Number of measured records (diagnostics).
    pub fn n_measured(&self) -> usize {
        self.records.values().filter(|r| r.is_measured()).count()
    }

    // -- persistence ----------------------------------------------------
    //
    // A deployed catalog is the cluster's accumulated knowledge; GOGH
    // checkpoints it across restarts (`gogh simulate --catalog c.json`).

    fn combo_json(c: &Combo) -> Json {
        match c {
            Combo::Solo(j) => Json::Array(vec![Json::from(j.0)]),
            Combo::Pair(a, b) => Json::Array(vec![Json::from(a.0), Json::from(b.0)]),
        }
    }

    fn combo_from_json(v: &Json) -> crate::Result<Combo> {
        let arr = v
            .as_array()
            .ok_or_else(|| anyhow::anyhow!("combo must be an array"))?;
        match arr {
            [a] => Ok(Combo::Solo(JobId(a.as_u64().unwrap_or(0) as u32))),
            [a, b] => Ok(Combo::pair(
                JobId(a.as_u64().unwrap_or(0) as u32),
                JobId(b.as_u64().unwrap_or(0) as u32),
            )),
            _ => anyhow::bail!("combo arity {} unsupported", arr.len()),
        }
    }

    /// Serialize the full catalog (records + Ψ registry) to JSON.
    pub fn to_json(&self) -> Json {
        let mut jobs: Vec<(String, Json)> = self
            .psis
            .iter()
            .map(|(j, psi)| {
                (
                    j.0.to_string(),
                    Json::Array(psi.iter().map(|&x| Json::Num(x as f64)).collect()),
                )
            })
            .collect();
        jobs.sort_by(|a, b| a.0.parse::<u32>().unwrap().cmp(&b.0.parse::<u32>().unwrap()));
        let mut recs: Vec<Json> = vec![];
        let mut keys: Vec<&EstimateKey> = self.records.keys().collect();
        keys.sort_by_key(|k| (k.accel.index(), k.job, k.combo));
        for k in keys {
            let r = &self.records[k];
            let mut fields = vec![
                ("accel", Json::from(k.accel.name())),
                ("job", Json::from(k.job.0)),
                ("combo", Self::combo_json(&k.combo)),
                ("sum", Json::Num(r.sum)),
                ("count", Json::from(r.count)),
                ("last_round", Json::from(r.last_round)),
            ];
            if let Some(m) = r.measured {
                fields.push(("measured", Json::Num(m)));
            }
            recs.push(Json::obj(fields));
        }
        Json::obj(vec![
            ("version", Json::from(1u32)),
            ("jobs", Json::Object(jobs)),
            ("records", Json::Array(recs)),
        ])
    }

    /// Restore a catalog serialized by [`Catalog::to_json`].
    pub fn from_json(v: &Json) -> crate::Result<Self> {
        anyhow::ensure!(v.req_f64("version")? as u32 == 1, "catalog version");
        let mut c = Catalog::new();
        for (id, psi_v) in v
            .req("jobs")?
            .as_object()
            .ok_or_else(|| anyhow::anyhow!("jobs must be an object"))?
        {
            let arr = psi_v
                .as_array()
                .ok_or_else(|| anyhow::anyhow!("psi must be an array"))?;
            anyhow::ensure!(arr.len() == crate::workload::PSI_DIM, "psi width");
            let mut psi = [0.0f32; crate::workload::PSI_DIM];
            for (i, x) in arr.iter().enumerate() {
                psi[i] = x.as_f64().unwrap_or(0.0) as f32;
            }
            c.register_job(JobId(id.parse()?), psi);
        }
        for rec in v
            .req("records")?
            .as_array()
            .ok_or_else(|| anyhow::anyhow!("records must be an array"))?
        {
            let accel_name = rec.req_str("accel")?;
            let accel = crate::workload::ACCEL_TYPES
                .iter()
                .copied()
                .find(|a| a.name() == accel_name)
                .ok_or_else(|| anyhow::anyhow!("unknown accel {accel_name}"))?;
            let key = EstimateKey {
                accel,
                job: JobId(rec.req_f64("job")? as u32),
                combo: Self::combo_from_json(rec.req("combo")?)?,
            };
            let measured = rec.get("measured").and_then(|m| m.as_f64());
            // rebuild the secondary indices the serialized form omits
            if measured.is_some() {
                c.measured_keys.entry(key.job).or_default().push(key);
            } else if !c.records.contains_key(&key) {
                c.index_new_estimate(key);
            }
            let r = c.records.entry(key).or_default();
            r.sum = rec.req_f64("sum")?;
            r.count = rec.req_f64("count")? as u32;
            r.last_round = rec.req_f64("last_round")? as u32;
            r.measured = measured;
        }
        Ok(c)
    }

    pub fn save(&self, path: &std::path::Path) -> crate::Result<()> {
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }

    pub fn load(path: &std::path::Path) -> crate::Result<Self> {
        Self::from_json(&Json::parse(&std::fs::read_to_string(path)?)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(a: AccelType, j: u32) -> EstimateKey {
        EstimateKey {
            accel: a,
            job: JobId(j),
            combo: Combo::Solo(JobId(j)),
        }
    }

    #[test]
    fn eq4_running_average() {
        let mut c = Catalog::new();
        let k = key(AccelType::K80, 1);
        c.write_initial(k, 0.4);
        assert_eq!(c.value(&k), Some(0.4));
        c.push_refinement(k, 0.6, 1);
        assert!((c.value(&k).unwrap() - 0.5).abs() < 1e-12);
        c.push_refinement(k, 0.8, 2);
        assert!((c.value(&k).unwrap() - 0.6).abs() < 1e-12);
        assert_eq!(c.record(&k).unwrap().refinements(), 3);
    }

    #[test]
    fn measurement_dominates_estimates() {
        let mut c = Catalog::new();
        let k = key(AccelType::V100, 2);
        c.write_initial(k, 0.3);
        c.record_measurement(k, 0.9);
        assert_eq!(c.value(&k), Some(0.9));
        // refinements keep accumulating but don't override the measurement
        c.push_refinement(k, 0.1, 1);
        assert_eq!(c.value(&k), Some(0.9));
        assert_eq!(c.record(&k).unwrap().estimate_only(), Some(0.2));
    }

    #[test]
    fn write_initial_resets_refinement_set() {
        let mut c = Catalog::new();
        let k = key(AccelType::P100, 3);
        c.push_refinement(k, 1.0, 1);
        c.push_refinement(k, 0.0, 2);
        c.write_initial(k, 0.5);
        assert_eq!(c.value(&k), Some(0.5));
        assert_eq!(c.record(&k).unwrap().refinements(), 1);
    }

    #[test]
    fn measured_records_filtering() {
        let mut c = Catalog::new();
        let k1 = key(AccelType::K80, 1);
        let k2 = EstimateKey {
            accel: AccelType::V100,
            job: JobId(1),
            combo: Combo::pair(JobId(1), JobId(2)),
        };
        c.write_initial(k1, 0.4); // estimate only
        c.record_measurement(k2, 0.7);
        let recs = c.measured_records_of(JobId(1));
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].0, k2);
    }

    #[test]
    fn unknown_key_is_none() {
        let c = Catalog::new();
        assert_eq!(c.value(&key(AccelType::K80, 9)), None);
    }

    #[test]
    fn evict_job_estimates_drops_pairs_but_keeps_history() {
        let mut c = Catalog::new();
        let solo1 = key(AccelType::K80, 1);
        let solo1_v = key(AccelType::V100, 1);
        let pair12 = EstimateKey {
            accel: AccelType::V100,
            job: JobId(1),
            combo: Combo::pair(JobId(1), JobId(2)),
        };
        let partner21 = EstimateKey {
            accel: AccelType::V100,
            job: JobId(2),
            combo: Combo::pair(JobId(1), JobId(2)),
        };
        let solo2 = key(AccelType::K80, 2);
        c.write_initial(solo1, 0.4);
        c.write_initial(solo1_v, 0.6);
        c.push_refinement(pair12, 0.3, 1);
        c.write_initial(partner21, 0.2);
        c.write_initial(solo2, 0.5);
        c.record_measurement(solo1, 0.45); // measured → survives eviction
        c.evict_job_estimates(JobId(1));
        assert_eq!(c.value(&solo1), Some(0.45), "measured history must survive");
        // solo estimates survive too: Eq. 1 transfer keeps reading them
        assert_eq!(c.value(&solo1_v), Some(0.6));
        assert_eq!(c.value(&pair12), None);
        // the partner's estimate for the pairing with job 1 is dead too
        assert_eq!(c.value(&partner21), None);
        // records not involving job 1 are untouched
        assert_eq!(c.value(&solo2), Some(0.5));
        // idempotent, and re-registering later works
        c.evict_job_estimates(JobId(1));
        c.write_initial(pair12, 0.33);
        assert_eq!(c.value(&pair12), Some(0.33));
    }

    #[test]
    fn measured_index_matches_full_scan() {
        let mut c = Catalog::new();
        assert!(!c.has_measurements(JobId(1)));
        let k1 = key(AccelType::K80, 1);
        let k2 = EstimateKey {
            accel: AccelType::V100,
            job: JobId(1),
            combo: Combo::pair(JobId(1), JobId(2)),
        };
        c.write_initial(k1, 0.4);
        assert!(!c.has_measurements(JobId(1)), "estimate is not a measurement");
        c.record_measurement(k1, 0.5);
        c.record_measurement(k1, 0.6); // repeated: must not duplicate
        c.record_measurement(k2, 0.7);
        assert!(c.has_measurements(JobId(1)));
        let recs = c.measured_records_of(JobId(1));
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[0], (k1, 0.6));
        assert_eq!(recs[1], (k2, 0.7));
    }

    #[test]
    fn json_persistence_roundtrip() {
        let mut c = Catalog::new();
        c.register_job(JobId(1), [0.5; crate::workload::PSI_DIM]);
        c.register_job(JobId(2), [0.25; crate::workload::PSI_DIM]);
        let k1 = key(AccelType::K80, 1);
        let k2 = EstimateKey {
            accel: AccelType::V100,
            job: JobId(1),
            combo: Combo::pair(JobId(1), JobId(2)),
        };
        c.write_initial(k1, 0.4);
        c.push_refinement(k1, 0.6, 3);
        c.record_measurement(k2, 0.77);
        let back = Catalog::from_json(&c.to_json()).unwrap();
        assert_eq!(back.len(), c.len());
        assert_eq!(back.value(&k1), c.value(&k1));
        assert_eq!(back.value(&k2), Some(0.77));
        assert_eq!(back.record(&k1).unwrap().refinements(), 2);
        assert_eq!(back.record(&k1).unwrap().last_round, 3);
        assert_eq!(back.psi(JobId(2)), c.psi(JobId(2)));
        // serialization is deterministic
        assert_eq!(c.to_json().to_string(), back.to_json().to_string());
        // secondary indices are rebuilt on load
        assert!(back.has_measurements(JobId(1)));
        assert_eq!(back.measured_records_of(JobId(1)), c.measured_records_of(JobId(1)));
        let pair13 = EstimateKey {
            accel: AccelType::K80,
            job: JobId(1),
            combo: Combo::pair(JobId(1), JobId(3)),
        };
        let mut back = back;
        back.push_refinement(pair13, 0.5, 4);
        let mut reload = Catalog::from_json(&back.to_json()).unwrap();
        reload.evict_job_estimates(JobId(1));
        assert_eq!(reload.value(&pair13), None, "estimate index not rebuilt");
        assert_eq!(reload.value(&k1), back.value(&k1), "solo estimates survive");
        assert_eq!(reload.value(&k2), Some(0.77));
    }
}
