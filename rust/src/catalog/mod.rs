//! The Catalog (paper §2.1): the store of measured and estimated
//! throughputs that P1 reads and P2 updates, plus job similarity search.

pub mod similarity;
pub mod store;

pub use similarity::SimilarityIndex;
pub use store::{Catalog, EstimateKey, Record};
