//! The project-invariant rules `gogh-lint` enforces and the per-file
//! checker. Every rule is documented with its rationale in
//! `docs/LINTS.md` (CI cross-checks that the table below and the doc
//! stay in sync).

use std::fmt;

use crate::lint::scanner::{parse_allows, scrub, test_fence, Line};

/// A lint rule: stable name (used in `allow(<rule>, …)` suppressions
/// and in docs/LINTS.md) plus a one-line summary.
pub struct Rule {
    pub name: &'static str,
    pub summary: &'static str,
}

/// Every rule the pass knows. Names are load-bearing: suppressions
/// reference them and `.github/scripts/docs_freshness.py` fails CI if
/// any is missing from docs/LINTS.md.
pub const RULES: &[Rule] = &[
    Rule {
        name: "determinism-wall-clock",
        summary: "no Instant::now / SystemTime in decision-path modules \
                  (ilp/, coordinator/, cluster/, baselines/)",
    },
    Rule {
        name: "determinism-hash-container",
        summary: "no HashMap / HashSet in decision-path modules: iteration \
                  order is per-process random and leaks into placements",
    },
    Rule {
        name: "panic-unwrap",
        summary: "no .unwrap() / .expect() in non-test daemon/, engine/, \
                  bin/ code — return Result or a protocol error envelope",
    },
    Rule {
        name: "panic-macro",
        summary: "no panic!/unreachable!/todo!/unimplemented! in non-test \
                  daemon/, engine/, bin/ code",
    },
    Rule {
        name: "panic-slice-index",
        summary: "no literal-index slicing (v[0]) in non-test daemon/, \
                  engine/, bin/ code — use .get() / .first()",
    },
    Rule {
        name: "protocol-error-code",
        summary: "ProtoError codes under daemon/ must come from the closed \
                  set documented in daemon/protocol.rs",
    },
    Rule {
        name: "rng-source",
        summary: "all randomness flows through util/rng.rs seeded streams; \
                  no thread_rng / RandomState / entropy sources",
    },
    Rule {
        name: "bad-suppression",
        summary: "a gogh-lint allow() must name a known rule and carry a \
                  non-empty reason",
    },
];

/// One finding: `file:line: rule: message`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Violation {
    pub file: String,
    pub line: usize,
    pub rule: &'static str,
    pub message: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: {}: {}", self.file, self.line, self.rule, self.message)
    }
}

/// Module zones, derived from path components so the same scoping works
/// for `rust/src/` and for the committed bad-fixture tree.
struct Zones {
    decision: bool,
    panic_free: bool,
    daemon: bool,
    rng_exempt: bool,
}

fn zones(path: &str) -> Zones {
    let p = path.replace('\\', "/");
    let comps: Vec<&str> = p.split('/').collect();
    let has = |name: &str| comps.iter().any(|c| *c == name);
    Zones {
        decision: has("ilp") || has("coordinator") || has("cluster") || has("baselines"),
        // main.rs is the `gogh` CLI's crate root — same zone as bin/
        panic_free: has("daemon") || has("engine") || has("bin") || p.ends_with("main.rs"),
        daemon: has("daemon"),
        rng_exempt: p.ends_with("util/rng.rs"),
    }
}

/// Wall-clock / hash-container / panic / RNG token patterns. A pattern
/// starting with an identifier char only matches on an identifier
/// boundary (`operand::` must not trip `rand::`).
fn find_token(code: &str, pat: &str) -> bool {
    let pat_ident = pat.as_bytes().first().is_some_and(|c| c.is_ascii_alphanumeric());
    let mut from = 0;
    while let Some(i) = code[from..].find(pat) {
        let at = from + i;
        let boundary = !pat_ident
            || at == 0
            || !{
                let prev = code.as_bytes()[at - 1];
                prev.is_ascii_alphanumeric() || prev == b'_'
            };
        if boundary {
            return true;
        }
        from = at + pat.len();
    }
    false
}

/// Literal-index slicing: `ident[<digits>]` (also after `)` / `]`).
fn has_literal_index(code: &str) -> bool {
    let b = code.as_bytes();
    for i in 0..b.len() {
        if b[i] != b'[' || i == 0 {
            continue;
        }
        let prev = b[i - 1];
        let indexable =
            prev.is_ascii_alphanumeric() || prev == b'_' || prev == b')' || prev == b']';
        if !indexable {
            continue;
        }
        let digits = b[i + 1..].iter().take_while(|c| c.is_ascii_digit()).count();
        if digits > 0 && b.get(i + 1 + digits) == Some(&b']') {
            return true;
        }
    }
    false
}

/// Check one file. `path` is used both for zone scoping and reporting.
pub fn check_source(path: &str, src: &str) -> Vec<Violation> {
    let lines = scrub(src);
    let allows = parse_allows(&lines);
    let fence = test_fence(&lines).unwrap_or(usize::MAX);
    let z = zones(path);
    let mut out: Vec<Violation> = Vec::new();

    // the suppression mechanism polices itself
    for a in &allows {
        if a.directive_line >= fence {
            continue;
        }
        if a.rule.is_empty() || a.reason.is_none() {
            out.push(Violation {
                file: path.to_string(),
                line: a.directive_line,
                rule: "bad-suppression",
                message: "suppression requires a rule and a reason: \
                          gogh-lint: allow(<rule>, <reason>)"
                    .into(),
            });
        } else if !RULES.iter().any(|r| r.name == a.rule) {
            out.push(Violation {
                file: path.to_string(),
                line: a.directive_line,
                rule: "bad-suppression",
                message: format!("unknown rule {:?} in suppression", a.rule),
            });
        }
    }
    let allowed = |line: usize, rule: &str| {
        allows
            .iter()
            .any(|a| a.target_line == line && a.rule == rule && a.reason.is_some())
    };
    let mut push = |line: usize, rule: &'static str, message: String, out: &mut Vec<Violation>| {
        if line < fence && !allowed(line, rule) {
            out.push(Violation {
                file: path.to_string(),
                line,
                rule,
                message,
            });
        }
    };

    for (idx, Line { code, .. }) in lines.iter().enumerate() {
        let lineno = idx + 1;
        if lineno >= fence {
            break;
        }
        if z.decision {
            for pat in ["Instant::now", "SystemTime"] {
                if find_token(code, pat) {
                    let msg = format!(
                        "{pat} in a decision-path module: wall-clock reads make \
                         scheduling non-reproducible (use deterministic budgets, \
                         or allow-list a timing-only statistic)"
                    );
                    push(lineno, "determinism-wall-clock", msg, &mut out);
                }
            }
            for pat in ["HashMap", "HashSet"] {
                if find_token(code, pat) {
                    let msg = format!(
                        "{pat} in a decision-path module: iteration order is \
                         per-process random (use BTreeMap/BTreeSet, or \
                         allow-list a lookup-only map with a reason)"
                    );
                    push(lineno, "determinism-hash-container", msg, &mut out);
                }
            }
        }
        if z.panic_free {
            for pat in [".unwrap()", ".expect("] {
                if code.contains(pat) {
                    let msg = format!(
                        "{pat} in a panic-free zone: a panicking daemon/engine \
                         loses the cluster — return Result or an error envelope"
                    );
                    push(lineno, "panic-unwrap", msg, &mut out);
                }
            }
            for pat in ["panic!(", "unreachable!(", "todo!(", "unimplemented!("] {
                if find_token(code, pat) {
                    let msg = format!("{pat}…) in a panic-free zone");
                    push(lineno, "panic-macro", msg, &mut out);
                }
            }
            if has_literal_index(code) {
                push(
                    lineno,
                    "panic-slice-index",
                    "literal index in a panic-free zone: out-of-bounds panics \
                     instead of returning an error (use .get())"
                        .into(),
                    &mut out,
                );
            }
        }
        if !z.rng_exempt {
            for pat in ["thread_rng", "from_entropy", "RandomState", "rand::", "getrandom"] {
                if find_token(code, pat) {
                    let msg = format!(
                        "{pat} bypasses util/rng.rs: experiments must be exactly \
                         reproducible from their seed"
                    );
                    push(lineno, "rng-source", msg, &mut out);
                }
            }
        }
    }

    if z.daemon {
        check_protocol_codes(path, src, fence, &allowed, &mut out);
    }

    out.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    out
}

/// Error-code literals passed to `ProtoError::new` must stay inside the
/// closed set the wire protocol documents ([`crate::daemon::protocol`]):
/// clients match on codes, so a new code is a protocol change that must
/// land in `ERROR_CODES` + docs/PROTOCOL.md first. Scans the *raw*
/// source (the argument is a string literal, which scrubbing blanks).
fn check_protocol_codes(
    path: &str,
    src: &str,
    fence: usize,
    allowed: &dyn Fn(usize, &str) -> bool,
    out: &mut Vec<Violation>,
) {
    const NEEDLE: &str = "ProtoError::new(";
    let mut from = 0;
    while let Some(i) = src[from..].find(NEEDLE) {
        let at = from + i;
        from = at + NEEDLE.len();
        let lineno = 1 + src[..at].bytes().filter(|&b| b == b'\n').count();
        if lineno >= fence {
            continue;
        }
        let rest = src[at + NEEDLE.len()..].trim_start();
        let code = rest
            .strip_prefix('"')
            .and_then(|r| r.split_once('"'))
            .map(|(code, _)| code);
        let ok = match code {
            Some(c) => crate::daemon::protocol::ERROR_CODES.contains(&c),
            // non-literal argument: cannot be verified against the set
            None => false,
        };
        if !ok && !allowed(lineno, "protocol-error-code") {
            let what = code.map_or("<non-literal>".to_string(), |c| format!("{c:?}"));
            out.push(Violation {
                file: path.to_string(),
                line: lineno,
                rule: "protocol-error-code",
                message: format!(
                    "error code {what} is outside the closed protocol set \
                     {:?} (extend daemon/protocol.rs ERROR_CODES + \
                     docs/PROTOCOL.md first)",
                    crate::daemon::protocol::ERROR_CODES
                ),
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules_of(path: &str, src: &str) -> Vec<(&'static str, usize)> {
        check_source(path, src).into_iter().map(|v| (v.rule, v.line)).collect()
    }

    #[test]
    fn wall_clock_flagged_only_in_decision_zone() {
        let src = "fn f() { let t = std::time::Instant::now(); }";
        assert_eq!(rules_of("rust/src/ilp/x.rs", src), vec![("determinism-wall-clock", 1)]);
        assert_eq!(rules_of("rust/src/runtime/x.rs", src), vec![]);
    }

    #[test]
    fn hash_container_flagged_with_line() {
        let src = "use std::collections::BTreeMap;\nfn f(m: &HashMap<u32, f64>) {}\n";
        assert_eq!(
            rules_of("rust/src/cluster/x.rs", src),
            vec![("determinism-hash-container", 2)]
        );
    }

    #[test]
    fn allow_with_reason_suppresses() {
        let src = "// gogh-lint: allow(determinism-wall-clock, timing stat only)\n\
                   let t = Instant::now();\n";
        assert_eq!(rules_of("rust/src/coordinator/x.rs", src), vec![]);
    }

    #[test]
    fn allow_without_reason_is_an_error_and_does_not_suppress() {
        let src = "// gogh-lint: allow(determinism-wall-clock)\nlet t = Instant::now();\n";
        assert_eq!(
            rules_of("rust/src/coordinator/x.rs", src),
            vec![("bad-suppression", 1), ("determinism-wall-clock", 2)]
        );
    }

    #[test]
    fn unknown_rule_in_allow_is_an_error() {
        let src = "// gogh-lint: allow(no-such-rule, because)\nx();\n";
        assert_eq!(rules_of("rust/src/engine/x.rs", src), vec![("bad-suppression", 1)]);
    }

    #[test]
    fn panic_rules_fire_in_zone_and_respect_test_fence() {
        let src = "fn f() { x.unwrap(); }\n#[cfg(test)]\nmod t { fn g() { y.unwrap(); } }\n";
        assert_eq!(rules_of("rust/src/daemon/x.rs", src), vec![("panic-unwrap", 1)]);
        assert_eq!(rules_of("rust/src/catalog/x.rs", src), vec![]);
        let src = "fn f() { unreachable!(\"no\"); }";
        assert_eq!(rules_of("rust/src/bin/x.rs", src), vec![("panic-macro", 1)]);
        assert_eq!(rules_of("rust/src/main.rs", "fn f() { v.expect(\"x\"); }"),
            vec![("panic-unwrap", 1)]);
    }

    #[test]
    fn slice_index_literal_only() {
        assert_eq!(rules_of("rust/src/engine/x.rs", "let a = xs[0];"),
            vec![("panic-slice-index", 1)]);
        for benign in ["let a = xs[i];", "let a = &xs[1..n];", "#[cfg(feature)]", "[0u8; 4];"] {
            assert_eq!(rules_of("rust/src/engine/x.rs", benign), vec![], "{benign}");
        }
    }

    #[test]
    fn unwrap_or_variants_are_fine() {
        for benign in ["x.unwrap_or(3);", "x.unwrap_or_else(f);", "x.unwrap_or_default();"] {
            assert_eq!(rules_of("rust/src/daemon/x.rs", benign), vec![], "{benign}");
        }
    }

    #[test]
    fn rng_rule_is_global_except_rng_module() {
        let src = "let r = rand::thread_rng();";
        let got = rules_of("rust/src/workload/x.rs", src);
        assert!(got.iter().all(|(r, _)| *r == "rng-source") && !got.is_empty());
        assert_eq!(rules_of("rust/src/util/rng.rs", src), vec![]);
        // identifier boundary: `operand::` is not `rand::`
        assert_eq!(rules_of("rust/src/workload/x.rs", "operand::f();"), vec![]);
    }

    #[test]
    fn strings_and_comments_never_match() {
        let src = "// HashMap Instant::now\nlet s = \"thread_rng .unwrap()\";\n";
        assert_eq!(rules_of("rust/src/coordinator/x.rs", src), vec![]);
        assert_eq!(rules_of("rust/src/daemon/x.rs", src), vec![]);
    }

    #[test]
    fn protocol_codes_checked_across_wrapped_lines() {
        let good = "fn f() { Err(ProtoError::new(\n    \"draining\",\n    \"x\")) }";
        assert_eq!(rules_of("rust/src/daemon/x.rs", good), vec![]);
        let bad = "fn f() { Err(ProtoError::new(\n    \"brand_new_code\",\n    \"x\")) }";
        assert_eq!(rules_of("rust/src/daemon/x.rs", bad), vec![("protocol-error-code", 1)]);
    }
}
