#![doc = include_str!("../../../docs/LINTS.md")]

use std::fs;
use std::path::{Path, PathBuf};

use crate::Result;

pub mod rules;
pub mod scanner;

pub use rules::{check_source, Rule, Violation, RULES};

/// Recursively lint every `.rs` file under `root`, in sorted path order
/// (deterministic output, like everything else in this repo). `root`
/// may be a single file.
pub fn check_tree(root: &Path) -> Result<Vec<Violation>> {
    let mut files: Vec<PathBuf> = Vec::new();
    collect_rs(root, &mut files)?;
    files.sort();
    let mut out = Vec::new();
    for f in &files {
        let src = fs::read_to_string(f)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", f.display()))?;
        out.extend(check_source(&f.to_string_lossy(), &src));
    }
    Ok(out)
}

fn collect_rs(path: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    let meta = fs::metadata(path)
        .map_err(|e| anyhow::anyhow!("stat {}: {e}", path.display()))?;
    if meta.is_file() {
        if path.extension().is_some_and(|e| e == "rs") {
            out.push(path.to_path_buf());
        }
        return Ok(());
    }
    let entries = fs::read_dir(path)
        .map_err(|e| anyhow::anyhow!("listing {}: {e}", path.display()))?;
    for entry in entries {
        let entry = entry.map_err(|e| anyhow::anyhow!("listing {}: {e}", path.display()))?;
        collect_rs(&entry.path(), out)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn repo_path(rel: &str) -> PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join(rel)
    }

    /// The real gate, also enforced by the CI `gogh-lint` job: the
    /// shipped tree must be violation-free.
    #[test]
    fn shipped_tree_is_clean() {
        let got = check_tree(&repo_path("rust/src")).unwrap();
        assert!(
            got.is_empty(),
            "gogh-lint violations in rust/src:\n{}",
            got.iter().map(|v| v.to_string()).collect::<Vec<_>>().join("\n")
        );
    }

    /// The committed bad-fixture tree must trip every rule, each with
    /// the right rule name, file, and a plausible line.
    #[test]
    fn fixture_tree_trips_every_rule() {
        let got = check_tree(&repo_path("rust/lint-fixtures")).unwrap();
        for rule in RULES {
            let hits: Vec<&Violation> =
                got.iter().filter(|v| v.rule == rule.name).collect();
            assert!(!hits.is_empty(), "no fixture violation for rule {}", rule.name);
            for v in hits {
                assert!(v.file.ends_with(".rs") && v.line >= 1, "{v}");
            }
        }
        // and allow-listed fixture code passes: the `allowed.rs` fixture
        // exercises a valid suppression and must produce no findings
        assert!(
            !got.iter().any(|v| v.file.ends_with("allowed.rs")),
            "allow-listed fixture flagged: {got:?}"
        );
    }

    #[test]
    fn check_tree_accepts_a_single_file() {
        let p = repo_path("rust/src/util/rng.rs");
        assert!(check_tree(&p).unwrap().is_empty());
    }

    #[test]
    fn check_tree_errors_on_missing_path() {
        assert!(check_tree(Path::new("/no/such/dir")).is_err());
    }
}
