//! Source preparation for the lint pass: a small lexical scrubber that
//! blanks string literals and comments (so rule patterns never match
//! inside data or prose), the `// gogh-lint: allow(<rule>, <reason>)`
//! suppression parser, and the `#[cfg(test)]` fence.
//!
//! The scrubber is deliberately lexical, not a parser: it tracks just
//! enough state (line comments, nested block comments, string / raw
//! string / char literals) to know which bytes of a line are *code*.
//! Rule patterns are then matched against the scrubbed text only, which
//! is also what lets the lint scan its own sources: the pattern tables
//! in `rules.rs` live inside string literals and scrub to blanks.

/// One source line after scrubbing, plus the raw text the suppression
/// parser reads (directives live in comments, which scrubbing removes).
pub struct Line<'a> {
    pub raw: &'a str,
    /// `raw` with comments and string/char literal *contents* replaced
    /// by spaces (delimiters too) — byte positions are preserved.
    pub code: String,
    /// Byte offset where a code-level `//` comment starts on this line,
    /// if any. Suppression directives are only honored there — never in
    /// string literals or block comments.
    pub comment_start: Option<usize>,
}

/// A parsed `gogh-lint: allow(...)` directive.
pub struct Allow<'a> {
    /// 1-based line the directive suppresses (the directive's own line
    /// for trailing comments, the following line for whole-line ones).
    pub target_line: usize,
    /// 1-based line the directive itself sits on (for error reporting).
    pub directive_line: usize,
    pub rule: &'a str,
    /// `None` when the reason is missing/empty — itself a lint error.
    pub reason: Option<&'a str>,
}

/// Scrub a whole file into per-line code views. Handles `//` comments,
/// nested `/* */` comments, `"…"` strings with escapes, `r"…"` /
/// `r#"…"#` raw strings (including multi-line bodies) and char
/// literals; lifetimes (`'a`) are left untouched.
pub fn scrub(src: &str) -> Vec<Line<'_>> {
    #[derive(Clone, Copy, PartialEq)]
    enum St {
        Code,
        Block(u32),     // nesting depth
        Str,            // inside "…"
        RawStr(usize),  // inside r#…"…"#… with N hashes
    }
    let mut st = St::Code;
    let mut out = Vec::new();
    for raw in src.lines() {
        let b = raw.as_bytes();
        let mut code: Vec<u8> = vec![b' '; b.len()];
        let mut comment_start = None;
        let mut i = 0;
        while i < b.len() {
            match st {
                St::Code => {
                    if b[i] == b'/' && i + 1 < b.len() && b[i + 1] == b'/' {
                        comment_start = Some(i);
                        break; // rest of line is a comment
                    } else if b[i] == b'/' && i + 1 < b.len() && b[i + 1] == b'*' {
                        st = St::Block(1);
                        i += 2;
                    } else if b[i] == b'"' {
                        st = St::Str;
                        i += 1;
                    } else if b[i] == b'r'
                        && !prev_is_ident(&code, i)
                        && raw_str_hashes(&b[i + 1..]).is_some()
                    {
                        let n = raw_str_hashes(&b[i + 1..]).unwrap_or(0);
                        st = St::RawStr(n);
                        i += 2 + n; // r, hashes, opening quote
                    } else if b[i] == b'\'' {
                        // char literal vs lifetime: a char literal closes
                        // with ' within a few bytes ('x', '\n', '\u{…}')
                        if let Some(len) = char_literal_len(&b[i..]) {
                            i += len;
                        } else {
                            code[i] = b[i]; // lifetime tick is code
                            i += 1;
                        }
                    } else {
                        code[i] = b[i];
                        i += 1;
                    }
                }
                St::Block(depth) => {
                    if b[i] == b'*' && i + 1 < b.len() && b[i + 1] == b'/' {
                        st = if depth == 1 { St::Code } else { St::Block(depth - 1) };
                        i += 2;
                    } else if b[i] == b'/' && i + 1 < b.len() && b[i + 1] == b'*' {
                        st = St::Block(depth + 1);
                        i += 2;
                    } else {
                        i += 1;
                    }
                }
                St::Str => {
                    if b[i] == b'\\' {
                        i += 2;
                    } else if b[i] == b'"' {
                        st = St::Code;
                        i += 1;
                    } else {
                        i += 1;
                    }
                }
                St::RawStr(n) => {
                    let hashes = b[i + 1..].iter().take(n).filter(|&&c| c == b'#').count();
                    if b[i] == b'"' && hashes == n {
                        st = St::Code;
                        i += 1 + n;
                    } else {
                        i += 1;
                    }
                }
            }
        }
        // a "…" string continues onto the next line only behind a
        // trailing backslash; otherwise reset so one stray quote cannot
        // blank the rest of the file
        if st == St::Str && !raw.ends_with('\\') {
            st = St::Code;
        }
        let code = String::from_utf8(code).unwrap_or_default();
        out.push(Line {
            raw,
            code,
            comment_start,
        });
    }
    out
}

fn prev_is_ident(code: &[u8], i: usize) -> bool {
    i > 0 && (code[i - 1].is_ascii_alphanumeric() || code[i - 1] == b'_')
}

/// `r"` → Some(0), `r#"` → Some(1), … ; anything else → None.
fn raw_str_hashes(after_r: &[u8]) -> Option<usize> {
    let n = after_r.iter().take_while(|&&c| c == b'#').count();
    (after_r.get(n) == Some(&b'"')).then_some(n)
}

/// Byte length of a char literal starting at `'`, or None for lifetimes.
fn char_literal_len(b: &[u8]) -> Option<usize> {
    if b.len() < 3 {
        return None;
    }
    if b[1] == b'\\' {
        // '\n', '\\', '\u{1F600}': scan to the closing quote, bounded
        return b
            .iter()
            .enumerate()
            .skip(3)
            .take(10)
            .find(|&(_, &c)| c == b'\'')
            .map(|(i, _)| i + 1);
    }
    // one (possibly multi-byte) char then the closing quote; reject
    // separator bytes so `<'a, 'b>` stays a pair of lifetimes
    (1..=4usize).find_map(|k| {
        let closes = b.get(1 + k) == Some(&b'\'');
        let plain = b[1..1 + k].iter().all(|&c| c != b' ' && c != b',');
        (closes && plain).then_some(k + 2)
    })
}

/// Extract every suppression directive in the file. The grammar is
/// `gogh-lint: allow(<rule>, <reason>)` inside a plain `//` comment; a
/// directive with no code before it on its line targets the *next*
/// line, a trailing directive targets its own line. String literals and
/// block comments never register, and doc comments (`///` / `//!`) are
/// rendered prose — a directive spelled there is documentation, not a
/// suppression (which is what lets this very grammar be documented).
pub fn parse_allows<'a>(lines: &[Line<'a>]) -> Vec<Allow<'a>> {
    let mut out = Vec::new();
    for (idx, line) in lines.iter().enumerate() {
        let Some(cstart) = line.comment_start else {
            continue;
        };
        let tail = &line.raw[cstart..];
        if tail.starts_with("///") || tail.starts_with("//!") {
            continue;
        }
        let Some(rel) = tail.find("gogh-lint:") else {
            continue;
        };
        let pos = cstart + rel;
        let lineno = idx + 1;
        let whole_line = line.code.trim().is_empty();
        let target = if whole_line { lineno + 1 } else { lineno };
        let rest = line.raw[pos + "gogh-lint:".len()..].trim_start();
        let body = rest
            .strip_prefix("allow(")
            .and_then(|r| r.rfind(')').map(|e| &r[..e]));
        let (rule, reason) = match body {
            Some(body) => match body.split_once(',') {
                Some((rule, reason)) => {
                    let reason = reason.trim();
                    (rule.trim(), (!reason.is_empty()).then_some(reason))
                }
                None => (body.trim(), None),
            },
            // malformed directive: surface it as a nameless allow so the
            // rule layer reports a bad-suppression error
            None => ("", None),
        };
        out.push(Allow {
            target_line: target,
            directive_line: lineno,
            rule,
            reason,
        });
    }
    out
}

/// 1-based line of the `#[cfg(test)]` fence, if any: everything from
/// that line on is test code (this repo keeps test modules at the end
/// of each file) and exempt from every rule.
pub fn test_fence(lines: &[Line<'_>]) -> Option<usize> {
    lines
        .iter()
        .position(|l| l.code.contains("#[cfg(test)]"))
        .map(|i| i + 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scrub_blanks_strings_and_comments() {
        let src = "let a = \"Instant::now\"; // Instant::now\nlet b = 1;";
        let lines = scrub(src);
        assert!(!lines[0].code.contains("Instant::now"));
        assert!(lines[0].code.contains("let a ="));
        assert!(lines[1].code.contains("let b = 1;"));
    }

    #[test]
    fn scrub_handles_raw_and_multiline() {
        let src = "let s = r#\"x\nHashMap\ny\"#;\nlet t = HashMap::new();";
        let lines = scrub(src);
        assert!(!lines[1].code.contains("HashMap"));
        assert!(lines[3].code.contains("HashMap::new"));
    }

    #[test]
    fn scrub_handles_block_comments_and_chars() {
        let src = "/* HashMap\n still comment */ let c = 'x'; let l: &'a str = v;";
        let lines = scrub(src);
        assert!(!lines[0].code.contains("HashMap"));
        assert!(lines[1].code.contains("let c ="));
        assert!(!lines[1].code.contains('x'));
        assert!(lines[1].code.contains("&'a str"));
    }

    #[test]
    fn allow_targets_trailing_and_next_line() {
        let src = "a(); // gogh-lint: allow(r1, reason one)\n// gogh-lint: allow(r2, two)\nb();";
        let allows = parse_allows(&scrub(src));
        assert_eq!(allows.len(), 2);
        assert_eq!((allows[0].target_line, allows[0].rule), (1, "r1"));
        assert_eq!(allows[0].reason, Some("reason one"));
        assert_eq!((allows[1].target_line, allows[1].rule), (3, "r2"));
    }

    #[test]
    fn allow_without_reason_is_detected() {
        let src = "// gogh-lint: allow(r1)\nx();";
        let allows = parse_allows(&scrub(src));
        assert_eq!(allows[0].reason, None);
        let src2 = "// gogh-lint: allow(r1, )\nx();";
        assert_eq!(parse_allows(&scrub(src2))[0].reason, None);
    }

    #[test]
    fn continued_string_spans_lines() {
        // a trailing backslash continues the literal onto the next line;
        // its body must stay scrubbed (the rule tables in rules.rs rely
        // on this)
        let src = "let s = \"no thread_rng here \\\n          more HashMap text\";\nlet x = 1;";
        let lines = scrub(src);
        assert!(!lines[1].code.contains("HashMap"));
        assert!(lines[2].code.contains("let x = 1;"));
    }

    #[test]
    fn directives_in_strings_and_doc_comments_are_inert() {
        // the lint's own sources mention the marker in literals and docs
        let src = "let p = line.find(\"gogh-lint:\");\n\
                   /// `// gogh-lint: allow(<rule>, <reason>)` syntax\n\
                   //! gogh-lint: allow(also, prose)\n\
                   /* gogh-lint: allow(blocked, out) */ x();\n\
                   // gogh-lint: allow(real, this one counts)\n\
                   y();";
        let allows = parse_allows(&scrub(src));
        assert_eq!(allows.len(), 1);
        assert_eq!((allows[0].target_line, allows[0].rule), (6, "real"));
    }

    #[test]
    fn fence_marks_test_tail() {
        let src = "fn a() {}\n#[cfg(test)]\nmod tests {}";
        assert_eq!(test_fence(&scrub(src)), Some(2));
        assert_eq!(test_fence(&scrub("fn a() {}")), None);
    }
}
