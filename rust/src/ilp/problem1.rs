//! Problem 1 — the GPU-allocation ILP (paper §2.4).
//!
//! Variables: the paper's x^c_{a,s} is indexed per (combination,
//! accelerator type, server). Instances of the same type are identical
//! in this substrate, so we aggregate per type: integer `n_{a,c}` =
//! number of type-`a` instances hosting combination `c`, with
//! `0 ≤ n_{a,c} ≤ count(a)`. The aggregation is exact (any aggregated
//! solution maps to a per-server one by assigning combos to free
//! instances arbitrarily) and shrinks the ILP by the server count.
//!
//! Objective (2a): `min Σ γ_a(load)·n` — energy of *used* instances;
//! γ_a is evaluated per combination (each instance hosts at most one
//! combination, constraint 2f, so no piecewise linearization is needed —
//! the nonlinearity is folded into per-column constants).
//!
//! Constraints: (2b) coverage ≥ 1 per job; (2c) ≤ D_j instances per job;
//! (2d) capacity |c| ≤ θ_a by combo pruning; (2e) aggregate throughput ≥
//! T̄_j; (2f) Σ_c n_{a,c} ≤ count(a).
//!
//! SLO softening: real traces can be transiently infeasible (more jobs
//! than capacity). `slack_penalty` adds per-job slack on (2b)/(2e) with
//! a large objective penalty, so the optimizer degrades gracefully and
//! the coordinator reports the violation instead of failing.
//!
//! Inference jobs (constraint 2e′): for a serving job the `n_{a,c}`
//! multiplicities are its **replica counts** — coverage (2b) keeps ≥ 1
//! replica, the distributability bound (2c) is the replica cap R_j, and
//! the throughput row (2e) carries the latency SLO linearized by
//! [`latency_adjusted_jobs`]: the M/M/c sojourn target becomes an
//! aggregate-capacity floor via the pooled-server bound of
//! [`crate::workload::serving::effective_min_throughput`]. The same
//! soft-slack machinery covers transient latency infeasibility.

use std::cmp::Ordering;
use std::collections::{BTreeMap, BTreeSet};

use super::branch_bound::{solve_ilp, BnbConfig, BnbResult, BnbStatus};
use super::model::{Model, ObjSense, Sense, VarId, VarKind};
use crate::power::{column_cost, PowerKnobs};
use crate::workload::{AccelType, Combo, JobId, JobSpec, ACCEL_TYPES};

/// Semantic simplex basis of a Problem 1 solve: the `(type, combo)`
/// columns basic at the root LP optimum. Variable indices shift between
/// arrivals as the column set changes, so the basis is exported in this
/// index-free form and re-mapped onto the next model's columns by
/// [`solve_problem1_with_basis`]; columns that no longer exist are
/// silently dropped (stale-hint tolerance).
pub type ColumnBasis = Vec<(AccelType, Combo)>;

/// Inputs to the allocation ILP.
pub struct Problem1Input<'a> {
    /// Active jobs 𝒥.
    pub jobs: &'a [JobSpec],
    /// Instances available per accelerator type.
    pub accel_counts: &'a BTreeMap<AccelType, u32>,
    /// Estimated (or measured) normalized throughput T̃^c_{a,j}.
    pub throughput: &'a dyn Fn(AccelType, JobId, &Combo) -> f64,
    /// Solo capability of type `a` (denominator of the relative load fed
    /// to γ_a): the best solo throughput any current job achieves on it.
    pub solo_capability: &'a dyn Fn(AccelType) -> f64,
    /// Max candidate pair-combos per job (0 = solos only). Pruning keeps
    /// the ILP tractable online; pairs are ranked by estimated combined
    /// throughput.
    pub max_pairs_per_job: usize,
    /// Penalty (objective units per unit of slack) for SLO softening.
    /// `None` builds the paper's hard formulation.
    pub slack_penalty: Option<f64>,
    /// Lagrangian throughput bonus λ (watts credited per unit of
    /// normalized throughput delivered). The paper's objective (2a) is
    /// pure instantaneous power (λ = 0), but that *slow-walks* jobs onto
    /// legacy GPUs — power drops while completion times, contention and
    /// total joules rise (a v100 delivers ~3× more work per joule than a
    /// k80 here). λ > 0 makes the per-column cost `γ_a(u) − λ·ΣT`, i.e.
    /// approximately energy-per-work, while keeping Problem 1 linear.
    /// `benches/e2e_scheduling.rs` quantifies the difference; λ = 0
    /// reproduces the paper's literal objective.
    pub throughput_bonus: f64,
    /// Simulated time the solve happens at — evaluates each inference
    /// job's diurnal request rate λ(t) for the latency-feasibility
    /// constraint 2e′ (irrelevant to pure-training pools; pass 0.0).
    pub now_s: f64,
    /// Power-subsystem knobs (docs/POWER.md): with DVFS on, each column
    /// cost is the minimum over the host's power states; the carbon
    /// weight scales the energy term. The default reproduces the
    /// pre-power objective bit-for-bit.
    pub power: PowerKnobs,
}

/// Decoded solution.
#[derive(Debug, Clone)]
pub struct AllocationSolution {
    /// (accel type, combo, multiplicity) with multiplicity ≥ 1.
    pub assignments: Vec<(AccelType, Combo, u32)>,
    /// jobs whose coverage or SLO slack is active (soft mode only).
    pub violated_jobs: Vec<JobId>,
    pub objective: f64,
    pub status: BnbStatus,
    pub nodes: usize,
    /// relative optimality gap at termination (0 = proved optimal)
    pub gap: f64,
    /// total simplex pivots across every node LP (per-node cost metric)
    pub lp_pivots: u64,
    /// whether a greedy/explicit incumbent seeded the search
    pub warm_started: bool,
    /// root LP basis in `(type, combo)` form, exported only by
    /// [`solve_problem1_with_basis`] — feed it back as the next
    /// arrival's hint to chain bases across solves
    pub basis: Option<ColumnBasis>,
}

/// Aggregate a concrete instance pool into the per-type capacity map of
/// [`Problem1Input::accel_counts`] — the pool-scoped problem build used
/// by the shard workers, the incremental arrival path and the full
/// re-solve (whose pool is the whole in-service cluster).
pub fn pool_accel_counts(pool: &[crate::cluster::AccelId]) -> BTreeMap<AccelType, u32> {
    let mut counts: BTreeMap<AccelType, u32> = BTreeMap::new();
    for a in pool {
        *counts.entry(a.accel).or_default() += 1;
    }
    counts
}

/// Constraint 2e′ — the latency-feasibility pre-pass: every inference
/// job's throughput row carries the capacity floor its latency SLO
/// implies at time `now_s` (pooled-server bound + utilization cap, see
/// [`crate::workload::serving`]); training jobs pass through untouched.
/// [`solve_problem1`] applies this automatically; callers of
/// [`build_problem1`] that host inference jobs should apply it first.
pub fn latency_adjusted_jobs(jobs: &[JobSpec], now_s: f64) -> Vec<JobSpec> {
    jobs.iter()
        .map(|j| {
            let mut j = j.clone();
            j.min_throughput = crate::workload::serving::effective_min_throughput(&j, now_s);
            j
        })
        .collect()
}

/// Build the candidate combination universe 𝒞 (solos + pruned pairs).
pub fn candidate_combos(
    jobs: &[JobSpec],
    throughput: &dyn Fn(AccelType, JobId, &Combo) -> f64,
    max_pairs_per_job: usize,
) -> Vec<Combo> {
    let mut combos: Vec<Combo> = jobs.iter().map(|j| Combo::Solo(j.id)).collect();
    if max_pairs_per_job == 0 || jobs.len() < 2 {
        return combos;
    }
    // score pairs by combined v100 estimated throughput, keep top-K per job
    let mut scored: Vec<(f64, Combo)> = vec![];
    for (i, a) in jobs.iter().enumerate() {
        for b in jobs.iter().skip(i + 1) {
            let c = Combo::pair(a.id, b.id);
            let s = throughput(AccelType::V100, a.id, &c) + throughput(AccelType::V100, b.id, &c);
            scored.push((s, c));
        }
    }
    scored.sort_by(|x, y| y.0.partial_cmp(&x.0).unwrap());
    let mut per_job: BTreeMap<JobId, usize> = BTreeMap::new();
    for (_, c) in scored {
        let js = c.jobs();
        if js.iter().all(|j| per_job.get(j).copied().unwrap_or(0) < max_pairs_per_job) {
            for j in &js {
                *per_job.entry(*j).or_default() += 1;
            }
            combos.push(c);
        }
    }
    combos
}

/// Build and solve Problem 1. Returns `None` only if the hard
/// formulation is infeasible (use `slack_penalty` to avoid that).
pub fn build_problem1(
    input: &Problem1Input,
    bnb: &BnbConfig,
) -> (
    Model,
    Vec<(AccelType, Combo, VarId)>,
    BTreeMap<JobId, (Option<VarId>, Option<VarId>)>,
) {
    let _ = bnb;
    let combos = candidate_combos(input.jobs, input.throughput, input.max_pairs_per_job);
    build_model(input, &combos)
}

/// Assemble the Problem 1 model over an already-chosen candidate
/// universe — the shared back half of [`build_problem1`] and the
/// incremental [`Problem1Builder`] path.
fn build_model(
    input: &Problem1Input,
    combos: &[Combo],
) -> (
    Model,
    Vec<(AccelType, Combo, VarId)>,
    BTreeMap<JobId, (Option<VarId>, Option<VarId>)>,
) {
    let mut model = Model::new(ObjSense::Minimize);

    // n_{a,c} variables with per-column energy coefficients.
    let mut cols: Vec<(AccelType, Combo, VarId)> = vec![];
    for &a in ACCEL_TYPES.iter() {
        let count = input.accel_counts.get(&a).copied().unwrap_or(0);
        if count == 0 {
            continue;
        }
        for c in combos {
            if c.len() as u32 > a.capacity() {
                continue; // constraint (2d) by pruning
            }
            let total_t: f64 = c.jobs().iter().map(|&j| (input.throughput)(a, j, c)).sum();
            if total_t <= 1e-9 {
                continue; // useless column
            }
            let u = (total_t / (input.solo_capability)(a).max(1e-9)).clamp(0.0, 1.0);
            let energy = column_cost(a, u, total_t, input.throughput_bonus, input.power);
            let v = model.add_var(
                format!("n[{},{:?}]", a.name(), c),
                0.0,
                count as f64,
                VarKind::Integer,
                energy,
            );
            cols.push((a, *c, v));
        }
    }

    // Per-job slack (soft mode).
    let mut slacks: BTreeMap<JobId, (Option<VarId>, Option<VarId>)> = BTreeMap::new();
    for j in input.jobs {
        let (mut cover_s, mut thr_s) = (None, None);
        if let Some(p) = input.slack_penalty {
            // Tier weighting: slack on a Critical job costs 4× the
            // Standard rate and slack on a Best job 1/4 of it, so under
            // contention the optimizer sheds SLOs bottom-tier first.
            // Standard's weight is 1.0, keeping priority-free runs
            // bit-identical to the unweighted formulation.
            let w = j.priority.weight();
            cover_s = Some(model.add_var(
                format!("sc[{}]", j.id),
                0.0,
                1.0,
                VarKind::Continuous,
                4.0 * p * w,
            ));
            thr_s = Some(model.add_var(
                format!("st[{}]", j.id),
                0.0,
                j.min_throughput.max(0.0),
                VarKind::Continuous,
                w * p / j.min_throughput.max(1e-3),
            ));
        }
        slacks.insert(j.id, (cover_s, thr_s));
    }

    // (2b) coverage + (2c) distributability + (2e) throughput
    for j in input.jobs {
        let owned: Vec<&(AccelType, Combo, VarId)> =
            cols.iter().filter(|(_, c, _)| c.contains(j.id)).collect();
        let mut cover_terms: Vec<(VarId, f64)> = owned.iter().map(|(_, _, v)| (*v, 1.0)).collect();
        if let (Some(sc), _) = slacks[&j.id] {
            cover_terms.push((sc, 1.0));
        }
        model.add_constraint(format!("cover[{}]", j.id), cover_terms, Sense::Ge, 1.0);

        let dist_terms: Vec<(VarId, f64)> = owned.iter().map(|(_, _, v)| (*v, 1.0)).collect();
        model.add_constraint(
            format!("dist[{}]", j.id),
            dist_terms,
            Sense::Le,
            j.distributability as f64,
        );

        let mut thr_terms: Vec<(VarId, f64)> = owned
            .iter()
            .map(|(a, c, v)| (*v, (input.throughput)(*a, j.id, c)))
            .collect();
        if let (_, Some(st)) = slacks[&j.id] {
            thr_terms.push((st, 1.0));
        }
        model.add_constraint(
            format!("thr[{}]", j.id),
            thr_terms,
            Sense::Ge,
            j.min_throughput,
        );
    }

    // (2f) instances per type
    for &a in ACCEL_TYPES.iter() {
        let count = input.accel_counts.get(&a).copied().unwrap_or(0);
        let terms: Vec<(VarId, f64)> = cols
            .iter()
            .filter(|(aa, _, _)| *aa == a)
            .map(|(_, _, v)| (*v, 1.0))
            .collect();
        if !terms.is_empty() {
            model.add_constraint(format!("cap[{}]", a.name()), terms, Sense::Le, count as f64);
        }
    }

    (model, cols, slacks)
}

/// Solve Problem 1 end-to-end and decode the solution.
///
/// When `bnb.auto_warm_start` is set (the default) and no explicit
/// incumbent was supplied, the search is seeded from
/// [`crate::baselines::greedy::greedy_incumbent`] — the energy-aware
/// greedy packing of the `baselines` layer — so pruning bites from the
/// first node. Without it the allocation trees at |J| ≥ 12 explore tens
/// of thousands of nodes before the first feasible point (measured by
/// `benches/ilp_scaling.rs`, asserted by `tests/warm_start.rs`).
pub fn solve_problem1(input: &Problem1Input, bnb: &BnbConfig) -> AllocationSolution {
    solve_problem1_impl(input, bnb, None, None)
}

/// [`solve_problem1`] with basis chaining: the previous arrival's
/// [`AllocationSolution::basis`] crash-starts this solve's LPs, and the
/// returned solution carries the new basis for the next arrival. An
/// empty hint still turns chaining on (first arrival of a sequence).
/// A stale hint only costs crash pivots — the optimum is unchanged
/// (asserted by `basis_chaining_reaches_the_same_optimum` below).
pub fn solve_problem1_with_basis(
    input: &Problem1Input,
    bnb: &BnbConfig,
    hint: &ColumnBasis,
) -> AllocationSolution {
    solve_problem1_impl(input, bnb, None, Some(hint))
}

fn solve_problem1_impl(
    input: &Problem1Input,
    bnb: &BnbConfig,
    combos: Option<&[Combo]>,
    hint: Option<&ColumnBasis>,
) -> AllocationSolution {
    // 2e′: fold each inference job's latency SLO into its throughput
    // row before the model is built (no-op — and no clone — for the
    // common pure-training pool).
    let adjusted: Option<Vec<JobSpec>> = input
        .jobs
        .iter()
        .any(|j| j.is_inference())
        .then(|| latency_adjusted_jobs(input.jobs, input.now_s));
    let input = &Problem1Input {
        jobs: adjusted.as_deref().unwrap_or(input.jobs),
        ..*input
    };
    let fresh: Vec<Combo>;
    let combos = match combos {
        Some(c) => c,
        None => {
            fresh = candidate_combos(input.jobs, input.throughput, input.max_pairs_per_job);
            &fresh
        }
    };
    let (model, cols, slacks) = build_model(input, combos);
    solve_built(input, bnb, &model, &cols, &slacks, hint)
}

/// Run the branch-and-bound over an already-built model (shared by the
/// from-scratch path and the [`Problem1Builder`] cached-matrix path).
fn solve_built(
    input: &Problem1Input,
    bnb: &BnbConfig,
    model: &Model,
    cols: &[(AccelType, Combo, VarId)],
    slacks: &BTreeMap<JobId, (Option<VarId>, Option<VarId>)>,
    hint: Option<&ColumnBasis>,
) -> AllocationSolution {
    let mut bnb = bnb.clone();
    if bnb.warm_start.is_none() && bnb.auto_warm_start {
        bnb.warm_start = crate::baselines::greedy::greedy_incumbent(input, model, cols, slacks);
    }
    if let Some(hint) = hint {
        // map the semantic (type, combo) basis onto this model's
        // columns; combos that left the candidate universe vanish
        let mapped: Vec<usize> = hint
            .iter()
            .filter_map(|(a, c)| {
                cols.iter().find(|(a2, c2, _)| a2 == a && c2 == c).map(|(_, _, v)| v.0)
            })
            .collect();
        bnb.basis_hint = Some(mapped);
    }
    let r: BnbResult = solve_ilp(model, &bnb);
    decode(&r, cols, slacks)
}

/// Incremental Problem 1 construction (scale-out lever 3): instead of
/// re-deriving the candidate universe and re-assembling the constraint
/// matrix from scratch on every arrival, the builder keeps the job set,
/// the capacity map, the scored pair list and the last-built model
/// alive, and applies job-add / job-remove / accelerator-churn edits
/// with dirty tracking. An arrival costs O(|J|) pair scorings instead
/// of the O(|J|²) full rescan, and a re-solve with no edits at all
/// (measurement rounds on a quiet cluster) reuses the entire matrix.
///
/// Equivalence contract: after any edit sequence,
/// [`Problem1Builder::build`] produces exactly the model
/// [`build_problem1`] would derive from the final state
/// (property-tested in `tests/proptests.rs`). The pair list is
/// maintained in `candidate_combos`' canonical order — score
/// descending, ties by ascending id pair, which is what its stable
/// sort over id-ordered generation yields — so the reuse is bit-exact.
///
/// Estimates are read through the caller's `throughput` closure (the
/// coordinator backs it with its `EstimateCache`); when entries behind
/// it change, call [`Problem1Builder::note_estimates_changed`] so the
/// stored pair scores and the cached matrix are refreshed.
pub struct Problem1Builder {
    max_pairs_per_job: usize,
    jobs: BTreeMap<JobId, JobSpec>,
    accel_counts: BTreeMap<AccelType, u32>,
    /// every candidate pair with its v100 combined-throughput score, in
    /// canonical order (see above)
    scored_pairs: Vec<(f64, Combo)>,
    rescore: bool,
    cached: Option<CachedModel>,
    /// edit / reuse counters for §Perf reporting
    pub edits: u64,
    pub pairs_scored: u64,
    pub model_rebuilds: u64,
    pub model_reuses: u64,
}

struct CachedModel {
    key: ModelKey,
    model: Model,
    cols: Vec<(AccelType, Combo, VarId)>,
    slacks: BTreeMap<JobId, (Option<VarId>, Option<VarId>)>,
}

/// Everything besides jobs / capacities / estimates that shapes the
/// model — a key mismatch forces a rebuild.
#[derive(Debug, Clone, PartialEq)]
struct ModelKey {
    /// `now_s` when any job is latency-constrained (2e′ reads the
    /// diurnal rate), else 0.0 so pure-training pools reuse the matrix
    /// across arrivals at any simulated time
    now_s: f64,
    slack_penalty: Option<f64>,
    throughput_bonus: f64,
    dvfs: bool,
    carbon_weight: f64,
}

impl ModelKey {
    fn of(input: &Problem1Input) -> Self {
        let latency = input.jobs.iter().any(|j| j.is_inference());
        Self {
            now_s: if latency { input.now_s } else { 0.0 },
            slack_penalty: input.slack_penalty,
            throughput_bonus: input.throughput_bonus,
            dvfs: input.power.dvfs,
            carbon_weight: input.power.carbon_weight,
        }
    }
}

fn pair_ids(c: &Combo) -> (JobId, JobId) {
    let js = c.jobs();
    (js[0], js[js.len() - 1])
}

impl Problem1Builder {
    pub fn new(max_pairs_per_job: usize) -> Self {
        Self {
            max_pairs_per_job,
            jobs: BTreeMap::new(),
            accel_counts: BTreeMap::new(),
            scored_pairs: vec![],
            rescore: false,
            cached: None,
            edits: 0,
            pairs_scored: 0,
            model_rebuilds: 0,
            model_reuses: 0,
        }
    }

    /// Jobs currently in the problem, ascending id (the order the
    /// optimizer passes to [`Problem1Input`]).
    pub fn jobs_sorted(&self) -> Vec<JobSpec> {
        self.jobs.values().cloned().collect()
    }

    pub fn accel_counts(&self) -> &BTreeMap<AccelType, u32> {
        &self.accel_counts
    }

    /// Add (or replace) a job: only its own O(|J|) pairs are scored.
    pub fn add_job(&mut self, job: JobSpec, throughput: &dyn Fn(AccelType, JobId, &Combo) -> f64) {
        self.remove_job(job.id);
        let others: Vec<JobId> = self.jobs.keys().copied().collect();
        for other in others {
            let c = Combo::pair(other, job.id);
            let s: f64 = c.jobs().iter().map(|&j| throughput(AccelType::V100, j, &c)).sum();
            let slot = self.pair_slot(s, pair_ids(&c));
            self.scored_pairs.insert(slot, (s, c));
            self.pairs_scored += 1;
        }
        self.jobs.insert(job.id, job);
        self.cached = None;
        self.edits += 1;
    }

    /// Drop a job and every pair containing it.
    pub fn remove_job(&mut self, id: JobId) -> bool {
        if self.jobs.remove(&id).is_none() {
            return false;
        }
        self.scored_pairs.retain(|(_, c)| !c.contains(id));
        self.cached = None;
        self.edits += 1;
        true
    }

    /// Apply accelerator churn: replace the capacity map.
    pub fn set_accel_counts(&mut self, counts: BTreeMap<AccelType, u32>) {
        if self.accel_counts != counts {
            self.accel_counts = counts;
            self.cached = None;
            self.edits += 1;
        }
    }

    /// Estimates behind the throughput closure changed (measurement or
    /// P2 refinement round): stored pair scores and the cached matrix
    /// are stale and will be refreshed at the next build.
    pub fn note_estimates_changed(&mut self) {
        self.rescore = true;
        self.cached = None;
    }

    /// Reconcile against the scheduler's current job list (ascending
    /// id): jobs that disappeared are removed, new or changed specs
    /// (re-)added. This is how an arrival, completion or elastic
    /// re-spec lands as an O(changes) edit instead of a rebuild.
    pub fn sync_jobs(
        &mut self,
        jobs: &[JobSpec],
        throughput: &dyn Fn(AccelType, JobId, &Combo) -> f64,
    ) {
        let target: BTreeSet<JobId> = jobs.iter().map(|j| j.id).collect();
        let gone: Vec<JobId> =
            self.jobs.keys().filter(|id| !target.contains(id)).copied().collect();
        for id in gone {
            self.remove_job(id);
        }
        for j in jobs {
            if self.jobs.get(&j.id) != Some(j) {
                self.add_job(j.clone(), throughput);
            }
        }
    }

    /// Canonical insertion slot: descending score, ties by ascending
    /// id pair (exactly `candidate_combos`' stable-sort order).
    fn pair_slot(&self, score: f64, ids: (JobId, JobId)) -> usize {
        self.scored_pairs
            .partition_point(|(s, c)| *s > score || (*s == score && pair_ids(c) < ids))
    }

    /// Candidate universe for the current state, reusing stored pair
    /// scores (rescored only after [`Problem1Builder::note_estimates_changed`]).
    fn combos(&mut self, throughput: &dyn Fn(AccelType, JobId, &Combo) -> f64) -> Vec<Combo> {
        if self.rescore {
            for (s, c) in &mut self.scored_pairs {
                *s = c.jobs().iter().map(|&j| throughput(AccelType::V100, j, c)).sum();
                self.pairs_scored += 1;
            }
            self.scored_pairs.sort_by(|x, y| {
                y.0.partial_cmp(&x.0)
                    .unwrap_or(Ordering::Equal)
                    .then_with(|| pair_ids(&x.1).cmp(&pair_ids(&y.1)))
            });
            self.rescore = false;
        }
        let mut combos: Vec<Combo> = self.jobs.keys().map(|&j| Combo::Solo(j)).collect();
        if self.max_pairs_per_job == 0 || self.jobs.len() < 2 {
            return combos;
        }
        let mut per_job: BTreeMap<JobId, usize> = BTreeMap::new();
        for (_, c) in &self.scored_pairs {
            let js = c.jobs();
            if js.iter().all(|j| per_job.get(j).copied().unwrap_or(0) < self.max_pairs_per_job) {
                for j in &js {
                    *per_job.entry(*j).or_default() += 1;
                }
                combos.push(*c);
            }
        }
        combos
    }

    /// Build (or reuse) the constraint matrix for the current state.
    /// `input.jobs` must be this builder's [`Problem1Builder::jobs_sorted`]
    /// list, with 2e′ already folded in by the caller when relevant.
    pub fn build(
        &mut self,
        input: &Problem1Input,
    ) -> (
        &Model,
        &[(AccelType, Combo, VarId)],
        &BTreeMap<JobId, (Option<VarId>, Option<VarId>)>,
    ) {
        debug_assert_eq!(input.jobs.len(), self.jobs.len());
        debug_assert_eq!(input.max_pairs_per_job, self.max_pairs_per_job);
        let key = ModelKey::of(input);
        if self.cached.as_ref().map_or(true, |c| c.key != key) {
            let combos = self.combos(input.throughput);
            let (model, cols, slacks) = build_model(input, &combos);
            self.cached = Some(CachedModel {
                key,
                model,
                cols,
                slacks,
            });
            self.model_rebuilds += 1;
        } else {
            self.model_reuses += 1;
        }
        let c = self.cached.as_ref().expect("just built");
        (&c.model, &c.cols, &c.slacks)
    }

    /// Solve Problem 1 through the cached matrix, with optional basis
    /// chaining. 2e′ latency folding matches [`solve_problem1`].
    pub fn solve(
        &mut self,
        input: &Problem1Input,
        bnb: &BnbConfig,
        hint: Option<&ColumnBasis>,
    ) -> AllocationSolution {
        let adjusted: Option<Vec<JobSpec>> = input
            .jobs
            .iter()
            .any(|j| j.is_inference())
            .then(|| latency_adjusted_jobs(input.jobs, input.now_s));
        let input = &Problem1Input {
            jobs: adjusted.as_deref().unwrap_or(input.jobs),
            ..*input
        };
        let (model, cols, slacks) = self.build(input);
        solve_built(input, bnb, model, cols, slacks, hint)
    }
}

fn decode(
    r: &BnbResult,
    cols: &[(AccelType, Combo, VarId)],
    slacks: &BTreeMap<JobId, (Option<VarId>, Option<VarId>)>,
) -> AllocationSolution {
    let mut assignments = vec![];
    let mut violated = vec![];
    if matches!(r.status, BnbStatus::Optimal | BnbStatus::Feasible) {
        for (a, c, v) in cols {
            let mult = r.x[v.0].round() as u32;
            if mult > 0 {
                assignments.push((*a, *c, mult));
            }
        }
        for (j, (sc, st)) in slacks {
            let viol = sc.map_or(false, |v| r.x[v.0] > 1e-6)
                || st.map_or(false, |v| r.x[v.0] > 1e-6);
            if viol {
                violated.push(*j);
            }
        }
        violated.sort();
    }
    // Re-map the root basis (original var indices) onto (type, combo)
    // pairs; slack variables are per-job and never transfer, so only
    // structural columns survive the export.
    let basis = r.root_basis.as_ref().map(|b| {
        b.iter()
            .filter_map(|&i| {
                cols.iter().find(|(_, _, v)| v.0 == i).map(|(a, c, _)| (*a, *c))
            })
            .collect()
    });
    AllocationSolution {
        assignments,
        violated_jobs: violated,
        objective: r.objective,
        status: r.status,
        nodes: r.nodes,
        gap: r.gap(),
        lp_pivots: r.lp_pivots,
        warm_started: r.warm_started,
        basis,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{ModelFamily, ThroughputOracle};

    fn mk_jobs(n: u32, oracle: &ThroughputOracle) -> Vec<JobSpec> {
        let fams = [
            ModelFamily::ResNet18,
            ModelFamily::ResNet50,
            ModelFamily::Transformer,
            ModelFamily::LanguageModel,
            ModelFamily::Recommendation,
        ];
        (0..n)
            .map(|i| {
                let f = fams[i as usize % fams.len()];
                let b = f.batch_sizes()[i as usize % f.batch_sizes().len()];
                let mut j = JobSpec {
                    id: JobId(i),
                    family: f,
                    batch_size: b,
                    replication: 1,
                    min_throughput: 0.0,
                    distributability: 2,
                    work: 100.0,
                    priority: Default::default(),
                    elastic: false,
                    inference: None,
                };
                j.min_throughput = 0.4 * oracle.solo(&j, AccelType::P100);
                j
            })
            .collect()
    }

    fn oracle_input<'a>(
        jobs: &'a [JobSpec],
        oracle: &'a ThroughputOracle,
        counts: &'a BTreeMap<AccelType, u32>,
        thr: &'a dyn Fn(AccelType, JobId, &Combo) -> f64,
        cap: &'a dyn Fn(AccelType) -> f64,
    ) -> Problem1Input<'a> {
        Problem1Input {
            jobs,
            accel_counts: counts,
            throughput: thr,
            solo_capability: cap,
            max_pairs_per_job: 3,
            slack_penalty: None,
            throughput_bonus: 0.0,
            now_s: 0.0,
            power: PowerKnobs::default(),
        }
        .with(oracle)
    }

    impl<'a> Problem1Input<'a> {
        fn with(self, _o: &'a ThroughputOracle) -> Self {
            self
        }
    }

    fn setup(
        n: u32,
        per_type: u32,
    ) -> (
        Vec<JobSpec>,
        ThroughputOracle,
        BTreeMap<AccelType, u32>,
    ) {
        let oracle = ThroughputOracle::new(11);
        let jobs = mk_jobs(n, &oracle);
        let counts: BTreeMap<AccelType, u32> =
            ACCEL_TYPES.iter().map(|&a| (a, per_type)).collect();
        (jobs, oracle, counts)
    }

    #[test]
    fn every_job_covered_and_slo_met() {
        let (jobs, oracle, counts) = setup(6, 2);
        let jobs_c = jobs.clone();
        let oracle_c = oracle.clone();
        let thr = move |a: AccelType, j: JobId, c: &Combo| -> f64 {
            let spec = jobs_c.iter().find(|s| s.id == j).unwrap();
            let lookup = |id: JobId| jobs_c.iter().find(|s| s.id == id).cloned();
            oracle_c.throughput(spec, c, a, &lookup)
        };
        let cap = |a: AccelType| a.base_speed() / 5.0; // v100-normalized
        let input = oracle_input(&jobs, &oracle, &counts, &thr, &cap);
        let sol = solve_problem1(&input, &BnbConfig::default());
        assert!(matches!(sol.status, BnbStatus::Optimal | BnbStatus::Feasible), "{:?}", sol.status);
        // coverage + SLO per job
        for j in &jobs {
            let total: f64 = sol
                .assignments
                .iter()
                .filter(|(_, c, _)| c.contains(j.id))
                .map(|(a, c, mult)| thr(*a, j.id, c) * *mult as f64)
                .sum();
            assert!(total >= j.min_throughput - 1e-6, "{}: {total} < {}", j.id, j.min_throughput);
        }
        // capacity per type
        for &a in ACCEL_TYPES.iter() {
            let used: u32 = sol
                .assignments
                .iter()
                .filter(|(aa, _, _)| *aa == a)
                .map(|(_, _, m)| m)
                .sum();
            assert!(used <= counts[&a]);
        }
    }

    #[test]
    fn infeasible_without_slack_feasible_with() {
        // 4 jobs, 1 accelerator of each of only k80 types → too slow for
        // harsh SLOs.
        let oracle = ThroughputOracle::new(11);
        let mut jobs = mk_jobs(4, &oracle);
        for j in &mut jobs {
            j.min_throughput = 0.95; // nearly the global max: only feasible on the best GPU solo
            j.distributability = 1;
        }
        let mut counts = BTreeMap::new();
        counts.insert(AccelType::K80, 4u32);
        let jobs_c = jobs.clone();
        let oracle_c = oracle.clone();
        let thr = move |a: AccelType, j: JobId, c: &Combo| -> f64 {
            let spec = jobs_c.iter().find(|s| s.id == j).unwrap();
            let lookup = |id: JobId| jobs_c.iter().find(|s| s.id == id).cloned();
            oracle_c.throughput(spec, c, a, &lookup)
        };
        let cap = |a: AccelType| a.base_speed() / 5.0;
        let hard = Problem1Input {
            jobs: &jobs,
            accel_counts: &counts,
            throughput: &thr,
            solo_capability: &cap,
            max_pairs_per_job: 2,
            slack_penalty: None,
            throughput_bonus: 0.0,
            now_s: 0.0,
            power: PowerKnobs::default(),
        };
        let sol = solve_problem1(&hard, &BnbConfig::default());
        assert_eq!(sol.status, BnbStatus::Infeasible);

        let soft = Problem1Input {
            slack_penalty: Some(1000.0),
            ..hard
        };
        let sol = solve_problem1(&soft, &BnbConfig::default());
        assert!(matches!(sol.status, BnbStatus::Optimal | BnbStatus::Feasible));
        assert!(!sol.violated_jobs.is_empty());
    }

    #[test]
    fn prefers_energy_efficient_packing() {
        // One tiny job with a loose SLO: the optimizer should pick the
        // cheapest-energy placement, not the fastest GPU.
        let oracle = ThroughputOracle::new(11);
        let mut jobs = mk_jobs(1, &oracle);
        jobs[0].min_throughput = 0.05 * oracle.solo(&jobs[0], AccelType::K80);
        let counts: BTreeMap<AccelType, u32> = ACCEL_TYPES.iter().map(|&a| (a, 1)).collect();
        let jobs_c = jobs.clone();
        let oracle_c = oracle.clone();
        let thr = move |a: AccelType, j: JobId, c: &Combo| -> f64 {
            let spec = jobs_c.iter().find(|s| s.id == j).unwrap();
            let lookup = |id: JobId| jobs_c.iter().find(|s| s.id == id).cloned();
            oracle_c.throughput(spec, c, a, &lookup)
        };
        let cap = |a: AccelType| a.base_speed() / 5.0;
        let input = Problem1Input {
            jobs: &jobs,
            accel_counts: &counts,
            throughput: &thr,
            solo_capability: &cap,
            max_pairs_per_job: 0,
            slack_penalty: None,
            throughput_bonus: 0.0,
            now_s: 0.0,
            power: PowerKnobs::default(),
        };
        let sol = solve_problem1(&input, &BnbConfig::default());
        assert_eq!(sol.assignments.len(), 1);
        let (a, _, _) = sol.assignments[0];
        // k80 idle+load power < v100's → must not pick a v100
        assert_ne!(a.consolidated(), AccelType::V100, "picked {a:?}");
    }

    #[test]
    fn distributability_allows_splitting_for_throughput() {
        // SLO above any single accelerator's capability; D_j = 2 lets the
        // job run on two instances whose sum meets the SLO.
        let oracle = ThroughputOracle::new(11);
        let mut jobs = mk_jobs(1, &oracle);
        let best = crate::workload::ACCEL_TYPES
            .iter()
            .map(|&a| oracle.solo(&jobs[0], a))
            .fold(0.0f64, f64::max);
        jobs[0].min_throughput = 1.5 * best;
        jobs[0].distributability = 2;
        let counts: BTreeMap<AccelType, u32> = ACCEL_TYPES.iter().map(|&a| (a, 2)).collect();
        let jobs_c = jobs.clone();
        let oracle_c = oracle.clone();
        let thr = move |a: AccelType, j: JobId, c: &Combo| -> f64 {
            let spec = jobs_c.iter().find(|s| s.id == j).unwrap();
            let lookup = |id: JobId| jobs_c.iter().find(|s| s.id == id).cloned();
            oracle_c.throughput(spec, c, a, &lookup)
        };
        let cap = |a: AccelType| a.base_speed() / 5.0;
        let input = Problem1Input {
            jobs: &jobs,
            accel_counts: &counts,
            throughput: &thr,
            solo_capability: &cap,
            max_pairs_per_job: 0,
            slack_penalty: None,
            throughput_bonus: 0.0,
            now_s: 0.0,
            power: PowerKnobs::default(),
        };
        let sol = solve_problem1(&input, &BnbConfig::default());
        assert!(matches!(sol.status, BnbStatus::Optimal | BnbStatus::Feasible));
        let total_mult: u32 = sol.assignments.iter().map(|(_, _, m)| m).sum();
        assert_eq!(total_mult, 2, "{:?}", sol.assignments);
    }

    #[test]
    fn throughput_bonus_prefers_efficient_fast_gpus() {
        // λ = 0 (paper-literal) parks a loose-SLO job on a low-power GPU;
        // λ = 300 makes energy-per-work the effective criterion → v100.
        let oracle = ThroughputOracle::new(11);
        let mut jobs = mk_jobs(1, &oracle);
        jobs[0].min_throughput = 0.05 * oracle.solo(&jobs[0], AccelType::K80);
        let counts: BTreeMap<AccelType, u32> = ACCEL_TYPES.iter().map(|&a| (a, 1)).collect();
        let jobs_c = jobs.clone();
        let oracle_c = oracle.clone();
        let thr = move |a: AccelType, j: JobId, c: &Combo| -> f64 {
            let spec = jobs_c.iter().find(|s| s.id == j).unwrap();
            let lookup = |id: JobId| jobs_c.iter().find(|s| s.id == id).cloned();
            oracle_c.throughput(spec, c, a, &lookup)
        };
        let cap = |a: AccelType| a.base_speed() / 5.0;
        let solve = |bonus: f64| {
            let input = Problem1Input {
                jobs: &jobs,
                accel_counts: &counts,
                throughput: &thr,
                solo_capability: &cap,
                max_pairs_per_job: 0,
                slack_penalty: None,
                throughput_bonus: bonus,
                now_s: 0.0,
                power: PowerKnobs::default(),
            };
            solve_problem1(&input, &BnbConfig::default())
        };
        let literal = solve(0.0);
        let bonus = solve(300.0);
        assert_ne!(literal.assignments[0].0.consolidated(), AccelType::V100);
        assert_eq!(bonus.assignments[0].0.consolidated(), AccelType::V100);
    }

    #[test]
    fn latency_slo_provisions_replicas() {
        // A serving job whose latency floor exceeds any single GPU's
        // capability must receive several replicas (constraint 2e′ on
        // the replica-count variables), while a relaxed SLO needs one.
        let oracle = ThroughputOracle::new(11);
        let mut jobs = mk_jobs(1, &oracle);
        let best = ACCEL_TYPES
            .iter()
            .map(|&a| oracle.solo(&jobs[0], a))
            .fold(0.0f64, f64::max);
        let lam = crate::workload::serving::service_rate(1.4 * best);
        jobs[0].min_throughput = 0.0;
        jobs[0].distributability = 3;
        jobs[0].inference = Some(crate::workload::InferenceSpec {
            base_rate: lam,
            diurnal_amplitude: 0.0,
            diurnal_phase_s: 0.0,
            latency_slo_s: 10.0 / lam.max(1e-9),
        });
        let counts: BTreeMap<AccelType, u32> = ACCEL_TYPES.iter().map(|&a| (a, 3)).collect();
        let jobs_c = jobs.clone();
        let oracle_c = oracle.clone();
        let thr = move |a: AccelType, j: JobId, c: &Combo| -> f64 {
            let spec = jobs_c.iter().find(|s| s.id == j).unwrap();
            let lookup = |id: JobId| jobs_c.iter().find(|s| s.id == id).cloned();
            oracle_c.throughput(spec, c, a, &lookup)
        };
        let cap = |a: AccelType| a.base_speed() / 5.0;
        let solve = |jobs: &[JobSpec]| {
            let input = Problem1Input {
                jobs,
                accel_counts: &counts,
                throughput: &thr,
                solo_capability: &cap,
                max_pairs_per_job: 0,
                slack_penalty: None,
                throughput_bonus: 0.0,
                now_s: 0.0,
                power: PowerKnobs::default(),
            };
            solve_problem1(&input, &BnbConfig::default())
        };
        let tight = solve(&jobs);
        assert!(matches!(tight.status, BnbStatus::Optimal | BnbStatus::Feasible));
        let replicas: u32 = tight.assignments.iter().map(|(_, _, m)| m).sum();
        assert!(replicas >= 2, "tight SLO got only {replicas} replica(s)");
        assert!(replicas <= jobs[0].distributability);

        // a very relaxed SLO and tiny rate needs a single replica
        let mut loose = jobs.clone();
        loose[0].inference = Some(crate::workload::InferenceSpec {
            base_rate: 0.05 * lam,
            diurnal_amplitude: 0.0,
            diurnal_phase_s: 0.0,
            latency_slo_s: 1000.0 / lam.max(1e-9),
        });
        let sol = solve(&loose);
        let replicas: u32 = sol.assignments.iter().map(|(_, _, m)| m).sum();
        assert_eq!(replicas, 1, "{:?}", sol.assignments);
    }

    #[test]
    fn tier_weight_sheds_best_effort_job_first() {
        // Two identical jobs, one K80, solos only, D_j = 1: exactly one
        // job can be covered. The Critical job's slack costs 16× the
        // Best job's, so the optimizer must shed the Best-effort one.
        let oracle = ThroughputOracle::new(11);
        let mut jobs = mk_jobs(2, &oracle);
        jobs[1].family = jobs[0].family;
        jobs[1].batch_size = jobs[0].batch_size;
        for j in &mut jobs {
            j.min_throughput = 0.3 * oracle.solo(j, AccelType::K80);
            j.distributability = 1;
        }
        jobs[0].priority = crate::workload::Priority::Best;
        jobs[1].priority = crate::workload::Priority::Critical;
        let mut counts = BTreeMap::new();
        counts.insert(AccelType::K80, 1u32);
        let jobs_c = jobs.clone();
        let oracle_c = oracle.clone();
        let thr = move |a: AccelType, j: JobId, c: &Combo| -> f64 {
            let spec = jobs_c.iter().find(|s| s.id == j).unwrap();
            let lookup = |id: JobId| jobs_c.iter().find(|s| s.id == id).cloned();
            oracle_c.throughput(spec, c, a, &lookup)
        };
        let cap = |a: AccelType| a.base_speed() / 5.0;
        let input = Problem1Input {
            jobs: &jobs,
            accel_counts: &counts,
            throughput: &thr,
            solo_capability: &cap,
            max_pairs_per_job: 0,
            slack_penalty: Some(1000.0),
            throughput_bonus: 0.0,
            now_s: 0.0,
            power: PowerKnobs::default(),
        };
        let sol = solve_problem1(&input, &BnbConfig::default());
        assert!(matches!(sol.status, BnbStatus::Optimal | BnbStatus::Feasible));
        assert_eq!(sol.violated_jobs, vec![jobs[0].id], "{:?}", sol.violated_jobs);
        assert!(sol
            .assignments
            .iter()
            .any(|(_, c, m)| c.contains(jobs[1].id) && *m >= 1));
    }

    #[test]
    fn basis_chaining_reaches_the_same_optimum() {
        let (jobs, oracle, counts) = setup(6, 2);
        let jobs_c = jobs.clone();
        let oracle_c = oracle.clone();
        let thr = move |a: AccelType, j: JobId, c: &Combo| -> f64 {
            let spec = jobs_c.iter().find(|s| s.id == j).unwrap();
            let lookup = |id: JobId| jobs_c.iter().find(|s| s.id == id).cloned();
            oracle_c.throughput(spec, c, a, &lookup)
        };
        let cap = |a: AccelType| a.base_speed() / 5.0;
        let input = oracle_input(&jobs, &oracle, &counts, &thr, &cap);
        let bnb = BnbConfig {
            max_nodes: 200_000,
            time_limit_s: 60.0,
            ..Default::default()
        };
        let cold = solve_problem1(&input, &bnb);
        assert_eq!(cold.status, BnbStatus::Optimal);
        assert!(cold.basis.is_none(), "plain solve exports no basis");
        // first arrival of a chain: empty hint, basis exported
        let first = solve_problem1_with_basis(&input, &bnb, &ColumnBasis::new());
        assert_eq!(first.status, BnbStatus::Optimal);
        assert!((cold.objective - first.objective).abs() < 1e-6);
        let basis = first.basis.clone().expect("chaining exports a basis");
        assert!(!basis.is_empty());
        // next arrival: crash-start from the previous basis
        let warm = solve_problem1_with_basis(&input, &bnb, &basis);
        assert_eq!(warm.status, BnbStatus::Optimal);
        assert!((cold.objective - warm.objective).abs() < 1e-6);
    }

    #[test]
    fn builder_edit_sequence_matches_from_scratch() {
        let (jobs, oracle, counts) = setup(8, 2);
        let jobs_c = jobs.clone();
        let thr = move |a: AccelType, j: JobId, c: &Combo| -> f64 {
            let spec = jobs_c.iter().find(|s| s.id == j).unwrap();
            let lookup = |id: JobId| jobs_c.iter().find(|s| s.id == id).cloned();
            oracle.throughput(spec, c, a, &lookup)
        };
        let cap = |a: AccelType| a.base_speed() / 5.0;
        let mut b = Problem1Builder::new(3);
        b.set_accel_counts(counts.clone());
        for j in &jobs {
            b.add_job(j.clone(), &thr);
        }
        b.remove_job(jobs[2].id);
        b.remove_job(jobs[5].id);
        let mut smaller = counts.clone();
        smaller.insert(AccelType::K80, 1);
        b.set_accel_counts(smaller.clone());
        let final_jobs = b.jobs_sorted();
        assert_eq!(final_jobs.len(), 6);
        let input = Problem1Input {
            jobs: &final_jobs,
            accel_counts: &smaller,
            throughput: &thr,
            solo_capability: &cap,
            max_pairs_per_job: 3,
            slack_penalty: Some(2000.0),
            throughput_bonus: 300.0,
            now_s: 0.0,
            power: PowerKnobs::default(),
        };
        let (sm, sc, ss) = build_problem1(&input, &BnbConfig::default());
        let (m, c, s) = b.build(&input);
        assert_eq!(c, sc.as_slice());
        assert_eq!(s, &ss);
        assert_eq!(m.vars.len(), sm.vars.len());
        for (a, z) in m.vars.iter().zip(&sm.vars) {
            assert_eq!(a.name, z.name);
            assert_eq!((a.lb, a.ub, a.obj), (z.lb, z.ub, z.obj));
            assert_eq!(a.kind, z.kind);
        }
        assert_eq!(m.constraints.len(), sm.constraints.len());
        for (a, z) in m.constraints.iter().zip(&sm.constraints) {
            assert_eq!(a.name, z.name);
            assert_eq!(a.terms, z.terms);
            assert_eq!(a.sense, z.sense);
            assert_eq!(a.rhs, z.rhs);
        }
    }

    #[test]
    fn builder_reuses_matrix_until_dirtied() {
        let (jobs, oracle, counts) = setup(4, 2);
        let jobs_c = jobs.clone();
        let thr = move |a: AccelType, j: JobId, c: &Combo| -> f64 {
            let spec = jobs_c.iter().find(|s| s.id == j).unwrap();
            let lookup = |id: JobId| jobs_c.iter().find(|s| s.id == id).cloned();
            oracle.throughput(spec, c, a, &lookup)
        };
        let cap = |a: AccelType| a.base_speed() / 5.0;
        let mut b = Problem1Builder::new(2);
        b.set_accel_counts(counts.clone());
        for j in &jobs {
            b.add_job(j.clone(), &thr);
        }
        // 4 arrivals score 0 + 1 + 2 + 3 = 6 pairs, O(|J|) each
        assert_eq!(b.pairs_scored, 6);
        let final_jobs = b.jobs_sorted();
        let input = Problem1Input {
            jobs: &final_jobs,
            accel_counts: &counts,
            throughput: &thr,
            solo_capability: &cap,
            max_pairs_per_job: 2,
            slack_penalty: Some(2000.0),
            throughput_bonus: 0.0,
            now_s: 0.0,
            power: PowerKnobs::default(),
        };
        let bnb = BnbConfig::default();
        let scratch = solve_problem1(&input, &bnb);
        let built = b.solve(&input, &bnb, None);
        assert_eq!(built.assignments, scratch.assignments);
        assert_eq!(built.objective, scratch.objective);
        assert_eq!((b.model_rebuilds, b.model_reuses), (1, 0));
        // identical re-solve: the whole matrix is reused
        let again = b.solve(&input, &bnb, None);
        assert_eq!(again.assignments, scratch.assignments);
        assert_eq!((b.model_rebuilds, b.model_reuses), (1, 1));
        // estimate change: every stored pair is rescored once
        let before = b.pairs_scored;
        b.note_estimates_changed();
        let _ = b.solve(&input, &bnb, None);
        assert_eq!((b.model_rebuilds, b.model_reuses), (2, 1));
        assert_eq!(b.pairs_scored, before + 6);
    }

    #[test]
    fn candidate_combos_prunes_pairs() {
        let (jobs, oracle, _) = setup(6, 1);
        let jobs_c = jobs.clone();
        let thr = move |a: AccelType, j: JobId, c: &Combo| -> f64 {
            let spec = jobs_c.iter().find(|s| s.id == j).unwrap();
            let lookup = |id: JobId| jobs_c.iter().find(|s| s.id == id).cloned();
            oracle.throughput(spec, c, a, &lookup)
        };
        let solos_only = candidate_combos(&jobs, &thr, 0);
        assert_eq!(solos_only.len(), 6);
        let with_pairs = candidate_combos(&jobs, &thr, 2);
        assert!(with_pairs.len() > 6);
        assert!(with_pairs.len() <= 6 + 6); // ≤ K·|J|/2 pairs
    }
}
