//! Problem 1 — the GPU-allocation ILP (paper §2.4).
//!
//! Variables: the paper's x^c_{a,s} is indexed per (combination,
//! accelerator type, server). Instances of the same type are identical
//! in this substrate, so we aggregate per type: integer `n_{a,c}` =
//! number of type-`a` instances hosting combination `c`, with
//! `0 ≤ n_{a,c} ≤ count(a)`. The aggregation is exact (any aggregated
//! solution maps to a per-server one by assigning combos to free
//! instances arbitrarily) and shrinks the ILP by the server count.
//!
//! Objective (2a): `min Σ γ_a(load)·n` — energy of *used* instances;
//! γ_a is evaluated per combination (each instance hosts at most one
//! combination, constraint 2f, so no piecewise linearization is needed —
//! the nonlinearity is folded into per-column constants).
//!
//! Constraints: (2b) coverage ≥ 1 per job; (2c) ≤ D_j instances per job;
//! (2d) capacity |c| ≤ θ_a by combo pruning; (2e) aggregate throughput ≥
//! T̄_j; (2f) Σ_c n_{a,c} ≤ count(a).
//!
//! SLO softening: real traces can be transiently infeasible (more jobs
//! than capacity). `slack_penalty` adds per-job slack on (2b)/(2e) with
//! a large objective penalty, so the optimizer degrades gracefully and
//! the coordinator reports the violation instead of failing.
//!
//! Inference jobs (constraint 2e′): for a serving job the `n_{a,c}`
//! multiplicities are its **replica counts** — coverage (2b) keeps ≥ 1
//! replica, the distributability bound (2c) is the replica cap R_j, and
//! the throughput row (2e) carries the latency SLO linearized by
//! [`latency_adjusted_jobs`]: the M/M/c sojourn target becomes an
//! aggregate-capacity floor via the pooled-server bound of
//! [`crate::workload::serving::effective_min_throughput`]. The same
//! soft-slack machinery covers transient latency infeasibility.

use std::collections::BTreeMap;

use super::branch_bound::{solve_ilp, BnbConfig, BnbResult, BnbStatus};
use super::model::{Model, ObjSense, Sense, VarId, VarKind};
use crate::power::{column_cost, PowerKnobs};
use crate::workload::{AccelType, Combo, JobId, JobSpec, ACCEL_TYPES};

/// Inputs to the allocation ILP.
pub struct Problem1Input<'a> {
    /// Active jobs 𝒥.
    pub jobs: &'a [JobSpec],
    /// Instances available per accelerator type.
    pub accel_counts: &'a BTreeMap<AccelType, u32>,
    /// Estimated (or measured) normalized throughput T̃^c_{a,j}.
    pub throughput: &'a dyn Fn(AccelType, JobId, &Combo) -> f64,
    /// Solo capability of type `a` (denominator of the relative load fed
    /// to γ_a): the best solo throughput any current job achieves on it.
    pub solo_capability: &'a dyn Fn(AccelType) -> f64,
    /// Max candidate pair-combos per job (0 = solos only). Pruning keeps
    /// the ILP tractable online; pairs are ranked by estimated combined
    /// throughput.
    pub max_pairs_per_job: usize,
    /// Penalty (objective units per unit of slack) for SLO softening.
    /// `None` builds the paper's hard formulation.
    pub slack_penalty: Option<f64>,
    /// Lagrangian throughput bonus λ (watts credited per unit of
    /// normalized throughput delivered). The paper's objective (2a) is
    /// pure instantaneous power (λ = 0), but that *slow-walks* jobs onto
    /// legacy GPUs — power drops while completion times, contention and
    /// total joules rise (a v100 delivers ~3× more work per joule than a
    /// k80 here). λ > 0 makes the per-column cost `γ_a(u) − λ·ΣT`, i.e.
    /// approximately energy-per-work, while keeping Problem 1 linear.
    /// `benches/e2e_scheduling.rs` quantifies the difference; λ = 0
    /// reproduces the paper's literal objective.
    pub throughput_bonus: f64,
    /// Simulated time the solve happens at — evaluates each inference
    /// job's diurnal request rate λ(t) for the latency-feasibility
    /// constraint 2e′ (irrelevant to pure-training pools; pass 0.0).
    pub now_s: f64,
    /// Power-subsystem knobs (docs/POWER.md): with DVFS on, each column
    /// cost is the minimum over the host's power states; the carbon
    /// weight scales the energy term. The default reproduces the
    /// pre-power objective bit-for-bit.
    pub power: PowerKnobs,
}

/// Decoded solution.
#[derive(Debug, Clone)]
pub struct AllocationSolution {
    /// (accel type, combo, multiplicity) with multiplicity ≥ 1.
    pub assignments: Vec<(AccelType, Combo, u32)>,
    /// jobs whose coverage or SLO slack is active (soft mode only).
    pub violated_jobs: Vec<JobId>,
    pub objective: f64,
    pub status: BnbStatus,
    pub nodes: usize,
    /// relative optimality gap at termination (0 = proved optimal)
    pub gap: f64,
    /// total simplex pivots across every node LP (per-node cost metric)
    pub lp_pivots: u64,
    /// whether a greedy/explicit incumbent seeded the search
    pub warm_started: bool,
}

/// Aggregate a concrete instance pool into the per-type capacity map of
/// [`Problem1Input::accel_counts`] — the pool-scoped problem build used
/// by the shard workers, the incremental arrival path and the full
/// re-solve (whose pool is the whole in-service cluster).
pub fn pool_accel_counts(pool: &[crate::cluster::AccelId]) -> BTreeMap<AccelType, u32> {
    let mut counts: BTreeMap<AccelType, u32> = BTreeMap::new();
    for a in pool {
        *counts.entry(a.accel).or_default() += 1;
    }
    counts
}

/// Constraint 2e′ — the latency-feasibility pre-pass: every inference
/// job's throughput row carries the capacity floor its latency SLO
/// implies at time `now_s` (pooled-server bound + utilization cap, see
/// [`crate::workload::serving`]); training jobs pass through untouched.
/// [`solve_problem1`] applies this automatically; callers of
/// [`build_problem1`] that host inference jobs should apply it first.
pub fn latency_adjusted_jobs(jobs: &[JobSpec], now_s: f64) -> Vec<JobSpec> {
    jobs.iter()
        .map(|j| {
            let mut j = j.clone();
            j.min_throughput = crate::workload::serving::effective_min_throughput(&j, now_s);
            j
        })
        .collect()
}

/// Build the candidate combination universe 𝒞 (solos + pruned pairs).
pub fn candidate_combos(
    jobs: &[JobSpec],
    throughput: &dyn Fn(AccelType, JobId, &Combo) -> f64,
    max_pairs_per_job: usize,
) -> Vec<Combo> {
    let mut combos: Vec<Combo> = jobs.iter().map(|j| Combo::Solo(j.id)).collect();
    if max_pairs_per_job == 0 || jobs.len() < 2 {
        return combos;
    }
    // score pairs by combined v100 estimated throughput, keep top-K per job
    let mut scored: Vec<(f64, Combo)> = vec![];
    for (i, a) in jobs.iter().enumerate() {
        for b in jobs.iter().skip(i + 1) {
            let c = Combo::pair(a.id, b.id);
            let s = throughput(AccelType::V100, a.id, &c) + throughput(AccelType::V100, b.id, &c);
            scored.push((s, c));
        }
    }
    scored.sort_by(|x, y| y.0.partial_cmp(&x.0).unwrap());
    let mut per_job: BTreeMap<JobId, usize> = BTreeMap::new();
    for (_, c) in scored {
        let js = c.jobs();
        if js.iter().all(|j| per_job.get(j).copied().unwrap_or(0) < max_pairs_per_job) {
            for j in &js {
                *per_job.entry(*j).or_default() += 1;
            }
            combos.push(c);
        }
    }
    combos
}

/// Build and solve Problem 1. Returns `None` only if the hard
/// formulation is infeasible (use `slack_penalty` to avoid that).
pub fn build_problem1(
    input: &Problem1Input,
    bnb: &BnbConfig,
) -> (
    Model,
    Vec<(AccelType, Combo, VarId)>,
    BTreeMap<JobId, (Option<VarId>, Option<VarId>)>,
) {
    let combos = candidate_combos(input.jobs, input.throughput, input.max_pairs_per_job);
    let mut model = Model::new(ObjSense::Minimize);
    let _ = bnb;

    // n_{a,c} variables with per-column energy coefficients.
    let mut cols: Vec<(AccelType, Combo, VarId)> = vec![];
    for &a in ACCEL_TYPES.iter() {
        let count = input.accel_counts.get(&a).copied().unwrap_or(0);
        if count == 0 {
            continue;
        }
        for c in &combos {
            if c.len() as u32 > a.capacity() {
                continue; // constraint (2d) by pruning
            }
            let total_t: f64 = c.jobs().iter().map(|&j| (input.throughput)(a, j, c)).sum();
            if total_t <= 1e-9 {
                continue; // useless column
            }
            let u = (total_t / (input.solo_capability)(a).max(1e-9)).clamp(0.0, 1.0);
            let energy = column_cost(a, u, total_t, input.throughput_bonus, input.power);
            let v = model.add_var(
                format!("n[{},{:?}]", a.name(), c),
                0.0,
                count as f64,
                VarKind::Integer,
                energy,
            );
            cols.push((a, *c, v));
        }
    }

    // Per-job slack (soft mode).
    let mut slacks: BTreeMap<JobId, (Option<VarId>, Option<VarId>)> = BTreeMap::new();
    for j in input.jobs {
        let (mut cover_s, mut thr_s) = (None, None);
        if let Some(p) = input.slack_penalty {
            // Tier weighting: slack on a Critical job costs 4× the
            // Standard rate and slack on a Best job 1/4 of it, so under
            // contention the optimizer sheds SLOs bottom-tier first.
            // Standard's weight is 1.0, keeping priority-free runs
            // bit-identical to the unweighted formulation.
            let w = j.priority.weight();
            cover_s = Some(model.add_var(
                format!("sc[{}]", j.id),
                0.0,
                1.0,
                VarKind::Continuous,
                4.0 * p * w,
            ));
            thr_s = Some(model.add_var(
                format!("st[{}]", j.id),
                0.0,
                j.min_throughput.max(0.0),
                VarKind::Continuous,
                w * p / j.min_throughput.max(1e-3),
            ));
        }
        slacks.insert(j.id, (cover_s, thr_s));
    }

    // (2b) coverage + (2c) distributability + (2e) throughput
    for j in input.jobs {
        let owned: Vec<&(AccelType, Combo, VarId)> =
            cols.iter().filter(|(_, c, _)| c.contains(j.id)).collect();
        let mut cover_terms: Vec<(VarId, f64)> = owned.iter().map(|(_, _, v)| (*v, 1.0)).collect();
        if let (Some(sc), _) = slacks[&j.id] {
            cover_terms.push((sc, 1.0));
        }
        model.add_constraint(format!("cover[{}]", j.id), cover_terms, Sense::Ge, 1.0);

        let dist_terms: Vec<(VarId, f64)> = owned.iter().map(|(_, _, v)| (*v, 1.0)).collect();
        model.add_constraint(
            format!("dist[{}]", j.id),
            dist_terms,
            Sense::Le,
            j.distributability as f64,
        );

        let mut thr_terms: Vec<(VarId, f64)> = owned
            .iter()
            .map(|(a, c, v)| (*v, (input.throughput)(*a, j.id, c)))
            .collect();
        if let (_, Some(st)) = slacks[&j.id] {
            thr_terms.push((st, 1.0));
        }
        model.add_constraint(
            format!("thr[{}]", j.id),
            thr_terms,
            Sense::Ge,
            j.min_throughput,
        );
    }

    // (2f) instances per type
    for &a in ACCEL_TYPES.iter() {
        let count = input.accel_counts.get(&a).copied().unwrap_or(0);
        let terms: Vec<(VarId, f64)> = cols
            .iter()
            .filter(|(aa, _, _)| *aa == a)
            .map(|(_, _, v)| (*v, 1.0))
            .collect();
        if !terms.is_empty() {
            model.add_constraint(format!("cap[{}]", a.name()), terms, Sense::Le, count as f64);
        }
    }

    (model, cols, slacks)
}

/// Solve Problem 1 end-to-end and decode the solution.
///
/// When `bnb.auto_warm_start` is set (the default) and no explicit
/// incumbent was supplied, the search is seeded from
/// [`crate::baselines::greedy::greedy_incumbent`] — the energy-aware
/// greedy packing of the `baselines` layer — so pruning bites from the
/// first node. Without it the allocation trees at |J| ≥ 12 explore tens
/// of thousands of nodes before the first feasible point (measured by
/// `benches/ilp_scaling.rs`, asserted by `tests/warm_start.rs`).
pub fn solve_problem1(input: &Problem1Input, bnb: &BnbConfig) -> AllocationSolution {
    // 2e′: fold each inference job's latency SLO into its throughput
    // row before the model is built (no-op — and no clone — for the
    // common pure-training pool).
    let adjusted: Option<Vec<JobSpec>> = input
        .jobs
        .iter()
        .any(|j| j.is_inference())
        .then(|| latency_adjusted_jobs(input.jobs, input.now_s));
    let input = &Problem1Input {
        jobs: adjusted.as_deref().unwrap_or(input.jobs),
        ..*input
    };
    let (model, cols, slacks) = build_problem1(input, bnb);
    let mut bnb = bnb.clone();
    if bnb.warm_start.is_none() && bnb.auto_warm_start {
        bnb.warm_start =
            crate::baselines::greedy::greedy_incumbent(input, &model, &cols, &slacks);
    }
    let r: BnbResult = solve_ilp(&model, &bnb);
    decode(&r, &cols, &slacks)
}

fn decode(
    r: &BnbResult,
    cols: &[(AccelType, Combo, VarId)],
    slacks: &BTreeMap<JobId, (Option<VarId>, Option<VarId>)>,
) -> AllocationSolution {
    let mut assignments = vec![];
    let mut violated = vec![];
    if matches!(r.status, BnbStatus::Optimal | BnbStatus::Feasible) {
        for (a, c, v) in cols {
            let mult = r.x[v.0].round() as u32;
            if mult > 0 {
                assignments.push((*a, *c, mult));
            }
        }
        for (j, (sc, st)) in slacks {
            let viol = sc.map_or(false, |v| r.x[v.0] > 1e-6)
                || st.map_or(false, |v| r.x[v.0] > 1e-6);
            if viol {
                violated.push(*j);
            }
        }
        violated.sort();
    }
    AllocationSolution {
        assignments,
        violated_jobs: violated,
        objective: r.objective,
        status: r.status,
        nodes: r.nodes,
        gap: r.gap(),
        lp_pivots: r.lp_pivots,
        warm_started: r.warm_started,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{ModelFamily, ThroughputOracle};

    fn mk_jobs(n: u32, oracle: &ThroughputOracle) -> Vec<JobSpec> {
        let fams = [
            ModelFamily::ResNet18,
            ModelFamily::ResNet50,
            ModelFamily::Transformer,
            ModelFamily::LanguageModel,
            ModelFamily::Recommendation,
        ];
        (0..n)
            .map(|i| {
                let f = fams[i as usize % fams.len()];
                let b = f.batch_sizes()[i as usize % f.batch_sizes().len()];
                let mut j = JobSpec {
                    id: JobId(i),
                    family: f,
                    batch_size: b,
                    replication: 1,
                    min_throughput: 0.0,
                    distributability: 2,
                    work: 100.0,
                    priority: Default::default(),
                    elastic: false,
                    inference: None,
                };
                j.min_throughput = 0.4 * oracle.solo(&j, AccelType::P100);
                j
            })
            .collect()
    }

    fn oracle_input<'a>(
        jobs: &'a [JobSpec],
        oracle: &'a ThroughputOracle,
        counts: &'a BTreeMap<AccelType, u32>,
        thr: &'a dyn Fn(AccelType, JobId, &Combo) -> f64,
        cap: &'a dyn Fn(AccelType) -> f64,
    ) -> Problem1Input<'a> {
        Problem1Input {
            jobs,
            accel_counts: counts,
            throughput: thr,
            solo_capability: cap,
            max_pairs_per_job: 3,
            slack_penalty: None,
            throughput_bonus: 0.0,
            now_s: 0.0,
            power: PowerKnobs::default(),
        }
        .with(oracle)
    }

    impl<'a> Problem1Input<'a> {
        fn with(self, _o: &'a ThroughputOracle) -> Self {
            self
        }
    }

    fn setup(
        n: u32,
        per_type: u32,
    ) -> (
        Vec<JobSpec>,
        ThroughputOracle,
        BTreeMap<AccelType, u32>,
    ) {
        let oracle = ThroughputOracle::new(11);
        let jobs = mk_jobs(n, &oracle);
        let counts: BTreeMap<AccelType, u32> =
            ACCEL_TYPES.iter().map(|&a| (a, per_type)).collect();
        (jobs, oracle, counts)
    }

    #[test]
    fn every_job_covered_and_slo_met() {
        let (jobs, oracle, counts) = setup(6, 2);
        let jobs_c = jobs.clone();
        let oracle_c = oracle.clone();
        let thr = move |a: AccelType, j: JobId, c: &Combo| -> f64 {
            let spec = jobs_c.iter().find(|s| s.id == j).unwrap();
            let lookup = |id: JobId| jobs_c.iter().find(|s| s.id == id).cloned();
            oracle_c.throughput(spec, c, a, &lookup)
        };
        let cap = |a: AccelType| a.base_speed() / 5.0; // v100-normalized
        let input = oracle_input(&jobs, &oracle, &counts, &thr, &cap);
        let sol = solve_problem1(&input, &BnbConfig::default());
        assert!(matches!(sol.status, BnbStatus::Optimal | BnbStatus::Feasible), "{:?}", sol.status);
        // coverage + SLO per job
        for j in &jobs {
            let total: f64 = sol
                .assignments
                .iter()
                .filter(|(_, c, _)| c.contains(j.id))
                .map(|(a, c, mult)| thr(*a, j.id, c) * *mult as f64)
                .sum();
            assert!(total >= j.min_throughput - 1e-6, "{}: {total} < {}", j.id, j.min_throughput);
        }
        // capacity per type
        for &a in ACCEL_TYPES.iter() {
            let used: u32 = sol
                .assignments
                .iter()
                .filter(|(aa, _, _)| *aa == a)
                .map(|(_, _, m)| m)
                .sum();
            assert!(used <= counts[&a]);
        }
    }

    #[test]
    fn infeasible_without_slack_feasible_with() {
        // 4 jobs, 1 accelerator of each of only k80 types → too slow for
        // harsh SLOs.
        let oracle = ThroughputOracle::new(11);
        let mut jobs = mk_jobs(4, &oracle);
        for j in &mut jobs {
            j.min_throughput = 0.95; // nearly the global max: only feasible on the best GPU solo
            j.distributability = 1;
        }
        let mut counts = BTreeMap::new();
        counts.insert(AccelType::K80, 4u32);
        let jobs_c = jobs.clone();
        let oracle_c = oracle.clone();
        let thr = move |a: AccelType, j: JobId, c: &Combo| -> f64 {
            let spec = jobs_c.iter().find(|s| s.id == j).unwrap();
            let lookup = |id: JobId| jobs_c.iter().find(|s| s.id == id).cloned();
            oracle_c.throughput(spec, c, a, &lookup)
        };
        let cap = |a: AccelType| a.base_speed() / 5.0;
        let hard = Problem1Input {
            jobs: &jobs,
            accel_counts: &counts,
            throughput: &thr,
            solo_capability: &cap,
            max_pairs_per_job: 2,
            slack_penalty: None,
            throughput_bonus: 0.0,
            now_s: 0.0,
            power: PowerKnobs::default(),
        };
        let sol = solve_problem1(&hard, &BnbConfig::default());
        assert_eq!(sol.status, BnbStatus::Infeasible);

        let soft = Problem1Input {
            slack_penalty: Some(1000.0),
            ..hard
        };
        let sol = solve_problem1(&soft, &BnbConfig::default());
        assert!(matches!(sol.status, BnbStatus::Optimal | BnbStatus::Feasible));
        assert!(!sol.violated_jobs.is_empty());
    }

    #[test]
    fn prefers_energy_efficient_packing() {
        // One tiny job with a loose SLO: the optimizer should pick the
        // cheapest-energy placement, not the fastest GPU.
        let oracle = ThroughputOracle::new(11);
        let mut jobs = mk_jobs(1, &oracle);
        jobs[0].min_throughput = 0.05 * oracle.solo(&jobs[0], AccelType::K80);
        let counts: BTreeMap<AccelType, u32> = ACCEL_TYPES.iter().map(|&a| (a, 1)).collect();
        let jobs_c = jobs.clone();
        let oracle_c = oracle.clone();
        let thr = move |a: AccelType, j: JobId, c: &Combo| -> f64 {
            let spec = jobs_c.iter().find(|s| s.id == j).unwrap();
            let lookup = |id: JobId| jobs_c.iter().find(|s| s.id == id).cloned();
            oracle_c.throughput(spec, c, a, &lookup)
        };
        let cap = |a: AccelType| a.base_speed() / 5.0;
        let input = Problem1Input {
            jobs: &jobs,
            accel_counts: &counts,
            throughput: &thr,
            solo_capability: &cap,
            max_pairs_per_job: 0,
            slack_penalty: None,
            throughput_bonus: 0.0,
            now_s: 0.0,
            power: PowerKnobs::default(),
        };
        let sol = solve_problem1(&input, &BnbConfig::default());
        assert_eq!(sol.assignments.len(), 1);
        let (a, _, _) = sol.assignments[0];
        // k80 idle+load power < v100's → must not pick a v100
        assert_ne!(a.consolidated(), AccelType::V100, "picked {a:?}");
    }

    #[test]
    fn distributability_allows_splitting_for_throughput() {
        // SLO above any single accelerator's capability; D_j = 2 lets the
        // job run on two instances whose sum meets the SLO.
        let oracle = ThroughputOracle::new(11);
        let mut jobs = mk_jobs(1, &oracle);
        let best = crate::workload::ACCEL_TYPES
            .iter()
            .map(|&a| oracle.solo(&jobs[0], a))
            .fold(0.0f64, f64::max);
        jobs[0].min_throughput = 1.5 * best;
        jobs[0].distributability = 2;
        let counts: BTreeMap<AccelType, u32> = ACCEL_TYPES.iter().map(|&a| (a, 2)).collect();
        let jobs_c = jobs.clone();
        let oracle_c = oracle.clone();
        let thr = move |a: AccelType, j: JobId, c: &Combo| -> f64 {
            let spec = jobs_c.iter().find(|s| s.id == j).unwrap();
            let lookup = |id: JobId| jobs_c.iter().find(|s| s.id == id).cloned();
            oracle_c.throughput(spec, c, a, &lookup)
        };
        let cap = |a: AccelType| a.base_speed() / 5.0;
        let input = Problem1Input {
            jobs: &jobs,
            accel_counts: &counts,
            throughput: &thr,
            solo_capability: &cap,
            max_pairs_per_job: 0,
            slack_penalty: None,
            throughput_bonus: 0.0,
            now_s: 0.0,
            power: PowerKnobs::default(),
        };
        let sol = solve_problem1(&input, &BnbConfig::default());
        assert!(matches!(sol.status, BnbStatus::Optimal | BnbStatus::Feasible));
        let total_mult: u32 = sol.assignments.iter().map(|(_, _, m)| m).sum();
        assert_eq!(total_mult, 2, "{:?}", sol.assignments);
    }

    #[test]
    fn throughput_bonus_prefers_efficient_fast_gpus() {
        // λ = 0 (paper-literal) parks a loose-SLO job on a low-power GPU;
        // λ = 300 makes energy-per-work the effective criterion → v100.
        let oracle = ThroughputOracle::new(11);
        let mut jobs = mk_jobs(1, &oracle);
        jobs[0].min_throughput = 0.05 * oracle.solo(&jobs[0], AccelType::K80);
        let counts: BTreeMap<AccelType, u32> = ACCEL_TYPES.iter().map(|&a| (a, 1)).collect();
        let jobs_c = jobs.clone();
        let oracle_c = oracle.clone();
        let thr = move |a: AccelType, j: JobId, c: &Combo| -> f64 {
            let spec = jobs_c.iter().find(|s| s.id == j).unwrap();
            let lookup = |id: JobId| jobs_c.iter().find(|s| s.id == id).cloned();
            oracle_c.throughput(spec, c, a, &lookup)
        };
        let cap = |a: AccelType| a.base_speed() / 5.0;
        let solve = |bonus: f64| {
            let input = Problem1Input {
                jobs: &jobs,
                accel_counts: &counts,
                throughput: &thr,
                solo_capability: &cap,
                max_pairs_per_job: 0,
                slack_penalty: None,
                throughput_bonus: bonus,
                now_s: 0.0,
                power: PowerKnobs::default(),
            };
            solve_problem1(&input, &BnbConfig::default())
        };
        let literal = solve(0.0);
        let bonus = solve(300.0);
        assert_ne!(literal.assignments[0].0.consolidated(), AccelType::V100);
        assert_eq!(bonus.assignments[0].0.consolidated(), AccelType::V100);
    }

    #[test]
    fn latency_slo_provisions_replicas() {
        // A serving job whose latency floor exceeds any single GPU's
        // capability must receive several replicas (constraint 2e′ on
        // the replica-count variables), while a relaxed SLO needs one.
        let oracle = ThroughputOracle::new(11);
        let mut jobs = mk_jobs(1, &oracle);
        let best = ACCEL_TYPES
            .iter()
            .map(|&a| oracle.solo(&jobs[0], a))
            .fold(0.0f64, f64::max);
        let lam = crate::workload::serving::service_rate(1.4 * best);
        jobs[0].min_throughput = 0.0;
        jobs[0].distributability = 3;
        jobs[0].inference = Some(crate::workload::InferenceSpec {
            base_rate: lam,
            diurnal_amplitude: 0.0,
            diurnal_phase_s: 0.0,
            latency_slo_s: 10.0 / lam.max(1e-9),
        });
        let counts: BTreeMap<AccelType, u32> = ACCEL_TYPES.iter().map(|&a| (a, 3)).collect();
        let jobs_c = jobs.clone();
        let oracle_c = oracle.clone();
        let thr = move |a: AccelType, j: JobId, c: &Combo| -> f64 {
            let spec = jobs_c.iter().find(|s| s.id == j).unwrap();
            let lookup = |id: JobId| jobs_c.iter().find(|s| s.id == id).cloned();
            oracle_c.throughput(spec, c, a, &lookup)
        };
        let cap = |a: AccelType| a.base_speed() / 5.0;
        let solve = |jobs: &[JobSpec]| {
            let input = Problem1Input {
                jobs,
                accel_counts: &counts,
                throughput: &thr,
                solo_capability: &cap,
                max_pairs_per_job: 0,
                slack_penalty: None,
                throughput_bonus: 0.0,
                now_s: 0.0,
                power: PowerKnobs::default(),
            };
            solve_problem1(&input, &BnbConfig::default())
        };
        let tight = solve(&jobs);
        assert!(matches!(tight.status, BnbStatus::Optimal | BnbStatus::Feasible));
        let replicas: u32 = tight.assignments.iter().map(|(_, _, m)| m).sum();
        assert!(replicas >= 2, "tight SLO got only {replicas} replica(s)");
        assert!(replicas <= jobs[0].distributability);

        // a very relaxed SLO and tiny rate needs a single replica
        let mut loose = jobs.clone();
        loose[0].inference = Some(crate::workload::InferenceSpec {
            base_rate: 0.05 * lam,
            diurnal_amplitude: 0.0,
            diurnal_phase_s: 0.0,
            latency_slo_s: 1000.0 / lam.max(1e-9),
        });
        let sol = solve(&loose);
        let replicas: u32 = sol.assignments.iter().map(|(_, _, m)| m).sum();
        assert_eq!(replicas, 1, "{:?}", sol.assignments);
    }

    #[test]
    fn tier_weight_sheds_best_effort_job_first() {
        // Two identical jobs, one K80, solos only, D_j = 1: exactly one
        // job can be covered. The Critical job's slack costs 16× the
        // Best job's, so the optimizer must shed the Best-effort one.
        let oracle = ThroughputOracle::new(11);
        let mut jobs = mk_jobs(2, &oracle);
        jobs[1].family = jobs[0].family;
        jobs[1].batch_size = jobs[0].batch_size;
        for j in &mut jobs {
            j.min_throughput = 0.3 * oracle.solo(j, AccelType::K80);
            j.distributability = 1;
        }
        jobs[0].priority = crate::workload::Priority::Best;
        jobs[1].priority = crate::workload::Priority::Critical;
        let mut counts = BTreeMap::new();
        counts.insert(AccelType::K80, 1u32);
        let jobs_c = jobs.clone();
        let oracle_c = oracle.clone();
        let thr = move |a: AccelType, j: JobId, c: &Combo| -> f64 {
            let spec = jobs_c.iter().find(|s| s.id == j).unwrap();
            let lookup = |id: JobId| jobs_c.iter().find(|s| s.id == id).cloned();
            oracle_c.throughput(spec, c, a, &lookup)
        };
        let cap = |a: AccelType| a.base_speed() / 5.0;
        let input = Problem1Input {
            jobs: &jobs,
            accel_counts: &counts,
            throughput: &thr,
            solo_capability: &cap,
            max_pairs_per_job: 0,
            slack_penalty: Some(1000.0),
            throughput_bonus: 0.0,
            now_s: 0.0,
            power: PowerKnobs::default(),
        };
        let sol = solve_problem1(&input, &BnbConfig::default());
        assert!(matches!(sol.status, BnbStatus::Optimal | BnbStatus::Feasible));
        assert_eq!(sol.violated_jobs, vec![jobs[0].id], "{:?}", sol.violated_jobs);
        assert!(sol
            .assignments
            .iter()
            .any(|(_, c, m)| c.contains(jobs[1].id) && *m >= 1));
    }

    #[test]
    fn candidate_combos_prunes_pairs() {
        let (jobs, oracle, _) = setup(6, 1);
        let jobs_c = jobs.clone();
        let thr = move |a: AccelType, j: JobId, c: &Combo| -> f64 {
            let spec = jobs_c.iter().find(|s| s.id == j).unwrap();
            let lookup = |id: JobId| jobs_c.iter().find(|s| s.id == id).cloned();
            oracle.throughput(spec, c, a, &lookup)
        };
        let solos_only = candidate_combos(&jobs, &thr, 0);
        assert_eq!(solos_only.len(), 6);
        let with_pairs = candidate_combos(&jobs, &thr, 2);
        assert!(with_pairs.len() > 6);
        assert!(with_pairs.len() <= 6 + 6); // ≤ K·|J|/2 pairs
    }
}
