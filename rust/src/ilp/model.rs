//! Modelling layer for linear / integer programs.
//!
//! A [`Model`] owns variables (continuous or integer, with bounds) and
//! linear constraints; [`crate::ilp::simplex`] solves its LP relaxation
//! and [`crate::ilp::branch_bound`] its integer form.

/// Variable handle (index into the model's variable table).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct VarId(pub usize);

/// Continuous or integer (B&B branches only on `Integer` variables).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum VarKind {
    Continuous,
    Integer,
}

/// Constraint sense.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Sense {
    Le,
    Ge,
    Eq,
}

/// Objective direction.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ObjSense {
    #[default]
    Minimize,
    Maximize,
}

#[derive(Debug, Clone)]
pub struct Variable {
    pub name: String,
    pub lb: f64,
    pub ub: f64,
    pub kind: VarKind,
    pub obj: f64,
}

/// Sparse linear constraint: Σ coef·x  sense  rhs.
#[derive(Debug, Clone)]
pub struct Constraint {
    pub name: String,
    pub terms: Vec<(VarId, f64)>,
    pub sense: Sense,
    pub rhs: f64,
}

/// A linear / mixed-integer program.
#[derive(Debug, Clone, Default)]
pub struct Model {
    pub vars: Vec<Variable>,
    pub constraints: Vec<Constraint>,
    pub obj_sense: ObjSense,
}

impl Model {
    pub fn new(sense: ObjSense) -> Self {
        Self {
            vars: vec![],
            constraints: vec![],
            obj_sense: sense,
        }
    }

    /// Add a variable; returns its handle.
    pub fn add_var(
        &mut self,
        name: impl Into<String>,
        lb: f64,
        ub: f64,
        kind: VarKind,
        obj: f64,
    ) -> VarId {
        assert!(lb <= ub, "inconsistent bounds");
        self.vars.push(Variable {
            name: name.into(),
            lb,
            ub,
            kind,
            obj,
        });
        VarId(self.vars.len() - 1)
    }

    /// Convenience: binary variable.
    pub fn add_binary(&mut self, name: impl Into<String>, obj: f64) -> VarId {
        self.add_var(name, 0.0, 1.0, VarKind::Integer, obj)
    }

    pub fn add_constraint(
        &mut self,
        name: impl Into<String>,
        terms: Vec<(VarId, f64)>,
        sense: Sense,
        rhs: f64,
    ) {
        debug_assert!(terms.iter().all(|(v, _)| v.0 < self.vars.len()));
        self.constraints.push(Constraint {
            name: name.into(),
            terms,
            sense,
            rhs,
        });
    }

    pub fn n_vars(&self) -> usize {
        self.vars.len()
    }

    pub fn n_constraints(&self) -> usize {
        self.constraints.len()
    }

    pub fn n_integer_vars(&self) -> usize {
        self.vars.iter().filter(|v| v.kind == VarKind::Integer).count()
    }

    /// Objective value of an assignment.
    pub fn objective_value(&self, x: &[f64]) -> f64 {
        self.vars.iter().zip(x).map(|(v, xi)| v.obj * xi).sum()
    }

    /// Check feasibility of an assignment within tolerance.
    pub fn is_feasible(&self, x: &[f64], tol: f64) -> bool {
        if x.len() != self.vars.len() {
            return false;
        }
        for (v, &xi) in self.vars.iter().zip(x) {
            if xi < v.lb - tol || xi > v.ub + tol {
                return false;
            }
            if v.kind == VarKind::Integer && (xi - xi.round()).abs() > tol {
                return false;
            }
        }
        for c in &self.constraints {
            let lhs: f64 = c.terms.iter().map(|&(v, coef)| coef * x[v.0]).sum();
            let ok = match c.sense {
                Sense::Le => lhs <= c.rhs + tol,
                Sense::Ge => lhs >= c.rhs - tol,
                Sense::Eq => (lhs - c.rhs).abs() <= tol,
            };
            if !ok {
                return false;
            }
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_and_evaluate() {
        let mut m = Model::new(ObjSense::Minimize);
        let x = m.add_var("x", 0.0, 10.0, VarKind::Continuous, 1.0);
        let y = m.add_binary("y", 2.0);
        m.add_constraint("c1", vec![(x, 1.0), (y, 1.0)], Sense::Ge, 1.0);
        assert_eq!(m.n_vars(), 2);
        assert_eq!(m.n_integer_vars(), 1);
        assert_eq!(m.objective_value(&[3.0, 1.0]), 5.0);
        assert!(m.is_feasible(&[1.0, 0.0], 1e-9));
        assert!(!m.is_feasible(&[0.0, 0.0], 1e-9)); // violates c1
        assert!(!m.is_feasible(&[0.5, 0.5], 1e-9)); // y fractional
        assert!(!m.is_feasible(&[11.0, 0.0], 1e-9)); // x above ub
    }
}
