//! ILP substrate — built from scratch (the paper relies on an
//! off-the-shelf solver; DESIGN.md §Substrates).
//!
//! * [`model`] — a small modelling layer: variables with bounds and
//!   integrality, linear constraints with ≤ / ≥ / = senses, min/max
//!   objective.
//! * [`simplex`] — dense two-phase primal simplex for the LP
//!   relaxations (Dantzig pricing with Bland anti-cycling fallback);
//!   [`SimplexWorkspace`] reuses every scratch buffer across the
//!   thousands of bound-only-differing LPs a B&B solve issues.
//! * [`branch_bound`] — branch-and-bound for the integer program, with
//!   LP bounding, most-fractional branching, a rounding primal
//!   heuristic, configurable node selection ([`NodeSelection`]),
//!   warm-start incumbent seeding, and node/time/gap budgets that
//!   degrade gracefully to the incumbent.
//! * [`problem1`] — builds the paper's Problem 1 (objective 2a,
//!   constraints 2b–2f) over the combination universe 𝒞.

pub mod branch_bound;
pub mod model;
pub mod problem1;
pub mod simplex;

pub use branch_bound::{solve_ilp, BnbConfig, BnbResult, BnbStatus, NodeSelection};
pub use model::{Constraint, Model, ObjSense, Sense, VarId, VarKind};
pub use problem1::{build_problem1, AllocationSolution, Problem1Input};
pub use simplex::{solve_lp, LpResult, LpStatus, SimplexWorkspace};
