//! Branch-and-bound for integer programs.
//!
//! Bounds come from the simplex LP relaxation; branching is
//! most-fractional; a floor/ceil rounding heuristic tightens incumbents
//! at every node. Two node-selection strategies are available
//! ([`NodeSelection`]): best-bound (default — minimal proved-optimality
//! tree) and depth-first (fast feasible points under tight budgets).
//!
//! All node LPs run through one shared [`SimplexWorkspace`], so a solve
//! allocates the dense tableau once and every node after the root costs
//! only pivots. Seeding the incumbent (via [`BnbConfig::warm_start`], or
//! the greedy seed `problem1::solve_problem1` derives from
//! `baselines::greedy`) lets pruning bite from the first node — the
//! difference is measured by `benches/ilp_scaling.rs` and asserted by
//! `tests/warm_start.rs`.

use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::time::Instant;

use super::model::{Model, ObjSense, VarKind};
use super::simplex::{LpStatus, SimplexWorkspace};

const INT_TOL: f64 = 1e-6;

/// Node-selection strategy for the search frontier.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum NodeSelection {
    /// Expand the open node with the best LP bound first (default):
    /// minimizes the tree needed to *prove* optimality.
    #[default]
    BestBound,
    /// LIFO dive: reaches integer-feasible leaves quickly, useful when a
    /// node budget cuts the search and any good incumbent is the goal.
    DepthFirst,
}

impl NodeSelection {
    pub fn key(self) -> &'static str {
        match self {
            NodeSelection::BestBound => "best-bound",
            NodeSelection::DepthFirst => "depth-first",
        }
    }

    pub fn from_key(k: &str) -> Option<Self> {
        match k {
            "best-bound" => Some(NodeSelection::BestBound),
            "depth-first" => Some(NodeSelection::DepthFirst),
            _ => None,
        }
    }
}

/// Solver limits / options.
#[derive(Debug, Clone)]
pub struct BnbConfig {
    pub max_nodes: usize,
    pub time_limit_s: f64,
    /// stop when (incumbent - bound) / |incumbent| < gap.
    pub rel_gap: f64,
    /// optional warm-start assignment (must be feasible) used as the
    /// initial incumbent.
    pub warm_start: Option<Vec<f64>>,
    /// allow the problem layer (`solve_problem1`) to derive a greedy
    /// incumbent automatically when `warm_start` is `None`.
    pub auto_warm_start: bool,
    /// Optional simplex crash basis (original-space variable indices
    /// from [`SimplexWorkspace::basic_structurals`] of a previous
    /// related solve). `Some` also turns on node-to-node basis
    /// chaining: each node LP crash-starts from the basis its
    /// predecessor exported. `Some(vec![])` enables chaining without a
    /// prior-arrival hint. A stale hint only costs pivots — the
    /// simplex falls back to the cold two-phase path.
    pub basis_hint: Option<Vec<usize>>,
    pub node_selection: NodeSelection,
}

impl Default for BnbConfig {
    fn default() -> Self {
        Self {
            max_nodes: 20_000,
            time_limit_s: 10.0,
            rel_gap: 1e-6,
            warm_start: None,
            auto_warm_start: true,
            basis_hint: None,
            node_selection: NodeSelection::BestBound,
        }
    }
}

/// Termination status.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BnbStatus {
    /// proved optimal (within rel_gap)
    Optimal,
    /// stopped at a limit with a feasible incumbent
    Feasible,
    Infeasible,
    /// hit a limit with no incumbent found
    NoSolution,
}

#[derive(Debug, Clone)]
pub struct BnbResult {
    pub status: BnbStatus,
    pub x: Vec<f64>,
    pub objective: f64,
    /// best LP bound at termination (lower bound for minimization)
    pub bound: f64,
    pub nodes: usize,
    pub lp_iterations: usize,
    /// total simplex pivots across every node LP (per-node cost metric)
    pub lp_pivots: u64,
    /// whether a feasible warm-start incumbent seeded the search
    pub warm_started: bool,
    /// structural variables basic at the root LP optimum, exported only
    /// when [`BnbConfig::basis_hint`] was set — feed it back as the next
    /// arrival's hint to chain bases across solves
    pub root_basis: Option<Vec<usize>>,
}

impl BnbResult {
    /// Relative optimality gap of the incumbent (0 when proved optimal).
    pub fn gap(&self) -> f64 {
        if !self.objective.is_finite() || !self.bound.is_finite() {
            return f64::INFINITY;
        }
        (self.objective - self.bound).abs() / self.objective.abs().max(1e-9)
    }
}

struct Node {
    bound: f64, // LP relaxation objective of the parent (min-sense)
    bounds: Vec<(f64, f64)>,
    depth: usize,
}

impl PartialEq for Node {
    fn eq(&self, other: &Self) -> bool {
        self.bound == other.bound
    }
}
impl Eq for Node {}
impl PartialOrd for Node {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Node {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; we want the SMALLEST bound first.
        other
            .bound
            .partial_cmp(&self.bound)
            .unwrap_or(Ordering::Equal)
            .then_with(|| self.depth.cmp(&other.depth))
    }
}

/// Open-node container: a heap for best-bound, a stack for depth-first.
enum Frontier {
    Best(BinaryHeap<Node>),
    Dfs(Vec<Node>),
}

impl Frontier {
    fn new(sel: NodeSelection) -> Self {
        match sel {
            NodeSelection::BestBound => Frontier::Best(BinaryHeap::new()),
            NodeSelection::DepthFirst => Frontier::Dfs(vec![]),
        }
    }

    fn push(&mut self, n: Node) {
        match self {
            Frontier::Best(h) => h.push(n),
            Frontier::Dfs(v) => v.push(n),
        }
    }

    fn pop(&mut self) -> Option<Node> {
        match self {
            Frontier::Best(h) => h.pop(),
            Frontier::Dfs(v) => v.pop(),
        }
    }

    /// Smallest stored bound among open nodes (min-sense).
    fn min_bound(&self) -> Option<f64> {
        match self {
            Frontier::Best(h) => h.peek().map(|n| n.bound),
            Frontier::Dfs(v) => v
                .iter()
                .map(|n| n.bound)
                .min_by(|a, b| a.partial_cmp(b).unwrap_or(Ordering::Equal)),
        }
    }
}

/// Solve `model` to integrality.
pub fn solve_ilp(model: &Model, cfg: &BnbConfig) -> BnbResult {
    // gogh-lint: allow(determinism-wall-clock, time_limit_s anytime cutoff is the documented config escape hatch; node budgets are the deterministic default)
    let start = Instant::now();
    let min_sense = model.obj_sense == ObjSense::Minimize;
    // Internally work with min-sense objective values.
    let to_min = |v: f64| if min_sense { v } else { -v };

    let mut ws = SimplexWorkspace::new();
    let mut lp_iterations = 0usize;
    let mut nodes = 0usize;

    let mut incumbent: Option<(Vec<f64>, f64)> = None; // (x, min-sense obj)
    if let Some(w) = &cfg.warm_start {
        if model.is_feasible(w, 1e-6) {
            incumbent = Some((w.clone(), to_min(model.objective_value(w))));
        }
    }
    let warm_started = incumbent.is_some();

    // Basis chaining: when a hint is supplied, the root LP crash-starts
    // from it, and every node LP crash-starts from the basis of the
    // previously solved node (structurally identical models differing
    // only in bounds, so the previous basis is usually one or two
    // pivots from re-optimal).
    let chain = cfg.basis_hint.is_some();
    let root_bounds: Vec<(f64, f64)> = model.vars.iter().map(|v| (v.lb, v.ub)).collect();
    let root = ws.solve_with_basis(model, Some(&root_bounds), cfg.basis_hint.as_deref());
    lp_iterations += root.iterations;
    match root.status {
        LpStatus::Infeasible => {
            return BnbResult {
                status: BnbStatus::Infeasible,
                x: vec![],
                objective: f64::INFINITY,
                bound: f64::INFINITY,
                nodes: 1,
                lp_iterations,
                lp_pivots: ws.total_pivots(),
                warm_started,
                root_basis: None,
            }
        }
        LpStatus::Unbounded => {
            return BnbResult {
                status: BnbStatus::NoSolution,
                x: vec![],
                objective: f64::NEG_INFINITY,
                bound: f64::NEG_INFINITY,
                nodes: 1,
                lp_iterations,
                lp_pivots: ws.total_pivots(),
                warm_started,
                root_basis: None,
            }
        }
        LpStatus::Optimal => {}
    }
    let root_basis = chain.then(|| ws.basic_structurals());
    let mut last_basis = root_basis.clone();

    let best_first = cfg.node_selection == NodeSelection::BestBound;
    let mut frontier = Frontier::new(cfg.node_selection);
    frontier.push(Node {
        bound: to_min(root.objective),
        bounds: root_bounds,
        depth: 0,
    });

    let mut best_bound = to_min(root.objective);
    let mut hit_limit = false;

    while let Some(node) = frontier.pop() {
        nodes += 1;
        if best_first {
            // heap pop order makes this the global lower bound
            best_bound = node.bound;
        }

        // prune against incumbent
        if let Some((_, inc)) = &incumbent {
            if node.bound >= *inc - INT_TOL {
                if best_first {
                    best_bound = *inc;
                    break; // best-first: all remaining nodes are worse
                }
                continue; // depth-first: other open nodes may still matter
            }
            let gap = (inc - node.bound).abs() / inc.abs().max(1e-9);
            if best_first && gap < cfg.rel_gap {
                best_bound = node.bound;
                break;
            }
        }
        if nodes > cfg.max_nodes || start.elapsed().as_secs_f64() > cfg.time_limit_s {
            if !best_first {
                // global bound = the node being discarded ∪ the open set
                // (computed only here — a per-pop scan would be O(n²))
                best_bound = frontier
                    .min_bound()
                    .map_or(node.bound, |b| b.min(node.bound));
            }
            hit_limit = true;
            break;
        }

        let lp = ws.solve_with_basis(model, Some(&node.bounds), last_basis.as_deref());
        lp_iterations += lp.iterations;
        if lp.status != LpStatus::Optimal {
            continue; // infeasible subtree
        }
        if chain {
            last_basis = Some(ws.basic_structurals());
        }
        let lp_obj = to_min(lp.objective);
        if let Some((_, inc)) = &incumbent {
            if lp_obj >= *inc - INT_TOL {
                continue;
            }
        }

        // find most-fractional integer variable
        let mut branch_var: Option<(usize, f64)> = None;
        let mut best_frac = INT_TOL;
        for (i, v) in model.vars.iter().enumerate() {
            if v.kind != VarKind::Integer {
                continue;
            }
            let xi = lp.x[i];
            let frac = (xi - xi.round()).abs();
            let dist_half = (xi - xi.floor() - 0.5).abs();
            if frac > best_frac && (branch_var.is_none() || dist_half < 0.49) {
                best_frac = frac;
                branch_var = Some((i, xi));
            }
        }

        match branch_var {
            None => {
                // integral → candidate incumbent
                let mut x = lp.x.clone();
                for (i, v) in model.vars.iter().enumerate() {
                    if v.kind == VarKind::Integer {
                        x[i] = x[i].round();
                    }
                }
                if model.is_feasible(&x, 1e-6) {
                    let obj = to_min(model.objective_value(&x));
                    if incumbent.as_ref().map_or(true, |(_, inc)| obj < *inc) {
                        incumbent = Some((x, obj));
                    }
                }
            }
            Some((bi, xi)) => {
                // Rounding heuristic at every node: snap all int vars,
                // keep if feasible and improving (cheap incumbent
                // seeding/tightening — O(n·m) vs an LP solve).
                {
                    let mut x = lp.x.clone();
                    for (i, v) in model.vars.iter().enumerate() {
                        if v.kind == VarKind::Integer {
                            x[i] = x[i].round().clamp(node.bounds[i].0, node.bounds[i].1);
                        }
                    }
                    if model.is_feasible(&x, 1e-6) {
                        let obj = to_min(model.objective_value(&x));
                        if incumbent.as_ref().map_or(true, |(_, inc)| obj < *inc) {
                            incumbent = Some((x, obj));
                        }
                    }
                }
                // branch floor / ceil
                let mut lo = node.bounds.clone();
                lo[bi].1 = xi.floor();
                let mut hi = node.bounds.clone();
                hi[bi].0 = xi.ceil();
                for child in [lo, hi] {
                    if child[bi].0 <= child[bi].1 + INT_TOL {
                        frontier.push(Node {
                            bound: lp_obj,
                            bounds: child,
                            depth: node.depth + 1,
                        });
                    }
                }
            }
        }
    }

    match incumbent {
        Some((x, obj_min)) => {
            // On a budget break the popped-but-unprocessed node is no
            // longer in the frontier, so its bound must come from
            // `best_bound` — otherwise a truncated search with an empty
            // frontier would be misreported as proved optimal.
            let open_bound = if hit_limit {
                Some(best_bound)
            } else {
                frontier.min_bound()
            };
            let proved = open_bound.map_or(true, |b| b >= obj_min - INT_TOL)
                || (obj_min - best_bound).abs() / obj_min.abs().max(1e-9) < cfg.rel_gap;
            let objective = if min_sense { obj_min } else { -obj_min };
            let bound = if min_sense { best_bound } else { -best_bound };
            BnbResult {
                status: if proved { BnbStatus::Optimal } else { BnbStatus::Feasible },
                x,
                objective,
                bound,
                nodes,
                lp_iterations,
                lp_pivots: ws.total_pivots(),
                warm_started,
                root_basis,
            }
        }
        None => BnbResult {
            // the whole tree was explored without finding any integer
            // point → the IP is infeasible (LP relaxation feasibility
            // notwithstanding); NoSolution is reserved for limit hits.
            status: if hit_limit {
                BnbStatus::NoSolution
            } else {
                BnbStatus::Infeasible
            },
            x: vec![],
            objective: if min_sense { f64::INFINITY } else { f64::NEG_INFINITY },
            bound: if min_sense { best_bound } else { -best_bound },
            nodes,
            lp_iterations,
            lp_pivots: ws.total_pivots(),
            warm_started,
            root_basis,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ilp::model::{Model, ObjSense, Sense};

    #[test]
    fn knapsack() {
        // max 10a + 13b + 7c s.t. 3a + 4b + 2c ≤ 6, binary → a+c (17)?
        // options: a+b w=7 no; b+c w=6 obj 20; a+c w=5 obj 17 → b+c best.
        let mut m = Model::new(ObjSense::Maximize);
        let a = m.add_binary("a", 10.0);
        let b = m.add_binary("b", 13.0);
        let c = m.add_binary("c", 7.0);
        m.add_constraint("w", vec![(a, 3.0), (b, 4.0), (c, 2.0)], Sense::Le, 6.0);
        let r = solve_ilp(&m, &BnbConfig::default());
        assert_eq!(r.status, BnbStatus::Optimal);
        assert!((r.objective - 20.0).abs() < 1e-6, "{}", r.objective);
        assert_eq!(r.x, vec![0.0, 1.0, 1.0]);
        assert!(r.lp_pivots > 0);
        assert!(!r.warm_started);
    }

    #[test]
    fn set_cover_min() {
        // min cost cover of {1,2,3}: s1={1,2} cost 3, s2={2,3} cost 3,
        // s3={1,3} cost 3, s4={1,2,3} cost 5 → s4 (5) beats any pair (6).
        let mut m = Model::new(ObjSense::Minimize);
        let s1 = m.add_binary("s1", 3.0);
        let s2 = m.add_binary("s2", 3.0);
        let s3 = m.add_binary("s3", 3.0);
        let s4 = m.add_binary("s4", 5.0);
        m.add_constraint("e1", vec![(s1, 1.0), (s3, 1.0), (s4, 1.0)], Sense::Ge, 1.0);
        m.add_constraint("e2", vec![(s1, 1.0), (s2, 1.0), (s4, 1.0)], Sense::Ge, 1.0);
        m.add_constraint("e3", vec![(s2, 1.0), (s3, 1.0), (s4, 1.0)], Sense::Ge, 1.0);
        let r = solve_ilp(&m, &BnbConfig::default());
        assert_eq!(r.status, BnbStatus::Optimal);
        assert!((r.objective - 5.0).abs() < 1e-6);
    }

    #[test]
    fn general_integers() {
        // min 4x + 5y s.t. 2x + y ≥ 7, x + 3y ≥ 9, integer
        // LP opt: x=2.4,y=2.2 (22.6); IP opt: check (3,2)=22 feasible:
        // 2*3+2=8≥7 ✓ 3+6=9≥9 ✓ → 22.
        let mut m = Model::new(ObjSense::Minimize);
        let x = m.add_var("x", 0.0, 100.0, VarKind::Integer, 4.0);
        let y = m.add_var("y", 0.0, 100.0, VarKind::Integer, 5.0);
        m.add_constraint("c1", vec![(x, 2.0), (y, 1.0)], Sense::Ge, 7.0);
        m.add_constraint("c2", vec![(x, 1.0), (y, 3.0)], Sense::Ge, 9.0);
        let r = solve_ilp(&m, &BnbConfig::default());
        assert_eq!(r.status, BnbStatus::Optimal);
        assert!((r.objective - 22.0).abs() < 1e-6, "{}", r.objective);
    }

    #[test]
    fn infeasible_ip() {
        let mut m = Model::new(ObjSense::Minimize);
        let x = m.add_binary("x", 1.0);
        m.add_constraint("c", vec![(x, 1.0)], Sense::Ge, 2.0);
        assert_eq!(solve_ilp(&m, &BnbConfig::default()).status, BnbStatus::Infeasible);
    }

    #[test]
    fn warm_start_is_used() {
        let mut m = Model::new(ObjSense::Maximize);
        let a = m.add_binary("a", 1.0);
        let b = m.add_binary("b", 1.0);
        m.add_constraint("c", vec![(a, 1.0), (b, 1.0)], Sense::Le, 1.0);
        let cfg = BnbConfig {
            warm_start: Some(vec![1.0, 0.0]),
            max_nodes: 1, // force early stop: incumbent must be the warm start or better
            ..Default::default()
        };
        let r = solve_ilp(&m, &cfg);
        assert!(r.objective >= 1.0 - 1e-9);
        assert!(r.warm_started);
    }

    #[test]
    fn infeasible_warm_start_is_rejected() {
        let mut m = Model::new(ObjSense::Maximize);
        let a = m.add_binary("a", 1.0);
        let b = m.add_binary("b", 1.0);
        m.add_constraint("c", vec![(a, 1.0), (b, 1.0)], Sense::Le, 1.0);
        let cfg = BnbConfig {
            warm_start: Some(vec![1.0, 1.0]), // violates the constraint
            ..Default::default()
        };
        let r = solve_ilp(&m, &cfg);
        assert!(!r.warm_started);
        assert_eq!(r.status, BnbStatus::Optimal);
        assert!((r.objective - 1.0).abs() < 1e-9);
    }

    #[test]
    fn mixed_integer_continuous() {
        // max x + y, x binary, y ≤ 1.5 cont, x + y ≤ 2 → x=1, y=1 → 2
        let mut m = Model::new(ObjSense::Maximize);
        let x = m.add_binary("x", 1.0);
        let y = m.add_var("y", 0.0, 1.5, VarKind::Continuous, 1.0);
        m.add_constraint("c", vec![(x, 1.0), (y, 1.0)], Sense::Le, 2.0);
        let r = solve_ilp(&m, &BnbConfig::default());
        assert_eq!(r.status, BnbStatus::Optimal);
        assert!((r.objective - 2.0).abs() < 1e-6);
        assert!((r.x[0] - 1.0).abs() < 1e-9);
    }

    #[test]
    fn depth_first_finds_the_same_optimum() {
        for sense in [ObjSense::Minimize, ObjSense::Maximize] {
            let mut m = Model::new(sense);
            let vars: Vec<_> = (0..6)
                .map(|i| m.add_binary(format!("x{i}"), (i as f64) - 2.5))
                .collect();
            let terms: Vec<_> = vars.iter().map(|&v| (v, 2.0)).collect();
            m.add_constraint("w", terms.clone(), Sense::Le, 7.0);
            m.add_constraint("lo", terms, Sense::Ge, 2.0);
            let best = solve_ilp(&m, &BnbConfig::default());
            let dfs = solve_ilp(
                &m,
                &BnbConfig {
                    node_selection: NodeSelection::DepthFirst,
                    ..Default::default()
                },
            );
            assert_eq!(best.status, BnbStatus::Optimal);
            assert_eq!(dfs.status, BnbStatus::Optimal);
            assert!(
                (best.objective - dfs.objective).abs() < 1e-9,
                "{} vs {}",
                best.objective,
                dfs.objective
            );
        }
    }

    #[test]
    fn basis_chaining_matches_cold_search() {
        let mut m = Model::new(ObjSense::Minimize);
        let x = m.add_var("x", 0.0, 100.0, VarKind::Integer, 4.0);
        let y = m.add_var("y", 0.0, 100.0, VarKind::Integer, 5.0);
        m.add_constraint("c1", vec![(x, 2.0), (y, 1.0)], Sense::Ge, 7.0);
        m.add_constraint("c2", vec![(x, 1.0), (y, 3.0)], Sense::Ge, 9.0);
        let cold = solve_ilp(&m, &BnbConfig::default());
        assert!(cold.root_basis.is_none(), "no hint → no basis export");
        let chained = solve_ilp(
            &m,
            &BnbConfig {
                basis_hint: Some(vec![]), // chaining on, no prior hint
                ..Default::default()
            },
        );
        assert_eq!(chained.status, BnbStatus::Optimal);
        assert!((cold.objective - chained.objective).abs() < 1e-9);
        // feed the exported root basis back in, as an arrival loop would
        let again = solve_ilp(
            &m,
            &BnbConfig {
                basis_hint: chained.root_basis.clone(),
                ..Default::default()
            },
        );
        assert_eq!(again.status, BnbStatus::Optimal);
        assert!((cold.objective - again.objective).abs() < 1e-9);
        assert!(chained.root_basis.is_some() && again.root_basis.is_some());
    }

    #[test]
    fn node_selection_keys_roundtrip() {
        for sel in [NodeSelection::BestBound, NodeSelection::DepthFirst] {
            assert_eq!(NodeSelection::from_key(sel.key()), Some(sel));
        }
        assert_eq!(NodeSelection::from_key("breadth-first"), None);
    }
}
