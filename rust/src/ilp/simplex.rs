//! Dense two-phase primal simplex.
//!
//! Solves the LP relaxation of a [`Model`]: variable lower bounds are
//! shifted out, upper bounds become explicit `≤` rows, `≥`/`=` rows get
//! artificials, and the standard-form tableau is optimized with Dantzig
//! pricing (switching to Bland's rule after a degeneracy streak, which
//! guarantees termination).
//!
//! This is deliberately a *dense* tableau: the GOGH allocation LPs are a
//! few hundred variables × a few hundred rows, where dense pivots are
//! cache-friendly and beat a naive sparse implementation. The §Perf pass
//! benchmarks pivot cost in `benches/ilp_scaling.rs`.
//!
//! ## Workspace reuse
//!
//! Branch-and-bound solves thousands of structurally identical LPs that
//! differ only in variable bounds. [`SimplexWorkspace`] keeps every
//! scratch buffer (tableau, basis, reduced-cost row, presolve maps, row
//! build area) alive across solves, so the per-node cost is pivots, not
//! allocator traffic. [`solve_lp`] remains the one-shot convenience
//! wrapper over a throwaway workspace.

use super::model::{Model, ObjSense, Sense};

const EPS: f64 = 1e-9;

/// LP outcome.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LpStatus {
    Optimal,
    Infeasible,
    Unbounded,
}

/// LP result: status, primal solution (in the model's original variable
/// space), objective value.
#[derive(Debug, Clone)]
pub struct LpResult {
    pub status: LpStatus,
    pub x: Vec<f64>,
    pub objective: f64,
    pub iterations: usize,
}

/// Flat-row metadata: coefficients live in `SimplexWorkspace::coefs`
/// at `start..start + len` (one shared buffer, no per-row allocation).
#[derive(Debug, Clone, Copy)]
struct RowMeta {
    start: usize,
    len: usize,
    sense: Sense,
    rhs: f64,
}

/// Tableau dimensions of one solve (compact space).
#[derive(Debug, Clone, Copy)]
struct Dims {
    m: usize,
    n_slack: usize,
    n_art: usize,
    total: usize,
    width: usize,
}

/// Reusable scratch space for repeated LP solves (see module docs).
#[derive(Debug, Default)]
pub struct SimplexWorkspace {
    /// dense tableau, `m × width`, row-major
    t: Vec<f64>,
    basis: Vec<usize>,
    /// reduced-cost row (phase 1, then rebuilt for phase 2)
    z: Vec<f64>,
    /// original variable index -> compact column (usize::MAX = fixed)
    compact: Vec<usize>,
    /// compact column -> original variable index
    originals: Vec<usize>,
    /// phase-2 costs over compact columns
    cost: Vec<f64>,
    /// flat row-coefficient buffer (indexed by `RowMeta`)
    coefs: Vec<(usize, f64)>,
    rows: Vec<RowMeta>,
    art_rows: Vec<usize>,
    total_pivots: u64,
    solves: u64,
}

impl SimplexWorkspace {
    pub fn new() -> Self {
        Self::default()
    }

    /// Cumulative pivot count over every solve through this workspace —
    /// the per-node cost metric `benches/ilp_scaling.rs` reports.
    pub fn total_pivots(&self) -> u64 {
        self.total_pivots
    }

    /// Number of LP solves performed through this workspace.
    pub fn solves(&self) -> u64 {
        self.solves
    }

    /// Original-space indices of the structural variables basic at the
    /// end of the last solve, ascending. Export these as a warm-start
    /// hint for [`SimplexWorkspace::solve_with_basis`] on the next
    /// structurally-similar model (the basis-reuse half of the
    /// scale-out levers; see `benches/ilp_scaling.rs`).
    pub fn basic_structurals(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .basis
            .iter()
            .filter(|&&b| b < self.originals.len())
            .map(|&b| self.originals[b])
            .collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    /// Solve the LP relaxation of `model`, with optional per-variable
    /// bound overrides (used by branch-and-bound to fix/branch
    /// variables). Identical semantics to [`solve_lp`]; buffers are
    /// reused across calls.
    pub fn solve(&mut self, model: &Model, bounds: Option<&[(f64, f64)]>) -> LpResult {
        self.solve_with_basis(model, bounds, None)
    }

    /// [`SimplexWorkspace::solve`] with an optional crash-start basis:
    /// `hint` names original-space variable indices that were basic at a
    /// previous solve of a related model (from
    /// [`SimplexWorkspace::basic_structurals`]). Each hinted column is
    /// driven into the starting basis by Gauss-Jordan pivots; when the
    /// crashed basis is primal-feasible with no artificial left basic,
    /// phase 1 is skipped entirely. A stale, fixed-out or
    /// rank-deficient hint degrades gracefully to the cold two-phase
    /// path on a rebuilt tableau, so the result is always correct —
    /// only the pivot count changes. `hint: None` is bit-identical to
    /// [`SimplexWorkspace::solve`].
    pub fn solve_with_basis(
        &mut self,
        model: &Model,
        bounds: Option<&[(f64, f64)]>,
        hint: Option<&[usize]>,
    ) -> LpResult {
        self.solves += 1;
        let n = model.n_vars();
        let get_bounds = |i: usize| -> (f64, f64) {
            match bounds {
                Some(b) => b[i],
                None => (model.vars[i].lb, model.vars[i].ub),
            }
        };

        // Quick inconsistency check (branching can cross bounds).
        for i in 0..n {
            let (lb, ub) = get_bounds(i);
            if lb > ub + EPS {
                return infeasible(0);
            }
        }

        // Shift x_i = lb_i + x'_i with x' >= 0; finite ub becomes a row.
        // Objective: always minimize internally.
        let obj_sign = match model.obj_sense {
            ObjSense::Minimize => 1.0,
            ObjSense::Maximize => -1.0,
        };

        // Presolve: variables with lb == ub are FIXED — they contribute
        // only constants. Eliminating them (no column, no bound row) is
        // the single biggest lever for branch-and-bound performance:
        // deep B&B nodes fix many integers, and before this presolve
        // each one cost an equality row + an artificial + phase-1 pivots.
        self.compact.clear();
        self.originals.clear();
        for i in 0..n {
            let (lb, ub) = get_bounds(i);
            if ub.is_finite() && ub - lb <= EPS {
                self.compact.push(usize::MAX);
            } else {
                self.compact.push(self.originals.len());
                self.originals.push(i);
            }
        }
        let nf = self.originals.len(); // free (non-fixed) variable count
        self.cost.clear();
        for &i in &self.originals {
            self.cost.push(obj_sign * model.vars[i].obj);
        }

        // Build rows over compact columns: (coefs, sense, rhs) after the
        // shift. Fixed variables' contributions fold into the rhs.
        self.coefs.clear();
        self.rows.clear();
        for c in &model.constraints {
            let mut rhs = c.rhs;
            let start = self.coefs.len();
            for &(v, coef) in &c.terms {
                rhs -= coef * get_bounds(v.0).0;
                if self.compact[v.0] != usize::MAX {
                    self.coefs.push((self.compact[v.0], coef));
                }
            }
            let len = self.coefs.len() - start;
            // constraint over only-fixed variables: check it directly
            if len == 0 {
                let ok = match c.sense {
                    Sense::Le => 0.0 <= rhs + EPS,
                    Sense::Ge => 0.0 >= rhs - EPS,
                    Sense::Eq => rhs.abs() <= EPS,
                };
                if !ok {
                    return infeasible(0);
                }
                continue;
            }
            self.rows.push(RowMeta {
                start,
                len,
                sense: c.sense,
                rhs,
            });
        }
        for ci in 0..nf {
            let (lb, ub) = get_bounds(self.originals[ci]);
            if ub.is_finite() {
                let start = self.coefs.len();
                self.coefs.push((ci, 1.0));
                self.rows.push(RowMeta {
                    start,
                    len: 1,
                    sense: Sense::Le,
                    rhs: ub - lb,
                });
            }
        }
        let n = nf; // from here on, work in the compact space
        let mut d = self.build_tableau(n);

        let mut iterations = 0usize;

        // ---- Optional crash start: drive the hinted basis in before
        // phase 1. On success the crashed basis is primal-feasible with
        // no artificial basic, so phase 1 is skipped outright (phase 2
        // below rebuilds its reduced-cost row from scratch for ANY
        // basis). On failure the crash pivots have corrupted the
        // tableau, so it is rebuilt and the cold path runs.
        let mut crashed = false;
        if let Some(hint) = hint {
            crashed = self.crash_basis(hint, n, d);
            if !crashed {
                d = self.build_tableau(n);
            }
        }
        let (m, n_slack, n_art) = (d.m, d.n_slack, d.n_art);
        let (total, width) = (d.total, d.width);

        // ---- Phase 1: minimize sum of artificials.
        if !crashed && n_art > 0 {
            // reduced costs z for the phase-1 objective (Σ artificial rows)
            self.z.clear();
            self.z.resize(width, 0.0);
            for &ri in &self.art_rows {
                for c in 0..width {
                    self.z[c] += self.t[ri * width + c];
                }
            }
            // artificial columns have cost 1 → track z_j - c_j
            for a in (n + n_slack)..total {
                self.z[a] -= 1.0;
            }
            let status = optimize(
                &mut self.t,
                &mut self.basis,
                &mut self.z,
                m,
                total,
                width,
                &mut iterations,
                Some(n + n_slack),
                &mut self.total_pivots,
            );
            if status == LpStatus::Unbounded {
                // phase-1 objective is bounded below by 0; cannot happen
                unreachable!("phase 1 unbounded");
            }
            if self.z[total] > 1e-7 {
                // Σ artificials > 0 at the phase-1 optimum → infeasible
                // (z[total] carries c_B'B⁻¹b = the current objective value)
                return infeasible(iterations);
            }
            // Drive any artificial still in the basis out (degenerate rows).
            for ri in 0..m {
                if self.basis[ri] >= n + n_slack {
                    // find a non-artificial column with nonzero coef here;
                    // a fully-zero row is redundant — leave the artificial
                    // basic at 0.
                    let col = (0..(n + n_slack)).find(|&c| self.t[ri * width + c].abs() > 1e-7);
                    if let Some(c) = col {
                        pivot(
                            &mut self.t,
                            &mut self.basis,
                            ri,
                            c,
                            m,
                            width,
                            &mut self.z,
                            &mut self.total_pivots,
                        );
                    }
                }
            }
        }

        // ---- Phase 2: minimize the real objective (artificials barred).
        self.z.clear();
        self.z.resize(width, 0.0);
        // z_j = c_B' B^-1 A_j - c_j  computed from the current tableau:
        for c in 0..width {
            let mut acc = 0.0;
            for ri in 0..m {
                let b = self.basis[ri];
                let cb = if b < n { self.cost[b] } else { 0.0 };
                acc += cb * self.t[ri * width + c];
            }
            self.z[c] = acc;
        }
        for j in 0..n {
            self.z[j] -= self.cost[j];
        }
        let status = optimize(
            &mut self.t,
            &mut self.basis,
            &mut self.z,
            m,
            total,
            width,
            &mut iterations,
            Some(n + n_slack),
            &mut self.total_pivots,
        );
        if status == LpStatus::Unbounded {
            return LpResult {
                status,
                x: vec![],
                objective: f64::NEG_INFINITY,
                iterations,
            };
        }

        // Extract structural solution (un-shift; fixed vars sit at lb).
        let mut x = vec![0.0f64; model.n_vars()];
        for (i, xi) in x.iter_mut().enumerate() {
            *xi = get_bounds(i).0;
        }
        for ri in 0..m {
            if self.basis[ri] < n {
                x[self.originals[self.basis[ri]]] += self.t[ri * width + total];
            }
        }
        for xi in x.iter_mut() {
            // clean numerical dust
            if xi.abs() < 1e-11 {
                *xi = 0.0;
            }
        }
        let objective = model.objective_value(&x);
        LpResult {
            status: LpStatus::Optimal,
            x,
            objective,
            iterations,
        }
    }

    /// (Re)build the standard-form tableau from the prepared `rows` /
    /// `coefs` buffers. Column layout: `[structural 0..n | slack/surplus
    /// | artificials] + RHS`; each row's starting basic column is its
    /// slack or artificial.
    fn build_tableau(&mut self, n: usize) -> Dims {
        let m = self.rows.len();
        let mut n_slack = 0;
        let mut n_art = 0;
        for r in &self.rows {
            let rhs_neg = r.rhs < -EPS;
            match effective_sense(r.sense, rhs_neg) {
                Sense::Le => n_slack += 1,
                Sense::Ge => {
                    n_slack += 1;
                    n_art += 1;
                }
                Sense::Eq => n_art += 1,
            }
        }
        let total = n + n_slack + n_art;
        let width = total + 1; // + RHS column
        self.t.clear();
        self.t.resize(m * width, 0.0);
        self.basis.clear();
        self.basis.resize(m, 0);
        self.art_rows.clear();

        let mut slack_col = n;
        let mut art_col = n + n_slack;
        for ri in 0..m {
            let r = self.rows[ri];
            let neg = r.rhs < -EPS;
            let sgn = if neg { -1.0 } else { 1.0 };
            for k in r.start..r.start + r.len {
                let (ci, coef) = self.coefs[k];
                self.t[ri * width + ci] += sgn * coef;
            }
            self.t[ri * width + total] = sgn * r.rhs;
            match effective_sense(r.sense, neg) {
                Sense::Le => {
                    self.t[ri * width + slack_col] = 1.0;
                    self.basis[ri] = slack_col;
                    slack_col += 1;
                }
                Sense::Ge => {
                    self.t[ri * width + slack_col] = -1.0;
                    slack_col += 1;
                    self.t[ri * width + art_col] = 1.0;
                    self.basis[ri] = art_col;
                    art_col += 1;
                    self.art_rows.push(ri);
                }
                Sense::Eq => {
                    self.t[ri * width + art_col] = 1.0;
                    self.basis[ri] = art_col;
                    art_col += 1;
                    self.art_rows.push(ri);
                }
            }
        }
        Dims {
            m,
            n_slack,
            n_art,
            total,
            width,
        }
    }

    /// Gauss-Jordan crash: drive each hinted structural column into the
    /// starting basis. Hint entries that are out of range, fixed out by
    /// presolve, or linearly dependent on already-crashed columns are
    /// skipped (stale-hint tolerance). Pivot rows are chosen by largest
    /// absolute coefficient among unclaimed rows (ties to the lowest
    /// row index — deterministic). Returns whether the crashed basis is
    /// usable: primal-feasible RHS and no artificial left basic. The
    /// crash pivots count toward [`SimplexWorkspace::total_pivots`].
    fn crash_basis(&mut self, hint: &[usize], n: usize, d: Dims) -> bool {
        let (m, n_slack, total, width) = (d.m, d.n_slack, d.total, d.width);
        if m == 0 {
            return true;
        }
        // dummy reduced-cost row for pivot bookkeeping: phase 2 rebuilds
        // the real one from scratch for whatever basis results
        self.z.clear();
        self.z.resize(width, 0.0);
        let mut claimed = vec![false; m];
        for &orig in hint {
            let Some(&ci) = self.compact.get(orig) else { continue };
            if ci == usize::MAX || ci >= n {
                continue;
            }
            if let Some(r) = (0..m).find(|&r| self.basis[r] == ci) {
                claimed[r] = true; // duplicate hint entry: already basic
                continue;
            }
            let mut best: Option<(f64, usize)> = None;
            for (r, c) in claimed.iter().enumerate() {
                if *c {
                    continue;
                }
                let a = self.t[r * width + ci].abs();
                if a > 1e-7 && best.map_or(true, |(ba, _)| a > ba) {
                    best = Some((a, r));
                }
            }
            let Some((_, r)) = best else { continue };
            pivot(
                &mut self.t,
                &mut self.basis,
                r,
                ci,
                m,
                width,
                &mut self.z,
                &mut self.total_pivots,
            );
            claimed[r] = true;
        }
        // Rescue pass: a row still holding a basic artificial can often
        // be claimed by a slack/surplus column instead (a `≥` row the
        // crashed structurals over-satisfy takes its surplus in with a
        // positive value). Only pivots keeping this row's RHS feasible
        // are tried; the final check validates the whole tableau.
        for r in 0..m {
            if self.basis[r] < n + n_slack {
                continue;
            }
            let mut best: Option<(f64, usize)> = None;
            for c in n..n + n_slack {
                if self.basis.contains(&c) {
                    continue;
                }
                let a = self.t[r * width + c];
                if a.abs() > 1e-7 && self.t[r * width + total] / a >= -EPS {
                    let better = best.map_or(true, |(ba, _)| a.abs() > ba);
                    if better {
                        best = Some((a.abs(), c));
                    }
                }
            }
            if let Some((_, c)) = best {
                pivot(
                    &mut self.t,
                    &mut self.basis,
                    r,
                    c,
                    m,
                    width,
                    &mut self.z,
                    &mut self.total_pivots,
                );
            }
        }
        (0..m).all(|r| self.basis[r] < n + n_slack && self.t[r * width + total] >= -EPS)
    }
}

/// Solve the LP relaxation of `model` with a throwaway workspace.
///
/// `bounds`: if `Some`, `bounds[i] = (lb, ub)` replaces the model's
/// bounds for variable `i`.
pub fn solve_lp(model: &Model, bounds: Option<&[(f64, f64)]>) -> LpResult {
    SimplexWorkspace::new().solve(model, bounds)
}

fn infeasible(iterations: usize) -> LpResult {
    LpResult {
        status: LpStatus::Infeasible,
        x: vec![],
        objective: f64::INFINITY,
        iterations,
    }
}

fn effective_sense(s: Sense, rhs_negated: bool) -> Sense {
    if !rhs_negated {
        return s;
    }
    match s {
        Sense::Le => Sense::Ge,
        Sense::Ge => Sense::Le,
        Sense::Eq => Sense::Eq,
    }
}

/// Core pivot loop. `z` is the reduced-cost row (z_j - c_j; entering
/// columns have z_j - c_j > 0 for a minimization), `z[width-1]` holds
/// `-objective`. `barred_from` bars columns ≥ that index (artificials in
/// phase 2).
fn optimize(
    t: &mut [f64],
    basis: &mut [usize],
    z: &mut [f64],
    m: usize,
    total: usize,
    width: usize,
    iterations: &mut usize,
    barred_from: Option<usize>,
    pivots: &mut u64,
) -> LpStatus {
    let bar = barred_from.unwrap_or(total);
    let mut degenerate_streak = 0usize;
    loop {
        *iterations += 1;
        if *iterations > 50_000 {
            // safety valve; with Bland's rule this should not trigger
            return LpStatus::Optimal;
        }
        // Pricing: Dantzig normally; Bland when cycling is suspected.
        let use_bland = degenerate_streak > 2 * (m + total);
        let mut enter: Option<usize> = None;
        if use_bland {
            for c in 0..bar {
                if z[c] > EPS {
                    enter = Some(c);
                    break;
                }
            }
        } else {
            let mut best = EPS;
            for c in 0..bar {
                if z[c] > best {
                    best = z[c];
                    enter = Some(c);
                }
            }
        }
        let Some(e) = enter else {
            return LpStatus::Optimal;
        };
        // Ratio test.
        let mut leave: Option<usize> = None;
        let mut best_ratio = f64::INFINITY;
        for ri in 0..m {
            let a = t[ri * width + e];
            if a > EPS {
                let ratio = t[ri * width + total] / a;
                if ratio < best_ratio - EPS
                    || (ratio < best_ratio + EPS && leave.map_or(true, |l| basis[ri] < basis[l]))
                {
                    best_ratio = ratio;
                    leave = Some(ri);
                }
            }
        }
        let Some(l) = leave else {
            return LpStatus::Unbounded;
        };
        if best_ratio < EPS {
            degenerate_streak += 1;
        } else {
            degenerate_streak = 0;
        }
        pivot(t, basis, l, e, m, width, z, pivots);
    }
}

/// Pivot on (row `l`, col `e`), updating tableau, basis, and the z-row.
fn pivot(
    t: &mut [f64],
    basis: &mut [usize],
    l: usize,
    e: usize,
    m: usize,
    width: usize,
    z: &mut [f64],
    pivots: &mut u64,
) {
    *pivots += 1;
    let piv = t[l * width + e];
    debug_assert!(piv.abs() > 1e-12);
    let inv = 1.0 / piv;
    for c in 0..width {
        t[l * width + c] *= inv;
    }
    t[l * width + e] = 1.0; // exact
    for ri in 0..m {
        if ri == l {
            continue;
        }
        let f = t[ri * width + e];
        if f.abs() > 1e-13 {
            for c in 0..width {
                t[ri * width + c] -= f * t[l * width + c];
            }
            t[ri * width + e] = 0.0;
        }
    }
    let f = z[e];
    if f.abs() > 1e-13 {
        for c in 0..width {
            z[c] -= f * t[l * width + c];
        }
        z[e] = 0.0;
    }
    basis[l] = e;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ilp::model::{Model, ObjSense, Sense, VarKind};

    fn var(m: &mut Model, name: &str, obj: f64) -> crate::ilp::VarId {
        m.add_var(name, 0.0, f64::INFINITY, VarKind::Continuous, obj)
    }

    #[test]
    fn maximize_classic_lp() {
        // max 3x + 5y s.t. x ≤ 4, 2y ≤ 12, 3x + 2y ≤ 18 → (2, 6) obj 36
        let mut m = Model::new(ObjSense::Maximize);
        let x = var(&mut m, "x", 3.0);
        let y = var(&mut m, "y", 5.0);
        m.add_constraint("c1", vec![(x, 1.0)], Sense::Le, 4.0);
        m.add_constraint("c2", vec![(y, 2.0)], Sense::Le, 12.0);
        m.add_constraint("c3", vec![(x, 3.0), (y, 2.0)], Sense::Le, 18.0);
        let r = solve_lp(&m, None);
        assert_eq!(r.status, LpStatus::Optimal);
        assert!((r.objective - 36.0).abs() < 1e-6, "{}", r.objective);
        assert!((r.x[0] - 2.0).abs() < 1e-6 && (r.x[1] - 6.0).abs() < 1e-6);
    }

    #[test]
    fn minimize_with_ge_constraints() {
        // min 2x + 3y s.t. x + y ≥ 10, x ≥ 2 → (8, 2)? obj: prefer x
        // (cheaper): x=10,y=0 gives 20; but x ≥ 2 only. optimum x=10 y=0 → 20
        let mut m = Model::new(ObjSense::Minimize);
        let x = var(&mut m, "x", 2.0);
        let y = var(&mut m, "y", 3.0);
        m.add_constraint("cover", vec![(x, 1.0), (y, 1.0)], Sense::Ge, 10.0);
        m.add_constraint("xmin", vec![(x, 1.0)], Sense::Ge, 2.0);
        let r = solve_lp(&m, None);
        assert_eq!(r.status, LpStatus::Optimal);
        assert!((r.objective - 20.0).abs() < 1e-6);
    }

    #[test]
    fn equality_constraints() {
        // min x + y s.t. x + 2y = 4, x - y = 1 → x=2, y=1, obj 3
        let mut m = Model::new(ObjSense::Minimize);
        let x = var(&mut m, "x", 1.0);
        let y = var(&mut m, "y", 1.0);
        m.add_constraint("e1", vec![(x, 1.0), (y, 2.0)], Sense::Eq, 4.0);
        m.add_constraint("e2", vec![(x, 1.0), (y, -1.0)], Sense::Eq, 1.0);
        let r = solve_lp(&m, None);
        assert_eq!(r.status, LpStatus::Optimal);
        assert!((r.x[0] - 2.0).abs() < 1e-6 && (r.x[1] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn detects_infeasible() {
        let mut m = Model::new(ObjSense::Minimize);
        let x = var(&mut m, "x", 1.0);
        m.add_constraint("lo", vec![(x, 1.0)], Sense::Ge, 5.0);
        m.add_constraint("hi", vec![(x, 1.0)], Sense::Le, 3.0);
        assert_eq!(solve_lp(&m, None).status, LpStatus::Infeasible);
    }

    #[test]
    fn detects_unbounded() {
        let mut m = Model::new(ObjSense::Maximize);
        let x = var(&mut m, "x", 1.0);
        m.add_constraint("lo", vec![(x, 1.0)], Sense::Ge, 0.0);
        assert_eq!(solve_lp(&m, None).status, LpStatus::Unbounded);
    }

    #[test]
    fn respects_upper_bounds() {
        let mut m = Model::new(ObjSense::Maximize);
        let x = m.add_var("x", 0.0, 2.5, VarKind::Continuous, 1.0);
        m.add_constraint("c", vec![(x, 1.0)], Sense::Le, 100.0);
        let r = solve_lp(&m, None);
        assert!((r.x[0] - 2.5).abs() < 1e-6);
    }

    #[test]
    fn respects_lower_bound_shift() {
        // min x with lb 3 → x = 3
        let mut m = Model::new(ObjSense::Minimize);
        let x = m.add_var("x", 3.0, 10.0, VarKind::Continuous, 1.0);
        m.add_constraint("c", vec![(x, 1.0)], Sense::Le, 100.0);
        let r = solve_lp(&m, None);
        assert!((r.x[0] - 3.0).abs() < 1e-6);
    }

    #[test]
    fn bound_overrides_fix_variable() {
        let mut m = Model::new(ObjSense::Maximize);
        let x = m.add_var("x", 0.0, 5.0, VarKind::Continuous, 1.0);
        let y = m.add_var("y", 0.0, 5.0, VarKind::Continuous, 1.0);
        m.add_constraint("c", vec![(x, 1.0), (y, 1.0)], Sense::Le, 6.0);
        let r = solve_lp(&m, Some(&[(2.0, 2.0), (0.0, 5.0)]));
        assert!((r.x[0] - 2.0).abs() < 1e-6);
        assert!((r.x[1] - 4.0).abs() < 1e-6);
    }

    #[test]
    fn degenerate_lp_terminates() {
        // classic degenerate corner: multiple constraints meet at origin
        let mut m = Model::new(ObjSense::Maximize);
        let x = var(&mut m, "x", 1.0);
        let y = var(&mut m, "y", 1.0);
        m.add_constraint("c1", vec![(x, 1.0), (y, 1.0)], Sense::Le, 1.0);
        m.add_constraint("c2", vec![(x, 1.0), (y, 2.0)], Sense::Le, 1.0);
        m.add_constraint("c3", vec![(x, 2.0), (y, 1.0)], Sense::Le, 1.0);
        let r = solve_lp(&m, None);
        assert_eq!(r.status, LpStatus::Optimal);
        assert!(r.objective <= 1.0 + 1e-6);
    }

    #[test]
    fn workspace_reuse_matches_fresh_solves() {
        // One workspace across differently-shaped models must give
        // bit-identical results to fresh solves (same arithmetic path).
        let mut ws = SimplexWorkspace::new();
        let mut rng = crate::util::Rng::seed_from_u64(99);
        for case in 0..30 {
            let nv = rng.range_usize(2, 12);
            let sense = if rng.bool(0.5) {
                ObjSense::Minimize
            } else {
                ObjSense::Maximize
            };
            let mut m = Model::new(sense);
            let vars: Vec<_> = (0..nv)
                .map(|i| {
                    m.add_var(
                        format!("x{i}"),
                        0.0,
                        rng.range_f64(1.0, 10.0),
                        VarKind::Continuous,
                        rng.range_f64(-4.0, 4.0),
                    )
                })
                .collect();
            for ci in 0..rng.range_usize(1, 6) {
                let mut terms = vec![];
                for &v in &vars {
                    if rng.bool(0.5) {
                        terms.push((v, rng.range_f64(-2.0, 2.0)));
                    }
                }
                if terms.is_empty() {
                    continue;
                }
                let s = match rng.range_usize(0, 3) {
                    0 => Sense::Le,
                    1 => Sense::Ge,
                    _ => Sense::Eq,
                };
                m.add_constraint(format!("c{ci}"), terms, s, rng.range_f64(-3.0, 6.0));
            }
            let fresh = solve_lp(&m, None);
            let reused = ws.solve(&m, None);
            assert_eq!(fresh.status, reused.status, "case {case}");
            if fresh.status == LpStatus::Optimal {
                assert_eq!(fresh.objective, reused.objective, "case {case}");
                assert_eq!(fresh.x, reused.x, "case {case}");
            }
        }
        assert!(ws.solves() == 30 && ws.total_pivots() > 0);
    }

    #[test]
    fn basis_warm_start_matches_cold_solve() {
        // Re-solving with the exported basis must reach the same
        // optimum as the cold two-phase path. Degenerate ties may pick
        // a different optimal vertex, so the objective (not x) is the
        // contract here.
        let mut cold = SimplexWorkspace::new();
        let mut warm = SimplexWorkspace::new();
        let mut rng = crate::util::Rng::seed_from_u64(7);
        for case in 0..30 {
            let nv = rng.range_usize(2, 12);
            let sense = if rng.bool(0.5) {
                ObjSense::Minimize
            } else {
                ObjSense::Maximize
            };
            let mut m = Model::new(sense);
            let vars: Vec<_> = (0..nv)
                .map(|i| {
                    m.add_var(
                        format!("x{i}"),
                        0.0,
                        rng.range_f64(1.0, 10.0),
                        VarKind::Continuous,
                        rng.range_f64(-4.0, 4.0),
                    )
                })
                .collect();
            for ci in 0..rng.range_usize(1, 6) {
                let mut terms = vec![];
                for &v in &vars {
                    if rng.bool(0.5) {
                        terms.push((v, rng.range_f64(-2.0, 2.0)));
                    }
                }
                if terms.is_empty() {
                    continue;
                }
                let s = match rng.range_usize(0, 3) {
                    0 => Sense::Le,
                    1 => Sense::Ge,
                    _ => Sense::Eq,
                };
                m.add_constraint(format!("c{ci}"), terms, s, rng.range_f64(-3.0, 6.0));
            }
            let a = cold.solve(&m, None);
            let hint = cold.basic_structurals();
            let b = warm.solve_with_basis(&m, None, Some(&hint));
            assert_eq!(a.status, b.status, "case {case}");
            if a.status == LpStatus::Optimal {
                assert!((a.objective - b.objective).abs() < 1e-7, "case {case}");
            }
        }
    }

    #[test]
    fn warm_start_on_ge_rows_matches_cold() {
        let mut m = Model::new(ObjSense::Minimize);
        let x = var(&mut m, "x", 2.0);
        let y = var(&mut m, "y", 3.0);
        m.add_constraint("cover", vec![(x, 1.0), (y, 1.0)], Sense::Ge, 10.0);
        m.add_constraint("xmin", vec![(x, 1.0)], Sense::Ge, 2.0);
        let mut ws = SimplexWorkspace::new();
        let cold = ws.solve(&m, None);
        let hint = ws.basic_structurals();
        assert!(hint.contains(&0), "x is basic at the optimum");
        let warm = ws.solve_with_basis(&m, None, Some(&hint));
        assert_eq!(warm.status, LpStatus::Optimal);
        assert!((cold.objective - warm.objective).abs() < 1e-9);
    }

    #[test]
    fn stale_or_bogus_basis_hints_degrade_gracefully() {
        let mut ws = SimplexWorkspace::new();
        let mut m = Model::new(ObjSense::Maximize);
        let x = var(&mut m, "x", 3.0);
        let y = var(&mut m, "y", 5.0);
        m.add_constraint("c1", vec![(x, 1.0)], Sense::Le, 4.0);
        m.add_constraint("c2", vec![(y, 2.0)], Sense::Le, 12.0);
        m.add_constraint("c3", vec![(x, 3.0), (y, 2.0)], Sense::Le, 18.0);
        for hint in [vec![], vec![0], vec![1, 1], vec![99, 7, 0, 1]] {
            let r = ws.solve_with_basis(&m, None, Some(&hint));
            assert_eq!(r.status, LpStatus::Optimal, "hint {hint:?}");
            assert!((r.objective - 36.0).abs() < 1e-6, "hint {hint:?}");
        }
        // hinting a variable that bound overrides have fixed out of the
        // model must fall through presolve harmlessly
        let r = ws.solve_with_basis(&m, Some(&[(2.0, 2.0), (0.0, f64::INFINITY)]), Some(&[0, 1]));
        assert_eq!(r.status, LpStatus::Optimal);
        assert!((r.x[0] - 2.0).abs() < 1e-6);
    }

    #[test]
    fn workspace_counts_pivots() {
        let mut ws = SimplexWorkspace::new();
        let mut m = Model::new(ObjSense::Maximize);
        let x = var(&mut m, "x", 3.0);
        let y = var(&mut m, "y", 5.0);
        m.add_constraint("c1", vec![(x, 1.0)], Sense::Le, 4.0);
        m.add_constraint("c2", vec![(y, 2.0)], Sense::Le, 12.0);
        m.add_constraint("c3", vec![(x, 3.0), (y, 2.0)], Sense::Le, 18.0);
        let before = ws.total_pivots();
        ws.solve(&m, None);
        assert!(ws.total_pivots() > before);
    }
}
