//! Dense two-phase primal simplex.
//!
//! Solves the LP relaxation of a [`Model`]: variable lower bounds are
//! shifted out, upper bounds become explicit `≤` rows, `≥`/`=` rows get
//! artificials, and the standard-form tableau is optimized with Dantzig
//! pricing (switching to Bland's rule after a degeneracy streak, which
//! guarantees termination).
//!
//! This is deliberately a *dense* tableau: the GOGH allocation LPs are a
//! few hundred variables × a few hundred rows, where dense pivots are
//! cache-friendly and beat a naive sparse implementation. The §Perf pass
//! benchmarks pivot cost in `benches/ilp_scaling.rs`.

use super::model::{Model, ObjSense, Sense};

const EPS: f64 = 1e-9;

/// LP outcome.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LpStatus {
    Optimal,
    Infeasible,
    Unbounded,
}

/// LP result: status, primal solution (in the model's original variable
/// space), objective value.
#[derive(Debug, Clone)]
pub struct LpResult {
    pub status: LpStatus,
    pub x: Vec<f64>,
    pub objective: f64,
    pub iterations: usize,
}

/// Solve the LP relaxation of `model`, with optional per-variable bound
/// overrides (used by branch-and-bound to fix/branch variables).
///
/// `bounds`: if `Some`, `bounds[i] = (lb, ub)` replaces the model's
/// bounds for variable `i`.
pub fn solve_lp(model: &Model, bounds: Option<&[(f64, f64)]>) -> LpResult {
    let n = model.n_vars();
    let get_bounds = |i: usize| -> (f64, f64) {
        match bounds {
            Some(b) => b[i],
            None => (model.vars[i].lb, model.vars[i].ub),
        }
    };

    // Quick inconsistency check (branching can cross bounds).
    for i in 0..n {
        let (lb, ub) = get_bounds(i);
        if lb > ub + EPS {
            return LpResult {
                status: LpStatus::Infeasible,
                x: vec![],
                objective: f64::INFINITY,
                iterations: 0,
            };
        }
    }

    // Shift x_i = lb_i + x'_i with x' >= 0; finite ub becomes a row.
    // Objective: always minimize internally.
    let obj_sign = match model.obj_sense {
        ObjSense::Minimize => 1.0,
        ObjSense::Maximize => -1.0,
    };

    // Presolve: variables with lb == ub are FIXED — they contribute only
    // constants. Eliminating them (no column, no bound row) is the
    // single biggest lever for branch-and-bound performance: deep B&B
    // nodes fix many integers, and before this presolve each one cost an
    // equality row + an artificial + phase-1 pivots (EXPERIMENTS.md
    // §Perf records the before/after).
    let mut compact: Vec<usize> = Vec::with_capacity(n); // original -> compact (or usize::MAX)
    let mut originals: Vec<usize> = Vec::with_capacity(n); // compact -> original
    for i in 0..n {
        let (lb, ub) = get_bounds(i);
        if ub.is_finite() && ub - lb <= EPS {
            compact.push(usize::MAX);
        } else {
            compact.push(originals.len());
            originals.push(i);
        }
    }
    let nf = originals.len(); // free (non-fixed) variable count
    let cost: Vec<f64> = originals
        .iter()
        .map(|&i| obj_sign * model.vars[i].obj)
        .collect();

    // Build rows over compact columns: (coefs, sense, rhs) after shift.
    // Fixed variables' contributions fold into the rhs via the lb shift.
    struct Row {
        coefs: Vec<(usize, f64)>,
        sense: Sense,
        rhs: f64,
    }
    let mut rows: Vec<Row> = Vec::with_capacity(model.n_constraints() + nf);
    for c in &model.constraints {
        let mut rhs = c.rhs;
        let mut coefs = Vec::with_capacity(c.terms.len());
        for &(v, coef) in &c.terms {
            rhs -= coef * get_bounds(v.0).0;
            if compact[v.0] != usize::MAX {
                coefs.push((compact[v.0], coef));
            }
        }
        // constraint over only-fixed variables: check it directly
        if coefs.is_empty() {
            let ok = match c.sense {
                Sense::Le => 0.0 <= rhs + EPS,
                Sense::Ge => 0.0 >= rhs - EPS,
                Sense::Eq => rhs.abs() <= EPS,
            };
            if !ok {
                return LpResult {
                    status: LpStatus::Infeasible,
                    x: vec![],
                    objective: f64::INFINITY,
                    iterations: 0,
                };
            }
            continue;
        }
        rows.push(Row {
            coefs,
            sense: c.sense,
            rhs,
        });
    }
    for (ci, &i) in originals.iter().enumerate() {
        let (lb, ub) = get_bounds(i);
        if ub.is_finite() {
            rows.push(Row {
                coefs: vec![(ci, 1.0)],
                sense: Sense::Le,
                rhs: ub - lb,
            });
        }
    }
    let n = nf; // from here on, work in the compact space

    let m = rows.len();
    // Column layout: [structural 0..n | slack/surplus | artificials] + RHS.
    // Count extras.
    let mut n_slack = 0;
    let mut n_art = 0;
    for r in &rows {
        let rhs_neg = r.rhs < -EPS;
        let sense = effective_sense(r.sense, rhs_neg);
        match sense {
            Sense::Le => n_slack += 1,
            Sense::Ge => {
                n_slack += 1;
                n_art += 1;
            }
            Sense::Eq => n_art += 1,
        }
    }
    let total = n + n_slack + n_art;
    let width = total + 1; // + RHS column
    let mut t = vec![0.0f64; m * width]; // tableau
    let mut basis = vec![0usize; m];

    let mut slack_col = n;
    let mut art_col = n + n_slack;
    let mut art_rows: Vec<usize> = vec![];
    for (ri, r) in rows.iter().enumerate() {
        let neg = r.rhs < -EPS;
        let sgn = if neg { -1.0 } else { 1.0 };
        let row = &mut t[ri * width..(ri + 1) * width];
        for &(ci, k) in &r.coefs {
            row[ci] += sgn * k;
        }
        row[total] = sgn * r.rhs;
        match effective_sense(r.sense, neg) {
            Sense::Le => {
                row[slack_col] = 1.0;
                basis[ri] = slack_col;
                slack_col += 1;
            }
            Sense::Ge => {
                row[slack_col] = -1.0;
                slack_col += 1;
                row[art_col] = 1.0;
                basis[ri] = art_col;
                art_col += 1;
                art_rows.push(ri);
            }
            Sense::Eq => {
                row[art_col] = 1.0;
                basis[ri] = art_col;
                art_col += 1;
                art_rows.push(ri);
            }
        }
    }

    let mut iterations = 0usize;

    // ---- Phase 1: minimize sum of artificials.
    if n_art > 0 {
        // reduced costs z for phase-1 objective (sum of artificial rows)
        let mut z = vec![0.0f64; width];
        for &ri in &art_rows {
            for c in 0..width {
                z[c] += t[ri * width + c];
            }
        }
        // artificial columns have cost 1 → their reduced cost is z - 1... we
        // track z_j - c_j: for artificials subtract 1.
        for a in (n + n_slack)..total {
            z[a] -= 1.0;
        }
        let status = optimize(&mut t, &mut basis, &mut z, m, total, width, &mut iterations, Some(n + n_slack));
        if status == LpStatus::Unbounded {
            // phase-1 objective is bounded below by 0; cannot happen
            unreachable!("phase 1 unbounded");
        }
        if z[total] > 1e-7 {
            // Σ artificials > 0 at the phase-1 optimum → infeasible
            // (z[total] carries c_B'B⁻¹b = the current objective value)
            return LpResult {
                status: LpStatus::Infeasible,
                x: vec![],
                objective: f64::INFINITY,
                iterations,
            };
        }
        // Drive any artificial still in the basis out (degenerate rows).
        for ri in 0..m {
            if basis[ri] >= n + n_slack {
                // find a non-artificial column with nonzero coef in this row
                let mut pivoted = false;
                for c in 0..(n + n_slack) {
                    if t[ri * width + c].abs() > 1e-7 {
                        pivot(&mut t, &mut basis, ri, c, m, width, &mut z);
                        pivoted = true;
                        break;
                    }
                }
                if !pivoted {
                    // redundant row; leave the artificial basic at 0
                }
            }
        }
    }

    // ---- Phase 2: minimize the real objective (artificial cols barred).
    let mut z = vec![0.0f64; width];
    // z_j = c_B' B^-1 A_j - c_j  computed from the current tableau:
    for c in 0..width {
        let mut acc = 0.0;
        for ri in 0..m {
            let cb = if basis[ri] < n { cost[basis[ri]] } else { 0.0 };
            acc += cb * t[ri * width + c];
        }
        z[c] = acc;
    }
    for (j, cj) in cost.iter().enumerate() {
        z[j] -= cj;
    }
    let status = optimize(&mut t, &mut basis, &mut z, m, total, width, &mut iterations, Some(n + n_slack));
    if status == LpStatus::Unbounded {
        return LpResult {
            status,
            x: vec![],
            objective: f64::NEG_INFINITY,
            iterations,
        };
    }

    // Extract structural solution (un-shift; fixed vars sit at lb).
    let mut x = vec![0.0f64; model.n_vars()];
    for (i, xi) in x.iter_mut().enumerate() {
        *xi = get_bounds(i).0;
    }
    for ri in 0..m {
        if basis[ri] < n {
            x[originals[basis[ri]]] += t[ri * width + total];
        }
    }
    for xi in x.iter_mut() {
        // clean numerical dust
        if xi.abs() < 1e-11 {
            *xi = 0.0;
        }
    }
    let objective = model.objective_value(&x);
    LpResult {
        status: LpStatus::Optimal,
        x,
        objective,
        iterations,
    }
}

fn effective_sense(s: Sense, rhs_negated: bool) -> Sense {
    if !rhs_negated {
        return s;
    }
    match s {
        Sense::Le => Sense::Ge,
        Sense::Ge => Sense::Le,
        Sense::Eq => Sense::Eq,
    }
}

/// Core pivot loop. `z` is the reduced-cost row (z_j - c_j; entering
/// columns have z_j - c_j > 0 for a minimization), `z[width-1]` holds
/// `-objective`. `barred_from` bars columns ≥ that index (artificials in
/// phase 2).
#[allow(clippy::too_many_arguments)]
fn optimize(
    t: &mut [f64],
    basis: &mut [usize],
    z: &mut [f64],
    m: usize,
    total: usize,
    width: usize,
    iterations: &mut usize,
    barred_from: Option<usize>,
) -> LpStatus {
    let bar = barred_from.unwrap_or(total);
    let mut degenerate_streak = 0usize;
    loop {
        *iterations += 1;
        if *iterations > 50_000 {
            // safety valve; with Bland's rule this should not trigger
            return LpStatus::Optimal;
        }
        // Pricing: Dantzig normally; Bland when cycling is suspected.
        let use_bland = degenerate_streak > 2 * (m + total);
        let mut enter: Option<usize> = None;
        if use_bland {
            for c in 0..bar {
                if z[c] > EPS {
                    enter = Some(c);
                    break;
                }
            }
        } else {
            let mut best = EPS;
            for c in 0..bar {
                if z[c] > best {
                    best = z[c];
                    enter = Some(c);
                }
            }
        }
        let Some(e) = enter else {
            return LpStatus::Optimal;
        };
        // Ratio test.
        let mut leave: Option<usize> = None;
        let mut best_ratio = f64::INFINITY;
        for ri in 0..m {
            let a = t[ri * width + e];
            if a > EPS {
                let ratio = t[ri * width + total] / a;
                if ratio < best_ratio - EPS
                    || (ratio < best_ratio + EPS
                        && leave.map_or(true, |l| basis[ri] < basis[l]))
                {
                    best_ratio = ratio;
                    leave = Some(ri);
                }
            }
        }
        let Some(l) = leave else {
            return LpStatus::Unbounded;
        };
        if best_ratio < EPS {
            degenerate_streak += 1;
        } else {
            degenerate_streak = 0;
        }
        pivot(t, basis, l, e, m, width, z);
    }
}

/// Pivot on (row `l`, col `e`), updating tableau, basis, and the z-row.
fn pivot(t: &mut [f64], basis: &mut [usize], l: usize, e: usize, m: usize, width: usize, z: &mut [f64]) {
    let piv = t[l * width + e];
    debug_assert!(piv.abs() > 1e-12);
    let inv = 1.0 / piv;
    for c in 0..width {
        t[l * width + c] *= inv;
    }
    t[l * width + e] = 1.0; // exact
    for ri in 0..m {
        if ri == l {
            continue;
        }
        let f = t[ri * width + e];
        if f.abs() > 1e-13 {
            for c in 0..width {
                t[ri * width + c] -= f * t[l * width + c];
            }
            t[ri * width + e] = 0.0;
        }
    }
    let f = z[e];
    if f.abs() > 1e-13 {
        for c in 0..width {
            z[c] -= f * t[l * width + c];
        }
        z[e] = 0.0;
    }
    basis[l] = e;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ilp::model::{Model, ObjSense, Sense, VarKind};

    fn var(m: &mut Model, name: &str, obj: f64) -> crate::ilp::VarId {
        m.add_var(name, 0.0, f64::INFINITY, VarKind::Continuous, obj)
    }

    #[test]
    fn maximize_classic_lp() {
        // max 3x + 5y s.t. x ≤ 4, 2y ≤ 12, 3x + 2y ≤ 18 → (2, 6) obj 36
        let mut m = Model::new(ObjSense::Maximize);
        let x = var(&mut m, "x", 3.0);
        let y = var(&mut m, "y", 5.0);
        m.add_constraint("c1", vec![(x, 1.0)], Sense::Le, 4.0);
        m.add_constraint("c2", vec![(y, 2.0)], Sense::Le, 12.0);
        m.add_constraint("c3", vec![(x, 3.0), (y, 2.0)], Sense::Le, 18.0);
        let r = solve_lp(&m, None);
        assert_eq!(r.status, LpStatus::Optimal);
        assert!((r.objective - 36.0).abs() < 1e-6, "{}", r.objective);
        assert!((r.x[0] - 2.0).abs() < 1e-6 && (r.x[1] - 6.0).abs() < 1e-6);
    }

    #[test]
    fn minimize_with_ge_constraints() {
        // min 2x + 3y s.t. x + y ≥ 10, x ≥ 2 → (8, 2)? obj: prefer x
        // (cheaper): x=10,y=0 gives 20; but x ≥ 2 only. optimum x=10 y=0 → 20
        let mut m = Model::new(ObjSense::Minimize);
        let x = var(&mut m, "x", 2.0);
        let y = var(&mut m, "y", 3.0);
        m.add_constraint("cover", vec![(x, 1.0), (y, 1.0)], Sense::Ge, 10.0);
        m.add_constraint("xmin", vec![(x, 1.0)], Sense::Ge, 2.0);
        let r = solve_lp(&m, None);
        assert_eq!(r.status, LpStatus::Optimal);
        assert!((r.objective - 20.0).abs() < 1e-6);
    }

    #[test]
    fn equality_constraints() {
        // min x + y s.t. x + 2y = 4, x - y = 1 → x=2, y=1, obj 3
        let mut m = Model::new(ObjSense::Minimize);
        let x = var(&mut m, "x", 1.0);
        let y = var(&mut m, "y", 1.0);
        m.add_constraint("e1", vec![(x, 1.0), (y, 2.0)], Sense::Eq, 4.0);
        m.add_constraint("e2", vec![(x, 1.0), (y, -1.0)], Sense::Eq, 1.0);
        let r = solve_lp(&m, None);
        assert_eq!(r.status, LpStatus::Optimal);
        assert!((r.x[0] - 2.0).abs() < 1e-6 && (r.x[1] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn detects_infeasible() {
        let mut m = Model::new(ObjSense::Minimize);
        let x = var(&mut m, "x", 1.0);
        m.add_constraint("lo", vec![(x, 1.0)], Sense::Ge, 5.0);
        m.add_constraint("hi", vec![(x, 1.0)], Sense::Le, 3.0);
        assert_eq!(solve_lp(&m, None).status, LpStatus::Infeasible);
    }

    #[test]
    fn detects_unbounded() {
        let mut m = Model::new(ObjSense::Maximize);
        let x = var(&mut m, "x", 1.0);
        m.add_constraint("lo", vec![(x, 1.0)], Sense::Ge, 0.0);
        assert_eq!(solve_lp(&m, None).status, LpStatus::Unbounded);
    }

    #[test]
    fn respects_upper_bounds() {
        let mut m = Model::new(ObjSense::Maximize);
        let x = m.add_var("x", 0.0, 2.5, VarKind::Continuous, 1.0);
        m.add_constraint("c", vec![(x, 1.0)], Sense::Le, 100.0);
        let r = solve_lp(&m, None);
        assert!((r.x[0] - 2.5).abs() < 1e-6);
    }

    #[test]
    fn respects_lower_bound_shift() {
        // min x with lb 3 → x = 3
        let mut m = Model::new(ObjSense::Minimize);
        let x = m.add_var("x", 3.0, 10.0, VarKind::Continuous, 1.0);
        m.add_constraint("c", vec![(x, 1.0)], Sense::Le, 100.0);
        let r = solve_lp(&m, None);
        assert!((r.x[0] - 3.0).abs() < 1e-6);
    }

    #[test]
    fn bound_overrides_fix_variable() {
        let mut m = Model::new(ObjSense::Maximize);
        let x = m.add_var("x", 0.0, 5.0, VarKind::Continuous, 1.0);
        let y = m.add_var("y", 0.0, 5.0, VarKind::Continuous, 1.0);
        m.add_constraint("c", vec![(x, 1.0), (y, 1.0)], Sense::Le, 6.0);
        let r = solve_lp(&m, Some(&[(2.0, 2.0), (0.0, 5.0)]));
        assert!((r.x[0] - 2.0).abs() < 1e-6);
        assert!((r.x[1] - 4.0).abs() < 1e-6);
    }

    #[test]
    fn degenerate_lp_terminates() {
        // classic degenerate corner: multiple constraints meet at origin
        let mut m = Model::new(ObjSense::Maximize);
        let x = var(&mut m, "x", 1.0);
        let y = var(&mut m, "y", 1.0);
        m.add_constraint("c1", vec![(x, 1.0), (y, 1.0)], Sense::Le, 1.0);
        m.add_constraint("c2", vec![(x, 1.0), (y, 2.0)], Sense::Le, 1.0);
        m.add_constraint("c3", vec![(x, 2.0), (y, 1.0)], Sense::Le, 1.0);
        let r = solve_lp(&m, None);
        assert_eq!(r.status, LpStatus::Optimal);
        assert!(r.objective <= 1.0 + 1e-6);
    }
}
