#![doc = include_str!("../../../docs/POWER.md")]

use crate::cluster::power_watts;
use crate::workload::AccelType;

/// One discrete DVFS operating point. Every accelerator instance is in
/// exactly one state; [`PowerState::Nominal`] is the pre-power behaviour
/// (and the default for fresh clusters and v1 snapshots).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord, Default)]
pub enum PowerState {
    /// Down-clocked: 0.70× frequency, 0.85× idle, 0.55× active power.
    Low,
    /// The unmodified catalog operating point.
    #[default]
    Nominal,
    /// Over-clocked: 1.15× frequency, 1.05× idle, 1.40× active power.
    Turbo,
}

impl PowerState {
    /// Every state, in `joules_by_state` index order.
    pub const ALL: [PowerState; 3] = [PowerState::Low, PowerState::Nominal, PowerState::Turbo];

    /// Stable wire/snapshot key.
    pub fn key(self) -> &'static str {
        match self {
            PowerState::Low => "low",
            PowerState::Nominal => "nominal",
            PowerState::Turbo => "turbo",
        }
    }

    pub fn from_key(s: &str) -> crate::Result<Self> {
        match s {
            "low" => Ok(PowerState::Low),
            "nominal" => Ok(PowerState::Nominal),
            "turbo" => Ok(PowerState::Turbo),
            other => anyhow::bail!("unknown power state {other:?} (want low|nominal|turbo)"),
        }
    }

    /// Index into `[low, nominal, turbo]` accumulators.
    pub fn index(self) -> usize {
        match self {
            PowerState::Low => 0,
            PowerState::Nominal => 1,
            PowerState::Turbo => 2,
        }
    }

    /// Frequency scalar: multiplies catalog throughput *and* solo
    /// capability, so relative load `u` is state-invariant.
    pub fn freq_scalar(self) -> f64 {
        match self {
            PowerState::Low => 0.70,
            PowerState::Nominal => 1.0,
            PowerState::Turbo => 1.15,
        }
    }

    /// `(idle multiplier, active-term multiplier)` on the type's
    /// `(idle, extra)` power parameters.
    fn power_mults(self) -> (f64, f64) {
        match self {
            PowerState::Low => (0.85, 0.55),
            PowerState::Nominal => (1.0, 1.0),
            PowerState::Turbo => (1.05, 1.40),
        }
    }
}

/// Instantaneous power (watts) of accelerator type `a` in DVFS state `s`
/// at relative load `u`. [`PowerState::Nominal`] routes through the
/// original [`crate::cluster::power_watts`] curve unmodified, so every
/// pre-power energy figure is bit-identical when DVFS never engages.
pub fn state_power_watts(a: AccelType, s: PowerState, u: f64) -> f64 {
    if s == PowerState::Nominal {
        return power_watts(a, u);
    }
    let (idle, extra) = a.power_params();
    let (idle_mult, extra_mult) = s.power_mults();
    let u = u.clamp(0.0, 1.0);
    idle_mult * idle + extra_mult * extra * u.powf(0.8)
}

/// Power-subsystem knobs threaded into the ILP objective. The default
/// (`dvfs: false`, `carbon_weight: 1.0`) reproduces the pre-power
/// objective bit-for-bit.
#[derive(Debug, Clone, Copy)]
pub struct PowerKnobs {
    /// Minimize each column's cost over DVFS states instead of assuming
    /// nominal.
    pub dvfs: bool,
    /// Multiplier on the energy term (the carbon/price signal's
    /// `weight(t)`; 1.0 = plain watts).
    pub carbon_weight: f64,
}

impl Default for PowerKnobs {
    fn default() -> Self {
        Self {
            dvfs: false,
            carbon_weight: 1.0,
        }
    }
}

/// Column cost of hosting aggregate throughput `total_t` (relative load
/// `u`) on type `a` in state `s`:
/// `carbon_weight·watts − throughput_bonus·freq_scalar·total_t`.
pub fn state_cost(
    a: AccelType,
    s: PowerState,
    u: f64,
    total_t: f64,
    throughput_bonus: f64,
    carbon_weight: f64,
) -> f64 {
    carbon_weight * state_power_watts(a, s, u) - throughput_bonus * s.freq_scalar() * total_t
}

/// The DVFS state minimizing [`state_cost`], preferring
/// [`PowerState::Nominal`] on ties (a strict improvement is required to
/// leave the default state).
pub fn best_state_cost(
    a: AccelType,
    u: f64,
    total_t: f64,
    throughput_bonus: f64,
    carbon_weight: f64,
) -> (PowerState, f64) {
    let mut best = PowerState::Nominal;
    let mut best_cost = state_cost(a, best, u, total_t, throughput_bonus, carbon_weight);
    for s in [PowerState::Low, PowerState::Turbo] {
        let c = state_cost(a, s, u, total_t, throughput_bonus, carbon_weight);
        if c < best_cost - 1e-12 {
            best = s;
            best_cost = c;
        }
    }
    (best, best_cost)
}

/// The effective per-column energy cost the ILP and the incremental
/// arrival path both use: with `dvfs` off, exactly the pre-power
/// expression (scaled by the carbon weight); with it on, the minimum
/// over states.
pub fn column_cost(
    a: AccelType,
    u: f64,
    total_t: f64,
    throughput_bonus: f64,
    knobs: PowerKnobs,
) -> f64 {
    if knobs.dvfs {
        best_state_cost(a, u, total_t, throughput_bonus, knobs.carbon_weight).1
    } else {
        state_cost(a, PowerState::Nominal, u, total_t, throughput_bonus, knobs.carbon_weight)
    }
}

/// Diurnal carbon/price signal (docs/POWER.md):
/// `intensity(t) = base · (1 + amplitude · sin(2π (t + phase_s) / 86400))`.
/// Lives in the *config*, never the trace event stream, so seeded
/// arrival streams stay byte-identical with and without it.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CarbonSignal {
    /// Mean grid intensity (gCO₂ per kWh); ≤ 0 disables the signal.
    pub base_gco2_per_kwh: f64,
    /// Diurnal swing, 0..1.
    pub amplitude: f64,
    /// Phase offset in seconds.
    pub phase_s: f64,
}

impl CarbonSignal {
    /// Grid intensity (gCO₂/kWh) at simulated time `t`.
    pub fn intensity(&self, t: f64) -> f64 {
        let day = 86_400.0;
        let swing = (2.0 * std::f64::consts::PI * (t + self.phase_s) / day).sin();
        self.base_gco2_per_kwh * (1.0 + self.amplitude.clamp(0.0, 1.0) * swing)
    }

    /// Objective reweight at time `t`: `intensity(t) / base` (1.0 when
    /// the signal is disabled).
    pub fn weight(&self, t: f64) -> f64 {
        if self.base_gco2_per_kwh <= 0.0 {
            1.0
        } else {
            self.intensity(t) / self.base_gco2_per_kwh
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nominal_state_matches_legacy_power_curve() {
        // bit-identical, not approximately equal: nominal must route
        // through the original curve so pre-power reports never move
        for a in crate::workload::ACCEL_TYPES {
            for i in 0..=10 {
                let u = i as f64 / 10.0;
                assert_eq!(state_power_watts(a, PowerState::Nominal, u), power_watts(a, u));
            }
        }
    }

    #[test]
    fn states_form_a_concave_throughput_power_curve() {
        for a in crate::workload::ACCEL_TYPES {
            let p = |s: PowerState| state_power_watts(a, s, 1.0);
            let (lo, nom, tur) = (p(PowerState::Low), p(PowerState::Nominal), p(PowerState::Turbo));
            assert!(lo < nom && nom < tur, "{a:?}: {lo} {nom} {tur}");
            // decreasing marginal throughput per watt = concavity
            let m1 = (1.0 - 0.70) / (nom - lo);
            let m2 = (1.15 - 1.0) / (tur - nom);
            assert!(m2 < m1, "{a:?}: marginal thr/W must decrease ({m1} vs {m2})");
        }
    }

    #[test]
    fn worked_example_v100_watts() {
        // the docs/POWER.md table
        assert!((state_power_watts(AccelType::V100, PowerState::Low, 1.0) - 148.0).abs() < 1e-9);
        assert!(
            (state_power_watts(AccelType::V100, PowerState::Nominal, 1.0) - 250.0).abs() < 1e-9
        );
        assert!(
            (state_power_watts(AccelType::V100, PowerState::Turbo, 1.0) - 337.75).abs() < 1e-9
        );
    }

    #[test]
    fn key_roundtrip_and_unknown_key() {
        for s in PowerState::ALL {
            assert_eq!(PowerState::from_key(s.key()).unwrap(), s);
        }
        assert_eq!(PowerState::ALL[PowerState::Turbo.index()], PowerState::Turbo);
        let err = PowerState::from_key("ludicrous").unwrap_err().to_string();
        assert!(err.contains("low|nominal|turbo"), "{err}");
        assert_eq!(PowerState::default(), PowerState::Nominal);
    }

    #[test]
    fn default_knobs_reproduce_legacy_column_cost() {
        for a in crate::workload::ACCEL_TYPES {
            for (u, t) in [(0.0, 0.0), (0.5, 0.8), (1.0, 1.6)] {
                let legacy = power_watts(a, u) - 300.0 * t;
                assert_eq!(column_cost(a, u, t, 300.0, PowerKnobs::default()), legacy);
            }
        }
    }

    #[test]
    fn dvfs_cost_never_exceeds_nominal_and_picks_sane_states() {
        let knobs = PowerKnobs {
            dvfs: true,
            carbon_weight: 1.0,
        };
        for a in crate::workload::ACCEL_TYPES {
            for (u, t) in [(0.0, 0.0), (0.3, 0.5), (1.0, 1.8)] {
                let dvfs = column_cost(a, u, t, 300.0, knobs);
                let nominal = column_cost(a, u, t, 300.0, PowerKnobs::default());
                assert!(dvfs <= nominal, "{a:?} u={u}: min over states must include nominal");
            }
        }
        // an idle accelerator always prefers low (pure idle-watt saving)
        let (s, _) = best_state_cost(AccelType::V100, 0.0, 0.0, 300.0, 1.0);
        assert_eq!(s, PowerState::Low);
        // a huge throughput bonus at full load buys turbo
        let (s, _) = best_state_cost(AccelType::V100, 1.0, 2.0, 5000.0, 1.0);
        assert_eq!(s, PowerState::Turbo);
        // zero bonus at full load: watts dominate, low wins
        let (s, _) = best_state_cost(AccelType::V100, 1.0, 2.0, 0.0, 1.0);
        assert_eq!(s, PowerState::Low);
    }

    #[test]
    fn carbon_signal_is_diurnal_and_disables_at_zero_base() {
        let sig = CarbonSignal {
            base_gco2_per_kwh: 420.0,
            amplitude: 0.35,
            phase_s: 0.0,
        };
        // peak a quarter-day in, trough at three quarters
        assert!((sig.intensity(21_600.0) - 420.0 * 1.35).abs() < 1e-6);
        assert!((sig.intensity(64_800.0) - 420.0 * 0.65).abs() < 1e-6);
        assert!((sig.intensity(0.0) - 420.0).abs() < 1e-9);
        assert!((sig.weight(21_600.0) - 1.35).abs() < 1e-9);
        // phase shifts the peak
        let shifted = CarbonSignal {
            phase_s: 21_600.0,
            ..sig
        };
        assert!((shifted.intensity(0.0) - 420.0 * 1.35).abs() < 1e-6);
        // disabled signal: weight pinned to 1
        let off = CarbonSignal {
            base_gco2_per_kwh: 0.0,
            ..sig
        };
        assert_eq!(off.weight(12_345.0), 1.0);
    }
}
