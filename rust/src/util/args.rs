//! Hand-rolled `--flag value` CLI parsing shared by the `gogh` and
//! `goghd` binaries (this build is fully offline — see Cargo.toml).
//!
//! A `--name` followed by a non-`--` token is a valued flag; a bare
//! `--name` is boolean. Positional tokens are ignored by this layer
//! (the binaries pull the subcommand off `argv` before parsing).

use std::collections::{HashMap, HashSet};

/// Parsed flags: valued (`--jobs 40`) and boolean (`--fresh`).
pub struct Args {
    flags: HashMap<String, String>,
    bools: HashSet<String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Self {
        let mut flags = HashMap::new();
        let mut bools = HashSet::new();
        let mut i = 0;
        while i < argv.len() {
            if let Some(name) = argv[i].strip_prefix("--") {
                if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    flags.insert(name.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    bools.insert(name.to_string());
                    i += 1;
                }
            } else {
                i += 1;
            }
        }
        Self { flags, bools }
    }

    /// The raw value of `--name value`, if present.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    /// The value of `--name value` parsed as `T` (None if absent or
    /// unparseable).
    pub fn get_parse<T: std::str::FromStr>(&self, name: &str) -> Option<T> {
        self.get(name).and_then(|v| v.parse().ok())
    }

    /// Whether `--name` appeared at all (valued or boolean).
    pub fn has(&self, name: &str) -> bool {
        self.bools.contains(name) || self.flags.contains_key(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn valued_boolean_and_missing_flags() {
        let argv: Vec<String> =
            ["--jobs", "40", "--fresh", "--preset", "serving"].map(String::from).to_vec();
        let a = Args::parse(&argv);
        assert_eq!(a.get("jobs"), Some("40"));
        assert_eq!(a.get_parse::<usize>("jobs"), Some(40));
        assert!(a.has("fresh"));
        assert!(a.has("preset"));
        assert_eq!(a.get("fresh"), None, "boolean flags carry no value");
        assert!(!a.has("seed"));
    }
}
