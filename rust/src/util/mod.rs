//! Self-contained utility substrates (this build is fully offline —
//! see Cargo.toml): a seeded PRNG, a JSON parser/serializer, CLI flag
//! parsing, and a tiny leveled logger.

pub mod args;
pub mod json;
pub mod logging;
pub mod rng;

pub use args::Args;
pub use json::Json;
pub use rng::Rng;
