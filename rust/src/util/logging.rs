//! Tiny leveled logger (replaces tracing in this offline build).
//! Level comes from `GOGH_LOG` (error|warn|info|debug; default warn);
//! output goes to stderr with a monotonic timestamp.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, PartialOrd)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX);
static START: OnceLock<Instant> = OnceLock::new();

fn level() -> u8 {
    let v = LEVEL.load(Ordering::Relaxed);
    if v != u8::MAX {
        return v;
    }
    let parsed = match std::env::var("GOGH_LOG").as_deref() {
        Ok("error") => 0,
        Ok("warn") | Err(_) => 1,
        Ok("info") => 2,
        Ok("debug") => 3,
        Ok(_) => 1,
    };
    LEVEL.store(parsed, Ordering::Relaxed);
    parsed
}

/// Force a level programmatically (CLI `-v` flags).
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn enabled(l: Level) -> bool {
    (l as u8) <= level()
}

pub fn log(l: Level, module: &str, msg: std::fmt::Arguments) {
    if !enabled(l) {
        return;
    }
    let t0 = START.get_or_init(Instant::now);
    eprintln!(
        "[{:>9.3}s {:5} {}] {}",
        t0.elapsed().as_secs_f64(),
        format!("{l:?}").to_uppercase(),
        module,
        msg
    );
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug, module_path!(), format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!(Level::Error < Level::Debug);
        set_level(Level::Info);
        assert!(enabled(Level::Warn));
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
        set_level(Level::Warn);
    }
}
