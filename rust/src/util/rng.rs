//! Deterministic PRNG: xoshiro256++ seeded via SplitMix64, plus the
//! distribution helpers the simulator needs (uniform ranges, Bernoulli,
//! Fisher–Yates shuffle, Box–Muller normal / lognormal, exponential).
//!
//! Replaces the `rand`/`rand_chacha` crates (offline build). The
//! generator passes the reference test vectors of xoshiro256++ and is
//! stable across platforms — every experiment in this repo is exactly
//! reproducible from its seed.

/// xoshiro256++ state.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Self { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform usize in [lo, hi). Panics if lo >= hi.
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "empty range");
        lo + (self.next_u64() % (hi - lo) as u64) as usize
    }

    /// Uniform u32 in [lo, hi].
    pub fn range_u32_inclusive(&mut self, lo: u32, hi: u32) -> u32 {
        assert!(lo <= hi);
        lo + (self.next_u64() % (hi - lo + 1) as u64) as u32
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.range_usize(0, i + 1);
            v.swap(i, j);
        }
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-15);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// exp(sigma · N(0,1)) — multiplicative lognormal noise.
    pub fn lognormal(&mut self, sigma: f64) -> f64 {
        if sigma <= 0.0 {
            1.0
        } else {
            (sigma * self.normal()).exp()
        }
    }

    /// Exponential with the given mean.
    pub fn exponential(&mut self, mean: f64) -> f64 {
        -mean * self.f64().max(1e-15).ln()
    }

    /// Pick a random element.
    pub fn choose<'a, T>(&mut self, v: &'a [T]) -> &'a T {
        &v[self.range_usize(0, v.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn xoshiro_reference_vector() {
        // seed_from_u64(0) must match the reference implementation of
        // splitmix64-seeded xoshiro256++ (first outputs).
        let mut r = Rng::seed_from_u64(0);
        let a = r.next_u64();
        let mut r2 = Rng::seed_from_u64(0);
        assert_eq!(a, r2.next_u64()); // deterministic
        let mut r3 = Rng::seed_from_u64(1);
        assert_ne!(a, r3.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::seed_from_u64(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn range_usize_bounds() {
        let mut r = Rng::seed_from_u64(4);
        let mut seen = [false; 5];
        for _ in 0..1000 {
            seen[r.range_usize(0, 5)] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from_u64(5);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from_u64(6);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
        assert_ne!(v, (0..100).collect::<Vec<u32>>()); // astronomically unlikely
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::seed_from_u64(7);
        let n = 50_000;
        let m = (0..n).map(|_| r.exponential(10.0)).sum::<f64>() / n as f64;
        assert!((m - 10.0).abs() < 0.3, "mean {m}");
    }
}
