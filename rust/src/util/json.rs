//! Minimal JSON parser + serializer (replaces serde_json in this
//! offline build). Handles the full JSON grammar the repo emits:
//! objects, arrays, strings with escapes, numbers, booleans, null.
//! Object key order is preserved (Vec-backed) so serialization is
//! deterministic.

use std::fmt;

use anyhow::{bail, Result};

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Array(Vec<Json>),
    Object(Vec<(String, Json)>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            b: text.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing characters at {}", p.pos());
        }
        Ok(v)
    }

    // -- accessors -------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Object(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|n| n as u64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Array(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_object(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Object(kv) => Some(kv),
            _ => None,
        }
    }

    /// Required-field helpers with contextual errors.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing field {key:?}"))
    }

    pub fn req_str(&self, key: &str) -> Result<&str> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("field {key:?} is not a string"))
    }

    pub fn req_f64(&self, key: &str) -> Result<f64> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("field {key:?} is not a number"))
    }

    pub fn req_usize(&self, key: &str) -> Result<usize> {
        Ok(self.req_f64(key)? as usize)
    }

    // -- builders --------------------------------------------------------

    pub fn obj(kv: Vec<(&str, Json)>) -> Json {
        Json::Object(kv.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
}

impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Self {
        Json::Num(n as f64)
    }
}
impl From<u32> for Json {
    fn from(n: u32) -> Self {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    /// Human position of the current byte: 1-based line and column
    /// (parse errors point here instead of at a raw byte offset).
    fn pos(&self) -> String {
        let upto = &self.b[..self.i.min(self.b.len())];
        let line = upto.iter().filter(|&&c| c == b'\n').count() + 1;
        let col = upto.iter().rev().take_while(|&&c| c != b'\n').count() + 1;
        format!("line {line} column {col}")
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            bail!("expected {:?} at {}", c as char, self.pos())
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at {}", other.map(|c| c as char), self.pos()),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("bad literal at {}", self.pos())
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut kv = vec![];
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Object(kv));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            let v = self.value()?;
            kv.push((k, v));
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Object(kv));
                }
                _ => bail!("expected ',' or '}}' at {}", self.pos()),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = vec![];
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Array(v));
        }
        loop {
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Array(v));
                }
                _ => bail!("expected ',' or ']' at {}", self.pos()),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string at {}", self.pos()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            // bounds-checked: a truncated \uXXXX (e.g. a
                            // cut-off network line) is an error, not a panic
                            if self.i + 5 > self.b.len() {
                                bail!("truncated \\u escape at {}", self.pos());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => bail!("bad escape at {}", self.pos()),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.i;
                    let text = std::str::from_utf8(&self.b[start..])?;
                    let ch = text.chars().next().unwrap();
                    s.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map_or(false, |c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(text.parse::<f64>()?))
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Array(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Object(kv) => {
                write!(f, "{{")?;
                for (i, (k, v)) in kv.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{v}", Json::Str(k.clone()))?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        let text = r#"{"a": 1, "b": [1.5, true, null, "x\ny"], "c": {"d": -2e3}}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.req_f64("a").unwrap(), 1.0);
        assert_eq!(v.get("b").unwrap().as_array().unwrap().len(), 4);
        assert_eq!(v.get("c").unwrap().req_f64("d").unwrap(), -2000.0);
        // serialize → parse → identical
        let text2 = v.to_string();
        assert_eq!(Json::parse(&text2).unwrap(), v);
    }

    #[test]
    fn parses_real_manifest_shape() {
        let text = r#"{"version": 2, "models": {"p1_ff": {"state": [{"name": "w0", "shape": [32, 96]}]}}}"#;
        let v = Json::parse(text).unwrap();
        let m = v.get("models").unwrap().get("p1_ff").unwrap();
        let s = &m.get("state").unwrap().as_array().unwrap()[0];
        assert_eq!(s.req_str("name").unwrap(), "w0");
        assert_eq!(
            s.get("shape").unwrap().as_array().unwrap()[1].as_usize(),
            Some(96)
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("{} x").is_err());
    }

    #[test]
    fn parse_errors_carry_line_and_column() {
        let err = Json::parse("{\n  \"a\": ,\n}").unwrap_err();
        assert!(err.to_string().contains("line 2"), "{err}");
        let err = Json::parse("[1, 2").unwrap_err();
        assert!(err.to_string().contains("line 1"), "{err}");
        // truncated \u escape is a clean error, not a slice panic
        assert!(Json::parse("\"\\u12").is_err());
    }

    #[test]
    fn string_escapes() {
        let v = Json::parse(r#""aA\t""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "aA\t");
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo → 世界\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo → 世界");
    }
}
