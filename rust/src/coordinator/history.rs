//! Historical bootstrap: the paper's P1 "relies on historical data from
//! previously executed jobs in the cluster". This module synthesizes
//! that history — measured records of past jobs — into the Catalog, and
//! builds bootstrap training samples for P1/P2 *from the Catalog alone*
//! (the estimators never see the oracle).

use crate::util::Rng;

use crate::catalog::{Catalog, EstimateKey, SimilarityIndex};
use crate::runtime::dataset::Sample;
use crate::workload::encoding::{p1_row, p2_row};
use crate::workload::trace::table2_universe;
use crate::workload::{Combo, JobId, JobSpec, ThroughputOracle, ACCEL_TYPES};

/// Ids of historical jobs start high to never collide with trace jobs.
pub const HISTORY_ID_BASE: u32 = 1_000_000;

/// Populate `catalog` with measured records of `n_jobs` past jobs:
/// solo runs on every accelerator type plus pairwise co-locations among
/// a sampled subset — what a production cluster's monitoring would have
/// accumulated. Measurement noise matches the monitor's.
pub fn seed_catalog(
    catalog: &mut Catalog,
    oracle: &ThroughputOracle,
    n_jobs: usize,
    noise_sigma: f64,
    seed: u64,
) -> Vec<JobSpec> {
    let mut rng = Rng::seed_from_u64(seed ^ 0x415);
    let universe = table2_universe();
    let noise = |rng: &mut Rng| -> f64 { rng.lognormal(noise_sigma) };
    let mut jobs = vec![];
    for i in 0..n_jobs {
        let (f, b) = universe[rng.range_usize(0, universe.len())];
        let job = JobSpec {
            id: JobId(HISTORY_ID_BASE + i as u32),
            family: f,
            batch_size: b,
            replication: 1,
            min_throughput: 0.0,
            distributability: 1,
            work: 0.0,
            priority: Default::default(),
            elastic: false,
            inference: None,
        };
        catalog.register_job(job.id, job.psi());
        for &a in ACCEL_TYPES.iter() {
            let t = oracle.solo(&job, a) * noise(&mut rng);
            catalog.record_measurement(
                EstimateKey {
                    accel: a,
                    job: job.id,
                    combo: Combo::Solo(job.id),
                },
                t,
            );
        }
        jobs.push(job);
    }
    // pairwise history: each job gets co-location records with ~3 peers
    for i in 0..jobs.len() {
        for _ in 0..3 {
            let k = rng.range_usize(0, jobs.len());
            if k == i {
                continue;
            }
            let (j1, j2) = (&jobs[i], &jobs[k]);
            let combo = Combo::pair(j1.id, j2.id);
            for &a in ACCEL_TYPES.iter() {
                let (t1, t2) = oracle.pair(j1, j2, a);
                catalog.record_measurement(
                    EstimateKey {
                        accel: a,
                        job: j1.id,
                        combo,
                    },
                    t1 * noise(&mut rng),
                );
                catalog.record_measurement(
                    EstimateKey {
                        accel: a,
                        job: j2.id,
                        combo,
                    },
                    t2 * noise(&mut rng),
                );
            }
        }
    }
    jobs
}

/// Build P1 bootstrap samples purely from the Catalog's measured
/// records: pretend job `j1` is new, use its most similar peer `j2` as
/// the reference, and its *actual measured* throughputs as targets.
pub fn p1_samples_from_catalog(catalog: &Catalog, n: usize, seed: u64) -> Vec<Sample> {
    let mut rng = Rng::seed_from_u64(seed ^ 0x91);
    let jobs: Vec<JobId> = {
        let mut v: Vec<JobId> = catalog.known_jobs().copied().collect();
        v.sort();
        v
    };
    if jobs.len() < 2 {
        return vec![];
    }
    let mut out = vec![];
    let mut guard = 0;
    while out.len() < n && guard < n * 20 {
        guard += 1;
        let j1 = jobs[rng.range_usize(0, jobs.len())];
        let psi1 = *catalog.psi(j1).unwrap();
        let idx = SimilarityIndex::new(catalog);
        let Some(j2) = idx.most_similar(&psi1, &[j1], true) else {
            continue;
        };
        let psi2 = *catalog.psi(j2).unwrap();
        // choose a measured record of j1 as the target
        let recs1 = catalog.measured_records_of(j1);
        if recs1.is_empty() {
            continue;
        }
        let (k1, y1) = recs1[rng.range_usize(0, recs1.len())];
        let a = k1.accel;
        match k1.combo.other(j1) {
            None => {
                // solo target: inputs are j2's solo record on a
                let k2 = EstimateKey {
                    accel: a,
                    job: j2,
                    combo: Combo::Solo(j2),
                };
                let Some(t2) = catalog.value(&k2) else { continue };
                let row = p1_row(
                    &psi2,
                    &crate::workload::encoding::PSI_EMPTY,
                    a,
                    t2 as f32,
                    0.0,
                    &psi1,
                );
                out.push(Sample {
                    x: row.to_vec(),
                    y: [y1 as f32, 0.0],
                });
            }
            Some(j3) => {
                // pair target: need j2's measured pair with some peer and
                // j3's measured value in (j1, j3)
                let Some(psi3) = catalog.psi(j3).copied() else { continue };
                let y3 = catalog
                    .value(&EstimateKey {
                        accel: a,
                        job: j3,
                        combo: k1.combo,
                    })
                    .unwrap_or(0.0);
                // j2's historical co-location on a (any peer ≈ j3's slot)
                let rec2 = catalog
                    .measured_records_of(j2)
                    .into_iter()
                    .find(|(k, _)| k.accel == a && k.combo.len() == 2);
                let Some((k2, t2)) = rec2 else { continue };
                let peer = k2.combo.other(j2).unwrap();
                let t_peer = catalog
                    .value(&EstimateKey {
                        accel: a,
                        job: peer,
                        combo: k2.combo,
                    })
                    .unwrap_or(0.0);
                let row = p1_row(&psi2, &psi3, a, t2 as f32, t_peer as f32, &psi1);
                out.push(Sample {
                    x: row.to_vec(),
                    y: [y1 as f32, y3 as f32],
                });
            }
        }
    }
    out
}

/// Build P2 bootstrap samples from the Catalog: a job measured on two
/// accel types yields a transfer tuple (observe a1 → predict a2), with
/// synthetic stale estimates perturbing the measured values (the
/// estimate-error distribution a deployed P1 produces).
pub fn p2_samples_from_catalog(
    catalog: &Catalog,
    n: usize,
    est_sigma: f64,
    seed: u64,
) -> Vec<Sample> {
    let mut rng = Rng::seed_from_u64(seed ^ 0x92);
    let jobs: Vec<JobId> = {
        let mut v: Vec<JobId> = catalog.known_jobs().copied().collect();
        v.sort();
        v
    };
    let noise = |rng: &mut Rng, s: f64| -> f64 { rng.lognormal(s) };
    let mut out = vec![];
    let mut guard = 0;
    while out.len() < n && guard < n * 20 {
        guard += 1;
        let j1 = jobs[rng.range_usize(0, jobs.len())];
        let recs = catalog.measured_records_of(j1);
        if recs.is_empty() {
            continue;
        }
        let (k1, t_a1_j1) = recs[rng.range_usize(0, recs.len())];
        let combo = k1.combo;
        let a1 = k1.accel;
        // find the same combo measured on a different accel
        let others: Vec<_> = recs
            .iter()
            .filter(|(k, _)| k.combo == combo && k.accel != a1)
            .collect();
        if others.is_empty() {
            continue;
        }
        let (k2, t_a2_j1) = others[rng.range_usize(0, others.len())];
        let a2 = k2.accel;
        let j2 = combo.other(j1);
        let t_of = |a, j| {
            catalog
                .value(&EstimateKey {
                    accel: a,
                    job: j,
                    combo,
                })
                .unwrap_or(0.0)
        };
        let (t_a1_j2, t_a2_j2) = match j2 {
            Some(j) => (t_of(a1, j), t_of(a2, j)),
            None => (0.0, 0.0),
        };
        let psi1 = *catalog.psi(j1).unwrap();
        let psi2 = j2
            .and_then(|j| catalog.psi(j).copied())
            .unwrap_or(crate::workload::encoding::PSI_EMPTY);
        // correlated stale-estimate synthesis (see dataset.rs)
        let e1 = noise(&mut rng, est_sigma);
        let e2 = noise(&mut rng, est_sigma);
        let r = |rng: &mut Rng| noise(rng, est_sigma * 0.3);
        let row = p2_row(
            &psi1,
            &psi2,
            a1,
            a2,
            (t_a1_j1 * e1 * r(&mut rng)) as f32,
            (t_a1_j2 * e2 * r(&mut rng)) as f32,
            t_a1_j1 as f32,
            t_a1_j2 as f32,
            (t_a2_j1 * e1 * r(&mut rng)) as f32,
            (t_a2_j2 * e2 * r(&mut rng)) as f32,
        );
        out.push(Sample {
            x: row.to_vec(),
            y: [*t_a2_j1 as f32, t_a2_j2 as f32],
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seeding_registers_jobs_and_measurements() {
        let oracle = ThroughputOracle::new(8);
        let mut c = Catalog::new();
        let jobs = seed_catalog(&mut c, &oracle, 10, 0.02, 1);
        assert_eq!(jobs.len(), 10);
        assert_eq!(c.known_jobs().count(), 10);
        // every job has ≥ 6 solo measurements
        for j in &jobs {
            assert!(c.measured_records_of(j.id).len() >= 6);
        }
        assert!(c.n_measured() > 60);
    }

    #[test]
    fn p1_bootstrap_samples_are_wellformed() {
        let oracle = ThroughputOracle::new(8);
        let mut c = Catalog::new();
        seed_catalog(&mut c, &oracle, 12, 0.02, 1);
        let s = p1_samples_from_catalog(&c, 100, 3);
        assert!(s.len() >= 80, "only {} samples", s.len());
        for smp in &s {
            assert_eq!(smp.x.len(), crate::workload::encoding::P1_DIM);
            assert!(smp.y[0] >= 0.0);
        }
        // mix of solo and pair targets
        assert!(s.iter().any(|s| s.y[1] == 0.0));
        assert!(s.iter().any(|s| s.y[1] > 0.0));
    }

    #[test]
    fn p2_bootstrap_samples_are_wellformed() {
        let oracle = ThroughputOracle::new(8);
        let mut c = Catalog::new();
        seed_catalog(&mut c, &oracle, 12, 0.02, 1);
        let s = p2_samples_from_catalog(&c, 100, 0.15, 3);
        assert!(s.len() >= 80);
        for smp in &s {
            assert_eq!(smp.x.len(), crate::workload::encoding::P2_PADDED);
        }
    }
}
