//! Estimation refinement (paper §2.5): after monitoring reports the
//! actual throughput of job j1 (with co-runner j2) on accelerator a1,
//! P2 transfers that observation into improved estimates on every other
//! accelerator type a2 (Eq. 3), which accumulate in the Catalog's
//! refinement sets 𝒯 (Eq. 4).

use crate::catalog::{Catalog, EstimateKey};
use crate::cluster::Measurement;
use crate::workload::encoding::{p2_row, PSI_DIM};
use crate::workload::{AccelType, Combo, JobId, ACCEL_TYPES};

/// Default pair-interference prior used when a pair estimate is missing
/// (a solo estimate exists but the combination was never seen).
pub const PAIR_PRIOR: f64 = 0.7;

/// Resolve the Catalog's best current value for (a, j, c), falling back
/// to `solo × PAIR_PRIOR` for unseen pairs and a generation-speed prior
/// for totally unknown jobs.
pub fn catalog_value(catalog: &Catalog, a: AccelType, j: JobId, c: &Combo) -> f64 {
    let key = EstimateKey {
        accel: a,
        job: j,
        combo: *c,
    };
    if let Some(v) = catalog.value(&key) {
        return v;
    }
    if c.len() == 2 {
        let solo = EstimateKey {
            accel: a,
            job: j,
            combo: Combo::Solo(j),
        };
        if let Some(v) = catalog.value(&solo) {
            return v * PAIR_PRIOR;
        }
    }
    // cold prior: scaled generation speed (≈ mid-range job)
    0.4 * a.base_speed() / AccelType::V100.base_speed()
}

/// A P2 query: refine (j1, j2?) in combo `c`, observed on `a1`, toward
/// target accel `a2`.
pub struct RefineQuery {
    pub x: Vec<f32>,
    pub a2: AccelType,
    pub j1: JobId,
    pub j2: Option<JobId>,
    pub combo: Combo,
}

/// Build the P2 query rows for one measurement round. `measured`
/// resolves this round's measured value for (j, combo) on `a1` (the
/// co-runner's measurement comes from the same round).
pub fn build_refine_queries(
    catalog: &Catalog,
    measurements: &[Measurement],
) -> Vec<RefineQuery> {
    let mut queries = vec![];
    for m in measurements {
        let a1 = m.accel.accel;
        let j1 = m.job;
        let combo = m.combo;
        let j2 = combo.other(j1);
        let psi_j1 = match catalog.psi(j1) {
            Some(p) => *p,
            None => continue,
        };
        let psi_j2: [f32; PSI_DIM] = j2
            .and_then(|j| catalog.psi(j).copied())
            .unwrap_or(crate::workload::encoding::PSI_EMPTY);
        // this-round measurement of the co-runner (same combo + accel)
        let meas_j2 = j2
            .and_then(|j| {
                measurements
                    .iter()
                    .find(|o| o.job == j && o.combo == combo && o.accel == m.accel)
            })
            .map(|o| o.throughput)
            .unwrap_or(0.0);
        // estimates *before* this measurement (refinement-set averages)
        let est_key = |a: AccelType, j: JobId| EstimateKey {
            accel: a,
            job: j,
            combo,
        };
        let est_a1_j1 = catalog
            .record(&est_key(a1, j1))
            .and_then(|r| r.estimate_only())
            .unwrap_or(m.throughput);
        let est_a1_j2 = j2
            .map(|j| {
                catalog
                    .record(&est_key(a1, j))
                    .and_then(|r| r.estimate_only())
                    .unwrap_or(meas_j2)
            })
            .unwrap_or(0.0);
        for &a2 in ACCEL_TYPES.iter() {
            if a2 == a1 {
                continue;
            }
            let est_a2_j1 = catalog_value(catalog, a2, j1, &combo);
            let est_a2_j2 = j2.map(|j| catalog_value(catalog, a2, j, &combo)).unwrap_or(0.0);
            let x = p2_row(
                &psi_j1,
                &psi_j2,
                a1,
                a2,
                est_a1_j1 as f32,
                est_a1_j2 as f32,
                m.throughput as f32,
                meas_j2 as f32,
                est_a2_j1 as f32,
                est_a2_j2 as f32,
            );
            queries.push(RefineQuery {
                x: x.to_vec(),
                a2,
                j1,
                j2,
                combo,
            });
        }
    }
    queries
}

/// Apply P2 outputs: push each prediction into the refinement set 𝒯 of
/// the (a2, job, combo) keys (Eq. 4 — the Catalog averages them).
pub fn apply_refinements(
    catalog: &mut Catalog,
    queries: &[RefineQuery],
    predictions: &[[f32; 2]],
    round: u32,
) {
    for (q, pred) in queries.iter().zip(predictions) {
        let k1 = EstimateKey {
            accel: q.a2,
            job: q.j1,
            combo: q.combo,
        };
        catalog.push_refinement(k1, (pred[0] as f64).clamp(0.0, 1.5), round);
        if let Some(j2) = q.j2 {
            let k2 = EstimateKey {
                accel: q.a2,
                job: j2,
                combo: q.combo,
            };
            catalog.push_refinement(k2, (pred[1] as f64).clamp(0.0, 1.5), round);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::AccelId;
    use crate::workload::encoding::psi;
    use crate::workload::ModelFamily;

    fn setup() -> (Catalog, Vec<Measurement>) {
        let mut c = Catalog::new();
        c.register_job(JobId(1), psi(ModelFamily::ResNet18, 32, 1));
        c.register_job(JobId(2), psi(ModelFamily::LanguageModel, 10, 1));
        let combo = Combo::pair(JobId(1), JobId(2));
        // prior estimates on two types
        for a in [AccelType::K80, AccelType::V100] {
            for j in [JobId(1), JobId(2)] {
                c.write_initial(
                    EstimateKey {
                        accel: a,
                        job: j,
                        combo,
                    },
                    0.3,
                );
            }
        }
        let aid = AccelId {
            server: 0,
            accel: AccelType::K80,
        };
        let ms = vec![
            Measurement {
                job: JobId(1),
                combo,
                accel: aid,
                throughput: 0.25,
                at: 1.0,
            },
            Measurement {
                job: JobId(2),
                combo,
                accel: aid,
                throughput: 0.18,
                at: 1.0,
            },
        ];
        (c, ms)
    }

    #[test]
    fn queries_cover_all_other_accels() {
        let (c, ms) = setup();
        let qs = build_refine_queries(&c, &ms);
        // 2 measurements × 5 other accel types
        assert_eq!(qs.len(), 10);
        for q in &qs {
            assert_eq!(q.x.len(), crate::workload::encoding::P2_PADDED);
            assert_ne!(q.a2, AccelType::K80);
        }
    }

    #[test]
    fn refinements_update_the_catalog_average() {
        let (mut c, ms) = setup();
        let qs = build_refine_queries(&c, &ms);
        let preds: Vec<[f32; 2]> = qs.iter().map(|_| [0.5, 0.5]).collect();
        apply_refinements(&mut c, &qs, &preds, 1);
        let k = EstimateKey {
            accel: AccelType::V100,
            job: JobId(1),
            combo: Combo::pair(JobId(1), JobId(2)),
        };
        // initial 0.3 + two refinements (one per measurement of the pair)
        let r = c.record(&k).unwrap();
        assert!(r.refinements() >= 2);
        let v = c.value(&k).unwrap();
        assert!(v > 0.3 && v <= 0.5, "{v}");
    }

    #[test]
    fn fallback_pair_prior() {
        let mut c = Catalog::new();
        c.write_initial(
            EstimateKey {
                accel: AccelType::K80,
                job: JobId(1),
                combo: Combo::Solo(JobId(1)),
            },
            0.6,
        );
        let v = catalog_value(&c, AccelType::K80, JobId(1), &Combo::pair(JobId(1), JobId(2)));
        assert!((v - 0.6 * PAIR_PRIOR).abs() < 1e-12);
        // unknown job → generation prior
        let v2 = catalog_value(&c, AccelType::V100, JobId(9), &Combo::Solo(JobId(9)));
        assert!(v2 > 0.0 && v2 <= 1.0);
    }
}
