//! Estimation refinement (paper §2.5): after monitoring reports the
//! actual throughput of job j1 (with co-runner j2) on accelerator a1,
//! P2 transfers that observation into improved estimates on every other
//! accelerator type a2 (Eq. 3), which accumulate in the Catalog's
//! refinement sets 𝒯 (Eq. 4).

use crate::catalog::{Catalog, EstimateKey};
use crate::cluster::Measurement;
use crate::runtime::Backend;
use crate::workload::encoding::{p2_row, PSI_DIM};
use crate::workload::{AccelType, Combo, JobId, ACCEL_TYPES};
use crate::Result;

/// Default pair-interference prior used when a pair estimate is missing
/// (a solo estimate exists but the combination was never seen).
pub const PAIR_PRIOR: f64 = 0.7;

/// Resolve the Catalog's best current value for (a, j, c), falling back
/// to the [`prior_value`] chain when the key was never seen.
pub fn catalog_value(catalog: &Catalog, a: AccelType, j: JobId, c: &Combo) -> f64 {
    let key = EstimateKey {
        accel: a,
        job: j,
        combo: *c,
    };
    if let Some(v) = catalog.value(&key) {
        return v;
    }
    prior_value(catalog, a, j, c)
}

/// Prior for (a, j, c) that never reads the (a, j, c) record itself:
/// `solo × PAIR_PRIOR` for unseen pairs, else the generation-speed cold
/// prior — which is *also* discounted by `PAIR_PRIOR` for pairs.
/// Co-location interference is never free, least of all when nothing
/// about the pairing is measured; without the discount the optimizer
/// saw unknown jobs as interference-free exactly where it knew least.
pub fn prior_value(catalog: &Catalog, a: AccelType, j: JobId, c: &Combo) -> f64 {
    if c.len() == 2 {
        let solo = EstimateKey {
            accel: a,
            job: j,
            combo: Combo::Solo(j),
        };
        if let Some(v) = catalog.value(&solo) {
            return v * PAIR_PRIOR;
        }
    }
    // cold prior: scaled generation speed (≈ mid-range job)
    let cold = 0.4 * a.base_speed() / AccelType::V100.base_speed();
    if c.len() == 2 {
        cold * PAIR_PRIOR
    } else {
        cold
    }
}

/// The Catalog's estimate for (a, j, c) *excluding* any measurement of
/// that key: the refinement-set average when one exists, else the
/// [`prior_value`] chain. This is the "estimate before measurement"
/// feature P2's Eq. 3 rows require — falling back to the measured value
/// itself would leak the current round's label into the query features.
pub fn estimate_before_measurement(catalog: &Catalog, a: AccelType, j: JobId, c: &Combo) -> f64 {
    let key = EstimateKey {
        accel: a,
        job: j,
        combo: *c,
    };
    if let Some(e) = catalog.record(&key).and_then(|r| r.estimate_only()) {
        return e;
    }
    prior_value(catalog, a, j, c)
}

/// A P2 query: refine (j1, j2?) in combo `c`, observed on `a1`, toward
/// target accel `a2`.
pub struct RefineQuery {
    pub x: Vec<f32>,
    pub a2: AccelType,
    pub j1: JobId,
    pub j2: Option<JobId>,
    pub combo: Combo,
}

/// Build the P2 query rows for one measurement round. `measured`
/// resolves this round's measured value for (j, combo) on `a1` (the
/// co-runner's measurement comes from the same round).
pub fn build_refine_queries(
    catalog: &Catalog,
    measurements: &[Measurement],
) -> Vec<RefineQuery> {
    let mut queries = vec![];
    for m in measurements {
        let a1 = m.accel.accel;
        let j1 = m.job;
        let combo = m.combo;
        let j2 = combo.other(j1);
        let psi_j1 = match catalog.psi(j1) {
            Some(p) => *p,
            None => continue,
        };
        let psi_j2: [f32; PSI_DIM] = j2
            .and_then(|j| catalog.psi(j).copied())
            .unwrap_or(crate::workload::encoding::PSI_EMPTY);
        // this-round measurement of the co-runner (same combo + accel).
        // A co-runner whose measurement is missing from the round is
        // encoded as its prior, NOT 0.0 — zero is indistinguishable from
        // "no co-runner" (the Ψ_EMPTY slot) and would teach P2 that the
        // pair behaves like a solo.
        let meas_j2 = match j2 {
            None => 0.0,
            Some(j) => measurements
                .iter()
                .find(|o| o.job == j && o.combo == combo && o.accel == m.accel)
                .map(|o| o.throughput)
                .unwrap_or_else(|| estimate_before_measurement(catalog, a1, j, &combo)),
        };
        // estimates *before* this measurement: refinement-set averages,
        // with the prior chain as fallback (never this round's labels)
        let est_a1_j1 = estimate_before_measurement(catalog, a1, j1, &combo);
        let est_a1_j2 = j2
            .map(|j| estimate_before_measurement(catalog, a1, j, &combo))
            .unwrap_or(0.0);
        for &a2 in ACCEL_TYPES.iter() {
            if a2 == a1 {
                continue;
            }
            // Eq. 3's T̃_{a2,·} is the refinement-set average, so the
            // target-side slots also exclude measurements: a distributed
            // job measured on BOTH a1 and a2 this round would otherwise
            // leak its fresh a2 label into the query features.
            let est_a2_j1 = estimate_before_measurement(catalog, a2, j1, &combo);
            let est_a2_j2 = j2
                .map(|j| estimate_before_measurement(catalog, a2, j, &combo))
                .unwrap_or(0.0);
            let x = p2_row(
                &psi_j1,
                &psi_j2,
                a1,
                a2,
                est_a1_j1 as f32,
                est_a1_j2 as f32,
                m.throughput as f32,
                meas_j2 as f32,
                est_a2_j1 as f32,
                est_a2_j2 as f32,
            );
            queries.push(RefineQuery {
                x: x.to_vec(),
                a2,
                j1,
                j2,
                combo,
            });
        }
    }
    queries
}

/// Apply P2 outputs: push each prediction into the refinement set 𝒯 of
/// the (a2, job, combo) keys (Eq. 4 — the Catalog averages them).
pub fn apply_refinements(
    catalog: &mut Catalog,
    queries: &[RefineQuery],
    predictions: &[[f32; 2]],
    round: u32,
) {
    for (q, pred) in queries.iter().zip(predictions) {
        let k1 = EstimateKey {
            accel: q.a2,
            job: q.j1,
            combo: q.combo,
        };
        catalog.push_refinement(k1, (pred[0] as f64).clamp(0.0, 1.5), round);
        if let Some(j2) = q.j2 {
            let k2 = EstimateKey {
                accel: q.a2,
                job: j2,
                combo: q.combo,
            };
            catalog.push_refinement(k2, (pred[1] as f64).clamp(0.0, 1.5), round);
        }
    }
}

/// One full P2 refinement round over any [`Backend`] (PJRT or native):
/// build the Eq. 3 query rows for this round's measurements, run the
/// refinement network, and push its predictions into the Catalog's
/// refinement sets 𝒯 (Eq. 4). Returns the number of queries applied
/// (0 when the round produced nothing refinable).
pub fn refine_round(
    catalog: &mut Catalog,
    p2: &mut dyn Backend,
    measurements: &[Measurement],
    round: u32,
) -> Result<usize> {
    let queries = build_refine_queries(catalog, measurements);
    if queries.is_empty() {
        return Ok(0);
    }
    let rows: Vec<Vec<f32>> = queries.iter().map(|q| q.x.clone()).collect();
    let preds = p2.predict(&rows)?;
    apply_refinements(catalog, &queries, &preds, round);
    Ok(queries.len())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::AccelId;
    use crate::workload::encoding::psi;
    use crate::workload::ModelFamily;

    fn setup() -> (Catalog, Vec<Measurement>) {
        let mut c = Catalog::new();
        c.register_job(JobId(1), psi(ModelFamily::ResNet18, 32, 1));
        c.register_job(JobId(2), psi(ModelFamily::LanguageModel, 10, 1));
        let combo = Combo::pair(JobId(1), JobId(2));
        // prior estimates on two types
        for a in [AccelType::K80, AccelType::V100] {
            for j in [JobId(1), JobId(2)] {
                c.write_initial(
                    EstimateKey {
                        accel: a,
                        job: j,
                        combo,
                    },
                    0.3,
                );
            }
        }
        let aid = AccelId {
            server: 0,
            accel: AccelType::K80,
        };
        let ms = vec![
            Measurement {
                job: JobId(1),
                combo,
                accel: aid,
                throughput: 0.25,
                at: 1.0,
            },
            Measurement {
                job: JobId(2),
                combo,
                accel: aid,
                throughput: 0.18,
                at: 1.0,
            },
        ];
        (c, ms)
    }

    #[test]
    fn queries_cover_all_other_accels() {
        let (c, ms) = setup();
        let qs = build_refine_queries(&c, &ms);
        // 2 measurements × 5 other accel types
        assert_eq!(qs.len(), 10);
        for q in &qs {
            assert_eq!(q.x.len(), crate::workload::encoding::P2_PADDED);
            assert_ne!(q.a2, AccelType::K80);
        }
    }

    #[test]
    fn refinements_update_the_catalog_average() {
        let (mut c, ms) = setup();
        let qs = build_refine_queries(&c, &ms);
        let preds: Vec<[f32; 2]> = qs.iter().map(|_| [0.5, 0.5]).collect();
        apply_refinements(&mut c, &qs, &preds, 1);
        let k = EstimateKey {
            accel: AccelType::V100,
            job: JobId(1),
            combo: Combo::pair(JobId(1), JobId(2)),
        };
        // initial 0.3 + two refinements (one per measurement of the pair)
        let r = c.record(&k).unwrap();
        assert!(r.refinements() >= 2);
        let v = c.value(&k).unwrap();
        assert!(v > 0.3 && v <= 0.5, "{v}");
    }

    #[test]
    fn refine_round_runs_over_any_backend() {
        // the backend-agnostic round: native P2 predictions land in the
        // refinement sets of every unobserved accel type
        let (mut c, ms) = setup();
        let mut p2 = crate::runtime::NativeBackend::p2(3);
        let n = refine_round(&mut c, &mut p2, &ms, 1).unwrap();
        assert_eq!(n, 10); // 2 measurements × 5 other accel types
        let k = EstimateKey {
            accel: AccelType::V100,
            job: JobId(1),
            combo: Combo::pair(JobId(1), JobId(2)),
        };
        assert!(c.record(&k).unwrap().refinements() >= 2);
        // a measurement-free round refines nothing
        assert_eq!(refine_round(&mut c, &mut p2, &[], 2).unwrap(), 0);
    }

    #[test]
    fn cold_pair_prior_is_discounted() {
        // an unknown job in a pair must NOT get the interference-free
        // solo-scale prior: the cold prior is discounted by PAIR_PRIOR.
        let c = Catalog::new();
        let solo = catalog_value(&c, AccelType::V100, JobId(7), &Combo::Solo(JobId(7)));
        let pair = catalog_value(&c, AccelType::V100, JobId(7), &Combo::pair(JobId(7), JobId(8)));
        assert!((solo - 0.4).abs() < 1e-12, "{solo}");
        assert!((pair - solo * PAIR_PRIOR).abs() < 1e-12, "{pair} vs {solo}·{PAIR_PRIOR}");
    }

    #[test]
    fn refine_queries_do_not_leak_round_labels() {
        // Fresh catalog, no prior estimates: record the round's
        // measurements first (the coordinator's order), then build the
        // queries — no estimate feature may carry a measured target.
        let mut c = Catalog::new();
        c.register_job(JobId(1), psi(ModelFamily::ResNet18, 32, 1));
        c.register_job(JobId(2), psi(ModelFamily::LanguageModel, 10, 1));
        let combo = Combo::pair(JobId(1), JobId(2));
        let aid = AccelId {
            server: 0,
            accel: AccelType::K80,
        };
        // distinctive labels far outside any prior's range (< 1.05)
        let ms = vec![
            Measurement {
                job: JobId(1),
                combo,
                accel: aid,
                throughput: 2.25,
                at: 1.0,
            },
            Measurement {
                job: JobId(2),
                combo,
                accel: aid,
                throughput: 2.5,
                at: 1.0,
            },
        ];
        for m in &ms {
            c.record_measurement(
                EstimateKey {
                    accel: m.accel.accel,
                    job: m.job,
                    combo: m.combo,
                },
                m.throughput,
            );
        }
        let qs = build_refine_queries(&c, &ms);
        assert!(!qs.is_empty());
        for q in &qs {
            // layout (encoding::p2_row): 28,29 = est_a1; 30,31 = meas_a1;
            // 32,33 = est_a2 — the estimate slots must hold priors
            for slot in [28usize, 29, 32, 33] {
                assert!(
                    q.x[slot] < 2.0,
                    "estimate slot {slot} leaked a label: {}",
                    q.x[slot]
                );
            }
            assert!(q.x[30] >= 2.0 && q.x[31] >= 2.0, "measured slots lost");
        }
    }

    #[test]
    fn missing_corunner_measurement_is_encoded_as_prior() {
        // the pair ran, but only j1 was measured this round: the
        // co-runner slot must carry j2's prior, not 0.0 (which would be
        // indistinguishable from "no co-runner").
        let (mut c, ms) = setup();
        let only_j1 = vec![ms[0].clone()];
        c.record_measurement(
            EstimateKey {
                accel: ms[0].accel.accel,
                job: ms[0].job,
                combo: ms[0].combo,
            },
            ms[0].throughput,
        );
        let qs = build_refine_queries(&c, &only_j1);
        assert_eq!(qs.len(), 5);
        for q in &qs {
            assert_eq!(q.j2, Some(JobId(2)));
            // setup wrote a 0.3 prior estimate for (k80, j2, pair)
            assert!((q.x[31] - 0.3).abs() < 1e-6, "meas_j2 slot: {}", q.x[31]);
            assert!(q.x[31] != 0.0);
        }
    }

    #[test]
    fn fallback_pair_prior() {
        let mut c = Catalog::new();
        c.write_initial(
            EstimateKey {
                accel: AccelType::K80,
                job: JobId(1),
                combo: Combo::Solo(JobId(1)),
            },
            0.6,
        );
        let v = catalog_value(&c, AccelType::K80, JobId(1), &Combo::pair(JobId(1), JobId(2)));
        assert!((v - 0.6 * PAIR_PRIOR).abs() < 1e-12);
        // unknown job → generation prior
        let v2 = catalog_value(&c, AccelType::V100, JobId(9), &Combo::Solo(JobId(9)));
        assert!(v2 > 0.0 && v2 <= 1.0);
    }
}
