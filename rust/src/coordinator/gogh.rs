//! The GOGH coordinator: online P1 → ILP → monitor → P2 loop (Fig. 1).
//!
//! [`GoghScheduler`] implements [`Scheduler`] over a live PJRT runtime:
//!
//! * **arrival** — register Ψ, pick the most similar measured job j2
//!   from the Catalog, build Eq. 1 rows for every accelerator type ×
//!   co-runner candidate, run the AOT-compiled P1, and write the round-0
//!   estimates into the Catalog; then solve Problem 1 over the current
//!   estimates and bind the result onto instances.
//! * **monitoring** — record measurements, score the pre-measurement
//!   estimates (the system's reported estimation MAE), build Eq. 3 rows
//!   and run P2 to refine every other GPU type's estimate (Eq. 4), then
//!   take a few Adam steps on both networks from the replay buffers
//!   (continuous learning; the paper's feedback loop).
//!
//! [`Gogh`] is the top-level system: config → engine + scheduler +
//! simulator, with catalog history seeding and estimator bootstrap
//! training.

use std::collections::BTreeSet;

use crate::catalog::{Catalog, EstimateKey, SimilarityIndex};
use crate::cluster::{
    AccelId, Cluster, ClusterSpec, Measurement, Placement, PlacementDelta, PlacementOp, ShardSpec,
    Topology,
};
use crate::config::ExperimentConfig;
use crate::coordinator::estimate_cache::{value_via, EstimateCache, EstimateCacheStats};
use crate::coordinator::history;
use crate::coordinator::optimizer::{self, Optimizer};
use crate::coordinator::refinement::{self, catalog_value};
use crate::coordinator::scheduler::{ClusterEvent, Decision, Scheduler, SimDriver};
use crate::engine::EngineOptions;
use crate::ilp::branch_bound::{BnbConfig, BnbStatus};
use crate::ilp::problem1::{
    pool_accel_counts, solve_problem1, solve_problem1_with_basis, ColumnBasis, Problem1Input,
};
use crate::metrics::{ErrorTracker, RunReport};
use crate::power::{state_cost, CarbonSignal, PowerKnobs, PowerState};
use crate::runtime::dataset::Sample;
use crate::runtime::{Backend, Engine, Estimator, NativeBackend};
use crate::workload::encoding::{p1_row, psi_distance};
use crate::workload::{
    serving, AccelType, Combo, JobId, JobSpec, ThroughputOracle, Trace, ACCEL_TYPES,
};
use crate::Result;

/// Node budget of the bounded local ILP on the incremental arrival path
/// (the full re-solve budget is `OptimizerConfig::max_nodes`).
const LOCAL_NODE_BUDGET: usize = 400;

/// Replica scale-down hysteresis: a replica is released only when the
/// predicted post-removal latency still clears this fraction of the
/// SLO, so the autoscaler never oscillates around the breach boundary.
const SCALE_DOWN_MARGIN: f64 = 0.6;

/// Knobs for the scheduler (subset of [`ExperimentConfig`] plus history
/// size; see config.rs for field docs).
#[derive(Debug, Clone)]
pub struct GoghOptions {
    pub estimator: crate::config::EstimatorConfig,
    pub optimizer: crate::config::OptimizerConfig,
    /// historical jobs seeded into the catalog at startup.
    pub history_jobs: usize,
    /// Apply P2 cross-GPU refinement (Eq. 3/4). Disabling it is the
    /// "P1-only" ablation of `examples/ablation_refinement.rs`.
    pub enable_refinement: bool,
    /// Active-exploration probability (extension of the paper's
    /// future-work direction): with probability ε per allocation round,
    /// one job is deliberately moved to its least-measured accelerator
    /// type, feeding P2 with cross-GPU observations it would otherwise
    /// never get. 0 disables (the paper's baseline behaviour).
    pub exploration_epsilon: f64,
    /// Escape hatch for the incremental arrival path: a full Problem-1
    /// re-solve is forced every K non-tick events (1 = always full).
    pub full_resolve_every: usize,
    /// Neighborhood size of the incremental arrival path (0 disables
    /// incremental solving — every arrival re-solves the full ILP).
    pub neighborhood: usize,
    /// Server-pool shards of the parallel decision path: arrivals are
    /// solved per shard on scoped worker threads and routed to the shard
    /// with the lowest marginal energy. 1 (the default) keeps the
    /// single-threaded pre-shard path bit-for-bit. With topology groups
    /// this is the shard count *per group*.
    pub shards: usize,
    /// Top-level shard-groups of the hierarchical decision path: a
    /// cheap catalog-only router scores groups (no LP) and only the
    /// winning group's shards solve the arrival, so per-decision work
    /// stays bounded however large the fleet grows. 1 (the default)
    /// keeps the flat single-level sharding.
    pub topology_groups: usize,
    /// Memoize `catalog_value` lookups in the [`EstimateCache`]
    /// (invalidated per refinement round). Value-transparent: disabling
    /// it changes wall-clock only, never placements.
    pub estimate_cache: bool,
    /// Cap on P1 co-runner candidates per arrival (0 = every active
    /// job). At 1000-accelerator scale the uncapped estimate fan-out is
    /// O(active² × types) over a trace; the cap keeps the most similar
    /// candidates (the ones P1's transfer is most reliable for).
    pub p1_candidates: usize,
    /// DVFS decision layer: power states enter the Problem-1 objective
    /// and the monitor-tick governor re-states accelerators between
    /// re-solves. Off (the default) reproduces the fixed-nominal
    /// objective bit-for-bit.
    pub power_dvfs: bool,
    /// Diurnal carbon/price signal reweighting the objective's energy
    /// term and pricing emissions in the energy meters. `None` keeps
    /// unweighted watts (the pre-power behaviour).
    pub carbon: Option<CarbonSignal>,
    /// Priority preemption (ISSUE 9): arrivals that outrank running
    /// work may park ([`PlacementOp::Suspend`]) the cheapest
    /// strictly-lower-tier victim when no free instance exists, and
    /// the full re-solve parks (rather than silently drops) still-
    /// active jobs the new allocation sheds. Parked jobs re-enter via
    /// the monitor-tick resume pass. Off (the default) reproduces the
    /// pre-priority behaviour bit-for-bit.
    pub preemption: bool,
    pub seed: u64,
}

impl Default for GoghOptions {
    fn default() -> Self {
        Self {
            estimator: Default::default(),
            optimizer: Default::default(),
            history_jobs: 24,
            enable_refinement: true,
            exploration_epsilon: 0.0,
            full_resolve_every: 8,
            neighborhood: 4,
            shards: 1,
            topology_groups: 1,
            estimate_cache: true,
            p1_candidates: 0,
            power_dvfs: false,
            carbon: None,
            preemption: false,
            seed: 17,
        }
    }
}

impl GoghOptions {
    /// The scheduler knobs an [`ExperimentConfig`] describes.
    pub fn from_config(cfg: &ExperimentConfig) -> Self {
        Self {
            estimator: cfg.estimator.clone(),
            optimizer: cfg.optimizer.clone(),
            history_jobs: cfg.gogh.history_jobs,
            enable_refinement: cfg.gogh.enable_refinement,
            exploration_epsilon: cfg.gogh.exploration_epsilon,
            full_resolve_every: cfg.gogh.full_resolve_every,
            neighborhood: cfg.gogh.neighborhood,
            shards: cfg.gogh.shards,
            topology_groups: cfg.gogh.topology_groups,
            estimate_cache: cfg.gogh.estimate_cache,
            p1_candidates: cfg.gogh.p1_candidates,
            power_dvfs: cfg.power.dvfs,
            carbon: cfg.power.carbon.signal(),
            preemption: cfg.gogh.preemption,
            seed: cfg.seed,
        }
    }
}

/// Decision-path solver statistics split by path (reported by the e2e
/// bench: the incremental neighborhood ILP must explore fewer nodes per
/// solve than the full re-solve).
#[derive(Debug, Clone, Copy, Default)]
pub struct SolverPathStats {
    pub full_solves: usize,
    pub full_nodes: usize,
    pub incremental_solves: usize,
    pub incremental_nodes: usize,
}

impl SolverPathStats {
    pub fn mean_full_nodes(&self) -> f64 {
        if self.full_solves == 0 {
            0.0
        } else {
            self.full_nodes as f64 / self.full_solves as f64
        }
    }

    pub fn mean_incremental_nodes(&self) -> f64 {
        if self.incremental_solves == 0 {
            0.0
        } else {
            self.incremental_nodes as f64 / self.incremental_solves as f64
        }
    }
}

/// Per-shard decision-path statistics of the parallel arrival path.
#[derive(Debug, Clone, Copy, Default)]
pub struct ShardStats {
    /// local arrival solves attempted by this shard's worker
    pub solves: usize,
    /// branch-and-bound nodes those solves explored
    pub nodes: usize,
    /// wall-clock seconds inside this shard's local solves
    pub seconds: f64,
    /// jobs whose winning placement this shard hosted
    pub routed: usize,
}

impl ShardStats {
    pub fn mean_nodes(&self) -> f64 {
        if self.solves == 0 {
            0.0
        } else {
            self.nodes as f64 / self.solves as f64
        }
    }
}

/// Learning-loop counters (the CI smoke greps these off the `simulate`
/// summary line, so the learning path can never silently degrade back
/// to estimator-free).
#[derive(Debug, Clone, Copy, Default)]
pub struct LearningStats {
    /// monitoring rounds in which ≥1 P2 refinement query was applied
    pub refinement_rounds: usize,
    /// Adam steps taken by P1 (bootstrap + online)
    pub p1_train_steps: u64,
    /// Adam steps taken by P2 (bootstrap + online)
    pub p2_train_steps: u64,
    /// P1 Adam steps taken *after* bootstrap (the continuous-learning
    /// half of the paper's loop — gated separately so a dead monitor
    /// path can't hide behind construction-time training)
    pub p1_online_steps: u64,
    /// P2 Adam steps taken after bootstrap
    pub p2_online_steps: u64,
    /// monitor measurements of *inference* jobs recorded into the
    /// catalog (and, when refinement is on, transferred cross-GPU by
    /// P2) — the CI mixed-workload smoke greps this to prove the
    /// learning loop ingests serving measurements, not just training
    pub inference_measurements: u64,
}

pub struct GoghScheduler {
    pub catalog: Catalog,
    /// P1/P2 estimator backends (PJRT artifacts or the pure-Rust native
    /// MLP — see [`crate::runtime::Backend`]); `None` runs the
    /// coordinator estimator-free (catalog priors + measurements only —
    /// the degraded mode for `backend = "none"`).
    p1: Option<Box<dyn Backend>>,
    p2: Option<Box<dyn Backend>>,
    opt: Optimizer,
    options: GoghOptions,
    /// memoized estimate matrix (invalidated on catalog mutation)
    cache: EstimateCache,
    /// two-level topology of the current cluster spec (computed lazily
    /// on the first sharded arrival, reused for the rest of the run)
    topology: Option<CachedTopology>,
    /// per-shard decision-path stats, by global shard index (index 0
    /// doubles as the unsharded incremental path's slot)
    shard_stats: Vec<ShardStats>,
    /// last exported simplex basis per global shard index: the next
    /// arrival's local ILP crash-starts its root LP from it (stale
    /// hints degrade gracefully to the cold solve)
    shard_bases: Vec<Option<ColumnBasis>>,
    /// jobs whose round-0 estimates were already produced
    initialized: BTreeSet<JobId>,
    /// live inference jobs (autoscaler + learning-stats attribution)
    inference_jobs: BTreeSet<JobId>,
    /// replica autoscaling events applied on monitor ticks
    scale_ups: u64,
    scale_downs: u64,
    /// elastic-training grow/shrink actions applied on monitor ticks
    elastic_grows: u64,
    elastic_shrinks: u64,
    /// monitor measurements of inference jobs seen so far
    inference_measurements: u64,
    replay_p1: Vec<Sample>,
    replay_p2: Vec<Sample>,
    errors: ErrorTracker,
    /// monitoring rounds in which ≥1 P2 refinement query was applied
    refine_rounds: usize,
    /// Adam steps taken during construction-time bootstrap, per network
    /// (splits the `steps_taken` counters into bootstrap vs online so
    /// the CI smoke can gate the *online* half of the learning loop).
    p1_bootstrap_steps: u64,
    p2_bootstrap_steps: u64,
    round: u32,
    rng: crate::util::Rng,
    p1_calls: usize,
    p1_seconds: f64,
    /// non-tick events since the last full re-solve (escape hatch).
    events_since_full: usize,
    inc_solves: usize,
    inc_nodes: usize,
    inc_seconds: f64,
}

impl GoghScheduler {
    /// Build over an engine, seeding history + bootstrap-training the
    /// estimators from the Catalog.
    pub fn new(
        engine: &Engine,
        oracle_for_history: &ThroughputOracle,
        options: GoghOptions,
    ) -> Result<Self> {
        let p1 = Estimator::new(engine, &format!("p1_{}", options.estimator.p1_arch.key()))?;
        let p2 = Estimator::new(engine, &format!("p2_{}", options.estimator.p2_arch.key()))?;
        Self::with_backends(Some(Box::new(p1)), Some(Box::new(p2)), oracle_for_history, options)
    }

    /// Build over the pure-Rust native backend: the full learning loop
    /// (P1 priors, P2 refinement, online Adam steps) with zero external
    /// artifacts. Seeded from `options.seed`, so runs are bit
    /// reproducible.
    pub fn with_native_backend(
        oracle_for_history: &ThroughputOracle,
        options: GoghOptions,
    ) -> Result<Self> {
        let p1 = NativeBackend::p1(options.seed ^ 0x7031); // "p1"
        let p2 = NativeBackend::p2(options.seed ^ 0x7032); // "p2"
        Self::with_backends(Some(Box::new(p1)), Some(Box::new(p2)), oracle_for_history, options)
    }

    /// Build without any estimator: the coordinator runs estimator-free
    /// on catalog priors, similarity transfer and live measurements (no
    /// P1/P2 networks, no online training). This is `backend = "none"`,
    /// the degraded mode the scale benches use to isolate decision-path
    /// cost from estimator cost.
    pub fn without_engine(
        oracle_for_history: &ThroughputOracle,
        options: GoghOptions,
    ) -> Result<Self> {
        Self::with_backends(None, None, oracle_for_history, options)
    }

    /// Build from explicit estimator [`Backend`]s (the general form
    /// behind [`GoghScheduler::new`], [`with_native_backend`] and
    /// [`without_engine`]; custom backends plug in here).
    ///
    /// [`with_native_backend`]: GoghScheduler::with_native_backend
    /// [`without_engine`]: GoghScheduler::without_engine
    pub fn with_backends(
        p1: Option<Box<dyn Backend>>,
        p2: Option<Box<dyn Backend>>,
        oracle_for_history: &ThroughputOracle,
        options: GoghOptions,
    ) -> Result<Self> {
        let mut s = Self {
            catalog: Catalog::new(),
            p1,
            p2,
            opt: Optimizer::new(options.optimizer.clone()),
            cache: EstimateCache::new(),
            topology: None,
            shard_stats: vec![ShardStats::default(); options.shards.max(1)],
            shard_bases: vec![],
            initialized: BTreeSet::new(),
            inference_jobs: BTreeSet::new(),
            scale_ups: 0,
            scale_downs: 0,
            elastic_grows: 0,
            elastic_shrinks: 0,
            inference_measurements: 0,
            replay_p1: vec![],
            replay_p2: vec![],
            errors: ErrorTracker::new(),
            refine_rounds: 0,
            p1_bootstrap_steps: 0,
            p2_bootstrap_steps: 0,
            round: 0,
            rng: crate::util::Rng::seed_from_u64(options.seed ^ 0x6064),
            p1_calls: 0,
            p1_seconds: 0.0,
            events_since_full: 0,
            inc_solves: 0,
            inc_nodes: 0,
            inc_seconds: 0.0,
            options,
        };
        if s.options.history_jobs > 0 {
            history::seed_catalog(
                &mut s.catalog,
                oracle_for_history,
                s.options.history_jobs,
                0.02,
                s.options.seed,
            );
            s.bootstrap()?;
        }
        s.p1_bootstrap_steps = s.p1.as_ref().map_or(0, |b| b.steps_taken());
        s.p2_bootstrap_steps = s.p2.as_ref().map_or(0, |b| b.steps_taken());
        Ok(s)
    }

    /// Replace the catalog with one restored from a daemon snapshot.
    /// Every job the restored catalog knows is marked as already
    /// initialized (its round-0 estimates *are* the restored records —
    /// re-running P1 would overwrite learned P2 refinements), and the
    /// estimate cache is invalidated so the next solve reads the
    /// restored values.
    pub fn restore_catalog(&mut self, catalog: Catalog) {
        self.initialized.extend(catalog.known_jobs().copied());
        self.catalog = catalog;
        self.cache.invalidate();
        // the full-resolve builder's pair scores derive from the old
        // catalog: rescore on the next solve
        self.opt.note_estimates_changed();
    }

    /// Pre-train P1/P2 on catalog history (build-time data only).
    fn bootstrap(&mut self) -> Result<()> {
        let steps = self.options.estimator.bootstrap_steps;
        if steps == 0 || (self.p1.is_none() && self.p2.is_none()) {
            return Ok(());
        }
        let n = (steps * 64).min(self.options.estimator.replay_capacity * 4);
        self.replay_p1 = history::p1_samples_from_catalog(&self.catalog, n, self.options.seed);
        self.replay_p2 =
            history::p2_samples_from_catalog(&self.catalog, n, 0.15, self.options.seed);
        for _ in 0..steps {
            self.train_once()?;
        }
        self.trim_replay();
        Ok(())
    }

    fn trim_replay(&mut self) {
        let cap = self.options.estimator.replay_capacity;
        let excess = self.replay_p1.len().saturating_sub(cap);
        if excess > 0 {
            self.replay_p1.drain(0..excess);
        }
        let excess = self.replay_p2.len().saturating_sub(cap);
        if excess > 0 {
            self.replay_p2.drain(0..excess);
        }
    }

    /// One Adam step for each network on a random replay batch.
    fn train_once(&mut self) -> Result<()> {
        for (est, replay) in [
            (self.p1.as_mut(), &self.replay_p1),
            (self.p2.as_mut(), &self.replay_p2),
        ] {
            let Some(est) = est else { continue };
            if replay.len() < 8 {
                continue;
            }
            let b = est.train_batch().min(replay.len());
            let mut idx: Vec<usize> = (0..replay.len()).collect();
            self.rng.shuffle(&mut idx);
            let xs: Vec<Vec<f32>> = idx[..b].iter().map(|&i| replay[i].x.clone()).collect();
            let ys: Vec<[f32; 2]> = idx[..b].iter().map(|&i| replay[i].y).collect();
            est.train_step(&xs, &ys)?;
        }
        Ok(())
    }

    /// Round-0 estimation for a new job (paper §2.3): Eq. 1 rows over
    /// every accel type × (solo + each active co-runner), one batched P1
    /// call, estimates written into the Catalog. Estimator-free mode
    /// writes the similarity-transfer inputs themselves as the round-0
    /// estimates (the Eq. 1 identity prior: j1 behaves like j2).
    fn initial_estimates(&mut self, cluster: &Cluster, j1: JobId) -> Result<()> {
        let spec = cluster.job(j1).expect("job registered").clone();
        let psi_j1 = spec.psi();
        self.catalog.register_job(j1, psi_j1);
        if spec.is_inference() {
            self.inference_jobs.insert(j1);
        }

        // most similar job with measured history
        let j2 = {
            let idx = SimilarityIndex::new(&self.catalog);
            idx.most_similar(&psi_j1, &[j1], true)
        };
        let Some(j2) = j2 else {
            // cold catalog: write generation-speed priors
            for &a in ACCEL_TYPES.iter() {
                let v = 0.4 * a.base_speed() / AccelType::V100.base_speed();
                self.catalog.write_initial(
                    EstimateKey {
                        accel: a,
                        job: j1,
                        combo: Combo::Solo(j1),
                    },
                    v,
                );
            }
            self.initialized.insert(j1);
            // round-0 writes only touch keys involving j1 — a targeted
            // drop keeps the rest of the memoized matrix warm
            self.cache.drop_job(j1);
            return Ok(());
        };
        let psi_j2 = *self.catalog.psi(j2).unwrap();

        // co-runner candidates: the empty job + every other active job
        let mut others: Vec<JobId> = cluster
            .active_job_ids()
            .into_iter()
            .filter(|&j| j != j1)
            .collect();
        others.sort();
        // at scale, cap the fan-out to the most similar candidates (the
        // pairings the optimizer is most likely to propose first)
        let cap = self.options.p1_candidates;
        if cap > 0 && others.len() > cap {
            let mut scored: Vec<(f32, JobId)> = others
                .iter()
                .map(|&j| {
                    let d = self
                        .catalog
                        .psi(j)
                        .map(|p| psi_distance(&psi_j1, p))
                        .unwrap_or(f32::INFINITY);
                    (d, j)
                })
                .collect();
            scored.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
            others = scored.into_iter().take(cap).map(|(_, j)| j).collect();
            others.sort();
        }

        let mut rows: Vec<Vec<f32>> = vec![];
        let mut keys: Vec<(EstimateKey, Option<EstimateKey>)> = vec![];
        // similarity-transfer inputs, doubling as the estimator-free
        // round-0 estimates
        let mut priors: Vec<[f64; 2]> = vec![];
        let build_rows = self.p1.is_some();
        for &a in ACCEL_TYPES.iter() {
            // solo row (j3 = j0)
            let t_j2_solo = catalog_value(&self.catalog, a, j2, &Combo::Solo(j2));
            if build_rows {
                rows.push(
                    p1_row(
                        &psi_j2,
                        &crate::workload::encoding::PSI_EMPTY,
                        a,
                        t_j2_solo as f32,
                        0.0,
                        &psi_j1,
                    )
                    .to_vec(),
                );
            }
            keys.push((
                EstimateKey {
                    accel: a,
                    job: j1,
                    combo: Combo::Solo(j1),
                },
                None,
            ));
            priors.push([t_j2_solo, 0.0]);
            // pair rows
            for &j3 in &others {
                let Some(psi_j3) = self.catalog.psi(j3).copied() else {
                    continue;
                };
                // historical analogue of the (j2, j3) co-location: j2's
                // measured pair with the peer most similar to j3, falling
                // back to solo values (documented Eq. 1 approximation).
                let (t_j2, t_j3) = self.historical_pair_inputs(a, j2, j3);
                if build_rows {
                    rows.push(
                        p1_row(&psi_j2, &psi_j3, a, t_j2 as f32, t_j3 as f32, &psi_j1).to_vec(),
                    );
                }
                let combo = Combo::pair(j1, j3);
                keys.push((
                    EstimateKey {
                        accel: a,
                        job: j1,
                        combo,
                    },
                    Some(EstimateKey {
                        accel: a,
                        job: j3,
                        combo,
                    }),
                ));
                priors.push([t_j2, t_j3]);
            }
        }

        let preds: Vec<[f32; 2]> = match self.p1.as_mut() {
            Some(p1) => {
                // gogh-lint: allow(determinism-wall-clock, p1_seconds is a latency statistic; nothing branches on it)
                let t0 = std::time::Instant::now();
                let preds = p1.predict(&rows)?;
                self.p1_seconds += t0.elapsed().as_secs_f64();
                self.p1_calls += 1;
                preds
            }
            None => priors.iter().map(|p| [p[0] as f32, p[1] as f32]).collect(),
        };

        for ((k1, k3), pred) in keys.iter().zip(&preds) {
            self.catalog
                .write_initial(*k1, (pred[0] as f64).clamp(0.0, 1.5));
            if let Some(k3) = k3 {
                // estimate of the co-runner's degraded throughput; only
                // written if we have no measurement for it
                if self.catalog.record(k3).map_or(true, |r| !r.is_measured()) {
                    self.catalog
                        .write_initial(*k3, (pred[1] as f64).clamp(0.0, 1.5));
                }
            }
        }
        self.initialized.insert(j1);
        // every key written above has j1 in its combo, so a targeted
        // drop is value-equivalent to a full invalidation and keeps the
        // rest of the memoized matrix warm across arrivals
        self.cache.drop_job(j1);
        Ok(())
    }

    /// Best available historical inputs for Eq. 1's T_{a,j2}^{(j2,j3)}:
    /// a measured co-location of j2 on `a` (with any peer), else solo
    /// values scaled by the pair prior.
    fn historical_pair_inputs(&self, a: AccelType, j2: JobId, j3: JobId) -> (f64, f64) {
        let rec = self
            .catalog
            .measured_records_of(j2)
            .into_iter()
            .find(|(k, _)| k.accel == a && k.combo.len() == 2);
        if let Some((k, t2)) = rec {
            let peer = k.combo.other(j2).unwrap();
            let t_peer = self
                .catalog
                .value(&EstimateKey {
                    accel: a,
                    job: peer,
                    combo: k.combo,
                })
                .unwrap_or(t2);
            return (t2, t_peer);
        }
        let t2 = catalog_value(&self.catalog, a, j2, &Combo::Solo(j2)) * refinement::PAIR_PRIOR;
        let t3 = catalog_value(&self.catalog, a, j3, &Combo::Solo(j3)) * refinement::PAIR_PRIOR;
        (t2, t3)
    }

    /// Move one randomly chosen job to a free instance of its
    /// least-measured accelerator type (ε-greedy active exploration).
    /// Solo placement only, and only when a free instance exists — the
    /// perturbation trades a little short-term energy/SLO for better
    /// cross-GPU coverage in the Catalog.
    fn explore(&mut self, cluster: &Cluster, placement: &mut Placement) {
        let ids = cluster.active_job_ids();
        if ids.is_empty() {
            return;
        }
        let j = ids[self.rng.range_usize(0, ids.len())];
        // least-measured accel type for this job
        let mut counts: Vec<(usize, AccelType)> = ACCEL_TYPES
            .iter()
            .map(|&a| {
                let n = self
                    .catalog
                    .measured_records_of(j)
                    .iter()
                    .filter(|(k, _)| k.accel == a)
                    .count();
                (n, a)
            })
            .collect();
        counts.sort_by_key(|&(n, a)| (n, a.index()));
        for (_, target) in counts {
            // a free in-service instance of that type?
            let accels = cluster.available_accels();
            let free = accels
                .iter()
                .find(|aid| aid.accel == target && placement.combo_on(**aid).is_none());
            if let Some(&aid) = free {
                // only move jobs that are currently solo or unplaced — never
                // break a pair (the co-runner would silently speed up and
                // corrupt its estimate provenance).
                let current = placement.accels_of(j).to_vec();
                let solo_everywhere = current
                    .iter()
                    .all(|a| placement.combo_on(*a).map_or(true, |c| c.len() == 1));
                if !solo_everywhere {
                    return;
                }
                for a in current {
                    placement.clear_accel(a);
                }
                placement.assign(aid, Combo::Solo(j));
                crate::log_debug!("explore: moved {j} to {aid}");
                return;
            }
        }
    }

    /// Collect online training samples out of this round's measurements.
    fn harvest_samples(&mut self, measurements: &[Measurement]) {
        // P1: (similar job j2's history) → (j1's measured outcome)
        let p1_new = history::p1_samples_from_catalog(
            &self.catalog,
            measurements.len().min(32),
            self.options.seed ^ (self.round as u64) << 8,
        );
        self.replay_p1.extend(p1_new);
        // P2: cross-GPU transfer among measured records
        let p2_new = history::p2_samples_from_catalog(
            &self.catalog,
            measurements.len().min(32),
            0.15,
            self.options.seed ^ (self.round as u64) << 9,
        );
        self.replay_p2.extend(p2_new);
        self.trim_replay();
    }
}

/// Outcome of one bounded local arrival solve (one shard worker, or the
/// whole-cluster pool on the unsharded path).
struct LocalSolve {
    delta: Option<PlacementDelta>,
    /// objective minus the pool's current estimated cost: the marginal
    /// energy of hosting the arrival here (the shard-routing score)
    marginal: f64,
    nodes: usize,
    seconds: f64,
    /// whether an ILP actually ran (early-outs must not count as solves)
    attempted: bool,
    /// root-LP basis exported by a chained solve, for the next arrival
    /// landing on the same shard
    basis: Option<ColumnBasis>,
}

impl LocalSolve {
    fn skipped() -> Self {
        Self {
            delta: None,
            marginal: f64::INFINITY,
            nodes: 0,
            seconds: 0.0,
            attempted: false,
            basis: None,
        }
    }
}

/// The two-level topology of one cluster spec, computed once per run
/// and reused on every sharded arrival (it depends only on the
/// immutable spec and the group/shard counts; rebuilding the
/// `ShardSpec`s and membership sets per event was measurable on the
/// 1000-accel hot path).
struct CachedTopology {
    /// the spec accels this topology was computed from (staleness key)
    spec: Vec<AccelId>,
    groups: usize,
    per_group: usize,
    topo: Topology,
}

/// Bounded local re-solve for one arrival over one instance pool: only
/// the new job and its best co-location neighborhood enter the ILP;
/// every other running job keeps its instances untouched. With
/// `shard: Some(_)` the neighborhood is restricted to jobs placed wholly
/// inside the shard and the pool to the shard's in-service instances —
/// this is the worker body of the shard-parallel decision path, pure
/// w.r.t. scheduler state so `std::thread::scope` can fan it out.
fn local_arrival_solve(
    catalog: &Catalog,
    cache: Option<&EstimateCache>,
    cluster: &Cluster,
    j1: JobId,
    shard: Option<(&ShardSpec, &BTreeSet<AccelId>)>,
    neighborhood: usize,
    ocfg: &crate::config::OptimizerConfig,
    power: PowerKnobs,
    basis: Option<&ColumnBasis>,
) -> LocalSolve {
    if neighborhood == 0 {
        return LocalSolve::skipped();
    }
    let within_shard = |j: JobId| -> bool {
        let Some((_, set)) = shard else { return true };
        let accels = cluster.placement.accels_of(j);
        !accels.is_empty() && accels.iter().all(|a| set.contains(a))
    };
    // rank co-location partners by estimated pair synergy
    let active = cluster.active_job_ids();
    let mut scored: Vec<(f64, JobId)> = active
        .iter()
        .filter(|&&j| j != j1 && (shard.is_none() || within_shard(j)))
        .map(|&j| {
            let c = Combo::pair(j1, j);
            let s = value_via(catalog, cache, AccelType::V100, j1, &c)
                + value_via(catalog, cache, AccelType::V100, j, &c);
            (s, j)
        })
        .collect();
    scored.sort_by(|a, b| b.0.total_cmp(&a.0).then(a.1.cmp(&b.1)));
    let mut nbr: BTreeSet<JobId> = scored.iter().take(neighborhood).map(|&(_, j)| j).collect();
    nbr.insert(j1);
    // close under co-location: drop members paired with outsiders
    loop {
        let victim = nbr.iter().copied().find(|&j| {
            cluster.placement.accels_of(j).iter().any(|aid| {
                cluster
                    .placement
                    .combo_on(*aid)
                    .map_or(false, |c| c.jobs().iter().any(|x| !nbr.contains(x)))
            })
        });
        match victim {
            Some(j) => {
                nbr.remove(&j);
            }
            None => break,
        }
    }
    // instance pool: free in-service instances + instances wholly owned
    // by the neighborhood (shard workers start from their own pool)
    let avail = match shard {
        Some((s, _)) => cluster.shard_available_accels(s),
        None => cluster.available_accels(),
    };
    let pool: Vec<AccelId> = avail
        .into_iter()
        .filter(|aid| match cluster.placement.combo_on(*aid) {
            None => true,
            Some(c) => c.jobs().iter().all(|j| nbr.contains(j)),
        })
        .collect();
    if pool.is_empty() {
        return LocalSolve::skipped();
    }
    let jobs: Vec<JobSpec> = nbr.iter().filter_map(|j| cluster.job(*j).cloned()).collect();
    let counts = pool_accel_counts(&pool);
    let thr = move |a: AccelType, j: JobId, c: &Combo| value_via(catalog, cache, a, j, c);
    let solo_cap = |a: AccelType| a.base_speed() / AccelType::V100.base_speed();
    let input = Problem1Input {
        jobs: &jobs,
        accel_counts: &counts,
        throughput: &thr,
        solo_capability: &solo_cap,
        max_pairs_per_job: ocfg.max_pairs_per_job,
        slack_penalty: Some(ocfg.slack_penalty),
        throughput_bonus: ocfg.throughput_bonus,
        now_s: cluster.now(),
        power,
    };
    let bnb = BnbConfig {
        max_nodes: ocfg.max_nodes.min(LOCAL_NODE_BUDGET),
        // deterministic budget only: a wall-clock cutoff would make the
        // incumbent — and thus shard routing and placements — depend on
        // host load, breaking the path's bit-reproducibility guarantee
        // (the tiny node-bounded local problems don't need an anytime
        // escape; the full re-solve keeps its time limit)
        time_limit_s: f64::INFINITY,
        auto_warm_start: ocfg.warm_start,
        node_selection: ocfg.node_selection,
        ..Default::default()
    };
    // gogh-lint: allow(determinism-wall-clock, shard solve latency statistic; the solve itself runs under a node budget)
    let t0 = std::time::Instant::now();
    // basis reuse across arrivals (sharded path only): crash-start the
    // root LP from the previous arrival's exported basis and export the
    // new one for the next arrival on this shard
    let sol = match basis {
        Some(hint) => solve_problem1_with_basis(&input, &bnb, hint),
        None => solve_problem1(&input, &bnb),
    };
    let seconds = t0.elapsed().as_secs_f64();
    let solved = matches!(sol.status, BnbStatus::Optimal | BnbStatus::Feasible)
        && sol.violated_jobs.is_empty();
    let delta = if solved {
        optimizer::bind_pool(cluster, &pool, &sol)
    } else {
        None
    };
    // routing score: subtract the pool's current estimated column cost,
    // so shards compete on the *marginal* energy of accepting j1 (a
    // busier shard's absolute objective is higher through no fault of
    // the arrival). Only the sharded path routes, and only feasible
    // solves compete — skip the pool sweep otherwise.
    let marginal = if shard.is_some() && delta.is_some() {
        let baseline: f64 = pool
            .iter()
            .filter_map(|aid| cluster.placement.combo_on(*aid).map(|c| (*aid, *c)))
            .map(|(aid, c)| {
                let total_t: f64 = c.jobs().iter().map(|&j| thr(aid.accel, j, &c)).sum();
                let u = (total_t / solo_cap(aid.accel).max(1e-9)).clamp(0.0, 1.0);
                crate::power::column_cost(aid.accel, u, total_t, ocfg.throughput_bonus, power)
            })
            .sum();
        sol.objective - baseline
    } else {
        f64::INFINITY
    };
    LocalSolve {
        marginal,
        delta,
        nodes: sol.nodes,
        seconds,
        attempted: true,
        basis: sol.basis,
    }
}

impl GoghScheduler {
    /// Decision-path solver statistics, split by full vs incremental.
    pub fn solver_stats(&self) -> SolverPathStats {
        SolverPathStats {
            full_solves: self.opt.solves,
            full_nodes: self.opt.total_nodes,
            incremental_solves: self.inc_solves,
            incremental_nodes: self.inc_nodes,
        }
    }

    /// Per-shard decision-path statistics (one slot when unsharded).
    pub fn shard_stats(&self) -> &[ShardStats] {
        &self.shard_stats
    }

    /// Estimate-matrix cache counters.
    pub fn cache_stats(&self) -> EstimateCacheStats {
        self.cache.stats()
    }

    /// Learning-loop counters: refinement rounds + per-network Adam
    /// steps (zero across the board when running estimator-free).
    pub fn learning_stats(&self) -> LearningStats {
        let p1_steps = self.p1.as_ref().map_or(0, |b| b.steps_taken());
        let p2_steps = self.p2.as_ref().map_or(0, |b| b.steps_taken());
        LearningStats {
            refinement_rounds: self.refine_rounds,
            p1_train_steps: p1_steps,
            p2_train_steps: p2_steps,
            p1_online_steps: p1_steps.saturating_sub(self.p1_bootstrap_steps),
            p2_online_steps: p2_steps.saturating_sub(self.p2_bootstrap_steps),
            inference_measurements: self.inference_measurements,
        }
    }

    /// Replica autoscaler for inference jobs, run on every monitor tick
    /// after measurements and P2 refinement have updated the catalog:
    ///
    /// * **scale-up** — a placed serving job whose estimated M/M/c
    ///   latency (over its current replicas, at the headroom-adjusted
    ///   diurnal rate λ(t)) breaches its SLO gains one replica on the
    ///   estimated-fastest free in-service instance, up to its replica
    ///   cap R_j.
    /// * **scale-down** — an over-provisioned job releases its weakest
    ///   solo-hosted replica, but only when the predicted post-removal
    ///   latency still clears `SCALE_DOWN_MARGIN · SLO` (hysteresis) and
    ///   never below one replica; paired replicas are never broken.
    ///
    /// Each op is emitted as a [`PlacementDelta`] entry (one scaling
    /// action per job per tick), validated transactionally by
    /// `Cluster::apply_delta` like every other decision. Public so the
    /// invariant proptests can drive it against arbitrary clusters.
    pub fn autoscale(&mut self, cluster: &Cluster) -> PlacementDelta {
        let now = cluster.now();
        let mut delta = PlacementDelta::new();
        let mut ups = 0u64;
        let mut downs = 0u64;
        {
            let catalog = &self.catalog;
            let cache = self.options.estimate_cache.then_some(&self.cache);
            // free in-service instances, spec order (deterministic)
            let mut free: Vec<AccelId> = cluster
                .available_accels()
                .into_iter()
                .filter(|a| cluster.placement.combo_on(*a).is_none())
                .collect();
            let mut jobs: Vec<JobSpec> =
                cluster.jobs().filter(|s| s.is_inference()).cloned().collect();
            jobs.sort_by_key(|s| s.id);
            for spec in &jobs {
                let Some(inf) = spec.inference else { continue };
                let replicas = cluster.placement.accels_of(spec.id).to_vec();
                if replicas.is_empty() {
                    continue; // unplaced: the arrival/repair paths own it
                }
                let mu_of = |aid: AccelId| {
                    let c = cluster
                        .placement
                        .combo_on(aid)
                        .copied()
                        .unwrap_or(Combo::Solo(spec.id));
                    serving::service_rate(value_via(catalog, cache, aid.accel, spec.id, &c))
                };
                let mus: Vec<f64> = replicas.iter().map(|a| mu_of(*a)).collect();
                let lam = spec.request_rate_at(now) * serving::LOAD_HEADROOM;
                let lat = serving::mmc_sojourn(lam, &mus);
                if lat > inf.latency_slo_s && (replicas.len() as u32) < spec.distributability {
                    // scale up onto the estimated-fastest free instance
                    let mut best: Option<(f64, usize)> = None;
                    for (i, a) in free.iter().enumerate() {
                        let v = value_via(catalog, cache, a.accel, spec.id, &Combo::Solo(spec.id));
                        if best.map_or(true, |(bv, _)| v > bv) {
                            best = Some((v, i));
                        }
                    }
                    if let Some((_, i)) = best {
                        let aid = free.remove(i);
                        delta.push(PlacementOp::Assign {
                            accel: aid,
                            combo: Combo::Solo(spec.id),
                        });
                        ups += 1;
                    }
                } else if replicas.len() >= 2 && lat.is_finite() {
                    // weakest replica this job holds solo (pairs stay)
                    let mut weakest: Option<(f64, AccelId)> = None;
                    for &aid in &replicas {
                        if cluster.placement.combo_on(aid).map_or(false, |c| c.len() == 1) {
                            let mu = mu_of(aid);
                            let better = weakest.map_or(true, |(wmu, waid)| {
                                mu.total_cmp(&wmu).then(aid.cmp(&waid)).is_lt()
                            });
                            if better {
                                weakest = Some((mu, aid));
                            }
                        }
                    }
                    if let Some((_, victim)) = weakest {
                        let rest: Vec<f64> = replicas
                            .iter()
                            .filter(|&&a| a != victim)
                            .map(|a| mu_of(*a))
                            .collect();
                        if serving::mmc_sojourn(lam, &rest)
                            <= SCALE_DOWN_MARGIN * inf.latency_slo_s
                        {
                            delta.push(PlacementOp::Evict { accel: victim });
                            downs += 1;
                            free.push(victim);
                        }
                    }
                }
            }
        }
        self.scale_ups += ups;
        self.scale_downs += downs;
        delta
    }

    /// Elastic grow/shrink counts applied on monitor ticks.
    pub fn elastic_counts(&self) -> (u64, u64) {
        (self.elastic_grows, self.elastic_shrinks)
    }

    /// Preemption path of one arrival: when preemption is enabled and
    /// no free in-service instance exists, the cheapest victim of a
    /// strictly lower tier is parked ([`PlacementOp::Suspend`]) and the
    /// arrival takes over the freed instance it runs fastest on. The
    /// victim keeps its progress and re-enters through the monitor-tick
    /// resume pass or a later full re-solve. Victims must hold at least
    /// one solo instance — pairs are never broken (the co-runner's
    /// estimate provenance would silently corrupt).
    fn preempt_for_arrival(&self, cluster: &Cluster, j1: JobId) -> Option<PlacementDelta> {
        if !self.options.preemption {
            return None;
        }
        let spec = cluster.job(j1)?;
        if cluster.placement.is_placed(j1) {
            return None;
        }
        // last resort only: a free instance means the normal decision
        // paths can host the arrival without collateral
        let any_free = cluster
            .available_accels()
            .into_iter()
            .any(|a| cluster.placement.combo_on(a).is_none());
        if any_free {
            return None;
        }
        let catalog = &self.catalog;
        let cache = self.options.estimate_cache.then_some(&self.cache);
        let solo_accels = |v: JobId| -> Vec<AccelId> {
            cluster
                .placement
                .accels_of(v)
                .iter()
                .copied()
                .filter(|a| cluster.placement.combo_on(*a).map_or(false, |c| c.len() == 1))
                .collect()
        };
        // cheapest lower-tier victim: tier ascending, then estimated
        // delivered throughput ascending, ties to the lower id
        let mut victims: Vec<(usize, f64, JobId)> = cluster
            .jobs()
            .filter(|v| v.priority < spec.priority && !solo_accels(v.id).is_empty())
            .map(|v| {
                let est: f64 = solo_accels(v.id)
                    .iter()
                    .map(|a| value_via(catalog, cache, a.accel, v.id, &Combo::Solo(v.id)))
                    .sum();
                (v.priority.index(), est, v.id)
            })
            .collect();
        victims.sort_by(|a, b| a.0.cmp(&b.0).then(a.1.total_cmp(&b.1)).then(a.2.cmp(&b.2)));
        let &(_, _, victim) = victims.first()?;
        let target = solo_accels(victim).into_iter().max_by(|x, y| {
            let vx = value_via(catalog, cache, x.accel, j1, &Combo::Solo(j1));
            let vy = value_via(catalog, cache, y.accel, j1, &Combo::Solo(j1));
            vx.total_cmp(&vy).then(y.cmp(x))
        })?;
        let mut delta = PlacementDelta::new();
        delta.push(PlacementOp::Suspend { job: victim });
        delta.push(PlacementOp::Assign {
            accel: target,
            combo: Combo::Solo(j1),
        });
        Some(delta)
    }

    /// Free in-service instances this tick's delta does not already
    /// target (shared by the resume and elastic passes; spec order).
    fn free_untouched(&self, cluster: &Cluster, delta: &PlacementDelta) -> Vec<AccelId> {
        let taken: BTreeSet<AccelId> = delta
            .ops
            .iter()
            .filter_map(|op| match *op {
                PlacementOp::Assign { accel, .. }
                | PlacementOp::Resume { accel, .. } => Some(accel),
                PlacementOp::Migrate { to, .. } => Some(to),
                _ => None,
            })
            .collect();
        cluster
            .available_accels()
            .into_iter()
            .filter(|a| cluster.placement.combo_on(*a).is_none() && !taken.contains(a))
            .collect()
    }

    /// Resume pass, run on monitor ticks when preemption is enabled:
    /// parked jobs re-enter highest tier first (FIFO by id within a
    /// tier), each onto the free in-service instance its estimated solo
    /// throughput is best on. Resuming charges the same migration-stall
    /// penalty a live migration pays (the checkpoint must reload).
    fn resume_suspended(&self, cluster: &Cluster, delta: &mut PlacementDelta) {
        if !self.options.preemption {
            return;
        }
        let suspended = cluster.suspended_job_ids();
        if suspended.is_empty() {
            return;
        }
        let catalog = &self.catalog;
        let cache = self.options.estimate_cache.then_some(&self.cache);
        let mut free = self.free_untouched(cluster, delta);
        let mut parked: Vec<(usize, JobId)> = suspended
            .iter()
            .filter_map(|&j| cluster.job(j).map(|s| (s.priority.index(), j)))
            .collect();
        parked.sort_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
        for (_, j) in parked {
            if free.is_empty() {
                break;
            }
            let mut best: Option<(f64, usize)> = None;
            for (i, a) in free.iter().enumerate() {
                let v = value_via(catalog, cache, a.accel, j, &Combo::Solo(j));
                if best.map_or(true, |(bv, _)| v > bv) {
                    best = Some((v, i));
                }
            }
            if let Some((_, i)) = best {
                let accel = free.remove(i);
                delta.push(PlacementOp::Resume { job: j, accel });
            }
        }
    }

    /// Elastic grow/shrink of training jobs, run on monitor ticks and
    /// mirroring the replica autoscaler: an elastic job delivering
    /// under its throughput floor gains one instance on the
    /// estimated-fastest free accel (up to its distributability D_j);
    /// one still clearing `min_throughput / SCALE_DOWN_MARGIN` after
    /// dropping its weakest solo-held instance releases it
    /// (hysteresis). One action per job per tick; pure grow/shrink of
    /// an elastic job is never billed as a migration by `apply_delta`.
    fn elastic_training(&mut self, cluster: &Cluster, delta: &mut PlacementDelta) {
        let mut free = self.free_untouched(cluster, delta);
        let mut grows = 0u64;
        let mut shrinks = 0u64;
        {
            let catalog = &self.catalog;
            let cache = self.options.estimate_cache.then_some(&self.cache);
            let mut jobs: Vec<JobSpec> = cluster
                .jobs()
                .filter(|s| s.elastic && !s.is_inference())
                .cloned()
                .collect();
            jobs.sort_by_key(|s| s.id);
            for spec in &jobs {
                let accels = cluster.placement.accels_of(spec.id).to_vec();
                if accels.is_empty() {
                    continue; // unplaced or parked: other paths own it
                }
                let est_of = |aid: AccelId| {
                    let c = cluster
                        .placement
                        .combo_on(aid)
                        .copied()
                        .unwrap_or(Combo::Solo(spec.id));
                    value_via(catalog, cache, aid.accel, spec.id, &c)
                };
                let est: f64 = accels.iter().map(|a| est_of(*a)).sum();
                if est + 1e-9 < spec.min_throughput
                    && (accels.len() as u32) < spec.distributability
                {
                    // grow onto the estimated-fastest free instance
                    let mut best: Option<(f64, usize)> = None;
                    for (i, a) in free.iter().enumerate() {
                        let v =
                            value_via(catalog, cache, a.accel, spec.id, &Combo::Solo(spec.id));
                        if best.map_or(true, |(bv, _)| v > bv) {
                            best = Some((v, i));
                        }
                    }
                    if let Some((_, i)) = best {
                        let aid = free.remove(i);
                        delta.push(PlacementOp::Assign {
                            accel: aid,
                            combo: Combo::Solo(spec.id),
                        });
                        grows += 1;
                    }
                } else if accels.len() >= 2 {
                    // weakest instance this job holds solo (pairs stay)
                    let mut weakest: Option<(f64, AccelId)> = None;
                    for &aid in &accels {
                        if cluster.placement.combo_on(aid).map_or(false, |c| c.len() == 1) {
                            let v = est_of(aid);
                            let better = weakest.map_or(true, |(wv, waid)| {
                                v.total_cmp(&wv).then(aid.cmp(&waid)).is_lt()
                            });
                            if better {
                                weakest = Some((v, aid));
                            }
                        }
                    }
                    if let Some((wv, victim)) = weakest {
                        if est - wv >= spec.min_throughput / SCALE_DOWN_MARGIN {
                            delta.push(PlacementOp::Evict { accel: victim });
                            shrinks += 1;
                            free.push(victim);
                        }
                    }
                }
            }
        }
        self.elastic_grows += grows;
        self.elastic_shrinks += shrinks;
    }

    /// Power knobs at simulated time `now`: DVFS enable from the
    /// options, carbon weight sampled off the diurnal signal (1.0
    /// without one).
    fn power_knobs(&self, now: f64) -> PowerKnobs {
        PowerKnobs {
            dvfs: self.options.power_dvfs,
            carbon_weight: self.options.carbon.map_or(1.0, |c| c.weight(now)),
        }
    }

    /// DVFS governor, run on every monitor tick after the autoscaler:
    /// appends cheap [`PlacementOp::SetPowerState`] ops (no migration)
    /// for in-service accelerators whose cost-optimal state differs
    /// from the current one.
    ///
    /// * **idle** instances drop to [`PowerState::Low`] — pure
    ///   idle-power savings with no throughput at stake;
    /// * **occupied** instances take the state minimizing the same
    ///   carbon-weighted column cost the ILP prices, except that `Low`
    ///   is skipped when the 0.70× frequency would push any hosted
    ///   job's estimated throughput under its floor, and combos hosting
    ///   inference jobs never run below nominal frequency (serving
    ///   latency is priced off nominal service rates).
    ///
    /// The ops ride the autoscale delta through the same transactional
    /// `apply_delta` (and the engine's power-cap trim) as every other
    /// decision. Accelerators that delta already touches are left alone
    /// this tick — their occupancy is about to change.
    fn power_governor(&self, cluster: &Cluster, delta: &mut PlacementDelta) {
        if !self.options.power_dvfs {
            return;
        }
        let knobs = self.power_knobs(cluster.now());
        let bonus = self.options.optimizer.throughput_bonus;
        let touched: BTreeSet<AccelId> = delta
            .ops
            .iter()
            .flat_map(|op| match *op {
                PlacementOp::Assign { accel, .. }
                | PlacementOp::Evict { accel }
                | PlacementOp::SetPowerState { accel, .. }
                | PlacementOp::Resume { accel, .. } => vec![accel],
                PlacementOp::Migrate { from, to, .. } => vec![from, to],
                // the instances a Suspend clears are not known until the
                // delta applies; they idle one tick and the governor
                // re-states them on the next
                PlacementOp::Suspend { .. } => vec![],
            })
            .collect();
        let catalog = &self.catalog;
        let cache = self.options.estimate_cache.then_some(&self.cache);
        for aid in cluster.available_accels() {
            if touched.contains(&aid) {
                continue;
            }
            let want = match cluster.placement.combo_on(aid) {
                None => PowerState::Low,
                Some(combo) => {
                    let ests: Vec<(JobId, f64)> = combo
                        .jobs()
                        .iter()
                        .map(|&j| (j, value_via(catalog, cache, aid.accel, j, combo)))
                        .collect();
                    let total_t: f64 = ests.iter().map(|&(_, v)| v).sum();
                    let solo = aid.accel.base_speed() / AccelType::V100.base_speed();
                    let u = (total_t / solo.max(1e-9)).clamp(0.0, 1.0);
                    let hosts_serving = ests
                        .iter()
                        .any(|&(j, _)| cluster.job(j).map_or(false, |s| s.is_inference()));
                    let safe = |s: PowerState| {
                        if hosts_serving && s.freq_scalar() < 1.0 {
                            return false;
                        }
                        ests.iter().all(|&(j, v)| {
                            cluster.job(j).map_or(true, |spec| {
                                s.freq_scalar() * v + 1e-9 >= spec.min_throughput
                            })
                        })
                    };
                    let mut best = PowerState::Nominal;
                    let mut best_cost =
                        state_cost(aid.accel, best, u, total_t, bonus, knobs.carbon_weight);
                    for s in [PowerState::Low, PowerState::Turbo] {
                        if !safe(s) {
                            continue;
                        }
                        let c = state_cost(aid.accel, s, u, total_t, bonus, knobs.carbon_weight);
                        if c < best_cost - 1e-12 {
                            best = s;
                            best_cost = c;
                        }
                    }
                    best
                }
            };
            if want != cluster.power_state(aid) {
                delta.push(PlacementOp::SetPowerState { accel: aid, state: want });
            }
        }
    }

    /// Full Problem-1 re-solve over every active job (the escape hatch,
    /// the pre-redesign behaviour, and — when sharded — the periodic
    /// cross-shard rebalance), returned as a delta.
    fn full_allocate(&mut self, cluster: &Cluster) -> Result<Decision> {
        // carbon weight is time-varying: refresh before every re-solve
        self.opt.power = self.power_knobs(cluster.now());
        let catalog = &self.catalog;
        let cache = self.options.estimate_cache.then_some(&self.cache);
        let thr = move |a: AccelType, j: JobId, c: &Combo| value_via(catalog, cache, a, j, c);
        let (mut placement, _sol) = self.opt.allocate(cluster, &thr)?;
        // active exploration (see GoghOptions::exploration_epsilon)
        if self.options.exploration_epsilon > 0.0
            && self.rng.bool(self.options.exploration_epsilon)
        {
            self.explore(cluster, &mut placement);
        }
        self.events_since_full = 0;
        // Suspend-transform (preemption mode): still-active jobs the new
        // allocation drops — typically low-tier work shed by the
        // tier-weighted slack — are parked instead of silently evicted,
        // so their progress survives until the resume pass or a later
        // re-solve lets them back in. The Suspends run first (a Suspend
        // requires the job to still be placed); the remaining diff is
        // computed against the post-suspend placement.
        if self.options.preemption {
            let dropped: Vec<JobId> = cluster
                .active_job_ids()
                .into_iter()
                .filter(|&j| cluster.placement.is_placed(j) && !placement.is_placed(j))
                .collect();
            if !dropped.is_empty() {
                let mut base = cluster.placement.clone();
                let mut delta = PlacementDelta::new();
                for j in dropped {
                    delta.push(PlacementOp::Suspend { job: j });
                    base.remove_job(j);
                }
                delta.ops.extend(PlacementDelta::diff(&base, &placement).ops);
                return Ok(Decision::apply(delta));
            }
        }
        Ok(Decision::replace(&cluster.placement, &placement))
    }

    /// Unsharded bounded local re-solve for one arrival (the P = 1
    /// decision path, bit-for-bit the pre-shard behaviour). Returns
    /// `None` whenever the local problem is not cleanly solvable
    /// (caller falls back to the full re-solve).
    fn incremental_arrival(
        &mut self,
        cluster: &Cluster,
        j1: JobId,
    ) -> Result<Option<PlacementDelta>> {
        if self.options.neighborhood == 0 {
            return Ok(None);
        }
        // older unplaced jobs need global capacity — go full (parked
        // jobs don't count: the resume pass owns them)
        let active = cluster.active_job_ids();
        if active
            .iter()
            .any(|&j| j != j1 && !cluster.placement.is_placed(j) && !cluster.is_suspended(j))
        {
            return Ok(None);
        }
        let ls = local_arrival_solve(
            &self.catalog,
            self.options.estimate_cache.then_some(&self.cache),
            cluster,
            j1,
            None,
            self.options.neighborhood,
            &self.options.optimizer,
            self.power_knobs(cluster.now()),
            // no basis chaining on the P = 1 path: it stays bit-for-bit
            // the pre-shard behaviour
            None,
        );
        self.record_local_solve(0, &ls);
        Ok(ls.delta)
    }

    fn record_local_solve(&mut self, shard: usize, ls: &LocalSolve) {
        if !ls.attempted {
            return;
        }
        self.inc_seconds += ls.seconds;
        self.inc_solves += 1;
        self.inc_nodes += ls.nodes;
        if let Some(s) = self.shard_stats.get_mut(shard) {
            s.solves += 1;
            s.nodes += ls.nodes;
            s.seconds += ls.seconds;
        }
    }

    /// Recompute the cached two-level topology if the spec or the
    /// group/shard counts changed (within one run they never do — this
    /// is a lazy init).
    fn refresh_topology(&mut self, cluster: &Cluster) {
        let g = self.options.topology_groups;
        let p = self.options.shards;
        let stale = self.topology.as_ref().map_or(true, |c| {
            c.groups != g || c.per_group != p || c.spec != cluster.spec.accels
        });
        if stale {
            self.topology = Some(CachedTopology {
                spec: cluster.spec.accels.clone(),
                groups: g,
                per_group: p,
                topo: cluster.spec.topology(g, p),
            });
        }
    }

    /// Top-level router: score every shard-group by the cheapest
    /// catalog-only solo column cost of hosting `j1` on a *free*
    /// in-service instance of the group (no LP runs here — this is
    /// O(fleet) arithmetic, not solver work). Ties break toward the
    /// lower group index. `None` when no group has a free instance —
    /// the caller then fans across every shard, since only a local
    /// repack can host the arrival.
    fn route_group(&self, cluster: &Cluster, j1: JobId) -> Option<usize> {
        let part = self.topology.as_ref()?;
        let cache = self.options.estimate_cache.then_some(&self.cache);
        let ocfg = &self.options.optimizer;
        let power = self.power_knobs(cluster.now());
        let solo_cap = |a: AccelType| a.base_speed() / AccelType::V100.base_speed();
        let free: BTreeSet<AccelId> = cluster
            .available_accels()
            .into_iter()
            .filter(|aid| cluster.placement.combo_on(*aid).is_none())
            .collect();
        let mut best: Option<(f64, usize)> = None;
        for g in &part.topo.groups {
            let types: BTreeSet<AccelType> =
                g.accels.iter().filter(|a| free.contains(a)).map(|a| a.accel).collect();
            let mut score = f64::INFINITY;
            for a in types {
                let t = value_via(&self.catalog, cache, a, j1, &Combo::Solo(j1));
                let u = (t / solo_cap(a).max(1e-9)).clamp(0.0, 1.0);
                let c = crate::power::column_cost(a, u, t, ocfg.throughput_bonus, power);
                if c < score {
                    score = c;
                }
            }
            if score.is_finite() && best.map_or(true, |(s, _)| score < s) {
                best = Some((score, g.index));
            }
        }
        best.map(|(_, i)| i)
    }

    /// Fan one arrival out to shard workers on scoped threads and route
    /// it to the shard whose local solve has the lowest marginal energy
    /// (deterministic: ties break toward the lower global shard index).
    /// With topology groups, the top-level router first picks the
    /// cheapest group and only its shards solve. Returns the winning
    /// (global shard index, delta) — the caller bumps that shard's
    /// `routed` count only when the delta is actually committed (a
    /// multi-straggler batch may abort to the full re-solve; the
    /// solve/node counters still record work genuinely performed).
    fn sharded_arrival_once(
        &mut self,
        cluster: &Cluster,
        j1: JobId,
    ) -> Result<Option<(usize, PlacementDelta)>> {
        self.refresh_topology(cluster);
        let n_shards = self.topology.as_ref().map_or(1, |c| c.topo.total_shards());
        if self.shard_stats.len() < n_shards {
            self.shard_stats.resize(n_shards, ShardStats::default());
        }
        if self.shard_bases.len() < n_shards {
            self.shard_bases.resize(n_shards, None);
        }
        let route = self
            .topology
            .as_ref()
            .filter(|c| c.topo.groups.len() > 1)
            .and_then(|_| self.route_group(cluster, j1));
        let solves: Vec<(usize, LocalSolve)> = {
            let part = self.topology.as_ref().expect("topology refreshed");
            let targets: Vec<(usize, &ShardSpec, &BTreeSet<AccelId>)> = part
                .topo
                .shards()
                .filter(|(g, _, _)| route.map_or(true, |r| g.index == r))
                .map(|(_, s, set)| (s.index, s, set))
                .collect();
            let hints: Vec<ColumnBasis> = targets
                .iter()
                .map(|(gi, _, _)| self.shard_bases[*gi].clone().unwrap_or_default())
                .collect();
            let catalog = &self.catalog;
            let cache = self.options.estimate_cache.then_some(&self.cache);
            let k = self.options.neighborhood;
            let ocfg = &self.options.optimizer;
            let power = self.power_knobs(cluster.now());
            // Scoped threads let workers borrow the catalog/cache
            // directly (a persistent pool would need 'static captures
            // or unsafe lifetime erasure); the per-arrival spawn cost
            // (~tens of µs × P) is small against the local ILP solves,
            // but it IS the fixed overhead of the sharded path — if the
            // scale bench margin ever thins, a channel-fed worker pool
            // over Arc snapshots is the next step.
            std::thread::scope(|scope| {
                let handles: Vec<_> = targets
                    .iter()
                    .zip(&hints)
                    .map(|(&(gi, shard, set), hint)| {
                        scope.spawn(move || {
                            let ls = local_arrival_solve(
                                catalog,
                                cache,
                                cluster,
                                j1,
                                Some((shard, set)),
                                k,
                                ocfg,
                                power,
                                Some(hint),
                            );
                            (gi, ls)
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| h.join().expect("shard worker panicked"))
                    .collect()
            })
        };
        // persist exported bases for the next arrival on each shard
        for (gi, ls) in &solves {
            if let Some(b) = &ls.basis {
                self.shard_bases[*gi] = Some(b.clone());
            }
        }
        let mut best: Option<usize> = None;
        for (i, (_, ls)) in solves.iter().enumerate() {
            if ls.delta.is_some() && best.map_or(true, |b| ls.marginal < solves[b].1.marginal) {
                best = Some(i);
            }
        }
        for (gi, ls) in &solves {
            self.record_local_solve(*gi, ls);
        }
        let Some(b) = best else { return Ok(None) };
        let gi = solves[b].0;
        let mut solves = solves;
        Ok(solves.swap_remove(b).1.delta.map(|d| (gi, d)))
    }

    /// Route every currently-unplaced job through the shard workers.
    /// The common single-job case (a fresh arrival) solves directly
    /// against the live cluster; with several stragglers the jobs go one
    /// at a time against a scratch clone so later placements see earlier
    /// ones. Covers fresh arrivals, churn-evicted jobs and queued jobs
    /// unblocked by a departure. Returns `None` — caller falls back to
    /// the full re-solve — as soon as any job has no feasible shard.
    fn sharded_place_unplaced(&mut self, cluster: &Cluster) -> Result<Option<PlacementDelta>> {
        if self.options.neighborhood == 0 {
            return Ok(None);
        }
        let unplaced: Vec<JobId> = cluster
            .active_job_ids()
            .into_iter()
            .filter(|&j| !cluster.placement.is_placed(j) && !cluster.is_suspended(j))
            .collect();
        match unplaced.as_slice() {
            [] => Ok(Some(PlacementDelta::new())),
            // common case (one fresh arrival): no scratch clone needed
            &[j] => Ok(self.sharded_arrival_once(cluster, j)?.map(|(b, delta)| {
                self.shard_stats[b].routed += 1;
                delta
            })),
            _ => {
                let mut scratch = cluster.clone();
                let mut combined = PlacementDelta::new();
                // routed counts commit only if the whole batch lands
                let mut routed_to: Vec<usize> = vec![];
                for j in unplaced {
                    match self.sharded_arrival_once(&scratch, j)? {
                        Some((b, delta)) => {
                            scratch.apply_delta(&delta)?;
                            combined.ops.extend(delta.ops);
                            routed_to.push(b);
                        }
                        None => return Ok(None),
                    }
                }
                for b in routed_to {
                    self.shard_stats[b].routed += 1;
                }
                Ok(Some(combined))
            }
        }
    }

    /// Whether any *placed* job's estimated delivered throughput is
    /// below its SLO — the repair signal for the sharded churn path: a
    /// distributed job can lose one of its instances to an `AccelDown`
    /// and remain "placed" (so no shard worker ever revisits it) while
    /// under-delivering. Cheap (O(active × D_j) catalog lookups) and
    /// only consulted on churn events.
    fn any_estimated_slo_gap(&self, cluster: &Cluster) -> bool {
        cluster.jobs().any(|spec| {
            let j = spec.id;
            let accels = cluster.placement.accels_of(j);
            if accels.is_empty() {
                return false; // unplaced jobs are re-placed shard-locally
            }
            let est: f64 = accels
                .iter()
                .map(|aid| {
                    let c = cluster
                        .placement
                        .combo_on(*aid)
                        .copied()
                        .unwrap_or(Combo::Solo(j));
                    catalog_value(&self.catalog, aid.accel, j, &c)
                })
                .sum();
            est + 1e-9 < spec.min_throughput
        })
    }

    /// The sharded fallback ladder shared by every non-tick event arm:
    /// shard-local placement of whatever is unplaced while the periodic
    /// re-solve is not yet due; the global re-solve otherwise (and
    /// whenever any job has no feasible shard) — it remains the
    /// cross-shard rebalance, including onto capacity an `AccelUp` just
    /// returned.
    fn sharded_or_full(&mut self, cluster: &Cluster) -> Result<Decision> {
        if self.events_since_full < self.options.full_resolve_every.max(1) {
            if let Some(delta) = self.sharded_place_unplaced(cluster)? {
                return Ok(Decision::apply(delta));
            }
        }
        self.full_allocate(cluster)
    }

    /// Monitoring round: score estimates, record measurements, run P2
    /// refinement and take online training steps.
    fn on_monitor_tick(&mut self, measurements: &[Measurement]) -> Result<()> {
        self.round += 1;
        // attribution for the learning stats: serving measurements flow
        // through the catalog → P2 exactly like training ones
        self.inference_measurements += measurements
            .iter()
            .filter(|m| self.inference_jobs.contains(&m.job))
            .count() as u64;
        // score pre-measurement estimates, then record measurements
        for m in measurements {
            let key = EstimateKey {
                accel: m.accel.accel,
                job: m.job,
                combo: m.combo,
            };
            if let Some(rec) = self.catalog.record(&key) {
                if !rec.is_measured() {
                    if let Some(est) = rec.estimate_only() {
                        self.errors.push(est, m.throughput);
                    }
                }
            }
            self.catalog.record_measurement(key, m.throughput);
        }
        // P2 refinement toward unobserved accel types (Eq. 3/4), via
        // whichever backend is mounted (PJRT or native); estimator-free
        // mode keeps measurements and skips the transfer
        if self.options.enable_refinement {
            if let Some(p2) = self.p2.as_deref_mut() {
                let applied =
                    refinement::refine_round(&mut self.catalog, p2, measurements, self.round)?;
                if applied > 0 {
                    self.refine_rounds += 1;
                }
            }
        }
        // continuous learning
        if self.options.estimator.online_steps_per_round > 0
            && !measurements.is_empty()
            && (self.p1.is_some() || self.p2.is_some())
        {
            self.harvest_samples(measurements);
            for _ in 0..self.options.estimator.online_steps_per_round {
                self.train_once()?;
            }
        }
        // Measurements + refinements mutated the estimate matrix — but
        // only rows touching the measured jobs and their co-runners
        // (round recording and P2 transfer both write under those jobs'
        // keys): a targeted drop keeps the rest of the memoized matrix
        // warm across rounds instead of the old O(entire cache) flush.
        if !measurements.is_empty() {
            let mut stale: BTreeSet<JobId> = BTreeSet::new();
            for m in measurements {
                stale.insert(m.job);
                for j in m.combo.jobs() {
                    stale.insert(j);
                }
            }
            for j in stale {
                self.cache.drop_job(j);
            }
            // the full-resolve builder's stored pair scores read the
            // same estimates: rescore at the next solve
            self.opt.note_estimates_changed();
        }
        Ok(())
    }
}

impl Scheduler for GoghScheduler {
    fn name(&self) -> &str {
        "gogh"
    }

    fn on_event(&mut self, event: &ClusterEvent, cluster: &Cluster) -> Result<Decision> {
        let sharded = self.options.shards > 1 || self.options.topology_groups > 1;
        match event {
            ClusterEvent::JobArrived { job } => {
                // round-0 estimates for any job we haven't seen
                for j in cluster.active_job_ids() {
                    if !self.initialized.contains(&j) {
                        self.initial_estimates(cluster, j)?;
                    }
                }
                self.events_since_full += 1;
                if sharded {
                    return self.sharded_or_full(cluster);
                }
                if self.events_since_full < self.options.full_resolve_every.max(1) {
                    if let Some(delta) = self.incremental_arrival(cluster, *job)? {
                        return Ok(Decision::apply(delta));
                    }
                    // capacity tight: park a lower-tier victim before
                    // paying for the global re-solve
                    if let Some(delta) = self.preempt_for_arrival(cluster, *job) {
                        return Ok(Decision::apply(delta));
                    }
                }
                self.full_allocate(cluster)
            }
            ClusterEvent::JobCompleted { job } | ClusterEvent::JobCancelled { job } => {
                // departures free capacity in place (co-runners are
                // re-hosted solo); compaction happens on the periodic
                // full re-solve. Queued (unplaced) jobs force a re-solve
                // now — the freed capacity may be their only chance to
                // run before the event stream dries up.
                // Estimates for the departed job (and for pairings with
                // it) are dead: evict them so the matrix stays O(active)
                // instead of O(every job ever seen).
                self.catalog.evict_job_estimates(*job);
                self.cache.drop_job(*job);
                self.inference_jobs.remove(job);
                self.events_since_full += 1;
                if cluster.n_jobs() == 0 {
                    return Ok(Decision::none());
                }
                let unplaced = cluster
                    .active_job_ids()
                    .iter()
                    .any(|&j| !cluster.placement.is_placed(j) && !cluster.is_suspended(j));
                if unplaced && sharded {
                    // sharded: place the stragglers locally before
                    // resorting to the global re-solve
                    return self.sharded_or_full(cluster);
                }
                if unplaced || self.events_since_full >= self.options.full_resolve_every.max(1) {
                    return self.full_allocate(cluster);
                }
                Ok(Decision::none())
            }
            ClusterEvent::AccelDown { .. } | ClusterEvent::AccelUp { .. } => {
                // capacity changed (possibly stranding evicted jobs)
                self.events_since_full += 1;
                if cluster.n_jobs() == 0 {
                    return Ok(Decision::none());
                }
                if sharded {
                    // shard-local re-placement of whatever the churn
                    // stranded (a 1000-accel global ILP per churn event
                    // is exactly what sharding avoids) — but a partially
                    // evicted distributed job stays "placed" while
                    // under-delivering its SLO, and only the global
                    // re-solve can restore its cross-shard coverage
                    if self.any_estimated_slo_gap(cluster) {
                        return self.full_allocate(cluster);
                    }
                    return self.sharded_or_full(cluster);
                }
                self.full_allocate(cluster)
            }
            ClusterEvent::MonitorTick { measurements } => {
                self.on_monitor_tick(measurements)?;
                // fresh measurements (and refinements) just landed:
                // react to measured serving latency with replica
                // scaling, then let the DVFS governor re-state whatever
                // the autoscaler left alone
                let mut delta = self.autoscale(cluster);
                // parked jobs re-enter before elastic growth competes
                // for the same free instances
                self.resume_suspended(cluster, &mut delta);
                self.elastic_training(cluster, &mut delta);
                self.power_governor(cluster, &mut delta);
                Ok(Decision::apply(delta))
            }
        }
    }

    fn estimation_mae(&self) -> Option<f64> {
        (self.errors.n() > 0).then(|| self.errors.mae())
    }

    fn autoscale_counts(&self) -> (u64, u64) {
        (self.scale_ups, self.scale_downs)
    }

    fn decision_latencies(&self) -> (f64, f64) {
        let solves = self.opt.solves + self.inc_solves;
        let solve_ms = if solves == 0 {
            0.0
        } else {
            1000.0 * (self.opt.solve_seconds + self.inc_seconds) / solves as f64
        };
        let p1_ms = if self.p1_calls == 0 {
            0.0
        } else {
            1000.0 * self.p1_seconds / self.p1_calls as f64
        };
        (solve_ms, p1_ms)
    }
}

/// The full GOGH system: backend + scheduler + simulator from one
/// config.
pub struct Gogh {
    driver: SimDriver,
    scheduler: GoghScheduler,
    /// which estimator backend actually got mounted ("pjrt" / "native"
    /// / "none") — may differ from the configured kind under `auto`.
    backend: &'static str,
}

impl Gogh {
    /// Build the system the config describes, resolving
    /// `cfg.gogh.backend`:
    ///
    /// * `pjrt` — requires loadable AOT artifacts; a missing artifact
    ///   dir is a hard error (no silent fallback).
    /// * `native` — the pure-Rust MLP backend, zero artifacts.
    /// * `none` — estimator-free (catalog priors + measurements only).
    /// * `auto` — the fallback ladder pjrt → native → none, logging a
    ///   warning that names the backend actually used (native init is
    ///   infallible, so the terminal `none` rung is never reached in
    ///   practice).
    pub fn from_config(cfg: &ExperimentConfig) -> Result<Self> {
        Self::builder(cfg).build()
    }

    /// Start building a system over `cfg`, overriding the backend with
    /// the builder's methods (the one construction path behind
    /// [`Gogh::from_config`], [`Gogh::with_engine`], [`Gogh::with_native`]
    /// and [`Gogh::without_engine`], which remain as thin shorthands).
    pub fn builder(cfg: &ExperimentConfig) -> GoghBuilder<'_> {
        GoghBuilder {
            cfg,
            engine: None,
            backend: None,
        }
    }

    /// Build reusing an existing engine (benches construct many systems).
    pub fn with_engine(engine: &Engine, cfg: &ExperimentConfig) -> Result<Self> {
        Self::builder(cfg).with_engine(engine).build()
    }

    /// Build over the native pure-Rust backend (see
    /// [`GoghScheduler::with_native_backend`]): the full learning loop
    /// with zero external artifacts.
    pub fn with_native(cfg: &ExperimentConfig) -> Result<Self> {
        Self::builder(cfg).native().build()
    }

    /// Build without any estimator: the estimator-free degraded mode
    /// (see [`GoghScheduler::without_engine`]).
    pub fn without_engine(cfg: &ExperimentConfig) -> Result<Self> {
        Self::builder(cfg).estimator_free().build()
    }

    /// The estimator backend actually mounted ("pjrt" / "native" /
    /// "none") — under `auto` this names the fallback that won.
    pub fn backend_name(&self) -> &'static str {
        self.backend
    }

    fn build_driver(cfg: &ExperimentConfig) -> Result<(SimDriver, ThroughputOracle)> {
        let oracle = cfg.build_oracle()?;
        let trace = Trace::generate(&cfg.trace, &oracle);
        let spec = ClusterSpec::mix(&cfg.cluster.accel_mix);
        // monitor_interval_s is validated (once) by SimDriver::new
        let driver = SimDriver::new(
            spec,
            oracle.clone(),
            trace,
            cfg.noise_sigma,
            cfg.monitor_interval_s,
            cfg.seed,
        )?
        .with_options(
            EngineOptions::new()
                .with_migration_cost(cfg.migration_cost_s)
                .with_power_cap(cfg.power.cap_w)
                .with_carbon(cfg.power.carbon.signal()),
        );
        Ok((driver, oracle))
    }

    /// Run the configured trace to completion.
    pub fn run(&mut self) -> Result<RunReport> {
        self.driver.run(&mut self.scheduler)
    }

    pub fn scheduler(&self) -> &GoghScheduler {
        &self.scheduler
    }

    pub fn scheduler_mut(&mut self) -> &mut GoghScheduler {
        &mut self.scheduler
    }
}

/// Builder behind [`Gogh::builder`]: one construction path instead of
/// the `from_config` / `with_engine` / `with_native` / `without_engine`
/// constructor zoo (mirroring [`EngineOptions`]' chained style). With
/// no override, `cfg.gogh.backend` resolves through the usual ladder.
pub struct GoghBuilder<'a> {
    cfg: &'a ExperimentConfig,
    engine: Option<&'a Engine>,
    backend: Option<crate::config::BackendKind>,
}

impl<'a> GoghBuilder<'a> {
    /// Mount the P1/P2 estimators from an already-loaded PJRT engine
    /// (benches construct many systems over one engine). Takes
    /// precedence over any backend override.
    pub fn with_engine(mut self, engine: &'a Engine) -> Self {
        self.engine = Some(engine);
        self
    }

    /// Force the native pure-Rust estimator backend, whatever the
    /// config says.
    pub fn native(mut self) -> Self {
        self.backend = Some(crate::config::BackendKind::Native);
        self
    }

    /// Force the estimator-free degraded mode (catalog priors +
    /// measurements only), whatever the config says.
    pub fn estimator_free(mut self) -> Self {
        self.backend = Some(crate::config::BackendKind::None);
        self
    }

    pub fn build(self) -> Result<Gogh> {
        let (driver, oracle) = Gogh::build_driver(self.cfg)?;
        if let Some(engine) = self.engine {
            let options = GoghOptions::from_config(self.cfg);
            let scheduler = GoghScheduler::new(engine, &oracle, options)?;
            return Ok(Gogh {
                driver,
                scheduler,
                backend: "pjrt",
            });
        }
        let overridden;
        let cfg = match self.backend {
            Some(kind) => {
                let mut c = self.cfg.clone();
                c.gogh.backend = kind;
                overridden = c;
                &overridden
            }
            None => self.cfg,
        };
        let (scheduler, backend) = build_scheduler(cfg, &oracle)?;
        Ok(Gogh {
            driver,
            scheduler,
            backend,
        })
    }
}

/// Resolve `cfg.gogh.backend` into a ready [`GoghScheduler`] — the
/// fallback ladder behind [`Gogh::from_config`], shared with the `goghd`
/// daemon (which owns a [`crate::engine::GoghCore`] instead of a
/// [`SimDriver`]). Returns the scheduler plus the backend name actually
/// mounted ("pjrt" / "native" / "none").
pub fn build_scheduler(
    cfg: &ExperimentConfig,
    oracle: &ThroughputOracle,
) -> Result<(GoghScheduler, &'static str)> {
    let options = GoghOptions::from_config(cfg);
    match cfg.gogh.backend {
        crate::config::BackendKind::Pjrt => {
            let engine = Engine::load(&cfg.estimator.artifacts_dir).map_err(|e| {
                anyhow::anyhow!(
                    "backend pjrt requested but the PJRT engine failed to load from {:?} \
                     ({e}); build artifacts with `make artifacts` or use --backend native",
                    cfg.estimator.artifacts_dir
                )
            })?;
            Ok((GoghScheduler::new(&engine, oracle, options)?, "pjrt"))
        }
        crate::config::BackendKind::Native => {
            Ok((GoghScheduler::with_native_backend(oracle, options)?, "native"))
        }
        crate::config::BackendKind::None => {
            Ok((GoghScheduler::without_engine(oracle, options)?, "none"))
        }
        crate::config::BackendKind::Auto => match Engine::load(&cfg.estimator.artifacts_dir) {
            Ok(engine) => Ok((GoghScheduler::new(&engine, oracle, options)?, "pjrt")),
            Err(err) => {
                crate::log_warn!(
                    "PJRT engine unavailable ({err}); using the native pure-Rust \
                     estimator backend instead"
                );
                Ok((GoghScheduler::with_native_backend(oracle, options)?, "native"))
            }
        },
    }
}
