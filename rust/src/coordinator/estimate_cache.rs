//! Memoized estimate matrix for the decision path.
//!
//! [`super::refinement::catalog_value`] is pure given a Catalog
//! snapshot, but the solver evaluates it on every branch-and-bound
//! node: pair scoring, column builds and instance binding re-resolve the
//! same (accelerator type, job, combination) keys thousands of times per
//! decision. The cache stores each resolved value until a catalog
//! mutation invalidates it: monitoring rounds (measurement batches + P2
//! refinements) clear the whole matrix, while job-scoped mutations
//! (round-0 estimate writes, departures) drop only the involved job's
//! keys — so the hot path resolves each key once per round instead of
//! once per solver node.
//!
//! The cache is shared by the shard workers of the parallel arrival path
//! (an `RwLock` guards the map — hits dominate after warm-up, so workers
//! mostly take the shared read path; values are deterministic, so
//! concurrent insertion order cannot change results) and is strictly
//! value-transparent: a hit returns exactly what `catalog_value` would.

// gogh-lint: allow(determinism-hash-container, import for the lookup-only memo below)
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::RwLock;

use crate::catalog::{Catalog, EstimateKey};
use crate::coordinator::refinement::catalog_value;
use crate::workload::{AccelType, Combo, JobId};

/// Map + reverse index, guarded together. The per-job index keeps
/// [`EstimateCache::drop_job`] O(own keys) — a whole-map retain per
/// arrival/departure would reintroduce the quadratic scan this PR
/// removed from the Catalog. A pair key lands in both jobs' lists;
/// entries whose key was already removed are skipped on drop.
#[derive(Debug, Default)]
struct CacheInner {
    // gogh-lint: allow(determinism-hash-container, lookup-only memo; never iterated, O(1) probes are why the cache exists)
    map: HashMap<EstimateKey, f64>,
    // gogh-lint: allow(determinism-hash-container, reverse index probed per job id; drained via its Vec values, never iterated)
    by_job: HashMap<JobId, Vec<EstimateKey>>,
}

/// Shared memo of resolved `catalog_value` lookups.
#[derive(Debug, Default)]
pub struct EstimateCache {
    inner: RwLock<CacheInner>,
    hits: AtomicU64,
    misses: AtomicU64,
    invalidations: AtomicU64,
}

/// Counters for the §Perf report.
#[derive(Debug, Clone, Copy, Default)]
pub struct EstimateCacheStats {
    pub hits: u64,
    pub misses: u64,
    /// rounds the matrix was cleared (catalog mutations)
    pub invalidations: u64,
    pub entries: usize,
}

impl EstimateCacheStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

impl EstimateCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Resolve (a, j, c), memoizing the result until the next
    /// [`EstimateCache::invalidate`].
    pub fn value(&self, catalog: &Catalog, a: AccelType, j: JobId, c: &Combo) -> f64 {
        let key = EstimateKey {
            accel: a,
            job: j,
            combo: *c,
        };
        if let Some(v) = self.inner.read().unwrap().map.get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return *v;
        }
        // compute outside any lock (the resolution is the expensive
        // part); a racing worker computing the same key inserts the
        // same deterministic value
        let v = catalog_value(catalog, a, j, c);
        self.misses.fetch_add(1, Ordering::Relaxed);
        let mut inner = self.inner.write().unwrap();
        if inner.map.insert(key, v).is_none() {
            for job in key.combo.jobs() {
                inner.by_job.entry(job).or_default().push(key);
            }
        }
        v
    }

    /// Clear the whole matrix. Called after catalog mutations that may
    /// touch many jobs at once (a monitoring round's measurement batch +
    /// P2 refinements); job-scoped mutations use [`EstimateCache::drop_job`]
    /// instead. The coordinator owns that discipline.
    pub fn invalidate(&self) {
        let mut inner = self.inner.write().unwrap();
        inner.map.clear();
        inner.by_job.clear();
        self.invalidations.fetch_add(1, Ordering::Relaxed);
    }

    /// Drop the cached keys involving one job — used when a job departs
    /// (its estimates can never be queried again) and after round-0
    /// estimate writes for an arrival (which only touch combos
    /// containing it). O(own keys) via the reverse index.
    pub fn drop_job(&self, j: JobId) {
        let mut inner = self.inner.write().unwrap();
        let Some(keys) = inner.by_job.remove(&j) else {
            return;
        };
        for key in keys {
            inner.map.remove(&key);
        }
    }

    pub fn stats(&self) -> EstimateCacheStats {
        EstimateCacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            invalidations: self.invalidations.load(Ordering::Relaxed),
            entries: self.inner.read().unwrap().map.len(),
        }
    }
}

/// Resolve through the cache when one is plumbed, else directly — the
/// single call-site helper the decision path funnels through.
pub(crate) fn value_via(
    catalog: &Catalog,
    cache: Option<&EstimateCache>,
    a: AccelType,
    j: JobId,
    c: &Combo,
) -> f64 {
    match cache {
        Some(cache) => cache.value(catalog, a, j, c),
        None => catalog_value(catalog, a, j, c),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(j: u32) -> (AccelType, JobId, Combo) {
        (AccelType::V100, JobId(j), Combo::Solo(JobId(j)))
    }

    #[test]
    fn cache_is_value_transparent() {
        let mut catalog = Catalog::new();
        let cache = EstimateCache::new();
        let (a, j, c) = key(1);
        catalog.write_initial(
            EstimateKey {
                accel: a,
                job: j,
                combo: c,
            },
            0.42,
        );
        for _ in 0..3 {
            assert_eq!(cache.value(&catalog, a, j, &c), catalog_value(&catalog, a, j, &c));
        }
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.entries), (2, 1, 1));
        assert!((s.hit_rate() - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn invalidation_tracks_catalog_mutations() {
        let mut catalog = Catalog::new();
        let cache = EstimateCache::new();
        let (a, j, c) = key(2);
        let ek = EstimateKey {
            accel: a,
            job: j,
            combo: c,
        };
        catalog.write_initial(ek, 0.3);
        assert_eq!(cache.value(&catalog, a, j, &c), 0.3);
        // a refinement changes the average: without invalidation the
        // cache would (deliberately) serve the stale 0.3 until the round
        // boundary clears it
        catalog.push_refinement(ek, 0.5, 1);
        assert_eq!(cache.value(&catalog, a, j, &c), 0.3);
        cache.invalidate();
        assert_eq!(cache.value(&catalog, a, j, &c), 0.4);
        assert_eq!(cache.stats().invalidations, 1);
    }

    #[test]
    fn drop_job_evicts_all_involved_keys() {
        let catalog = Catalog::new();
        let cache = EstimateCache::new();
        let pair = Combo::pair(JobId(1), JobId(2));
        cache.value(&catalog, AccelType::K80, JobId(1), &Combo::Solo(JobId(1)));
        cache.value(&catalog, AccelType::K80, JobId(2), &pair);
        cache.value(&catalog, AccelType::K80, JobId(3), &Combo::Solo(JobId(3)));
        assert_eq!(cache.stats().entries, 3);
        cache.drop_job(JobId(1));
        // solo(1) and the pair involving 1 go; solo(3) stays
        assert_eq!(cache.stats().entries, 1);
    }

    #[test]
    fn shared_across_threads() {
        let catalog = Catalog::new();
        let cache = EstimateCache::new();
        std::thread::scope(|s| {
            for t in 0..4 {
                let cache = &cache;
                let catalog = &catalog;
                s.spawn(move || {
                    for i in 0..16 {
                        let j = JobId((t * 16 + i) % 8);
                        cache.value(catalog, AccelType::P100, j, &Combo::Solo(j));
                    }
                });
            }
        });
        let s = cache.stats();
        assert_eq!(s.entries, 8);
        assert_eq!(s.hits + s.misses, 64);
    }
}
