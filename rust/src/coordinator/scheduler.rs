//! Event-driven scheduler API + the shared discrete-event simulation
//! driver.
//!
//! Every policy (GOGH and the baselines) implements [`Scheduler`]: the
//! driver dispatches one [`ClusterEvent`] at a time (arrival,
//! completion, cancellation, monitor tick, accelerator churn) from a
//! time-ordered event queue, and the policy answers with a [`Decision`]
//! carrying an incremental [`PlacementDelta`] that the cluster validates
//! and applies atomically. The [`SimDriver`] replays a trace against a
//! policy, integrating energy, SLO deficit, migrations (with a
//! configurable restart penalty) and completion times into a
//! [`crate::metrics::RunReport`]. Using one driver for all policies is
//! what makes the e2e comparison table apples-to-apples.

use std::cmp::Ordering;
use std::collections::{BinaryHeap, HashMap};

use crate::cluster::energy::{placement_loads, EnergyMeter};
use crate::cluster::{
    AccelId, Cluster, ClusterSpec, Measurement, Monitor, Placement, PlacementDelta, PlacementOp,
};
use crate::metrics::{LatencyHistogram, RunReport};
use crate::workload::{
    serving, AccelType, Combo, JobId, JobSpec, ThroughputOracle, Trace, TraceEvent,
};
use crate::Result;

/// One event in the life of the cluster, dispatched to the policy.
///
/// State transitions (job registration, eviction on `AccelDown`) happen
/// *before* dispatch, so the policy always sees the post-event cluster
/// and only has to answer with a placement delta.
#[derive(Debug, Clone)]
pub enum ClusterEvent {
    /// `job` is registered and waiting for its first placement.
    JobArrived { job: JobId },
    /// `job` finished and was removed (a co-runner, if any, was
    /// re-hosted solo on the same instance).
    JobCompleted { job: JobId },
    /// `job` was cancelled by its owner and removed.
    JobCancelled { job: JobId },
    /// Periodic monitoring round: noisy throughput measurements of the
    /// current placement (learning schedulers refine estimates here).
    MonitorTick { measurements: Vec<Measurement> },
    /// `accel` went out of service; any jobs it hosted are now unplaced.
    AccelDown { accel: AccelId },
    /// `accel` came back into service.
    AccelUp { accel: AccelId },
}

/// A policy's answer to one event: the placement ops to apply now.
#[derive(Debug, Clone, Default)]
pub struct Decision {
    pub delta: PlacementDelta,
}

impl Decision {
    /// Change nothing.
    pub fn none() -> Self {
        Self::default()
    }

    /// Apply an explicit delta.
    pub fn apply(delta: PlacementDelta) -> Self {
        Self { delta }
    }

    /// Single-op convenience: host `combo` on `accel`.
    pub fn assign(accel: AccelId, combo: Combo) -> Self {
        Self {
            delta: PlacementDelta {
                ops: vec![PlacementOp::Assign { accel, combo }],
            },
        }
    }

    /// Compatibility shim for full-placement policies: the delta that
    /// turns `current` into `target` (unchanged instances cost nothing).
    pub fn replace(current: &Placement, target: &Placement) -> Self {
        Self {
            delta: PlacementDelta::diff(current, target),
        }
    }
}

/// A placement policy reacting to the cluster event stream.
pub trait Scheduler {
    fn name(&self) -> &str;

    /// React to one event with an incremental placement decision. The
    /// cluster already reflects the event (see [`ClusterEvent`]); the
    /// returned delta is validated and applied by the driver.
    fn on_event(&mut self, event: &ClusterEvent, cluster: &Cluster) -> Result<Decision>;

    /// Estimation MAE vs ground truth, if this scheduler estimates.
    fn estimation_mae(&self) -> Option<f64> {
        None
    }

    /// Mean decision-path latencies (solve_ms, p1_ms) for the report.
    fn decision_latencies(&self) -> (f64, f64) {
        (0.0, 0.0)
    }

    /// Replica autoscaling events this policy applied over the run, as
    /// `(scale_ups, scale_downs)`. Policies without an inference
    /// autoscaler report zeros.
    fn autoscale_counts(&self) -> (u64, u64) {
        (0, 0)
    }
}

/// Internal queue payloads (trace events + self-scheduling ticks).
#[derive(Debug, Clone)]
enum SimEvent {
    Arrival(JobSpec),
    Cancel(JobId),
    MonitorTick,
    AccelDown(AccelId),
    AccelUp(AccelId),
}

struct QueueEntry {
    at: f64,
    seq: u64,
    ev: SimEvent,
}

impl PartialEq for QueueEntry {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}
impl Eq for QueueEntry {}
impl PartialOrd for QueueEntry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for QueueEntry {
    /// `BinaryHeap` is a max-heap: earliest time pops first, ties break
    /// by insertion order (lower seq first) for determinism.
    fn cmp(&self, other: &Self) -> Ordering {
        other
            .at
            .total_cmp(&self.at)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Time-ordered event queue with deterministic FIFO tie-breaking.
#[derive(Default)]
struct EventQueue {
    heap: BinaryHeap<QueueEntry>,
    seq: u64,
}

impl EventQueue {
    fn push(&mut self, at: f64, ev: SimEvent) {
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(QueueEntry { at, seq, ev });
    }

    fn pop(&mut self) -> Option<QueueEntry> {
        self.heap.pop()
    }
}

/// Per-run bookkeeping (JCT, queueing delay, decision latency).
#[derive(Default)]
struct RunState {
    jct_sum: f64,
    arrival_time: HashMap<JobId, f64>,
    first_place: HashMap<JobId, f64>,
    queue_wait_sum: f64,
    queue_waits: usize,
    decision_s: f64,
    /// jobs evicted by an AccelDown; they pay the restart penalty when
    /// re-placed (the eviction happens outside `apply_delta`, so
    /// `DeltaOutcome::migrated_jobs` cannot see them).
    failure_evicted: std::collections::BTreeSet<JobId>,
    /// time-weighted serving-latency distribution over all inference jobs
    inf_hist: LatencyHistogram,
    /// seconds of inference serving-time inside the latency SLO
    inf_attained_s: f64,
    /// total seconds of inference serving-time observed
    inf_total_s: f64,
    /// per-job (attained, total) serving seconds, for the SLO-met count
    inf_job_time: HashMap<JobId, (f64, f64)>,
}

/// Discrete-event simulation of a trace under a policy.
pub struct SimDriver {
    pub cluster: Cluster,
    pub monitor: Monitor,
    meter_busy: EnergyMeter,
    meter_total: EnergyMeter,
    trace: Trace,
    monitor_interval_s: f64,
    /// restart penalty charged to every migrated job (seconds of stall).
    migration_cost_s: f64,
    /// max simulated seconds after the last arrival (safety stop)
    pub drain_limit_s: f64,
}

impl SimDriver {
    /// Build a driver. Fails if `monitor_interval_s` is not strictly
    /// positive — a zero interval would spin the event loop forever at
    /// t = 0 (this is the single validation point; callers must not
    /// patch the interval themselves).
    pub fn new(
        spec: ClusterSpec,
        oracle: ThroughputOracle,
        trace: Trace,
        noise_sigma: f64,
        monitor_interval_s: f64,
        seed: u64,
    ) -> Result<Self> {
        anyhow::ensure!(
            monitor_interval_s > 0.0 && monitor_interval_s.is_finite(),
            "monitor_interval_s must be > 0 (got {monitor_interval_s})"
        );
        Ok(Self {
            cluster: Cluster::new(spec),
            monitor: Monitor::new(oracle, noise_sigma, seed),
            meter_busy: EnergyMeter::new(),
            meter_total: EnergyMeter::new(),
            trace,
            monitor_interval_s,
            migration_cost_s: 0.0,
            drain_limit_s: 24.0 * 3600.0,
        })
    }

    /// Charge every migrated job `cost_s` seconds of restart stall
    /// (integrated into energy, SLO and JCT accounting).
    pub fn with_migration_cost(mut self, cost_s: f64) -> Self {
        self.migration_cost_s = cost_s.max(0.0);
        self
    }

    /// Run the full trace; returns the report.
    pub fn run(&mut self, policy: &mut dyn Scheduler) -> Result<RunReport> {
        let mut report = RunReport {
            scheduler: policy.name().to_string(),
            jobs_total: self.trace.n_jobs(),
            inference_total: self.trace.jobs().filter(|j| j.is_inference()).count(),
            ..Default::default()
        };
        let mut state = RunState::default();
        let mut queue = EventQueue::default();
        let mut arrivals_pending = 0usize;
        let mut last_arrival_t = 0.0f64;
        let n_accels = self.cluster.spec.len();
        for ev in &self.trace.events {
            match ev {
                TraceEvent::Arrival { at, job } => {
                    queue.push(*at, SimEvent::Arrival(job.clone()));
                    arrivals_pending += 1;
                    last_arrival_t = last_arrival_t.max(*at);
                }
                TraceEvent::Cancel { at, job } => queue.push(*at, SimEvent::Cancel(*job)),
                TraceEvent::AccelChurn { at, accel_index, up } if n_accels > 0 => {
                    let aid = self.cluster.spec.accels[accel_index % n_accels];
                    let ev = if *up {
                        SimEvent::AccelUp(aid)
                    } else {
                        SimEvent::AccelDown(aid)
                    };
                    queue.push(*at, ev);
                }
                TraceEvent::AccelChurn { .. } => {} // no accelerators to churn
            }
        }
        queue.push(self.monitor_interval_s, SimEvent::MonitorTick);
        // Distinct trace cycles can collide on one physical instance
        // (accel_index is taken modulo the cluster size), so outages are
        // reference-counted: an instance is down while any cycle holds it.
        let mut down_votes: HashMap<AccelId, u32> = HashMap::new();

        while let Some(entry) = queue.pop() {
            let now = self.cluster.now();
            let t = entry.at.max(now);
            // ---- integrate [now, t] (detects + dispatches completions)
            self.integrate(now, t, policy, &mut report, &mut state)?;
            self.cluster.advance_to(t);

            // ---- dispatch the event
            match entry.ev {
                SimEvent::Arrival(job) => {
                    arrivals_pending -= 1;
                    let id = job.id;
                    state.arrival_time.insert(id, t);
                    self.cluster.add_job(job);
                    let ev = ClusterEvent::JobArrived { job: id };
                    self.dispatch(policy, ev, &mut report, &mut state)?;
                }
                SimEvent::Cancel(j) => {
                    // ignore cancellations racing a completed/unknown job
                    if self.cluster.job(j).is_some() {
                        self.cluster.remove_job(j);
                        report.jobs_cancelled += 1;
                        let ev = ClusterEvent::JobCancelled { job: j };
                        self.dispatch(policy, ev, &mut report, &mut state)?;
                    }
                }
                SimEvent::MonitorTick => {
                    let measurements = self.monitor.sample(&self.cluster);
                    let ev = ClusterEvent::MonitorTick { measurements };
                    self.dispatch(policy, ev, &mut report, &mut state)?;
                    queue.push(t + self.monitor_interval_s, SimEvent::MonitorTick);
                }
                SimEvent::AccelDown(a) => {
                    let votes = down_votes.entry(a).or_insert(0);
                    *votes += 1;
                    if *votes == 1 {
                        let evicted = self.cluster.set_accel_down(a);
                        state.failure_evicted.extend(evicted);
                        let ev = ClusterEvent::AccelDown { accel: a };
                        self.dispatch(policy, ev, &mut report, &mut state)?;
                    }
                }
                SimEvent::AccelUp(a) => {
                    let votes = down_votes.entry(a).or_insert(0);
                    if *votes > 0 {
                        *votes -= 1;
                        if *votes == 0 {
                            self.cluster.set_accel_up(a);
                            let ev = ClusterEvent::AccelUp { accel: a };
                            self.dispatch(policy, ev, &mut report, &mut state)?;
                        }
                    }
                }
            }

            // ---- termination
            let drained = arrivals_pending == 0 && self.cluster.n_jobs() == 0;
            let timed_out = self.cluster.now() > last_arrival_t + self.drain_limit_s;
            if drained || timed_out {
                break;
            }
        }

        report.sim_seconds = self.cluster.now();
        report.energy_joules = self.meter_busy.total_joules();
        report.total_energy_joules = self.meter_total.total_joules();
        report.mean_jct = if report.jobs_completed > 0 {
            state.jct_sum / report.jobs_completed as f64
        } else {
            f64::NAN
        };
        report.mean_queue_s = if state.queue_waits > 0 {
            state.queue_wait_sum / state.queue_waits as f64
        } else {
            0.0
        };
        report.mean_decision_ms = if report.events > 0 {
            1000.0 * state.decision_s / report.events as f64
        } else {
            0.0
        };
        report.estimation_mae = policy.estimation_mae();
        let (solve_ms, p1_ms) = policy.decision_latencies();
        report.mean_solve_ms = solve_ms;
        report.mean_p1_ms = p1_ms;
        report.inference_attainment = if state.inf_total_s > 0.0 {
            state.inf_attained_s / state.inf_total_s
        } else {
            0.0
        };
        if state.inf_hist.total_weight() > 0.0 {
            report.inference_p50_latency_s = state.inf_hist.quantile(0.5);
            report.inference_p99_latency_s = state.inf_hist.quantile(0.99);
        }
        let (scale_ups, scale_downs) = policy.autoscale_counts();
        report.scale_ups = scale_ups;
        report.scale_downs = scale_downs;
        Ok(report)
    }

    /// Ask the policy for a decision, apply + validate its delta, and
    /// account migrations, restart penalties and queueing delays.
    fn dispatch(
        &mut self,
        policy: &mut dyn Scheduler,
        event: ClusterEvent,
        report: &mut RunReport,
        state: &mut RunState,
    ) -> Result<()> {
        let t0 = std::time::Instant::now();
        let decision = policy.on_event(&event, &self.cluster)?;
        state.decision_s += t0.elapsed().as_secs_f64();
        report.events += 1;
        let outcome = self.cluster.apply_delta(&decision.delta)?;
        report.migrations += outcome.moves;
        // jobs restarting from scratch: migrated by this delta, plus any
        // failure-evicted job re-placed now (unplaced when the delta
        // applied, so migrated_jobs cannot see it — the sets are disjoint)
        let mut restarted = outcome.migrated_jobs;
        let replaced: Vec<JobId> = state
            .failure_evicted
            .iter()
            .copied()
            .filter(|j| self.cluster.placement.is_placed(*j))
            .collect();
        for j in &replaced {
            state.failure_evicted.remove(j);
        }
        restarted.extend(replaced);
        if self.migration_cost_s > 0.0 {
            let until = self.cluster.now() + self.migration_cost_s;
            for j in restarted {
                // stall_job returns the stall actually added, so
                // overlapping penalties extend rather than double-charge
                report.migration_stall_s += self.cluster.stall_job(j, until);
            }
        }
        // queueing delay: record the first time each job gets capacity
        let now = self.cluster.now();
        for j in self.cluster.active_job_ids() {
            if self.cluster.placement.is_placed(j) && !state.first_place.contains_key(&j) {
                state.first_place.insert(j, now);
                let arrived = state.arrival_time.get(&j).copied().unwrap_or(now);
                state.queue_wait_sum += now - arrived;
                state.queue_waits += 1;
            }
        }
        Ok(())
    }

    /// Advance work, energy and SLO accounting over [t0, t1] using the
    /// ground-truth throughputs of the current placement (the substrate
    /// "runs" the jobs; schedulers only ever see monitor samples).
    /// Jobs inside their migration-restart window make no progress.
    fn integrate(
        &mut self,
        t0: f64,
        t1: f64,
        policy: &mut dyn Scheduler,
        report: &mut RunReport,
        state: &mut RunState,
    ) -> Result<()> {
        let dt = t1 - t0;
        if dt <= 0.0 {
            return Ok(());
        }
        // ground-truth throughput per job; inference jobs additionally
        // keep their per-replica rates for the M/M/c latency model
        let oracle = self.monitor.oracle().clone();
        let mut per_job: HashMap<JobId, f64> = HashMap::new();
        let mut replica_mus: HashMap<JobId, Vec<f64>> = HashMap::new();
        for (aid, combo) in self.cluster.placement.iter() {
            for j in combo.jobs() {
                let spec = self.cluster.job(j).expect("placed job registered");
                let lookup = |id: JobId| self.cluster.job(id).cloned();
                let t = oracle.throughput(spec, combo, aid.accel, &lookup);
                *per_job.entry(j).or_default() += t;
                if spec.is_inference() {
                    replica_mus.entry(j).or_default().push(serving::service_rate(t));
                }
            }
        }

        // energy: busy = only instances hosting work; total = in-service
        let solo_cap = |a: AccelType| a.base_speed() / AccelType::V100.base_speed();
        let loads = placement_loads(
            &self.cluster.placement,
            &|j, aid| {
                let spec = self.cluster.job(j).unwrap();
                let combo = self.cluster.placement.combo_on(aid).unwrap();
                let lookup = |id: JobId| self.cluster.job(id).cloned();
                oracle.throughput(spec, combo, aid.accel, &lookup)
            },
            &|aid| solo_cap(aid.accel),
        );
        let busy: Vec<AccelId> = loads.keys().copied().collect();
        self.meter_busy.accrue(t1, &busy, &loads);
        let in_service = self.cluster.available_accels();
        self.meter_total.accrue(t1, &in_service, &loads);

        // SLO + progress + completion (stalled jobs make no progress).
        // Training jobs burn work at their achieved throughput against a
        // throughput floor; inference jobs burn serving lifetime while
        // placed and are scored on M/M/c latency vs their SLO.
        let mut slo_violated = false;
        let ids = self.cluster.active_job_ids();
        let mut completed: Vec<JobId> = vec![];
        for id in ids {
            let achieved = per_job.get(&id).copied().unwrap_or(0.0);
            let stalled_until = self.cluster.stalled_until(id);
            let run_dt = (t1 - stalled_until.max(t0)).clamp(0.0, dt);
            let spec = self.cluster.job(id).unwrap();
            if let Some(inf) = spec.inference {
                // serving capacity over the interval, de-rated by the
                // stalled fraction (a restarting replica serves nothing);
                // unplaced jobs have no replicas → infinite latency
                let mus = replica_mus.get(&id).cloned().unwrap_or_default();
                let frac = run_dt / dt;
                let eff: Vec<f64> = mus.iter().map(|m| m * frac).collect();
                let lam = spec.request_rate_at(t0);
                let lat = serving::mmc_sojourn(lam, &eff);
                let ok = lat <= inf.latency_slo_s;
                state.inf_total_s += dt;
                if ok {
                    state.inf_attained_s += dt;
                }
                let e = state.inf_job_time.entry(id).or_insert((0.0, 0.0));
                e.1 += dt;
                if ok {
                    e.0 += dt;
                }
                state.inf_hist.record(lat, dt);
                report.replica_seconds += mus.len() as f64 * dt;
                let placed = !mus.is_empty();
                let j = self.cluster.job_mut(id).unwrap();
                if placed {
                    j.work -= run_dt;
                }
                if j.work <= 0.0 {
                    completed.push(id);
                }
            } else {
                let avg = achieved * run_dt / dt;
                let deficit = (spec.min_throughput - avg).max(0.0);
                if deficit > 1e-9 {
                    report.slo_deficit += deficit * dt;
                    slo_violated = true;
                }
                let j = self.cluster.job_mut(id).unwrap();
                j.work -= achieved * run_dt;
                if j.work <= 0.0 {
                    completed.push(id);
                }
            }
        }
        if slo_violated {
            report.slo_violations += 1;
        }
        if !completed.is_empty() {
            self.cluster.advance_to(t1);
            for id in completed {
                let was_inference = self.cluster.job(id).map_or(false, |s| s.is_inference());
                self.cluster.remove_job(id);
                report.jobs_completed += 1;
                if was_inference {
                    report.inference_completed += 1;
                    if let Some(&(attained, total)) = state.inf_job_time.get(&id) {
                        if total > 0.0 && attained / total >= serving::SLO_MET_FRACTION {
                            report.inference_slo_met += 1;
                        }
                    }
                }
                state.jct_sum += t1 - state.arrival_time.get(&id).copied().unwrap_or(0.0);
                self.dispatch(policy, ClusterEvent::JobCompleted { job: id }, report, state)?;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::TraceConfig;

    /// Trivial incremental policy: place every waiting job solo on the
    /// first free in-service accelerator, retrying on every event.
    struct FirstFit;
    impl Scheduler for FirstFit {
        fn name(&self) -> &str {
            "firstfit"
        }
        fn on_event(&mut self, event: &ClusterEvent, cluster: &Cluster) -> Result<Decision> {
            if matches!(event, ClusterEvent::MonitorTick { .. }) {
                return Ok(Decision::none());
            }
            let mut delta = PlacementDelta::new();
            let mut free: Vec<AccelId> = cluster
                .available_accels()
                .into_iter()
                .filter(|a| cluster.placement.combo_on(*a).is_none())
                .collect();
            for j in cluster.active_job_ids() {
                if !cluster.placement.is_placed(j) {
                    if let Some(a) = free.pop() {
                        delta.push(PlacementOp::Assign {
                            accel: a,
                            combo: Combo::Solo(j),
                        });
                    }
                }
            }
            Ok(Decision::apply(delta))
        }
    }

    fn job(id: u32, work: f64) -> JobSpec {
        JobSpec {
            id: JobId(id),
            family: crate::workload::ModelFamily::ResNet18,
            batch_size: 32,
            replication: 1,
            min_throughput: 0.0,
            distributability: 1,
            work,
            inference: None,
        }
    }

    fn serving_job(id: u32, lifetime_s: f64, base_rate: f64, slo_s: f64) -> JobSpec {
        let mut j = job(id, lifetime_s);
        j.inference = Some(crate::workload::InferenceSpec {
            base_rate,
            diurnal_amplitude: 0.0,
            diurnal_phase_s: 0.0,
            latency_slo_s: slo_s,
        });
        j
    }

    #[test]
    fn driver_completes_all_jobs() {
        let oracle = ThroughputOracle::new(2);
        let cfg = TraceConfig {
            n_jobs: 6,
            mean_interarrival_s: 10.0,
            mean_work_s: 50.0,
            ..Default::default()
        };
        let trace = Trace::generate(&cfg, &oracle);
        let mut driver =
            SimDriver::new(ClusterSpec::balanced(2), oracle, trace, 0.0, 15.0, 1).unwrap();
        let report = driver.run(&mut FirstFit).unwrap();
        assert_eq!(report.jobs_completed, 6);
        assert_eq!(report.jobs_total, 6);
        assert_eq!(report.jobs_cancelled, 0);
        assert!(report.energy_joules > 0.0);
        assert!(report.total_energy_joules >= report.energy_joules);
        assert!(report.mean_jct > 0.0);
        assert!(report.sim_seconds > 0.0);
        assert!(report.events > 0);
    }

    #[test]
    fn driver_is_deterministic() {
        let mk = || {
            let oracle = ThroughputOracle::new(2);
            let cfg = TraceConfig {
                n_jobs: 5,
                mean_interarrival_s: 5.0,
                mean_work_s: 30.0,
                ..Default::default()
            };
            let trace = Trace::generate(&cfg, &oracle);
            let mut d =
                SimDriver::new(ClusterSpec::balanced(1), oracle, trace, 0.01, 10.0, 3).unwrap();
            d.run(&mut FirstFit).unwrap()
        };
        let a = mk();
        let b = mk();
        assert_eq!(a.energy_joules, b.energy_joules);
        assert_eq!(a.slo_violations, b.slo_violations);
        assert_eq!(a.mean_jct, b.mean_jct);
        assert_eq!(a.migrations, b.migrations);
        assert_eq!(a.events, b.events);
    }

    #[test]
    fn zero_monitor_interval_is_rejected() {
        let oracle = ThroughputOracle::new(1);
        let trace = Trace::generate(&TraceConfig::default(), &oracle);
        assert!(SimDriver::new(ClusterSpec::balanced(1), oracle, trace, 0.0, 0.0, 1).is_err());
    }

    #[test]
    fn driver_scores_inference_latency_and_burns_lifetime() {
        // A lightly-loaded serving job placed immediately: every
        // interval clears the SLO (attainment 1.0), the lifetime burns
        // in placed wall-clock seconds, and replica-seconds accrue.
        let oracle = ThroughputOracle::new(8);
        let probe = serving_job(0, 100.0, 1.0, 1.0);
        let mu = crate::workload::serving::service_rate(
            oracle.solo(&probe, AccelType::V100),
        );
        let trace = Trace {
            events: vec![TraceEvent::Arrival {
                at: 1.0,
                job: serving_job(0, 100.0, 0.3 * mu, 10.0 / mu),
            }],
            config: TraceConfig {
                n_jobs: 1,
                ..Default::default()
            },
        };
        let spec = ClusterSpec::mix(&[(AccelType::V100, 1)]);
        let mut driver = SimDriver::new(spec, oracle, trace, 0.0, 15.0, 1).unwrap();
        let report = driver.run(&mut FirstFit).unwrap();
        assert_eq!(report.jobs_completed, 1);
        assert_eq!(report.inference_total, 1);
        assert_eq!(report.inference_completed, 1);
        assert_eq!(report.inference_slo_met, 1);
        assert!((report.inference_attainment - 1.0).abs() < 1e-9);
        assert!(report.inference_p99_latency_s.is_finite());
        // one replica held for the ~100 s lifetime
        assert!(report.replica_seconds >= 100.0, "{}", report.replica_seconds);
        // mean JCT ≈ lifetime, rounded up to the next event boundary
        assert!(report.mean_jct >= 100.0 && report.mean_jct < 130.0, "{}", report.mean_jct);
        // training SLO machinery untouched: no throughput deficit
        assert_eq!(report.slo_deficit, 0.0);
    }

    #[test]
    fn unplaced_serving_job_breaches_its_slo() {
        // No capacity at all: the serving job never places, every
        // interval is a breach (infinite latency), nothing completes.
        let oracle = ThroughputOracle::new(8);
        let trace = Trace {
            events: vec![TraceEvent::Arrival {
                at: 1.0,
                job: serving_job(0, 50.0, 1.0, 0.5),
            }],
            config: TraceConfig {
                n_jobs: 1,
                ..Default::default()
            },
        };
        let spec = ClusterSpec::mix(&[]);
        let mut driver = SimDriver::new(spec, oracle, trace, 0.0, 15.0, 1).unwrap();
        driver.drain_limit_s = 200.0;
        let report = driver.run(&mut FirstFit).unwrap();
        assert_eq!(report.jobs_completed, 0);
        assert_eq!(report.inference_completed, 0);
        assert_eq!(report.inference_slo_met, 0);
        assert_eq!(report.inference_attainment, 0.0);
        assert_eq!(report.inference_p99_latency_s, f64::INFINITY);
        assert_eq!(report.replica_seconds, 0.0);
    }

    #[test]
    fn cancellation_frees_capacity_and_is_reported() {
        // one instance; a huge job blocks it, a small job waits; the
        // cancellation frees the instance and the small job completes.
        let oracle = ThroughputOracle::new(4);
        let trace = Trace {
            events: vec![
                TraceEvent::Arrival {
                    at: 1.0,
                    job: job(0, 1.0e9),
                },
                TraceEvent::Arrival {
                    at: 2.0,
                    job: job(1, 50.0),
                },
                TraceEvent::Cancel {
                    at: 100.0,
                    job: JobId(0),
                },
            ],
            config: TraceConfig {
                n_jobs: 2,
                ..Default::default()
            },
        };
        let spec = ClusterSpec::mix(&[(AccelType::V100, 1)]);
        let mut driver = SimDriver::new(spec, oracle, trace, 0.0, 15.0, 1).unwrap();
        let report = driver.run(&mut FirstFit).unwrap();
        assert_eq!(report.jobs_total, 2);
        assert_eq!(report.jobs_cancelled, 1);
        assert_eq!(report.jobs_completed, 1);
        // the small job queued from t=2 until the cancellation at t=100
        assert!(report.mean_queue_s > 0.0, "queueing delay not tracked");
        assert!(report.sim_seconds < driver.drain_limit_s, "run failed to drain");
    }

    #[test]
    fn accel_churn_reroutes_work() {
        // two instances; one goes down mid-run and comes back — FirstFit
        // re-places the evicted job and everything still completes.
        let oracle = ThroughputOracle::new(5);
        let trace = Trace {
            events: vec![
                TraceEvent::Arrival {
                    at: 1.0,
                    job: job(0, 200.0),
                },
                TraceEvent::Arrival {
                    at: 2.0,
                    job: job(1, 200.0),
                },
                TraceEvent::AccelChurn {
                    at: 10.0,
                    accel_index: 0,
                    up: false,
                },
                TraceEvent::AccelChurn {
                    at: 400.0,
                    accel_index: 0,
                    up: true,
                },
            ],
            config: TraceConfig {
                n_jobs: 2,
                ..Default::default()
            },
        };
        let spec = ClusterSpec::mix(&[(AccelType::V100, 2)]);
        let mut driver = SimDriver::new(spec, oracle, trace, 0.0, 15.0, 1).unwrap();
        let report = driver.run(&mut FirstFit).unwrap();
        assert_eq!(report.jobs_completed, 2);
    }

    #[test]
    fn down_accelerator_is_not_billed_during_outage() {
        // one job busy on the k80; the idle v100 goes down for
        // [10, 1000] — the outage must remove exactly the v100's idle
        // draw from total energy and leave busy energy untouched.
        let run = |churn: bool| {
            let oracle = ThroughputOracle::new(7);
            let mut events = vec![TraceEvent::Arrival {
                at: 1.0,
                job: job(0, 2000.0),
            }];
            if churn {
                events.push(TraceEvent::AccelChurn {
                    at: 10.0,
                    accel_index: 0,
                    up: false,
                });
                events.push(TraceEvent::AccelChurn {
                    at: 1000.0,
                    accel_index: 0,
                    up: true,
                });
            }
            let trace = Trace {
                events,
                config: TraceConfig {
                    n_jobs: 1,
                    ..Default::default()
                },
            };
            // FirstFit pops the LAST free instance → the k80 hosts the job
            let spec = ClusterSpec::mix(&[(AccelType::V100, 1), (AccelType::K80, 1)]);
            let mut d = SimDriver::new(spec, oracle, trace, 0.0, 15.0, 1).unwrap();
            d.run(&mut FirstFit).unwrap()
        };
        let with = run(true);
        let without = run(false);
        assert_eq!(with.jobs_completed, 1);
        assert_eq!(with.sim_seconds, without.sim_seconds);
        assert!((with.energy_joules - without.energy_joules).abs() < 1e-6);
        let expected_saving = crate::cluster::power_watts(AccelType::V100, 0.0) * 990.0;
        let saving = without.total_energy_joules - with.total_energy_joules;
        assert!(
            (saving - expected_saving).abs() < 1e-3 * expected_saving,
            "outage saved {saving} J, expected {expected_saving} J"
        );
    }

    /// Places arrivals on the first free instance, then migrates the
    /// job once at the first monitor tick (exercises the restart cost).
    struct MigrateOnce {
        done: bool,
    }
    impl Scheduler for MigrateOnce {
        fn name(&self) -> &str {
            "migrate-once"
        }
        fn on_event(&mut self, event: &ClusterEvent, cluster: &Cluster) -> Result<Decision> {
            match event {
                ClusterEvent::JobArrived { job } => {
                    Ok(Decision::assign(cluster.available_accels()[0], Combo::Solo(*job)))
                }
                ClusterEvent::MonitorTick { .. } if !self.done && cluster.n_jobs() > 0 => {
                    self.done = true;
                    let j = cluster.active_job_ids()[0];
                    let from = cluster.placement.accels_of(j)[0];
                    let to = cluster
                        .available_accels()
                        .into_iter()
                        .find(|a| cluster.placement.combo_on(*a).is_none())
                        .expect("a free instance");
                    Ok(Decision::apply(PlacementDelta {
                        ops: vec![PlacementOp::Migrate { job: j, from, to }],
                    }))
                }
                _ => Ok(Decision::none()),
            }
        }
    }

    #[test]
    fn migration_cost_stalls_progress() {
        // same single-job run with and without a restart penalty on the
        // mid-run migration: the penalized run finishes later.
        let run = |cost: f64| {
            let oracle = ThroughputOracle::new(6);
            let trace = Trace {
                events: vec![TraceEvent::Arrival {
                    at: 1.0,
                    job: job(0, 300.0),
                }],
                config: TraceConfig {
                    n_jobs: 1,
                    ..Default::default()
                },
            };
            let spec = ClusterSpec::mix(&[(AccelType::V100, 2)]);
            let mut d = SimDriver::new(spec, oracle, trace, 0.0, 15.0, 1)
                .unwrap()
                .with_migration_cost(cost);
            d.run(&mut MigrateOnce { done: false }).unwrap()
        };
        let free = run(0.0);
        let penalized = run(120.0);
        assert_eq!(free.migration_stall_s, 0.0);
        assert_eq!(penalized.migration_stall_s, 120.0);
        assert!(free.migrations >= 2, "migrate op must count as moves");
        assert!(
            penalized.mean_jct > free.mean_jct + 60.0,
            "restart penalty had no effect: {} vs {}",
            penalized.mean_jct,
            free.mean_jct
        );
    }
}
