//! Scheduler trait + the shared discrete-event simulation driver.
//!
//! Every policy (GOGH and the baselines) implements [`Scheduler`]; the
//! [`SimDriver`] replays a trace against a policy, integrating energy,
//! SLO deficit, migrations and completion times into a
//! [`crate::metrics::RunReport`]. Using one driver for all policies is
//! what makes the e2e comparison table apples-to-apples.

use std::collections::HashMap;

use crate::cluster::energy::{placement_loads, EnergyMeter};
use crate::cluster::{Cluster, ClusterSpec, Measurement, Monitor, Placement};
use crate::metrics::RunReport;
use crate::workload::{AccelType, JobId, ThroughputOracle, Trace, TraceEvent};
use crate::Result;

/// A placement policy.
pub trait Scheduler {
    fn name(&self) -> &str;

    /// Produce a (full) placement for the currently active jobs.
    /// Called on every arrival and departure.
    fn allocate(&mut self, cluster: &Cluster) -> Result<Placement>;

    /// Digest monitoring data (learning schedulers refine estimates and
    /// train here; baselines ignore it).
    fn observe(&mut self, _measurements: &[Measurement], _cluster: &Cluster) -> Result<()> {
        Ok(())
    }

    /// Estimation MAE vs ground truth, if this scheduler estimates.
    fn estimation_mae(&self) -> Option<f64> {
        None
    }

    /// Mean decision-path latencies (solve_ms, p1_ms) for the report.
    fn decision_latencies(&self) -> (f64, f64) {
        (0.0, 0.0)
    }
}

/// Discrete-event simulation of a trace under a policy.
pub struct SimDriver {
    pub cluster: Cluster,
    pub monitor: Monitor,
    meter_busy: EnergyMeter,
    meter_total: EnergyMeter,
    trace: Trace,
    monitor_interval_s: f64,
    /// max simulated seconds after the last arrival (safety stop)
    pub drain_limit_s: f64,
}

impl SimDriver {
    pub fn new(
        spec: ClusterSpec,
        oracle: ThroughputOracle,
        trace: Trace,
        noise_sigma: f64,
        monitor_interval_s: f64,
        seed: u64,
    ) -> Self {
        Self {
            cluster: Cluster::new(spec),
            monitor: Monitor::new(oracle, noise_sigma, seed),
            meter_busy: EnergyMeter::new(),
            meter_total: EnergyMeter::new(),
            trace,
            monitor_interval_s,
            drain_limit_s: 24.0 * 3600.0,
        }
    }

    /// Run the full trace; returns the report.
    pub fn run(&mut self, policy: &mut dyn Scheduler) -> Result<RunReport> {
        let mut report = RunReport {
            scheduler: policy.name().to_string(),
            jobs_total: self.trace.len(),
            ..Default::default()
        };
        let mut arrivals: Vec<(f64, crate::workload::JobSpec)> = self
            .trace
            .events
            .iter()
            .map(|TraceEvent::Arrival { at, job }| (*at, job.clone()))
            .collect();
        arrivals.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let mut next_arrival = 0usize;
        let mut arrival_time: HashMap<JobId, f64> = HashMap::new();
        let mut jct_sum = 0.0f64;
        let last_arrival_t = arrivals.last().map(|(t, _)| *t).unwrap_or(0.0);
        let mut next_tick = self.monitor_interval_s;

        loop {
            let now = self.cluster.now();
            // next event: arrival or monitor tick
            let t_arr = arrivals.get(next_arrival).map(|(t, _)| *t);
            let t_next = match t_arr {
                Some(ta) if ta <= next_tick => ta,
                _ => next_tick,
            };

            // ---- integrate the interval [now, t_next]
            self.integrate(now, t_next, &mut report, &mut jct_sum, &arrival_time, policy)?;
            self.cluster.advance_to(t_next);

            // ---- dispatch the event
            if t_arr == Some(t_next) {
                let (_, job) = arrivals[next_arrival].clone();
                next_arrival += 1;
                arrival_time.insert(job.id, t_next);
                self.cluster.add_job(job);
                let new_placement = policy.allocate(&self.cluster)?;
                report.migrations += self.cluster.placement.diff_count(&new_placement);
                self.cluster.placement = new_placement;
            } else {
                next_tick = t_next + self.monitor_interval_s;
                let measurements = self.monitor.sample(&self.cluster);
                policy.observe(&measurements, &self.cluster)?;
            }

            // ---- termination
            let drained = next_arrival >= arrivals.len() && self.cluster.n_jobs() == 0;
            let timed_out = self.cluster.now() > last_arrival_t + self.drain_limit_s;
            if drained || timed_out {
                break;
            }
        }

        report.sim_seconds = self.cluster.now();
        report.energy_joules = self.meter_busy.total_joules();
        report.total_energy_joules = self.meter_total.total_joules();
        report.mean_jct = if report.jobs_completed > 0 {
            jct_sum / report.jobs_completed as f64
        } else {
            f64::NAN
        };
        report.estimation_mae = policy.estimation_mae();
        let (solve_ms, p1_ms) = policy.decision_latencies();
        report.mean_solve_ms = solve_ms;
        report.mean_p1_ms = p1_ms;
        Ok(report)
    }

    /// Advance work, energy and SLO accounting over [t0, t1] using the
    /// ground-truth throughputs of the current placement (the substrate
    /// "runs" the jobs; schedulers only ever see monitor samples).
    fn integrate(
        &mut self,
        t0: f64,
        t1: f64,
        report: &mut RunReport,
        jct_sum: &mut f64,
        arrival_time: &HashMap<JobId, f64>,
        policy: &mut dyn Scheduler,
    ) -> Result<()> {
        let dt = t1 - t0;
        if dt <= 0.0 {
            return Ok(());
        }
        // ground-truth throughput per (job, accel)
        let oracle = self.monitor.oracle().clone();
        let mut per_job: HashMap<JobId, f64> = HashMap::new();
        let mut per_accel: HashMap<crate::cluster::AccelId, f64> = HashMap::new();
        for (aid, combo) in self.cluster.placement.iter() {
            for j in combo.jobs() {
                let spec = self.cluster.job(j).expect("placed job registered");
                let lookup = |id: JobId| self.cluster.job(id).cloned();
                let t = oracle.throughput(spec, combo, aid.accel, &lookup);
                *per_job.entry(j).or_default() += t;
                *per_accel.entry(*aid).or_default() += t;
            }
        }

        // energy: busy = only instances hosting work; total = whole cluster
        let solo_cap = |a: AccelType| a.base_speed() / AccelType::V100.base_speed();
        let loads = placement_loads(
            &self.cluster.placement,
            &|j, aid| {
                let spec = self.cluster.job(j).unwrap();
                let combo = self.cluster.placement.combo_on(aid).unwrap();
                let lookup = |id: JobId| self.cluster.job(id).cloned();
                oracle.throughput(spec, combo, aid.accel, &lookup)
            },
            &|aid| solo_cap(aid.accel),
        );
        let busy: Vec<crate::cluster::AccelId> = loads.keys().copied().collect();
        self.meter_busy.accrue(t1, &busy, &loads);
        self.meter_total.accrue(t1, &self.cluster.spec.accels, &loads);

        // SLO + progress + completion
        let mut slo_violated = false;
        let ids = self.cluster.active_job_ids();
        let mut completed: Vec<JobId> = vec![];
        for id in ids {
            let achieved = per_job.get(&id).copied().unwrap_or(0.0);
            let spec = self.cluster.job(id).unwrap();
            let deficit = (spec.min_throughput - achieved).max(0.0);
            if deficit > 1e-9 {
                report.slo_deficit += deficit * dt;
                slo_violated = true;
            }
            let j = self.cluster.job_mut(id).unwrap();
            j.work -= achieved * dt;
            if j.work <= 0.0 {
                completed.push(id);
            }
        }
        if slo_violated {
            report.slo_violations += 1;
        }
        if !completed.is_empty() {
            for id in completed {
                self.cluster.remove_job(id);
                report.jobs_completed += 1;
                *jct_sum += t1 - arrival_time.get(&id).copied().unwrap_or(0.0);
            }
            if self.cluster.n_jobs() > 0 {
                let new_placement = policy.allocate(&self.cluster)?;
                report.migrations += self.cluster.placement.diff_count(&new_placement);
                self.cluster.placement = new_placement;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{Combo, TraceConfig};

    /// Trivial policy: first free accelerator, solo.
    struct FirstFit;
    impl Scheduler for FirstFit {
        fn name(&self) -> &str {
            "firstfit"
        }
        fn allocate(&mut self, cluster: &Cluster) -> Result<Placement> {
            let mut p = Placement::new();
            let mut free: Vec<_> = cluster.spec.accels.clone();
            for id in cluster.active_job_ids() {
                if let Some(a) = free.pop() {
                    p.assign(a, Combo::Solo(id));
                }
            }
            Ok(p)
        }
    }

    #[test]
    fn driver_completes_all_jobs() {
        let oracle = ThroughputOracle::new(2);
        let cfg = TraceConfig {
            n_jobs: 6,
            mean_interarrival_s: 10.0,
            mean_work_s: 50.0,
            ..Default::default()
        };
        let trace = Trace::generate(&cfg, &oracle);
        let mut driver = SimDriver::new(ClusterSpec::balanced(2), oracle, trace, 0.0, 15.0, 1);
        let report = driver.run(&mut FirstFit).unwrap();
        assert_eq!(report.jobs_completed, 6);
        assert!(report.energy_joules > 0.0);
        assert!(report.total_energy_joules >= report.energy_joules);
        assert!(report.mean_jct > 0.0);
        assert!(report.sim_seconds > 0.0);
    }

    #[test]
    fn driver_is_deterministic() {
        let mk = || {
            let oracle = ThroughputOracle::new(2);
            let cfg = TraceConfig {
                n_jobs: 5,
                mean_interarrival_s: 5.0,
                mean_work_s: 30.0,
                ..Default::default()
            };
            let trace = Trace::generate(&cfg, &oracle);
            let mut d = SimDriver::new(ClusterSpec::balanced(1), oracle, trace, 0.01, 10.0, 3);
            d.run(&mut FirstFit).unwrap()
        };
        let a = mk();
        let b = mk();
        assert_eq!(a.energy_joules, b.energy_joules);
        assert_eq!(a.slo_violations, b.slo_violations);
        assert_eq!(a.mean_jct, b.mean_jct);
    }
}
