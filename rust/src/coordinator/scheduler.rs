//! Event-driven scheduler API + the trace-replay simulation frontend.
//!
//! Every policy (GOGH and the baselines) implements [`Scheduler`]: the
//! engine dispatches one [`ClusterEvent`] at a time (arrival,
//! completion, cancellation, monitor tick, accelerator churn) from a
//! time-ordered event queue, and the policy answers with a [`Decision`]
//! carrying an incremental [`PlacementDelta`] that the cluster validates
//! and applies atomically. The event loop itself lives in
//! [`crate::engine::GoghCore`], shared with the `goghd` daemon;
//! [`SimDriver`] is the simulator frontend — it loads a trace into the
//! core, drives the virtual clock to drain, and returns the
//! [`crate::metrics::RunReport`]. Using one engine for all policies and
//! both frontends is what makes the e2e comparison table
//! apples-to-apples.

use crate::cluster::{
    AccelId, Cluster, ClusterSpec, Measurement, Monitor, Placement, PlacementDelta, PlacementOp,
};
use crate::engine::{EngineOptions, GoghCore};
use crate::metrics::RunReport;
use crate::workload::{Combo, JobId, ThroughputOracle, Trace};
use crate::Result;

/// One event in the life of the cluster, dispatched to the policy.
///
/// State transitions (job registration, eviction on `AccelDown`) happen
/// *before* dispatch, so the policy always sees the post-event cluster
/// and only has to answer with a placement delta.
#[derive(Debug, Clone)]
pub enum ClusterEvent {
    /// `job` is registered and waiting for its first placement.
    JobArrived { job: JobId },
    /// `job` finished and was removed (a co-runner, if any, was
    /// re-hosted solo on the same instance).
    JobCompleted { job: JobId },
    /// `job` was cancelled by its owner and removed.
    JobCancelled { job: JobId },
    /// Periodic monitoring round: noisy throughput measurements of the
    /// current placement (learning schedulers refine estimates here).
    MonitorTick { measurements: Vec<Measurement> },
    /// `accel` went out of service; any jobs it hosted are now unplaced.
    AccelDown { accel: AccelId },
    /// `accel` came back into service.
    AccelUp { accel: AccelId },
}

/// A policy's answer to one event: the placement ops to apply now.
#[derive(Debug, Clone, Default)]
pub struct Decision {
    pub delta: PlacementDelta,
}

impl Decision {
    /// Change nothing.
    pub fn none() -> Self {
        Self::default()
    }

    /// Apply an explicit delta.
    pub fn apply(delta: PlacementDelta) -> Self {
        Self { delta }
    }

    /// Single-op convenience: host `combo` on `accel`.
    pub fn assign(accel: AccelId, combo: Combo) -> Self {
        Self {
            delta: PlacementDelta {
                ops: vec![PlacementOp::Assign { accel, combo }],
            },
        }
    }

    /// Compatibility shim for full-placement policies: the delta that
    /// turns `current` into `target` (unchanged instances cost nothing).
    ///
    /// Hidden from the public API: every shipped policy now emits native
    /// incremental deltas; this survives as the equivalence oracle for
    /// the diff-vs-delta proptest and for the full re-solve path, which
    /// genuinely computes a whole-placement target.
    #[doc(hidden)]
    pub fn replace(current: &Placement, target: &Placement) -> Self {
        Self {
            delta: PlacementDelta::diff(current, target),
        }
    }
}

/// A placement policy reacting to the cluster event stream.
pub trait Scheduler {
    fn name(&self) -> &str;

    /// React to one event with an incremental placement decision. The
    /// cluster already reflects the event (see [`ClusterEvent`]); the
    /// returned delta is validated and applied by the driver.
    fn on_event(&mut self, event: &ClusterEvent, cluster: &Cluster) -> Result<Decision>;

    /// Estimation MAE vs ground truth, if this scheduler estimates.
    fn estimation_mae(&self) -> Option<f64> {
        None
    }

    /// Mean decision-path latencies (solve_ms, p1_ms) for the report.
    fn decision_latencies(&self) -> (f64, f64) {
        (0.0, 0.0)
    }

    /// Replica autoscaling events this policy applied over the run, as
    /// `(scale_ups, scale_downs)`. Policies without an inference
    /// autoscaler report zeros.
    fn autoscale_counts(&self) -> (u64, u64) {
        (0, 0)
    }
}

/// Discrete-event simulation of a trace under a policy: a thin frontend
/// over [`GoghCore`] that owns the trace and the drain policy, while the
/// core owns the event loop (the daemon drives the very same loop in
/// wall-clock time).
pub struct SimDriver {
    core: GoghCore,
    trace: Trace,
    /// max simulated seconds after the last arrival (safety stop)
    pub drain_limit_s: f64,
}

impl SimDriver {
    /// Build a driver. Fails if `monitor_interval_s` is not strictly
    /// positive — a zero interval would spin the event loop forever at
    /// t = 0 (validated once, in [`GoghCore::new`]; callers must not
    /// patch the interval themselves).
    pub fn new(
        spec: ClusterSpec,
        oracle: ThroughputOracle,
        trace: Trace,
        noise_sigma: f64,
        monitor_interval_s: f64,
        seed: u64,
    ) -> Result<Self> {
        Ok(Self {
            core: GoghCore::new(spec, oracle, noise_sigma, monitor_interval_s, seed)?,
            trace,
            drain_limit_s: 24.0 * 3600.0,
        })
    }

    /// Apply the shared substrate knobs (migration cost, power cap,
    /// carbon signal): one [`EngineOptions`] struct consumed by both
    /// frontends, forwarded to [`GoghCore::with_options`].
    pub fn with_options(mut self, opts: EngineOptions) -> Self {
        self.core = self.core.with_options(opts);
        self
    }

    /// The simulated cluster (read access for tests and tooling).
    pub fn cluster(&self) -> &Cluster {
        self.core.cluster()
    }

    /// The monitoring subsystem feeding the policy noisy measurements.
    pub fn monitor(&self) -> &Monitor {
        self.core.monitor()
    }

    /// Run the full trace; returns the report. Single-shot: the trace is
    /// loaded into the core's event queue and driven to drain (or to the
    /// drain timeout after the last arrival).
    pub fn run(&mut self, policy: &mut dyn Scheduler) -> Result<RunReport> {
        self.core.load_trace(&self.trace);
        // the first monitor tick enqueues after the trace so event-queue
        // tie-breaking (and thus every report) stays byte-stable
        self.core.start_monitor();
        self.core.run(policy, self.drain_limit_s)?;
        Ok(self.core.report(policy))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::power::{state_power_watts, PowerState};
    use crate::workload::{AccelType, InferenceSpec, JobSpec, TraceConfig, TraceEvent};

    /// Trivial incremental policy: place every waiting job solo on the
    /// first free in-service accelerator, retrying on every event.
    struct FirstFit;
    impl Scheduler for FirstFit {
        fn name(&self) -> &str {
            "firstfit"
        }
        fn on_event(&mut self, event: &ClusterEvent, cluster: &Cluster) -> Result<Decision> {
            if matches!(event, ClusterEvent::MonitorTick { .. }) {
                return Ok(Decision::none());
            }
            let mut delta = PlacementDelta::new();
            let mut free: Vec<AccelId> = cluster
                .available_accels()
                .into_iter()
                .filter(|a| cluster.placement.combo_on(*a).is_none())
                .collect();
            for j in cluster.active_job_ids() {
                if !cluster.placement.is_placed(j) {
                    if let Some(a) = free.pop() {
                        delta.push(PlacementOp::Assign {
                            accel: a,
                            combo: Combo::Solo(j),
                        });
                    }
                }
            }
            Ok(Decision::apply(delta))
        }
    }

    fn job(id: u32, work: f64) -> JobSpec {
        JobSpec {
            id: JobId(id),
            family: crate::workload::ModelFamily::ResNet18,
            batch_size: 32,
            replication: 1,
            min_throughput: 0.0,
            distributability: 1,
            work,
            priority: Default::default(),
            elastic: false,
            inference: None,
        }
    }

    fn serving_job(id: u32, lifetime_s: f64, base_rate: f64, slo_s: f64) -> JobSpec {
        let mut j = job(id, lifetime_s);
        j.inference = Some(InferenceSpec {
            base_rate,
            diurnal_amplitude: 0.0,
            diurnal_phase_s: 0.0,
            latency_slo_s: slo_s,
        });
        j
    }

    #[test]
    fn driver_completes_all_jobs() {
        let oracle = ThroughputOracle::new(2);
        let cfg = TraceConfig {
            n_jobs: 6,
            mean_interarrival_s: 10.0,
            mean_work_s: 50.0,
            ..Default::default()
        };
        let trace = Trace::generate(&cfg, &oracle);
        let mut driver =
            SimDriver::new(ClusterSpec::balanced(2), oracle, trace, 0.0, 15.0, 1).unwrap();
        let report = driver.run(&mut FirstFit).unwrap();
        assert_eq!(report.jobs_completed, 6);
        assert_eq!(report.jobs_total, 6);
        assert_eq!(report.jobs_cancelled, 0);
        assert!(report.energy_joules > 0.0);
        assert!(report.total_energy_joules >= report.energy_joules);
        assert!(report.mean_jct > 0.0);
        assert!(report.sim_seconds > 0.0);
        assert!(report.events > 0);
    }

    #[test]
    fn driver_is_deterministic() {
        let mk = || {
            let oracle = ThroughputOracle::new(2);
            let cfg = TraceConfig {
                n_jobs: 5,
                mean_interarrival_s: 5.0,
                mean_work_s: 30.0,
                ..Default::default()
            };
            let trace = Trace::generate(&cfg, &oracle);
            let mut d =
                SimDriver::new(ClusterSpec::balanced(1), oracle, trace, 0.01, 10.0, 3).unwrap();
            d.run(&mut FirstFit).unwrap()
        };
        let a = mk();
        let b = mk();
        assert_eq!(a.energy_joules, b.energy_joules);
        assert_eq!(a.slo_violations, b.slo_violations);
        assert_eq!(a.mean_jct, b.mean_jct);
        assert_eq!(a.migrations, b.migrations);
        assert_eq!(a.events, b.events);
    }

    #[test]
    fn zero_monitor_interval_is_rejected() {
        let oracle = ThroughputOracle::new(1);
        let trace = Trace::generate(&TraceConfig::default(), &oracle);
        assert!(SimDriver::new(ClusterSpec::balanced(1), oracle, trace, 0.0, 0.0, 1).is_err());
    }

    #[test]
    fn driver_scores_inference_latency_and_burns_lifetime() {
        // A lightly-loaded serving job placed immediately: every
        // interval clears the SLO (attainment 1.0), the lifetime burns
        // in placed wall-clock seconds, and replica-seconds accrue.
        let oracle = ThroughputOracle::new(8);
        let probe = serving_job(0, 100.0, 1.0, 1.0);
        let mu = crate::workload::serving::service_rate(
            oracle.solo(&probe, AccelType::V100),
        );
        let trace = Trace {
            events: vec![TraceEvent::Arrival {
                at: 1.0,
                job: serving_job(0, 100.0, 0.3 * mu, 10.0 / mu),
            }],
            config: TraceConfig {
                n_jobs: 1,
                ..Default::default()
            },
        };
        let spec = ClusterSpec::mix(&[(AccelType::V100, 1)]);
        let mut driver = SimDriver::new(spec, oracle, trace, 0.0, 15.0, 1).unwrap();
        let report = driver.run(&mut FirstFit).unwrap();
        assert_eq!(report.jobs_completed, 1);
        assert_eq!(report.inference_total, 1);
        assert_eq!(report.inference_completed, 1);
        assert_eq!(report.inference_slo_met, 1);
        assert!((report.inference_attainment - 1.0).abs() < 1e-9);
        assert!(report.inference_p99_latency_s.is_finite());
        // one replica held for the ~100 s lifetime
        assert!(report.replica_seconds >= 100.0, "{}", report.replica_seconds);
        // mean JCT ≈ lifetime, rounded up to the next event boundary
        assert!(report.mean_jct >= 100.0 && report.mean_jct < 130.0, "{}", report.mean_jct);
        // training SLO machinery untouched: no throughput deficit
        assert_eq!(report.slo_deficit, 0.0);
    }

    #[test]
    fn unplaced_serving_job_breaches_its_slo() {
        // No capacity at all: the serving job never places, every
        // interval is a breach (infinite latency), nothing completes.
        let oracle = ThroughputOracle::new(8);
        let trace = Trace {
            events: vec![TraceEvent::Arrival {
                at: 1.0,
                job: serving_job(0, 50.0, 1.0, 0.5),
            }],
            config: TraceConfig {
                n_jobs: 1,
                ..Default::default()
            },
        };
        let spec = ClusterSpec::mix(&[]);
        let mut driver = SimDriver::new(spec, oracle, trace, 0.0, 15.0, 1).unwrap();
        driver.drain_limit_s = 200.0;
        let report = driver.run(&mut FirstFit).unwrap();
        assert_eq!(report.jobs_completed, 0);
        assert_eq!(report.inference_completed, 0);
        assert_eq!(report.inference_slo_met, 0);
        assert_eq!(report.inference_attainment, 0.0);
        assert_eq!(report.inference_p99_latency_s, f64::INFINITY);
        assert_eq!(report.replica_seconds, 0.0);
    }

    #[test]
    fn cancellation_frees_capacity_and_is_reported() {
        // one instance; a huge job blocks it, a small job waits; the
        // cancellation frees the instance and the small job completes.
        let oracle = ThroughputOracle::new(4);
        let trace = Trace {
            events: vec![
                TraceEvent::Arrival {
                    at: 1.0,
                    job: job(0, 1.0e9),
                },
                TraceEvent::Arrival {
                    at: 2.0,
                    job: job(1, 50.0),
                },
                TraceEvent::Cancel {
                    at: 100.0,
                    job: JobId(0),
                },
            ],
            config: TraceConfig {
                n_jobs: 2,
                ..Default::default()
            },
        };
        let spec = ClusterSpec::mix(&[(AccelType::V100, 1)]);
        let mut driver = SimDriver::new(spec, oracle, trace, 0.0, 15.0, 1).unwrap();
        let report = driver.run(&mut FirstFit).unwrap();
        assert_eq!(report.jobs_total, 2);
        assert_eq!(report.jobs_cancelled, 1);
        assert_eq!(report.jobs_completed, 1);
        // the small job queued from t=2 until the cancellation at t=100
        assert!(report.mean_queue_s > 0.0, "queueing delay not tracked");
        assert!(report.sim_seconds < driver.drain_limit_s, "run failed to drain");
    }

    #[test]
    fn accel_churn_reroutes_work() {
        // two instances; one goes down mid-run and comes back — FirstFit
        // re-places the evicted job and everything still completes.
        let oracle = ThroughputOracle::new(5);
        let trace = Trace {
            events: vec![
                TraceEvent::Arrival {
                    at: 1.0,
                    job: job(0, 200.0),
                },
                TraceEvent::Arrival {
                    at: 2.0,
                    job: job(1, 200.0),
                },
                TraceEvent::AccelChurn {
                    at: 10.0,
                    accel_index: 0,
                    up: false,
                },
                TraceEvent::AccelChurn {
                    at: 400.0,
                    accel_index: 0,
                    up: true,
                },
            ],
            config: TraceConfig {
                n_jobs: 2,
                ..Default::default()
            },
        };
        let spec = ClusterSpec::mix(&[(AccelType::V100, 2)]);
        let mut driver = SimDriver::new(spec, oracle, trace, 0.0, 15.0, 1).unwrap();
        let report = driver.run(&mut FirstFit).unwrap();
        assert_eq!(report.jobs_completed, 2);
    }

    #[test]
    fn down_accelerator_is_not_billed_during_outage() {
        // one job busy on the k80; the idle v100 goes down for
        // [10, 1000] — the outage must remove exactly the v100's idle
        // draw from total energy and leave busy energy untouched.
        let run = |churn: bool| {
            let oracle = ThroughputOracle::new(7);
            let mut events = vec![TraceEvent::Arrival {
                at: 1.0,
                job: job(0, 2000.0),
            }];
            if churn {
                events.push(TraceEvent::AccelChurn {
                    at: 10.0,
                    accel_index: 0,
                    up: false,
                });
                events.push(TraceEvent::AccelChurn {
                    at: 1000.0,
                    accel_index: 0,
                    up: true,
                });
            }
            let trace = Trace {
                events,
                config: TraceConfig {
                    n_jobs: 1,
                    ..Default::default()
                },
            };
            // FirstFit pops the LAST free instance → the k80 hosts the job
            let spec = ClusterSpec::mix(&[(AccelType::V100, 1), (AccelType::K80, 1)]);
            let mut d = SimDriver::new(spec, oracle, trace, 0.0, 15.0, 1).unwrap();
            d.run(&mut FirstFit).unwrap()
        };
        let with = run(true);
        let without = run(false);
        assert_eq!(with.jobs_completed, 1);
        assert_eq!(with.sim_seconds, without.sim_seconds);
        assert!((with.energy_joules - without.energy_joules).abs() < 1e-6);
        let expected_saving = crate::cluster::power_watts(AccelType::V100, 0.0) * 990.0;
        let saving = without.total_energy_joules - with.total_energy_joules;
        assert!(
            (saving - expected_saving).abs() < 1e-3 * expected_saving,
            "outage saved {saving} J, expected {expected_saving} J"
        );
    }

    /// Puts the arriving job on the last free instance (the k80, like
    /// `FirstFit`), drops the idle v100 to the low state at arrival,
    /// and re-states it to turbo at the first monitor tick past t=10.
    struct StatefulFit {
        idle: Option<AccelId>,
        restated: bool,
    }
    impl Scheduler for StatefulFit {
        fn name(&self) -> &str {
            "stateful-fit"
        }
        fn on_event(&mut self, event: &ClusterEvent, cluster: &Cluster) -> Result<Decision> {
            let mut delta = PlacementDelta::new();
            match event {
                ClusterEvent::JobArrived { job } => {
                    let accels = cluster.available_accels();
                    self.idle = Some(accels[0]);
                    delta.push(PlacementOp::SetPowerState {
                        accel: accels[0],
                        state: PowerState::Low,
                    });
                    delta.push(PlacementOp::Assign {
                        accel: *accels.last().unwrap(),
                        combo: Combo::Solo(*job),
                    });
                }
                ClusterEvent::MonitorTick { .. } if !self.restated && cluster.now() > 10.0 => {
                    // legal even while the accelerator is down: the
                    // state is remembered for when it comes back
                    self.restated = true;
                    delta.push(PlacementOp::SetPowerState {
                        accel: self.idle.unwrap(),
                        state: PowerState::Turbo,
                    });
                }
                _ => {}
            }
            Ok(Decision::apply(delta))
        }
    }

    #[test]
    fn down_accelerator_bills_zero_regardless_of_power_state() {
        // like the outage test above but with DVFS in play: the idle
        // v100 sits in the low state when it goes down at t=10 and is
        // re-stated to turbo mid-outage (t=15). A down accelerator
        // bills zero watts no matter what state it holds, and the
        // state survives for when it comes back up.
        let run = |churn: bool| {
            let oracle = ThroughputOracle::new(7);
            let mut events = vec![TraceEvent::Arrival {
                at: 1.0,
                job: job(0, 2000.0),
            }];
            if churn {
                events.push(TraceEvent::AccelChurn {
                    at: 10.0,
                    accel_index: 0,
                    up: false,
                });
                events.push(TraceEvent::AccelChurn {
                    at: 1000.0,
                    accel_index: 0,
                    up: true,
                });
            }
            let trace = Trace {
                events,
                config: TraceConfig {
                    n_jobs: 1,
                    ..Default::default()
                },
            };
            let spec = ClusterSpec::mix(&[(AccelType::V100, 1), (AccelType::K80, 1)]);
            let mut d = SimDriver::new(spec, oracle, trace, 0.0, 15.0, 1).unwrap();
            let mut policy = StatefulFit {
                idle: None,
                restated: false,
            };
            d.run(&mut policy).unwrap()
        };
        let with = run(true);
        let without = run(false);
        assert_eq!(with.jobs_completed, 1);
        assert_eq!(with.sim_seconds, without.sim_seconds);
        assert!((with.energy_joules - without.energy_joules).abs() < 1e-6);
        // the un-churned run bills the v100 at low idle over [10, 15]
        // and turbo idle over [15, 1000]; the churned run bills zero
        // for the whole outage. Everything outside [10, 1000] cancels.
        let low_idle = state_power_watts(AccelType::V100, PowerState::Low, 0.0);
        let turbo_idle = state_power_watts(AccelType::V100, PowerState::Turbo, 0.0);
        let expected_saving = low_idle * 5.0 + turbo_idle * 985.0;
        let saving = without.total_energy_joules - with.total_energy_joules;
        assert!(
            (saving - expected_saving).abs() < 1e-3 * expected_saving,
            "outage saved {saving} J, expected {expected_saving} J"
        );
    }

    /// Places arrivals on the first free instance, then migrates the
    /// job once at the first monitor tick (exercises the restart cost).
    struct MigrateOnce {
        done: bool,
    }
    impl Scheduler for MigrateOnce {
        fn name(&self) -> &str {
            "migrate-once"
        }
        fn on_event(&mut self, event: &ClusterEvent, cluster: &Cluster) -> Result<Decision> {
            match event {
                ClusterEvent::JobArrived { job } => {
                    Ok(Decision::assign(cluster.available_accels()[0], Combo::Solo(*job)))
                }
                ClusterEvent::MonitorTick { .. } if !self.done && cluster.n_jobs() > 0 => {
                    self.done = true;
                    let j = cluster.active_job_ids()[0];
                    let from = cluster.placement.accels_of(j)[0];
                    let to = cluster
                        .available_accels()
                        .into_iter()
                        .find(|a| cluster.placement.combo_on(*a).is_none())
                        .expect("a free instance");
                    Ok(Decision::apply(PlacementDelta {
                        ops: vec![PlacementOp::Migrate { job: j, from, to }],
                    }))
                }
                _ => Ok(Decision::none()),
            }
        }
    }

    #[test]
    fn migration_cost_stalls_progress() {
        // same single-job run with and without a restart penalty on the
        // mid-run migration: the penalized run finishes later.
        let run = |cost: f64| {
            let oracle = ThroughputOracle::new(6);
            let trace = Trace {
                events: vec![TraceEvent::Arrival {
                    at: 1.0,
                    job: job(0, 300.0),
                }],
                config: TraceConfig {
                    n_jobs: 1,
                    ..Default::default()
                },
            };
            let spec = ClusterSpec::mix(&[(AccelType::V100, 2)]);
            let mut d = SimDriver::new(spec, oracle, trace, 0.0, 15.0, 1)
                .unwrap()
                .with_options(EngineOptions::new().with_migration_cost(cost));
            d.run(&mut MigrateOnce { done: false }).unwrap()
        };
        let free = run(0.0);
        let penalized = run(120.0);
        assert_eq!(free.migration_stall_s, 0.0);
        assert_eq!(penalized.migration_stall_s, 120.0);
        assert!(free.migrations >= 2, "migrate op must count as moves");
        assert!(
            penalized.mean_jct > free.mean_jct + 60.0,
            "restart penalty had no effect: {} vs {}",
            penalized.mean_jct,
            free.mean_jct
        );
    }
}
