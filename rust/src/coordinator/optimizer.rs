//! Optimizer module (paper §2.4): wraps Problem 1 — building the ILP
//! from throughput estimates, solving it, and binding the aggregated
//! (type-level) solution onto concrete accelerator instances with
//! migration-minimizing stability.

use std::collections::{BTreeMap, BTreeSet};

use crate::cluster::{AccelId, Cluster, Placement, PlacementDelta};
use crate::config::OptimizerConfig;
use crate::ilp::branch_bound::BnbConfig;
use crate::ilp::problem1::{AllocationSolution, Problem1Builder, Problem1Input};
use crate::power::PowerKnobs;
use crate::workload::{AccelType, Combo, JobId};
use crate::Result;

pub struct Optimizer {
    pub cfg: OptimizerConfig,
    /// Power knobs threaded into every solve. The GOGH coordinator
    /// refreshes the carbon weight before each re-solve; baselines keep
    /// the default (fixed nominal state, unweighted watts).
    pub power: PowerKnobs,
    /// cumulative solve statistics for §Perf reporting
    pub solves: usize,
    pub solve_seconds: f64,
    pub total_nodes: usize,
    /// cumulative simplex pivots across every solve (per-node cost)
    pub total_lp_pivots: u64,
    /// solves that started from a greedy/explicit incumbent
    pub warm_started_solves: usize,
    /// Incremental Problem 1 state: job edits land as O(changes)
    /// updates and the constraint matrix is reused verbatim between
    /// solves whose inputs did not change.
    pub builder: Problem1Builder,
}

impl Optimizer {
    pub fn new(cfg: OptimizerConfig) -> Self {
        let builder = Problem1Builder::new(cfg.max_pairs_per_job);
        Self {
            cfg,
            power: PowerKnobs::default(),
            solves: 0,
            solve_seconds: 0.0,
            total_nodes: 0,
            total_lp_pivots: 0,
            warm_started_solves: 0,
            builder,
        }
    }

    /// The throughput estimates behind the next `allocate` call changed
    /// (measurement or Problem 2 refinement round): invalidate the
    /// builder's stored pair scores and cached matrix.
    pub fn note_estimates_changed(&mut self) {
        self.builder.note_estimates_changed();
    }

    pub fn mean_solve_ms(&self) -> f64 {
        if self.solves == 0 {
            0.0
        } else {
            1000.0 * self.solve_seconds / self.solves as f64
        }
    }

    /// Mean simplex pivots per explored branch-and-bound node — the
    /// per-node cost metric the §Perf benches track.
    pub fn mean_pivots_per_node(&self) -> f64 {
        if self.total_nodes == 0 {
            0.0
        } else {
            self.total_lp_pivots as f64 / self.total_nodes as f64
        }
    }

    /// Solve Problem 1 for the active jobs and bind to instances.
    /// `throughput(a, j, c)` supplies T̃ (estimates or truth).
    pub fn allocate(
        &mut self,
        cluster: &Cluster,
        throughput: &dyn Fn(AccelType, JobId, &Combo) -> f64,
    ) -> Result<(Placement, AllocationSolution)> {
        let jobs: Vec<_> = {
            let mut v: Vec<_> = cluster.jobs().cloned().collect();
            v.sort_by_key(|j| j.id);
            v
        };
        // capacity = in-service instances only (AccelDown churn)
        let counts = crate::ilp::problem1::pool_accel_counts(&cluster.available_accels());
        let solo_cap = |a: AccelType| a.base_speed() / AccelType::V100.base_speed();
        let input = Problem1Input {
            jobs: &jobs,
            accel_counts: &counts,
            throughput,
            solo_capability: &solo_cap,
            max_pairs_per_job: self.cfg.max_pairs_per_job,
            slack_penalty: Some(self.cfg.slack_penalty),
            throughput_bonus: self.cfg.throughput_bonus,
            // inference latency floors (2e′) are sized at the cluster's
            // current simulated time
            now_s: cluster.now(),
            power: self.power,
        };
        let bnb = BnbConfig {
            max_nodes: self.cfg.max_nodes,
            time_limit_s: self.cfg.time_limit_s,
            auto_warm_start: self.cfg.warm_start,
            node_selection: self.cfg.node_selection,
            ..Default::default()
        };
        // gogh-lint: allow(determinism-wall-clock, solve_seconds is a reporting statistic; nothing branches on it)
        let t0 = std::time::Instant::now();
        self.builder.sync_jobs(&jobs, throughput);
        self.builder.set_accel_counts(counts.clone());
        let sol = self.builder.solve(&input, &bnb, None);
        self.solve_seconds += t0.elapsed().as_secs_f64();
        self.solves += 1;
        self.total_nodes += sol.nodes;
        self.total_lp_pivots += sol.lp_pivots;
        self.warm_started_solves += sol.warm_started as usize;

        let placement = bind_instances(cluster, &sol)?;
        Ok((placement, sol))
    }
}

/// Map (type, combo, multiplicity) onto concrete instances, preferring
/// instances that already host the same combo (stability → fewer
/// migrations).
fn bind_instances(cluster: &Cluster, sol: &AllocationSolution) -> Result<Placement> {
    let mut placement = Placement::new();
    // in-service instances per type, stable order
    let mut by_type: BTreeMap<AccelType, Vec<AccelId>> = BTreeMap::new();
    for a in cluster.available_accels() {
        by_type.entry(a.accel).or_default().push(a);
    }
    for v in by_type.values_mut() {
        v.sort();
    }
    let mut used: BTreeSet<AccelId> = BTreeSet::new();

    // pass 1: keep combos where they already run
    let mut remaining: Vec<(AccelType, Combo, u32)> = vec![];
    for &(a, combo, mult) in &sol.assignments {
        let mut left = mult;
        for aid in by_type.get(&a).map(|v| v.as_slice()).unwrap_or(&[]) {
            if left == 0 {
                break;
            }
            if used.contains(aid) {
                continue;
            }
            if cluster.placement.combo_on(*aid) == Some(&combo) {
                placement.assign(*aid, combo);
                used.insert(*aid);
                left -= 1;
            }
        }
        if left > 0 {
            remaining.push((a, combo, left));
        }
    }
    // pass 2: fill the rest onto free instances
    for (a, combo, mult) in remaining {
        let mut left = mult;
        for aid in by_type.get(&a).map(|v| v.as_slice()).unwrap_or(&[]) {
            if left == 0 {
                break;
            }
            if used.contains(aid) {
                continue;
            }
            placement.assign(*aid, combo);
            used.insert(*aid);
            left -= 1;
        }
        anyhow::ensure!(left == 0, "solution over-subscribes {a:?} (leftover {left})");
    }
    Ok(placement)
}

/// Bind a (local) allocation solution onto a restricted instance pool
/// as an incremental delta against the current placement. Combos that
/// already run on a pool instance stay put (no ops); everything else in
/// the pool is evicted and re-assigned. Instances outside the pool are
/// untouched — this is the delta the GOGH incremental arrival path
/// applies after its bounded neighborhood ILP.
///
/// Returns `None` when the pool cannot host the solution (the caller
/// falls back to a full re-solve).
pub(crate) fn bind_pool(
    cluster: &Cluster,
    pool: &[AccelId],
    sol: &AllocationSolution,
) -> Option<PlacementDelta> {
    let mut by_type: BTreeMap<AccelType, Vec<AccelId>> = BTreeMap::new();
    for a in pool {
        by_type.entry(a.accel).or_default().push(*a);
    }
    for v in by_type.values_mut() {
        v.sort();
    }
    let mut target: BTreeMap<AccelId, Combo> = BTreeMap::new();
    let mut used: BTreeSet<AccelId> = BTreeSet::new();
    // pass 1: keep combos where they already run
    let mut remaining: Vec<(AccelType, Combo, u32)> = vec![];
    for &(a, combo, mult) in &sol.assignments {
        let mut left = mult;
        for aid in by_type.get(&a).map(|v| v.as_slice()).unwrap_or(&[]) {
            if left == 0 {
                break;
            }
            if used.contains(aid) {
                continue;
            }
            if cluster.placement.combo_on(*aid) == Some(&combo) {
                target.insert(*aid, combo);
                used.insert(*aid);
                left -= 1;
            }
        }
        if left > 0 {
            remaining.push((a, combo, left));
        }
    }
    // pass 2: fill the rest
    for (a, combo, mult) in remaining {
        let mut left = mult;
        for aid in by_type.get(&a).map(|v| v.as_slice()).unwrap_or(&[]) {
            if left == 0 {
                break;
            }
            if used.contains(aid) {
                continue;
            }
            target.insert(*aid, combo);
            used.insert(*aid);
            left -= 1;
        }
        if left > 0 {
            return None;
        }
    }
    // pool-scoped delta: restrict both sides to the pool and reuse the
    // canonical evict-before-assign diff
    let mut current_pool = Placement::new();
    let mut target_pool = Placement::new();
    for aid in pool {
        if let Some(c) = cluster.placement.combo_on(*aid) {
            current_pool.assign(*aid, *c);
        }
        if let Some(c) = target.get(aid) {
            target_pool.assign(*aid, *c);
        }
    }
    Some(PlacementDelta::diff(&current_pool, &target_pool))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::workload::{JobSpec, ThroughputOracle};

    fn mk_cluster(n_jobs: u32) -> (Cluster, ThroughputOracle) {
        let oracle = ThroughputOracle::new(4);
        let mut c = Cluster::new(ClusterSpec::balanced(1));
        for i in 0..n_jobs {
            let f = crate::workload::FAMILIES[i as usize % 5];
            let b = f.batch_sizes()[0];
            let mut j = JobSpec {
                id: JobId(i),
                family: f,
                batch_size: b,
                replication: 1,
                min_throughput: 0.0,
                distributability: 1,
                work: 100.0,
                priority: Default::default(),
                elastic: false,
                inference: None,
            };
            j.min_throughput = 0.3 * oracle.solo(&j, AccelType::P100);
            c.add_job(j);
        }
        (c, oracle)
    }

    #[test]
    fn allocation_covers_all_jobs() {
        let (c, oracle) = mk_cluster(4);
        let jobs: Vec<JobSpec> = c.jobs().cloned().collect();
        let thr = move |a: AccelType, j: JobId, combo: &Combo| {
            let spec = jobs.iter().find(|s| s.id == j).unwrap();
            let lookup = |id: JobId| jobs.iter().find(|s| s.id == id).cloned();
            oracle.throughput(spec, combo, a, &lookup)
        };
        let mut opt = Optimizer::new(OptimizerConfig::default());
        let (p, sol) = opt.allocate(&c, &thr).unwrap();
        assert!(sol.violated_jobs.is_empty(), "{:?}", sol.violated_jobs);
        for i in 0..4 {
            assert!(p.is_placed(JobId(i)));
        }
        assert!(opt.mean_solve_ms() > 0.0);
        // solver stats: greedy incumbent seeded (soft mode), pivots tracked
        assert_eq!(opt.warm_started_solves, opt.solves);
        assert!(opt.mean_pivots_per_node() > 0.0);
    }

    #[test]
    fn rebinding_is_stable() {
        let (mut c, oracle) = mk_cluster(3);
        let jobs: Vec<JobSpec> = c.jobs().cloned().collect();
        let thr = move |a: AccelType, j: JobId, combo: &Combo| {
            let spec = jobs.iter().find(|s| s.id == j).unwrap();
            let lookup = |id: JobId| jobs.iter().find(|s| s.id == id).cloned();
            oracle.throughput(spec, combo, a, &lookup)
        };
        let mut opt = Optimizer::new(OptimizerConfig::default());
        let (p1, _) = opt.allocate(&c, &thr).unwrap();
        c.placement = p1.clone();
        // same jobs, same estimates → the rebound placement must be identical
        let (p2, _) = opt.allocate(&c, &thr).unwrap();
        assert_eq!(p1.diff_count(&p2), 0);
        // ... and the second solve must have reused the cached matrix
        assert!(opt.builder.model_reuses >= 1, "{}", opt.builder.model_reuses);
        assert_eq!(opt.builder.model_rebuilds, 1);
    }
}
