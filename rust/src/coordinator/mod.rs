//! L3 coordinator — the GOGH system contribution (paper §2).
//!
//! [`gogh::Gogh`] runs the online loop: job arrival → similarity lookup
//! → P1 initial estimates (Eq. 1) → ILP allocation (Problem 1) →
//! monitoring → P2 refinement across unobserved GPU types (Eq. 3/4) →
//! online training of both networks from measured data.

pub mod estimate_cache;
pub mod gogh;
pub mod history;
pub mod optimizer;
pub mod refinement;
pub mod scheduler;

pub use estimate_cache::{EstimateCache, EstimateCacheStats};
pub use gogh::{
    build_scheduler, Gogh, GoghBuilder, GoghOptions, GoghScheduler, LearningStats, ShardStats,
    SolverPathStats,
};
pub use optimizer::Optimizer;
pub use scheduler::{ClusterEvent, Decision, Scheduler, SimDriver};
