//! Metrics: estimation-error tracking (the paper's MAE/MSE), energy
//! accounting summaries, and report tables.

pub mod mae;
pub mod summary;

pub use mae::ErrorTracker;
pub use summary::{peak_rss_bytes, BenchRecord, LatencyHistogram, RunReport, SchedulerComparison};
