//! Streaming MAE / MSE tracking — the paper's Figure 2/3 metrics.

/// Accumulates absolute and squared errors.
#[derive(Debug, Clone, Default)]
pub struct ErrorTracker {
    n: u64,
    abs_sum: f64,
    sq_sum: f64,
    max_abs: f64,
}

impl ErrorTracker {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, predicted: f64, actual: f64) {
        let e = predicted - actual;
        self.n += 1;
        self.abs_sum += e.abs();
        self.sq_sum += e * e;
        self.max_abs = self.max_abs.max(e.abs());
    }

    pub fn merge(&mut self, other: &ErrorTracker) {
        self.n += other.n;
        self.abs_sum += other.abs_sum;
        self.sq_sum += other.sq_sum;
        self.max_abs = self.max_abs.max(other.max_abs);
    }

    pub fn n(&self) -> u64 {
        self.n
    }

    /// Mean absolute error (the paper's headline metric).
    pub fn mae(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.abs_sum / self.n as f64
        }
    }

    /// Mean squared error (the paper's training loss).
    pub fn mse(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.sq_sum / self.n as f64
        }
    }

    pub fn max_abs(&self) -> f64 {
        self.max_abs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mae_mse_basic() {
        let mut t = ErrorTracker::new();
        t.push(1.0, 0.0); // err 1
        t.push(0.0, 2.0); // err -2
        assert_eq!(t.n(), 2);
        assert!((t.mae() - 1.5).abs() < 1e-12);
        assert!((t.mse() - 2.5).abs() < 1e-12);
        assert_eq!(t.max_abs(), 2.0);
    }

    #[test]
    fn empty_is_nan() {
        assert!(ErrorTracker::new().mae().is_nan());
    }

    #[test]
    fn merge_matches_combined() {
        let mut a = ErrorTracker::new();
        let mut b = ErrorTracker::new();
        let mut all = ErrorTracker::new();
        for i in 0..10 {
            let (p, y) = (i as f64 * 0.1, 0.5);
            if i % 2 == 0 {
                a.push(p, y)
            } else {
                b.push(p, y)
            }
            all.push(p, y);
        }
        a.merge(&b);
        assert!((a.mae() - all.mae()).abs() < 1e-12);
        assert!((a.mse() - all.mse()).abs() < 1e-12);
    }
}
