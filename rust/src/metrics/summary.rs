//! Run reports, scheduler-comparison tables (the e2e bench output),
//! and the machine-readable bench record the CI regression gate
//! consumes.

/// Summary of one simulated run.
#[derive(Debug, Clone, Default)]
pub struct RunReport {
    pub scheduler: String,
    /// total simulated time (s)
    pub sim_seconds: f64,
    /// energy of busy accelerators (objective 2a integrated over time)
    pub energy_joules: f64,
    /// energy including idle accelerators
    pub total_energy_joules: f64,
    /// completed / total jobs
    pub jobs_completed: usize,
    pub jobs_total: usize,
    /// jobs cancelled by their owner before completing
    pub jobs_cancelled: usize,
    /// time-integral of unmet SLO (Σ max(0, T̄_j − T_j) dt)
    pub slo_deficit: f64,
    /// rounds in which ≥1 job was below its SLO
    pub slo_violations: usize,
    /// placement moves applied over the run (migration cost)
    pub migrations: usize,
    /// total restart-stall seconds charged for migrations
    pub migration_stall_s: f64,
    /// mean queueing delay: arrival → first placement (s)
    pub mean_queue_s: f64,
    /// cluster events dispatched to the policy
    pub events: usize,
    /// mean wall-clock policy latency per dispatched event (ms)
    pub mean_decision_ms: f64,
    /// p99 wall-clock policy latency per dispatched event (ms) — the
    /// tail the hierarchical decision path is sized against
    pub p99_decision_ms: f64,
    /// mean job completion time (s)
    pub mean_jct: f64,
    /// throughput-estimation MAE vs ground truth, if an estimator ran
    pub estimation_mae: Option<f64>,
    /// mean ILP solve latency (ms) on the decision path
    pub mean_solve_ms: f64,
    /// mean P1 inference latency (ms)
    pub mean_p1_ms: f64,
    /// inference-serving jobs in the trace (subset of `jobs_total`)
    pub inference_total: usize,
    /// inference jobs that completed their serving lifetime
    pub inference_completed: usize,
    /// completed inference jobs inside their latency SLO for at least
    /// [`crate::workload::serving::SLO_MET_FRACTION`] of their lifetime
    pub inference_slo_met: usize,
    /// time-weighted fraction of inference serving-time within SLO
    pub inference_attainment: f64,
    /// p50 of the time-weighted serving-latency distribution (s)
    pub inference_p50_latency_s: f64,
    /// p99 of the time-weighted serving-latency distribution (s)
    pub inference_p99_latency_s: f64,
    /// accelerator-seconds held by inference replicas (provisioning cost)
    pub replica_seconds: f64,
    /// replica scale-up events the policy's autoscaler applied
    pub scale_ups: u64,
    /// replica scale-down events the policy's autoscaler applied
    pub scale_downs: u64,
    /// peak instantaneous measured cluster draw over the run (W)
    pub power_peak_w: f64,
    /// configured cluster power cap, if any (W)
    pub power_cap_w: Option<f64>,
    /// fraction of integration intervals with measured draw ≤ cap
    /// (1.0 when uncapped — vacuously attained)
    pub power_cap_attainment: f64,
    /// cluster joules by DVFS state, `[low, nominal, turbo]`
    pub joules_by_state: [f64; 3],
    /// cumulative emissions under the carbon signal (g; 0 without one)
    pub grams_co2: f64,
    /// jobs parked by `Suspend` ops over the run (preemption count)
    pub preemptions: usize,
    /// job-seconds spent parked (summed across suspended jobs)
    pub suspended_seconds: f64,
    /// p99 finish-time fairness over completed training jobs: actual
    /// JCT ÷ ideal exclusive JCT (Gavel, PAPERS.md); 0 when none
    pub ftf_p99: f64,
    /// per-priority-tier SLO attainment `[best, standard, critical]`:
    /// fraction of each tier's scored seconds that met the SLO (parked
    /// seconds never count as attained; 1.0 for an empty tier)
    pub tier_attainment: [f64; 3],
}

impl RunReport {
    /// Energy per completed job — the headline efficiency number.
    pub fn joules_per_job(&self) -> f64 {
        if self.jobs_completed == 0 {
            f64::NAN
        } else {
            self.energy_joules / self.jobs_completed as f64
        }
    }

    /// One row of the comparison table.
    pub fn row(&self) -> String {
        format!(
            "{:<14} {:>10.0} {:>12.0} {:>7}/{:<4} {:>6} {:>9.3} {:>6} {:>7.1} {:>9} {:>7.1} \
             {:>4}/{:<4} {:>8.3} {:>6.3} {:>7} {:>8.0} {:>7.2}",
            self.scheduler,
            self.energy_joules,
            self.total_energy_joules,
            self.jobs_completed,
            self.jobs_total,
            self.jobs_cancelled,
            self.slo_deficit,
            self.slo_violations,
            self.mean_jct,
            self.migrations,
            self.mean_queue_s,
            self.inference_slo_met,
            self.inference_total,
            self.inference_p99_latency_s,
            self.inference_attainment,
            self.preemptions,
            self.suspended_seconds,
            self.ftf_p99,
        )
    }

    pub fn header() -> String {
        format!(
            "{:<14} {:>10} {:>12} {:>12} {:>6} {:>9} {:>6} {:>7} {:>9} {:>7} {:>9} {:>8} {:>6} \
             {:>7} {:>8} {:>7}",
            "scheduler",
            "busy_J",
            "total_J",
            "done/total",
            "cancel",
            "slo_def",
            "viols",
            "jct_s",
            "moves",
            "queue_s",
            "inf_met",
            "p99_lat",
            "attain",
            "preempt",
            "susp_s",
            "ftf_p99"
        )
    }
}

/// Exponentially-bucketed, time-weighted latency histogram: fixed
/// memory regardless of trace length, deterministic, and good to ~8%
/// relative quantile error (30 buckets per decade over 1 ms .. 1000 s).
/// The driver folds every integration interval's serving latency in,
/// weighted by the interval length; `quantile` reads p50/p99 back out.
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    weights: Vec<f64>,
    underflow: f64,
    overflow: f64,
    total: f64,
}

/// Buckets per decade of the latency histogram.
const LAT_PER_DECADE: f64 = 30.0;
/// Lower edge (seconds) of the first latency bucket.
const LAT_FLOOR_S: f64 = 1e-3;
/// Number of log-spaced buckets (6 decades: 1 ms .. 1000 s).
const LAT_BUCKETS: usize = 180;

impl LatencyHistogram {
    pub fn new() -> Self {
        Self {
            weights: vec![0.0; LAT_BUCKETS],
            underflow: 0.0,
            overflow: 0.0,
            total: 0.0,
        }
    }

    /// Fold in `weight` seconds spent at `latency_s`. Non-finite
    /// latencies (saturated/unplaced serving) land in the overflow
    /// bucket, so they drag the upper quantiles to infinity instead of
    /// vanishing.
    pub fn record(&mut self, latency_s: f64, weight: f64) {
        if weight <= 0.0 {
            return;
        }
        self.total += weight;
        if !latency_s.is_finite() {
            self.overflow += weight;
        } else if latency_s < LAT_FLOOR_S {
            self.underflow += weight;
        } else {
            let idx = ((latency_s / LAT_FLOOR_S).log10() * LAT_PER_DECADE) as usize;
            if idx >= LAT_BUCKETS {
                self.overflow += weight;
            } else {
                self.weights[idx] += weight;
            }
        }
    }

    /// Total recorded weight (seconds).
    pub fn total_weight(&self) -> f64 {
        self.total
    }

    /// Weighted quantile `q` ∈ [0, 1]: the upper edge of the bucket the
    /// cumulative weight crosses `q·total` in. `NAN` when empty,
    /// `INFINITY` when the quantile falls in the overflow bucket.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.total <= 0.0 {
            return f64::NAN;
        }
        let target = q.clamp(0.0, 1.0) * self.total;
        let mut cum = self.underflow;
        if cum >= target {
            return LAT_FLOOR_S;
        }
        for (i, w) in self.weights.iter().enumerate() {
            cum += w;
            if cum >= target {
                return LAT_FLOOR_S * 10f64.powf((i + 1) as f64 / LAT_PER_DECADE);
            }
        }
        f64::INFINITY
    }
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

/// One bench measurement in the `BENCH_<name>.json` schema: CI uploads
/// it as an artifact and fails the build when `mean_decision_ms`
/// regresses more than the gate's tolerance vs the committed baseline
/// (see `.github/scripts/bench_gate.py`).
#[derive(Debug, Clone, Default)]
pub struct BenchRecord {
    /// bench name, e.g. `"e2e_scheduling"`
    pub bench: String,
    /// trace size the measurement was taken at
    pub jobs: usize,
    /// mean per-event decision latency (ms) — the gated number
    pub mean_decision_ms: f64,
    /// p99 per-event decision latency (ms) — gated alongside the mean
    /// so a fat tail can't hide behind a healthy average
    pub p99_decision_ms: f64,
    /// total branch-and-bound nodes explored across the run
    pub explored_nodes: usize,
    /// peak resident set of the bench process (bytes; 0 off-Linux)
    pub peak_rss_bytes: u64,
}

impl BenchRecord {
    pub fn to_json(&self) -> crate::util::Json {
        crate::util::Json::obj(vec![
            ("bench", self.bench.as_str().into()),
            ("jobs", self.jobs.into()),
            ("mean_decision_ms", self.mean_decision_ms.into()),
            ("p99_decision_ms", self.p99_decision_ms.into()),
            ("explored_nodes", self.explored_nodes.into()),
            ("peak_rss_bytes", self.peak_rss_bytes.into()),
        ])
    }

    /// Write the record to `path` as JSON.
    pub fn write(&self, path: &std::path::Path) -> crate::Result<()> {
        std::fs::write(path, format!("{}\n", self.to_json()))?;
        Ok(())
    }
}

/// Peak resident set size of the current process in bytes, read from
/// `/proc/self/status` (`VmHWM`). Returns 0 on platforms without procfs
/// — callers must treat 0 as "unknown", not "tiny".
pub fn peak_rss_bytes() -> u64 {
    let Ok(status) = std::fs::read_to_string("/proc/self/status") else {
        return 0;
    };
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: u64 = rest.trim().trim_end_matches("kB").trim().parse().unwrap_or(0);
            return kb * 1024;
        }
    }
    0
}

/// Multiple runs side by side.
#[derive(Debug, Clone, Default)]
pub struct SchedulerComparison {
    pub reports: Vec<RunReport>,
}

impl SchedulerComparison {
    pub fn push(&mut self, r: RunReport) {
        self.reports.push(r);
    }

    pub fn table(&self) -> String {
        let mut s = RunReport::header();
        s.push('\n');
        for r in &self.reports {
            s.push_str(&r.row());
            s.push('\n');
        }
        s
    }

    /// Relative energy of each scheduler vs the first (baseline) row.
    pub fn energy_ratios(&self) -> Vec<(String, f64)> {
        let Some(base) = self.reports.first() else {
            return vec![];
        };
        self.reports
            .iter()
            .map(|r| (r.scheduler.clone(), r.energy_joules / base.energy_joules.max(1e-9)))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn joules_per_job() {
        let r = RunReport {
            energy_joules: 100.0,
            jobs_completed: 4,
            ..Default::default()
        };
        assert_eq!(r.joules_per_job(), 25.0);
    }

    #[test]
    fn bench_record_serializes_every_gated_field() {
        let r = BenchRecord {
            bench: "e2e_scheduling".into(),
            jobs: 300,
            mean_decision_ms: 1.25,
            p99_decision_ms: 4.5,
            explored_nodes: 42,
            peak_rss_bytes: 4096,
        };
        let j = crate::util::Json::parse(&r.to_json().to_string()).unwrap();
        assert_eq!(j.req_str("bench").unwrap(), "e2e_scheduling");
        assert_eq!(j.req_usize("jobs").unwrap(), 300);
        assert!((j.req_f64("mean_decision_ms").unwrap() - 1.25).abs() < 1e-12);
        assert!((j.req_f64("p99_decision_ms").unwrap() - 4.5).abs() < 1e-12);
        assert_eq!(j.req_usize("explored_nodes").unwrap(), 42);
        assert_eq!(j.req_usize("peak_rss_bytes").unwrap(), 4096);
    }

    #[test]
    fn peak_rss_reads_procfs_where_available() {
        let rss = peak_rss_bytes();
        if cfg!(target_os = "linux") {
            assert!(rss > 0, "VmHWM should be readable on Linux");
        }
    }

    #[test]
    fn latency_histogram_quantiles() {
        let mut h = LatencyHistogram::new();
        assert!(h.quantile(0.5).is_nan());
        // 99 seconds at 10 ms, 1 second saturated
        h.record(0.010, 99.0);
        h.record(f64::INFINITY, 1.0);
        assert_eq!(h.total_weight(), 100.0);
        let p50 = h.quantile(0.5);
        assert!(p50 >= 0.010 && p50 < 0.012, "p50 {p50}");
        // p99 still inside the 10 ms bucket, p100 pulled to overflow
        let p99 = h.quantile(0.99);
        assert!(p99 < 0.012, "p99 {p99}");
        assert_eq!(h.quantile(1.0), f64::INFINITY);
        // zero/negative weights and sub-floor latencies are safe
        h.record(0.5, 0.0);
        h.record(1e-9, 1.0);
        assert_eq!(h.quantile(0.0), 1e-3);
    }

    #[test]
    fn latency_histogram_orders_quantiles() {
        let mut h = LatencyHistogram::new();
        for (lat, w) in [(0.05, 50.0), (0.2, 30.0), (2.0, 15.0), (40.0, 5.0)] {
            h.record(lat, w);
        }
        let (p50, p90, p99) = (h.quantile(0.5), h.quantile(0.9), h.quantile(0.99));
        assert!(p50 <= p90 && p90 <= p99, "{p50} {p90} {p99}");
        assert!(p50 >= 0.05 && p50 < 0.06, "p50 {p50}");
        assert!(p99 >= 40.0 && p99 < 48.0, "p99 {p99}");
    }

    #[test]
    fn report_row_carries_inference_columns() {
        let r = RunReport {
            scheduler: "gogh".into(),
            inference_total: 7,
            inference_slo_met: 5,
            inference_attainment: 0.93,
            inference_p99_latency_s: 0.25,
            ..Default::default()
        };
        let row = r.row();
        assert!(row.contains("5/7"), "{row}");
        assert!(row.contains("0.930"), "{row}");
        assert!(RunReport::header().contains("inf_met"));
        assert!(RunReport::header().contains("attain"));
    }

    #[test]
    fn report_row_carries_priority_columns() {
        let r = RunReport {
            scheduler: "gogh".into(),
            preemptions: 3,
            suspended_seconds: 120.0,
            ftf_p99: 1.75,
            tier_attainment: [0.5, 0.8, 1.0],
            ..Default::default()
        };
        let row = r.row();
        assert!(row.contains("120"), "{row}");
        assert!(row.contains("1.75"), "{row}");
        for col in ["preempt", "susp_s", "ftf_p99"] {
            assert!(RunReport::header().contains(col), "missing {col}");
        }
    }

    #[test]
    fn table_has_all_rows() {
        let mut c = SchedulerComparison::default();
        for name in ["gogh", "random"] {
            c.push(RunReport {
                scheduler: name.into(),
                energy_joules: 10.0,
                ..Default::default()
            });
        }
        let t = c.table();
        assert!(t.contains("gogh") && t.contains("random"));
        assert_eq!(c.energy_ratios()[1].1, 1.0);
    }
}
