//! Run reports, scheduler-comparison tables (the e2e bench output),
//! and the machine-readable bench record the CI regression gate
//! consumes.

/// Summary of one simulated run.
#[derive(Debug, Clone, Default)]
pub struct RunReport {
    pub scheduler: String,
    /// total simulated time (s)
    pub sim_seconds: f64,
    /// energy of busy accelerators (objective 2a integrated over time)
    pub energy_joules: f64,
    /// energy including idle accelerators
    pub total_energy_joules: f64,
    /// completed / total jobs
    pub jobs_completed: usize,
    pub jobs_total: usize,
    /// jobs cancelled by their owner before completing
    pub jobs_cancelled: usize,
    /// time-integral of unmet SLO (Σ max(0, T̄_j − T_j) dt)
    pub slo_deficit: f64,
    /// rounds in which ≥1 job was below its SLO
    pub slo_violations: usize,
    /// placement moves applied over the run (migration cost)
    pub migrations: usize,
    /// total restart-stall seconds charged for migrations
    pub migration_stall_s: f64,
    /// mean queueing delay: arrival → first placement (s)
    pub mean_queue_s: f64,
    /// cluster events dispatched to the policy
    pub events: usize,
    /// mean wall-clock policy latency per dispatched event (ms)
    pub mean_decision_ms: f64,
    /// mean job completion time (s)
    pub mean_jct: f64,
    /// throughput-estimation MAE vs ground truth, if an estimator ran
    pub estimation_mae: Option<f64>,
    /// mean ILP solve latency (ms) on the decision path
    pub mean_solve_ms: f64,
    /// mean P1 inference latency (ms)
    pub mean_p1_ms: f64,
}

impl RunReport {
    /// Energy per completed job — the headline efficiency number.
    pub fn joules_per_job(&self) -> f64 {
        if self.jobs_completed == 0 {
            f64::NAN
        } else {
            self.energy_joules / self.jobs_completed as f64
        }
    }

    /// One row of the comparison table.
    pub fn row(&self) -> String {
        format!(
            "{:<14} {:>10.0} {:>12.0} {:>7}/{:<4} {:>6} {:>9.3} {:>6} {:>7.1} {:>9} {:>7.1}",
            self.scheduler,
            self.energy_joules,
            self.total_energy_joules,
            self.jobs_completed,
            self.jobs_total,
            self.jobs_cancelled,
            self.slo_deficit,
            self.slo_violations,
            self.mean_jct,
            self.migrations,
            self.mean_queue_s,
        )
    }

    pub fn header() -> String {
        format!(
            "{:<14} {:>10} {:>12} {:>12} {:>6} {:>9} {:>6} {:>7} {:>9} {:>7}",
            "scheduler",
            "busy_J",
            "total_J",
            "done/total",
            "cancel",
            "slo_def",
            "viols",
            "jct_s",
            "moves",
            "queue_s"
        )
    }
}

/// One bench measurement in the `BENCH_<name>.json` schema: CI uploads
/// it as an artifact and fails the build when `mean_decision_ms`
/// regresses more than the gate's tolerance vs the committed baseline
/// (see `.github/scripts/bench_gate.py`).
#[derive(Debug, Clone, Default)]
pub struct BenchRecord {
    /// bench name, e.g. `"e2e_scheduling"`
    pub bench: String,
    /// trace size the measurement was taken at
    pub jobs: usize,
    /// mean per-event decision latency (ms) — the gated number
    pub mean_decision_ms: f64,
    /// total branch-and-bound nodes explored across the run
    pub explored_nodes: usize,
    /// peak resident set of the bench process (bytes; 0 off-Linux)
    pub peak_rss_bytes: u64,
}

impl BenchRecord {
    pub fn to_json(&self) -> crate::util::Json {
        crate::util::Json::obj(vec![
            ("bench", self.bench.as_str().into()),
            ("jobs", self.jobs.into()),
            ("mean_decision_ms", self.mean_decision_ms.into()),
            ("explored_nodes", self.explored_nodes.into()),
            ("peak_rss_bytes", self.peak_rss_bytes.into()),
        ])
    }

    /// Write the record to `path` as JSON.
    pub fn write(&self, path: &std::path::Path) -> crate::Result<()> {
        std::fs::write(path, format!("{}\n", self.to_json()))?;
        Ok(())
    }
}

/// Peak resident set size of the current process in bytes, read from
/// `/proc/self/status` (`VmHWM`). Returns 0 on platforms without procfs
/// — callers must treat 0 as "unknown", not "tiny".
pub fn peak_rss_bytes() -> u64 {
    let Ok(status) = std::fs::read_to_string("/proc/self/status") else {
        return 0;
    };
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: u64 = rest.trim().trim_end_matches("kB").trim().parse().unwrap_or(0);
            return kb * 1024;
        }
    }
    0
}

/// Multiple runs side by side.
#[derive(Debug, Clone, Default)]
pub struct SchedulerComparison {
    pub reports: Vec<RunReport>,
}

impl SchedulerComparison {
    pub fn push(&mut self, r: RunReport) {
        self.reports.push(r);
    }

    pub fn table(&self) -> String {
        let mut s = RunReport::header();
        s.push('\n');
        for r in &self.reports {
            s.push_str(&r.row());
            s.push('\n');
        }
        s
    }

    /// Relative energy of each scheduler vs the first (baseline) row.
    pub fn energy_ratios(&self) -> Vec<(String, f64)> {
        let Some(base) = self.reports.first() else {
            return vec![];
        };
        self.reports
            .iter()
            .map(|r| (r.scheduler.clone(), r.energy_joules / base.energy_joules.max(1e-9)))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn joules_per_job() {
        let r = RunReport {
            energy_joules: 100.0,
            jobs_completed: 4,
            ..Default::default()
        };
        assert_eq!(r.joules_per_job(), 25.0);
    }

    #[test]
    fn bench_record_serializes_every_gated_field() {
        let r = BenchRecord {
            bench: "e2e_scheduling".into(),
            jobs: 300,
            mean_decision_ms: 1.25,
            explored_nodes: 42,
            peak_rss_bytes: 4096,
        };
        let j = crate::util::Json::parse(&r.to_json().to_string()).unwrap();
        assert_eq!(j.req_str("bench").unwrap(), "e2e_scheduling");
        assert_eq!(j.req_usize("jobs").unwrap(), 300);
        assert!((j.req_f64("mean_decision_ms").unwrap() - 1.25).abs() < 1e-12);
        assert_eq!(j.req_usize("explored_nodes").unwrap(), 42);
        assert_eq!(j.req_usize("peak_rss_bytes").unwrap(), 4096);
    }

    #[test]
    fn peak_rss_reads_procfs_where_available() {
        let rss = peak_rss_bytes();
        if cfg!(target_os = "linux") {
            assert!(rss > 0, "VmHWM should be readable on Linux");
        }
    }

    #[test]
    fn table_has_all_rows() {
        let mut c = SchedulerComparison::default();
        for name in ["gogh", "random"] {
            c.push(RunReport {
                scheduler: name.into(),
                energy_joules: 10.0,
                ..Default::default()
            });
        }
        let t = c.table();
        assert!(t.contains("gogh") && t.contains("random"));
        assert_eq!(c.energy_ratios()[1].1, 1.0);
    }
}
