//! `gogh` — CLI for the GOGH heterogeneous-cluster orchestrator.
//!
//! Subcommands:
//!   * `simulate [--policy gogh|random|greedy|oracle] [--jobs N] [--seed S] [--config cfg.json]`
//!   * `info [--workloads]`   — workload universe / accelerators / artifacts
//!   * `solve [--jobs N] [--servers-per-type K] [--seed S]` — one-shot Problem 1
//!   * `config`               — dump the default config JSON
//!
//! (Argument parsing is hand-rolled — offline build, see Cargo.toml.)

use gogh::baselines::{GreedyScheduler, OracleScheduler, RandomScheduler};
use gogh::config::{BackendKind, ExperimentConfig};
use gogh::coordinator::{Gogh, Scheduler, SimDriver};
use gogh::runtime::Engine;
use gogh::workload::{ThroughputOracle, Trace};
use gogh::Result;

struct Args {
    flags: std::collections::HashMap<String, String>,
    bools: std::collections::HashSet<String>,
}

impl Args {
    fn parse(argv: &[String]) -> Self {
        let mut flags = std::collections::HashMap::new();
        let mut bools = std::collections::HashSet::new();
        let mut i = 0;
        while i < argv.len() {
            if let Some(name) = argv[i].strip_prefix("--") {
                if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    flags.insert(name.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    bools.insert(name.to_string());
                    i += 1;
                }
            } else {
                i += 1;
            }
        }
        Self { flags, bools }
    }

    fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    fn get_parse<T: std::str::FromStr>(&self, name: &str) -> Option<T> {
        self.get(name).and_then(|v| v.parse().ok())
    }

    fn has(&self, name: &str) -> bool {
        self.bools.contains(name) || self.flags.contains_key(name)
    }
}

const USAGE: &str = "gogh — correlation-guided orchestration of GPUs in heterogeneous clusters

USAGE:
  gogh simulate [--policy gogh|random|greedy|oracle] [--jobs N] [--seed S]
                [--config cfg.json] [--preset default|large|mixed|serving]
                [--shards P] [--backend auto|pjrt|native|none]
                [--save-catalog catalog.json] [--gavel-csv data.csv]
                [--cancel-rate P] [--accel-churn N] [--migration-cost-s S]
                [--inference-fraction F]
  gogh info [--workloads]
  gogh solve [--jobs N] [--servers-per-type K] [--seed S]
  gogh config [--preset default|large|mixed|serving]

The `large` preset is the scale scenario: ≥1024 accelerator instances,
a ≥50k-event trace, and the shard-parallel decision path (--shards
overrides the shard count; 1 = the single-threaded path).

The `mixed` and `serving` presets add the inference workload class:
a fraction of arrivals (--inference-fraction overrides it) are
latency-SLO serving jobs scaled across 1..R replicas, with GOGH
autoscaling replicas on monitor ticks.

--backend picks the P1/P2 estimator engine: `pjrt` (AOT artifacts,
errors if absent), `native` (pure-Rust MLP, zero artifacts), `none`
(estimator-free catalog priors), or `auto` (default: pjrt when
artifacts load, else native, with a warning naming the one used).
";

fn main() {
    if let Err(e) = run() {
        // one clear line, never a panic/backtrace (e.g. `--backend
        // pjrt` without an artifact dir)
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first().cloned() else {
        print!("{USAGE}");
        return Ok(());
    };
    let args = Args::parse(&argv[1..]);
    match cmd.as_str() {
        "simulate" => simulate(&args),
        "info" => info(&args),
        "solve" => solve(&args),
        "config" => {
            let cfg = ExperimentConfig::preset(args.get("preset").unwrap_or("default"))?;
            println!("{}", cfg.to_json());
            Ok(())
        }
        _ => {
            print!("{USAGE}");
            Ok(())
        }
    }
}

fn load_cfg(args: &Args) -> Result<ExperimentConfig> {
    let mut cfg = match (args.get("config"), args.get("preset")) {
        (Some(_), Some(_)) => anyhow::bail!("--config and --preset are mutually exclusive"),
        (Some(p), None) => ExperimentConfig::load(std::path::Path::new(p))?,
        (None, Some(name)) => ExperimentConfig::preset(name)?,
        (None, None) => ExperimentConfig::default(),
    };
    if let Some(n) = args.get_parse::<usize>("jobs") {
        cfg.trace.n_jobs = n;
    }
    if let Some(p) = args.get_parse::<usize>("shards") {
        cfg.gogh.shards = p.max(1);
    }
    if let Some(b) = args.get("backend") {
        cfg.gogh.backend = BackendKind::from_key(b)?;
    }
    if let Some(s) = args.get_parse::<u64>("seed") {
        cfg.seed = s;
        cfg.trace.seed = s;
    }
    if let Some(p) = args.get("gavel-csv") {
        cfg.gavel_csv = Some(p.to_string());
    }
    if let Some(r) = args.get_parse::<f64>("cancel-rate") {
        cfg.trace.cancel_rate = r;
    }
    if let Some(f) = args.get_parse::<f64>("inference-fraction") {
        cfg.trace.inference_fraction = f.clamp(0.0, 1.0);
    }
    if let Some(n) = args.get_parse::<f64>("accel-churn") {
        cfg.trace.accel_churn = n;
    }
    if let Some(s) = args.get_parse::<f64>("migration-cost-s") {
        cfg.migration_cost_s = s;
    }
    Ok(cfg)
}

fn simulate(args: &Args) -> Result<()> {
    let cfg = load_cfg(args)?;
    let policy = args.get("policy").unwrap_or("gogh");
    let report = match policy {
        "gogh" => {
            // backend resolution (pjrt/native/none, or the auto ladder
            // with its fallback warning) lives in Gogh::from_config;
            // explicit `--backend pjrt` without artifacts errors out
            let mut sys = Gogh::from_config(&cfg)?;
            let backend_used = sys.backend_name();
            let report = sys.run()?;
            let stats = sys.scheduler().solver_stats();
            let cache = sys.scheduler().cache_stats();
            let learn = sys.scheduler().learning_stats();
            println!(
                "learning loop: backend {}, {} refinement rounds, \
                 {} P1 train steps ({} online), {} P2 train steps ({} online)",
                backend_used,
                learn.refinement_rounds,
                learn.p1_train_steps,
                learn.p1_online_steps,
                learn.p2_train_steps,
                learn.p2_online_steps
            );
            if learn.inference_measurements > 0 {
                println!(
                    "inference learning: {} inference measurements fed the \
                     P2 refinement loop",
                    learn.inference_measurements
                );
            }
            println!(
                "solver paths: {} full ({:.1} nodes/solve), {} incremental \
                 ({:.1} nodes/solve); estimate cache {:.1}% hit over {} lookups",
                stats.full_solves,
                stats.mean_full_nodes(),
                stats.incremental_solves,
                stats.mean_incremental_nodes(),
                100.0 * cache.hit_rate(),
                cache.hits + cache.misses,
            );
            if cfg.gogh.shards > 1 {
                // stats are sized by the requested shard count; the
                // partition clamps to the cluster size, so skip slots
                // that never solved
                for (i, s) in sys.scheduler().shard_stats().iter().enumerate() {
                    if s.solves == 0 {
                        continue;
                    }
                    println!(
                        "  shard {i}: {} solves ({:.1} nodes/solve), {} jobs routed",
                        s.solves,
                        s.mean_nodes(),
                        s.routed
                    );
                }
            }
            // checkpoint the learned catalog for later sessions
            if let Some(path) = args.get("save-catalog") {
                sys.scheduler().catalog.save(std::path::Path::new(path))?;
                println!("catalog saved to {path}");
            }
            report
        }
        other => {
            let oracle = cfg.build_oracle()?;
            let trace = Trace::generate(&cfg.trace, &oracle);
            let spec = gogh::cluster::ClusterSpec::mix(&cfg.cluster.accel_mix);
            // monitor_interval_s is validated (once) by SimDriver::new
            let mut driver = SimDriver::new(
                spec,
                oracle.clone(),
                trace,
                cfg.noise_sigma,
                cfg.monitor_interval_s,
                cfg.seed,
            )?
            .with_migration_cost(cfg.migration_cost_s);
            let mut sched: Box<dyn Scheduler> = match other {
                "random" => Box::new(RandomScheduler::new(cfg.seed)),
                "greedy" => Box::new(GreedyScheduler::new()),
                "oracle" => Box::new(OracleScheduler::new(oracle, cfg.optimizer.clone())),
                _ => anyhow::bail!("unknown policy {other:?} (want gogh|random|greedy|oracle)"),
            };
            driver.run(sched.as_mut())?
        }
    };
    println!("{}", gogh::metrics::RunReport::header());
    println!("{}", report.row());
    if let Some(mae) = report.estimation_mae {
        println!("estimation MAE vs measured: {mae:.4}");
    }
    println!(
        "decision path: ILP {:.2} ms, P1 {:.2} ms, {:.3} ms/event over {} events",
        report.mean_solve_ms, report.mean_p1_ms, report.mean_decision_ms, report.events
    );
    println!(
        "completed {}/{} jobs ({} cancelled, mean queue {:.1} s, \
         migration stall {:.0} s)",
        report.jobs_completed,
        report.jobs_total,
        report.jobs_cancelled,
        report.mean_queue_s,
        report.migration_stall_s
    );
    if report.inference_total > 0 {
        println!(
            "inference: {}/{} jobs met latency SLO (attainment {:.3}, \
             p50 {:.3} s, p99 {:.3} s, {} scale-ups, {} scale-downs, \
             {:.0} replica-seconds)",
            report.inference_slo_met,
            report.inference_total,
            report.inference_attainment,
            report.inference_p50_latency_s,
            report.inference_p99_latency_s,
            report.scale_ups,
            report.scale_downs,
            report.replica_seconds
        );
    }
    Ok(())
}

fn info(args: &Args) -> Result<()> {
    println!("accelerator types (θ=2 each):");
    for a in gogh::workload::ACCEL_TYPES {
        let (idle, extra) = a.power_params();
        println!(
            "  {:<22} speed {:.2}x  power {}+{} W",
            a.name(),
            a.base_speed(),
            idle,
            extra
        );
    }
    if args.has("workloads") {
        println!("\nTable 2 workload universe:");
        for f in gogh::workload::FAMILIES {
            println!("  {:<16} batches {:?}", f.name(), f.batch_sizes());
        }
    }
    if std::path::Path::new("artifacts/manifest.json").exists() {
        let engine = Engine::load("artifacts")?;
        println!("\nAOT artifacts:");
        let mut keys: Vec<_> = engine.manifest().models.keys().collect();
        keys.sort();
        for k in keys {
            let m = &engine.manifest().models[k];
            println!(
                "  {:<16} {} params, in {}→{}",
                k, m.param_count, m.input_dim, m.padded_dim
            );
        }
    }
    Ok(())
}

fn solve(args: &Args) -> Result<()> {
    use gogh::cluster::{Cluster, ClusterSpec};
    use gogh::workload::{JobId, JobSpec, FAMILIES};
    let jobs: u32 = args.get_parse("jobs").unwrap_or(8);
    let servers_per_type: u32 = args.get_parse("servers-per-type").unwrap_or(2);
    let seed: u64 = args.get_parse("seed").unwrap_or(17);

    let oracle = ThroughputOracle::new(seed);
    let mut cluster = Cluster::new(ClusterSpec::balanced(servers_per_type));
    for i in 0..jobs {
        let f = FAMILIES[i as usize % FAMILIES.len()];
        let b = f.batch_sizes()[i as usize % f.batch_sizes().len()];
        let mut j = JobSpec {
            id: JobId(i),
            family: f,
            batch_size: b,
            replication: 1,
            min_throughput: 0.0,
            distributability: 2,
            work: 100.0,
            inference: None,
        };
        j.min_throughput = 0.4 * oracle.solo(&j, gogh::workload::AccelType::P100);
        cluster.add_job(j);
    }
    let all_jobs: Vec<JobSpec> = cluster.jobs().cloned().collect();
    let thr = {
        let oracle = oracle.clone();
        move |a, j: JobId, c: &gogh::workload::Combo| {
            let spec = all_jobs.iter().find(|s| s.id == j).unwrap();
            let lookup = |id: JobId| all_jobs.iter().find(|s| s.id == id).cloned();
            oracle.throughput(spec, c, a, &lookup)
        }
    };
    let mut opt = gogh::coordinator::Optimizer::new(gogh::config::OptimizerConfig::default());
    let t0 = std::time::Instant::now();
    let (placement, sol) = opt.allocate(&cluster, &thr)?;
    println!(
        "solved {} jobs on {} instances in {:.1} ms ({} B&B nodes, objective {:.1} W)",
        jobs,
        cluster.spec.len(),
        t0.elapsed().as_secs_f64() * 1000.0,
        sol.nodes,
        sol.objective
    );
    let mut rows: Vec<String> = placement
        .iter()
        .map(|(a, c)| format!("  {a} <- {c:?}"))
        .collect();
    rows.sort();
    for r in rows {
        println!("{r}");
    }
    Ok(())
}
