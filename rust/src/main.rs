//! `gogh` — CLI for the GOGH heterogeneous-cluster orchestrator.
//!
//! Subcommands (full flag reference: docs/CLI.md):
//!   * `simulate [--policy gogh|random|greedy|oracle] [--jobs N] [--seed S] [--config cfg.json]`
//!   * `info [--workloads]`   — workload universe / accelerators / artifacts
//!   * `solve [--jobs N] [--servers-per-type K] [--seed S]` — one-shot Problem 1
//!   * `config`               — dump the default config JSON
//!   * `submit|queue|cancel|status|drain` — clients for a running `goghd`
//!
//! (Argument parsing is hand-rolled — offline build, see Cargo.toml.)

use gogh::baselines::{GavelRoundsScheduler, GreedyScheduler, OracleScheduler, RandomScheduler};
use gogh::config::{BackendKind, CarbonConfig, ExperimentConfig};
use gogh::coordinator::{Gogh, Scheduler, SimDriver};
use gogh::daemon::{JobRequest, Request};
use gogh::engine::EngineOptions;
use gogh::runtime::Engine;
use gogh::util::{Args, Json};
use gogh::workload::{InferenceSpec, Priority, ThroughputOracle, Trace, FAMILIES};
use gogh::Result;

const USAGE: &str = "gogh — correlation-guided orchestration of GPUs in heterogeneous clusters

USAGE:
  gogh simulate [--policy gogh|random|greedy|oracle|gavel] [--jobs N] [--seed S]
                [--config cfg.json]
                [--preset default|large|huge|mixed|serving|powercap|carbon|
                          priority|burst|contended]
                [--shards P] [--topology G] [--backend auto|pjrt|native|none]
                [--save-catalog catalog.json] [--gavel-csv data.csv]
                [--cancel-rate P] [--accel-churn N] [--migration-cost-s S]
                [--inference-fraction F] [--power-cap W]
                [--power-dvfs true|false] [--carbon-trace signal.json]
                [--preemption true|false]
  gogh info [--workloads]
  gogh solve [--jobs N] [--servers-per-type K] [--seed S]
  gogh config [--preset default|large|huge|mixed|serving|powercap|carbon|
                        priority|burst|contended]

Daemon clients (talk to a running goghd; see docs/PROTOCOL.md):
  gogh submit --family NAME --work S [--batch N] [--min-throughput F]
              [--distributability N] [--priority best|standard|critical]
              [--rate R --latency-slo S]
              [--diurnal-amplitude A] [--diurnal-phase-s P]
  gogh submit --file jobs.json        (a JSON array of job objects)
  gogh queue | status | drain
  gogh cancel --job N
All five accept --addr HOST:PORT (default 127.0.0.1:7411) or
--socket PATH to pick the daemon endpoint.

The `large` preset is the scale scenario: ≥1024 accelerator instances,
a ≥50k-event trace, and the shard-parallel decision path (--shards
overrides the shard count; 1 = the single-threaded path). The `huge`
preset is the fleet scenario: ≥10k instances, a ≥500k-event trace, and
the two-level topology router (--topology overrides the group count;
each group holds --shards shards, and arrivals are routed to one group
before its shards solve in parallel).

The `mixed` and `serving` presets add the inference workload class:
a fraction of arrivals (--inference-fraction overrides it) are
latency-SLO serving jobs scaled across 1..R replicas, with GOGH
autoscaling replicas on monitor ticks.

The `powercap` and `carbon` presets turn on the power subsystem
(docs/POWER.md): per-accelerator DVFS states with a cluster power cap,
resp. a diurnal grid carbon signal. --power-cap sets/overrides the cap
in watts, --power-dvfs toggles the DVFS layer, and --carbon-trace reads
a {\"base_gco2_per_kwh\", \"amplitude\", \"phase_s\"} JSON signal.

The `priority`, `burst`, and `contended` presets mix priority tiers
(best/standard/critical) and elastic training jobs into the trace and
turn on GOGH's preemption path: when capacity is tight a critical
arrival may park (`Suspend`) best-effort jobs, which resume later
without losing progress. --preemption toggles the path; the `gavel`
policy is the round-based finish-time-fairness baseline it is scored
against.

--backend picks the P1/P2 estimator engine: `pjrt` (AOT artifacts,
errors if absent), `native` (pure-Rust MLP, zero artifacts), `none`
(estimator-free catalog priors), or `auto` (default: pjrt when
artifacts load, else native, with a warning naming the one used).
";

fn main() {
    if let Err(e) = run() {
        // one clear line, never a panic/backtrace (e.g. `--backend
        // pjrt` without an artifact dir)
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first().cloned() else {
        print!("{USAGE}");
        return Ok(());
    };
    let args = Args::parse(&argv[1..]);
    match cmd.as_str() {
        "simulate" => simulate(&args),
        "info" => info(&args),
        "solve" => solve(&args),
        "config" => {
            let cfg = ExperimentConfig::preset(args.get("preset").unwrap_or("default"))?;
            println!("{}", cfg.to_json());
            Ok(())
        }
        "submit" => submit(&args),
        "queue" => queue(&args),
        "cancel" => cancel(&args),
        "status" => status(&args),
        "drain" => drain(&args),
        _ => {
            print!("{USAGE}");
            Ok(())
        }
    }
}

fn load_cfg(args: &Args) -> Result<ExperimentConfig> {
    let mut cfg = match (args.get("config"), args.get("preset")) {
        (Some(_), Some(_)) => anyhow::bail!("--config and --preset are mutually exclusive"),
        (Some(p), None) => ExperimentConfig::load(std::path::Path::new(p))?,
        (None, Some(name)) => ExperimentConfig::preset(name)?,
        (None, None) => ExperimentConfig::default(),
    };
    if let Some(n) = args.get_parse::<usize>("jobs") {
        cfg.trace.n_jobs = n;
    }
    if let Some(p) = args.get_parse::<usize>("shards") {
        cfg.gogh.shards = p.max(1);
    }
    if let Some(g) = args.get_parse::<usize>("topology") {
        cfg.gogh.topology_groups = g.max(1);
    }
    if let Some(b) = args.get("backend") {
        cfg.gogh.backend = BackendKind::from_key(b)?;
    }
    if let Some(s) = args.get_parse::<u64>("seed") {
        cfg.seed = s;
        cfg.trace.seed = s;
    }
    if let Some(p) = args.get("gavel-csv") {
        cfg.gavel_csv = Some(p.to_string());
    }
    if let Some(r) = args.get_parse::<f64>("cancel-rate") {
        cfg.trace.cancel_rate = r;
    }
    if let Some(f) = args.get_parse::<f64>("inference-fraction") {
        cfg.trace.inference_fraction = f.clamp(0.0, 1.0);
    }
    if let Some(n) = args.get_parse::<f64>("accel-churn") {
        cfg.trace.accel_churn = n;
    }
    if let Some(s) = args.get_parse::<f64>("migration-cost-s") {
        cfg.migration_cost_s = s;
    }
    if let Some(w) = args.get_parse::<f64>("power-cap") {
        cfg.power.cap_w = Some(w);
    }
    if let Some(d) = args.get_parse::<bool>("power-dvfs") {
        cfg.power.dvfs = d;
    }
    if let Some(p) = args.get("carbon-trace") {
        let text = std::fs::read_to_string(p).map_err(|e| anyhow::anyhow!("{p}: {e}"))?;
        cfg.power.carbon =
            CarbonConfig::from_json(&text).map_err(|e| anyhow::anyhow!("{p}: {e}"))?;
    }
    if let Some(p) = args.get_parse::<bool>("preemption") {
        cfg.gogh.preemption = p;
    }
    Ok(cfg)
}

fn simulate(args: &Args) -> Result<()> {
    let cfg = load_cfg(args)?;
    let policy = args.get("policy").unwrap_or("gogh");
    let report = match policy {
        "gogh" => {
            // backend resolution (pjrt/native/none, or the auto ladder
            // with its fallback warning) lives in Gogh::from_config;
            // explicit `--backend pjrt` without artifacts errors out
            let mut sys = Gogh::from_config(&cfg)?;
            let backend_used = sys.backend_name();
            let report = sys.run()?;
            let stats = sys.scheduler().solver_stats();
            let cache = sys.scheduler().cache_stats();
            let learn = sys.scheduler().learning_stats();
            println!(
                "learning loop: backend {}, {} refinement rounds, \
                 {} P1 train steps ({} online), {} P2 train steps ({} online)",
                backend_used,
                learn.refinement_rounds,
                learn.p1_train_steps,
                learn.p1_online_steps,
                learn.p2_train_steps,
                learn.p2_online_steps
            );
            if learn.inference_measurements > 0 {
                println!(
                    "inference learning: {} inference measurements fed the \
                     P2 refinement loop",
                    learn.inference_measurements
                );
            }
            println!(
                "solver paths: {} full ({:.1} nodes/solve), {} incremental \
                 ({:.1} nodes/solve); estimate cache {:.1}% hit \
                 ({} hits / {} misses, {} invalidation rounds)",
                stats.full_solves,
                stats.mean_full_nodes(),
                stats.incremental_solves,
                stats.mean_incremental_nodes(),
                100.0 * cache.hit_rate(),
                cache.hits,
                cache.misses,
                cache.invalidations,
            );
            if cfg.gogh.shards > 1 || cfg.gogh.topology_groups > 1 {
                // stats are sized by the requested shard count; the
                // topology clamps to the cluster size, so skip slots
                // that never solved
                for (i, s) in sys.scheduler().shard_stats().iter().enumerate() {
                    if s.solves == 0 {
                        continue;
                    }
                    println!(
                        "  shard {i}: {} solves ({:.1} nodes/solve), {} jobs routed",
                        s.solves,
                        s.mean_nodes(),
                        s.routed
                    );
                }
            }
            // checkpoint the learned catalog for later sessions
            if let Some(path) = args.get("save-catalog") {
                sys.scheduler().catalog.save(std::path::Path::new(path))?;
                println!("catalog saved to {path}");
            }
            report
        }
        other => {
            let oracle = cfg.build_oracle()?;
            let trace = Trace::generate(&cfg.trace, &oracle);
            let spec = gogh::cluster::ClusterSpec::mix(&cfg.cluster.accel_mix);
            // monitor_interval_s is validated (once) by SimDriver::new
            let mut driver = SimDriver::new(
                spec,
                oracle.clone(),
                trace,
                cfg.noise_sigma,
                cfg.monitor_interval_s,
                cfg.seed,
            )?
            .with_options(
                EngineOptions::new()
                    .with_migration_cost(cfg.migration_cost_s)
                    .with_power_cap(cfg.power.cap_w)
                    .with_carbon(cfg.power.carbon.signal()),
            );
            let mut sched: Box<dyn Scheduler> = match other {
                "random" => Box::new(RandomScheduler::new(cfg.seed)),
                "greedy" => Box::new(GreedyScheduler::new()),
                "oracle" => Box::new(OracleScheduler::new(oracle, cfg.optimizer.clone())),
                "gavel" => Box::new(GavelRoundsScheduler::new(oracle)),
                _ => {
                    anyhow::bail!("unknown policy {other:?} (want gogh|random|greedy|oracle|gavel)")
                }
            };
            driver.run(sched.as_mut())?
        }
    };
    println!("{}", gogh::metrics::RunReport::header());
    println!("{}", report.row());
    if let Some(mae) = report.estimation_mae {
        println!("estimation MAE vs measured: {mae:.4}");
    }
    println!(
        "decision path: ILP {:.2} ms, P1 {:.2} ms, {:.3} ms/event \
         (p99 {:.3} ms) over {} events",
        report.mean_solve_ms,
        report.mean_p1_ms,
        report.mean_decision_ms,
        report.p99_decision_ms,
        report.events
    );
    println!(
        "completed {}/{} jobs ({} cancelled, mean queue {:.1} s, \
         migration stall {:.0} s)",
        report.jobs_completed,
        report.jobs_total,
        report.jobs_cancelled,
        report.mean_queue_s,
        report.migration_stall_s
    );
    if report.inference_total > 0 {
        println!(
            "inference: {}/{} jobs met latency SLO (attainment {:.3}, \
             p50 {:.3} s, p99 {:.3} s, {} scale-ups, {} scale-downs, \
             {:.0} replica-seconds)",
            report.inference_slo_met,
            report.inference_total,
            report.inference_attainment,
            report.inference_p50_latency_s,
            report.inference_p99_latency_s,
            report.scale_ups,
            report.scale_downs,
            report.replica_seconds
        );
    }
    // emitted whenever the power subsystem was active (cap set, DVFS
    // re-stated something, or a carbon signal priced emissions) — the
    // CI power smokes grep and parse this line
    let [j_low, j_nominal, j_turbo] = report.joules_by_state;
    let power_active = report.power_cap_w.is_some()
        || report.grams_co2 > 0.0
        || j_low > 0.0
        || j_turbo > 0.0;
    if power_active {
        println!(
            "power: peak {:.0} W / cap {} W, attainment {:.3}, {:.0} J total \
             (low {:.0} J, nominal {:.0} J, turbo {:.0} J), {:.1} gCO2",
            report.power_peak_w,
            report.power_cap_w.map_or("-".to_string(), |c| format!("{c:.0}")),
            report.power_cap_attainment,
            report.energy_joules,
            j_low,
            j_nominal,
            j_turbo,
            report.grams_co2
        );
    }
    // emitted whenever priority tiers are in play (tiered/elastic
    // trace, preemption enabled, or any job actually parked) — the CI
    // priority smoke greps and parses this line
    let priority_active = cfg.trace.critical_fraction > 0.0
        || cfg.trace.best_fraction > 0.0
        || cfg.trace.elastic_fraction > 0.0
        || cfg.gogh.preemption
        || report.preemptions > 0
        || report.suspended_seconds > 0.0;
    if priority_active {
        let [best, standard, critical] = report.tier_attainment;
        println!(
            "priority: {} preemptions, {:.0} s suspended, attainment best {:.3} / \
             standard {:.3} / critical {:.3}, ftf p99 {:.2}",
            report.preemptions, report.suspended_seconds, best, standard, critical, report.ftf_p99
        );
    }
    Ok(())
}

fn info(args: &Args) -> Result<()> {
    println!("accelerator types (θ=2 each):");
    for a in gogh::workload::ACCEL_TYPES {
        let (idle, extra) = a.power_params();
        println!(
            "  {:<22} speed {:.2}x  power {}+{} W",
            a.name(),
            a.base_speed(),
            idle,
            extra
        );
    }
    if args.has("workloads") {
        println!("\nTable 2 workload universe:");
        for f in gogh::workload::FAMILIES {
            println!("  {:<16} batches {:?}", f.name(), f.batch_sizes());
        }
    }
    if std::path::Path::new("artifacts/manifest.json").exists() {
        let engine = Engine::load("artifacts")?;
        println!("\nAOT artifacts:");
        let mut keys: Vec<_> = engine.manifest().models.keys().collect();
        keys.sort();
        for k in keys {
            let m = &engine.manifest().models[k];
            println!(
                "  {:<16} {} params, in {}→{}",
                k, m.param_count, m.input_dim, m.padded_dim
            );
        }
    }
    Ok(())
}

fn solve(args: &Args) -> Result<()> {
    use gogh::cluster::{Cluster, ClusterSpec};
    use gogh::workload::{JobId, JobSpec};
    let jobs: u32 = args.get_parse("jobs").unwrap_or(8);
    let servers_per_type: u32 = args.get_parse("servers-per-type").unwrap_or(2);
    let seed: u64 = args.get_parse("seed").unwrap_or(17);

    let oracle = ThroughputOracle::new(seed);
    let mut cluster = Cluster::new(ClusterSpec::balanced(servers_per_type));
    for i in 0..jobs {
        let f = FAMILIES[i as usize % FAMILIES.len()];
        let b = f.batch_sizes()[i as usize % f.batch_sizes().len()];
        let mut j = JobSpec {
            id: JobId(i),
            family: f,
            batch_size: b,
            replication: 1,
            min_throughput: 0.0,
            distributability: 2,
            work: 100.0,
            priority: Default::default(),
            elastic: false,
            inference: None,
        };
        j.min_throughput = 0.4 * oracle.solo(&j, gogh::workload::AccelType::P100);
        cluster.add_job(j);
    }
    let all_jobs: Vec<JobSpec> = cluster.jobs().cloned().collect();
    let thr = {
        let oracle = oracle.clone();
        move |a, j: JobId, c: &gogh::workload::Combo| {
            // unknown job id contributes nothing rather than panicking
            let Some(spec) = all_jobs.iter().find(|s| s.id == j) else {
                return 0.0;
            };
            let lookup = |id: JobId| all_jobs.iter().find(|s| s.id == id).cloned();
            oracle.throughput(spec, c, a, &lookup)
        }
    };
    let mut opt = gogh::coordinator::Optimizer::new(gogh::config::OptimizerConfig::default());
    let t0 = std::time::Instant::now();
    let (placement, sol) = opt.allocate(&cluster, &thr)?;
    println!(
        "solved {} jobs on {} instances in {:.1} ms ({} B&B nodes, objective {:.1} W)",
        jobs,
        cluster.spec.len(),
        t0.elapsed().as_secs_f64() * 1000.0,
        sol.nodes,
        sol.objective
    );
    let mut rows: Vec<String> = placement
        .iter()
        .map(|(a, c)| format!("  {a} <- {c:?}"))
        .collect();
    rows.sort();
    for r in rows {
        println!("{r}");
    }
    Ok(())
}

// ---- goghd clients -----------------------------------------------------

/// Send one request line to the daemon named by --addr/--socket and
/// return the parsed response body, turning protocol-level errors
/// (`"ok": false`) into CLI errors.
fn daemon_request(args: &Args, req: &Request) -> Result<Json> {
    use std::io::{BufRead as _, BufReader, Write as _};
    let line = req.to_json().to_string();
    let mut response = String::new();
    match (args.get("socket"), args.get("addr")) {
        (Some(_), Some(_)) => anyhow::bail!("--socket and --addr are mutually exclusive"),
        (Some(path), None) => {
            let mut s = std::os::unix::net::UnixStream::connect(path)
                .map_err(|e| anyhow::anyhow!("connecting to goghd at {path}: {e}"))?;
            writeln!(s, "{line}")?;
            BufReader::new(s).read_line(&mut response)?;
        }
        (None, addr) => {
            let addr = addr.unwrap_or("127.0.0.1:7411");
            let mut s = std::net::TcpStream::connect(addr)
                .map_err(|e| anyhow::anyhow!("connecting to goghd at {addr}: {e}"))?;
            writeln!(s, "{line}")?;
            BufReader::new(s).read_line(&mut response)?;
        }
    }
    anyhow::ensure!(!response.trim().is_empty(), "goghd closed the connection mid-request");
    let v = Json::parse(response.trim())?;
    if v.get("ok").and_then(Json::as_bool) == Some(true) {
        Ok(v)
    } else {
        let err = v.get("error");
        let code = err.and_then(|e| e.get("code")).and_then(Json::as_str).unwrap_or("internal");
        let msg = err
            .and_then(|e| e.get("message"))
            .and_then(Json::as_str)
            .unwrap_or("malformed error response");
        anyhow::bail!("goghd refused the request ({code}): {msg}")
    }
}

/// Build one job from `gogh submit` flags (--family/--work plus
/// optional shape and serving flags).
fn job_from_flags(args: &Args) -> Result<JobRequest> {
    let family_name = args
        .get("family")
        .ok_or_else(|| anyhow::anyhow!("--family is required (see `gogh info --workloads`)"))?;
    let family = FAMILIES
        .iter()
        .copied()
        .find(|f| f.name() == family_name)
        .ok_or_else(|| anyhow::anyhow!("unknown model family {family_name:?}"))?;
    let work = args
        .get_parse::<f64>("work")
        .ok_or_else(|| anyhow::anyhow!("--work SECONDS is required"))?;
    let inference = match (args.get_parse::<f64>("rate"), args.get_parse::<f64>("latency-slo")) {
        (None, None) => None,
        (Some(base_rate), Some(latency_slo_s)) => Some(InferenceSpec {
            base_rate,
            diurnal_amplitude: args.get_parse("diurnal-amplitude").unwrap_or(0.0),
            diurnal_phase_s: args.get_parse("diurnal-phase-s").unwrap_or(0.0),
            latency_slo_s,
        }),
        _ => anyhow::bail!("inference jobs need both --rate and --latency-slo"),
    };
    let priority = match args.get("priority") {
        Some(key) => Priority::from_key(key)?,
        None => Priority::Standard,
    };
    Ok(JobRequest {
        family,
        batch_size: args.get_parse("batch").unwrap_or(32),
        min_throughput: args.get_parse("min-throughput").unwrap_or(0.0),
        distributability: args.get_parse::<u32>("distributability").unwrap_or(1).max(1),
        work,
        priority,
        inference,
    })
}

fn submit(args: &Args) -> Result<()> {
    let jobs: Vec<JobRequest> = match args.get("file") {
        Some(path) => {
            let text = std::fs::read_to_string(path).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
            let v = Json::parse(&text).map_err(|e| anyhow::anyhow!("{path}: invalid JSON: {e}"))?;
            let arr = v.as_array().ok_or_else(|| anyhow::anyhow!("{path}: not a JSON array"))?;
            arr.iter()
                .enumerate()
                .map(|(i, j)| {
                    JobRequest::from_json(j)
                        .map_err(|e| anyhow::anyhow!("{path}[{i}]: {}", e.message))
                })
                .collect::<Result<Vec<_>>>()?
        }
        None => vec![job_from_flags(args)?],
    };
    for job in jobs {
        let family = job.family.name();
        let kind = if job.inference.is_some() { "inference" } else { "training" };
        let resp = daemon_request(args, &Request::Submit { job })?;
        println!(
            "submitted job {} ({family}, {kind}) at t={:.1} s",
            resp.req_f64("id")? as u64,
            resp.req_f64("at")?
        );
    }
    Ok(())
}

fn queue(args: &Args) -> Result<()> {
    let resp = daemon_request(args, &Request::Queue)?;
    let jobs = resp.get("jobs").and_then(Json::as_array).unwrap_or(&[]);
    println!(
        "queue: {} active jobs ({} pending arrivals, draining: {})",
        jobs.len(),
        resp.get("pending").and_then(Json::as_u64).unwrap_or(0),
        resp.get("draining").and_then(Json::as_bool).unwrap_or(false)
    );
    for j in jobs {
        let accels: Vec<&str> = j
            .get("accels")
            .and_then(Json::as_array)
            .unwrap_or(&[])
            .iter()
            .filter_map(Json::as_str)
            .collect();
        // priority/suspended are additive-v1: absent when talking to
        // a pre-priority daemon, so default rather than error
        let tier = j.get("priority").and_then(Json::as_str).unwrap_or("standard");
        let suspended = j.get("suspended").and_then(Json::as_bool).unwrap_or(false);
        println!(
            "  j{} {} {} [{}{}] placed={} work={:.1}",
            j.req_f64("id")? as u64,
            j.req_str("family")?,
            j.req_str("kind")?,
            tier,
            if suspended { ", suspended" } else { "" },
            if accels.is_empty() { "-".to_string() } else { accels.join("+") },
            j.req_f64("work_remaining")?
        );
    }
    Ok(())
}

fn cancel(args: &Args) -> Result<()> {
    let job = args.get_parse::<u32>("job").ok_or_else(|| anyhow::anyhow!("--job N is required"))?;
    daemon_request(args, &Request::Cancel { job })?;
    println!("cancelled job {job}");
    Ok(())
}

fn status(args: &Args) -> Result<()> {
    let resp = daemon_request(args, &Request::Status)?;
    println!(
        "daemon: backend {}, draining {}, sim t={:.1} s",
        resp.req_str("backend")?,
        resp.get("draining").and_then(Json::as_bool).unwrap_or(false),
        resp.req_f64("sim_seconds")?
    );
    let jobs = resp.get("jobs").ok_or_else(|| anyhow::anyhow!("status response missing jobs"))?;
    println!(
        "jobs: {} total, {} active, {} completed, {} cancelled",
        jobs.req_f64("total")? as u64,
        jobs.req_f64("active")? as u64,
        jobs.req_f64("completed")? as u64,
        jobs.req_f64("cancelled")? as u64
    );
    let catalog =
        resp.get("catalog").ok_or_else(|| anyhow::anyhow!("status response missing catalog"))?;
    println!(
        "catalog: {} records ({} measured)",
        catalog.req_f64("records")? as u64,
        catalog.req_f64("measured")? as u64
    );
    let placements = resp.get("placements").and_then(Json::as_array).unwrap_or(&[]);
    println!("placements: {} busy accelerators", placements.len());
    for p in placements {
        let ids: Vec<String> = p
            .get("jobs")
            .and_then(Json::as_array)
            .unwrap_or(&[])
            .iter()
            .filter_map(|j| j.as_u64().map(|n| format!("j{n}")))
            .collect();
        println!("  {} <- [{}]", p.req_str("accel")?, ids.join(", "));
    }
    println!("energy: {:.0} J", resp.req_f64("energy_joules")?);
    // power block (absent on pre-power daemons — unknown-field rule)
    if let Some(p) = resp.get("power") {
        let cap = p
            .get("cap_w")
            .and_then(Json::as_f64)
            .map_or("-".to_string(), |c| format!("{c:.0}"));
        println!(
            "power: peak {:.0} W / cap {cap} W, {:.1} gCO2",
            p.req_f64("peak_w")?,
            p.req_f64("grams_co2")?
        );
        let states: Vec<String> = p
            .get("states")
            .and_then(Json::as_array)
            .unwrap_or(&[])
            .iter()
            .filter_map(|s| {
                let accel = s.get("accel").and_then(Json::as_str)?;
                let state = s.get("state").and_then(Json::as_str)?;
                Some(format!("{accel}={state}"))
            })
            .collect();
        if !states.is_empty() {
            println!("  non-nominal states: {}", states.join(", "));
        }
    }
    // priority block (absent on pre-priority daemons — unknown-field rule)
    if let Some(p) = resp.get("priority") {
        let tiers: Vec<String> = p
            .get("tiers")
            .and_then(Json::as_array)
            .unwrap_or(&[])
            .iter()
            .filter_map(|t| {
                let tier = t.get("tier").and_then(Json::as_str)?;
                let att = t.get("attainment").and_then(Json::as_f64)?;
                Some(format!("{tier} {att:.3}"))
            })
            .collect();
        println!(
            "priority: {} preemptions, {} suspended now, {:.0} s suspended, \
             ftf p99 {:.2} ({})",
            p.req_f64("preemptions")? as u64,
            p.req_f64("suspended_now")? as u64,
            p.req_f64("suspended_seconds")?,
            p.req_f64("ftf_p99")?,
            tiers.join(", ")
        );
    }
    Ok(())
}

fn drain(args: &Args) -> Result<()> {
    let resp = daemon_request(args, &Request::Drain)?;
    println!(
        "drain requested; {} active jobs remain (goghd exits when they finish)",
        resp.req_f64("active")? as u64
    );
    Ok(())
}
