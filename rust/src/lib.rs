//! # GOGH — Correlation-Guided Orchestration of GPUs in Heterogeneous Clusters
//!
//! Production reimplementation of the GOGH scheduler (Raeisi et al.,
//! CS.DC 2025) as a three-layer rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the online coordinator: job queue, [`catalog`]
//!   of throughput estimates, nearest-neighbour similarity, the ILP
//!   [`ilp`] optimizer (built from scratch: simplex + branch-and-bound),
//!   the heterogeneous [`cluster`] simulator with energy accounting, and
//!   the continuous P1→optimize→monitor→P2 learning loop in
//!   [`coordinator`].
//! * **L2/L1 (build-time python)** — the P1/P2 estimator networks
//!   (FF/RNN/Transformer) with Pallas kernels, AOT-lowered to HLO text in
//!   `artifacts/`; the [`runtime`] module loads and drives them through
//!   the PJRT CPU client. Python never runs on the request path.
//!
//! ## Quick start
//!
//! ```no_run
//! use gogh::config::ExperimentConfig;
//! use gogh::coordinator::Gogh;
//!
//! let cfg = ExperimentConfig::default();
//! let mut sys = Gogh::from_config(&cfg).unwrap();
//! let report = sys.run().unwrap();
//! println!("energy: {:.1} J, SLO violations: {}", report.energy_joules, report.slo_violations);
//! ```
//!
//! See `examples/` for runnable end-to-end drivers and `rust/benches/`
//! for the harnesses that regenerate every figure of the paper.

pub mod baselines;
pub mod catalog;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod ilp;
pub mod metrics;
pub mod runtime;
pub mod util;
pub mod workload;

pub use config::ExperimentConfig;
pub use coordinator::Gogh;

/// Crate-wide result type (anyhow for rich error context).
pub type Result<T> = anyhow::Result<T>;
