//! # GOGH — Correlation-Guided Orchestration of GPUs in Heterogeneous Clusters
//!
//! Production reimplementation of the GOGH scheduler (Raeisi et al.,
//! CS.DC 2025) as a three-layer rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the online coordinator: job queue, [`catalog`]
//!   of throughput estimates, nearest-neighbour similarity, the ILP
//!   [`ilp`] optimizer (built from scratch: simplex + branch-and-bound),
//!   the heterogeneous [`cluster`] simulator with energy accounting, and
//!   the continuous P1→optimize→monitor→P2 learning loop in
//!   [`coordinator`].
//! * **L2/L1 (build-time python)** — the P1/P2 estimator networks
//!   (FF/RNN/Transformer) with Pallas kernels, AOT-lowered to HLO text in
//!   `artifacts/`; the [`runtime`] module loads and drives them through
//!   the PJRT CPU client. Python never runs on the request path. Without
//!   artifacts, the dependency-free pure-Rust backend
//!   ([`runtime::native`]) runs the same learning loop behind the same
//!   [`runtime::Backend`] trait — `gogh.backend = "native"` / `--backend
//!   native`.
//!
//! ## Quick start
//!
//! ```no_run
//! use gogh::config::ExperimentConfig;
//! use gogh::coordinator::Gogh;
//!
//! let cfg = ExperimentConfig::default();
//! let mut sys = Gogh::from_config(&cfg).unwrap();
//! let report = sys.run().unwrap();
//! println!("energy: {:.1} J, SLO violations: {}", report.energy_joules, report.slo_violations);
//! ```
//!
//! See `examples/` for runnable end-to-end drivers and `rust/benches/`
//! for the harnesses that regenerate every figure of the paper.
//!
//! ## Building
//!
//! The workspace manifest lives at the repository root and builds fully
//! offline (`vendor/` holds an `anyhow` shim and a build-only `xla`
//! PJRT stub as path dependencies):
//!
//! ```sh
//! cargo build --release   # library + `gogh` CLI + examples
//! cargo test -q           # tier-1 gate (PJRT suites skip without artifacts/)
//! cargo bench --no-run    # compile every bench harness
//! ```
//!
//! The allocator hot path — every arrival solves Problem 1 — is kept
//! fast by the workspace-reuse simplex ([`ilp::SimplexWorkspace`]) and
//! the greedy warm start ([`baselines::greedy::greedy_incumbent`]);
//! `benches/ilp_scaling.rs` measures both.

// The scheduler must never reach for raw pointers: the shard fan-out is
// scoped threads + RwLock, the runtime talks to PJRT through the xla
// crate's safe surface, and gogh-lint (docs/LINTS.md) polices the rest
// of the project invariants this attribute can't reach.
#![deny(unsafe_code)]

pub mod baselines;
pub mod catalog;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod daemon;
pub mod engine;
pub mod ilp;
pub mod lint;
pub mod metrics;
pub mod power;
pub mod runtime;
pub mod util;
pub mod workload;

pub use config::ExperimentConfig;
pub use coordinator::Gogh;

/// Crate-wide result type (anyhow for rich error context).
pub type Result<T> = anyhow::Result<T>;
