//! Round-based Gavel-style baseline (Narayanan et al., OSDI '20):
//! scheduling happens only at round boundaries (monitor ticks). Each
//! round the policy ranks active jobs by *least attained
//! heterogeneity-normalized service* — the max-min-fairness objective
//! Gavel optimizes — and hands the fastest instances (by ground-truth
//! solo throughput) to the jobs furthest behind, solo only, one
//! instance per job. Arrivals wait for the next round boundary; that
//! queueing is the cost of round-based scheduling that GOGH's
//! event-driven path avoids, and it is what the finish-time-fairness
//! (`ftf_p99`) column of the run report measures.

use std::collections::BTreeMap;

use crate::cluster::{AccelId, Cluster, Placement, PlacementDelta};
use crate::coordinator::{ClusterEvent, Decision, Scheduler};
use crate::workload::{Combo, JobId, ThroughputOracle};
use crate::Result;

pub struct GavelRoundsScheduler {
    oracle: ThroughputOracle,
    /// Attained service per job in oracle-throughput × rounds. Placed
    /// rounds on fast hardware count for more — Gavel's
    /// heterogeneity-normalized accounting.
    attained: BTreeMap<JobId, f64>,
}

impl GavelRoundsScheduler {
    pub fn new(oracle: ThroughputOracle) -> Self {
        Self {
            oracle,
            attained: BTreeMap::new(),
        }
    }

    /// One round boundary: credit the round that just ran, then build
    /// the next round's allocation least-attained-first and return it
    /// as a delta against the current placement.
    fn round(&mut self, cluster: &Cluster) -> PlacementDelta {
        let jobs: Vec<_> = cluster.jobs().cloned().collect();
        let lookup = |id: JobId| jobs.iter().find(|s| s.id == id).cloned();
        for spec in &jobs {
            let combo = Combo::Solo(spec.id);
            let gain: f64 = cluster
                .placement
                .accels_of(spec.id)
                .iter()
                .map(|a| self.oracle.throughput(spec, &combo, a.accel, &lookup))
                .sum();
            *self.attained.entry(spec.id).or_insert(0.0) += gain;
        }
        let live = cluster.active_job_ids();
        self.attained.retain(|j, _| live.contains(j));
        // least attained service first (ties: arrival order) — the jobs
        // furthest behind their fair share pick instances first
        let mut order: Vec<(f64, JobId)> = live
            .iter()
            .filter(|&&j| !cluster.is_suspended(j))
            .map(|&j| (self.attained.get(&j).copied().unwrap_or(0.0), j))
            .collect();
        order.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        let mut remaining: Vec<AccelId> = cluster.available_accels();
        let mut target = Placement::new();
        for (_, j) in order {
            if remaining.is_empty() {
                break;
            }
            let Some(spec) = jobs.iter().find(|s| s.id == j) else {
                continue;
            };
            let combo = Combo::Solo(j);
            let score = |a: &AccelId| self.oracle.throughput(spec, &combo, a.accel, &lookup);
            let best = remaining.iter().map(score).fold(f64::NEG_INFINITY, f64::max);
            // sticky rounds: keep the current instance when it is
            // already throughput-optimal, so equal-attainment rounds do
            // not reshuffle (migration restarts would eat the quantum)
            let cur = cluster
                .placement
                .accels_of(j)
                .into_iter()
                .find(|a| remaining.contains(a) && score(a) >= best - 1e-12);
            let pick = cur.or_else(|| {
                remaining
                    .iter()
                    .copied()
                    .filter(|a| score(a) >= best - 1e-12)
                    .min()
            });
            if let Some(a) = pick {
                remaining.retain(|x| *x != a);
                target.assign(a, combo);
            }
        }
        PlacementDelta::diff(&cluster.placement, &target)
    }
}

impl Scheduler for GavelRoundsScheduler {
    fn name(&self) -> &str {
        "gavel-rounds"
    }

    fn on_event(&mut self, event: &ClusterEvent, cluster: &Cluster) -> Result<Decision> {
        match event {
            ClusterEvent::MonitorTick { .. } if cluster.n_jobs() > 0 => {
                Ok(Decision::apply(self.round(cluster)))
            }
            // everything else waits for the next round boundary
            _ => Ok(Decision::none()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::workload::{AccelType, JobSpec, ModelFamily};

    fn job(id: u32) -> JobSpec {
        JobSpec {
            id: JobId(id),
            family: ModelFamily::ResNet50,
            batch_size: 64,
            replication: 1,
            min_throughput: 0.0,
            distributability: 1,
            work: 100.0,
            priority: Default::default(),
            elastic: false,
            inference: None,
        }
    }

    #[test]
    fn arrivals_wait_for_the_round_boundary() {
        let mut c = Cluster::new(ClusterSpec::balanced(1));
        c.add_job(job(0));
        let mut s = GavelRoundsScheduler::new(ThroughputOracle::new(6));
        let d = s.on_event(&ClusterEvent::JobArrived { job: JobId(0) }, &c).unwrap();
        assert!(d.delta.is_empty(), "arrival must wait for the round boundary");
        let tick = ClusterEvent::MonitorTick { measurements: vec![] };
        let d = s.on_event(&tick, &c).unwrap();
        assert!(!d.delta.is_empty());
        c.apply_delta(&d.delta).unwrap();
        assert!(c.placement.is_placed(JobId(0)));
    }

    #[test]
    fn least_attained_service_rotates_on_a_contended_instance() {
        // one instance, two jobs: rounds must time-slice between them
        let mut c = Cluster::new(ClusterSpec::mix(&[(AccelType::V100, 1)]));
        c.add_job(job(0));
        c.add_job(job(1));
        let mut s = GavelRoundsScheduler::new(ThroughputOracle::new(6));
        c.apply_delta(&s.round(&c)).unwrap();
        assert!(c.placement.is_placed(JobId(0)), "ties break by arrival order");
        c.apply_delta(&s.round(&c)).unwrap();
        assert!(
            c.placement.is_placed(JobId(1)) && !c.placement.is_placed(JobId(0)),
            "the job with less attained service must take the next round"
        );
        c.apply_delta(&s.round(&c)).unwrap();
        assert!(c.placement.is_placed(JobId(0)), "and the slices keep alternating");
    }

    #[test]
    fn sticky_when_capacity_is_plentiful() {
        let mut c = Cluster::new(ClusterSpec::mix(&[(AccelType::V100, 2)]));
        c.add_job(job(0));
        c.add_job(job(1));
        let mut s = GavelRoundsScheduler::new(ThroughputOracle::new(6));
        c.apply_delta(&s.round(&c)).unwrap();
        assert!(c.placement.is_placed(JobId(0)) && c.placement.is_placed(JobId(1)));
        let second = s.round(&c);
        assert!(second.is_empty(), "no churn when everyone keeps a slot: {:?}", second.ops);
    }

    #[test]
    fn fastest_instance_goes_to_the_furthest_behind() {
        let mut c = Cluster::new(ClusterSpec::mix(&[(AccelType::V100, 1), (AccelType::K80, 1)]));
        c.add_job(job(0));
        let mut s = GavelRoundsScheduler::new(ThroughputOracle::new(6));
        c.apply_delta(&s.round(&c)).unwrap();
        let hosts = c.placement.accels_of(JobId(0));
        assert_eq!(hosts.len(), 1);
        assert_eq!(hosts[0].accel, AccelType::V100, "solo job must get the fast instance");
    }
}
