//! Random placement baseline: every job goes to a uniformly random
//! accelerator with free capacity (pairing at random when instances run
//! short). Heterogeneity- and energy-oblivious — the floor of the
//! comparison table.
//!
//! Decisions are native incremental deltas (ISSUE 9): each non-tick
//! event places whatever is unplaced with explicit [`PlacementOp`]s and
//! relocates one random solo job onto leftover free capacity — the
//! incremental analogue of the pre-redesign full reshuffle, keeping
//! this baseline exactly as migration-happy as it was (the
//! migration-cost plumbing stays exercised end to end).

use crate::util::Rng;

use crate::cluster::{AccelId, Cluster, PlacementDelta, PlacementOp};
use crate::coordinator::{ClusterEvent, Decision, Scheduler};
use crate::workload::{Combo, JobId};
use crate::Result;

pub struct RandomScheduler {
    rng: Rng,
}

impl RandomScheduler {
    pub fn new(seed: u64) -> Self {
        Self {
            rng: Rng::seed_from_u64(seed ^ 0xbadd),
        }
    }

    /// One decision round: place every unplaced active job onto a
    /// uniformly random free instance (inference jobs draw a uniformly
    /// random replica count up to their cap — rate- and
    /// latency-oblivious, like everything else here), pair with a
    /// random solo host once free instances run out, then shuffle one
    /// random pre-existing solo job onto a leftover free instance.
    fn incremental(&mut self, cluster: &Cluster) -> PlacementDelta {
        let mut delta = PlacementDelta::new();
        let mut free: Vec<AccelId> = cluster
            .available_accels()
            .into_iter()
            .filter(|a| cluster.placement.combo_on(*a).is_none())
            .collect();
        self.rng.shuffle(&mut free);
        // (host, job, pre-existing?) — only pre-existing solos are
        // relocation candidates (a job assigned by this very delta has
        // no progress to move)
        let mut solos: Vec<(AccelId, JobId, bool)> = cluster
            .available_accels()
            .into_iter()
            .filter_map(|a| match cluster.placement.combo_on(a) {
                Some(Combo::Solo(j)) => Some((a, *j, true)),
                _ => None,
            })
            .collect();
        let mut jobs: Vec<JobId> = cluster
            .active_job_ids()
            .into_iter()
            .filter(|&j| !cluster.placement.is_placed(j) && !cluster.is_suspended(j))
            .collect();
        self.rng.shuffle(&mut jobs);
        for j in jobs {
            if let Some(a) = free.pop() {
                delta.push(PlacementOp::Assign { accel: a, combo: Combo::Solo(j) });
                solos.push((a, j, false));
                let replica_cap = cluster
                    .job(j)
                    .filter(|s| s.is_inference())
                    .map_or(1, |s| s.distributability.max(1));
                if replica_cap > 1 {
                    let extra = self.rng.range_u32_inclusive(0, replica_cap - 1);
                    for _ in 0..extra {
                        let Some(a) = free.pop() else { break };
                        delta.push(PlacementOp::Assign { accel: a, combo: Combo::Solo(j) });
                        solos.push((a, j, false));
                    }
                }
            } else if !solos.is_empty() {
                // out of free instances: pair with a random solo host
                // (the Evict clears the host so the pair Assign lands on
                // an empty instance — apply_op validates targets)
                let idx = (self.rng.next_u32() as usize) % solos.len();
                let (a, existing, pre) = solos.swap_remove(idx);
                if pre {
                    delta.push(PlacementOp::Evict { accel: a });
                } else {
                    // the solo assign is still pending inside this delta:
                    // retract it and re-push as a pair
                    delta.ops.retain(|op| {
                        !matches!(op, PlacementOp::Assign { accel, combo: Combo::Solo(e) }
                            if *accel == a && *e == existing)
                    });
                }
                delta.push(PlacementOp::Assign { accel: a, combo: Combo::pair(existing, j) });
            }
            // else: cluster totally full (2 jobs everywhere) → job waits
        }
        // random relocation of one pre-existing solo job — the
        // incremental stand-in for the old every-event reshuffle
        let movable: Vec<(AccelId, JobId)> = solos
            .iter()
            .filter(|&&(_, _, pre)| pre)
            .map(|&(a, j, _)| (a, j))
            .collect();
        if !free.is_empty() && !movable.is_empty() {
            let (from, j) = movable[(self.rng.next_u32() as usize) % movable.len()];
            let to = free[(self.rng.next_u32() as usize) % free.len()];
            delta.push(PlacementOp::Migrate { job: j, from, to });
        }
        delta
    }
}

impl Scheduler for RandomScheduler {
    fn name(&self) -> &str {
        "random"
    }

    fn on_event(&mut self, event: &ClusterEvent, cluster: &Cluster) -> Result<Decision> {
        match event {
            ClusterEvent::MonitorTick { .. } => Ok(Decision::none()),
            _ if cluster.n_jobs() == 0 => Ok(Decision::none()),
            _ => Ok(Decision::apply(self.incremental(cluster))),
        }
    }
}


#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::workload::{JobId, JobSpec, ModelFamily};

    fn job(id: u32) -> JobSpec {
        JobSpec {
            id: JobId(id),
            family: ModelFamily::ResNet18,
            batch_size: 32,
            replication: 1,
            min_throughput: 0.0,
            distributability: 1,
            work: 10.0,
            priority: Default::default(),
            elastic: false,
            inference: None,
        }
    }

    #[test]
    fn places_all_jobs_when_capacity_allows() {
        let mut c = Cluster::new(ClusterSpec::balanced(1)); // 6 instances
        for i in 0..9 {
            c.add_job(job(i)); // 9 jobs > 6 instances → pairing needed
        }
        let mut s = RandomScheduler::new(1);
        let delta = s.incremental(&c);
        c.apply_delta(&delta).unwrap();
        for i in 0..9 {
            assert!(c.placement.is_placed(JobId(i)), "job {i} unplaced");
        }
        // capacity respected
        for (_, combo) in c.placement.iter() {
            assert!(combo.len() <= 2);
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let build = || {
            let mut c = Cluster::new(ClusterSpec::balanced(1));
            for i in 0..4 {
                c.add_job(job(i));
            }
            c
        };
        let mut c1 = build();
        let mut c2 = build();
        c1.apply_delta(&RandomScheduler::new(7).incremental(&c1)).unwrap();
        c2.apply_delta(&RandomScheduler::new(7).incremental(&c2)).unwrap();
        assert_eq!(c1.placement.diff_count(&c2.placement), 0);
    }

    #[test]
    fn decision_is_a_delta_against_current_placement() {
        let mut c = Cluster::new(ClusterSpec::balanced(1));
        for i in 0..3 {
            c.add_job(job(i));
        }
        let mut s = RandomScheduler::new(9);
        let ev = ClusterEvent::JobArrived { job: JobId(2) };
        let d = s.on_event(&ev, &c).unwrap();
        assert!(!d.delta.is_empty());
        c.apply_delta(&d.delta).unwrap();
        for i in 0..3 {
            assert!(c.placement.is_placed(JobId(i)));
        }
        // a monitor tick changes nothing
        let tick = ClusterEvent::MonitorTick { measurements: vec![] };
        assert!(s.on_event(&tick, &c).unwrap().delta.is_empty());
    }

    #[test]
    fn reshuffles_one_placed_job_when_capacity_allows() {
        // 6 instances, 1 placed job, 1 arrival: after placing the
        // arrival a free instance remains, so the pre-existing solo job
        // must be relocated by a native Migrate op.
        let mut c = Cluster::new(ClusterSpec::balanced(1));
        c.add_job(job(0));
        let mut s = RandomScheduler::new(3);
        c.apply_delta(&s.incremental(&c)).unwrap();
        c.add_job(job(1));
        let delta = s.incremental(&c);
        assert!(
            delta.ops.iter().any(|op| matches!(op, PlacementOp::Migrate { job: JobId(0), .. })),
            "no relocation emitted: {:?}",
            delta.ops
        );
        c.apply_delta(&delta).unwrap();
        assert!(c.placement.is_placed(JobId(0)) && c.placement.is_placed(JobId(1)));
    }
}
