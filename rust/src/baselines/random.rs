//! Random placement baseline: every job goes to a uniformly random
//! accelerator with free capacity (pairing at random when instances run
//! short). Heterogeneity- and energy-oblivious — the floor of the
//! comparison table.

use crate::util::Rng;

use crate::cluster::{Cluster, Placement};
use crate::coordinator::{ClusterEvent, Decision, Scheduler};
use crate::workload::Combo;
use crate::Result;

pub struct RandomScheduler {
    rng: Rng,
}

impl RandomScheduler {
    pub fn new(seed: u64) -> Self {
        Self {
            rng: Rng::seed_from_u64(seed ^ 0xbadd),
        }
    }

    /// Fresh random placement of every active job (full-rebuild policy;
    /// the driver applies it as a delta against the current placement).
    /// Inference jobs receive a uniformly random replica count up to
    /// their cap — rate- and latency-oblivious, like everything else
    /// this baseline does (training-only traces draw exactly as before).
    fn rebuild(&mut self, cluster: &Cluster) -> Placement {
        let mut p = Placement::new();
        let mut accels = cluster.available_accels();
        self.rng.shuffle(&mut accels);
        let mut jobs = cluster.active_job_ids();
        self.rng.shuffle(&mut jobs);
        let mut free = accels;
        let mut solos: Vec<crate::cluster::AccelId> = vec![];
        for j in jobs {
            if let Some(a) = free.pop() {
                p.assign(a, Combo::Solo(j));
                solos.push(a);
                let replica_cap = cluster
                    .job(j)
                    .filter(|s| s.is_inference())
                    .map_or(1, |s| s.distributability.max(1));
                if replica_cap > 1 {
                    let extra = self.rng.range_u32_inclusive(0, replica_cap - 1);
                    for _ in 0..extra {
                        let Some(a) = free.pop() else { break };
                        p.assign(a, Combo::Solo(j));
                        solos.push(a);
                    }
                }
            } else if !solos.is_empty() {
                // out of free instances: pair with a random solo host
                let idx = (self.rng.next_u32() as usize) % solos.len();
                let a = solos.swap_remove(idx);
                let existing = match p.combo_on(a) {
                    Some(Combo::Solo(e)) => *e,
                    _ => unreachable!("solos list only holds solo hosts"),
                };
                p.assign(a, Combo::pair(existing, j));
            }
            // else: cluster totally full (2 jobs everywhere) → job waits
        }
        p
    }
}

impl Scheduler for RandomScheduler {
    fn name(&self) -> &str {
        "random"
    }

    fn on_event(&mut self, event: &ClusterEvent, cluster: &Cluster) -> Result<Decision> {
        match event {
            ClusterEvent::MonitorTick { .. } => Ok(Decision::none()),
            _ if cluster.n_jobs() == 0 => Ok(Decision::none()),
            _ => {
                let target = self.rebuild(cluster);
                Ok(Decision::replace(&cluster.placement, &target))
            }
        }
    }
}


#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::workload::{JobId, JobSpec, ModelFamily};

    fn job(id: u32) -> JobSpec {
        JobSpec {
            id: JobId(id),
            family: ModelFamily::ResNet18,
            batch_size: 32,
            replication: 1,
            min_throughput: 0.0,
            distributability: 1,
            work: 10.0,
            inference: None,
        }
    }

    #[test]
    fn places_all_jobs_when_capacity_allows() {
        let mut c = Cluster::new(ClusterSpec::balanced(1)); // 6 instances
        for i in 0..9 {
            c.add_job(job(i)); // 9 jobs > 6 instances → pairing needed
        }
        let mut s = RandomScheduler::new(1);
        let p = s.rebuild(&c);
        for i in 0..9 {
            assert!(p.is_placed(JobId(i)), "job {i} unplaced");
        }
        // capacity respected
        for (_, combo) in p.iter() {
            assert!(combo.len() <= 2);
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let mut c = Cluster::new(ClusterSpec::balanced(1));
        for i in 0..4 {
            c.add_job(job(i));
        }
        let p1 = RandomScheduler::new(7).rebuild(&c);
        let p2 = RandomScheduler::new(7).rebuild(&c);
        assert_eq!(p1.diff_count(&p2), 0);
    }

    #[test]
    fn decision_is_a_delta_against_current_placement() {
        let mut c = Cluster::new(ClusterSpec::balanced(1));
        for i in 0..3 {
            c.add_job(job(i));
        }
        let mut s = RandomScheduler::new(9);
        let ev = ClusterEvent::JobArrived { job: JobId(2) };
        let d = s.on_event(&ev, &c).unwrap();
        assert!(!d.delta.is_empty());
        c.apply_delta(&d.delta).unwrap();
        for i in 0..3 {
            assert!(c.placement.is_placed(JobId(i)));
        }
        // a monitor tick changes nothing
        let tick = ClusterEvent::MonitorTick { measurements: vec![] };
        assert!(s.on_event(&tick, &c).unwrap().delta.is_empty());
    }
}
