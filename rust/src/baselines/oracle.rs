//! Oracle-ILP baseline: Problem 1 solved with *ground-truth*
//! throughputs. This is the energy lower bound GOGH approaches as its
//! estimates converge — labelled "oracle" in the e2e table.

use crate::cluster::Cluster;
use crate::config::OptimizerConfig;
use crate::coordinator::{ClusterEvent, Decision, Optimizer, Scheduler};
use crate::workload::{AccelType, Combo, JobId, ThroughputOracle};
use crate::Result;

pub struct OracleScheduler {
    oracle: ThroughputOracle,
    opt: Optimizer,
}

impl OracleScheduler {
    pub fn new(oracle: ThroughputOracle, cfg: OptimizerConfig) -> Self {
        Self {
            oracle,
            opt: Optimizer::new(cfg),
        }
    }
}

impl Scheduler for OracleScheduler {
    fn name(&self) -> &str {
        "oracle-ilp"
    }

    fn on_event(&mut self, event: &ClusterEvent, cluster: &Cluster) -> Result<Decision> {
        if matches!(event, ClusterEvent::MonitorTick { .. }) || cluster.n_jobs() == 0 {
            return Ok(Decision::none());
        }
        let oracle = self.oracle.clone();
        let jobs: Vec<_> = cluster.jobs().cloned().collect();
        let thr = move |a: AccelType, j: JobId, c: &Combo| {
            let spec = jobs.iter().find(|s| s.id == j).unwrap();
            let lookup = |id: JobId| jobs.iter().find(|s| s.id == id).cloned();
            oracle.throughput(spec, c, a, &lookup)
        };
        let (target, _) = self.opt.allocate(cluster, &thr)?;
        Ok(Decision::replace(&cluster.placement, &target))
    }

    fn decision_latencies(&self) -> (f64, f64) {
        (self.opt.mean_solve_ms(), 0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::coordinator::SimDriver;
    use crate::workload::{Trace, TraceConfig};

    #[test]
    fn oracle_run_completes_and_meets_slos() {
        let oracle = ThroughputOracle::new(6);
        let trace = Trace::generate(
            &TraceConfig {
                n_jobs: 5,
                mean_interarrival_s: 20.0,
                mean_work_s: 60.0,
                ..Default::default()
            },
            &oracle,
        );
        let mut driver =
            SimDriver::new(ClusterSpec::balanced(1), oracle.clone(), trace, 0.0, 15.0, 2)
                .unwrap();
        let mut sched = OracleScheduler::new(oracle, OptimizerConfig::default());
        let report = driver.run(&mut sched).unwrap();
        assert_eq!(report.jobs_completed, 5);
        // with truth-driven ILP and a loose cluster, SLO deficits should be ~0
        assert!(report.slo_deficit < 1e-6, "deficit {}", report.slo_deficit);
    }
}
