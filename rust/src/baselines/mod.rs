//! Baseline schedulers the e2e benches compare GOGH against:
//!
//! * [`RandomScheduler`] — uniform random feasible placement.
//! * [`GreedyScheduler`] — fastest-available-GPU first fit (the
//!   "throughput-greedy" policy heterogeneity-unaware schedulers
//!   approximate).
//! * [`OracleScheduler`] — Problem 1 solved with *ground-truth*
//!   throughputs: the energy lower bound (what GOGH converges toward as
//!   estimates sharpen).
//! * [`GavelRoundsScheduler`] — round-based least-attained-service
//!   scheduling (Gavel-style): heterogeneity-aware but tied to round
//!   boundaries, the finish-time-fairness yardstick for `ftf_p99`.
//!
//! Random and greedy emit native incremental [`PlacementOp`] deltas;
//! Gavel diffs a whole-round target placement. Only the ILP paths still
//! go through full placement replacement.
//!
//! [`PlacementOp`]: crate::cluster::PlacementOp

pub mod gavel_rounds;
pub mod greedy;
pub mod oracle;
pub mod random;

pub use gavel_rounds::GavelRoundsScheduler;
pub use greedy::{greedy_incumbent, GreedyScheduler};
pub use oracle::OracleScheduler;
pub use random::RandomScheduler;
