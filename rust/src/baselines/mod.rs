//! Baseline schedulers the e2e benches compare GOGH against:
//!
//! * [`RandomScheduler`] — uniform random feasible placement.
//! * [`GreedyScheduler`] — fastest-available-GPU first fit (the
//!   "throughput-greedy" policy heterogeneity-unaware schedulers
//!   approximate).
//! * [`OracleScheduler`] — Problem 1 solved with *ground-truth*
//!   throughputs: the energy lower bound (what GOGH converges toward as
//!   estimates sharpen).

pub mod greedy;
pub mod oracle;
pub mod random;

pub use greedy::{greedy_incumbent, GreedyScheduler};
pub use oracle::OracleScheduler;
pub use random::RandomScheduler;
