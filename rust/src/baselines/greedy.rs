//! Greedy fastest-first baseline: jobs grab the fastest *free*
//! accelerator by hardware generation (public spec knowledge — no
//! throughput estimates), pairing onto the fastest solo host once the
//! cluster fills. This is the heterogeneity-aware-but-energy-oblivious
//! policy a throughput-maximizing scheduler approximates.

use crate::cluster::{AccelId, Cluster, Placement};
use crate::coordinator::Scheduler;
use crate::workload::Combo;
use crate::Result;

#[derive(Default)]
pub struct GreedyScheduler;

impl GreedyScheduler {
    pub fn new() -> Self {
        Self
    }
}

impl Scheduler for GreedyScheduler {
    fn name(&self) -> &str {
        "greedy"
    }

    fn allocate(&mut self, cluster: &Cluster) -> Result<Placement> {
        let mut p = Placement::new();
        // fastest instances first (stable order for determinism)
        let mut free: Vec<AccelId> = cluster.spec.accels.clone();
        free.sort_by(|a, b| {
            b.accel
                .base_speed()
                .partial_cmp(&a.accel.base_speed())
                .unwrap()
                .then(a.server.cmp(&b.server))
        });
        let mut jobs = cluster.active_job_ids(); // sorted: arrival order
        let mut solos: Vec<AccelId> = vec![];
        let mut i = 0;
        for j in jobs.drain(..) {
            if i < free.len() {
                p.assign(free[i], Combo::Solo(j));
                solos.push(free[i]);
                i += 1;
            } else if !solos.is_empty() {
                // pair onto the fastest host still holding a solo
                let a = solos.remove(0);
                let existing = match p.combo_on(a) {
                    Some(Combo::Solo(e)) => *e,
                    _ => unreachable!(),
                };
                p.assign(a, Combo::pair(existing, j));
            }
        }
        Ok(p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::workload::{AccelType, JobId, JobSpec, ModelFamily};

    fn job(id: u32) -> JobSpec {
        JobSpec {
            id: JobId(id),
            family: ModelFamily::ResNet50,
            batch_size: 64,
            replication: 1,
            min_throughput: 0.0,
            distributability: 1,
            work: 10.0,
        }
    }

    #[test]
    fn first_job_gets_fastest_gpu() {
        let mut c = Cluster::new(ClusterSpec::balanced(1));
        c.add_job(job(0));
        let p = GreedyScheduler::new().allocate(&c).unwrap();
        let (aid, _) = p.iter().next().unwrap();
        assert_eq!(aid.accel, AccelType::V100);
    }

    #[test]
    fn overflow_pairs_on_fastest() {
        let mut c = Cluster::new(ClusterSpec::mix(&[(AccelType::V100, 1), (AccelType::K80, 1)]));
        for i in 0..3 {
            c.add_job(job(i));
        }
        let p = GreedyScheduler::new().allocate(&c).unwrap();
        // 2 instances, 3 jobs: the v100 must host a pair
        let v100 = c.spec.accels.iter().find(|a| a.accel == AccelType::V100).unwrap();
        assert_eq!(p.combo_on(*v100).unwrap().len(), 2);
        for i in 0..3 {
            assert!(p.is_placed(JobId(i)));
        }
    }
}
