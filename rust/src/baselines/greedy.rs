//! Greedy fastest-first baseline: jobs grab the fastest *free*
//! accelerator by hardware generation (public spec knowledge — no
//! throughput estimates), pairing onto the fastest solo host once the
//! cluster fills. This is the heterogeneity-aware-but-energy-oblivious
//! policy a throughput-maximizing scheduler approximates.
//!
//! Decisions are native incremental deltas (ISSUE 9): each non-tick
//! event places whatever is unplaced with explicit [`PlacementOp`]s,
//! splits pairs back onto capacity that came free (the incremental
//! analogue of the old full-rebuild compaction — throughput-greedy
//! never leaves two jobs sharing while an instance idles), and grants
//! leftover instances to inference jobs as extra replicas.
//!
//! This module also hosts [`greedy_incumbent`]: the energy-aware greedy
//! packing that seeds the ILP's branch-and-bound with its first
//! incumbent (the warm start of `ilp::problem1::solve_problem1`).

use std::collections::BTreeMap;

use crate::cluster::{AccelId, Cluster, PlacementDelta, PlacementOp};
use crate::coordinator::{ClusterEvent, Decision, Scheduler};
use crate::ilp::model::{Model, VarId};
use crate::ilp::problem1::Problem1Input;
use crate::workload::{AccelType, Combo, JobId, JobSpec};
use crate::Result;

/// Fastest-hardware-first instance order (stable for determinism).
fn by_speed_desc(a: &AccelId, b: &AccelId) -> std::cmp::Ordering {
    b.accel
        .base_speed()
        .partial_cmp(&a.accel.base_speed())
        .unwrap_or(std::cmp::Ordering::Equal)
        .then(a.server.cmp(&b.server))
}

#[derive(Default)]
pub struct GreedyScheduler;

impl GreedyScheduler {
    pub fn new() -> Self {
        Self
    }

    /// One decision round as a native delta: unplaced jobs take the
    /// fastest free instance (pairing onto the fastest solo host once
    /// the cluster fills), pairs split back onto freed capacity, and
    /// leftover instances become inference replicas (fastest-first,
    /// round-robin, up to each job's replica cap) — throughput-
    /// maximizing serving, as energy-oblivious as the rest of this
    /// baseline.
    fn incremental(&self, cluster: &Cluster) -> PlacementDelta {
        let mut delta = PlacementDelta::new();
        let mut free: Vec<AccelId> = cluster
            .available_accels()
            .into_iter()
            .filter(|a| cluster.placement.combo_on(*a).is_none())
            .collect();
        free.sort_by(by_speed_desc);
        // solo hosts able to take a second job, fastest first
        let mut solos: Vec<(AccelId, JobId)> = cluster
            .available_accels()
            .into_iter()
            .filter_map(|a| match cluster.placement.combo_on(a) {
                Some(Combo::Solo(j)) => Some((a, *j)),
                _ => None,
            })
            .collect();
        solos.sort_by(|x, y| by_speed_desc(&x.0, &y.0));
        let unplaced: Vec<JobId> = cluster
            .active_job_ids() // sorted: arrival order
            .into_iter()
            .filter(|&j| !cluster.placement.is_placed(j) && !cluster.is_suspended(j))
            .collect();
        let mut i = 0;
        for j in unplaced {
            if i < free.len() {
                delta.push(PlacementOp::Assign { accel: free[i], combo: Combo::Solo(j) });
                solos.push((free[i], j));
                solos.sort_by(|x, y| by_speed_desc(&x.0, &y.0));
                i += 1;
            } else if !solos.is_empty() {
                // pair onto the fastest host still holding a solo; the
                // Evict clears a pre-existing host so the pair Assign
                // lands on an empty instance (pending solos from this
                // delta are retracted and re-pushed as the pair)
                let (a, existing) = solos.remove(0);
                let pending = delta.ops.iter().any(|op| {
                    matches!(op, PlacementOp::Assign { accel, .. } if *accel == a)
                });
                if pending {
                    delta.ops.retain(|op| {
                        !matches!(op, PlacementOp::Assign { accel, combo: Combo::Solo(e) }
                            if *accel == a && *e == existing)
                    });
                } else {
                    delta.push(PlacementOp::Evict { accel: a });
                }
                delta.push(PlacementOp::Assign { accel: a, combo: Combo::pair(existing, j) });
            }
        }
        // compaction: split existing pairs onto instances still free
        // (fastest pair host first — its jobs gain the most)
        let mut pairs: Vec<(AccelId, Combo)> = cluster
            .available_accels()
            .into_iter()
            .filter_map(|a| match cluster.placement.combo_on(a) {
                Some(c) if c.len() == 2 => Some((a, *c)),
                _ => None,
            })
            .collect();
        pairs.sort_by(|x, y| by_speed_desc(&x.0, &y.0));
        for (host, combo) in pairs {
            if i >= free.len() {
                break;
            }
            // move the younger member out; the peer keeps the host solo
            let js = combo.jobs();
            let Some(&mover) = js.iter().max() else { continue };
            delta.push(PlacementOp::Migrate { job: mover, from: host, to: free[i] });
            i += 1;
        }
        // inference replica pass over whatever capacity is left
        let serving: Vec<(JobId, u32)> = {
            let mut v: Vec<_> = cluster
                .jobs()
                .filter(|s| s.is_inference())
                .map(|s| (s.id, s.distributability))
                .collect();
            v.sort(); // arrival order
            v
        };
        let mut replicas: BTreeMap<JobId, u32> = BTreeMap::new();
        for &(j, _) in &serving {
            let pending = delta
                .ops
                .iter()
                .filter(|op| {
                    matches!(op, PlacementOp::Assign { combo, .. } if combo.contains(j))
                })
                .count() as u32;
            replicas.insert(j, cluster.placement.accels_of(j).len() as u32 + pending);
        }
        loop {
            let mut granted = false;
            for &(j, cap) in &serving {
                if i >= free.len() {
                    break;
                }
                let n = replicas.get(&j).copied().unwrap_or(0);
                if n > 0 && n < cap {
                    delta.push(PlacementOp::Assign { accel: free[i], combo: Combo::Solo(j) });
                    replicas.insert(j, n + 1);
                    i += 1;
                    granted = true;
                }
            }
            if !granted || i >= free.len() {
                break;
            }
        }
        delta
    }
}

impl Scheduler for GreedyScheduler {
    fn name(&self) -> &str {
        "greedy"
    }

    fn on_event(&mut self, event: &ClusterEvent, cluster: &Cluster) -> Result<Decision> {
        match event {
            ClusterEvent::MonitorTick { .. } => Ok(Decision::none()),
            _ if cluster.n_jobs() == 0 => Ok(Decision::none()),
            _ => Ok(Decision::apply(self.incremental(cluster))),
        }
    }
}

/// Greedy warm start for Problem 1: each job solo on the
/// cheapest-energy instance type that still has capacity and meets its
/// SLO (falling back to the fastest remaining type, then to slack).
/// Seeds B&B with an incumbent so pruning bites immediately.
///
/// Returns `None` when no feasible greedy assignment exists — in the
/// hard formulation (no slack variables) that happens whenever some job
/// cannot meet its SLO solo, and the solver then starts cold.
pub fn greedy_incumbent(
    input: &Problem1Input,
    model: &Model,
    cols: &[(AccelType, Combo, VarId)],
    slacks: &BTreeMap<JobId, (Option<VarId>, Option<VarId>)>,
) -> Option<Vec<f64>> {
    let mut x = vec![0.0f64; model.n_vars()];
    let mut remaining: BTreeMap<AccelType, u32> = input.accel_counts.clone();
    // hardest SLOs first
    let mut jobs: Vec<&JobSpec> = input.jobs.iter().collect();
    jobs.sort_by(|a, b| b.min_throughput.partial_cmp(&a.min_throughput).unwrap());
    for j in jobs {
        let solo = Combo::Solo(j.id);
        // candidate types sorted by the energy coefficient of the solo col
        let mut cands: Vec<(f64, AccelType, VarId, f64)> = cols
            .iter()
            .filter(|(a, c, _)| *c == solo && remaining.get(a).copied().unwrap_or(0) > 0)
            .map(|(a, c, v)| {
                let t = (input.throughput)(*a, j.id, c);
                (model.vars[v.0].obj, *a, *v, t)
            })
            .collect();
        cands.sort_by(|x, y| x.0.partial_cmp(&y.0).unwrap());
        let pick = cands
            .iter()
            .find(|(_, _, _, t)| *t >= j.min_throughput)
            .or_else(|| cands.iter().max_by(|a, b| a.3.partial_cmp(&b.3).unwrap()));
        match pick {
            Some(&(_, a, v, t)) => {
                x[v.0] = 1.0;
                *remaining.get_mut(&a).unwrap() -= 1;
                if t < j.min_throughput {
                    let (_, st) = slacks[&j.id];
                    x[st?.0] = (j.min_throughput - t).min(model.vars[st?.0].ub);
                }
            }
            None => {
                let (sc, st) = slacks[&j.id];
                x[sc?.0] = 1.0;
                x[st?.0] = model.vars[st?.0].ub;
            }
        }
    }
    model.is_feasible(&x, 1e-6).then_some(x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::workload::ModelFamily;

    fn job(id: u32) -> JobSpec {
        JobSpec {
            id: JobId(id),
            family: ModelFamily::ResNet50,
            batch_size: 64,
            replication: 1,
            min_throughput: 0.0,
            distributability: 1,
            work: 10.0,
            priority: Default::default(),
            elastic: false,
            inference: None,
        }
    }

    #[test]
    fn first_job_gets_fastest_gpu() {
        let mut c = Cluster::new(ClusterSpec::balanced(1));
        c.add_job(job(0));
        let delta = GreedyScheduler::new().incremental(&c);
        c.apply_delta(&delta).unwrap();
        let (aid, _) = c.placement.iter().next().unwrap();
        assert_eq!(aid.accel, AccelType::V100);
    }

    #[test]
    fn overflow_pairs_on_fastest() {
        let mut c = Cluster::new(ClusterSpec::mix(&[(AccelType::V100, 1), (AccelType::K80, 1)]));
        for i in 0..3 {
            c.add_job(job(i));
        }
        let delta = GreedyScheduler::new().incremental(&c);
        c.apply_delta(&delta).unwrap();
        // 2 instances, 3 jobs: the v100 must host a pair
        let v100 = c.spec.accels.iter().find(|a| a.accel == AccelType::V100).unwrap();
        assert_eq!(c.placement.combo_on(*v100).unwrap().len(), 2);
        for i in 0..3 {
            assert!(c.placement.is_placed(JobId(i)));
        }
    }

    #[test]
    fn leftover_capacity_becomes_inference_replicas() {
        // 1 training + 2 serving jobs on 6 instances: after everyone has
        // an instance, the 3 spares go to the serving jobs round-robin,
        // capped by each job's replica cap (2 and 3 → caps bind at 2+3,
        // but only 3 spares exist → 2 and 2... fastest-first order).
        let mut c = Cluster::new(ClusterSpec::mix(&[(AccelType::V100, 4), (AccelType::K80, 2)]));
        c.add_job(job(0)); // training, never replicated
        for (id, cap) in [(1u32, 2u32), (2, 3)] {
            let mut s = job(id);
            s.distributability = cap;
            s.inference = Some(crate::workload::InferenceSpec {
                base_rate: 5.0,
                diurnal_amplitude: 0.0,
                diurnal_phase_s: 0.0,
                latency_slo_s: 0.5,
            });
            c.add_job(s);
        }
        let delta = GreedyScheduler::new().incremental(&c);
        c.apply_delta(&delta).unwrap();
        let p = &c.placement;
        assert_eq!(p.accels_of(JobId(0)).len(), 1, "training job must stay solo");
        let r1 = p.accels_of(JobId(1)).len();
        let r2 = p.accels_of(JobId(2)).len();
        // every instance used, caps respected, round-robin fairness
        assert_eq!(r1 + r2, 5, "spare capacity left idle: {r1}+{r2}");
        assert!(r1 as u32 <= 2 && r2 as u32 <= 3);
        assert_eq!(r1, 2);
        assert_eq!(r2, 3);
        // replica caps bind even with capacity to spare: 1 serving job
        // with cap 2 on 6 instances gets exactly 2 replicas
        let mut c = Cluster::new(ClusterSpec::mix(&[(AccelType::V100, 6)]));
        let mut s = job(0);
        s.distributability = 2;
        s.inference = Some(crate::workload::InferenceSpec {
            base_rate: 5.0,
            diurnal_amplitude: 0.0,
            diurnal_phase_s: 0.0,
            latency_slo_s: 0.5,
        });
        c.add_job(s);
        let delta = GreedyScheduler::new().incremental(&c);
        c.apply_delta(&delta).unwrap();
        assert_eq!(c.placement.accels_of(JobId(0)).len(), 2);
    }

    #[test]
    fn delta_skips_down_accels() {
        let mut c = Cluster::new(ClusterSpec::mix(&[(AccelType::V100, 1), (AccelType::K80, 1)]));
        c.add_job(job(0));
        let v100 = *c.spec.accels.iter().find(|a| a.accel == AccelType::V100).unwrap();
        c.set_accel_down(v100);
        let delta = GreedyScheduler::new().incremental(&c);
        c.apply_delta(&delta).unwrap();
        let (aid, _) = c.placement.iter().next().unwrap();
        assert_eq!(aid.accel, AccelType::K80, "down v100 must not be used");
    }

    #[test]
    fn pairs_split_back_onto_freed_capacity() {
        // a pre-existing pair on the v100 while the k80 sits free: the
        // incremental compaction pass must split the pair with a native
        // Migrate instead of leaving capacity idle
        let mut c = Cluster::new(ClusterSpec::mix(&[(AccelType::V100, 1), (AccelType::K80, 1)]));
        c.add_job(job(0));
        c.add_job(job(1));
        let v100 = *c.spec.accels.iter().find(|a| a.accel == AccelType::V100).unwrap();
        let mut seed = PlacementDelta::new();
        seed.push(PlacementOp::Assign { accel: v100, combo: Combo::pair(JobId(0), JobId(1)) });
        c.apply_delta(&seed).unwrap();
        let delta = GreedyScheduler::new().incremental(&c);
        assert!(
            delta.ops.iter().any(|op| matches!(op, PlacementOp::Migrate { job: JobId(1), .. })),
            "no pair split emitted: {:?}",
            delta.ops
        );
        c.apply_delta(&delta).unwrap();
        assert_eq!(c.placement.combo_on(v100).map(|co| co.len()), Some(1));
        assert!(c.placement.is_placed(JobId(0)) && c.placement.is_placed(JobId(1)));
    }
}
