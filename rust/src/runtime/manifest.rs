//! `artifacts/manifest.json` — the contract between `aot.py` and this
//! runtime: per-model state tensor list (names/shapes in flat order),
//! I/O dims, fixed batch sizes, and artifact file names.

use std::collections::HashMap;
use std::path::Path;

use crate::util::Json;
use crate::Result;

#[derive(Debug, Clone)]
pub struct Manifest {
    pub version: u32,
    pub token_dim: usize,
    pub models: HashMap<String, ModelSpec>,
}

#[derive(Debug, Clone)]
pub struct ModelSpec {
    pub net: String,
    pub arch: String,
    pub input_dim: usize,
    pub padded_dim: usize,
    pub tokens: usize,
    pub out_dim: usize,
    pub train_batch: usize,
    pub pred_batch: usize,
    pub lr: f64,
    pub param_count: usize,
    /// Number of parameter tensors (fwd consumes state[..n_params]).
    pub n_params: usize,
    pub state: Vec<StateEntry>,
    pub files: Files,
}

#[derive(Debug, Clone)]
pub struct StateEntry {
    pub name: String,
    pub shape: Vec<usize>,
}

#[derive(Debug, Clone)]
pub struct Files {
    pub init: String,
    pub fwd: String,
    pub train: String,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(dir.join("manifest.json")).map_err(|e| {
            anyhow::anyhow!("manifest.json not found in {dir:?} (run `make artifacts`): {e}")
        })?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Self> {
        let j = Json::parse(text)?;
        let version = j.req_f64("version")? as u32;
        anyhow::ensure!(version == 2, "manifest version {version} unsupported (want 2)");
        let token_dim = j.req_usize("token_dim")?;
        let mut models = HashMap::new();
        for (key, m) in j
            .req("models")?
            .as_object()
            .ok_or_else(|| anyhow::anyhow!("models is not an object"))?
        {
            let state = m
                .req("state")?
                .as_array()
                .ok_or_else(|| anyhow::anyhow!("state is not an array"))?
                .iter()
                .map(|e| {
                    Ok(StateEntry {
                        name: e.req_str("name")?.to_string(),
                        shape: e
                            .req("shape")?
                            .as_array()
                            .ok_or_else(|| anyhow::anyhow!("shape not array"))?
                            .iter()
                            .map(|d| d.as_usize().unwrap_or(0))
                            .collect(),
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            let files = m.req("files")?;
            models.insert(
                key.clone(),
                ModelSpec {
                    net: m.req_str("net")?.to_string(),
                    arch: m.req_str("arch")?.to_string(),
                    input_dim: m.req_usize("input_dim")?,
                    padded_dim: m.req_usize("padded_dim")?,
                    tokens: m.req_usize("tokens")?,
                    out_dim: m.req_usize("out_dim")?,
                    train_batch: m.req_usize("train_batch")?,
                    pred_batch: m.req_usize("pred_batch")?,
                    lr: m.req_f64("lr")?,
                    param_count: m.req_usize("param_count")?,
                    n_params: m.req_usize("n_params")?,
                    state,
                    files: Files {
                        init: files.req_str("init")?.to_string(),
                        fwd: files.req_str("fwd")?.to_string(),
                        train: files.req_str("train")?.to_string(),
                    },
                },
            );
        }
        Ok(Manifest {
            version,
            token_dim,
            models,
        })
    }

    pub fn model(&self, key: &str) -> Result<&ModelSpec> {
        self.models.get(key).ok_or_else(|| {
            anyhow::anyhow!(
                "model {key} not in manifest (have: {:?})",
                self.models.keys().collect::<Vec<_>>()
            )
        })
    }
}

impl ModelSpec {
    /// Total number of state tensors (params + Adam m/v + step).
    pub fn n_state(&self) -> usize {
        self.state.len()
    }

    /// Elements in one state tensor.
    pub fn state_elems(&self, i: usize) -> usize {
        self.state[i].shape.iter().product::<usize>().max(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_real_manifest_when_present() {
        // artifacts/ is produced by `make artifacts`; skip silently if absent
        let dir = std::path::Path::new("artifacts");
        if !dir.join("manifest.json").exists() {
            return;
        }
        let m = Manifest::load(dir).unwrap();
        assert_eq!(m.models.len(), 6);
        let p1 = m.model("p1_rnn").unwrap();
        assert_eq!(p1.input_dim, 32);
        assert_eq!(p1.out_dim, 2);
        assert!(p1.n_state() > 3);
        // last state tensor is the scalar Adam step
        assert_eq!(p1.state.last().unwrap().name, "adam_step");
        assert!(p1.state.last().unwrap().shape.is_empty());
        assert_eq!(p1.state_elems(p1.n_state() - 1), 1);
        let p2 = m.model("p2_ff").unwrap();
        assert_eq!(p2.input_dim, 34);
        assert_eq!(p2.padded_dim, 40);
    }

    #[test]
    fn missing_model_is_error() {
        let m = Manifest {
            version: 2,
            token_dim: 8,
            models: HashMap::new(),
        };
        assert!(m.model("nope").is_err());
    }

    #[test]
    fn parse_synthetic_manifest() {
        let text = r#"{
            "version": 2, "token_dim": 8,
            "models": {"p1_ff": {
                "net": "p1", "arch": "ff", "input_dim": 32, "padded_dim": 32,
                "tokens": 4, "out_dim": 2, "train_batch": 256, "pred_batch": 256,
                "lr": 0.001, "param_count": 10, "n_params": 1,
                "state": [{"name": "w0", "shape": [32, 96]}, {"name": "adam_step", "shape": []}],
                "files": {"init": "a", "fwd": "b", "train": "c"}
            }}
        }"#;
        let m = Manifest::parse(text).unwrap();
        let spec = m.model("p1_ff").unwrap();
        assert_eq!(spec.state_elems(0), 32 * 96);
        assert_eq!(spec.files.train, "c");
    }
}
