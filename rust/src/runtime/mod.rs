//! Estimator runtime: the P1/P2 networks behind the [`Backend`]
//! abstraction — either AOT-compiled PJRT artifacts (HLO text produced
//! by `python/compile/aot.py`; Python never runs here) or the
//! dependency-free pure-Rust [`native`] engine.
//!
//! * [`backend`] — the [`Backend`] trait the coordinator programs
//!   against (`predict` / `train_step` / flat Adam state).
//! * [`manifest`] — parses `artifacts/manifest.json` (the I/O contract).
//! * [`engine`] — PJRT CPU client; compiles `init` / `fwd` / `train`
//!   executables per (net × arch).
//! * [`estimator`] — the PJRT [`Backend`]: owns a model's mutable state
//!   (params + Adam moments), exposing `predict` and `train_step` over
//!   f32 rows.
//! * [`native`] — the pure-Rust [`Backend`]: row-major matmul MLP,
//!   manual backprop, Adam over the same flat state layout, seeded init.
//! * [`dataset`] — P1/P2 training-tuple builders over the workload
//!   universe (shared by the figure benches and the online loop).

pub mod backend;
pub mod dataset;
pub mod engine;
pub mod estimator;
pub mod manifest;
pub mod native;

pub use backend::{Backend, PjrtBackend};
pub use dataset::{split_universe, DatasetBuilder, PipelineItem, Sample, Split};
pub use engine::{CompiledModel, Engine};
pub use estimator::Estimator;
pub use manifest::{Manifest, ModelSpec};
pub use native::{NativeBackend, NativeSpec};
