//! PJRT runtime: loads the AOT-compiled estimator artifacts (HLO text
//! produced by `python/compile/aot.py`) and drives them from the
//! coordinator's hot path. Python never runs here.
//!
//! * [`manifest`] — parses `artifacts/manifest.json` (the I/O contract).
//! * [`engine`] — PJRT CPU client; compiles `init` / `fwd` / `train`
//!   executables per (net × arch).
//! * [`estimator`] — owns a model's mutable state (params + Adam
//!   moments), exposing `predict` and `train_step` over f32 rows.
//! * [`dataset`] — P1/P2 training-tuple builders over the workload
//!   universe (shared by the figure benches and the online loop).

pub mod dataset;
pub mod engine;
pub mod estimator;
pub mod manifest;

pub use dataset::{split_universe, DatasetBuilder, PipelineItem, Sample, Split};
pub use engine::{CompiledModel, Engine};
pub use estimator::Estimator;
pub use manifest::{Manifest, ModelSpec};
