//! Estimator handle: owns a model's mutable flat state (params + Adam
//! moments + step) and exposes `predict` / `train_step` over plain f32
//! rows. This is the only boundary between the coordinator's world and
//! PJRT.
//!
//! Shape discipline: PJRT executables are specialized to the fixed
//! batches recorded in the manifest. `predict` chunks + pads with
//! repeated rows; `train_step` cycle-pads (repeating real samples keeps
//! gradients unbiased, unlike zero-padding which would drag predictions
//! toward 0).

use xla::Literal;

use crate::Result;

use super::engine::{CompiledModel, Engine};

pub struct Estimator {
    model: CompiledModel,
    /// flat state, order per manifest (params…, m…, v…, adam_step).
    state: Vec<Literal>,
    steps_taken: u64,
    /// cumulative wall time in execute() for §Perf accounting.
    pub exec_seconds: f64,
}

impl Estimator {
    /// Load + compile the model and materialize its seeded initial state.
    pub fn new(engine: &Engine, key: &str) -> Result<Self> {
        let model = engine.load_model(key)?;
        let t0 = std::time::Instant::now();
        let out = model
            .init
            .execute::<Literal>(&[])
            .map_err(|e| anyhow::anyhow!("init exec: {e}"))?;
        let tuple = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("init sync: {e}"))?;
        let state = tuple.to_tuple().map_err(|e| anyhow::anyhow!("init tuple: {e}"))?;
        anyhow::ensure!(
            state.len() == model.spec.n_state(),
            "init returned {} tensors, manifest says {}",
            state.len(),
            model.spec.n_state()
        );
        Ok(Self {
            model,
            state,
            steps_taken: 0,
            exec_seconds: t0.elapsed().as_secs_f64(),
        })
    }

    pub fn key(&self) -> &str {
        &self.model.key
    }

    pub fn spec(&self) -> &super::manifest::ModelSpec {
        &self.model.spec
    }

    pub fn steps_taken(&self) -> u64 {
        self.steps_taken
    }

    /// Reset to a freshly initialized state (for repeated experiments
    /// without recompiling).
    pub fn reset(&mut self) -> Result<()> {
        let out = self
            .model
            .init
            .execute::<Literal>(&[])
            .map_err(|e| anyhow::anyhow!("init exec: {e}"))?;
        self.state = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("init sync: {e}"))?
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("init tuple: {e}"))?;
        self.steps_taken = 0;
        Ok(())
    }

    fn batch_literal(rows: &[&[f32]], batch: usize, dim: usize) -> Result<Literal> {
        debug_assert!(!rows.is_empty());
        let mut flat = Vec::with_capacity(batch * dim);
        for i in 0..batch {
            let r = rows[i % rows.len()]; // cycle-pad
            debug_assert_eq!(r.len(), dim);
            flat.extend_from_slice(r);
        }
        Literal::vec1(&flat)
            .reshape(&[batch as i64, dim as i64])
            .map_err(|e| anyhow::anyhow!("reshape: {e}"))
    }

    /// Predict (B, out_dim) for arbitrary-many input rows (each of
    /// `padded_dim` width). Rows beyond multiples of the compiled batch
    /// are handled by cycle-padding the final chunk.
    pub fn predict(&mut self, rows: &[Vec<f32>]) -> Result<Vec<[f32; 2]>> {
        let spec = &self.model.spec;
        anyhow::ensure!(spec.out_dim == 2, "out_dim != 2");
        if rows.is_empty() {
            return Ok(vec![]);
        }
        let b = spec.pred_batch;
        let mut out = Vec::with_capacity(rows.len());
        let t0 = std::time::Instant::now();
        let n_params = spec.n_params;
        for chunk in rows.chunks(b) {
            let refs: Vec<&[f32]> = chunk.iter().map(|r| r.as_slice()).collect();
            let x = Self::batch_literal(&refs, b, spec.padded_dim)?;
            // fwd consumes the parameter tensors only (manifest contract)
            let mut args: Vec<&Literal> = self.state[..n_params].iter().collect();
            args.push(&x);
            let res = self
                .model
                .fwd
                .execute::<&Literal>(&args)
                .map_err(|e| anyhow::anyhow!("fwd exec: {e}"))?;
            let yhat = res[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow::anyhow!("fwd sync: {e}"))?
                .to_tuple1()
                .map_err(|e| anyhow::anyhow!("fwd tuple: {e}"))?;
            let v: Vec<f32> = yhat.to_vec().map_err(|e| anyhow::anyhow!("fwd vec: {e}"))?;
            for i in 0..chunk.len() {
                out.push([v[2 * i], v[2 * i + 1]]);
            }
        }
        self.exec_seconds += t0.elapsed().as_secs_f64();
        Ok(out)
    }

    /// One Adam step on (x, y) rows; returns (mse_loss, mae). Inputs are
    /// cycle-padded to the compiled train batch.
    pub fn train_step(&mut self, xs: &[Vec<f32>], ys: &[[f32; 2]]) -> Result<(f32, f32)> {
        let spec = &self.model.spec;
        anyhow::ensure!(!xs.is_empty() && xs.len() == ys.len(), "bad batch");
        let b = spec.train_batch;
        let xrefs: Vec<&[f32]> = xs.iter().map(|r| r.as_slice()).collect();
        let yflat: Vec<Vec<f32>> = ys.iter().map(|y| y.to_vec()).collect();
        let yrefs: Vec<&[f32]> = yflat.iter().map(|r| r.as_slice()).collect();
        let x = Self::batch_literal(&xrefs, b, spec.padded_dim)?;
        let y = Self::batch_literal(&yrefs, b, spec.out_dim)?;

        let t0 = std::time::Instant::now();
        let mut args: Vec<&Literal> = self.state.iter().collect();
        args.push(&x);
        args.push(&y);
        let res = self
            .model
            .train
            .execute::<&Literal>(&args)
            .map_err(|e| anyhow::anyhow!("train exec: {e}"))?;
        let tuple = res[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("train sync: {e}"))?
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("train tuple: {e}"))?;
        self.exec_seconds += t0.elapsed().as_secs_f64();
        let n = self.model.spec.n_state();
        anyhow::ensure!(tuple.len() == n + 2, "train returned {} tensors", tuple.len());
        let mut tuple = tuple;
        let mae_l = tuple.pop().unwrap();
        let loss_l = tuple.pop().unwrap();
        self.state = tuple;
        self.steps_taken += 1;
        let loss = loss_l
            .get_first_element::<f32>()
            .map_err(|e| anyhow::anyhow!("loss elem: {e}"))?;
        let mae = mae_l
            .get_first_element::<f32>()
            .map_err(|e| anyhow::anyhow!("mae elem: {e}"))?;
        Ok((loss, mae))
    }

    /// Evaluate MAE/MSE of predictions against targets (no training).
    pub fn evaluate(&mut self, xs: &[Vec<f32>], ys: &[[f32; 2]]) -> Result<(f32, f32)> {
        let preds = self.predict(xs)?;
        let mut abs = 0.0f64;
        let mut sq = 0.0f64;
        let mut n = 0usize;
        for (p, y) in preds.iter().zip(ys) {
            for k in 0..2 {
                let e = (p[k] - y[k]) as f64;
                abs += e.abs();
                sq += e * e;
                n += 1;
            }
        }
        Ok(((sq / n as f64) as f32, (abs / n as f64) as f32))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn engine() -> Option<std::sync::Arc<Engine>> {
        if !std::path::Path::new("artifacts/manifest.json").exists() {
            eprintln!("skipping: no artifacts");
            return None;
        }
        Some(Engine::load("artifacts").unwrap())
    }

    #[test]
    fn init_predict_shapes() {
        let Some(engine) = engine() else { return };
        let mut est = Estimator::new(&engine, "p1_ff").unwrap();
        let rows = vec![vec![0.1f32; 32]; 5];
        let preds = est.predict(&rows).unwrap();
        assert_eq!(preds.len(), 5);
        // identical rows → identical predictions
        assert_eq!(preds[0], preds[1]);
        assert!(preds[0].iter().all(|v| v.is_finite()));
    }

    #[test]
    fn train_reduces_loss_on_fixed_batch() {
        let Some(engine) = engine() else { return };
        let mut est = Estimator::new(&engine, "p1_ff").unwrap();
        let mut rng = Rng::seed_from_u64(5);
        let xs: Vec<Vec<f32>> = (0..64)
            .map(|_| (0..32).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect())
            .collect();
        let ys: Vec<[f32; 2]> = (0..64)
            .map(|_| [rng.f64() as f32, rng.f64() as f32])
            .collect();
        let (first, _) = est.train_step(&xs, &ys).unwrap();
        let mut last = first;
        for _ in 0..40 {
            last = est.train_step(&xs, &ys).unwrap().0;
        }
        assert!(last < 0.5 * first, "loss {first} -> {last}");
        assert_eq!(est.steps_taken(), 41);
    }

    #[test]
    fn reset_restores_initial_predictions() {
        let Some(engine) = engine() else { return };
        let mut est = Estimator::new(&engine, "p2_ff").unwrap();
        let rows = vec![vec![0.3f32; 40]; 2];
        let before = est.predict(&rows).unwrap();
        let xs = vec![vec![0.3f32; 40]; 8];
        let ys = vec![[1.0f32, 1.0f32]; 8];
        est.train_step(&xs, &ys).unwrap();
        let trained = est.predict(&rows).unwrap();
        assert_ne!(before[0], trained[0]);
        est.reset().unwrap();
        let after = est.predict(&rows).unwrap();
        assert_eq!(before[0], after[0]);
    }
}
