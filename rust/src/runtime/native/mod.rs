//! Native pure-Rust estimator backend: a dependency-free tensor + MLP
//! engine that stands in for the PJRT artifacts, so the full GOGH
//! learning loop (P1 priors → deployment → monitoring → P2 refinement →
//! online Adam steps) runs — and is CI-gated — with zero external
//! artifacts.
//!
//! * [`tensor`] — row-major matmul kernels + ReLU forward/backward.
//! * [`mlp`] — the network: manual backprop, MSE loss, Adam over the
//!   same flat `params…, m…, v…, adam_step` state layout the PJRT path
//!   threads through its `train` executable.
//! * [`NativeBackend`] — the [`crate::runtime::Backend`] implementation:
//!   seeded init from [`crate::util::Rng`], and the exact chunk /
//!   cycle-pad batching discipline `runtime/estimator.rs` documents
//!   (predict chunks + repeats rows into the fixed batch; train
//!   cycle-pads so gradients stay unbiased, unlike zero-padding).

pub mod mlp;
pub mod tensor;

pub use mlp::{Mlp, NativeSpec};

use crate::Result;

use super::backend::Backend;

/// The native estimator handle: owns an [`Mlp`] plus the step/latency
/// accounting the coordinator reads (mirrors
/// [`crate::runtime::Estimator`]'s surface).
pub struct NativeBackend {
    mlp: Mlp,
    steps_taken: u64,
    /// cumulative wall time inside forward/backward for §Perf accounting.
    pub exec_seconds: f64,
}

impl NativeBackend {
    /// Build from a spec (deterministic: same spec ⇒ same model).
    pub fn new(spec: NativeSpec) -> Self {
        Self {
            mlp: Mlp::new(spec),
            steps_taken: 0,
            exec_seconds: 0.0,
        }
    }

    /// Seeded P1 (initial-estimation) model over Eq. 1 rows.
    pub fn p1(seed: u64) -> Self {
        Self::new(NativeSpec::p1(seed))
    }

    /// Seeded P2 (refinement) model over Eq. 3 rows.
    pub fn p2(seed: u64) -> Self {
        Self::new(NativeSpec::p2(seed))
    }

    /// The model spec (shapes, batches, seed).
    pub fn spec(&self) -> &NativeSpec {
        self.mlp.spec()
    }

    /// The flat `params…, m…, v…, adam_step` state (tests + checkpoints).
    pub fn state(&self) -> &[f32] {
        self.mlp.state()
    }

    /// Restore an exported flat state (length-checked).
    pub fn set_state(&mut self, state: &[f32]) -> Result<()> {
        self.mlp.set_state(state)
    }

    /// Cycle-pad `rows` into one flat `[batch × dim]` buffer — the same
    /// repetition rule as `Estimator::batch_literal`.
    fn batch_flat(rows: &[&[f32]], batch: usize, dim: usize) -> Vec<f32> {
        debug_assert!(!rows.is_empty());
        let mut flat = Vec::with_capacity(batch * dim);
        for i in 0..batch {
            let r = rows[i % rows.len()]; // cycle-pad
            debug_assert_eq!(r.len(), dim);
            flat.extend_from_slice(r);
        }
        flat
    }
}

impl Backend for NativeBackend {
    fn key(&self) -> &str {
        &self.mlp.spec().key
    }

    fn input_dim(&self) -> usize {
        self.mlp.spec().input_dim
    }

    fn out_dim(&self) -> usize {
        self.mlp.spec().out_dim
    }

    fn train_batch(&self) -> usize {
        self.mlp.spec().train_batch
    }

    fn pred_batch(&self) -> usize {
        self.mlp.spec().pred_batch
    }

    fn state_dim(&self) -> usize {
        self.mlp.spec().state_dim()
    }

    fn steps_taken(&self) -> u64 {
        self.steps_taken
    }

    fn predict(&mut self, rows: &[Vec<f32>]) -> Result<Vec<[f32; 2]>> {
        let spec = self.mlp.spec();
        anyhow::ensure!(spec.out_dim == 2, "out_dim != 2");
        if rows.is_empty() {
            return Ok(vec![]);
        }
        let dim = spec.input_dim;
        anyhow::ensure!(
            rows.iter().all(|r| r.len() == dim),
            "predict row width != input_dim {dim}"
        );
        let b = spec.pred_batch;
        let mut out = Vec::with_capacity(rows.len());
        let t0 = std::time::Instant::now();
        for chunk in rows.chunks(b) {
            let refs: Vec<&[f32]> = chunk.iter().map(|r| r.as_slice()).collect();
            let flat = Self::batch_flat(&refs, b, dim);
            let y = self.mlp.forward(&flat, b);
            for i in 0..chunk.len() {
                out.push([y[2 * i], y[2 * i + 1]]);
            }
        }
        self.exec_seconds += t0.elapsed().as_secs_f64();
        Ok(out)
    }

    fn train_step(&mut self, xs: &[Vec<f32>], ys: &[[f32; 2]]) -> Result<(f32, f32)> {
        let spec = self.mlp.spec();
        anyhow::ensure!(!xs.is_empty() && xs.len() == ys.len(), "bad batch");
        let dim = spec.input_dim;
        anyhow::ensure!(
            xs.iter().all(|r| r.len() == dim),
            "train row width != input_dim {dim}"
        );
        let b = spec.train_batch;
        let xrefs: Vec<&[f32]> = xs.iter().map(|r| r.as_slice()).collect();
        let x = Self::batch_flat(&xrefs, b, dim);
        let yflat: Vec<Vec<f32>> = ys.iter().map(|y| y.to_vec()).collect();
        let yrefs: Vec<&[f32]> = yflat.iter().map(|r| r.as_slice()).collect();
        let y = Self::batch_flat(&yrefs, b, spec.out_dim);

        let t0 = std::time::Instant::now();
        let (grads, loss, mae) = self.mlp.gradients(&x, &y, b);
        self.mlp.adam_update(&grads);
        self.exec_seconds += t0.elapsed().as_secs_f64();
        self.steps_taken += 1;
        Ok((loss, mae))
    }

    fn reset(&mut self) -> Result<()> {
        self.mlp = Mlp::new(self.mlp.spec().clone());
        self.steps_taken = 0;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> NativeBackend {
        NativeBackend::new(NativeSpec {
            key: "tiny".to_string(),
            input_dim: 4,
            hidden: vec![6],
            out_dim: 2,
            train_batch: 8,
            pred_batch: 4,
            lr: 1e-2,
            seed: 21,
        })
    }

    fn row(i: usize) -> Vec<f32> {
        (0..4).map(|j| ((i * 4 + j) as f32 * 0.37).sin()).collect()
    }

    #[test]
    fn predict_chunking_and_cycle_padding_match_per_row_results() {
        // 5 rows over pred_batch 4: the final chunk is cycle-padded.
        // Padding must be invisible — every row's prediction equals the
        // prediction of that row alone (bit-for-bit: row-major matmul
        // accumulates per row, independent of its batch neighbours).
        let mut be = tiny();
        let rows: Vec<Vec<f32>> = (0..5).map(row).collect();
        let batched = be.predict(&rows).unwrap();
        assert_eq!(batched.len(), 5);
        for (i, r) in rows.iter().enumerate() {
            let solo = be.predict(std::slice::from_ref(r)).unwrap();
            assert_eq!(batched[i], solo[0], "row {i} changed under padding");
        }
        // identical rows → identical predictions (estimator contract)
        let same_rows = vec![row(0); 3];
        let same = be.predict(&same_rows).unwrap();
        assert_eq!(same[0], same[1]);
        assert!(same[0].iter().all(|v| v.is_finite()));
    }

    #[test]
    fn train_cycle_padding_equals_explicit_padding() {
        // train_step on 3 rows (cycle-padded internally to train_batch
        // 8) must leave the model in exactly the state of training on
        // the explicitly repeated batch [r0 r1 r2 r0 r1 r2 r0 r1] — the
        // documented PJRT padding semantics (repeating real samples
        // keeps gradients unbiased; zero-padding would not).
        let mut short = tiny();
        let mut padded = tiny();
        assert_eq!(short.state(), padded.state());
        let xs: Vec<Vec<f32>> = (0..3).map(row).collect();
        let ys: Vec<[f32; 2]> = (0..3).map(|i| [0.1 * i as f32, 0.5]).collect();
        let xs_pad: Vec<Vec<f32>> = (0..8).map(|i| xs[i % 3].clone()).collect();
        let ys_pad: Vec<[f32; 2]> = (0..8).map(|i| ys[i % 3]).collect();
        let (l1, m1) = short.train_step(&xs, &ys).unwrap();
        let (l2, m2) = padded.train_step(&xs_pad, &ys_pad).unwrap();
        assert_eq!(l1, l2);
        assert_eq!(m1, m2);
        assert_eq!(short.state(), padded.state());
    }

    #[test]
    fn reset_restores_initial_predictions() {
        let mut be = tiny();
        let rows = vec![row(1); 2];
        let before = be.predict(&rows).unwrap();
        let xs = vec![row(1); 4];
        let ys = vec![[1.0f32, 1.0f32]; 4];
        be.train_step(&xs, &ys).unwrap();
        assert_eq!(be.steps_taken(), 1);
        let trained = be.predict(&rows).unwrap();
        assert_ne!(before[0], trained[0]);
        be.reset().unwrap();
        assert_eq!(be.steps_taken(), 0);
        let after = be.predict(&rows).unwrap();
        assert_eq!(before[0], after[0]);
    }

    #[test]
    fn p1_p2_shapes_follow_the_encoding_layout() {
        let p1 = NativeBackend::p1(3);
        assert_eq!(p1.input_dim(), crate::workload::encoding::P1_DIM);
        let p2 = NativeBackend::p2(3);
        assert_eq!(p2.input_dim(), crate::workload::encoding::P2_PADDED);
        assert_eq!(p2.out_dim(), 2);
        assert_eq!(p2.state_dim(), p2.spec().state_dim());
        assert_eq!(p2.state().len(), p2.state_dim());
    }

    #[test]
    fn empty_and_malformed_batches() {
        let mut be = tiny();
        assert!(be.predict(&[]).unwrap().is_empty());
        assert!(be.train_step(&[], &[]).is_err());
        assert!(be.predict(&[vec![0.0; 3]]).is_err()); // wrong width
    }
}
