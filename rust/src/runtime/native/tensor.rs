//! Row-major f32 tensor kernels for the native backend: the three
//! matmul variants an MLP's forward + backward passes need, written as
//! plain loops over flat slices (no allocation inside the kernels, no
//! SIMD intrinsics — the models are a few thousand parameters, so the
//! autovectorized scalar loops are already far off the hot path).
//!
//! Layout convention (shared with [`super::mlp`]): a matrix of shape
//! `[rows, cols]` is a flat slice of `rows * cols` f32 in row-major
//! order, i.e. element `(r, c)` lives at `r * cols + c`.

/// `c[m×n] = a[m×k] · b[k×n]`. `c` is overwritten.
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, c: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    c.fill(0.0);
    for r in 0..m {
        for p in 0..k {
            let av = a[r * k + p];
            if av == 0.0 {
                continue;
            }
            let brow = &b[p * n..(p + 1) * n];
            let crow = &mut c[r * n..(r + 1) * n];
            for j in 0..n {
                crow[j] += av * brow[j];
            }
        }
    }
}

/// `c[k×n] = aᵀ · b` with `a[m×k]`, `b[m×n]` — the weight-gradient
/// contraction `∇W = hᵀ · δ` of backprop. `c` is overwritten.
pub fn matmul_at_b(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, c: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), m * n);
    debug_assert_eq!(c.len(), k * n);
    c.fill(0.0);
    for r in 0..m {
        let brow = &b[r * n..(r + 1) * n];
        for p in 0..k {
            let av = a[r * k + p];
            if av == 0.0 {
                continue;
            }
            let crow = &mut c[p * n..(p + 1) * n];
            for j in 0..n {
                crow[j] += av * brow[j];
            }
        }
    }
}

/// `c[m×k] = a · bᵀ` with `a[m×n]`, `b[k×n]` — the input-gradient
/// contraction `δ_prev = δ · Wᵀ` of backprop (W stored `[k_in × n_out]`,
/// so `b = W` viewed as `[k×n]` with k = fan-in). `c` is overwritten.
pub fn matmul_a_bt(a: &[f32], b: &[f32], m: usize, n: usize, k: usize, c: &mut [f32]) {
    debug_assert_eq!(a.len(), m * n);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * k);
    for r in 0..m {
        let arow = &a[r * n..(r + 1) * n];
        for p in 0..k {
            let brow = &b[p * n..(p + 1) * n];
            let mut acc = 0.0f32;
            for j in 0..n {
                acc += arow[j] * brow[j];
            }
            c[r * k + p] = acc;
        }
    }
}

/// Add row-vector `bias[n]` to every row of `x[m×n]` in place.
pub fn add_bias(x: &mut [f32], bias: &[f32], m: usize, n: usize) {
    debug_assert_eq!(x.len(), m * n);
    debug_assert_eq!(bias.len(), n);
    for r in 0..m {
        let row = &mut x[r * n..(r + 1) * n];
        for j in 0..n {
            row[j] += bias[j];
        }
    }
}

/// ReLU in place.
pub fn relu(x: &mut [f32]) {
    for v in x.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// Mask `d` by the ReLU derivative of the matching pre-activation `z`
/// (`d[i] = 0` wherever `z[i] <= 0`) in place — the backward half of
/// [`relu`]. Uses the post-activation convention `z > 0.0` so the
/// subgradient at exactly 0 is 0, matching what XLA's
/// `select(gt(z, 0), d, 0)` lowering produces.
pub fn relu_backward(d: &mut [f32], z: &[f32]) {
    debug_assert_eq!(d.len(), z.len());
    for (dv, &zv) in d.iter_mut().zip(z) {
        if zv <= 0.0 {
            *dv = 0.0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_known_values() {
        // [1 2; 3 4] · [5 6; 7 8] = [19 22; 43 50]
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [5.0, 6.0, 7.0, 8.0];
        let mut c = [0.0f32; 4];
        matmul(&a, &b, 2, 2, 2, &mut c);
        assert_eq!(c, [19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn matmul_rectangular() {
        // [1 0 2] (1×3) · [[1 1],[2 2],[3 3]] (3×2) = [7 7]
        let a = [1.0, 0.0, 2.0];
        let b = [1.0, 1.0, 2.0, 2.0, 3.0, 3.0];
        let mut c = [0.0f32; 2];
        matmul(&a, &b, 1, 3, 2, &mut c);
        assert_eq!(c, [7.0, 7.0]);
    }

    #[test]
    fn transposed_variants_agree_with_explicit_transpose() {
        // random-ish fixed matrices, checked against matmul on the
        // explicitly transposed operand
        let a = [0.5, -1.0, 2.0, 1.5, 0.25, -0.75]; // 2×3
        let b = [1.0, 2.0, -1.0, 0.5, 3.0, -2.0]; // 2×3
        // aᵀ·b : (3×2)·(2×3) = 3×3
        let mut c1 = [0.0f32; 9];
        matmul_at_b(&a, &b, 2, 3, 3, &mut c1);
        let at = [0.5, 1.5, -1.0, 0.25, 2.0, -0.75]; // 3×2
        let mut c2 = [0.0f32; 9];
        matmul(&at, &b, 3, 2, 3, &mut c2);
        assert_eq!(c1, c2);
        // a·bᵀ : (2×3)·(3×2) = 2×2
        let mut c3 = [0.0f32; 4];
        matmul_a_bt(&a, &b, 2, 3, 2, &mut c3);
        let bt = [1.0, 0.5, 2.0, 3.0, -1.0, -2.0]; // 3×2
        let mut c4 = [0.0f32; 4];
        matmul(&a, &bt, 2, 3, 2, &mut c4);
        assert_eq!(c3, c4);
    }

    #[test]
    fn bias_and_relu() {
        let mut x = [1.0, -2.0, 3.0, -4.0];
        add_bias(&mut x, &[1.0, 1.0], 2, 2);
        assert_eq!(x, [2.0, -1.0, 4.0, -3.0]);
        relu(&mut x);
        assert_eq!(x, [2.0, 0.0, 4.0, 0.0]);
    }

    #[test]
    fn relu_backward_masks_nonpositive() {
        let z = [1.0, 0.0, -3.0, 2.0];
        let mut d = [5.0, 5.0, 5.0, 5.0];
        relu_backward(&mut d, &z);
        assert_eq!(d, [5.0, 0.0, 0.0, 5.0]);
    }
}
