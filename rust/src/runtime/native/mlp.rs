//! Pure-Rust MLP with manual backprop and Adam, over ONE flat f32 state
//! vector laid out exactly like the PJRT path's state tuple:
//! `params…, m…, v…, adam_step` (see `runtime/manifest.rs` — the flat
//! order the AOT `train` executable threads through every step).
//!
//! The network is deliberately tiny and boring: row-major matmuls from
//! [`super::tensor`], ReLU hidden layers, a linear 2-wide output head,
//! MSE loss with an MAE side-metric — the same contract the compiled
//! P1/P2 artifacts expose. Everything is seeded through
//! [`crate::util::Rng`], so two models built from the same
//! [`NativeSpec`] are bit-identical forever.

use crate::util::Rng;
use crate::Result;

use super::tensor;

/// Adam hyper-parameters (the values `python/compile/model.py` bakes
/// into the AOT `train` executables).
const BETA1: f32 = 0.9;
const BETA2: f32 = 0.999;
const EPS: f32 = 1e-8;

/// Shape + training spec of one native model — the manifest-compatible
/// description of a network (`input_dim`/`out_dim`/`train_batch`/
/// `pred_batch`/`lr` mirror the fields of
/// [`crate::runtime::manifest::ModelSpec`]; `hidden` replaces the HLO
/// files, and `seed` replaces the AOT `init` executable).
#[derive(Debug, Clone)]
pub struct NativeSpec {
    /// Model key, e.g. `"p1_native"` (reported by `Backend::key`).
    pub key: String,
    /// Input row width — P1 rows are [`crate::workload::encoding::P1_DIM`]
    /// wide, P2 rows [`crate::workload::encoding::P2_PADDED`].
    pub input_dim: usize,
    /// Hidden-layer widths (ReLU); the output head is linear.
    pub hidden: Vec<usize>,
    /// Output width (always 2 for P1/P2: the job + co-runner slots).
    pub out_dim: usize,
    /// Training batch the flat state was tuned for; smaller batches are
    /// cycle-padded up to this size (PJRT padding semantics).
    pub train_batch: usize,
    /// Prediction chunk size; longer row sets are chunked, the final
    /// chunk cycle-padded (PJRT padding semantics).
    pub pred_batch: usize,
    /// Adam learning rate.
    pub lr: f32,
    /// Seed of the Glorot-uniform parameter init.
    pub seed: u64,
}

impl NativeSpec {
    /// The P1 (initial estimation, Eq. 1) native model: 32 input
    /// features ([`crate::workload::encoding::P1_DIM`]).
    pub fn p1(seed: u64) -> Self {
        Self {
            key: "p1_native".to_string(),
            input_dim: crate::workload::encoding::P1_DIM,
            hidden: vec![64, 32],
            out_dim: 2,
            train_batch: 64,
            pred_batch: 64,
            lr: 1e-3,
            seed,
        }
    }

    /// The P2 (refinement, Eq. 3) native model: 40 input features
    /// ([`crate::workload::encoding::P2_PADDED`]).
    pub fn p2(seed: u64) -> Self {
        Self {
            key: "p2_native".to_string(),
            input_dim: crate::workload::encoding::P2_PADDED,
            hidden: vec![64, 32],
            out_dim: 2,
            train_batch: 64,
            pred_batch: 64,
            lr: 1e-3,
            seed,
        }
    }

    /// Layer dimension pairs `(fan_in, fan_out)` from input to output.
    pub fn layer_dims(&self) -> Vec<(usize, usize)> {
        let mut dims = vec![self.input_dim];
        dims.extend_from_slice(&self.hidden);
        dims.push(self.out_dim);
        dims.windows(2).map(|w| (w[0], w[1])).collect()
    }

    /// Total parameter count (weights + biases).
    pub fn n_params(&self) -> usize {
        self.layer_dims().iter().map(|(i, o)| i * o + o).sum()
    }

    /// Length of the flat state vector: `params…, m…, v…, adam_step`.
    pub fn state_dim(&self) -> usize {
        3 * self.n_params() + 1
    }

    /// Manifest-style state entries `(name, shape)` in flat order —
    /// `w0/b0…`, `m_*`, `v_*`, then the scalar `adam_step` last, the
    /// same discipline `artifacts/manifest.json` records for the PJRT
    /// state tuple.
    pub fn state_entries(&self) -> Vec<(String, Vec<usize>)> {
        let mut entries = vec![];
        for prefix in ["", "m_", "v_"] {
            for (l, (fi, fo)) in self.layer_dims().iter().enumerate() {
                entries.push((format!("{prefix}w{l}"), vec![*fi, *fo]));
                entries.push((format!("{prefix}b{l}"), vec![*fo]));
            }
        }
        entries.push(("adam_step".to_string(), vec![]));
        entries
    }
}

/// The network itself: a [`NativeSpec`] plus its flat mutable state.
#[derive(Debug, Clone)]
pub struct Mlp {
    spec: NativeSpec,
    /// `params…, m…, v…, adam_step` — see the module doc.
    state: Vec<f32>,
}

impl Mlp {
    /// Build with Glorot-uniform seeded init (deterministic per spec).
    pub fn new(spec: NativeSpec) -> Self {
        let n = spec.n_params();
        let mut state = vec![0.0f32; 3 * n + 1];
        let mut rng = Rng::seed_from_u64(spec.seed ^ 0x6e61_7469); // "nati"
        let mut off = 0;
        for (fan_in, fan_out) in spec.layer_dims() {
            let limit = (6.0 / (fan_in + fan_out) as f64).sqrt();
            for w in state[off..off + fan_in * fan_out].iter_mut() {
                *w = rng.range_f64(-limit, limit) as f32;
            }
            off += fan_in * fan_out + fan_out; // biases stay 0
        }
        debug_assert_eq!(off, n);
        Self { spec, state }
    }

    pub fn spec(&self) -> &NativeSpec {
        &self.spec
    }

    /// The flat `params…, m…, v…, adam_step` state vector.
    pub fn state(&self) -> &[f32] {
        &self.state
    }

    /// Restore a previously exported flat state (length-checked).
    pub fn set_state(&mut self, state: &[f32]) -> Result<()> {
        anyhow::ensure!(
            state.len() == self.state.len(),
            "state length {} != expected {}",
            state.len(),
            self.state.len()
        );
        self.state.copy_from_slice(state);
        Ok(())
    }

    /// Adam step counter (the scalar tail of the flat state).
    pub fn adam_step(&self) -> u64 {
        self.state[self.state.len() - 1] as u64
    }

    /// Forward pass over a flat `[batch × input_dim]` row-major input;
    /// returns `[batch × out_dim]` predictions.
    pub fn forward(&self, xs: &[f32], batch: usize) -> Vec<f32> {
        self.forward_cached(xs, batch).pop().expect("≥1 layer")
    }

    /// Forward pass keeping every layer's post-activation (index 0 is
    /// the input itself) — the cache backprop consumes.
    fn forward_cached(&self, xs: &[f32], batch: usize) -> Vec<Vec<f32>> {
        debug_assert_eq!(xs.len(), batch * self.spec.input_dim);
        let dims = self.spec.layer_dims();
        let mut acts: Vec<Vec<f32>> = Vec::with_capacity(dims.len() + 1);
        acts.push(xs.to_vec());
        let mut off = 0;
        for (l, &(fi, fo)) in dims.iter().enumerate() {
            let w = &self.state[off..off + fi * fo];
            let b = &self.state[off + fi * fo..off + fi * fo + fo];
            off += fi * fo + fo;
            let mut z = vec![0.0f32; batch * fo];
            tensor::matmul(&acts[l], w, batch, fi, fo, &mut z);
            tensor::add_bias(&mut z, b, batch, fo);
            if l + 1 < dims.len() {
                tensor::relu(&mut z);
            }
            acts.push(z);
        }
        acts
    }

    /// MSE loss + MAE of the predictions against `[batch × out_dim]`
    /// targets (both means over `batch * out_dim` elements — the PJRT
    /// `train`/`evaluate` reduction).
    pub fn loss(&self, xs: &[f32], ys: &[f32], batch: usize) -> (f32, f32) {
        let yhat = self.forward(xs, batch);
        Self::mse_mae(&yhat, ys)
    }

    fn mse_mae(yhat: &[f32], ys: &[f32]) -> (f32, f32) {
        debug_assert_eq!(yhat.len(), ys.len());
        let mut sq = 0.0f64;
        let mut abs = 0.0f64;
        for (p, y) in yhat.iter().zip(ys) {
            let e = (p - y) as f64;
            sq += e * e;
            abs += e.abs();
        }
        let n = yhat.len().max(1) as f64;
        ((sq / n) as f32, (abs / n) as f32)
    }

    /// Backprop: parameter gradients of the MSE loss on one batch, plus
    /// the (loss, mae) pair of that forward pass.
    pub fn gradients(&self, xs: &[f32], ys: &[f32], batch: usize) -> (Vec<f32>, f32, f32) {
        let dims = self.spec.layer_dims();
        let acts = self.forward_cached(xs, batch);
        let yhat = &acts[dims.len()];
        let (loss, mae) = Self::mse_mae(yhat, ys);

        let mut grads = vec![0.0f32; self.spec.n_params()];
        // dL/dyhat for the mean-over-(batch·out) MSE
        let scale = 2.0 / (batch * self.spec.out_dim) as f32;
        let mut delta: Vec<f32> = yhat.iter().zip(ys).map(|(p, y)| scale * (p - y)).collect();

        // walk layers backward; param offsets are easiest recomputed
        let mut offsets = Vec::with_capacity(dims.len());
        let mut off = 0;
        for &(fi, fo) in &dims {
            offsets.push(off);
            off += fi * fo + fo;
        }
        for l in (0..dims.len()).rev() {
            let (fi, fo) = dims[l];
            let off = offsets[l];
            // ∇W_l = acts[l]ᵀ · δ
            tensor::matmul_at_b(&acts[l], &delta, batch, fi, fo, &mut grads[off..off + fi * fo]);
            // ∇b_l = column sums of δ
            for r in 0..batch {
                for j in 0..fo {
                    grads[off + fi * fo + j] += delta[r * fo + j];
                }
            }
            if l > 0 {
                // δ_prev = δ · W_lᵀ, masked by the ReLU of layer l-1
                let w = &self.state[off..off + fi * fo];
                let mut prev = vec![0.0f32; batch * fi];
                tensor::matmul_a_bt(&delta, w, batch, fo, fi, &mut prev);
                tensor::relu_backward(&mut prev, &acts[l]);
                delta = prev;
            }
        }
        (grads, loss, mae)
    }

    /// One Adam update from precomputed gradients (advances `adam_step`).
    pub fn adam_update(&mut self, grads: &[f32]) {
        let n = self.spec.n_params();
        debug_assert_eq!(grads.len(), n);
        let t = self.state[3 * n] as i32 + 1;
        let bc1 = 1.0 - BETA1.powi(t);
        let bc2 = 1.0 - BETA2.powi(t);
        let lr = self.spec.lr;
        for i in 0..n {
            let g = grads[i];
            let m = BETA1 * self.state[n + i] + (1.0 - BETA1) * g;
            let v = BETA2 * self.state[2 * n + i] + (1.0 - BETA2) * g * g;
            self.state[n + i] = m;
            self.state[2 * n + i] = v;
            self.state[i] -= lr * (m / bc1) / ((v / bc2).sqrt() + EPS);
        }
        self.state[3 * n] = t as f32;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_spec(seed: u64) -> NativeSpec {
        NativeSpec {
            key: "tiny".to_string(),
            input_dim: 3,
            hidden: vec![5],
            out_dim: 2,
            train_batch: 4,
            pred_batch: 4,
            lr: 1e-2,
            seed,
        }
    }

    #[test]
    fn param_counts_and_state_layout() {
        let spec = tiny_spec(1);
        // 3·5+5 + 5·2+2 = 32 params
        assert_eq!(spec.n_params(), 32);
        assert_eq!(spec.state_dim(), 3 * 32 + 1);
        let entries = spec.state_entries();
        assert_eq!(entries.first().unwrap().0, "w0");
        // the scalar Adam step is LAST with an empty shape, exactly like
        // the PJRT manifest's state tuple
        let (name, shape) = entries.last().unwrap();
        assert_eq!(name, "adam_step");
        assert!(shape.is_empty());
        let elems: usize = entries
            .iter()
            .map(|(_, s)| s.iter().product::<usize>().max(1))
            .sum();
        assert_eq!(elems, spec.state_dim());
    }

    #[test]
    fn seeded_init_is_deterministic_and_seed_sensitive() {
        let a = Mlp::new(tiny_spec(7));
        let b = Mlp::new(tiny_spec(7));
        let c = Mlp::new(tiny_spec(8));
        assert_eq!(a.state(), b.state());
        assert_ne!(a.state(), c.state());
        // moments and step start at zero
        let n = a.spec().n_params();
        assert!(a.state()[n..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn finite_difference_gradient_check() {
        // Backprop vs central finite differences on a tiny MLP; rel err
        // < 1e-3 on every parameter. The state is crafted so every
        // hidden pre-activation sits far from the ReLU kink (two units
        // pinned strictly negative ≈ -0.8, three strictly positive
        // ≥ 0.5, perturbations move z by ≤ 9e-3): the loss is smooth
        // around the test point AND the dead-unit masking is exercised
        // (their weight gradients must be exactly 0 both ways).
        let mut mlp = Mlp::new(tiny_spec(3));
        let n = mlp.spec().n_params();
        let mut st = vec![0.0f32; mlp.state().len()];
        // W0 [3×5]: small positive weights; b0 pins units 0-1 dead
        for k in 0..15 {
            st[k] = 0.02 + 0.01 * (k % 7) as f32;
        }
        for (j, b) in [-1.0f32, -1.0, 0.5, 0.5, 0.5].into_iter().enumerate() {
            st[15 + j] = b;
        }
        // W1 [5×2] mixed signs; b1 small
        for k in 0..10 {
            st[20 + k] = ((k % 3) as f32 - 1.0) * 0.3;
        }
        st[30] = 0.1;
        st[31] = -0.1;
        mlp.set_state(&st).unwrap();
        let batch = 4;
        // strictly positive inputs keep the z-margins computed above
        let xs: Vec<f32> = (0..batch * 3).map(|k| 0.1 + 0.08 * (k % 10) as f32).collect();
        let ys: Vec<f32> = (0..batch * 2).map(|k| (k % 2) as f32 * 0.5 - 0.25).collect();
        let (grads, loss, _) = mlp.gradients(&xs, &ys, batch);
        assert!(loss > 0.0);
        // dead units contribute nothing: their W0/b0 grads are exactly 0
        for j in [0usize, 1] {
            for i in 0..3 {
                assert_eq!(grads[i * 5 + j], 0.0, "dead unit {j} got a W grad");
            }
            assert_eq!(grads[15 + j], 0.0, "dead unit {j} got a b grad");
        }
        let h = 1e-2f32;
        for i in 0..n {
            let orig = st[i];
            let wp = orig + h;
            let wm = orig - h;
            st[i] = wp;
            mlp.set_state(&st).unwrap();
            let (lp, _) = mlp.loss(&xs, &ys, batch);
            st[i] = wm;
            mlp.set_state(&st).unwrap();
            let (lm, _) = mlp.loss(&xs, &ys, batch);
            st[i] = orig;
            let numeric = ((lp as f64) - (lm as f64)) / ((wp - wm) as f64);
            let analytic = grads[i] as f64;
            let tol = 1e-3 * analytic.abs().max(numeric.abs()).max(0.05);
            assert!(
                (numeric - analytic).abs() <= tol,
                "param {i}: analytic {analytic} vs numeric {numeric}"
            );
        }
        mlp.set_state(&st).unwrap();
    }

    #[test]
    fn adam_reduces_loss_on_a_fixed_batch() {
        let mut mlp = Mlp::new(tiny_spec(5));
        let batch = 4;
        let xs: Vec<f32> = (0..batch * 3).map(|i| (i as f32 * 0.13).sin()).collect();
        let ys: Vec<f32> = (0..batch * 2).map(|i| 0.1 + 0.05 * i as f32).collect();
        let (first, _) = mlp.loss(&xs, &ys, batch);
        for _ in 0..200 {
            let (g, _, _) = mlp.gradients(&xs, &ys, batch);
            mlp.adam_update(&g);
        }
        let (last, _) = mlp.loss(&xs, &ys, batch);
        assert!(last < 0.1 * first, "loss {first} -> {last}");
        assert_eq!(mlp.adam_step(), 200);
    }

    #[test]
    fn state_roundtrip_restores_the_optimizer_exactly() {
        // export mid-training, keep training, re-import: the continued
        // trajectory must replay bit-for-bit (params AND Adam moments
        // AND the step counter all live in the one flat vector).
        let mut mlp = Mlp::new(tiny_spec(9));
        let batch = 4;
        let xs: Vec<f32> = (0..batch * 3).map(|i| (i as f32 * 0.31).cos()).collect();
        let ys: Vec<f32> = (0..batch * 2).map(|i| 0.2 * i as f32).collect();
        let step = |m: &mut Mlp| {
            let (g, loss, _) = m.gradients(&xs, &ys, batch);
            m.adam_update(&g);
            loss
        };
        for _ in 0..5 {
            step(&mut mlp);
        }
        let snapshot = mlp.state().to_vec();
        assert_eq!(mlp.adam_step(), 5);
        let after: Vec<f32> = (0..3).map(|_| step(&mut mlp)).collect();
        mlp.set_state(&snapshot).unwrap();
        assert_eq!(mlp.adam_step(), 5);
        let replay: Vec<f32> = (0..3).map(|_| step(&mut mlp)).collect();
        assert_eq!(after, replay);
        assert_eq!(mlp.adam_step(), 8);
        // wrong length is rejected
        assert!(mlp.set_state(&snapshot[1..]).is_err());
    }
}
