//! Training-tuple builders for P1 (Eq. 1) and P2 (Eq. 3).
//!
//! Used in two places:
//!  * the figure benches (fig2a/fig2b/fig3) build train/val/test sets
//!    over the Table 2 universe from the ground-truth oracle, mirroring
//!    the paper's offline evaluation;
//!  * the coordinator's online loop builds the same rows from *measured*
//!    catalog records (never the oracle).
//!
//! Splits are by workload configuration (family × batch): test configs
//! never appear as the estimation target j1 in train — that is the
//! "unseen input distributions" generalization the paper's test MAE
//! probes.

use crate::util::Rng;
use crate::workload::encoding::{p1_row, p2_row, psi_distance, PSI_DIM};
#[cfg(test)]
use crate::workload::encoding::{P1_DIM, P2_PADDED};
use crate::workload::trace::table2_universe;
use crate::workload::{AccelType, JobId, JobSpec, ModelFamily, ThroughputOracle, ACCEL_TYPES};

/// One (x, y) training sample.
#[derive(Debug, Clone)]
pub struct Sample {
    pub x: Vec<f32>,
    pub y: [f32; 2],
}

/// A train/val/test split of samples.
#[derive(Debug, Clone, Default)]
pub struct Split {
    pub train: Vec<Sample>,
    pub val: Vec<Sample>,
    pub test: Vec<Sample>,
}

impl Split {
    pub fn sizes(&self) -> (usize, usize, usize) {
        (self.train.len(), self.val.len(), self.test.len())
    }
}

/// Assign the 22 Table 2 configs to train/val/test (70/15/15 by count:
/// 16/3/3), deterministically per seed.
type ConfigPool = Vec<(ModelFamily, u32)>;

pub fn split_universe(seed: u64) -> (ConfigPool, ConfigPool, ConfigPool) {
    let mut univ = table2_universe();
    let mut rng = Rng::seed_from_u64(seed ^ 0x5b117);
    rng.shuffle(&mut univ);
    let n = univ.len();
    let n_test = (n as f64 * 0.15).round() as usize;
    let n_val = (n as f64 * 0.15).round() as usize;
    let test = univ.split_off(n - n_test);
    let val = univ.split_off(univ.len() - n_val);
    (univ, val, test)
}

/// Builds P1/P2 datasets from the ground-truth oracle.
pub struct DatasetBuilder<'a> {
    pub oracle: &'a ThroughputOracle,
    /// estimate-noise sigma used to synthesize the "current estimate"
    /// inputs of P2 rows (relative error of a plausible P1 output).
    pub est_sigma: f64,
    /// measurement-noise sigma applied to measured inputs.
    pub meas_sigma: f64,
    pub seed: u64,
}

fn mk_job(id: u32, cfg: (ModelFamily, u32)) -> JobSpec {
    JobSpec {
        id: JobId(id),
        family: cfg.0,
        batch_size: cfg.1,
        replication: 1,
        min_throughput: 0.0,
        distributability: 1,
        work: 1.0,
        priority: Default::default(),
        elastic: false,
        inference: None,
    }
}

impl<'a> DatasetBuilder<'a> {
    pub fn new(oracle: &'a ThroughputOracle, seed: u64) -> Self {
        Self {
            oracle,
            est_sigma: 0.15,
            meas_sigma: 0.02,
            seed,
        }
    }

    fn noise(&self, rng: &mut Rng, sigma: f64) -> f64 {
        if sigma <= 0.0 {
            return 1.0;
        }
        let u1: f64 = rng.f64().max(1e-12);
        let u2: f64 = rng.range_f64(0.0, std::f64::consts::TAU);
        let z = (-2.0 * u1.ln()).sqrt() * u2.cos();
        (sigma * z).exp()
    }

    /// Nearest config (by Ψ distance) to `target` within `pool`,
    /// excluding exact identity — the j2 selection of Eq. 1.
    fn nearest_config(
        target: (ModelFamily, u32),
        pool: &[(ModelFamily, u32)],
    ) -> (ModelFamily, u32) {
        let tpsi = crate::workload::encoding::psi(target.0, target.1, 1);
        let mut best = pool[0];
        let mut best_d = f32::INFINITY;
        for &c in pool {
            if c == target {
                continue;
            }
            let d = psi_distance(&tpsi, &crate::workload::encoding::psi(c.0, c.1, 1));
            if d < best_d {
                best_d = d;
                best = c;
            }
        }
        best
    }

    /// Generate `n` P1 samples whose estimation target j1 is drawn from
    /// `j1_pool` and whose reference job j2 comes from `ref_pool`
    /// (train configs — the "previously seen" jobs of the Catalog).
    pub fn p1_samples(
        &self,
        n: usize,
        j1_pool: &[(ModelFamily, u32)],
        ref_pool: &[(ModelFamily, u32)],
        salt: u64,
    ) -> Vec<Sample> {
        let mut rng = Rng::seed_from_u64(self.seed ^ salt ^ 0x9101);
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            let j1_cfg = j1_pool[rng.range_usize(0, j1_pool.len())];
            let j2_cfg = Self::nearest_config(j1_cfg, ref_pool);
            // j3: co-runner, or the empty job j0 ~25% of the time
            let j3_cfg = if rng.bool(0.25) {
                None
            } else {
                Some(ref_pool[rng.range_usize(0, ref_pool.len())])
            };
            let a = ACCEL_TYPES[rng.range_usize(0, ACCEL_TYPES.len())];
            let j1 = mk_job(3 * i as u32, j1_cfg);
            let j2 = mk_job(3 * i as u32 + 1, j2_cfg);
            let j3 = j3_cfg.map(|c| mk_job(3 * i as u32 + 2, c));
            let (x, y) = self.p1_tuple(&j1, &j2, j3, a, &mut rng);
            out.push(Sample { x, y });
        }
        out
    }

    /// One Eq. 1 tuple: historical throughputs of (j2, j3) on `a` as
    /// inputs, true throughputs of (j1, j3) as targets.
    fn p1_tuple(
        &self,
        j1: &JobSpec,
        j2: &JobSpec,
        j3: Option<JobSpec>,
        a: AccelType,
        rng: &mut Rng,
    ) -> (Vec<f32>, [f32; 2]) {
        let psi_j1 = j1.psi();
        let psi_j2 = j2.psi();
        let (psi_j3, t_j2, t_j3, y1, y3) = match &j3 {
            None => {
                // j3 = j0 (empty): historical solo throughput of j2,
                // target solo throughput of j1.
                let t2 = self.oracle.solo(j2, a) * self.noise(rng, self.meas_sigma);
                let y1 = self.oracle.solo(j1, a);
                (crate::workload::encoding::PSI_EMPTY, t2, 0.0, y1, 0.0)
            }
            Some(j3) => {
                let (t2, t3) = self.oracle.pair(j2, j3, a);
                let (y1, y3) = self.oracle.pair(j1, j3, a);
                (
                    j3.psi(),
                    t2 * self.noise(rng, self.meas_sigma),
                    t3 * self.noise(rng, self.meas_sigma),
                    y1,
                    y3,
                )
            }
        };
        let row = p1_row(&psi_j2, &psi_j3, a, t_j2 as f32, t_j3 as f32, &psi_j1);
        (row.to_vec(), [y1 as f32, y3 as f32])
    }

    /// Generate `n` P2 samples with targets from `j1_pool`.
    pub fn p2_samples(
        &self,
        n: usize,
        j1_pool: &[(ModelFamily, u32)],
        ref_pool: &[(ModelFamily, u32)],
        salt: u64,
    ) -> Vec<Sample> {
        let mut rng = Rng::seed_from_u64(self.seed ^ salt ^ 0x9202);
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            let j1_cfg = j1_pool[rng.range_usize(0, j1_pool.len())];
            let j2_cfg = if rng.bool(0.25) {
                None
            } else {
                Some(ref_pool[rng.range_usize(0, ref_pool.len())])
            };
            let a1 = ACCEL_TYPES[rng.range_usize(0, ACCEL_TYPES.len())];
            let mut a2 = ACCEL_TYPES[rng.range_usize(0, ACCEL_TYPES.len())];
            while a2 == a1 {
                a2 = ACCEL_TYPES[rng.range_usize(0, ACCEL_TYPES.len())];
            }
            let j1 = mk_job(2 * i as u32, j1_cfg);
            let j2 = j2_cfg.map(|c| mk_job(2 * i as u32 + 1, c));
            out.push(self.p2_tuple(&j1, j2.as_ref(), a1, a2, &mut rng));
        }
        out
    }

    /// One Eq. 3 tuple: stale estimates + fresh measurement on a1 as
    /// inputs, true throughputs on a2 as targets.
    fn p2_tuple(
        &self,
        j1: &JobSpec,
        j2: Option<&JobSpec>,
        a1: AccelType,
        a2: AccelType,
        rng: &mut Rng,
    ) -> Sample {
        let (true_a1_j1, true_a1_j2, true_a2_j1, true_a2_j2, psi_j2) = match j2 {
            None => (
                self.oracle.solo(j1, a1),
                0.0,
                self.oracle.solo(j1, a2),
                0.0,
                crate::workload::encoding::PSI_EMPTY,
            ),
            Some(j2) => {
                let (p1a, p2a) = self.oracle.pair(j1, j2, a1);
                let (p1b, p2b) = self.oracle.pair(j1, j2, a2);
                (p1a, p2a, p1b, p2b, j2.psi())
            }
        };
        // Stale estimates share one multiplicative error per (job, pair):
        // a plausible P1 output is wrong in a *correlated* way across GPUs
        // (it mispredicts the job, not one GPU) — this is exactly the
        // structure P2 can exploit: observe the error on a1, correct a2.
        let e_j1 = self.noise(rng, self.est_sigma);
        let e_j2 = self.noise(rng, self.est_sigma);
        // plus small independent per-GPU residuals
        let r = |rng: &mut Rng| self.noise(rng, self.est_sigma * 0.3);
        let est_a1_j1 = true_a1_j1 * e_j1 * r(rng);
        let est_a1_j2 = true_a1_j2 * e_j2 * r(rng);
        let est_a2_j1 = true_a2_j1 * e_j1 * r(rng);
        let est_a2_j2 = true_a2_j2 * e_j2 * r(rng);
        let meas_a1_j1 = true_a1_j1 * self.noise(rng, self.meas_sigma);
        let meas_a1_j2 = true_a1_j2 * self.noise(rng, self.meas_sigma);
        let row = p2_row(
            &j1.psi(),
            &psi_j2,
            a1,
            a2,
            est_a1_j1 as f32,
            est_a1_j2 as f32,
            meas_a1_j1 as f32,
            meas_a1_j2 as f32,
            est_a2_j1 as f32,
            est_a2_j2 as f32,
        );
        Sample {
            x: row.to_vec(),
            y: [true_a2_j1 as f32, true_a2_j2 as f32],
        }
    }

    /// Full train/val/test split for one network (`"p1"` or `"p2"`).
    pub fn build_split(&self, net: &str, n_train: usize, n_eval: usize) -> Split {
        let (train_cfgs, val_cfgs, test_cfgs) = split_universe(self.seed);
        let gen = |pool: &[(ModelFamily, u32)], n: usize, salt: u64| match net {
            "p1" => self.p1_samples(n, pool, &train_cfgs, salt),
            "p2" => self.p2_samples(n, pool, &train_cfgs, salt),
            _ => panic!("unknown net {net}"),
        };
        Split {
            train: gen(&train_cfgs, n_train, 1),
            val: gen(&val_cfgs, n_eval, 2),
            test: gen(&test_cfgs, n_eval, 3),
        }
    }
}

/// One item of the two-phase (P1 → P2) pipeline evaluation of Figure 3:
/// P1 estimates job j1 on a1 and a2 from a similar reference job; the
/// "cluster" then measures a1; P2 transfers that observation to a2.
#[derive(Debug, Clone)]
pub struct PipelineItem {
    /// Eq. 1 row targeting accelerator a1 (solo).
    pub p1_row_a1: Vec<f32>,
    /// Eq. 1 row targeting accelerator a2 (solo).
    pub p1_row_a2: Vec<f32>,
    /// noisy measurement of j1 on a1 (what the monitor reports).
    pub meas_a1: f32,
    /// ground-truth throughput of j1 on a2 — the pipeline target.
    pub truth_a2: f32,
    pub psi_j1: [f32; PSI_DIM],
    pub a1: AccelType,
    pub a2: AccelType,
}

impl<'a> DatasetBuilder<'a> {
    /// Build `n` pipeline-evaluation items with targets from `pool`
    /// and reference jobs from `ref_pool` (the catalog's history).
    pub fn pipeline_items(
        &self,
        n: usize,
        pool: &[(ModelFamily, u32)],
        ref_pool: &[(ModelFamily, u32)],
        salt: u64,
    ) -> Vec<PipelineItem> {
        let mut rng = Rng::seed_from_u64(self.seed ^ salt ^ 0x9303);
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            let j1_cfg = pool[rng.range_usize(0, pool.len())];
            let j2_cfg = Self::nearest_config(j1_cfg, ref_pool);
            let a1 = ACCEL_TYPES[rng.range_usize(0, ACCEL_TYPES.len())];
            let mut a2 = ACCEL_TYPES[rng.range_usize(0, ACCEL_TYPES.len())];
            while a2 == a1 {
                a2 = ACCEL_TYPES[rng.range_usize(0, ACCEL_TYPES.len())];
            }
            let j1 = mk_job(2 * i as u32, j1_cfg);
            let j2 = mk_job(2 * i as u32 + 1, j2_cfg);
            let empty = crate::workload::encoding::PSI_EMPTY;
            let mk_row = |a: AccelType, rng: &mut Rng| {
                let t2 = self.oracle.solo(&j2, a) * self.noise(rng, self.meas_sigma);
                p1_row(&j2.psi(), &empty, a, t2 as f32, 0.0, &j1.psi()).to_vec()
            };
            let p1_row_a1 = mk_row(a1, &mut rng);
            let p1_row_a2 = mk_row(a2, &mut rng);
            let meas_a1 =
                (self.oracle.solo(&j1, a1) * self.noise(&mut rng, self.meas_sigma)) as f32;
            out.push(PipelineItem {
                p1_row_a1,
                p1_row_a2,
                meas_a1,
                truth_a2: self.oracle.solo(&j1, a2) as f32,
                psi_j1: j1.psi(),
                a1,
                a2,
            });
        }
        out
    }
}

/// Shuffle + batch iterator for training.
pub fn batches(samples: &[Sample], batch: usize, seed: u64) -> Vec<(Vec<Vec<f32>>, Vec<[f32; 2]>)> {
    let mut idx: Vec<usize> = (0..samples.len()).collect();
    let mut rng = Rng::seed_from_u64(seed);
    rng.shuffle(&mut idx);
    idx.chunks(batch)
        .map(|c| {
            (
                c.iter().map(|&i| samples[i].x.clone()).collect(),
                c.iter().map(|&i| samples[i].y).collect(),
            )
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_is_disjoint_and_covers() {
        let (tr, va, te) = split_universe(3);
        assert_eq!(tr.len() + va.len() + te.len(), 22);
        for c in &te {
            assert!(!tr.contains(c) && !va.contains(c));
        }
        for c in &va {
            assert!(!tr.contains(c));
        }
        // deterministic
        let (tr2, _, _) = split_universe(3);
        assert_eq!(tr, tr2);
    }

    #[test]
    fn p1_rows_have_correct_dims_and_range() {
        let oracle = ThroughputOracle::new(5);
        let b = DatasetBuilder::new(&oracle, 5);
        let (tr, _, _) = split_universe(5);
        let s = b.p1_samples(50, &tr, &tr, 0);
        assert_eq!(s.len(), 50);
        for smp in &s {
            assert_eq!(smp.x.len(), P1_DIM);
            assert!(smp.y[0] > 0.0 && smp.y[0] <= 1.0);
            assert!(smp.y[1] >= 0.0 && smp.y[1] <= 1.0);
        }
        // some samples must involve the empty co-runner (y[1] == 0)
        assert!(s.iter().any(|s| s.y[1] == 0.0));
        assert!(s.iter().any(|s| s.y[1] > 0.0));
    }

    #[test]
    fn p2_rows_have_correct_dims() {
        let oracle = ThroughputOracle::new(5);
        let b = DatasetBuilder::new(&oracle, 5);
        let (tr, _, _) = split_universe(5);
        let s = b.p2_samples(50, &tr, &tr, 0);
        for smp in &s {
            assert_eq!(smp.x.len(), P2_PADDED);
            assert_eq!(&smp.x[34..40], &[0.0; 6]);
        }
    }

    #[test]
    fn datasets_are_deterministic() {
        let oracle = ThroughputOracle::new(5);
        let b = DatasetBuilder::new(&oracle, 5);
        let (tr, _, _) = split_universe(5);
        let s1 = b.p1_samples(10, &tr, &tr, 7);
        let s2 = b.p1_samples(10, &tr, &tr, 7);
        for (a, b) in s1.iter().zip(&s2) {
            assert_eq!(a.x, b.x);
            assert_eq!(a.y, b.y);
        }
    }

    #[test]
    fn p2_estimate_inputs_are_informative() {
        // The stale estimate of a2 must correlate with the target —
        // otherwise the refinement task would be unlearnable.
        let oracle = ThroughputOracle::new(5);
        let b = DatasetBuilder::new(&oracle, 5);
        let (tr, _, _) = split_universe(5);
        let s = b.p2_samples(300, &tr, &tr, 0);
        let xs: Vec<f64> = s.iter().map(|s| s.x[32] as f64).collect(); // est_a2_j1
        let ys: Vec<f64> = s.iter().map(|s| s.y[0] as f64).collect();
        let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
        let (mx, my) = (mean(&xs), mean(&ys));
        let cov: f64 = xs.iter().zip(&ys).map(|(x, y)| (x - mx) * (y - my)).sum();
        let vx: f64 = xs.iter().map(|x| (x - mx).powi(2)).sum();
        let vy: f64 = ys.iter().map(|y| (y - my).powi(2)).sum();
        assert!(cov / (vx.sqrt() * vy.sqrt()) > 0.7);
    }

    #[test]
    fn batches_cover_all_samples() {
        let oracle = ThroughputOracle::new(5);
        let b = DatasetBuilder::new(&oracle, 5);
        let (tr, _, _) = split_universe(5);
        let s = b.p1_samples(25, &tr, &tr, 0);
        let bs = batches(&s, 8, 0);
        assert_eq!(bs.iter().map(|(x, _)| x.len()).sum::<usize>(), 25);
        assert_eq!(bs.len(), 4); // 8+8+8+1
    }

    #[test]
    fn psi_dim_used() {
        assert_eq!(PSI_DIM, 8);
    }
}
