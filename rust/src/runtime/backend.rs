//! The estimator [`Backend`] abstraction: everything the coordinator
//! needs from a P1/P2 network, implemented by both the PJRT path
//! ([`Estimator`], compiled AOT artifacts) and the dependency-free
//! [`crate::runtime::NativeBackend`] (pure-Rust MLP). The coordinator
//! holds `Option<Box<dyn Backend>>`, so the whole
//! P1-estimate → monitor-measure → P2-refine learning loop is backend
//! agnostic — and CI runs it natively with zero external artifacts.
//!
//! | backend  | engine                  | artifacts | seeded init          |
//! |----------|-------------------------|-----------|----------------------|
//! | `pjrt`   | XLA PJRT CPU client     | required  | AOT `init` exec      |
//! | `native` | in-crate MLP (`native`) | none      | [`crate::util::Rng`] |
//! | `none`   | estimator-free priors   | none      | n/a                  |
//!
//! Shared contract (documented in `runtime/estimator.rs`, upheld by
//! both implementations and asserted in the native unit tests):
//! `predict` chunks rows by `pred_batch` and cycle-pads the final
//! chunk with repeated rows; `train_step` cycle-pads up to
//! `train_batch` (repeating real samples keeps gradients unbiased,
//! unlike zero-padding); the mutable state is the flat
//! `params…, m…, v…, adam_step` vector.

use crate::Result;

use super::estimator::Estimator;

/// A PJRT-backed estimator — the [`Estimator`] type itself; the alias
/// names the role it plays next to [`crate::runtime::NativeBackend`].
pub type PjrtBackend = Estimator;

/// One P1/P2 estimation network: seeded-initialized mutable model state
/// plus `predict` / `train_step` over plain f32 rows.
///
/// Construction is per-implementation (`Estimator::new` compiles AOT
/// artifacts; `NativeBackend::p1`/`p2` seed a pure-Rust MLP from
/// [`crate::util::Rng`]); everything after construction goes through
/// this trait.
pub trait Backend {
    /// Model key (e.g. `"p1_rnn"` for PJRT, `"p1_native"` for native).
    fn key(&self) -> &str;

    /// Input row width (`padded_dim` of the manifest / native spec).
    fn input_dim(&self) -> usize;

    /// Output width (2: the job slot + the co-runner slot).
    fn out_dim(&self) -> usize;

    /// Fixed training batch; smaller batches are cycle-padded up.
    fn train_batch(&self) -> usize;

    /// Prediction chunk size; longer row sets are chunked.
    fn pred_batch(&self) -> usize;

    /// Total f32 elements of the flat mutable state
    /// (`params…, m…, v…, adam_step`).
    fn state_dim(&self) -> usize;

    /// Adam steps taken since construction / [`Backend::reset`].
    fn steps_taken(&self) -> u64;

    /// Predict `[f32; 2]` outputs for arbitrarily many input rows.
    fn predict(&mut self, rows: &[Vec<f32>]) -> Result<Vec<[f32; 2]>>;

    /// One Adam step on `(x, y)` rows; returns `(mse_loss, mae)`.
    fn train_step(&mut self, xs: &[Vec<f32>], ys: &[[f32; 2]]) -> Result<(f32, f32)>;

    /// Restore the freshly initialized state (same seed ⇒ same state).
    fn reset(&mut self) -> Result<()>;

    /// Evaluate `(mse, mae)` of predictions against targets, without
    /// training.
    fn evaluate(&mut self, xs: &[Vec<f32>], ys: &[[f32; 2]]) -> Result<(f32, f32)> {
        let preds = self.predict(xs)?;
        let mut abs = 0.0f64;
        let mut sq = 0.0f64;
        let mut n = 0usize;
        for (p, y) in preds.iter().zip(ys) {
            for k in 0..2 {
                let e = (p[k] - y[k]) as f64;
                abs += e.abs();
                sq += e * e;
                n += 1;
            }
        }
        Ok(((sq / n.max(1) as f64) as f32, (abs / n.max(1) as f64) as f32))
    }
}

impl Backend for Estimator {
    fn key(&self) -> &str {
        Estimator::key(self)
    }

    fn input_dim(&self) -> usize {
        self.spec().padded_dim
    }

    fn out_dim(&self) -> usize {
        self.spec().out_dim
    }

    fn train_batch(&self) -> usize {
        self.spec().train_batch
    }

    fn pred_batch(&self) -> usize {
        self.spec().pred_batch
    }

    fn state_dim(&self) -> usize {
        let spec = self.spec();
        (0..spec.n_state()).map(|i| spec.state_elems(i)).sum()
    }

    fn steps_taken(&self) -> u64 {
        Estimator::steps_taken(self)
    }

    fn predict(&mut self, rows: &[Vec<f32>]) -> Result<Vec<[f32; 2]>> {
        Estimator::predict(self, rows)
    }

    fn train_step(&mut self, xs: &[Vec<f32>], ys: &[[f32; 2]]) -> Result<(f32, f32)> {
        Estimator::train_step(self, xs, ys)
    }

    fn reset(&mut self) -> Result<()> {
        Estimator::reset(self)
    }

    fn evaluate(&mut self, xs: &[Vec<f32>], ys: &[[f32; 2]]) -> Result<(f32, f32)> {
        Estimator::evaluate(self, xs, ys)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::NativeBackend;

    #[test]
    fn native_backend_is_object_safe_and_usable_boxed() {
        let mut be: Box<dyn Backend> = Box::new(NativeBackend::p1(5));
        assert_eq!(be.key(), "p1_native");
        let rows = vec![vec![0.25f32; be.input_dim()]; 3];
        let preds = be.predict(&rows).unwrap();
        assert_eq!(preds.len(), 3);
        let ys = vec![[0.5f32, 0.0f32]; 3];
        let (loss, mae) = be.train_step(&rows, &ys).unwrap();
        assert!(loss.is_finite() && mae.is_finite());
        assert_eq!(be.steps_taken(), 1);
        let (mse, mae2) = be.evaluate(&rows, &ys).unwrap();
        assert!(mse >= 0.0 && mae2 >= 0.0);
    }

    #[test]
    fn state_dim_matches_flat_layout() {
        let be = NativeBackend::p2(5);
        // params…, m…, v…, adam_step
        assert_eq!(be.state_dim() % 3, 1);
        assert_eq!(Backend::state_dim(&be), be.state().len());
    }
}
