//! PJRT engine: one CPU client, compiled executables per model.
//!
//! Follows the HLO-text interchange pattern (see /opt/xla-example and
//! aot.py): `HloModuleProto::from_text_file` → `XlaComputation` →
//! `client.compile`. Compilation happens once at startup; the request
//! path only executes.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::Result;

use super::manifest::{Manifest, ModelSpec};

/// The PJRT client + manifest; cheap to clone (Arc inside the xla crate
/// types is not exposed, so we wrap in Arc ourselves).
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    dir: PathBuf,
}

/// One model's compiled executables + spec.
pub struct CompiledModel {
    pub spec: ModelSpec,
    pub key: String,
    pub init: xla::PjRtLoadedExecutable,
    pub fwd: xla::PjRtLoadedExecutable,
    pub train: xla::PjRtLoadedExecutable,
}

impl Engine {
    /// Create the CPU client and read the manifest from `dir`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Arc<Self>> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("PJRT cpu client: {e}"))?;
        crate::log_info!(
            "engine up: platform={} devices={} models={}",
            client.platform_name(),
            client.device_count(),
            manifest.models.len()
        );
        Ok(Arc::new(Self {
            client,
            manifest,
            dir,
        }))
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    fn compile_file(&self, fname: &str) -> Result<xla::PjRtLoadedExecutable> {
        let path = self.dir.join(fname);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow::anyhow!("parse {path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {path:?}: {e}"))
    }

    /// Compile all three executables of model `key` (e.g. `"p1_rnn"`).
    pub fn load_model(&self, key: &str) -> Result<CompiledModel> {
        let spec = self.manifest.model(key)?.clone();
        let t0 = std::time::Instant::now();
        let init = self.compile_file(&spec.files.init)?;
        let fwd = self.compile_file(&spec.files.fwd)?;
        let train = self.compile_file(&spec.files.train)?;
        crate::log_info!("compiled {key} in {} ms", t0.elapsed().as_millis());
        Ok(CompiledModel {
            spec,
            key: key.to_string(),
            init,
            fwd,
            train,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_present() -> bool {
        Path::new("artifacts/manifest.json").exists()
    }

    #[test]
    fn engine_loads_and_compiles_one_model() {
        if !artifacts_present() {
            eprintln!("skipping: no artifacts");
            return;
        }
        let engine = Engine::load("artifacts").unwrap();
        let model = engine.load_model("p1_ff").unwrap();
        assert_eq!(model.spec.input_dim, 32);
    }

    #[test]
    fn unknown_model_errors() {
        if !artifacts_present() {
            return;
        }
        let engine = Engine::load("artifacts").unwrap();
        assert!(engine.load_model("p9_mlp").is_err());
    }
}
